"""L1 correctness: Pallas reuse kernel vs the pure-jnp oracle.

The integer kernel must be BIT-EXACT against dense matmul (reuse is a
scheduling transformation); the f32 wrapper must match to round-off.
Hypothesis sweeps shapes, dtypes ranges, and block sizes.
"""

import sys

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

sys.path.insert(0, ".")

from compile.kernels.ref import (
    dense_matmul_batch_ref,
    dense_matmul_ref,
    qmatmul_f32_ref,
)
from compile.kernels.reuse_matmul import (
    CODE_OFFSET,
    N_CODES,
    quantize_activations,
    qmatmul_f32,
    reuse_matmul,
    reuse_matmul_batch,
)


def rand_case(rng, r, c):
    x = rng.integers(-127, 128, r).astype(np.int32)
    w = rng.integers(0, N_CODES, (r, c)).astype(np.int32)
    return jnp.array(x), jnp.array(w)


class TestReuseMatmulExact:
    @pytest.mark.parametrize("r,c,bc", [(8, 16, 16), (64, 128, 64), (128, 512, 512), (100, 96, 32)])
    def test_bit_exact_vs_dense(self, r, c, bc):
        x, w = rand_case(np.random.default_rng(r * 1000 + c), r, c)
        y = reuse_matmul(x, w, block_cols=bc)
        ref = dense_matmul_ref(x, w)
        np.testing.assert_array_equal(np.array(y), np.array(ref))

    def test_block_size_invariance(self):
        x, w = rand_case(np.random.default_rng(7), 48, 240)
        outs = [np.array(reuse_matmul(x, w, block_cols=bc)) for bc in (16, 48, 80, 240)]
        for o in outs[1:]:
            np.testing.assert_array_equal(o, outs[0])

    def test_extreme_codes(self):
        # All-min / all-max codes exercise the table edges.
        r, c = 16, 32
        x = jnp.full((r,), -127, jnp.int32)
        w = jnp.full((r, c), 0, jnp.int32)  # code -127
        y = reuse_matmul(x, w, block_cols=c)
        np.testing.assert_array_equal(np.array(y), np.full(c, (-127) * (-127) * r))
        w = jnp.full((r, c), N_CODES - 1, jnp.int32)  # code +127
        y = reuse_matmul(x, w, block_cols=c)
        np.testing.assert_array_equal(np.array(y), np.full(c, (-127) * 127 * r))

    def test_zero_input_vector(self):
        x = jnp.zeros((32,), jnp.int32)
        _, w = rand_case(np.random.default_rng(3), 32, 64)
        y = reuse_matmul(x, w, block_cols=64)
        np.testing.assert_array_equal(np.array(y), np.zeros(64, np.int32))

    def test_bad_block_divisor_rejected(self):
        x, w = rand_case(np.random.default_rng(4), 8, 30)
        with pytest.raises(ValueError, match="must divide"):
            reuse_matmul(x, w, block_cols=16)

    @settings(max_examples=40, deadline=None)
    @given(
        r=st.integers(1, 96),
        c_blocks=st.integers(1, 4),
        bc=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, r, c_blocks, bc, seed):
        c = c_blocks * bc
        x, w = rand_case(np.random.default_rng(seed), r, c)
        y = reuse_matmul(x, w, block_cols=bc)
        np.testing.assert_array_equal(np.array(y), np.array(dense_matmul_ref(x, w)))

    @settings(max_examples=20, deadline=None)
    @given(
        s=st.integers(1, 8),
        r=st.integers(4, 64),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_batch(self, s, r, seed):
        rng = np.random.default_rng(seed)
        c = 32
        xs = jnp.array(rng.integers(-127, 128, (s, r)).astype(np.int32))
        w = jnp.array(rng.integers(0, N_CODES, (r, c)).astype(np.int32))
        y = reuse_matmul_batch(xs, w, block_cols=32)
        np.testing.assert_array_equal(np.array(y), np.array(dense_matmul_batch_ref(xs, w)))


class TestQuantization:
    def test_quantize_bounds_and_scale(self):
        x = jnp.array([[-2.0, 0.5, 1.0, 2.0]], jnp.float32)
        q, s = quantize_activations(x)
        assert np.abs(np.array(q)).max() <= 127
        np.testing.assert_allclose(float(s), 2.0 / 127.0, rtol=1e-6)

    def test_roundtrip_error_half_lsb(self):
        rng = np.random.default_rng(5)
        x = jnp.array(rng.normal(0, 1, (4, 64)).astype(np.float32))
        q, s = quantize_activations(x)
        err = np.abs(np.array(q) * float(s) - np.array(x))
        assert err.max() <= float(s) / 2 + 1e-6

    @settings(max_examples=25, deadline=None)
    @given(
        s=st.integers(1, 6),
        r=st.integers(8, 64),
        c_blocks=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_f32_wrapper_matches_ref(self, s, r, c_blocks, seed):
        rng = np.random.default_rng(seed)
        c = c_blocks * 16
        x = jnp.array(rng.normal(0, 1, (s, r)).astype(np.float32))
        w = jnp.array(rng.integers(0, N_CODES, (r, c)).astype(np.int32))
        scale = np.float32(0.02 * 4 / 127)
        y = qmatmul_f32(x, w, scale, block_cols=16)
        ref = qmatmul_f32_ref(x, w, scale)
        np.testing.assert_allclose(np.array(y), np.array(ref), rtol=1e-5, atol=1e-5)

    def test_code_offset_consistency(self):
        assert CODE_OFFSET == 127
        assert N_CODES == 255
