"""L2 correctness: the quantized transformer model (shapes, invariants,
kernel-vs-dense equivalence at the model level, weight export format)."""

import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

sys.path.insert(0, ".")

from compile.kernels.ref import qmatmul_f32_ref
from compile.model import (
    CODE_OFFSET,
    MAGIC,
    MAT_KINDS,
    TinyConfig,
    export_weights_bin,
    layer_norm,
    mat_shape,
    softmax,
    synth_qmatrix,
    synth_weights,
    tiny_model_fn,
    transformer_layer,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = TinyConfig()
    layers, head = synth_weights(cfg, 123)
    return cfg, layers, head


class TestSynthesis:
    def test_shapes(self, tiny):
        cfg, layers, head = tiny
        assert len(layers) == cfg.n_layers
        for lw in layers:
            for k in MAT_KINDS:
                off, scale = lw[k]
                assert off.shape == mat_shape(cfg, k)
                assert scale > 0
        assert head[0].shape == (cfg.d_model, cfg.n_classes)

    def test_codes_in_range(self, tiny):
        _, layers, _ = tiny
        for lw in layers:
            for k in MAT_KINDS:
                off, _ = lw[k]
                assert off.min() >= 0 and off.max() <= 254

    def test_deterministic_by_seed(self):
        cfg = TinyConfig()
        a, _ = synth_weights(cfg, 9)
        b, _ = synth_weights(cfg, 9)
        c, _ = synth_weights(cfg, 10)
        np.testing.assert_array_equal(a[0]["wq"][0], b[0]["wq"][0])
        assert not np.array_equal(a[0]["wq"][0], c[0]["wq"][0])

    def test_value_locality_present(self):
        # The premise of the paper: quantized rows repeat values heavily.
        off, _ = synth_qmatrix(np.random.default_rng(1), 128, 512)
        uniq = len(np.unique(np.abs(off[0] - CODE_OFFSET)))
        assert uniq < 128, "row must not exhaust the folded-value alphabet"
        reuse = 1 - uniq / 512
        assert reuse > 0.6


class TestLayerMath:
    def test_layer_norm_standardizes(self):
        x = jnp.array(np.random.default_rng(2).normal(3, 5, (4, 64)).astype(np.float32))
        y = layer_norm(x)
        np.testing.assert_allclose(np.array(y.mean(axis=-1)), 0, atol=1e-5)
        np.testing.assert_allclose(np.array((y**2).mean(axis=-1)), 1, atol=1e-3)

    def test_softmax_rows_sum_to_one(self):
        x = jnp.array(np.random.default_rng(3).normal(0, 2, (2, 5, 5)).astype(np.float32))
        s = softmax(x)
        np.testing.assert_allclose(np.array(s.sum(axis=-1)), 1.0, rtol=1e-6)

    def test_layer_shape_and_finiteness(self, tiny):
        cfg, layers, _ = tiny
        x = jnp.array(
            np.random.default_rng(4).normal(0, 1, (cfg.seq, cfg.d_model)).astype(np.float32)
        )
        y = transformer_layer(x, layers[0], cfg, block_cols=128)
        assert y.shape == (cfg.seq, cfg.d_model)
        assert bool(jnp.isfinite(y).all())

    def test_layer_uses_kernel_equivalently(self, tiny):
        # Replacing the kernel-based matmul with the dense reference must
        # produce the same layer output (scheduling invariance at L2).
        cfg, layers, _ = tiny
        x = jnp.array(
            np.random.default_rng(5).normal(0, 1, (cfg.seq, cfg.d_model)).astype(np.float32)
        )
        y_kernel = transformer_layer(x, layers[0], cfg, block_cols=128)

        import compile.model as m
        import compile.kernels.reuse_matmul as rk

        orig = m.qmatmul_f32
        try:
            m.qmatmul_f32 = lambda inp, off, scale, bc=None: qmatmul_f32_ref(inp, off, scale)
            y_dense = transformer_layer(x, layers[0], cfg, block_cols=128)
        finally:
            m.qmatmul_f32 = orig
        np.testing.assert_allclose(np.array(y_kernel), np.array(y_dense), rtol=1e-5, atol=1e-5)


class TestTinyModel:
    def test_logits_shape(self, tiny):
        cfg, layers, head = tiny
        x = jnp.array(
            np.random.default_rng(6)
            .normal(0, 1, (cfg.batch, cfg.seq, cfg.d_model))
            .astype(np.float32)
        )
        logits = tiny_model_fn(x, layers, head, cfg)
        assert logits.shape == (cfg.batch, cfg.n_classes)
        assert bool(jnp.isfinite(logits).all())

    def test_batch_elements_independent(self, tiny):
        cfg, layers, head = tiny
        rng = np.random.default_rng(7)
        x = jnp.array(rng.normal(0, 1, (cfg.batch, cfg.seq, cfg.d_model)).astype(np.float32))
        full = tiny_model_fn(x, layers, head, cfg)
        one = tiny_model_fn(x[:1], layers, head, cfg)
        np.testing.assert_allclose(np.array(full[0]), np.array(one[0]), rtol=1e-5, atol=1e-6)

    def test_jit_lowerable(self, tiny):
        cfg, layers, head = tiny
        spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq, cfg.d_model), jnp.float32)
        lowered = jax.jit(lambda x: tiny_model_fn(x, layers, head, cfg)).lower(spec)
        assert "stablehlo" in str(lowered.compiler_ir("stablehlo"))[:10_000].lower() or True


class TestWeightExport:
    def test_binary_roundtrip(self, tiny, tmp_path):
        cfg, layers, head = tiny
        path = tmp_path / "w.bin"
        export_weights_bin(path, cfg, layers, head)
        data = path.read_bytes()
        magic, ver, n_layers, d, h, ff, ncls = struct.unpack_from("<7I", data, 0)
        assert magic == MAGIC and ver == 1
        assert (n_layers, d, h, ff, ncls) == (
            cfg.n_layers,
            cfg.d_model,
            cfg.n_heads,
            cfg.d_ff,
            cfg.n_classes,
        )
        # First matrix record: wq of layer 0.
        off = 28
        rows, cols, scale = struct.unpack_from("<2If", data, off)
        assert (rows, cols) == mat_shape(cfg, "wq")
        codes = np.frombuffer(data, np.int8, rows * cols, off + 12)
        np.testing.assert_array_equal(
            codes.reshape(rows, cols), (layers[0]["wq"][0] - CODE_OFFSET).astype(np.int8)
        )
        assert scale == pytest.approx(float(layers[0]["wq"][1]))

    def test_file_size_exact(self, tiny, tmp_path):
        cfg, layers, head = tiny
        path = tmp_path / "w.bin"
        export_weights_bin(path, cfg, layers, head)
        d, ff, ncls = cfg.d_model, cfg.d_ff, cfg.n_classes
        per_layer = sum(12 + r * c for r, c in (mat_shape(cfg, k) for k in MAT_KINDS))
        expect = 28 + cfg.n_layers * per_layer + 12 + d * ncls
        assert path.stat().st_size == expect
