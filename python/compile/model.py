"""L2: the quantized transformer model in JAX, calling the L1 reuse kernel
for every weight matmul.

Architecture mirrors ``rust/src/exec/layer.rs`` exactly (post-LN,
non-affine layer norm, ReLU FFN, per-tensor dynamic activation
quantization), so the Rust functional executor and the AOT artifact can be
cross-checked on the same weights.

Weights are synthesized here (numpy RNG, Gaussian, percentile-clip grid —
substitution S1 in DESIGN.md) and exported to ``artifacts/tiny_weights.bin``
in a simple binary format the Rust side parses; the AOT artifact bakes the
same weights in as constants so the PJRT executable is self-contained.
"""

import dataclasses
import struct

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.reuse_matmul import CODE_OFFSET, qmatmul_f32

# Matrix kinds, in the order rust's MatKind::ALL uses.
MAT_KINDS = ("wq", "wk", "wv", "wo", "ff1", "ff2")

# Weight synthesis parameters (keep in sync with rust model::synth
# defaults: σ=0.02, percentile clip at 4σ).
SIGMA = 0.02
CLIP_SIGMAS = 4.0


@dataclasses.dataclass
class TinyConfig:
    """Mirror of rust ``ModelConfig::tiny()`` plus a classifier head."""

    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 256
    n_classes: int = 4
    seq: int = 32
    batch: int = 4

    @property
    def d_head(self):
        return self.d_model // self.n_heads


def mat_shape(cfg, kind):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "wq": (d, d),
        "wk": (d, d),
        "wv": (d, d),
        "wo": (d, d),
        "ff1": (d, f),
        "ff2": (f, d),
    }[kind]


def synth_qmatrix(rng, rows, cols):
    """Gaussian weights quantized on the percentile-clip grid.

    Returns (offsets int32 [rows, cols] in [0, 254], scale f32).
    """
    w = rng.normal(0.0, SIGMA, (rows, cols))
    scale = SIGMA * CLIP_SIGMAS / 127.0
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int32)
    return q + CODE_OFFSET, np.float32(scale)


def synth_weights(cfg, seed):
    """All layer weights plus the classifier head.

    Returns a pytree: list of per-layer dicts {kind: (off, scale)}, and
    (head_off, head_scale) mapping pooled d_model → n_classes.
    """
    rng = np.random.default_rng(seed)
    layers = []
    for _ in range(cfg.n_layers):
        layers.append({k: synth_qmatrix(rng, *mat_shape(cfg, k)) for k in MAT_KINDS})
    head = synth_qmatrix(rng, cfg.d_model, cfg.n_classes)
    return layers, head


def layer_norm(x):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + 1e-5)


def softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def transformer_layer(x, weights, cfg, block_cols):
    """One layer forward: x [S, D] f32 → [S, D] f32.

    Every weight matmul routes through the Pallas reuse kernel.
    """
    s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    def mm(inp, kind):
        off, scale = weights[kind]
        return qmatmul_f32(inp, off, scale, block_cols)

    q = mm(x, "wq").reshape(s, h, dh)
    k = mm(x, "wk").reshape(s, h, dh)
    v = mm(x, "wv").reshape(s, h, dh)

    scores = jnp.einsum("ihd,jhd->hij", q, k) / jnp.sqrt(jnp.float32(dh))
    attn = softmax(scores)
    ctx = jnp.einsum("hij,jhd->ihd", attn, v).reshape(s, d)

    attn_out = mm(ctx, "wo")
    h1 = layer_norm(x + attn_out)

    ff = jnp.maximum(mm(h1, "ff1"), 0.0)
    ff2 = mm(ff, "ff2")
    return layer_norm(h1 + ff2)


def tiny_model_fn(x, layers, head, cfg, block_cols=128):
    """The end-to-end tiny classifier: embeddings [B, S, D] → logits
    [B, n_classes] (mean-pool + quantized head).

    The batch loop is unrolled at trace time (B is small and static)
    rather than vmapped: vmap over the interpret-mode Pallas call lowers
    to constructs the pinned xla_extension 0.5.1 (the Rust runtime's XLA)
    miscompiles to zeros, while the unrolled form round-trips exactly.
    """

    def one_seq(seq_x):
        h = seq_x
        for lw in layers:
            h = transformer_layer(h, lw, cfg, block_cols)
        pooled = jnp.mean(h, axis=0, keepdims=True)  # [1, D]
        off, scale = head
        return qmatmul_f32(pooled, off, scale, block_cols=cfg.n_classes)[0]

    return jnp.stack([one_seq(x[b]) for b in range(x.shape[0])])


MAGIC = 0x41584C4D  # "AXLM"


def export_weights_bin(path, cfg, layers, head):
    """Binary weight export for the Rust side.

    Layout (little endian):
      u32 magic, u32 version, u32 n_layers, u32 d_model, u32 n_heads,
      u32 d_ff, u32 n_classes
      then per layer, per kind in MAT_KINDS order:
        u32 rows, u32 cols, f32 scale, rows*cols i8 codes (offset removed)
      then the head in the same record format.
    """
    with open(path, "wb") as f:
        f.write(
            struct.pack(
                "<7I",
                MAGIC,
                1,
                cfg.n_layers,
                cfg.d_model,
                cfg.n_heads,
                cfg.d_ff,
                cfg.n_classes,
            )
        )

        def write_mat(off, scale):
            rows, cols = off.shape
            f.write(struct.pack("<2If", rows, cols, float(scale)))
            codes = (off - CODE_OFFSET).astype(np.int8)
            f.write(codes.tobytes())

        for lw in layers:
            for k in MAT_KINDS:
                write_mat(*lw[k])
        write_mat(*head)
