"""AOT compile path: lower the L2/L1 computations to HLO **text** and write
them (plus the weight binary and a manifest) into ``artifacts/``.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Python runs ONCE, at build time (``make artifacts``); the Rust binary is
self-contained afterwards.

Weight codes are passed as **runtime parameters** (int32 offset tensors in
a canonical order: per layer wq wk wv wo ff1 ff2, then the head), NOT baked
as constants: xla_extension 0.5.1 mis-constant-folds the gather over baked
weight tensors (verified bit-exact with parameters, garbage with
constants). Scales are scalars and bake safely. The Rust runtime feeds the
parameters from tiny_weights.bin.

Artifacts:
  tiny_model.hlo.txt        ([B,S,D] f32, 13 × i32 weights) → [B,n_classes] f32
  tiny_layer.hlo.txt        ([S,D] f32, 6 × i32 weights) → [S,D] f32
  reuse_matmul_128.hlo.txt  ([R=128] i32, [128,128] i32) → [128] i32
  reuse_matmul_768.hlo.txt  ([R=768] i32, [768,768] i32) → [768] i32
  tiny_weights.bin          int8 codes + scales (runtime weight source)
  manifest.toml             shapes/dtypes/seed for the Rust loader
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.reuse_matmul import reuse_matmul
from .model import TinyConfig, export_weights_bin, synth_weights, tiny_model_fn, transformer_layer

SEED = 20250710


def to_hlo_text(lowered):
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, args, path):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    cfg = TinyConfig()
    layers, head = synth_weights(cfg, SEED)
    from .model import MAT_KINDS

    def wspec(off):
        return jax.ShapeDtypeStruct(off.shape, jnp.int32)

    # 1. End-to-end tiny classifier. Weight codes are parameters in
    #    canonical order; scales are baked scalars (see module docs).
    def model_fn(x, *w_params):
        rebuilt, k = [], 0
        for lw in layers:
            d = {}
            for kind in MAT_KINDS:
                d[kind] = (w_params[k], lw[kind][1])
                k += 1
            rebuilt.append(d)
        head_p = (w_params[k], head[1])
        return (tiny_model_fn(x, rebuilt, head_p, cfg),)

    x_spec = jax.ShapeDtypeStruct((cfg.batch, cfg.seq, cfg.d_model), jnp.float32)
    w_specs = [wspec(lw[kind][0]) for lw in layers for kind in MAT_KINDS]
    w_specs.append(wspec(head[0]))
    lower_to_file(model_fn, (x_spec, *w_specs), f"{out}/tiny_model.hlo.txt")

    # 2. Single layer (layer 0), for layer-level integration tests.
    def layer_fn(x, *w_params):
        d = {
            kind: (w_params[i], layers[0][kind][1])
            for i, kind in enumerate(MAT_KINDS)
        }
        return (transformer_layer(x, d, cfg, block_cols=128),)

    l_spec = jax.ShapeDtypeStruct((cfg.seq, cfg.d_model), jnp.float32)
    l_wspecs = [wspec(layers[0][kind][0]) for kind in MAT_KINDS]
    lower_to_file(layer_fn, (l_spec, *l_wspecs), f"{out}/tiny_layer.hlo.txt")

    # 3. Raw reuse-matmul kernels at two shapes (bit-exact integration
    #    tests + runtime microbenchmarks).
    for r, c, bc in ((128, 128, 128), (768, 768, 256)):
        xq = jax.ShapeDtypeStruct((r,), jnp.int32)
        wq = jax.ShapeDtypeStruct((r, c), jnp.int32)
        lower_to_file(
            lambda x, w, bc=bc: (reuse_matmul(x, w, block_cols=bc),),
            (xq, wq),
            f"{out}/reuse_matmul_{r}.hlo.txt",
        )

    # 4. Weights for the Rust functional cross-check.
    export_weights_bin(f"{out}/tiny_weights.bin", cfg, layers, head)
    print(f"wrote {out}/tiny_weights.bin")

    # 5. Manifest consumed by rust runtime::artifacts.
    with open(f"{out}/manifest.toml", "w") as f:
        f.write(
            "\n".join(
                [
                    "[tiny]",
                    f"batch = {cfg.batch}",
                    f"seq = {cfg.seq}",
                    f"d_model = {cfg.d_model}",
                    f"n_layers = {cfg.n_layers}",
                    f"n_heads = {cfg.n_heads}",
                    f"d_ff = {cfg.d_ff}",
                    f"n_classes = {cfg.n_classes}",
                    f"seed = {SEED}",
                    "",
                    "[kernels]",
                    "shapes = [128, 768]",
                    "",
                ]
            )
        )
    print(f"wrote {out}/manifest.toml")


if __name__ == "__main__":
    main()
