"""Pure-jnp correctness oracles for the Pallas kernels.

The oracle is the dense formulation of the same arithmetic: the reuse
kernel is a *scheduling* transformation, so its output must be **bit
identical** to the dense int32 matmul (no tolerance), and the f32 wrapper
must match the dense dequantized matmul to f32 round-off.
"""

import jax.numpy as jnp

from .reuse_matmul import CODE_OFFSET, quantize_activations


def dense_matmul_ref(x_q, w_off):
    """[R] int32 × [R, C] offsets → [C] int32 exact."""
    w = w_off - CODE_OFFSET
    return jnp.einsum("r,rc->c", x_q, w).astype(jnp.int32)


def dense_matmul_batch_ref(x_q, w_off):
    """[S, R] × [R, C] → [S, C] int32 exact."""
    w = w_off - CODE_OFFSET
    return jnp.einsum("sr,rc->sc", x_q, w).astype(jnp.int32)


def qmatmul_f32_ref(x, w_off, w_scale):
    """Dense reference of kernels.reuse_matmul.qmatmul_f32."""
    q, s_x = quantize_activations(x)
    y = dense_matmul_batch_ref(q, w_off)
    return y.astype(jnp.float32) * (s_x * w_scale)
