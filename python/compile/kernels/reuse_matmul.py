"""L1: the computation-reuse matmul as a Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): AxLLM's Result Cache
is an SRAM next to a multiplier on a 15nm ASIC. On TPU the same insight —
*compute each product ``x[i]·u`` once per unique quantized value ``u`` and
reuse it for every repeat* — maps to a **product table + gather**:

1. build ``T[i, v] = x[i] * dq(v)`` for all 2^q code values ``v`` (one
   multiplication per (input element, unique value) — exactly the work the
   RC's compute path performs), materialized in VMEM (255 × 4 B per input
   element — tiny);
2. evaluate ``y[j] = Σ_i T[i, W_idx[i, j]]`` as a gather + reduction, the
   reuse path: weights are stored as **uint8 indices into the table**, the
   paper's "weights as pointers into the RC" (§III.b).

BlockSpec tiles the output columns, mirroring the paper's §IV bounded
512-column rounds (the HBM↔VMEM schedule the ASIC expresses with W_buff /
Out_buff sizing).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; numerics are validated on the interpret path and the same
HLO runs from Rust (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Signed 8-bit codes live in [-127, 127]; code -128 is excluded by the
# symmetric quantizer (it would break sign-folding), so the table has 255
# entries addressed by the unsigned offset ``code + 127``.
N_CODES = 255
CODE_OFFSET = 127

# Default output-column tile — the paper's §IV round width.
DEFAULT_BLOCK_COLS = 512


def _reuse_matmul_kernel(x_ref, w_ref, o_ref):
    """One grid step: one input row × one block of weight columns.

    x_ref: [1, R] int32 — quantized input row (codes).
    w_ref: [R, C_blk] int32 — weight codes as table offsets in [0, 254].
    o_ref: [1, C_blk] int32 — output partial sums for this (row, block).
    """
    x = x_ref[0, :]
    # Product table: the Result Cache. One multiply per (i, unique value):
    # R × 255 multiplications regardless of C — all C·R products are then
    # *reused* from the table.
    codes = jnp.arange(N_CODES, dtype=jnp.int32) - CODE_OFFSET
    table = x[:, None] * codes[None, :]  # [R, 255] in VMEM
    # Reuse path: gather each weight's cached product and accumulate.
    w = w_ref[...]
    gathered = jnp.take_along_axis(table, w, axis=1)  # [R, C_blk]
    o_ref[0, :] = jnp.sum(gathered, axis=0, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_cols",))
def reuse_matmul_batch(x_q, w_off, block_cols=DEFAULT_BLOCK_COLS):
    """``y[s, j] = Σ_i x_q[s, i] · (w_off[i, j] − 127)`` via the reuse
    kernel.

    Batching is expressed natively in the Pallas grid — one grid row per
    input row — NOT via `jax.vmap`: vmapping the interpret-mode
    `pallas_call` lowers to HLO that the pinned xla_extension 0.5.1 (the
    Rust runtime's XLA) miscompiles to zeros, while the gridded form
    round-trips bit-exactly.

    Args:
      x_q: [S, R] int32, quantized input codes in [-127, 127].
      w_off: [R, C] int32, weight codes offset to [0, 254].
      block_cols: output-column tile width (static).

    Returns:
      [S, C] int32 exact integer matmul result.
    """
    s, r = x_q.shape
    r2, c = w_off.shape
    if r != r2:
        raise ValueError(f"x rows {r} != W rows {r2}")
    bc = min(block_cols, c)
    if c % bc != 0:
        raise ValueError(f"block_cols {bc} must divide C {c}")
    grid = (s, c // bc)
    return pl.pallas_call(
        _reuse_matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, r), lambda i, j: (i, 0)),
            pl.BlockSpec((r, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, c), jnp.int32),
        interpret=True,
    )(x_q, w_off)


def reuse_matmul(x_q, w_off, block_cols=DEFAULT_BLOCK_COLS):
    """Single-vector reuse matmul: x_q [R] → [C] (batch of one)."""
    return reuse_matmul_batch(x_q[None, :], w_off, block_cols)[0]


def quantize_activations(x, qmax=127.0):
    """Symmetric dynamic per-tensor activation quantization (the int8
    input datapath of the accelerator). Returns (codes int32, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    scale = amax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    return q, scale


def qmatmul_f32(x, w_off, w_scale, block_cols=DEFAULT_BLOCK_COLS):
    """f32 activations × quantized weights through the reuse kernel.

    x: [S, R] f32. w_off: [R, C] int32 offsets. Returns [S, C] f32.
    """
    q, s_x = quantize_activations(x)
    y = reuse_matmul_batch(q, w_off, block_cols)
    return y.astype(jnp.float32) * (s_x * w_scale)
