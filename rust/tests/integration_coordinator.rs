//! Integration: the serving coordinator over real artifacts (requires
//! `make artifacts`) — trace serving, batching behaviour, attribution,
//! and the threaded server front-end.

use axllm::config::{AcceleratorConfig, Dataset};
use axllm::coordinator::{BatchPolicy, Engine, Server};
use axllm::runtime::ArtifactSet;
use axllm::workload::{Request, TraceGenerator};

fn engine() -> Engine {
    let dir = ArtifactSet::default_dir();
    assert!(
        dir.join("manifest.toml").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    Engine::load(&dir, AcceleratorConfig::paper()).unwrap()
}

#[test]
fn serve_trace_answers_every_request() {
    let e = engine();
    let trace = TraceGenerator::new(Dataset::AgNews, 300.0, 11).take(40);
    let ids: Vec<u64> = trace.iter().map(|r| r.id).collect();
    let (results, summary) = e
        .serve_trace(
            trace,
            BatchPolicy {
                max_batch: 4,
                max_wait_s: 0.005,
            },
        )
        .unwrap();
    assert_eq!(results.len(), 40);
    let mut got: Vec<u64> = results.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, ids);
    assert_eq!(summary.requests, 40);
    assert!(summary.batches >= 10, "≥10 batches at max_batch=4");
    assert!(summary.throughput_rps > 0.0);
    assert!(summary.sim_cycles > 0);
    assert!(summary.sim_speedup > 1.3);
    assert!(results.iter().all(|r| r.logits.len() == 4));
    assert!(results
        .iter()
        .all(|r| r.logits.iter().all(|v| v.is_finite())));
}

#[test]
fn identical_request_ids_get_identical_logits() {
    // Embeddings derive deterministically from request id — the same id
    // served in different batches must produce the same logits.
    let e = engine();
    let mk = |arrival: f64| Request {
        id: 123,
        dataset: Dataset::Imdb,
        seq_len: 20,
        arrival_s: arrival,
        gen_tokens: 0,
        adapter: None,
        prefix: None,
        slo: axllm::workload::SloClass::Standard,
    };
    let (r1, _) = e
        .serve_trace(vec![mk(0.0)], BatchPolicy::default())
        .unwrap();
    let (r2, _) = e
        .serve_trace(vec![mk(5.0)], BatchPolicy::default())
        .unwrap();
    assert_eq!(r1[0].logits, r2[0].logits);
}

#[test]
fn attribution_scales_with_sequence_length() {
    let e = engine();
    let mk = |id: u64, len: usize| Request {
        id,
        dataset: Dataset::Imdb,
        seq_len: len,
        arrival_s: id as f64 * 0.001,
        gen_tokens: 0,
        adapter: None,
        prefix: None,
        slo: axllm::workload::SloClass::Standard,
    };
    let (results, _) = e
        .serve_trace(
            vec![mk(0, 8), mk(1, 32)],
            BatchPolicy {
                max_batch: 2,
                max_wait_s: 0.01,
            },
        )
        .unwrap();
    let short = results.iter().find(|r| r.id == 0).unwrap();
    let long = results.iter().find(|r| r.id == 1).unwrap();
    assert!(long.sim_cycles > 3 * short.sim_cycles);
    assert!(long.sim_energy_j > 3.0 * short.sim_energy_j);
}

#[test]
fn queue_wait_reflects_batching_policy() {
    let e = engine();
    // Two requests far apart with a long max_wait: the first waits for
    // the timeout, not for the second request.
    let trace = vec![
        Request {
            id: 0,
            dataset: Dataset::AgNews,
            seq_len: 16,
            arrival_s: 0.0,
            gen_tokens: 0,
            adapter: None,
            prefix: None,
            slo: axllm::workload::SloClass::Standard,
        },
        Request {
            id: 1,
            dataset: Dataset::AgNews,
            seq_len: 16,
            arrival_s: 1.0,
            gen_tokens: 0,
            adapter: None,
            prefix: None,
            slo: axllm::workload::SloClass::Standard,
        },
    ];
    let (results, summary) = e
        .serve_trace(
            trace,
            BatchPolicy {
                max_batch: 4,
                max_wait_s: 0.02,
            },
        )
        .unwrap();
    let first = results.iter().find(|r| r.id == 0).unwrap();
    assert!(
        (first.queue_wait_s - 0.02).abs() < 1e-6,
        "first request should wait exactly max_wait: {}",
        first.queue_wait_s
    );
    assert_eq!(summary.batches, 2);
}

#[test]
fn threaded_server_round_trips() {
    let server = Server::start(
        ArtifactSet::default_dir(),
        AcceleratorConfig::paper(),
        BatchPolicy {
            max_batch: 4,
            max_wait_s: 0.005,
        },
    );
    let mut rxs = Vec::new();
    for id in 0..8u64 {
        rxs.push(server.submit(Request {
            id,
            dataset: Dataset::Squad,
            seq_len: 24,
            arrival_s: 0.0,
            gen_tokens: 0,
            adapter: None,
            prefix: None,
            slo: axllm::workload::SloClass::Standard,
        }));
    }
    for (id, rx) in rxs.into_iter().enumerate() {
        let res = rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .expect("server must answer");
        assert_eq!(res.id, id as u64);
        assert_eq!(res.logits.len(), 4);
    }
    server.shutdown().unwrap();
}
