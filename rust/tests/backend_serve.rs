//! Backend-generic serving: `Engine::serve_trace` over the artifact-free
//! execution backends. No PJRT runtime, no artifact directory — this is
//! the CI-servable path the `ExecutionBackend` redesign exists for.

use axllm::backend::{ExecutionBackend, FunctionalBackend, SimBackend};
use axllm::config::{AcceleratorConfig, Dataset, ModelConfig};
use axllm::coordinator::{BatchPolicy, Engine};
use axllm::workload::TraceGenerator;

fn policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 4,
        max_wait_s: 0.005,
    }
}

fn sim_engine() -> Engine<SimBackend> {
    Engine::new(SimBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper()).unwrap())
}

fn functional_engine() -> Engine<FunctionalBackend> {
    Engine::new(
        FunctionalBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper(), 42).unwrap(),
    )
}

#[test]
fn sim_backend_serves_trace_without_artifacts() {
    let e = sim_engine();
    let trace = TraceGenerator::new(Dataset::AgNews, 300.0, 11).take(40);
    let ids: Vec<u64> = trace.iter().map(|r| r.id).collect();
    let (results, summary) = e.serve_trace(trace, policy()).unwrap();
    assert_eq!(results.len(), 40);
    let mut got: Vec<u64> = results.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, ids);
    assert_eq!(summary.requests, 40);
    assert!(summary.batches >= 10, "≥10 batches at max_batch=4");
    assert!(summary.tokens > 0);
    assert!(summary.throughput_rps > 0.0);
    assert!(summary.sim_cycles > 0);
    assert!(summary.sim_speedup > 1.3);
    // Pure simulation computes no logits but still attributes work.
    assert!(results.iter().all(|r| r.logits.is_empty()));
    assert!(results.iter().all(|r| r.sim_cycles > 0 && r.latency_s > 0.0));
}

#[test]
fn functional_backend_serves_trace_with_finite_logits() {
    let e = functional_engine();
    let trace = TraceGenerator::new(Dataset::Squad, 300.0, 11).take(16);
    let (results, summary) = e.serve_trace(trace, policy()).unwrap();
    assert_eq!(results.len(), 16);
    assert_eq!(summary.requests, 16);
    assert!(summary.sim_cycles > 0);
    assert!(results
        .iter()
        .all(|r| r.logits.len() == e.backend.n_classes()));
    assert!(results
        .iter()
        .all(|r| r.logits.iter().all(|v| v.is_finite())));
}

#[test]
fn sim_and_functional_backends_batch_identically() {
    // Same trace + same policy must produce the same batching decisions
    // and token accounting regardless of how batches execute.
    let sim = sim_engine();
    let fun = functional_engine();
    assert_eq!(sim.backend.seq_limit(), fun.backend.seq_limit());
    let trace = TraceGenerator::new(Dataset::Imdb, 250.0, 23).take(32);
    let (rs, ss) = sim.serve_trace(trace.clone(), policy()).unwrap();
    let (rf, sf) = fun.serve_trace(trace, policy()).unwrap();
    assert_eq!(ss.batches, sf.batches, "batch count must match");
    assert_eq!(ss.tokens, sf.tokens, "token totals must match");
    assert_eq!(ss.requests, sf.requests);
    assert_eq!(rs.len(), rf.len());
    // Request → batch assignment identical: queue waits, dispatch stamps
    // and batch sizes match pairwise (attributed cycles differ — the
    // backends model different weights).
    for (a, b) in rs.iter().zip(&rf) {
        assert_eq!(a.id, b.id);
        assert!((a.queue_wait_s - b.queue_wait_s).abs() < 1e-12);
        assert!((a.dispatch_s - b.dispatch_s).abs() < 1e-12);
        assert_eq!(a.batch_size, b.batch_size);
        assert_eq!(a.tokens, b.tokens);
    }
}

#[test]
fn sim_decode_trace_covers_every_session_with_ttft_tpot() {
    let e = sim_engine();
    let trace = TraceGenerator::new(Dataset::Imdb, 500.0, 19).take_decode(24, None);
    let budgets: Vec<(u64, u32)> = trace.iter().map(|r| (r.id, r.gen_tokens)).collect();
    let (results, summary) = e.serve_trace_decode(trace, policy(), 1).unwrap();
    assert_eq!(results.len(), 24);
    assert_eq!(summary.requests, 24);
    assert!(summary.gen_tokens > 0);
    assert!(summary.batches >= 1);
    let mut got: Vec<u64> = results.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, (0..24).collect::<Vec<_>>());
    for r in &results {
        let budget = budgets.iter().find(|(id, _)| *id == r.id).unwrap().1 as u64;
        assert_eq!(r.gen_tokens, budget, "request {} budget", r.id);
        assert!(r.tokens > r.gen_tokens, "tokens include the prompt");
        assert!(r.ttft_s <= r.latency_s + 1e-12);
        assert!(r.tpot_s >= 0.0);
        assert!(r.sim_cycles > 0);
        assert!(r.batch_size >= 1 && r.batch_size <= policy().max_batch);
    }
    // TTFT/TPOT aggregates are populated and ordered.
    assert!(summary.ttft.count == 24);
    assert!(summary.ttft.p50_s <= summary.ttft.p99_s);
    assert!(summary.tpot.count > 0, "sampled budgets include multi-token sessions");
}

#[test]
fn functional_decode_trace_returns_final_logits() {
    let e = functional_engine();
    let trace = TraceGenerator::new(Dataset::AgNews, 500.0, 29).take_decode(6, Some(3));
    let (results, summary) = e.serve_trace_decode(trace, policy(), 1).unwrap();
    assert_eq!(results.len(), 6);
    assert_eq!(summary.gen_tokens, 18);
    for r in &results {
        assert_eq!(r.gen_tokens, 3);
        assert_eq!(r.logits.len(), e.backend.n_classes());
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn empty_trace_serves_cleanly_on_every_artifact_free_backend() {
    // Edge pin for the serve paths the sharded live stack leans on: an
    // empty trace must produce an empty, well-formed summary — zero
    // counts, zero span, zero finite throughputs — on both the
    // closed-batch and decode paths, sharded or not. (The PJRT backend
    // shares the same engine code; its artifact-dependent twin lives in
    // tests/integration_coordinator.rs.)
    let sim = sim_engine();
    let fun = functional_engine();
    let sharded = Engine::new(
        SimBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper())
            .unwrap()
            .with_shards(4),
    );
    let check = |results: Vec<axllm::coordinator::RequestResult>,
                 s: axllm::coordinator::ServeSummary| {
        assert!(results.is_empty());
        assert_eq!(s.requests, 0);
        assert_eq!(s.tokens, 0);
        assert_eq!(s.span_s, 0.0);
        assert_eq!(s.throughput_rps, 0.0);
        assert_eq!(s.throughput_tps, 0.0);
        assert!(s.throughput_rps.is_finite() && s.throughput_tps.is_finite());
        assert!(s.by_adapter.is_empty());
        assert!(s.per_shard.is_empty());
    };
    let (r, s) = sim.serve_trace(Vec::new(), policy()).unwrap();
    check(r, s);
    let (r, s) = fun.serve_trace(Vec::new(), policy()).unwrap();
    check(r, s);
    let (r, s) = sim.serve_trace_decode(Vec::new(), policy(), 4).unwrap();
    check(r, s);
    let (r, s) = fun.serve_trace_decode(Vec::new(), policy(), 4).unwrap();
    check(r, s);
    let (r, s) = sharded.serve_trace_decode(Vec::new(), policy(), 4).unwrap();
    check(r, s);
    let (r, s) = sim
        .serve_trace_decode_closed(Vec::new(), policy(), 4)
        .unwrap();
    check(r, s);
}

#[test]
fn zero_gen_token_decode_runs_produce_one_token_sessions() {
    // serve --decode with gen_tokens = 0 everywhere AND a zero default:
    // the budget floor (≥ 1 — a session always produces its prefill
    // token) must hold on every backend, with coherent TTFT/TPOT.
    let trace = |n: u64| -> Vec<axllm::workload::Request> {
        (0..n)
            .map(|id| axllm::workload::Request {
                id,
                dataset: Dataset::Imdb,
                seq_len: 8,
                arrival_s: id as f64 * 0.001,
                gen_tokens: 0,
                adapter: None,
                prefix: None,
                slo: axllm::workload::SloClass::Standard,
            })
            .collect()
    };
    let (rs, ss) = sim_engine()
        .serve_trace_decode(trace(6), policy(), 0)
        .unwrap();
    let (rf, sf) = functional_engine()
        .serve_trace_decode(trace(6), policy(), 0)
        .unwrap();
    for (results, summary) in [(&rs, &ss), (&rf, &sf)] {
        assert_eq!(results.len(), 6);
        assert_eq!(summary.gen_tokens, 6, "budget floors at one token");
        for r in results.iter() {
            assert_eq!(r.gen_tokens, 1);
            assert_eq!(r.tokens, 8 + 1);
            assert_eq!(r.tpot_s, 0.0, "one-token sessions have no TPOT");
            assert!(r.ttft_s.is_finite() && r.ttft_s >= 0.0);
        }
        assert!(summary.span_s > 0.0);
        assert!(summary.throughput_tps.is_finite());
    }
}

#[test]
fn continuous_batching_never_loses_to_closed_batches() {
    // Deterministic virtual-time comparison on a ragged burst: the
    // continuous iteration loop refills retired slots, so its span can
    // never exceed the closed-batch schedule's (the strict win on mixed
    // lengths is pinned by benches/decode_serve.rs).
    let e = sim_engine();
    let mut trace = TraceGenerator::new(Dataset::Squad, 100_000.0, 7).take_decode(48, None);
    for r in &mut trace {
        r.seq_len = 8;
    }
    let pol = axllm::coordinator::BatchPolicy {
        max_batch: 8,
        max_wait_s: 0.001,
    };
    let (rc, cont) = e.serve_trace_decode(trace.clone(), pol, 1).unwrap();
    let (rx, closed) = e.serve_trace_decode_closed(trace, pol, 1).unwrap();
    assert_eq!(rc.len(), rx.len());
    assert!(
        cont.span_s <= closed.span_s + 1e-12,
        "continuous {} vs closed {}",
        cont.span_s,
        closed.span_s
    );
    assert!(cont.throughput_tps >= closed.throughput_tps - 1e-9);
    // Same total work either way.
    assert_eq!(cont.tokens, closed.tokens);
    assert_eq!(cont.gen_tokens, closed.gen_tokens);
}

#[test]
fn decode_attribution_is_identical_across_sim_and_functional() {
    // The engine attributes decode cycles/energy from the cost model's
    // context-dependent regime only — identical batching plus identical
    // contexts means identical attribution, real execution or not.
    let sim = sim_engine();
    let fun = functional_engine();
    let mut trace = TraceGenerator::new(Dataset::Imdb, 400.0, 41).take_decode(10, Some(4));
    // Burst arrivals: admission is then purely capacity-driven, so the
    // iteration structure is identical even though the two backends'
    // cost models tick their virtual clocks at different rates.
    for r in &mut trace {
        r.arrival_s = 0.0;
    }
    let (rs, ss) = sim.serve_trace_decode(trace.clone(), policy(), 1).unwrap();
    let (rf, sf) = fun.serve_trace_decode(trace, policy(), 1).unwrap();
    assert_eq!(ss.batches, sf.batches);
    assert_eq!(ss.tokens, sf.tokens);
    let key = |rs: &[axllm::coordinator::RequestResult]| {
        let mut v: Vec<(u64, u64, u64)> =
            rs.iter().map(|r| (r.id, r.tokens, r.gen_tokens)).collect();
        v.sort_unstable();
        v
    };
    assert_eq!(key(&rs), key(&rf));
}

#[test]
fn identical_request_ids_get_identical_logits_functionally() {
    use axllm::workload::Request;
    let e = functional_engine();
    let mk = |arrival: f64| Request {
        id: 123,
        dataset: Dataset::Imdb,
        seq_len: 20,
        arrival_s: arrival,
        gen_tokens: 0,
        adapter: None,
        prefix: None,
        slo: axllm::workload::SloClass::Standard,
    };
    let (r1, _) = e
        .serve_trace(vec![mk(0.0)], BatchPolicy::default())
        .unwrap();
    let (r2, _) = e
        .serve_trace(vec![mk(5.0)], BatchPolicy::default())
        .unwrap();
    assert_eq!(r1[0].logits, r2[0].logits);
}

fn decode_req(id: u64, arrival_s: f64, seq_len: usize, gen: u32) -> axllm::workload::Request {
    axllm::workload::Request {
        id,
        dataset: Dataset::Imdb,
        seq_len,
        arrival_s,
        gen_tokens: gen,
        adapter: None,
        prefix: None,
        slo: axllm::workload::SloClass::Standard,
    }
}

#[test]
fn chunked_prefill_serving_is_bit_identical_to_monolithic() {
    // The engine-level chunked-prefill contract: slicing prompts into
    // per-iteration token budgets changes only the virtual clock, never
    // the computation — logits, tokens, and reuse counters all match the
    // monolithic path per request.
    use axllm::coordinator::DecodeServeOpts;
    let trace: Vec<axllm::workload::Request> = (0..10)
        .map(|i| decode_req(i, 0.01 * i as f64, 5 + (i as usize % 7), 2 + (i % 3) as u32))
        .collect();
    let (mut mono, _) = functional_engine()
        .serve_trace_decode(trace.clone(), policy(), 4)
        .unwrap();
    let opts = DecodeServeOpts::new(4).with_chunking(3);
    let (mut chunked, _) = functional_engine()
        .serve_trace_decode_opts(trace, policy(), opts)
        .unwrap();
    assert_eq!(mono.len(), chunked.len());
    mono.sort_by_key(|r| r.id);
    chunked.sort_by_key(|r| r.id);
    for (m, c) in mono.iter().zip(chunked.iter()) {
        assert_eq!(m.id, c.id);
        assert_eq!(m.logits, c.logits, "request {} diverged under chunking", m.id);
        assert_eq!(m.tokens, c.tokens);
        assert_eq!(m.gen_tokens, c.gen_tokens);
        assert_eq!(m.base_mults, c.base_mults);
        assert_eq!(m.base_reuses, c.base_reuses);
    }
}

#[test]
fn zero_deadline_slo_admission_composes_with_chunked_prefill() {
    // max_wait_s = 0 is the harshest admission deadline: chunk jobs hold
    // session slots for several iterations, so a burst that outsizes the
    // slot count sheds its overflow on the first pass after the clock
    // moves — and every request is accounted exactly once.
    use axllm::coordinator::{DecodeServeOpts, SloPolicy, SloTarget};
    let base = SloPolicy::default();
    let slo = SloPolicy {
        standard: SloTarget {
            max_wait_s: 0.0,
            ttft_s: f64::INFINITY, // isolate shedding from degradation
            ..base.standard
        },
        ..base
    };
    let trace: Vec<axllm::workload::Request> = (0..12).map(|i| decode_req(i, 0.0, 40, 4)).collect();
    let opts = DecodeServeOpts::new(4).with_chunking(8).with_slo(slo);
    let pol = BatchPolicy {
        max_batch: 2,
        max_wait_s: 0.0,
    };
    let (results, summary) = sim_engine().serve_trace_decode_opts(trace, pol, opts).unwrap();
    assert!(summary.shed > 0, "burst past the zero deadline must shed");
    assert_eq!(results.len() + summary.shed, 12);
    assert!(results.iter().all(|r| !r.shed), "shed requests never execute");
    assert!(results.iter().all(|r| r.gen_tokens == 4), "served sessions run full budgets");
}
