//! Backend-generic serving: `Engine::serve_trace` over the artifact-free
//! execution backends. No PJRT runtime, no artifact directory — this is
//! the CI-servable path the `ExecutionBackend` redesign exists for.

use axllm::backend::{ExecutionBackend, FunctionalBackend, SimBackend};
use axllm::config::{AcceleratorConfig, Dataset, ModelConfig};
use axllm::coordinator::{BatchPolicy, Engine};
use axllm::workload::TraceGenerator;

fn policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 4,
        max_wait_s: 0.005,
    }
}

fn sim_engine() -> Engine<SimBackend> {
    Engine::new(SimBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper()).unwrap())
}

fn functional_engine() -> Engine<FunctionalBackend> {
    Engine::new(
        FunctionalBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper(), 42).unwrap(),
    )
}

#[test]
fn sim_backend_serves_trace_without_artifacts() {
    let e = sim_engine();
    let trace = TraceGenerator::new(Dataset::AgNews, 300.0, 11).take(40);
    let ids: Vec<u64> = trace.iter().map(|r| r.id).collect();
    let (results, summary) = e.serve_trace(trace, policy()).unwrap();
    assert_eq!(results.len(), 40);
    let mut got: Vec<u64> = results.iter().map(|r| r.id).collect();
    got.sort_unstable();
    assert_eq!(got, ids);
    assert_eq!(summary.requests, 40);
    assert!(summary.batches >= 10, "≥10 batches at max_batch=4");
    assert!(summary.tokens > 0);
    assert!(summary.throughput_rps > 0.0);
    assert!(summary.sim_cycles > 0);
    assert!(summary.sim_speedup > 1.3);
    // Pure simulation computes no logits but still attributes work.
    assert!(results.iter().all(|r| r.logits.is_empty()));
    assert!(results.iter().all(|r| r.sim_cycles > 0 && r.latency_s > 0.0));
}

#[test]
fn functional_backend_serves_trace_with_finite_logits() {
    let e = functional_engine();
    let trace = TraceGenerator::new(Dataset::Squad, 300.0, 11).take(16);
    let (results, summary) = e.serve_trace(trace, policy()).unwrap();
    assert_eq!(results.len(), 16);
    assert_eq!(summary.requests, 16);
    assert!(summary.sim_cycles > 0);
    assert!(results
        .iter()
        .all(|r| r.logits.len() == e.backend.n_classes()));
    assert!(results
        .iter()
        .all(|r| r.logits.iter().all(|v| v.is_finite())));
}

#[test]
fn sim_and_functional_backends_batch_identically() {
    // Same trace + same policy must produce the same batching decisions
    // and token accounting regardless of how batches execute.
    let sim = sim_engine();
    let fun = functional_engine();
    assert_eq!(sim.backend.seq_limit(), fun.backend.seq_limit());
    let trace = TraceGenerator::new(Dataset::Imdb, 250.0, 23).take(32);
    let (rs, ss) = sim.serve_trace(trace.clone(), policy()).unwrap();
    let (rf, sf) = fun.serve_trace(trace, policy()).unwrap();
    assert_eq!(ss.batches, sf.batches, "batch count must match");
    assert_eq!(ss.tokens, sf.tokens, "token totals must match");
    assert_eq!(ss.requests, sf.requests);
    assert_eq!(rs.len(), rf.len());
    // Request → batch assignment identical: queue waits, dispatch stamps
    // and batch sizes match pairwise (attributed cycles differ — the
    // backends model different weights).
    for (a, b) in rs.iter().zip(&rf) {
        assert_eq!(a.id, b.id);
        assert!((a.queue_wait_s - b.queue_wait_s).abs() < 1e-12);
        assert!((a.dispatch_s - b.dispatch_s).abs() < 1e-12);
        assert_eq!(a.batch_size, b.batch_size);
        assert_eq!(a.tokens, b.tokens);
    }
}

#[test]
fn identical_request_ids_get_identical_logits_functionally() {
    use axllm::workload::Request;
    let e = functional_engine();
    let mk = |arrival: f64| Request {
        id: 123,
        dataset: Dataset::Imdb,
        seq_len: 20,
        arrival_s: arrival,
    };
    let (r1, _) = e
        .serve_trace(vec![mk(0.0)], BatchPolicy::default())
        .unwrap();
    let (r2, _) = e
        .serve_trace(vec![mk(5.0)], BatchPolicy::default())
        .unwrap();
    assert_eq!(r1[0].logits, r2[0].logits);
}
