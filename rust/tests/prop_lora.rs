//! Property tests for per-request LoRA serving (in-crate property runner
//! — see `util::prop`).
//!
//! Three claims anchor the multi-tenant adapter dimension:
//! 1. **Kernel equivalence** — the serving decomposition (base reuse
//!    pipe + dense rank-r side pipe) is value-identical to the offline
//!    combined `[W ∥ A]` kernel `exec::lora_matmul` for every input,
//!    rank, and chunk size, with the base pipe's reuse accounting
//!    untouched by the side pipe.
//! 2. **Serving exactness** — adapter routing through
//!    `FunctionalBackend` prefill + decode is bit-identical to a full
//!    offline recompute of the extended sequence through the same
//!    adaptor (the LoRA analogue of the PR 3 KV-exactness property).
//! 3. **Tenant isolation** — `adapter: None` requests are byte-for-byte
//!    unaffected by adapters elsewhere in the batch, and the base-pipe
//!    reuse rate of a mixed-adapter continuous batch sits exactly on
//!    the adapter-free run's (the paper's "reuse survives LoRA" claim).

use axllm::backend::{ExecutionBackend, FunctionalBackend, SimBackend};
use axllm::config::{AcceleratorConfig, Dataset, LoraConfig, ModelConfig};
use axllm::coordinator::{BatchPolicy, Engine};
use axllm::exec::{lora_matmul, lora_side_matmul, reuse_matmul_chunked};
use axllm::model::{synthesize_matrix, LoraAdaptor, WeightDistribution};
use axllm::util::prop::{check, Config};
use axllm::workload::Request;
use axllm::{prop_assert, prop_assert_eq};

fn req(id: u64, seq_len: usize, gen_tokens: u32, adapter: Option<u32>) -> Request {
    Request {
        id,
        dataset: Dataset::Imdb,
        seq_len,
        arrival_s: 0.0,
        gen_tokens,
        adapter,
        prefix: None,
        slo: axllm::workload::SloClass::Standard,
    }
}

#[test]
fn prop_dual_pipe_matches_offline_combined_kernel() {
    check(
        "lora-dual-pipe-kernel-equivalence",
        Config {
            cases: 24,
            seed: 0x10A4,
        },
        |rng| {
            let rows = 8 + rng.index(64);
            let cols = 8 + rng.index(96);
            let rank = 1 + rng.index(12);
            let chunk = 1 + rng.index(cols + rank);
            let dist = WeightDistribution::default();
            let mut mrng = axllm::util::rng::Rng::new(rng.below(1 << 40));
            let w = synthesize_matrix(rows, cols, dist, &mut mrng);
            let adaptor = LoraAdaptor::synthesize(
                &w,
                LoraConfig {
                    rank,
                    alpha: 16.0,
                },
                dist,
                &mut mrng,
            );
            let x: Vec<i8> = (0..rows)
                .map(|_| mrng.range_i64(-127, 127) as i8)
                .collect();

            let (base, base_stats) = reuse_matmul_chunked(&x, &w, chunk);
            let (side, side_stats) = lora_side_matmul(&x, &adaptor);
            let (combined, _) = lora_matmul(&x, &w, &adaptor, chunk);
            // Value-identical for every column, at any chunk bound.
            for j in 0..cols {
                prop_assert_eq!(base[j] as i64 + side[j], combined[j]);
            }
            // The base pipe's reuse accounting is untouched by the side
            // pipe, and the side pipe is fully dense.
            let (_, base_alone) = reuse_matmul_chunked(&x, &w, chunk);
            prop_assert_eq!(base_stats, base_alone);
            prop_assert_eq!(side_stats.mults, 0);
            prop_assert_eq!(side_stats.reuses, 0);
            prop_assert_eq!(side_stats.adapter_mults, adaptor.extra_macs());
            Ok(())
        },
    );
}

#[test]
fn prop_adapter_decode_bit_identical_to_offline_recompute() {
    check(
        "lora-decode-exact",
        Config {
            cases: 5,
            seed: 0x10AD,
        },
        |rng| {
            let model_seed = rng.below(1_000_000);
            let backend = FunctionalBackend::new(
                ModelConfig::tiny(),
                AcceleratorConfig::paper(),
                model_seed,
            )
            .map_err(|e| e.to_string())?
            .with_adapters(3, 1 + rng.index(16));
            let adapter = Some(rng.below(3) as u32);
            let r = req(rng.below(10_000), 2 + rng.index(12), 0, adapter);
            let steps = 1 + rng.index(3);
            let (mut kv, first) = backend
                .prefill(&r, (steps + 1) as u32)
                .map_err(|e| e.to_string())?;
            prop_assert_eq!(kv.adapter, adapter);
            // Prefill logits == one-shot causal recompute through the
            // same adaptor.
            prop_assert_eq!(first.logits, backend.recompute_logits(&r, &[]));
            prop_assert!(
                first.activity.adapter_ops > 0,
                "adapter prefill must do side-pipe work"
            );
            for _ in 0..steps {
                let tokens_before = kv.generated.clone();
                let out = backend.decode_step(&mut kv).map_err(|e| e.to_string())?;
                prop_assert_eq!(out.logits, backend.recompute_logits(&r, &tokens_before));
                prop_assert!(
                    out.stats.mults > 0 && out.stats.rc_hits > 0,
                    "decode steps must exercise the base reuse datapath"
                );
                prop_assert!(out.activity.adapter_ops > 0);
            }
            prop_assert_eq!(backend.adapter_misses(), 0);
            Ok(())
        },
    );
}

#[test]
fn prop_base_requests_unaffected_by_mixed_adapters_and_reuse_survives() {
    // One shared trace: half the requests carry adapters, half run the
    // base model. Served through a mixed-adapter continuous batch, the
    // base requests' logits must be byte-identical to an adapter-free
    // deployment serving the all-None twin trace, and the base-pipeline
    // reuse rate of every group must sit exactly on the adapter-free
    // run's — reuse survives LoRA.
    check(
        "lora-tenant-isolation",
        Config {
            cases: 3,
            seed: 0x150A,
        },
        |rng| {
            let model_seed = rng.below(1_000_000);
            let mk_backend = |adapters: usize| {
                FunctionalBackend::new(
                    ModelConfig::tiny(),
                    AcceleratorConfig::paper(),
                    model_seed,
                )
                .map(|b| b.with_adapters(adapters, 4))
                .map_err(|e| e.to_string())
            };
            let n = 6 + rng.index(6);
            let mixed: Vec<Request> = (0..n)
                .map(|i| {
                    let adapter = (i % 2 == 1).then_some((i % 3) as u32);
                    req(i as u64, 4 + rng.index(8), 2 + rng.index(3) as u32, adapter)
                })
                .collect();
            let plain: Vec<Request> = mixed
                .iter()
                .map(|r| Request {
                    adapter: None,
                    ..r.clone()
                })
                .collect();
            let policy = BatchPolicy {
                max_batch: 4,
                max_wait_s: 0.001,
            };
            let engine = Engine::new(mk_backend(3)?);
            let (rm, sm) = engine
                .serve_trace_decode(mixed, policy, 2)
                .map_err(|e| e.to_string())?;
            let base_engine = Engine::new(mk_backend(0)?);
            let (rp, sp) = base_engine
                .serve_trace_decode(plain, policy, 2)
                .map_err(|e| e.to_string())?;
            prop_assert_eq!(rm.len(), n);
            for (m, p) in rm.iter().zip(&rp) {
                prop_assert_eq!(m.id, p.id);
                if m.adapter.is_none() {
                    // Tenant isolation: co-batched adapters never touch a
                    // base request's logits or base-pipe accounting.
                    prop_assert_eq!(&m.logits, &p.logits);
                    prop_assert_eq!(m.adapter_ops, 0);
                    prop_assert_eq!(m.sim_cycles, p.sim_cycles);
                } else {
                    prop_assert!(m.adapter_ops > 0);
                    prop_assert!(m.sim_cycles > p.sim_cycles, "side pipe is charged");
                }
                // Reuse survives LoRA: base-pipe ops identical per
                // request, adapter or not.
                prop_assert_eq!(m.base_mults, p.base_mults);
                prop_assert_eq!(m.base_reuses, p.base_reuses);
            }
            // …and therefore at the rollup level too: every adapter
            // group's measured base reuse sits within noise of the
            // adapter-free run's rate. (Groups mix prompt/generation
            // lengths differently, so rates agree to request-mix noise,
            // not bit-exactly — the bit-exact claim is the per-request
            // equality above.)
            prop_assert!(sm.by_adapter.len() > 1, "run must mix adapters");
            prop_assert_eq!(sp.by_adapter.len(), 1);
            let free = sp.by_adapter[0].base_reuse_rate;
            prop_assert!(free > 0.0);
            for g in &sm.by_adapter {
                prop_assert!(
                    (g.base_reuse_rate - free).abs() < 0.02,
                    "group reuse must sit within noise of the adapter-free rate"
                );
            }
            prop_assert!(sm.adapter_ops > 0);
            Ok(())
        },
    );
}

#[test]
fn prop_sim_adapter_attribution_batch_independent() {
    // The PR 3 batch-independence property, extended along the adapter
    // dimension: per-request cycles depend only on the request's own
    // trajectory and adapter, never on co-batched tenants.
    let engine = Engine::new(
        SimBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper())
            .unwrap()
            .with_adapters(4, 8),
    );
    let attribution = |results: &[axllm::coordinator::RequestResult]| {
        let mut v: Vec<(u64, Option<u32>, u64, u64)> = results
            .iter()
            .map(|r| (r.id, r.adapter, r.sim_cycles, r.adapter_ops))
            .collect();
        v.sort_unstable();
        v
    };
    check(
        "sim-adapter-attribution-batch-independent",
        Config {
            cases: 8,
            seed: 0xBA7D,
        },
        |rng| {
            let n = 4 + rng.index(10);
            let trace: Vec<Request> = (0..n)
                .map(|i| {
                    let adapter = (rng.index(2) == 0).then(|| rng.below(4) as u32);
                    let mut r = req(
                        i as u64,
                        4 + rng.index(20),
                        1 + rng.index(8) as u32,
                        adapter,
                    );
                    r.arrival_s = i as f64 * 0.0004;
                    r
                })
                .collect();
            let narrow = BatchPolicy {
                max_batch: 2,
                max_wait_s: 0.001,
            };
            let wide = BatchPolicy {
                max_batch: 16,
                max_wait_s: 0.001,
            };
            let (rn, _) = engine
                .serve_trace_decode(trace.clone(), narrow, 4)
                .map_err(|e| e.to_string())?;
            let (rw, _) = engine
                .serve_trace_decode(trace, wide, 4)
                .map_err(|e| e.to_string())?;
            prop_assert_eq!(attribution(&rn), attribution(&rw));
            Ok(())
        },
    );
}
