//! Property tests for group-wise quantization regimes (in-crate property
//! runner — see `util::prop`).
//!
//! Four claims anchor the regime machinery:
//! 1. **Degeneracy** — every group kernel at `group_size ≥ cols` is
//!    bit-identical to the seed per-tensor kernel: outputs *and*
//!    [`ExecStats`], scalar and packed, monolithic and per shard.
//! 2. **Exactness under scoping** — group boundaries only move the
//!    mult/reuse split, never values: for *any* group width (including
//!    widths straddling the 4-code pack width and ragged tail groups)
//!    the group kernels reproduce `dense_matmul` bit for bit, packed
//!    matches scalar, and mults + reuses is conserved.
//! 3. **Monotonicity** — refining the scale grid can only lose reuse:
//!    nested group widths give non-decreasing mult counts, and
//!    per-window unique-code counts are monotone under nested windows on
//!    clustered code distributions (the RC-friendly regime the paper
//!    targets).
//! 4. **Backend transparency** — threading a `QuantRegime` through
//!    `FunctionalBackend` re-scopes reuse accounting but leaves logits,
//!    tokens, and total op counts bit-identical, across scalar/packed
//!    kernels, shard counts {1, 2, 4}, and LoRA tenant mixes.

use axllm::backend::{ExecutionBackend, FunctionalBackend};
use axllm::config::{AcceleratorConfig, Dataset, ModelConfig};
use axllm::exec::{
    dense_matmul, group_accounting, group_reuse_matmul_chunked, group_reuse_matmul_packed,
    reuse_matmul_chunked, sharded_group_reuse_matmul_chunked, sharded_group_reuse_matmul_packed,
    sharded_reuse_matmul_chunked, ExecArena, ExecStats,
};
use axllm::quant::{chunk_unique_counts, GroupQuantMatrix, QuantMatrix, QuantParams, QuantRegime};
use axllm::util::prop::{check, Config};
use axllm::util::rng::Rng;
use axllm::workload::Request;
use axllm::{prop_assert, prop_assert_eq};

/// Random quantized matrix covering the full i8 code range, including
/// −128 (the packed tiler's product-table hazard code).
fn random_matrix(rng: &mut Rng, rows: usize, cols: usize) -> QuantMatrix {
    let data: Vec<i8> = (0..rows * cols)
        .map(|_| rng.range_i64(-128, 127) as i8)
        .collect();
    QuantMatrix {
        rows,
        cols,
        data,
        params: QuantParams {
            scale: 0.02,
            bits: 8,
        },
    }
}

fn random_x(rng: &mut Rng, rows: usize) -> Vec<i8> {
    (0..rows).map(|_| rng.range_i64(-127, 127) as i8).collect()
}

#[test]
fn prop_whole_tensor_group_degenerates_to_per_tensor_kernels() {
    check(
        "group-degenerate-exact",
        Config {
            cases: 20,
            seed: 0x96F0A1,
        },
        |rng| {
            let rows = 1 + rng.index(32);
            let cols = *rng.choose(&[0usize, 1, 3, 4, 5, 31, 64, 130]);
            let w = random_matrix(rng, rows, cols);
            let x = random_x(rng, rows);
            let packed = w.packed();
            let mut arena = ExecArena::new();
            for chunk in [1usize, 3, 7, 64, 500] {
                let (y_ref, st_ref) = reuse_matmul_chunked(&x, &w, chunk);
                for group in [cols.max(1), cols + 7, usize::MAX] {
                    let (y_g, st_g) = group_reuse_matmul_chunked(&x, &w, group, chunk);
                    prop_assert_eq!(&y_g, &y_ref);
                    prop_assert_eq!(st_g, st_ref);
                    let st_p = group_reuse_matmul_packed(&x, &packed, group, chunk, &mut arena);
                    prop_assert_eq!(arena.yq(), &y_ref[..]);
                    prop_assert_eq!(st_p, st_ref);
                }
                for shards in [1usize, 2, 4] {
                    let (y_ref, per_ref) = sharded_reuse_matmul_chunked(&x, &w, chunk, shards);
                    let (y_g, per_g) =
                        sharded_group_reuse_matmul_chunked(&x, &w, usize::MAX, chunk, shards);
                    prop_assert_eq!(&y_g, &y_ref);
                    prop_assert_eq!(&per_g, &per_ref);
                    let mut per_p = vec![ExecStats::default(); per_ref.len()];
                    sharded_group_reuse_matmul_packed(
                        &x,
                        &packed,
                        usize::MAX,
                        chunk,
                        shards,
                        &mut per_p,
                        &mut arena,
                    );
                    prop_assert_eq!(arena.yq(), &y_ref[..]);
                    prop_assert_eq!(&per_p, &per_ref);
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_group_scoping_preserves_values_for_any_width() {
    check(
        "group-width-exact",
        Config {
            cases: 20,
            seed: 0x96F0A2,
        },
        |rng| {
            let rows = 1 + rng.index(24);
            // Ragged widths: tails rarely align with group or pack edges.
            let cols = *rng.choose(&[1usize, 2, 5, 13, 31, 64, 130]);
            let w = random_matrix(rng, rows, cols);
            let x = random_x(rng, rows);
            let packed = w.packed();
            let dense = dense_matmul(&x, &w);
            let mut arena = ExecArena::new();
            // Widths straddling PACK_WIDTH = 4 plus a random one.
            let random_group = 1 + rng.index(cols.max(1));
            for group in [1usize, 2, 3, 5, 7, random_group] {
                for chunk in [1usize, 4, 17, 256] {
                    let (y_g, st_g) = group_reuse_matmul_chunked(&x, &w, group, chunk);
                    prop_assert_eq!(&y_g, &dense);
                    // Scoping moves the split, never the op total.
                    prop_assert_eq!(st_g.mults + st_g.reuses, (rows * cols) as u64);
                    let st_p = group_reuse_matmul_packed(&x, &packed, group, chunk, &mut arena);
                    prop_assert_eq!(arena.yq(), &dense[..]);
                    prop_assert_eq!(st_p, st_g);
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_group_kernels_match_scalar_per_shard() {
    check(
        "group-sharded-exact",
        Config {
            cases: 14,
            seed: 0x96F0A3,
        },
        |rng| {
            let rows = 1 + rng.index(20);
            let cols = *rng.choose(&[1usize, 5, 16, 65, 130]);
            let w = random_matrix(rng, rows, cols);
            let x = random_x(rng, rows);
            let packed = w.packed();
            let dense = dense_matmul(&x, &w);
            let mut arena = ExecArena::new();
            let group = 1 + rng.index(cols.max(1) + 8);
            for shards in [1usize, 2, 4] {
                for chunk in [1usize, 3, 64] {
                    let (y_s, per_s) =
                        sharded_group_reuse_matmul_chunked(&x, &w, group, chunk, shards);
                    prop_assert_eq!(&y_s, &dense);
                    let mut per_p = vec![ExecStats::default(); per_s.len()];
                    let total = sharded_group_reuse_matmul_packed(
                        &x,
                        &packed,
                        group,
                        chunk,
                        shards,
                        &mut per_p,
                        &mut arena,
                    );
                    prop_assert_eq!(arena.yq(), &dense[..]);
                    prop_assert_eq!(&per_p, &per_s);
                    let fold = per_s.iter().fold(ExecStats::default(), |mut a, s| {
                        a.add(s);
                        a
                    });
                    prop_assert_eq!((total.mults, total.reuses), (fold.mults, fold.reuses));
                    // The x-free accounting scan must agree with the
                    // executing kernel it predicts.
                    let acct = group_accounting(&w, group, chunk, shards, rows as u64);
                    prop_assert_eq!(&acct, &per_s);
                }
            }
            Ok(())
        },
    );
}

/// Clustered codes: a mixture of narrow bands, the value-locality regime
/// quantized LLM weights actually exhibit (paper §III.b).
fn clustered_codes(rng: &mut Rng, n: usize, bands: usize, spread: i64) -> Vec<i8> {
    let centers: Vec<i64> = (0..bands).map(|_| rng.range_i64(-100, 100)).collect();
    (0..n)
        .map(|_| {
            let c = centers[rng.index(bands)];
            (c + rng.range_i64(-spread, spread)).clamp(-127, 127) as i8
        })
        .collect()
}

#[test]
fn prop_refining_groups_is_monotone_in_mults_and_unique_codes() {
    check(
        "group-monotone",
        Config {
            cases: 20,
            seed: 0x96F0A4,
        },
        |rng| {
            let rows = 1 + rng.index(12);
            let cols = 4 * (2 + rng.index(40)); // divisible by 4 for nesting
            let bands = 1 + rng.index(5);
            let spread = 1 + rng.range_i64(0, 6);
            let data: Vec<i8> = (0..rows)
                .flat_map(|_| clustered_codes(rng, cols, bands, spread))
                .collect();
            let w = QuantMatrix {
                rows,
                cols,
                data,
                params: QuantParams {
                    scale: 0.02,
                    bits: 8,
                },
            };
            // Nested group widths: every finer segment sits inside a
            // coarser one, so its first-occurrence set can only shrink —
            // mults are monotone non-decreasing as groups refine.
            let chunk = *rng.choose(&[3usize, 64, 256]);
            let widths = [cols, cols / 2, cols / 4];
            let mut last_mults = 0u64;
            for group in widths {
                let mut st = ExecStats::default();
                for s in group_accounting(&w, group, chunk, 1, rows as u64) {
                    st.add(&s);
                }
                prop_assert!(
                    st.mults >= last_mults,
                    "group {} mults {} < coarser {}",
                    group,
                    st.mults,
                    last_mults
                );
                prop_assert_eq!(st.mults + st.reuses, (rows * cols) as u64);
                last_mults = st.mults;
            }
            // Same law at the raw statistic level: per-window unique-code
            // counts under nested windows.
            let row = clustered_codes(rng, cols, bands, spread);
            for (wide, narrow) in [(cols, cols / 2), (cols / 2, cols / 4)] {
                let u_wide = chunk_unique_counts(&row, wide);
                let u_narrow = chunk_unique_counts(&row, narrow);
                let max_wide = u_wide.iter().copied().max().unwrap_or(0);
                let max_narrow = u_narrow.iter().copied().max().unwrap_or(0);
                prop_assert!(
                    max_narrow <= max_wide,
                    "window {}: max unique {} exceeds window {}'s {}",
                    narrow,
                    max_narrow,
                    wide,
                    max_wide
                );
                let sum_wide: usize = u_wide.iter().sum();
                let sum_narrow: usize = u_narrow.iter().sum();
                prop_assert!(sum_narrow >= sum_wide, "refining windows cannot merge epochs");
            }
            Ok(())
        },
    );
}

#[test]
fn prop_group_fit_roundtrip_error_bounded_by_group_scale() {
    check(
        "group-fit-roundtrip",
        Config {
            cases: 24,
            seed: 0x96F0A5,
        },
        |rng| {
            let rows = 1 + rng.index(10);
            let cols = 1 + rng.index(120);
            let group = 1 + rng.index(cols + 8);
            let data: Vec<f32> = (0..rows * cols)
                .map(|_| (rng.range_i64(-1000, 1000) as f32) / 500.0)
                .collect();
            let g = GroupQuantMatrix::fit(rows, cols, &data, 8, group);
            prop_assert_eq!(g.n_groups(), cols.div_ceil(g.group_size));
            let deq = g.dequantize();
            for (i, (&x, &y)) in data.iter().zip(&deq).enumerate() {
                let params = g.group_params[(i % cols) / g.group_size];
                prop_assert!(
                    (x - y).abs() <= 0.5 * params.scale + f32::EPSILON,
                    "idx {}: |{} - {}| > half step {}",
                    i,
                    x,
                    y,
                    0.5 * params.scale
                );
            }
            Ok(())
        },
    );
}

fn backend(seed: u64) -> FunctionalBackend {
    FunctionalBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper(), seed).unwrap()
}

fn req(id: u64, seq_len: usize) -> Request {
    Request {
        id,
        dataset: Dataset::AgNews,
        seq_len,
        arrival_s: 0.0,
        gen_tokens: 0,
        adapter: None,
        prefix: None,
        slo: axllm::workload::SloClass::Standard,
    }
}

#[test]
fn prop_backend_regime_rescopes_reuse_without_touching_values() {
    check(
        "group-backend-transparent",
        Config {
            cases: 3,
            seed: 0x96F0A6,
        },
        |rng| {
            let model_seed = rng.below(1_000_000);
            for shards in [1usize, 2, 4] {
                let base = backend(model_seed).with_shards(shards).with_adapters(2, 4);
                let reqs: Vec<Request> = (0..4u64)
                    .map(|i| Request {
                        adapter: if i % 2 == 0 { None } else { Some((i % 3) as u32) },
                        ..req(i, 3 + rng.index(10))
                    })
                    .collect();
                let o_pt = base.run_batch(&reqs).map_err(|e| e.to_string())?;
                let group = *rng.choose(&[1usize, 3, 8]);
                for compressed in [false, true] {
                    let regime = QuantRegime::grouped(group).with_compressed(compressed);
                    let fast = backend(model_seed)
                        .with_shards(shards)
                        .with_adapters(2, 4)
                        .with_quant_regime(regime);
                    let slow = backend(model_seed)
                        .with_shards(shards)
                        .with_adapters(2, 4)
                        .with_quant_regime(regime)
                        .with_scalar_kernels(true);
                    let o_g = fast.run_batch(&reqs).map_err(|e| e.to_string())?;
                    let o_s = slow.run_batch(&reqs).map_err(|e| e.to_string())?;
                    // Values are regime-independent; packed == scalar.
                    prop_assert_eq!(&o_g.logits, &o_pt.logits);
                    prop_assert_eq!(&o_s.logits, &o_pt.logits);
                    prop_assert_eq!(&o_s.activity, &o_g.activity);
                    // Scoping conserves ops and can only remove reuse.
                    for (a, g) in o_pt.activity.iter().zip(&o_g.activity) {
                        prop_assert_eq!(a.base_mults + a.base_reuses, g.base_mults + g.base_reuses);
                        prop_assert!(g.base_reuses <= a.base_reuses);
                        prop_assert_eq!(a.adapter_ops, g.adapter_ops);
                    }
                    // KV-cached decode: token streams are regime-blind.
                    let r = Request {
                        adapter: Some(1),
                        ..req(99, 2 + rng.index(6))
                    };
                    let (mut kv_g, f_g) = fast.prefill(&r, 3).map_err(|e| e.to_string())?;
                    let (mut kv_p, f_p) = base.prefill(&r, 3).map_err(|e| e.to_string())?;
                    prop_assert_eq!(&f_g.logits, &f_p.logits);
                    while !kv_g.done() {
                        let s_g = fast.decode_step(&mut kv_g).map_err(|e| e.to_string())?;
                        let s_p = base.decode_step(&mut kv_p).map_err(|e| e.to_string())?;
                        prop_assert_eq!(&s_g.logits, &s_p.logits);
                        prop_assert_eq!(s_g.token, s_p.token);
                    }
                    prop_assert_eq!(&kv_g.generated, &kv_p.generated);
                }
            }
            Ok(())
        },
    );
}
