//! Property tests for the packed-code functional hot path (in-crate
//! property runner — see `util::prop`).
//!
//! Three claims anchor the packed/tiled/thread-parallel rework:
//! 1. **Kernel exactness** — `reuse_matmul_packed` is bit-identical to
//!    `dense_matmul` AND to the seed scalar `reuse_matmul_chunked` —
//!    outputs *and* reuse counters — across random shapes, chunk sizes
//!    (including chunks that straddle the 4-code pack width), ragged
//!    tile edges, and empty/single-column matrices; likewise per shard
//!    for the sharded variants, for shard counts {1, 2, 4}.
//! 2. **Code −128 exactness** — matrices containing i8's most negative
//!    code contribute its true product on every kernel (the seed scalar
//!    kernel's fixed product-table hazard).
//! 3. **Backend exactness** — `with_scalar_kernels(true)` (the seed
//!    sequential baseline) and the default packed/tiled/thread-parallel
//!    path serve identical logits, activity, and counters across shard
//!    counts and LoRA tenant mixes, on batch prefill and KV-cached
//!    decode — and per-request results are batch-order-independent.

use axllm::backend::{ExecutionBackend, FunctionalBackend};
use axllm::config::{AcceleratorConfig, Dataset, ModelConfig};
use axllm::exec::{
    dense_matmul, reuse_matmul_chunked, reuse_matmul_packed, sharded_reuse_matmul_chunked,
    sharded_reuse_matmul_packed, ExecArena, ExecStats,
};
use axllm::quant::{QuantMatrix, QuantParams};
use axllm::util::prop::{check, Config};
use axllm::util::rng::Rng;
use axllm::workload::Request;
use axllm::{prop_assert, prop_assert_eq};

/// Random quantized matrix whose codes cover the full i8 range —
/// including −128, which synthesized weights never carry
/// (`QuantMatrix::from_q` rejects it); built by struct literal precisely
/// to pin every kernel's handling of that code.
fn random_matrix(rng: &mut Rng, rows: usize, cols: usize) -> QuantMatrix {
    let data: Vec<i8> = (0..rows * cols)
        .map(|_| rng.range_i64(-128, 127) as i8)
        .collect();
    QuantMatrix {
        rows,
        cols,
        data,
        params: QuantParams {
            scale: 0.02,
            bits: 8,
        },
    }
}

fn random_x(rng: &mut Rng, rows: usize) -> Vec<i8> {
    (0..rows).map(|_| rng.range_i64(-127, 127) as i8).collect()
}

#[test]
fn prop_packed_kernel_matches_dense_and_scalar_exactly() {
    check(
        "packed-kernel-exact",
        Config {
            cases: 24,
            seed: 0xBAC5ED,
        },
        |rng| {
            let rows = 1 + rng.index(40);
            // Cols stress the tile walker: empty, single, sub-word,
            // word-aligned, and ragged widths all occur.
            let cols = *rng.choose(&[0usize, 1, 3, 4, 5, 8, 31, 64, 130]);
            let w = random_matrix(rng, rows, cols);
            let x = random_x(rng, rows);
            let packed = w.packed();
            let dense = dense_matmul(&x, &w);
            let mut arena = ExecArena::new();
            for chunk in [1usize, 2, 3, 4, 7, 16, 64, 500] {
                let (y_scalar, st_scalar) = reuse_matmul_chunked(&x, &w, chunk);
                prop_assert_eq!(&y_scalar, &dense);
                let st_packed = reuse_matmul_packed(&x, &packed, chunk, &mut arena);
                prop_assert_eq!(arena.yq(), &dense[..]);
                // Counters too: first-occurrence accounting is
                // order-free within a chunk epoch, so the tiled walk
                // must reproduce the scalar split exactly.
                prop_assert_eq!(st_packed, st_scalar);
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packed_sharded_kernel_matches_scalar_per_shard() {
    check(
        "packed-sharded-exact",
        Config {
            cases: 16,
            seed: 0xBAC5EE,
        },
        |rng| {
            let rows = 1 + rng.index(24);
            let cols = *rng.choose(&[1usize, 2, 5, 16, 65, 130]);
            let w = random_matrix(rng, rows, cols);
            let x = random_x(rng, rows);
            let packed = w.packed();
            let dense = dense_matmul(&x, &w);
            let mut arena = ExecArena::new();
            for shards in [1usize, 2, 4] {
                for chunk in [1usize, 3, 7, 64] {
                    let (y_scalar, per_scalar) =
                        sharded_reuse_matmul_chunked(&x, &w, chunk, shards);
                    prop_assert_eq!(&y_scalar, &dense);
                    let mut per_packed = vec![ExecStats::default(); per_scalar.len()];
                    let total = sharded_reuse_matmul_packed(
                        &x,
                        &packed,
                        chunk,
                        shards,
                        &mut per_packed,
                        &mut arena,
                    );
                    prop_assert_eq!(arena.yq(), &dense[..]);
                    prop_assert_eq!(&per_packed, &per_scalar);
                    let fold = per_scalar.iter().fold(ExecStats::default(), |mut a, s| {
                        a.add(s);
                        a
                    });
                    prop_assert_eq!((total.mults, total.reuses), (fold.mults, fold.reuses));
                }
            }
            Ok(())
        },
    );
}

fn backend(seed: u64) -> FunctionalBackend {
    FunctionalBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper(), seed).unwrap()
}

fn req(id: u64, seq_len: usize) -> Request {
    Request {
        id,
        dataset: Dataset::AgNews,
        seq_len,
        arrival_s: 0.0,
        gen_tokens: 0,
        adapter: None,
        prefix: None,
        slo: axllm::workload::SloClass::Standard,
    }
}

#[test]
fn prop_backend_scalar_baseline_and_packed_default_agree_end_to_end() {
    check(
        "packed-backend-exact",
        Config {
            cases: 3,
            seed: 0xBAC5EF,
        },
        |rng| {
            let model_seed = rng.below(1_000_000);
            for shards in [1usize, 2, 4] {
                let fast = backend(model_seed).with_shards(shards).with_adapters(2, 4);
                let slow = backend(model_seed)
                    .with_shards(shards)
                    .with_adapters(2, 4)
                    .with_scalar_kernels(true);
                // A mixed batch: base-only and both LoRA tenants.
                let reqs: Vec<Request> = (0..4u64)
                    .map(|i| Request {
                        adapter: if i % 2 == 0 { None } else { Some((i % 3) as u32) },
                        ..req(i, 3 + rng.index(10))
                    })
                    .collect();
                let of = fast.run_batch(&reqs).map_err(|e| e.to_string())?;
                let os = slow.run_batch(&reqs).map_err(|e| e.to_string())?;
                prop_assert_eq!(&of.logits, &os.logits);
                prop_assert_eq!(&of.activity, &os.activity);
                prop_assert_eq!(of.stats.mults, os.stats.mults);
                prop_assert_eq!(of.stats.rc_hits, os.stats.rc_hits);
                // Batch-order independence: reversing the batch permutes
                // per-request rows without changing any of them.
                let mut rev = reqs.clone();
                rev.reverse();
                let or = fast.run_batch(&rev).map_err(|e| e.to_string())?;
                for (i, r) in rev.iter().enumerate() {
                    let j = reqs.iter().position(|q| q.id == r.id).expect("same ids");
                    prop_assert_eq!(&or.logits[i], &of.logits[j]);
                    prop_assert_eq!(&or.activity[i], &of.activity[j]);
                }
                // KV-cached decode: stepped sessions agree bit for bit.
                let r = Request {
                    adapter: Some(1),
                    ..req(99, 2 + rng.index(8))
                };
                let (mut kv_f, f_f) = fast.prefill(&r, 3).map_err(|e| e.to_string())?;
                let (mut kv_s, f_s) = slow.prefill(&r, 3).map_err(|e| e.to_string())?;
                prop_assert_eq!(&f_f.logits, &f_s.logits);
                prop_assert_eq!(&f_f.activity, &f_s.activity);
                while !kv_f.done() {
                    let o_f = fast.decode_step(&mut kv_f).map_err(|e| e.to_string())?;
                    let o_s = slow.decode_step(&mut kv_s).map_err(|e| e.to_string())?;
                    prop_assert_eq!(&o_f.logits, &o_s.logits);
                    prop_assert_eq!(o_f.token, o_s.token);
                    prop_assert_eq!(&o_f.activity, &o_s.activity);
                }
                prop_assert_eq!(&kv_f.generated, &kv_s.generated);
            }
            Ok(())
        },
    );
}

#[test]
fn prop_decode_waves_match_single_stepping() {
    check(
        "packed-decode-waves",
        Config {
            cases: 3,
            seed: 0xBAC5F0,
        },
        |rng| {
            let model_seed = rng.below(1_000_000);
            let b = backend(model_seed);
            let n = 2 + rng.index(5);
            let jobs: Vec<(Request, u32)> = (0..n as u64)
                .map(|i| (req(i, 2 + rng.index(10)), 2 + rng.below(3) as u32))
                .collect();
            // Reference: one call at a time.
            let mut seq = Vec::new();
            for (r, budget) in &jobs {
                seq.push(b.prefill(r, *budget).map_err(|e| e.to_string())?);
            }
            // Wave APIs (thread-parallel inside the backend).
            let mut wave = b.prefill_batch(&jobs).map_err(|e| e.to_string())?;
            for ((kv_w, out_w), (kv_s, out_s)) in wave.iter().zip(&seq) {
                prop_assert_eq!(&out_w.logits, &out_s.logits);
                prop_assert_eq!(&out_w.activity, &out_s.activity);
                prop_assert_eq!(&kv_w.generated, &kv_s.generated);
            }
            while wave.iter().any(|(kv, _)| !kv.done()) {
                let refs: Vec<_> = wave
                    .iter_mut()
                    .filter(|(kv, _)| !kv.done())
                    .map(|(kv, _)| kv)
                    .collect();
                let outs = b.decode_steps(refs).map_err(|e| e.to_string())?;
                let mut outs = outs.into_iter();
                for (kv_s, _) in seq.iter_mut() {
                    if kv_s.done() {
                        continue;
                    }
                    let expect = b.decode_step(kv_s).map_err(|e| e.to_string())?;
                    let got = outs.next().expect("wave covers every live session");
                    prop_assert_eq!(&got.logits, &expect.logits);
                    prop_assert_eq!(got.token, expect.token);
                    prop_assert_eq!(&got.activity, &expect.activity);
                }
            }
            for ((kv_w, _), (kv_s, _)) in wave.iter().zip(&seq) {
                prop_assert_eq!(&kv_w.generated, &kv_s.generated);
            }
            Ok(())
        },
    );
}
