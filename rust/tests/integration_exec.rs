//! Integration: the functional executor against the simulator and the
//! transformer layer against its own invariants.

use axllm::config::{AcceleratorConfig, LoraConfig, ModelConfig};
use axllm::exec::{dense_matmul, reuse_matmul_chunked, LayerExec};
use axllm::model::{MatKind, Model};
use axllm::quant::stats::measure_locality;
use axllm::sim::accelerator::synth_input;
use axllm::sim::Accelerator;
use axllm::workload::synth_embeddings;

#[test]
fn exec_reuse_counters_match_locality_statistics() {
    // The executor's measured mult count must equal the locality
    // module's unique-per-chunk count — two independent implementations
    // of the same statistic.
    let model = Model::new(ModelConfig::distilbert(), 21);
    let w = model.matrix_rows(0, MatKind::Ff1, 32);
    let x = synth_input(w.rows, 1);
    for chunk in [64usize, 256, 512] {
        let (_, stats) = reuse_matmul_chunked(&x, &w, chunk);
        let loc = measure_locality(&w, chunk);
        assert_eq!(stats.mults, loc.unique, "chunk={chunk}");
        assert!((stats.reuse_rate() - loc.reuse_rate()).abs() < 1e-12);
    }
}

#[test]
fn exec_and_simulator_agree_on_mult_counts() {
    let model = Model::new(ModelConfig::bert_base(), 23);
    let w = model.matrix_rows(0, MatKind::Wv, 64);
    let x = synth_input(w.rows, 2);
    let cfg = AcceleratorConfig::paper();
    let sim = Accelerator::axllm(cfg).matmul(&x, &w).stats;
    let (y, stats) = reuse_matmul_chunked(&x, &w, cfg.buffer_entries.min(cfg.round_cols));
    assert_eq!(sim.mults, stats.mults);
    assert_eq!(sim.rc_hits, stats.reuses);
    assert_eq!(y, dense_matmul(&x, &w));
}

#[test]
fn layer_forward_runs_tiny_model_end_to_end_in_rust() {
    let cfg = ModelConfig::tiny();
    let model = Model::new(cfg.clone(), 25);
    let w0 = model.layer(0);
    let w1 = model.layer(1);
    let seq = 8;
    let x = synth_embeddings(seq, cfg.d_model, 9);
    let mut l0 = LayerExec::new(&cfg, &w0, 256);
    let mut l1 = LayerExec::new(&cfg, &w1, 256);
    let h = l0.forward(&x, seq);
    let y = l1.forward(&h, seq);
    assert_eq!(y.len(), seq * cfg.d_model);
    assert!(y.iter().all(|v| v.is_finite()));
    // Layers have different weights → different transforms.
    assert_ne!(h, y);
    // Both layers exercised reuse.
    assert!(l0.stats.reuse_rate() > 0.2);
    assert!(l1.stats.reuse_rate() > 0.2);
}

#[test]
fn lora_layer_weights_share_grid_with_base() {
    let cfg = ModelConfig::tiny().with_lora(LoraConfig { rank: 8, alpha: 16.0 });
    let model = Model::new(cfg, 27);
    let layer = model.layer(0);
    let wq = layer.get(MatKind::Wq);
    let lora = layer.lora_q.as_ref().unwrap();
    assert_eq!(lora.a.params, wq.params, "A must live on W's grid");
    assert!(lora.overlap_with(wq) > 0.5);
}

#[test]
fn reuse_rate_insensitive_to_input_values() {
    // Reuse is a weight-side property: different inputs, same counters.
    let model = Model::new(ModelConfig::distilbert(), 29);
    let w = model.matrix_rows(0, MatKind::Wq, 16);
    let x1 = synth_input(w.rows, 100);
    let x2 = synth_input(w.rows, 200);
    let (_, s1) = reuse_matmul_chunked(&x1, &w, 256);
    let (_, s2) = reuse_matmul_chunked(&x2, &w, 256);
    assert_eq!(s1.mults, s2.mults);
    assert_eq!(s1.reuses, s2.reuses);
}
