//! Property tests for tensor-parallel sharded execution (in-crate
//! property runner — see `util::prop`).
//!
//! Three claims anchor the shard-aware serving stack:
//! 1. **Shard exactness** — `FunctionalBackend::with_shards(n)` logits
//!    are bit-identical to the unsharded deployment for n ∈ {1, 2, 4},
//!    on batch prefill AND on KV-cached decode: column partitioning is
//!    exact, so sharding (like the Result Cache and the KV cache) is a
//!    scheduling transformation, never an approximation.
//! 2. **Sum-consistent accounting** — per-shard reuse counters partition
//!    the request's total base ops exactly, and independent per-shard
//!    caches can only lose reuse in aggregate.
//! 3. **Honest collective costs** — the sharded sim deployment serves a
//!    token batch faster than monolithic (compute / N) but sub-linearly
//!    (the all-gather does not shard away).

use axllm::backend::{ExecutionBackend, FunctionalBackend, SimBackend};
use axllm::config::{AcceleratorConfig, Dataset, ModelConfig};
use axllm::coordinator::{BatchPolicy, Engine};
use axllm::util::prop::{check, Config};
use axllm::workload::{Request, TraceGenerator};
use axllm::{prop_assert, prop_assert_eq};

fn req(id: u64, seq_len: usize, gen_tokens: u32, arrival_s: f64) -> Request {
    Request {
        id,
        dataset: Dataset::Imdb,
        seq_len,
        arrival_s,
        gen_tokens,
        adapter: None,
        prefix: None,
        slo: axllm::workload::SloClass::Standard,
    }
}

#[test]
fn prop_sharded_functional_bit_identical_on_prefill_and_decode() {
    check(
        "sharded-functional-exact",
        Config {
            cases: 4,
            seed: 0x54A2D,
        },
        |rng| {
            let model_seed = rng.below(1_000_000);
            let mono = FunctionalBackend::new(
                ModelConfig::tiny(),
                AcceleratorConfig::paper(),
                model_seed,
            )
            .map_err(|e| e.to_string())?;
            let r = req(rng.below(10_000), 2 + rng.index(12), 0, 0.0);
            let steps = 1 + rng.index(3);
            let (lm, sm) = mono.forward(&r);
            for shards in [1usize, 2, 4] {
                let b = FunctionalBackend::new(
                    ModelConfig::tiny(),
                    AcceleratorConfig::paper(),
                    model_seed,
                )
                .map_err(|e| e.to_string())?
                .with_shards(shards);
                prop_assert_eq!(b.shard_count(), shards);
                // Batch-prefill logits: bit-identical.
                let (ls, ss) = b.forward(&r);
                prop_assert_eq!(&lm, &ls);
                // Ops partition exactly; reuse can only drop.
                prop_assert_eq!(sm.mults + sm.reuses, ss.mults + ss.reuses);
                prop_assert!(
                    ss.mults >= sm.mults,
                    "shards={} mults {} < monolithic {}",
                    shards,
                    ss.mults,
                    sm.mults
                );
                // Per-request per-shard split is sum-consistent.
                let out = b.run_batch(std::slice::from_ref(&r)).map_err(|e| e.to_string())?;
                let a = &out.activity[0];
                if shards > 1 {
                    prop_assert_eq!(a.per_shard.len(), shards);
                    let ops: u64 = a.per_shard.iter().map(|s| s.ops()).sum();
                    prop_assert_eq!(ops, a.base_mults + a.base_reuses);
                } else {
                    prop_assert!(a.per_shard.is_empty(), "1-shard runs are monolithic");
                }
                // KV-cached decode: every step's logits and token match
                // the unsharded session bit for bit.
                let (mut kv_m, f_m) =
                    mono.prefill(&r, (steps + 1) as u32).map_err(|e| e.to_string())?;
                let (mut kv_s, f_s) =
                    b.prefill(&r, (steps + 1) as u32).map_err(|e| e.to_string())?;
                prop_assert_eq!(&f_m.logits, &f_s.logits);
                prop_assert_eq!(f_m.token, f_s.token);
                while !kv_m.done() {
                    let om = mono.decode_step(&mut kv_m).map_err(|e| e.to_string())?;
                    let os = b.decode_step(&mut kv_s).map_err(|e| e.to_string())?;
                    prop_assert_eq!(&om.logits, &os.logits);
                    prop_assert_eq!(om.token, os.token);
                    if shards > 1 {
                        let ops: u64 =
                            os.activity.per_shard.iter().map(|s| s.ops()).sum();
                        prop_assert_eq!(
                            ops,
                            os.activity.base_mults + os.activity.base_reuses
                        );
                    }
                }
                prop_assert_eq!(&kv_m.generated, &kv_s.generated);
                // And the decode-exactness reference still holds sharded.
                prop_assert_eq!(
                    b.recompute_logits(&r, &kv_m.generated[..kv_m.generated.len() - 1]),
                    mono.recompute_logits(&r, &kv_m.generated[..kv_m.generated.len() - 1])
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sharded_serve_summary_is_sum_consistent_and_faster() {
    check(
        "sharded-serve-summary",
        Config {
            cases: 6,
            seed: 0x54A2E,
        },
        |rng| {
            let n = 8 + rng.index(16);
            let trace = TraceGenerator::new(Dataset::Imdb, 100_000.0, rng.below(1_000))
                .take(n);
            let policy = BatchPolicy {
                max_batch: 8,
                max_wait_s: 0.001,
            };
            let mono = Engine::new(
                SimBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper())
                    .map_err(|e| e.to_string())?,
            );
            let sharded = Engine::new(
                SimBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper())
                    .map_err(|e| e.to_string())?
                    .with_shards(4),
            );
            let (rm, sm) = mono
                .serve_trace(trace.clone(), policy)
                .map_err(|e| e.to_string())?;
            let (rs, ss) = sharded.serve_trace(trace, policy).map_err(|e| e.to_string())?;
            prop_assert_eq!(rm.len(), rs.len());
            // Identical batching and token accounting per request.
            for (a, b) in rm.iter().zip(&rs) {
                prop_assert_eq!(a.id, b.id);
                prop_assert_eq!(a.tokens, b.tokens);
                prop_assert_eq!(a.batch_size, b.batch_size);
            }
            // Sharding wins in aggregate: total simulated service time is
            // strictly smaller. (A degenerate few-token batch can lose to
            // the collective latency on its own — that is the honest
            // physics of tensor parallelism — but the run as a whole
            // must come out ahead.)
            let mono_exec: f64 = rm.iter().map(|r| r.exec_s).sum();
            let shard_exec: f64 = rs.iter().map(|r| r.exec_s).sum();
            prop_assert!(
                shard_exec < mono_exec,
                "sharded total exec {shard_exec} !< monolithic {mono_exec}"
            );
            // The summary reports 4 shards, sum-consistent with the
            // run's total base ops.
            prop_assert_eq!(ss.per_shard.len(), 4);
            let shard_ops: u64 = ss
                .per_shard
                .iter()
                .map(|g| g.base_mults + g.base_reuses)
                .sum();
            let total_ops: u64 = rs.iter().map(|r| r.base_mults + r.base_reuses).sum();
            prop_assert_eq!(shard_ops, total_ops);
            prop_assert!(
                ss.per_shard.iter().all(|g| g.reuse_rate > 0.0),
                "every shard must see reuse on Gaussian weights"
            );
            prop_assert!(sm.per_shard.is_empty(), "monolithic run has no shard rollup");
            Ok(())
        },
    );
}

#[test]
fn sharded_decode_trace_matches_unsharded_logits_end_to_end() {
    // Engine-level fixed case: the whole continuous-batching decode path
    // (admission, iteration loop, retirement) under sharding returns the
    // same final logits per request as the unsharded deployment.
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait_s: 0.002,
    };
    let trace: Vec<Request> = (0..8).map(|i| req(i, 4 + (i as usize % 7), 3, 0.0)).collect();
    let mono = Engine::new(
        FunctionalBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper(), 42).unwrap(),
    );
    let sharded = Engine::new(
        FunctionalBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper(), 42)
            .unwrap()
            .with_shards(2),
    );
    let (rm, _) = mono.serve_trace_decode(trace.clone(), policy, 1).unwrap();
    let (rs, ss) = sharded.serve_trace_decode(trace, policy, 1).unwrap();
    assert_eq!(rm.len(), rs.len());
    let by_id = |mut v: Vec<axllm::coordinator::RequestResult>| {
        v.sort_by_key(|r| r.id);
        v
    };
    let (rm, rs) = (by_id(rm), by_id(rs));
    for (a, b) in rm.iter().zip(&rs) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.logits, b.logits, "request {}", a.id);
        assert_eq!(a.gen_tokens, b.gen_tokens);
        assert_eq!(
            a.base_mults + a.base_reuses,
            b.base_mults + b.base_reuses,
            "ops partition for request {}",
            a.id
        );
        assert_eq!(b.per_shard.len(), 2);
    }
    assert_eq!(ss.per_shard.len(), 2);
    let shard_ops: u64 = ss
        .per_shard
        .iter()
        .map(|g| g.base_mults + g.base_reuses)
        .sum();
    let total_ops: u64 = rs.iter().map(|r| r.base_mults + r.base_reuses).sum();
    assert_eq!(shard_ops, total_ops);
}
