//! Property tests over the cycle simulator (in-crate property runner —
//! see `util::prop`). Each property runs 64 seeded cases by default
//! (AXLLM_PROP_CASES overrides).

use axllm::config::AcceleratorConfig;
use axllm::quant::{QuantMatrix, QuantParams};
use axllm::sim::{baseline, lane, sliced, Accelerator, LaneModel};
use axllm::util::prop::{check_default, Config};
use axllm::util::rng::Rng;
use axllm::{prop_assert, prop_assert_eq};

fn random_weights(rng: &mut Rng, n: usize) -> Vec<i8> {
    // Mix of distributions: uniform, concentrated, constant runs.
    match rng.index(3) {
        0 => (0..n).map(|_| rng.range_i64(-127, 127) as i8).collect(),
        1 => (0..n)
            .map(|_| (rng.normal() * 12.0).round().clamp(-127.0, 127.0) as i8)
            .collect(),
        _ => {
            let v = rng.range_i64(-127, 127) as i8;
            let mut out = vec![v; n];
            for _ in 0..n / 4 {
                let i = rng.index(n);
                out[i] = rng.range_i64(-127, 127) as i8;
            }
            out
        }
    }
}

fn rand_cfg(rng: &mut Rng) -> AcceleratorConfig {
    let slices = *rng.choose(&[1usize, 2, 4, 8]);
    AcceleratorConfig {
        lanes: *rng.choose(&[1usize, 4, 16, 64]),
        buffer_entries: *rng.choose(&[64usize, 128, 256, 512]),
        slices,
        queue_depth: *rng.choose(&[1usize, 2, 4, 8]),
        ..AcceleratorConfig::paper()
    }
}

#[test]
fn prop_all_lane_models_functionally_equivalent() {
    check_default("lane-models-equivalent", |rng| {
        let n = 1 + rng.index(256);
        let weights = random_weights(rng, n);
        let x = rng.range_i64(-127, 127) as i8;
        let cfg = rand_cfg(rng);
        let cfg = AcceleratorConfig {
            buffer_entries: cfg.buffer_entries.max(n),
            ..cfg
        };
        let expect: Vec<i32> = weights.iter().map(|&w| x as i32 * w as i32).collect();
        prop_assert_eq!(lane::simulate_chunk(x, &weights, &cfg).partials, expect);
        prop_assert_eq!(baseline::simulate_chunk(x, &weights, &cfg).partials, expect);
        prop_assert_eq!(sliced::simulate_chunk(x, &weights, &cfg).partials, expect);
        Ok(())
    });
}

#[test]
fn prop_element_conservation_and_reuse_bounds() {
    check_default("element-conservation", |rng| {
        let n = 1 + rng.index(256);
        let weights = random_weights(rng, n);
        let x = rng.range_i64(-127, 127) as i8;
        let cfg = AcceleratorConfig {
            buffer_entries: 256,
            ..rand_cfg(rng)
        };
        for s in [
            lane::simulate_chunk(x, &weights, &cfg).stats,
            sliced::simulate_chunk(x, &weights, &cfg).stats,
        ] {
            prop_assert_eq!(s.elements, n as u64);
            prop_assert_eq!(s.mults + s.rc_hits, s.elements);
            prop_assert_eq!(s.out_writes, s.elements);
            prop_assert_eq!(s.rc_writes, s.mults);
            prop_assert_eq!(s.rc_reads, s.rc_hits);
            prop_assert!(s.mults <= 128.min(n) as u64, "mults {} n {}", s.mults, n);
        }
        Ok(())
    });
}

#[test]
fn prop_serial_cycles_closed_form() {
    check_default("serial-closed-form", |rng| {
        let n = 1 + rng.index(256);
        let weights = random_weights(rng, n);
        let x = rng.range_i64(-127, 127) as i8;
        let cfg = AcceleratorConfig::paper();
        let r = lane::simulate_chunk(x, &weights, &cfg);
        prop_assert_eq!(
            r.stats.cycles,
            lane::serial_cycles(n as u64, r.stats.mults, &cfg)
        );
        Ok(())
    });
}

#[test]
fn prop_reuse_never_slower_than_baseline() {
    check_default("reuse-never-slower", |rng| {
        let n = 1 + rng.index(256);
        let weights = random_weights(rng, n);
        let x = rng.range_i64(-127, 127) as i8;
        let cfg = AcceleratorConfig::paper();
        let ax = lane::simulate_chunk(x, &weights, &cfg).stats.cycles;
        let ba = baseline::simulate_chunk(x, &weights, &cfg).stats.cycles;
        prop_assert!(ax <= ba, "ax {} > baseline {}", ax, ba);
        Ok(())
    });
}

#[test]
fn prop_sliced_worst_case_bounded_by_serialization() {
    // The §IV claim: worst case degrades to the non-parallel baseline —
    // never worse than a small constant over the serial lane (queue
    // effects can add a few cycles of pipeline fill).
    check_default("sliced-worst-case", |rng| {
        let n = 1 + rng.index(256);
        let weights = random_weights(rng, n);
        let x = rng.range_i64(-127, 127) as i8;
        let cfg = AcceleratorConfig {
            buffer_entries: 256,
            ..rand_cfg(rng)
        };
        let s = sliced::simulate_chunk(x, &weights, &cfg).stats.cycles;
        let serial = lane::simulate_chunk(x, &weights, &cfg).stats.cycles;
        prop_assert!(
            s <= serial + 16 + n as u64 / 4,
            "sliced {} vs serial bound {}",
            s,
            serial
        );
        Ok(())
    });
}

#[test]
fn prop_accelerator_matmul_equals_dense_random_shapes() {
    axllm::util::prop::check(
        "accelerator-dense",
        Config { cases: 24, seed: 0xACC },
        |rng| {
            let rows = 1 + rng.index(96);
            let cols = 1 + rng.index(160);
            let data: Vec<i8> = (0..rows * cols)
                .map(|_| rng.range_i64(-127, 127) as i8)
                .collect();
            let w = QuantMatrix::from_q(rows, cols, data, QuantParams { scale: 1.0, bits: 8 });
            let x: Vec<i8> = (0..rows).map(|_| rng.range_i64(-127, 127) as i8).collect();
            let cfg = AcceleratorConfig {
                lanes: *rng.choose(&[1usize, 8, 32]),
                ..AcceleratorConfig::paper()
            };
            let lm = *rng.choose(&[LaneModel::Baseline, LaneModel::Serial, LaneModel::Sliced]);
            let out = Accelerator::axllm(cfg).with_lane_model(lm).matmul(&x, &w);
            let mut dense = vec![0i32; cols];
            for i in 0..rows {
                for j in 0..cols {
                    dense[j] += x[i] as i32 * w.get(i, j) as i32;
                }
            }
            prop_assert_eq!(out.output, dense);
            Ok(())
        },
    );
}

#[test]
fn prop_stats_scaled_consistency() {
    check_default("stats-scaling", |rng| {
        let n = 1 + rng.index(200);
        let weights = random_weights(rng, n);
        let x = rng.range_i64(-127, 127) as i8;
        let s = lane::simulate_chunk(x, &weights, &AcceleratorConfig::paper()).stats;
        let k = 1 + rng.below(7);
        let scaled = s.scaled(k, 1);
        prop_assert_eq!(scaled.cycles, s.cycles * k);
        prop_assert_eq!(scaled.mults + scaled.rc_hits, scaled.elements);
        // Rates are scale-invariant.
        prop_assert!((scaled.reuse_rate() - s.reuse_rate()).abs() < 1e-9);
        Ok(())
    });
}
