//! Property tests over the execution-backend API (in-crate property
//! runner — see `util::prop`).
//!
//! Two equivalence claims anchor the backend redesign:
//! 1. the `FunctionalBackend` logit path (reuse matmul at the backend's
//!    W_buff chunk) is bit-identical to dense int8×int8→i32 GEMM;
//! 2. every built-in `LaneSim` implementation produces identical
//!    functional output and element counts — lane models differ only in
//!    timing, never in arithmetic.

use axllm::backend::FunctionalBackend;
use axllm::config::{AcceleratorConfig, ModelConfig};
use axllm::exec::{dense_matmul, reuse_matmul_chunked};
use axllm::quant::{QuantMatrix, QuantParams};
use axllm::sim::{Accelerator, LaneModel, ALL_LANE_SIMS};
use axllm::util::prop::{check, Config};
use axllm::util::rng::Rng;
use axllm::{prop_assert, prop_assert_eq};

fn random_matrix(rng: &mut Rng, rows: usize, cols: usize) -> QuantMatrix {
    let data: Vec<i8> = (0..rows * cols)
        .map(|_| rng.range_i64(-127, 127) as i8)
        .collect();
    QuantMatrix::from_q(rows, cols, data, QuantParams { scale: 1.0, bits: 8 })
}

fn random_input(rng: &mut Rng, n: usize) -> Vec<i8> {
    (0..n).map(|_| rng.range_i64(-127, 127) as i8).collect()
}

#[test]
fn prop_functional_logit_path_bit_identical_to_dense() {
    let backend =
        FunctionalBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper(), 42).unwrap();
    let chunk = backend.chunk();
    assert!(chunk > 0);
    check(
        "functional-dense-exact",
        Config {
            cases: 48,
            seed: 0xF0,
        },
        |rng| {
            let rows = 1 + rng.index(96);
            let cols = 1 + rng.index(160);
            let w = random_matrix(rng, rows, cols);
            let x = random_input(rng, rows);
            let (y, stats) = reuse_matmul_chunked(&x, &w, chunk);
            prop_assert_eq!(y, dense_matmul(&x, &w));
            prop_assert_eq!(stats.mults + stats.reuses, (rows * cols) as u64);
            Ok(())
        },
    );
}

#[test]
fn prop_lane_sim_trait_objects_agree_on_chunks() {
    check(
        "lane-sim-chunks-agree",
        Config {
            cases: 64,
            seed: 0x1A,
        },
        |rng| {
            let n = 1 + rng.index(256);
            let weights = random_input(rng, n);
            let x = rng.range_i64(-127, 127) as i8;
            let cfg = AcceleratorConfig::paper();
            let base = ALL_LANE_SIMS[0].simulate_chunk(x, &weights, &cfg);
            prop_assert_eq!(base.stats.elements, n as u64);
            for sim in &ALL_LANE_SIMS[1..] {
                let r = sim.simulate_chunk(x, &weights, &cfg);
                prop_assert_eq!(r.partials, base.partials);
                prop_assert_eq!(r.stats.elements, base.stats.elements);
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lane_sim_impls_agree_on_matmuls() {
    // Generalizes the fixed-case `matmul_matches_dense_all_lane_models`
    // unit test: randomized shapes/configs, dispatched through the
    // builder-constructed trait objects.
    check(
        "lane-sim-matmuls-agree",
        Config {
            cases: 24,
            seed: 0x1B,
        },
        |rng| {
            let rows = 1 + rng.index(80);
            let cols = 1 + rng.index(128);
            let w = random_matrix(rng, rows, cols);
            let x = random_input(rng, rows);
            let cfg = AcceleratorConfig {
                lanes: *rng.choose(&[1usize, 8, 32]),
                ..AcceleratorConfig::paper()
            };
            let dense = dense_matmul(&x, &w);
            let mut outputs = Vec::new();
            for lm in LaneModel::ALL {
                let acc = Accelerator::builder()
                    .config(cfg)
                    .lane_model(lm)
                    .build()
                    .map_err(|e| e.to_string())?;
                let r = acc.matmul(&x, &w);
                prop_assert_eq!(r.output, dense);
                outputs.push((r.stats.elements, lm));
            }
            for (elems, lm) in &outputs[1..] {
                prop_assert!(
                    *elems == outputs[0].0,
                    "{lm:?} elements {} != {}",
                    elems,
                    outputs[0].0
                );
            }
            Ok(())
        },
    );
}
