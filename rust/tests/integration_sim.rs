//! Integration: the cycle simulator across modules — model zoo →
//! quantization → accelerator → stats, including cross-lane-model
//! functional equivalence and the paper's headline bands.

use axllm::config::{table1_benchmarks, AcceleratorConfig, ModelConfig};
use axllm::exec::dense_matmul;
use axllm::model::{MatKind, Model};
use axllm::sim::accelerator::synth_input;
use axllm::sim::{Accelerator, LaneModel};

#[test]
fn all_lane_models_agree_with_dense_on_all_matrix_kinds() {
    let model = Model::new(ModelConfig::tiny(), 3);
    let cfg = AcceleratorConfig {
        lanes: 16,
        ..AcceleratorConfig::paper()
    };
    for kind in MatKind::ALL {
        let w = model.matrix(0, kind);
        let x = synth_input(w.rows, kind as u64);
        let dense = dense_matmul(&x, &w);
        for lm in [LaneModel::Baseline, LaneModel::Serial, LaneModel::Sliced] {
            let out = Accelerator::axllm(cfg).with_lane_model(lm).matmul(&x, &w);
            assert_eq!(out.output, dense, "{kind:?} {lm:?}");
        }
    }
}

#[test]
fn element_conservation_across_all_benchmarks() {
    // Every weight element is processed exactly once: elements ==
    // mults + rc_hits == out_writes, for every Table-I model.
    let cfg = AcceleratorConfig::paper();
    for b in table1_benchmarks() {
        let model = Model::new(b.model.clone(), 1);
        let w = model.matrix_rows(0, MatKind::Wk, 64);
        let x = synth_input(w.rows, 2);
        let s = Accelerator::axllm(cfg).matmul(&x, &w).stats;
        assert_eq!(s.elements, s.mults + s.rc_hits, "{}", b.key());
        assert_eq!(s.elements, s.out_writes, "{}", b.key());
        assert_eq!(s.elements, (w.rows * w.cols) as u64, "{}", b.key());
    }
}

#[test]
fn speedup_grows_with_buffer_size() {
    let model = Model::new(ModelConfig::bert_large(), 5);
    let w = model.matrix_rows(0, MatKind::Ff1, 64);
    let x = synth_input(w.rows, 3);
    let mut prev = 0.0;
    for buffers in [64usize, 256, 1024] {
        let cfg = AcceleratorConfig {
            buffer_entries: buffers,
            slices: 4,
            ..AcceleratorConfig::paper()
        };
        let ax = Accelerator::axllm(cfg).matmul(&x, &w).stats;
        let base = Accelerator::baseline(cfg).matmul(&x, &w).stats;
        let speedup = base.cycles as f64 / ax.cycles as f64;
        assert!(speedup > prev, "buffers={buffers}: {speedup} !> {prev}");
        prev = speedup;
    }
}

#[test]
fn lane_count_scales_group_cycles_inverse_linearly() {
    let model = Model::new(ModelConfig::distilbert(), 7);
    let w = model.matrix_rows(0, MatKind::Wo, 64);
    let x = synth_input(w.rows, 4);
    let c16 = Accelerator::axllm(AcceleratorConfig {
        lanes: 16,
        ..AcceleratorConfig::paper()
    })
    .matmul(&x, &w)
    .stats
    .cycles;
    let c64 = Accelerator::axllm(AcceleratorConfig::paper())
        .matmul(&x, &w)
        .stats
        .cycles;
    let ratio = c16 as f64 / c64 as f64;
    assert!((3.0..5.0).contains(&ratio), "16→64 lanes ratio {ratio}");
}

#[test]
fn sliced_model_beats_serial_on_this_workload() {
    // The §IV parallel architecture exists to go faster; confirm it does
    // on realistic weights at P=4.
    let model = Model::new(ModelConfig::distilbert(), 9);
    let w = model.matrix_rows(0, MatKind::Wq, 64);
    let x = synth_input(w.rows, 5);
    let cfg = AcceleratorConfig::paper();
    let serial = Accelerator::axllm(cfg).matmul(&x, &w).stats.cycles;
    let sliced = Accelerator::axllm(cfg)
        .with_lane_model(LaneModel::Sliced)
        .matmul(&x, &w)
        .stats
        .cycles;
    assert!(
        sliced < serial,
        "sliced ({sliced}) should beat serial ({serial})"
    );
}

#[test]
fn mult_reduction_up_to_90_percent_with_full_rows() {
    // Headline claim: "up to 90% reduction in computations" — holds for
    // large matrices with full-row buffers.
    let model = Model::new(ModelConfig::llama_7b(), 11);
    let w = model.matrix_rows(0, MatKind::Wq, 64);
    let x = synth_input(w.rows, 6);
    let cfg = AcceleratorConfig {
        buffer_entries: 4096,
        slices: 4,
        round_cols: 4096,
        ..AcceleratorConfig::paper()
    };
    let s = Accelerator::axllm(cfg).matmul(&x, &w).stats;
    assert!(
        s.mult_reduction() > 0.90,
        "mult reduction {}",
        s.mult_reduction()
    );
}

#[test]
fn run_model_parallelism_is_deterministic() {
    let model = Model::new(ModelConfig::tiny(), 13);
    let acc = Accelerator::axllm(AcceleratorConfig::paper());
    let a = acc.run_model(&model, 64, 9).total;
    let b = acc.run_model(&model, 64, 9).total;
    assert_eq!(a, b);
}
