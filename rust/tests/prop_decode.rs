//! Property tests for phase-aware decode (in-crate property runner —
//! see `util::prop`).
//!
//! Two claims anchor the step-based execution API:
//! 1. **KV-cache exactness** — on `FunctionalBackend`, every decode
//!    step's logits are bit-identical to a full causal recomputation of
//!    the extended sequence from scratch. The KV cache (like the Result
//!    Cache) is a scheduling transformation, never an approximation.
//! 2. **Batch-independent attribution** — simulated decode cost depends
//!    only on each session's own context trajectory, never on which
//!    sessions it was continuously batched with.

use axllm::backend::{ExecutionBackend, FunctionalBackend, SimBackend};
use axllm::config::{AcceleratorConfig, Dataset, ModelConfig};
use axllm::coordinator::{BatchPolicy, Engine, RequestResult};
use axllm::util::prop::{check, Config};
use axllm::workload::Request;
use axllm::{prop_assert, prop_assert_eq};

fn req(id: u64, seq_len: usize, gen_tokens: u32, arrival_s: f64) -> Request {
    Request {
        id,
        dataset: Dataset::Imdb,
        seq_len,
        arrival_s,
        gen_tokens,
        adapter: None,
        prefix: None,
        slo: axllm::workload::SloClass::Standard,
    }
}

#[test]
fn prop_kv_cached_decode_bit_identical_to_full_recompute() {
    check(
        "kv-decode-exact",
        Config {
            cases: 6,
            seed: 0xDEC0,
        },
        |rng| {
            // A fresh random model per case (weights derive from the
            // seed), plus a random prompt and step count.
            let model_seed = rng.below(1_000_000);
            let backend = FunctionalBackend::new(
                ModelConfig::tiny(),
                AcceleratorConfig::paper(),
                model_seed,
            )
            .map_err(|e| e.to_string())?;
            let r = req(rng.below(10_000), 2 + rng.index(12), 0, 0.0);
            let steps = 1 + rng.index(4);
            let (mut kv, first) = backend
                .prefill(&r, (steps + 1) as u32)
                .map_err(|e| e.to_string())?;
            // Prefill logits == one-shot causal pass over the bare prompt.
            prop_assert_eq!(first.logits, backend.recompute_logits(&r, &[]));
            prop_assert_eq!(kv.generated.len(), 1);
            for step in 0..steps {
                let tokens_before = kv.generated.clone();
                let out = backend.decode_step(&mut kv).map_err(|e| e.to_string())?;
                // Step logits == full recompute of prompt + all tokens
                // fed so far, bit for bit.
                prop_assert_eq!(out.logits, backend.recompute_logits(&r, &tokens_before));
                prop_assert_eq!(kv.generated.len(), step + 2);
                prop_assert!(
                    out.stats.mults > 0 && out.stats.rc_hits > 0,
                    "decode steps must exercise the reuse datapath"
                );
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sim_decode_attribution_batch_independent() {
    let engine = Engine::new(
        SimBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper()).unwrap(),
    );
    let attribution = |results: &[RequestResult]| {
        let mut v: Vec<(u64, u64, u64, u64)> = results
            .iter()
            .map(|r| (r.id, r.tokens, r.gen_tokens, r.sim_cycles))
            .collect();
        v.sort_unstable();
        v
    };
    check(
        "sim-decode-attribution-batch-independent",
        Config {
            cases: 12,
            seed: 0xBA7C,
        },
        |rng| {
            let n = 4 + rng.index(12);
            let trace: Vec<Request> = (0..n)
                .map(|i| {
                    req(
                        i as u64,
                        4 + rng.index(28),
                        1 + rng.index(12) as u32,
                        i as f64 * 0.0004,
                    )
                })
                .collect();
            let narrow = BatchPolicy {
                max_batch: 2,
                max_wait_s: 0.001,
            };
            let wide = BatchPolicy {
                max_batch: 16,
                max_wait_s: 0.001,
            };
            let (rn, _) = engine
                .serve_trace_decode(trace.clone(), narrow, 4)
                .map_err(|e| e.to_string())?;
            let (rw, _) = engine
                .serve_trace_decode(trace.clone(), wide, 4)
                .map_err(|e| e.to_string())?;
            let (rcl, _) = engine
                .serve_trace_decode_closed(trace, wide, 4)
                .map_err(|e| e.to_string())?;
            prop_assert_eq!(rn.len(), n);
            // Per-request cycles/tokens identical at any concurrency —
            // and identical on the closed-batch comparator too.
            prop_assert_eq!(attribution(&rn), attribution(&rw));
            prop_assert_eq!(attribution(&rn), attribution(&rcl));
            Ok(())
        },
    );
}
