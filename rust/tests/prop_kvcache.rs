//! Property tests for the paged prefix KV cache (in-crate property
//! runner — see `util::prop`).
//!
//! Three claims anchor the cross-request prefix-reuse subsystem:
//! 1. **Warm-prefix exactness** — serving a request whose shared prefix
//!    is already cached produces logits and decode streams bit-identical
//!    to a cache-less deployment, across shard counts {1, 2, 4} and
//!    across adapter assignments (layer KV state is adapter-independent
//!    because LoRA touches only the classifier head). Prefix reuse —
//!    like the Result Cache, sharding, and the decode KV cache — is a
//!    scheduling transformation, never an approximation.
//! 2. **Pool soundness** — under arbitrary interleavings of inserts,
//!    pinned lookups, releases, evictions, and preemptions, every
//!    structural invariant holds: block refcounts are exactly
//!    `1 + pins`, never negative; blocks-in-use equals live trie nodes
//!    (no leaks, no double frees); capacity accounting balances.
//! 3. **Graceful degradation** — a zero-capacity pool is inert but
//!    safe, and preempted leases release as no-ops.

use axllm::backend::{ExecutionBackend, FunctionalBackend};
use axllm::config::{AcceleratorConfig, Dataset, ModelConfig};
use axllm::kvcache::{aligned_prefix, block_keys, BlockPool, KvCacheConfig, PrefixCache};
use axllm::util::prop::{check, Config};
use axllm::workload::{PrefixTag, Request};
use axllm::{prop_assert, prop_assert_eq};

fn req(
    id: u64,
    seq_len: usize,
    gen_tokens: u32,
    adapter: Option<u32>,
    prefix: Option<PrefixTag>,
) -> Request {
    Request {
        id,
        dataset: Dataset::Imdb,
        seq_len,
        arrival_s: 0.0,
        gen_tokens,
        adapter,
        prefix,
        slo: axllm::workload::SloClass::Standard,
    }
}

#[test]
fn prop_warm_prefix_bit_identical_across_shards_and_adapters() {
    check(
        "kvcache-warm-exact",
        Config {
            cases: 3,
            seed: 0x6B7CA,
        },
        |rng| {
            let model_seed = rng.below(1_000_000);
            let block_size = *rng.choose(&[4usize, 8]);
            let tag = PrefixTag {
                group: rng.below(64),
                len: block_size * (1 + rng.index(3)),
            };
            // Both requests extend past the tag so the full tag is
            // block-aligned cacheable (seq_len ≥ tag.len + 1).
            let seq_a = tag.len + 1 + rng.index(8);
            let seq_b = tag.len + 1 + rng.index(8);
            let budget = 1 + rng.index(3) as u32;
            // The primer and the warm request may carry *different*
            // adapters (or none): cached layer KV must be shared anyway.
            let adapter_a = (rng.index(2) == 0).then(|| rng.below(3) as u32);
            let adapter_b = (rng.index(2) == 0).then(|| rng.below(3) as u32);
            let a = req(101, seq_a, 0, adapter_a, Some(tag));
            let b = req(102, seq_b, 0, adapter_b, Some(tag));
            // Cold reference: cache-less, unsharded.
            let cold = FunctionalBackend::new(
                ModelConfig::tiny(),
                AcceleratorConfig::paper(),
                model_seed,
            )
            .map_err(|e| e.to_string())?
            .with_adapters(3, 2);
            let (mut kv_cold, f_cold) = cold.prefill(&b, budget).map_err(|e| e.to_string())?;
            for shards in [1usize, 2, 4] {
                let warm = FunctionalBackend::new(
                    ModelConfig::tiny(),
                    AcceleratorConfig::paper(),
                    model_seed,
                )
                .map_err(|e| e.to_string())?
                .with_adapters(3, 2)
                .with_shards(shards)
                .with_kv_cache(64, block_size);
                // Prime with the same-group request, then serve warm.
                warm.prefill(&a, 1).map_err(|e| e.to_string())?;
                let primed = warm.prefix_stats().expect("cache-enabled backend");
                prop_assert_eq!(primed.hits, 0);
                prop_assert_eq!(primed.inserted_blocks as usize, tag.len / block_size);
                let (mut kv_warm, f_warm) =
                    warm.prefill(&b, budget).map_err(|e| e.to_string())?;
                prop_assert_eq!(
                    kv_warm.cached_tokens,
                    aligned_prefix(tag.len, seq_b, block_size)
                );
                prop_assert_eq!(kv_warm.cached_tokens, tag.len);
                prop_assert_eq!(&f_cold.logits, &f_warm.logits);
                prop_assert_eq!(f_cold.token, f_warm.token);
                // Decode streams match step for step. The cold handle is
                // cloned per shard count by replaying from a fresh prefill.
                let (mut kv_ref, f_ref) =
                    cold.prefill(&b, budget).map_err(|e| e.to_string())?;
                prop_assert_eq!(f_ref.token, f_warm.token);
                while !kv_ref.done() {
                    let oc = cold.decode_step(&mut kv_ref).map_err(|e| e.to_string())?;
                    let ow = warm.decode_step(&mut kv_warm).map_err(|e| e.to_string())?;
                    prop_assert_eq!(&oc.logits, &ow.logits);
                    prop_assert_eq!(oc.token, ow.token);
                }
                prop_assert_eq!(&kv_ref.generated, &kv_warm.generated);
                let s = warm.prefix_stats().expect("cache-enabled backend");
                prop_assert_eq!(s.hits, 1);
                prop_assert_eq!(s.hit_tokens as usize, tag.len);
                prop_assert!(
                    s.pinned_blocks == 0,
                    "shards={} left {} pinned blocks after retirement",
                    shards,
                    s.pinned_blocks
                );
            }
            // Drain the cold handle so both sessions retire.
            while !kv_cold.done() {
                cold.decode_step(&mut kv_cold).map_err(|e| e.to_string())?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pool_invariants_hold_under_random_op_interleavings() {
    check(
        "kvcache-pool-invariants",
        Config {
            cases: 24,
            seed: 0x6B7CB,
        },
        |rng| {
            // Small pools (including zero capacity) force eviction and
            // preemption to fire constantly under held pins.
            let capacity = rng.index(6);
            let block_size = 1 + rng.index(8);
            let cache: PrefixCache<usize> =
                PrefixCache::new(KvCacheConfig::new(capacity, block_size));
            let mut leases = Vec::new();
            for _ in 0..60 {
                match rng.index(10) {
                    0..=3 => {
                        let keys = block_keys(rng.below(6), 1 + rng.index(4));
                        cache.insert_with(&keys, |tokens| tokens);
                    }
                    4..=6 => {
                        let keys = block_keys(rng.below(6), 1 + rng.index(4));
                        if let Some(hit) = cache.lookup_pin(&keys) {
                            prop_assert_eq!(hit.tokens, hit.lease.blocks() * block_size);
                            prop_assert_eq!(hit.payload, hit.tokens);
                            leases.push(hit.lease);
                        }
                    }
                    _ => {
                        if !leases.is_empty() {
                            let i = rng.index(leases.len());
                            cache.release(leases.swap_remove(i));
                        }
                    }
                }
                cache.validate()?;
                let s = cache.stats();
                prop_assert!(
                    s.blocks_in_use <= s.capacity_blocks,
                    "{} blocks in a {}-block pool",
                    s.blocks_in_use,
                    s.capacity_blocks
                );
                prop_assert!(s.pinned_blocks <= s.blocks_in_use);
                prop_assert!(s.hit_tokens >= s.hits, "hits serve at least one block");
            }
            // Releasing every outstanding lease (including any whose
            // nodes were preempted mid-run) must drain all pins.
            for lease in leases.drain(..) {
                cache.release(lease);
            }
            cache.validate()?;
            prop_assert_eq!(cache.stats().pinned_blocks, 0);
            Ok(())
        },
    );
}

#[test]
fn preemption_frees_all_pins_and_leaks_nothing() {
    // Capacity 2, a fully pinned 2-block chain, then a competing
    // 2-block insert: both pinned leaves must be preempted, the holder's
    // lease must release as a no-op, and accounting must balance.
    let cache: PrefixCache<()> = PrefixCache::new(KvCacheConfig::new(2, 4));
    cache.insert_with(&block_keys(1, 2), |_| ());
    let hit = cache.lookup_pin(&block_keys(1, 2)).expect("primed chain");
    assert_eq!(hit.lease.blocks(), 2);
    cache.insert_with(&block_keys(2, 2), |_| ());
    let s = cache.stats();
    assert_eq!(s.preemptions, 2, "both pinned leaves preempted in turn");
    assert_eq!(s.blocks_in_use, 2, "the new chain owns the pool");
    assert_eq!(s.pinned_blocks, 0, "preemption force-drops pins");
    assert!(cache.lookup_pin(&block_keys(1, 2)).is_none(), "victim gone");
    let survivor = cache.lookup_pin(&block_keys(2, 2)).expect("winner cached");
    cache.release(survivor.lease);
    // Dangling release after preemption is a safe no-op.
    cache.release(hit.lease);
    cache.validate().unwrap();
    assert_eq!(cache.stats().blocks_in_use, 2);
}

#[test]
fn zero_capacity_cache_serves_tagged_requests_bit_identically() {
    // An empty pool must never pin, never insert, and never perturb
    // results — the warm path degrades to the cold path exactly.
    assert!(BlockPool::new(0, 4).try_alloc().is_none());
    let plain = FunctionalBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper(), 7)
        .unwrap();
    let empty = FunctionalBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper(), 7)
        .unwrap()
        .with_kv_cache(0, 8);
    let tag = PrefixTag { group: 3, len: 16 };
    let r = req(9, 24, 0, None, Some(tag));
    let (mut kv_p, f_p) = plain.prefill(&r, 2).unwrap();
    let (mut kv_e, f_e) = empty.prefill(&r, 2).unwrap();
    assert_eq!(kv_e.cached_tokens, 0);
    assert_eq!(f_p.logits, f_e.logits);
    while !kv_p.done() {
        let op = plain.decode_step(&mut kv_p).unwrap();
        let oe = empty.decode_step(&mut kv_e).unwrap();
        assert_eq!(op.logits, oe.logits);
        assert_eq!(op.token, oe.token);
    }
    let s = empty.prefix_stats().unwrap();
    assert!(s.lookups > 0, "tagged prompts still consult the trie");
    assert_eq!((s.hits, s.inserted_blocks, s.pinned_blocks), (0, 0, 0));
    assert_eq!(s.capacity_blocks, 0);
}
