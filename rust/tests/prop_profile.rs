//! Differential suite for the unified execution profile
//! (`config::ExecProfile`): profile-built backends must be
//! **bit-identical** to the legacy `with_*` builder chains they replace
//! — logits, `ExecStats`, `ReqActivity`, and cost attribution — and
//! `CostModel::from_profile` must land on the same model as any
//! permutation of the legacy regime builders.
//!
//! The PJRT tests are artifact-gated (they skip, not fail, when
//! `make artifacts` has not run), matching `integration_runtime.rs`.

use axllm::backend::{ExecutionBackend, FunctionalBackend, PjrtBackend, SimBackend};
use axllm::config::{AcceleratorConfig, BackendKind, Dataset, ExecProfile, ModelConfig};
use axllm::coordinator::CostModel;
use axllm::quant::QuantRegime;
use axllm::runtime::ArtifactSet;
use axllm::workload::{Request, SloClass};
use std::path::PathBuf;

fn req(id: u64, seq_len: usize, adapter: Option<u32>) -> Request {
    Request {
        id,
        dataset: Dataset::Imdb,
        seq_len,
        arrival_s: 0.0,
        gen_tokens: 0,
        adapter,
        prefix: None,
        slo: SloClass::Standard,
    }
}

/// The quant regimes the differential grid visits: the default (which
/// `from_profile` must *skip* — applying `with_quant_regime(per_tensor)`
/// is not a no-op) and a grouped/compressed regime.
fn quant_points() -> [QuantRegime; 2] {
    [
        QuantRegime::default(),
        QuantRegime::grouped(64).with_compressed(true),
    ]
}

#[test]
fn profile_built_sim_is_bit_identical_to_legacy_chain() {
    let model_cfg = ModelConfig::tiny();
    for shards in [1usize, 2, 4] {
        for adapters in [0usize, 2] {
            for kv in [None, Some((16usize, 8usize))] {
                for quant in quant_points() {
                    let mut profile = ExecProfile::new(BackendKind::Sim)
                        .with_shards(shards)
                        .with_adapters(adapters, 8)
                        .with_quant(quant);
                    if let Some((blocks, bs)) = kv {
                        profile = profile.with_kv_cache(blocks, bs);
                    }
                    let built = SimBackend::from_profile(&model_cfg, &profile).unwrap();

                    let mut legacy = SimBackend::new(model_cfg.clone(), AcceleratorConfig::paper())
                        .unwrap()
                        .with_paced(false)
                        .with_adapters(adapters, 8)
                        .with_shards(shards);
                    if let Some((blocks, bs)) = kv {
                        legacy = legacy.with_kv_cache(blocks, bs);
                    }
                    if quant != QuantRegime::default() {
                        legacy = legacy.with_quant_regime(quant);
                    }

                    let tag = profile.label();
                    assert_eq!(built.cost(), legacy.cost(), "cost drift at {tag}");
                    let reqs: Vec<Request> = (0..2)
                        .map(|i| req(i, 4 + i as usize * 3, (adapters > 0).then_some(1)))
                        .collect();
                    let a = built.run_batch(&reqs).unwrap();
                    let b = legacy.run_batch(&reqs).unwrap();
                    assert_eq!(a.logits, b.logits, "{tag}");
                    assert_eq!(a.exec_s, b.exec_s, "exec_s drift at {tag}");
                    assert_eq!(a.stats, b.stats, "sim stats drift at {tag}");
                    assert_eq!(a.activity, b.activity, "activity drift at {tag}");
                }
            }
        }
    }
}

#[test]
fn profile_built_functional_is_bit_identical_to_legacy_chain() {
    let model_cfg = ModelConfig::tiny();
    for shards in [1usize, 2] {
        for scalar in [false, true] {
            for quant in quant_points() {
                let mut profile = ExecProfile::new(BackendKind::Functional)
                    .with_shards(shards)
                    .with_quant(quant);
                profile.seed = 23;
                profile.scalar_kernels = scalar;
                let built = FunctionalBackend::from_profile(&model_cfg, &profile).unwrap();

                let mut legacy =
                    FunctionalBackend::new(model_cfg.clone(), AcceleratorConfig::paper(), 23)
                        .unwrap()
                        .with_scalar_kernels(scalar)
                        .with_shards(shards);
                if quant != QuantRegime::default() {
                    legacy = legacy.with_quant_regime(quant);
                }

                let tag = format!("{} scalar={scalar}", profile.label());
                assert_eq!(built.cost(), legacy.cost(), "cost drift at {tag}");
                for r in [req(3, 6, None), req(9, 11, None)] {
                    let (la, sa) = built.forward(&r);
                    let (lb, sb) = legacy.forward(&r);
                    assert_eq!(la, lb, "logits drift at {tag}");
                    assert_eq!(sa, sb, "ExecStats drift at {tag}");
                }
            }
        }
    }
}

#[test]
fn cost_model_from_profile_is_order_canonical() {
    let model_cfg = ModelConfig::tiny();
    let acc = AcceleratorConfig::paper();
    let quant = QuantRegime::grouped(64).with_compressed(true);
    let bytes = (1000.0, 600.0, 0.5);
    let handoff = (2 * model_cfg.n_layers * model_cfg.d_model * 4) as f64;
    let mut profile = ExecProfile::new(BackendKind::Sim)
        .with_shards(2)
        .with_adapters(2, 8)
        .with_kv_cache(16, 8)
        .with_quant(quant);
    profile.handoff_bytes_per_token = handoff;

    let base = *SimBackend::new(model_cfg.clone(), acc).unwrap().cost();
    let canonical = CostModel::from_profile(base, &model_cfg, &profile, Some(bytes));

    // Every legacy regime builder, as a reorderable step.
    let n = 6;
    let apply = |c: CostModel, step: usize| -> CostModel {
        match step {
            0 => c.with_decode_regime(&model_cfg, acc),
            1 => c.with_adapter_regime(&model_cfg, acc, 8),
            2 => c.with_shard_regime(&model_cfg, 2),
            3 => c.with_kv_regime(&model_cfg, acc, 8),
            4 => c.with_handoff_regime(&model_cfg),
            _ => c.with_quant_regime(quant, bytes.0, bytes.1, bytes.2),
        }
    };
    // Rotations plus the full reversal: enough to place every builder
    // both before and after every other one.
    for rot in 0..n {
        let order: Vec<usize> = (0..n).map(|i| (i + rot) % n).collect();
        let mut c = base;
        for &i in &order {
            c = apply(c, i);
        }
        assert_eq!(c, canonical, "order {order:?} diverged from canonical");
    }
    let mut c = base;
    for i in (0..n).rev() {
        c = apply(c, i);
    }
    assert_eq!(c, canonical, "reversed order diverged from canonical");
}

#[test]
fn toml_round_trip_rebuilds_identical_backends() {
    let dir = std::env::temp_dir().join("axllm_prop_profile");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("roundtrip.toml");

    let mut profile = ExecProfile::new(BackendKind::Functional)
        .with_shards(2)
        .with_quant(QuantRegime::grouped(16).with_compressed(true));
    profile.seed = 11;
    profile.save(&path).unwrap();
    let reloaded = ExecProfile::load(&path).unwrap();
    assert_eq!(reloaded, profile, "TOML round trip must be exact");

    let model_cfg = ModelConfig::tiny();
    let a = FunctionalBackend::from_profile(&model_cfg, &profile).unwrap();
    let b = FunctionalBackend::from_profile(&model_cfg, &reloaded).unwrap();
    assert_eq!(a.cost(), b.cost());
    let r = req(5, 9, None);
    let (la, sa) = a.forward(&r);
    let (lb, sb) = b.forward(&r);
    assert_eq!(la, lb);
    assert_eq!(sa, sb);

    // The same round trip must preserve sim cost timings bit-for-bit.
    let mut sp = ExecProfile::new(BackendKind::Sim).with_shards(4);
    sp.handoff_bytes_per_token = 1234.5;
    sp.save(&path).unwrap();
    let sim_a = SimBackend::from_profile(&model_cfg, &sp).unwrap();
    let sim_b = SimBackend::from_profile(&model_cfg, &ExecProfile::load(&path).unwrap()).unwrap();
    assert_eq!(sim_a.cost(), sim_b.cost());
}

#[test]
fn malformed_profile_toml_is_rejected() {
    let dir = std::env::temp_dir().join("axllm_prop_profile");
    std::fs::create_dir_all(&dir).unwrap();
    for (name, text) in [
        ("garbage.toml", "not toml [[[\n= ="),
        ("badtype.toml", "[profile]\nshards = \"two\"\n"),
        ("badbackend.toml", "[profile]\nbackend = \"tpu\"\n"),
        ("badrange.toml", "[profile]\nadapter_rank = 0\n"),
    ] {
        let path = dir.join(name);
        std::fs::write(&path, text).unwrap();
        assert!(
            ExecProfile::load(&path).is_err(),
            "{name} must be rejected"
        );
    }
    assert!(
        ExecProfile::load(&dir.join("does_not_exist.toml")).is_err(),
        "missing file must be an error, not a default profile"
    );
}

#[test]
fn regime_aware_backends_report_zero_quant_misses() {
    // sim/functional honor grouped regimes for real, so the trait's
    // quant-miss channel must stay silent on them.
    let model_cfg = ModelConfig::tiny();
    let profile = ExecProfile::new(BackendKind::Sim)
        .with_quant(QuantRegime::grouped(64).with_compressed(true));
    let sim = SimBackend::from_profile(&model_cfg, &profile).unwrap();
    sim.run_batch(&[req(1, 5, None)]).unwrap();
    assert_eq!(sim.quant_misses(), 0);

    let mut fp = profile.clone();
    fp.backend = BackendKind::Functional;
    let f = FunctionalBackend::from_profile(&model_cfg, &fp).unwrap();
    f.run_batch(&[req(1, 5, None)]).unwrap();
    assert_eq!(f.quant_misses(), 0);
}

// ---------------------------------------------------------------------
// PJRT (artifact-gated): skip, not fail, without `make artifacts`.
// ---------------------------------------------------------------------

fn artifacts_dir() -> Option<PathBuf> {
    let dir = ArtifactSet::default_dir();
    if dir.join("manifest.toml").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts missing — run `make artifacts` first");
        None
    }
}

#[test]
fn pjrt_from_profile_matches_legacy_load_chain() {
    let Some(dir) = artifacts_dir() else { return };
    let mut profile = ExecProfile::new(BackendKind::Pjrt).with_shards(2);
    profile.artifacts = dir.to_str().unwrap().to_string();
    let built = PjrtBackend::from_profile(&ModelConfig::tiny(), &profile).unwrap();
    let legacy = PjrtBackend::load(&dir, AcceleratorConfig::paper())
        .unwrap()
        .with_shards(2);
    assert_eq!(built.cost(), legacy.cost());
    let r = req(7, 6, None);
    let a = built.run_batch(std::slice::from_ref(&r)).unwrap();
    let b = legacy.run_batch(std::slice::from_ref(&r)).unwrap();
    assert_eq!(a.logits, b.logits);
}

#[test]
fn pjrt_capability_misses_fire_per_field() {
    let Some(dir) = artifacts_dir() else { return };
    let mut profile = ExecProfile::new(BackendKind::Pjrt)
        .with_shards(2)
        .with_kv_cache(8, 8)
        .with_quant(QuantRegime::grouped(64).with_compressed(true));
    profile.artifacts = dir.to_str().unwrap().to_string();
    let b = PjrtBackend::from_profile(&ModelConfig::tiny(), &profile).unwrap();
    assert_eq!(b.shard_misses(), 0, "misses fire per served request, not at build");
    let reqs = [req(1, 5, Some(1)), req(2, 7, None)];
    b.run_batch(&reqs).unwrap();
    // One miss per request per unhonorable ask; the adapter channel
    // counts only the adapter-carrying request.
    assert_eq!(b.shard_misses(), 2, "shard asks must be recorded uniformly");
    assert_eq!(b.kv_misses(), 2, "kv asks must be recorded uniformly");
    assert_eq!(b.quant_misses(), 2, "quant asks must be recorded uniformly");
    assert_eq!(b.adapter_misses(), 1);
}

#[test]
fn pjrt_default_quant_regime_is_not_a_miss() {
    let Some(dir) = artifacts_dir() else { return };
    let mut profile = ExecProfile::new(BackendKind::Pjrt);
    profile.artifacts = dir.to_str().unwrap().to_string();
    let b = PjrtBackend::from_profile(&ModelConfig::tiny(), &profile).unwrap();
    b.run_batch(&[req(1, 5, None)]).unwrap();
    assert_eq!(
        b.quant_misses(),
        0,
        "per-tensor raw IS what the artifacts execute — no downgrade to report"
    );
}
