//! Integration: every report generator produces a well-formed table with
//! the paper-shaped conclusions — the regression net over EXPERIMENTS.md.
//! (Uses a smaller sample than the defaults to keep runtime bounded; the
//! per-report unit tests assert the tight bands.)

use axllm::report::{ablation, fig1, fig8, fig9, lora, power, shiftadd, RunCtx};

fn ctx() -> RunCtx {
    RunCtx {
        seed: 42,
        sample_rows: 32,
    }
}

#[test]
fn every_generator_renders_and_exports_csv() {
    let tables = vec![
        fig1::generate(),
        fig8::table1(),
        fig8::generate(ctx()),
        fig9::generate(ctx()),
        lora::generate(ctx()),
        shiftadd::generate(ctx()),
        power::generate(ctx()),
        power::generate_area(),
        ablation::buffer_sweep(ctx()),
        ablation::slice_sweep_table(ctx()),
        ablation::hazard_rates(ctx()),
        ablation::distribution_sensitivity(ctx()),
        ablation::rc_mapping_note(ctx()),
    ];
    for t in &tables {
        assert!(t.n_rows() > 0);
        let rendered = t.render();
        assert!(rendered.lines().count() > 4);
        let csv = t.csv();
        assert_eq!(csv.lines().count(), t.n_rows() + 1);
        // CSV header matches column count in every row.
        let cols = t.headers().len();
        for line in csv.lines() {
            assert!(
                line.split(',').count() >= cols.min(2),
                "short csv row in {rendered}"
            );
        }
    }
}

#[test]
fn headline_claims_hold_at_reduced_sampling() {
    // Fig. 8 shape: reuse grows with matrix size.
    let rows = fig8::measure(ctx());
    assert!(rows[6].reuse_full_row > rows[0].reuse_full_row);
    // Fig. 9 shape: all speedups within the paper's band, DistilBERT
    // anchor close to 85.11M/159.34M.
    let f9 = fig9::measure(ctx());
    for r in &f9 {
        let s = r.speedup();
        assert!((1.4..2.4).contains(&s), "{}: {s}", r.model);
    }
    // ShiftAdd: AxLLM wins.
    let sa = shiftadd::measure_model(&axllm::config::ModelConfig::distilbert(), ctx());
    assert!(sa.axllm_speedup() > 1.0);
    // Power: energy reduction ≥ 15% even at reduced sampling.
    let p = power::measure(ctx());
    assert!(1.0 - p.energy_ratio > 0.15);
}

#[test]
fn seeds_change_numbers_but_not_conclusions() {
    for seed in [1u64, 1234, 0xDEAD] {
        let c = RunCtx {
            seed,
            sample_rows: 32,
        };
        let rows = fig8::measure(c);
        for r in &rows {
            assert!(
                r.reuse_256 > 0.55,
                "seed {seed} {}: reuse {}",
                r.model,
                r.reuse_256
            );
        }
        let f9 = fig9::measure(c);
        for r in &f9 {
            assert!(r.speedup() > 1.4, "seed {seed} {}", r.model);
        }
    }
}
