//! Integration: PJRT runtime against the AOT artifacts (requires
//! `make artifacts`). These tests prove the three layers compose: the
//! Pallas kernel's HLO runs from Rust bit-exactly against the Rust
//! functional executor, and the JAX tiny model matches the Rust layer
//! implementation on the exported weights.

use axllm::exec::dense_matmul;
use axllm::exec::LayerExec;
use axllm::quant::{QuantMatrix, QuantParams};
use axllm::runtime::{load_weights_bin, ArtifactSet, Runtime};
use axllm::util::rng::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    let dir = ArtifactSet::default_dir();
    assert!(
        dir.join("manifest.toml").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    dir
}

#[test]
fn kernel_artifact_bit_exact_vs_rust_executor() {
    let rt = Runtime::cpu().unwrap();
    let dir = artifacts_dir();
    let arts = ArtifactSet::load(&rt, &dir).unwrap();
    let mut rng = Rng::new(99);
    for (r, exe) in &arts.kernels {
        let n = *r;
        // Random codes and input; artifact takes (x i32[n], w i32[n,n]
        // offsets) and returns i32[n].
        let x_codes: Vec<i32> = (0..n).map(|_| rng.range_i64(-127, 127) as i32).collect();
        let w_off: Vec<i32> = (0..n * n).map(|_| rng.range_i64(0, 254) as i32).collect();
        let y = exe
            .run_i32(&[
                (&x_codes, &[n as i64]),
                (&w_off, &[n as i64, n as i64]),
            ])
            .unwrap();
        // Rust side: same arithmetic through the reuse executor.
        let x_i8: Vec<i8> = x_codes.iter().map(|&v| v as i8).collect();
        let w_q: Vec<i8> = w_off.iter().map(|&v| (v - 127) as i8).collect();
        let wm = QuantMatrix::from_q(n, n, w_q, QuantParams { scale: 1.0, bits: 8 });
        let expect = dense_matmul(&x_i8, &wm);
        assert_eq!(y, expect, "kernel artifact {n}x{n} must be bit-exact");
    }
}

#[test]
fn tiny_model_artifact_produces_finite_batch_logits() {
    let rt = Runtime::cpu().unwrap();
    let dir = artifacts_dir();
    let arts = ArtifactSet::load(&rt, &dir).unwrap();
    let m = &arts.manifest;
    let mut rng = Rng::new(5);
    let x: Vec<f32> = (0..m.batch * m.seq * m.d_model)
        .map(|_| rng.normal() as f32)
        .collect();
    let logits = arts.run_tiny_model(&x).unwrap();
    assert_eq!(logits.len(), m.batch * m.n_classes);
    assert!(logits.iter().all(|v| v.is_finite()));
    // Different batch elements produce different (nonzero) logits.
    assert!(logits.iter().any(|&v| v != 0.0));
    assert_ne!(logits[..m.n_classes], logits[m.n_classes..2 * m.n_classes]);
}

#[test]
fn tiny_layer_artifact_matches_rust_layer_exec_on_exported_weights() {
    let rt = Runtime::cpu().unwrap();
    let dir = artifacts_dir();
    let arts = ArtifactSet::load(&rt, &dir).unwrap();
    let weights = load_weights_bin(&dir.join("tiny_weights.bin")).unwrap();
    let m = &arts.manifest;
    assert_eq!(weights.n_layers, m.n_layers);
    assert_eq!(weights.d_model, m.d_model);

    let mut rng = Rng::new(17);
    let x: Vec<f32> = (0..m.seq * m.d_model).map(|_| rng.normal() as f32).collect();
    let jax_out = arts.run_tiny_layer(&x).unwrap();

    let cfg = m.model_config();
    let mut layer = LayerExec::new(&cfg, &weights.layers[0], 128);
    let rust_out = layer.forward(&x, m.seq);

    assert_eq!(jax_out.len(), rust_out.len());
    // Two independent implementations of the same quantized layer: equal
    // up to activation-quantization rounding-mode differences (rust
    // rounds half-away, XLA rounds half-even) amplified by layer norm.
    let mut max_err = 0f32;
    for (a, b) in jax_out.iter().zip(&rust_out) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err < 0.15,
        "JAX vs Rust layer divergence too large: {max_err}"
    );
    // And they must be strongly correlated (same transform, not noise).
    let dot: f32 = jax_out.iter().zip(&rust_out).map(|(a, b)| a * b).sum();
    let na: f32 = jax_out.iter().map(|a| a * a).sum::<f32>().sqrt();
    let nb: f32 = rust_out.iter().map(|b| b * b).sum::<f32>().sqrt();
    let cos = dot / (na * nb);
    assert!(cos > 0.999, "cosine similarity {cos}");
}

#[test]
fn weights_bin_consistent_with_manifest() {
    let dir = artifacts_dir();
    let w = load_weights_bin(&dir.join("tiny_weights.bin")).unwrap();
    use axllm::model::MatKind;
    for layer in &w.layers {
        assert_eq!(layer.get(MatKind::Wq).rows, w.d_model);
        assert_eq!(layer.get(MatKind::Ff1).cols, w.d_ff);
        assert_eq!(layer.get(MatKind::Ff2).rows, w.d_ff);
        // The exported weights must show the value locality AxLLM needs.
        let loc = axllm::quant::stats::measure_locality(layer.get(MatKind::Wq), 128);
        assert!(loc.reuse_rate() > 0.3, "reuse {}", loc.reuse_rate());
    }
    assert_eq!(w.head.cols, w.n_classes);
}
