//! Live serving without artifacts: `Server<SimBackend>` and
//! `Server<FunctionalBackend>` integration coverage — deadline-bounded
//! queue waits under a trickle (the starvation regression), batch-policy
//! conformance, monotone dispatch, live-vs-trace attribution equivalence,
//! and the multi-replica pool. No PJRT runtime, no artifact directory:
//! this is the live path CI can actually execute.

use axllm::backend::{FunctionalBackend, SimBackend};
use axllm::config::{AcceleratorConfig, Dataset, ModelConfig};
use axllm::coordinator::{
    BatchPolicy, DecodeOpts, DisaggPoolOpts, Engine, RequestResult, Server, SloPolicy, SloTarget,
};
use axllm::workload::{Request, TraceGenerator};
use std::time::{Duration, Instant};

fn sim_engine() -> axllm::Result<Engine<SimBackend>> {
    Ok(Engine::new(SimBackend::new(
        ModelConfig::tiny(),
        AcceleratorConfig::paper(),
    )?))
}

fn functional_engine() -> axllm::Result<Engine<FunctionalBackend>> {
    Ok(Engine::new(FunctionalBackend::new(
        ModelConfig::tiny(),
        AcceleratorConfig::paper(),
        42,
    )?))
}

fn req(id: u64, seq_len: usize) -> Request {
    Request {
        id,
        dataset: Dataset::Imdb,
        seq_len,
        // Overwritten by Server::submit with the shared-epoch stamp.
        arrival_s: 0.0,
        gen_tokens: 0,
        adapter: None,
        prefix: None,
        slo: axllm::workload::SloClass::Standard,
    }
}

/// Regression test for the worker-timeout starvation bug: a steady
/// trickle of sub-`max_batch` arrivals must NOT keep resetting the wait
/// window. The oldest request's wall-clock wait is bounded by
/// `max_wait_s` plus scheduling slop.
#[test]
fn trickle_cannot_starve_oldest_request() {
    const MAX_WAIT_S: f64 = 0.06;
    const TRICKLE_GAP: Duration = Duration::from_millis(30);
    const N: u64 = 12;
    // Generous CI slop, still far below the ≥0.39s wait the old
    // fresh-window-per-message loop produced for the first request.
    const BOUND_S: f64 = 0.2;

    let server = Server::start_with(
        sim_engine,
        BatchPolicy {
            max_batch: 64,
            max_wait_s: MAX_WAIT_S,
        },
    );
    // Block until the engine is constructed so load time does not eat
    // into the measured waits.
    assert!(server.cost().is_some(), "worker must report a cost model");

    let mut watchers = Vec::new();
    for id in 0..N {
        let rx = server.submit(req(id, 16));
        // Measure end-to-end wall wait per request from its own submit
        // instant (receiving in a thread so later submissions cannot
        // inflate earlier measurements).
        let t0 = Instant::now();
        watchers.push(std::thread::spawn(move || {
            let res = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("server must answer");
            (res, t0.elapsed().as_secs_f64())
        }));
        std::thread::sleep(TRICKLE_GAP);
    }

    let mut max_wall = 0.0f64;
    for (id, w) in watchers.into_iter().enumerate() {
        let (res, wall_s) = w.join().expect("watcher thread");
        assert_eq!(res.id, id as u64);
        // Attributed queue wait is the *actual* wall time the request
        // spent queued (live dispatches stamp at dispatch time, not at
        // the scheduler deadline), so the same bound applies to it.
        assert!(
            res.queue_wait_s <= BOUND_S,
            "request {id} attributed wait {} > {BOUND_S}",
            res.queue_wait_s
        );
        max_wall = max_wall.max(wall_s);
    }
    assert!(
        max_wall <= BOUND_S,
        "max wall-clock wait {max_wall}s exceeds {BOUND_S}s — trickle starvation is back"
    );
    server.shutdown().unwrap();
}

#[test]
fn live_sim_matches_trace_attribution() {
    let trace = TraceGenerator::new(Dataset::AgNews, 300.0, 11).take(32);
    let (trace_results, _) = sim_engine()
        .unwrap()
        .serve_trace(trace.clone(), BatchPolicy::default())
        .unwrap();

    let server = Server::start_with(sim_engine, BatchPolicy::default());
    let rxs: Vec<_> = trace.iter().map(|r| server.submit(r.clone())).collect();
    let live_results: Vec<RequestResult> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap())
        .collect();
    server.shutdown().unwrap();

    assert_eq!(trace_results.len(), live_results.len());
    for (t, l) in trace_results.iter().zip(&live_results) {
        assert_eq!(t.id, l.id);
        // Attribution is per-token and batch-independent: identical
        // across the trace-driven and live paths for the same request.
        assert_eq!(t.tokens, l.tokens);
        assert_eq!(t.sim_cycles, l.sim_cycles);
        assert!((t.sim_energy_j - l.sim_energy_j).abs() < 1e-15);
        assert!(l.logits.is_empty());
        assert!(l.sim_cycles > 0);
    }
}

#[test]
fn live_functional_matches_trace_logits() {
    let trace = TraceGenerator::new(Dataset::Squad, 300.0, 23).take(12);
    let (trace_results, _) = functional_engine()
        .unwrap()
        .serve_trace(trace.clone(), BatchPolicy::default())
        .unwrap();

    let server = Server::start_with(functional_engine, BatchPolicy::default());
    let rxs: Vec<_> = trace.iter().map(|r| server.submit(r.clone())).collect();
    let live_results: Vec<RequestResult> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(60)).unwrap())
        .collect();
    server.shutdown().unwrap();

    for (t, l) in trace_results.iter().zip(&live_results) {
        assert_eq!(t.id, l.id);
        // Embeddings derive from (seed, id): live batching differences
        // cannot change the logits.
        assert_eq!(t.logits, l.logits);
        assert_eq!(t.sim_cycles, l.sim_cycles);
        assert!(!l.logits.is_empty());
        assert!(l.logits.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn live_batches_respect_policy_and_monotone_dispatch() {
    const MAX_BATCH: usize = 4;
    const N: usize = 32;
    let server = Server::start_with(
        sim_engine,
        BatchPolicy {
            max_batch: MAX_BATCH,
            max_wait_s: 0.02,
        },
    );
    let rxs: Vec<_> = (0..N).map(|i| server.submit(req(i as u64, 16))).collect();
    let results: Vec<RequestResult> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap())
        .collect();

    let stats = server.stats();
    let batches = stats.batches.load(std::sync::atomic::Ordering::Relaxed);
    assert_eq!(stats.submitted.load(std::sync::atomic::Ordering::Relaxed), N);
    assert_eq!(stats.completed.load(std::sync::atomic::Ordering::Relaxed), N);
    assert!(batches >= N / MAX_BATCH, "{batches} batches for {N} requests");

    // Single replica, FIFO scheduler: results in submit order must have
    // non-decreasing dispatch stamps and policy-bounded batch sizes.
    for w in results.windows(2) {
        assert!(w[1].dispatch_s >= w[0].dispatch_s);
    }
    for r in &results {
        assert!(r.batch_size >= 1 && r.batch_size <= MAX_BATCH);
        assert!(r.queue_wait_s >= 0.0);
        assert!(r.latency_s >= r.exec_s);
    }
    // Batch-size claims are consistent: requests sharing a dispatch stamp
    // are exactly one batch.
    let mut i = 0;
    while i < results.len() {
        let size = results[i].batch_size;
        let group = &results[i..i + size];
        assert!(group.iter().all(|r| r.dispatch_s == results[i].dispatch_s));
        assert!(group.iter().all(|r| r.batch_size == size));
        i += size;
    }
    assert_eq!(i, results.len());
    server.shutdown().unwrap();
}

#[test]
fn shutdown_flushes_pending_requests() {
    let server = Server::start_with(
        sim_engine,
        BatchPolicy {
            max_batch: 64,
            max_wait_s: 10.0,
        },
    );
    assert!(server.cost().is_some());
    let rx0 = server.submit(req(0, 16));
    let rx1 = server.submit(req(1, 16));
    server.shutdown().unwrap();
    assert_eq!(rx0.recv().unwrap().id, 0);
    assert_eq!(rx1.recv().unwrap().id, 1);
}

#[test]
fn pool_spreads_load_and_aggregates_a_summary() {
    const N: usize = 30;
    let pool = Server::start_pool(
        3,
        |_i| sim_engine(),
        BatchPolicy {
            max_batch: 4,
            max_wait_s: 0.005,
        },
    );
    assert!(pool.cost().is_some(), "every replica must construct");
    let trace: Vec<Request> = (0..N).map(|i| req(i as u64, 16)).collect();
    let run = pool.run(trace, false).expect("live run must complete");

    assert_eq!(run.results.len(), N);
    let answered: usize = run.replica_stats.iter().map(|(_, c)| c).sum();
    assert_eq!(answered, N);
    let active = run.replica_stats.iter().filter(|(_, c)| *c > 0).count();
    assert!(active >= 2, "dispatch must spread: {:?}", run.replica_stats);

    let summary = &run.summary;
    assert_eq!(summary.requests, N);
    assert!(summary.batches >= 1);
    assert!(summary.tokens > 0);
    assert!(summary.throughput_rps > 0.0);
    assert!(summary.sim_cycles > 0);
    assert!(summary.sim_speedup > 1.3);
    assert!(summary.latency.p50_s <= summary.latency.p99_s);
}

fn req_gen(id: u64, seq_len: usize, gen_tokens: u32) -> Request {
    Request {
        gen_tokens,
        ..req(id, seq_len)
    }
}

#[test]
fn live_decode_sessions_round_trip_with_ttft_tpot() {
    const N: u64 = 12;
    let server = Server::start_decode_with(
        sim_engine,
        BatchPolicy {
            max_batch: 4,
            max_wait_s: 0.01,
        },
        // Default budget of 5 for requests that carry none; unpaced.
        DecodeOpts::new(5),
    );
    assert!(server.cost().is_some(), "worker must report a cost model");
    let rxs: Vec<_> = (0..N)
        .map(|id| {
            // Mix per-request budgets with the server default.
            let gen = if id % 3 == 0 { 0 } else { (id % 7) as u32 + 1 };
            server.submit(req_gen(id, 16, gen))
        })
        .collect();
    for (id, rx) in rxs.into_iter().enumerate() {
        let res = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("decode server must answer");
        assert_eq!(res.id, id as u64);
        let expect = if id % 3 == 0 { 5 } else { (id % 7) as u64 + 1 };
        assert_eq!(res.gen_tokens, expect, "request {id} budget");
        assert_eq!(res.tokens, 16 + expect, "prompt + generated tokens");
        assert!(res.ttft_s >= 0.0 && res.ttft_s <= res.latency_s + 1e-9);
        assert!(res.tpot_s >= 0.0);
        assert!(res.queue_wait_s >= 0.0);
        assert!(res.sim_cycles > 0);
        assert!(res.batch_size >= 1 && res.batch_size <= 4);
    }
    let stats = server.stats();
    assert_eq!(
        stats.completed.load(std::sync::atomic::Ordering::Relaxed),
        N as usize
    );
    server.shutdown().unwrap();
}

#[test]
fn live_decode_functional_streams_final_logits() {
    let server = Server::start_decode_with(
        functional_engine,
        BatchPolicy {
            max_batch: 2,
            max_wait_s: 0.01,
        },
        DecodeOpts::new(3),
    );
    assert!(server.cost().is_some());
    let rxs: Vec<_> = (0..4).map(|id| server.submit(req_gen(id, 8, 3))).collect();
    for (id, rx) in rxs.into_iter().enumerate() {
        let res = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("functional decode server must answer");
        assert_eq!(res.id, id as u64);
        assert_eq!(res.gen_tokens, 3);
        assert_eq!(res.logits.len(), 4, "final-step logits");
        assert!(res.logits.iter().all(|v| v.is_finite()));
    }
    server.shutdown().unwrap();
}

#[test]
fn live_decode_paced_occupies_the_worker_per_iteration() {
    // Paced decode sleeps the modeled iteration time (shared decode
    // weight pass + per-token prefill passes) at the worker level — the
    // backend itself stays unpaced. Lower bound: every prompt token's
    // weight pass is charged in some iteration's sleep before the last
    // session completes.
    const N: u64 = 6;
    const SEQ: usize = 32;
    let server = Server::start_decode_with(
        sim_engine,
        BatchPolicy {
            max_batch: 8,
            max_wait_s: 0.01,
        },
        DecodeOpts {
            default_gen: 4,
            pace: true,
        },
    );
    let cost = server.cost().expect("worker must report a cost model");
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..N).map(|id| server.submit(req_gen(id, SEQ, 4))).collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(30)).unwrap();
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let floor = cost.sim_time_s((N as usize * SEQ) as u64) * 0.9;
    assert!(
        elapsed >= floor,
        "paced decode worker finished in {elapsed}s < modeled floor {floor}s"
    );
    server.shutdown().unwrap();
}

#[test]
fn live_decode_mixes_adapters_in_one_continuous_batch() {
    // Multi-tenant live decode: base and adapter sessions share the one
    // continuous batch; adapter results carry side-pipe work, base
    // results are byte-identical to a tenant-free deployment's.
    let tenant_engine = || {
        FunctionalBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper(), 42)
            .map(|b| Engine::new(b.with_adapters(2, 4)))
    };
    let server = Server::start_decode_with(
        tenant_engine,
        BatchPolicy {
            max_batch: 8,
            max_wait_s: 0.01,
        },
        DecodeOpts::new(3),
    );
    let cost = server.cost().expect("worker must report a cost model");
    assert!(cost.adapter_cycles_per_token > 0.0);
    let rxs: Vec<_> = (0..6u64)
        .map(|id| {
            let mut r = req_gen(id, 8, 3);
            r.adapter = (id % 3 != 0).then_some((id % 2) as u32);
            server.submit(r)
        })
        .collect();
    let mut results: Vec<RequestResult> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(60)).unwrap())
        .collect();
    server.shutdown().unwrap();
    results.sort_by_key(|r| r.id);

    // Reference: the same requests served base-only by a tenant-free
    // deployment (trace path — attribution is path-independent).
    let plain: Vec<Request> = (0..6u64)
        .map(|id| Request {
            arrival_s: 0.0,
            ..req_gen(id, 8, 3)
        })
        .collect();
    let (base_results, _) = functional_engine()
        .unwrap()
        .serve_trace_decode(
            plain,
            BatchPolicy {
                max_batch: 8,
                max_wait_s: 0.01,
            },
            3,
        )
        .unwrap();

    let mut adapters_seen = std::collections::BTreeSet::new();
    for r in &results {
        let base = base_results.iter().find(|b| b.id == r.id).unwrap();
        match r.adapter {
            None => {
                // Tenant isolation: co-batched adapters never touch a
                // base request.
                assert_eq!(r.logits, base.logits, "request {}", r.id);
                assert_eq!(r.adapter_ops, 0);
            }
            Some(id) => {
                adapters_seen.insert(id);
                assert!(r.adapter_ops > 0, "request {} side pipe", r.id);
                assert_ne!(r.logits, base.logits, "adapter must shift logits");
            }
        }
        // Reuse survives LoRA: base-pipe ops identical either way.
        assert_eq!(r.base_mults, base.base_mults, "request {}", r.id);
        assert_eq!(r.base_reuses, base.base_reuses, "request {}", r.id);
    }
    assert!(
        adapters_seen.len() >= 2,
        "run must mix ≥2 distinct adapters: {adapters_seen:?}"
    );
}

#[test]
fn live_decode_shutdown_drains_running_sessions() {
    let server = Server::start_decode_with(
        sim_engine,
        BatchPolicy {
            max_batch: 8,
            max_wait_s: 10.0,
        },
        DecodeOpts::new(4),
    );
    assert!(server.cost().is_some());
    let rx0 = server.submit(req_gen(0, 16, 6));
    let rx1 = server.submit(req_gen(1, 16, 2));
    server.shutdown().unwrap();
    let r0 = rx0.recv().unwrap();
    let r1 = rx1.recv().unwrap();
    assert_eq!((r0.id, r0.gen_tokens), (0, 6));
    assert_eq!((r1.id, r1.gen_tokens), (1, 2));
}

#[test]
fn backend_capacity_clamps_live_batches() {
    // FunctionalBackend caps batches at 64; a policy asking for more must
    // be clamped by the worker, not tripped as an engine assert.
    let server = Server::start_with(
        functional_engine,
        BatchPolicy {
            max_batch: usize::MAX,
            max_wait_s: 0.005,
        },
    );
    assert!(server.cost().is_some());
    let rxs: Vec<_> = (0..8).map(|i| server.submit(req(i, 8))).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let res = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(res.id, i as u64);
        assert_eq!(res.logits.len(), 4);
    }
    server.shutdown().unwrap();
}

#[test]
fn disagg_pool_matches_unified_decode_results_and_meters_handoff() {
    // Two-tier live serving is a scheduling change, not a computation
    // change: a 1-prefill + 1-decode pool answers with exactly the
    // logits, tokens, and reuse counters of the single-engine trace
    // path, while the KV link meters one handoff per request.
    const N: u64 = 6;
    const BPT: f64 = 64.0;
    let pool = Server::start_disagg_pool(
        1,
        1,
        |_i| functional_engine(),
        BatchPolicy {
            max_batch: 4,
            max_wait_s: 0.01,
        },
        DisaggPoolOpts::new(3).with_handoff(BPT),
    );
    assert!(pool.cost().is_some(), "both tiers must construct");
    let trace: Vec<Request> = (0..N).map(|id| req_gen(id, 8, 3)).collect();
    let run = pool.run(trace, false).expect("disagg run must complete");

    assert_eq!(run.results.len(), N as usize);
    assert!(run.results.iter().all(|r| !r.shed), "FIFO pool sheds nothing");
    let plain: Vec<Request> = (0..N).map(|id| req_gen(id, 8, 3)).collect();
    let (mut reference, _) = functional_engine()
        .unwrap()
        .serve_trace_decode(plain, BatchPolicy::default(), 3)
        .unwrap();
    reference.sort_by_key(|r| r.id);
    let mut live = run.results.clone();
    live.sort_by_key(|r| r.id);
    for (l, t) in live.iter().zip(reference.iter()) {
        assert_eq!(l.id, t.id);
        assert_eq!(l.logits, t.logits, "request {} diverged across tiers", l.id);
        assert_eq!(l.tokens, t.tokens);
        assert_eq!(l.gen_tokens, t.gen_tokens);
        assert_eq!(l.base_mults, t.base_mults);
        assert_eq!(l.base_reuses, t.base_reuses);
        assert!(l.ttft_s >= 0.0 && l.tpot_s >= 0.0);
    }
    // One handoff per request, billed at BPT × context (prompt + the
    // prefill-produced first token).
    assert_eq!(run.summary.handoff_bytes, (BPT as u64) * (8 + 1) * N);
    assert_eq!(run.summary.requests, N as usize);
}

#[test]
fn disagg_pool_answers_shed_requests_with_marker_results() {
    // Zero-tolerance admission on the live pool: wall time strictly
    // advances between submit and the prefill tier's pop, so every
    // request overshoots a 0-second deadline and is shed — answered
    // with a marker row (never dropped on the floor) and excluded from
    // the served summary.
    let base = SloPolicy::default();
    let slo = SloPolicy {
        standard: SloTarget {
            max_wait_s: 0.0,
            ttft_s: f64::INFINITY,
            ..base.standard
        },
        ..base
    };
    let pool = Server::start_disagg_pool(
        1,
        1,
        |_i| sim_engine(),
        BatchPolicy {
            max_batch: 2,
            max_wait_s: 0.01,
        },
        DisaggPoolOpts::new(4).with_slo(slo),
    );
    assert!(pool.cost().is_some());
    let trace: Vec<Request> = (0..6).map(|id| req_gen(id, 16, 4)).collect();
    let run = pool.run(trace, false).expect("disagg run must complete");
    assert_eq!(run.results.len(), 6);
    assert!(run.results.iter().all(|r| r.shed && r.gen_tokens == 0));
    assert_eq!(run.summary.shed, 6);
    assert_eq!(run.summary.requests, 0, "markers never enter the summary");
}
