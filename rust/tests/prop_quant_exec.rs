//! Property tests over quantization and the functional executor.

use axllm::exec::{dense_matmul, lora_matmul, reuse_matmul_chunked};
use axllm::model::synth::{DistKind, WeightDistribution};
use axllm::model::LoraAdaptor;
use axllm::quant::{fold, unfold, QuantMatrix, QuantParams};
use axllm::util::prop::{check, check_default, Config};
use axllm::{prop_assert, prop_assert_eq};

#[test]
fn prop_quant_roundtrip_error_bounded() {
    check_default("quant-roundtrip", |rng| {
        let bits = 2 + rng.below(7) as u8;
        let data: Vec<f32> = (0..200).map(|_| (rng.normal() * 3.0) as f32).collect();
        let p = QuantParams::fit(&data, bits);
        for &x in &data {
            let q = p.quantize(x);
            prop_assert!(q != i8::MIN, "must never emit -128");
            let err = (x - p.dequantize(q)).abs();
            prop_assert!(
                err <= p.scale / 2.0 + 1e-5,
                "err {} scale {}",
                err,
                p.scale
            );
        }
        Ok(())
    });
}

#[test]
fn prop_fold_unfold_bijection() {
    check_default("fold-bijection", |rng| {
        let q = rng.range_i64(-127, 127) as i8;
        let (u, neg) = fold(q);
        prop_assert!(u <= 127);
        prop_assert_eq!(unfold(u, neg), q);
        prop_assert_eq!(fold(q).0, fold(-q.max(-127)).0);
        Ok(())
    });
}

#[test]
fn prop_reuse_matmul_exact_all_distributions() {
    check("reuse-exact", Config { cases: 48, seed: 0xE8 }, |rng| {
        let rows = 1 + rng.index(64);
        let cols = 1 + rng.index(300);
        let kind = *rng.choose(&[
            DistKind::Gaussian,
            DistKind::Laplace,
            DistKind::StudentT(3),
            DistKind::Uniform,
        ]);
        let dist = WeightDistribution::default().with_kind(kind);
        let w = axllm::model::synth::synthesize_matrix(rows, cols, dist, rng);
        let x: Vec<i8> = (0..rows).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let chunk = 1 + rng.index(cols.max(1));
        let (y, stats) = reuse_matmul_chunked(&x, &w, chunk);
        prop_assert_eq!(y, dense_matmul(&x, &w));
        prop_assert_eq!(stats.mults + stats.reuses, (rows * cols) as u64);
        Ok(())
    });
}

#[test]
fn prop_chunk_monotone_reuse() {
    check_default("chunk-monotone", |rng| {
        let rows = 1 + rng.index(16);
        let cols = 64 + rng.index(448);
        let w = axllm::model::synth::synthesize_matrix(
            rows,
            cols,
            WeightDistribution::default(),
            rng,
        );
        let x: Vec<i8> = (0..rows).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let small = 8 + rng.index(32);
        let big = small * 2;
        let (_, s_small) = reuse_matmul_chunked(&x, &w, small);
        let (_, s_big) = reuse_matmul_chunked(&x, &w, big);
        // A chunk of size 2k can always reuse at least as much as two
        // chunks of size k.
        prop_assert!(
            s_big.reuses >= s_small.reuses,
            "reuse not monotone: {} vs {}",
            s_big.reuses,
            s_small.reuses
        );
        Ok(())
    });
}

#[test]
fn prop_lora_matmul_matches_explicit() {
    check("lora-exact", Config { cases: 24, seed: 0x10A }, |rng| {
        let d = 16 + rng.index(48);
        let rank = 1 + rng.index(8);
        let dist = WeightDistribution::default();
        let w = axllm::model::synth::synthesize_matrix(d, d, dist, rng);
        let adaptor = LoraAdaptor::synthesize(
            &w,
            axllm::config::LoraConfig {
                rank,
                alpha: 1.0,
            },
            dist,
            rng,
        );
        let x: Vec<i8> = (0..d).map(|_| rng.range_i64(-127, 127) as i8).collect();
        let chunk = d + rank;
        let (y, _) = lora_matmul(&x, &w, &adaptor, chunk);
        let yw = dense_matmul(&x, &w);
        let ya = dense_matmul(&x, &adaptor.a);
        for j in 0..d {
            let mut expect = yw[j] as i64;
            for k in 0..rank {
                expect += ya[k] as i64 * adaptor.b.get(k, j) as i64;
            }
            prop_assert_eq!(y[j], expect);
        }
        Ok(())
    });
}

#[test]
fn prop_matrix_concat_preserves_row_contents() {
    check_default("concat-rows", |rng| {
        let rows = 1 + rng.index(16);
        let c1 = 1 + rng.index(32);
        let c2 = 1 + rng.index(8);
        let p = QuantParams { scale: 1.0, bits: 8 };
        let a = QuantMatrix::from_q(
            rows,
            c1,
            (0..rows * c1).map(|_| rng.range_i64(-127, 127) as i8).collect(),
            p,
        );
        let b = QuantMatrix::from_q(
            rows,
            c2,
            (0..rows * c2).map(|_| rng.range_i64(-127, 127) as i8).collect(),
            p,
        );
        let c = a.concat_cols(&b);
        for r in 0..rows {
            prop_assert_eq!(&c.row(r)[..c1], a.row(r));
            prop_assert_eq!(&c.row(r)[c1..], b.row(r));
        }
        Ok(())
    });
}
