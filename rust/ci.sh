#!/usr/bin/env bash
# Local mirror of .github/workflows/ci.yml: the tier-1 gate plus lints,
# the artifact-free live-server integration tests, and the live-serving
# perf log.
set -euo pipefail
cd "$(dirname "$0")"

if [ ! -f Cargo.toml ]; then
  echo "ci: rust/Cargo.toml not in-tree (provisioned by the offline build env); nothing to run here" >&2
  exit 0
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
# Includes the artifact-free live-server integration suite
# (rust/tests/live_server.rs): trickle-starvation regression,
# live-vs-trace attribution equivalence, replica pool. Sim/functional
# backends only — no artifacts needed.
cargo test -q

echo "== live serve bench (writes BENCH_live_serve.json) =="
AXLLM_BENCH_FAST=1 cargo bench --bench live_serve

echo "== decode serve bench (writes BENCH_decode_serve.json) =="
# Asserts continuous batching out-serves closed-batch decode on a
# mixed-output-length trace (simulated token throughput).
AXLLM_BENCH_FAST=1 cargo bench --bench decode_serve

echo "== lora serve bench (writes BENCH_lora_serve.json) =="
# Asserts mixed-adapter continuous batching out-serves per-adapter
# serialized batches, and that the base-pipeline reuse rate survives
# LoRA (every tenant group within noise of the adapter-free run).
AXLLM_BENCH_FAST=1 cargo bench --bench lora_serve

echo "== shard serve bench (writes BENCH_shard_serve.json) =="
# Asserts the sim-backend shard speedup is > 1 (and sub-linear) at n=4,
# and that per-shard reuse rates are reported sum-consistent with the
# run's total base ops.
AXLLM_BENCH_FAST=1 cargo bench --bench shard_serve

echo "== prefix serve bench (writes BENCH_prefix_serve.json) =="
# Asserts warm prefix-cache serving beats the cold run's p50 TTFT with a
# nonzero prefix hit rate, while per-request token accounting stays
# identical (reuse is a scheduling transformation, not an approximation).
AXLLM_BENCH_FAST=1 cargo bench --bench prefix_serve

echo "== functional hot-loop bench (writes BENCH_functional_hot_loop.json) =="
# Asserts the packed/tiled/thread-parallel functional path is bit-identical
# to the seed scalar path (logits AND mult/reuse counters), beats it
# outright, and clears 3x tokens/s on >= 4-thread machines; the JSON perf
# log must stay free of NaN/inf.
AXLLM_BENCH_FAST=1 cargo bench --bench functional_hot_loop

echo "== disagg serve bench (writes BENCH_disagg_serve.json) =="
# Asserts the disaggregated 2-prefill/2-decode fleet with chunked
# prefill strictly beats the 4-replica unified pool's p99 TTFT on a
# flash-crowd trace (handoff tariff included), and that the JSON perf
# log stays NaN/inf-free.
AXLLM_BENCH_FAST=1 cargo bench --bench disagg_serve

echo "== quant regime property suite (smoke) =="
# Group-wise quantization regimes: degenerate bit-identity to the
# per-tensor kernels, value exactness at every group width (packed,
# sharded, LoRA-mixed), and reuse-monotonicity under grid refinement.
cargo test -q --test prop_quant_group

echo "== quant sweep bench (writes BENCH_quant_sweep.json) =="
# Asserts the group-size Pareto actually trades: finest-group reuse
# strictly below per-tensor while SNR improves, and compressed code
# streaming beats raw bytes at every swept group size.
AXLLM_BENCH_FAST=1 cargo bench --bench quant_sweep

echo "== execution-profile differential suite (smoke) =="
# Unified config plane: profile-built backends bit-identical to the
# legacy builder chains (logits, ExecStats, cost attribution),
# CostModel::from_profile order-canonical under builder permutation,
# TOML round trips exact, malformed profiles rejected.
cargo test -q --test prop_profile

echo "== map sweep bench (writes BENCH_map_sweep.json) =="
# Asserts the profile grid enumerates >= 16 configs, every axis stays
# finite, the best-throughput config sits on the Pareto front, and
# re-evaluating the winner through from_profile reproduces its tokens/s
# bit-exactly (the sweep rediscovers its own best config).
AXLLM_BENCH_FAST=1 cargo bench --bench map_sweep

echo "== config-plane lint: no new with_* constructors outside delegation shims =="
# Every backend-level with_* builder must stay a thin shim over the
# profile plane (ExecProfile / CostModel::from_profile). A new one
# appearing here means a capability was added without wiring it through
# the unified profile — extend ExecProfile instead.
allowed='with_paced|with_adapters|with_shards|with_kv_cache|with_quant_regime|with_seq_limit|with_scalar_kernels|with_decode_regime|with_adapter_regime|with_kv_regime|with_handoff_regime|with_shard_regime'
if grep -hoE 'pub fn with_[a-z_]+' src/backend/*.rs | sort -u | grep -vE "pub fn ($allowed)\$"; then
  echo "ci: new with_* constructor in src/backend/ — route it through ExecProfile" >&2
  exit 1
fi

echo "== cargo doc --no-deps (rustdoc must stay warning-free) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy --all-targets -- -D warnings =="
# --all-targets lints the tests and benches too, not just the library.
cargo clippy --all-targets -- -D warnings

echo "ci: all green"
