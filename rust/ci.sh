#!/usr/bin/env bash
# Local mirror of .github/workflows/ci.yml: the tier-1 gate plus lints.
set -euo pipefail
cd "$(dirname "$0")"

if [ ! -f Cargo.toml ]; then
  echo "ci: rust/Cargo.toml not in-tree (provisioned by the offline build env); nothing to run here" >&2
  exit 0
fi

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy -- -D warnings

echo "ci: all green"
