//! Functional (value-exact) execution of the reuse datapath.
//!
//! [`reuse_matmul`] performs `y = x·W` exactly the way the accelerator
//! does — per input element, a Result Cache keyed by folded weight value,
//! filled on first occurrence and read on repeats — and is proven
//! bit-identical to dense int8×int8→i32 GEMM by tests and property tests.
//! This is the paper's central exactness claim: *"preserves exact
//! arithmetic semantics"* — reuse is a scheduling transformation, not an
//! approximation.

pub mod group;
pub mod layer;
pub mod sharded;

pub use group::{
    group_accounting, group_matmul_f32, group_reuse_matmul_chunked, group_reuse_matmul_packed,
    sharded_group_reuse_matmul_chunked, sharded_group_reuse_matmul_packed,
};
pub use layer::{qmatmul_rowwise, quantize_row, softmax_rows, LayerExec, LayerKv};
pub use sharded::{
    shard_accounting, shard_ranges, sharded_reuse_matmul_chunked, sharded_reuse_matmul_packed,
};

use crate::model::LoraAdaptor;
use crate::quant::{fold, PackedQuantMatrix, QuantMatrix, PACK_WIDTH};

/// Per-call counters of the functional executor, split between the base
/// reuse pipeline and the LoRA side pipeline.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExecStats {
    /// Base-pipeline multiplications (Result-Cache fills).
    pub mults: u64,
    /// Base-pipeline reuses (Result-Cache hits).
    pub reuses: u64,
    /// Dense MACs performed on the rank-r adapter side pipeline
    /// ([`lora_side_matmul`]). Kept out of [`ExecStats::reuse_rate`] so
    /// the base pipe's reuse accounting is unchanged by adapters — the
    /// invariant behind the paper's "reuse survives LoRA" claim.
    pub adapter_mults: u64,
}

impl ExecStats {
    /// Base-pipeline reuse rate: reuses over (mults + reuses). Adapter
    /// side-pipe MACs are excluded by construction.
    pub fn reuse_rate(&self) -> f64 {
        let n = self.mults + self.reuses;
        if n == 0 {
            0.0
        } else {
            self.reuses as f64 / n as f64
        }
    }

    /// Accumulate another counter record into this one.
    pub fn add(&mut self, o: &ExecStats) {
        self.mults += o.mults;
        self.reuses += o.reuses;
        self.adapter_mults += o.adapter_mults;
    }

    /// Scale all counters by `num/den` (row-sampled measurements
    /// extrapolating to the full matrix, like
    /// [`crate::sim::SimStats::scaled`]).
    pub fn scaled(&self, num: u64, den: u64) -> ExecStats {
        let s = |v: u64| (v as u128 * num as u128 / den.max(1) as u128) as u64;
        ExecStats {
            mults: s(self.mults),
            reuses: s(self.reuses),
            adapter_mults: s(self.adapter_mults),
        }
    }
}

/// Epoch-tagged first-occurrence tracker — the branch-free Result-Cache
/// *accounting* used by [`reuse_matmul_chunked`]. A fresh epoch starts per
/// RC chunk; a tag equal to the current epoch means "this folded value was
/// already seen this chunk".
///
/// Hardened against counter wraparound: after 2^32 epochs the `u32`
/// counter revisits old values, and a stale tag written 2^32 chunks ago
/// would silently alias a live epoch (a first occurrence would be
/// miscounted as a reuse). [`EpochTags::next_epoch`] therefore physically
/// resets the tag array when the counter wraps — O(1) everywhere else —
/// mirroring the wrap reset in [`crate::sim::rc::ResultCache::clear`].
#[derive(Clone, Debug)]
pub struct EpochTags {
    /// 256-wide so a `u8` index provably never bounds-checks.
    tags: [u32; 256],
    epoch: u32,
}

impl Default for EpochTags {
    fn default() -> Self {
        Self::new()
    }
}

impl EpochTags {
    /// Fresh tracker: zeroed tags, epoch 1.
    pub fn new() -> EpochTags {
        // Epoch starts at 1 (the same value the wrap reset restarts at):
        // a zeroed tag must never equal a live epoch, so a fresh tracker
        // counts first occurrences correctly even before any
        // `next_epoch` call.
        EpochTags {
            tags: [0; 256],
            epoch: 1,
        }
    }

    /// Start a fresh epoch (O(1); O(entries) only on the 2^32 wrap, where
    /// the tags are physically reset so no stale tag can alias).
    #[inline]
    pub fn next_epoch(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            self.tags = [0; 256];
            self.epoch = 1;
        }
    }

    /// True the first time `u` is seen in the current epoch (and tags it).
    #[inline]
    pub fn first_occurrence(&mut self, u: u8) -> bool {
        let first = self.tags[u as usize] != self.epoch;
        self.tags[u as usize] = self.epoch;
        first
    }

    /// Current epoch counter (diagnostics / wrap tests).
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Jump the counter to an arbitrary epoch. Exists so the wraparound
    /// regression test can exercise the 2^32 boundary without performing
    /// 2^32 clears; production callers never need it.
    pub fn force_epoch(&mut self, epoch: u32) {
        self.epoch = epoch;
    }
}

/// Fill the signed product table for input element `xi`:
/// `products[q + 127] = xi·q` for `q ∈ [-127, 127]`. Entry 255 is
/// reachable only by weight code −128 (its `q + 127` offset wraps to 255
/// in `u8`); the symmetric quantizer excludes −128, but matrices built
/// directly from codes may carry it, so the entry holds the true product
/// `xi · −128` instead of a silent 0 (regression-tested below).
#[inline]
pub(crate) fn fill_products(xi: i32, products: &mut [i32; 256]) {
    for (off, p) in products.iter_mut().enumerate().take(255) {
        *p = xi * (off as i32 - 127);
    }
    products[255] = xi * -128;
}

/// Folded-value index per product-table offset: `FOLD[q + 127] = |q|`,
/// with entry 255 → 128 (the fold of code −128). Lets the packed kernels
/// run the value gather and the RC first-occurrence accounting off the
/// same extracted offset byte in a single pass — first-occurrence counts
/// are order-free within a chunk epoch, so the fused pass produces
/// counters identical to the scalar two-pass kernel.
pub(crate) const FOLD: [u8; 256] = {
    let mut t = [0u8; 256];
    let mut i = 0;
    while i < 256 {
        let q = i as i32 - 127;
        t[i] = if q < 0 { (-q) as u8 } else { q as u8 };
        i += 1;
    }
    t
};

/// Walk one RC chunk `[col, end)` of a packed weight row: gather products
/// into `y` (indexed at `c - y_off`, so sharded callers can hand a
/// shard-local slab) and count folded first occurrences against `tags`.
/// The tile is bounded by the chunk edges, never the word grid — byte-wise
/// head until word-aligned, whole `u32` words (4 codes) through the body,
/// byte-wise tail — so row-padding bytes are never visited. Returns the
/// chunk's unique (multiply) count.
#[inline]
pub(crate) fn packed_tile(
    words: &[u32],
    col: usize,
    end: usize,
    products: &[i32; 256],
    tags: &mut EpochTags,
    y: &mut [i32],
    y_off: usize,
) -> u64 {
    let mut unique = 0u64;
    let mut c = col;
    while c < end && c % PACK_WIDTH != 0 {
        let off = ((words[c / PACK_WIDTH] >> (8 * (c % PACK_WIDTH))) & 0xFF) as usize;
        y[c - y_off] += products[off];
        unique += tags.first_occurrence(FOLD[off]) as u64;
        c += 1;
    }
    while c + PACK_WIDTH <= end {
        let word = words[c / PACK_WIDTH];
        let o0 = (word & 0xFF) as usize;
        let o1 = ((word >> 8) & 0xFF) as usize;
        let o2 = ((word >> 16) & 0xFF) as usize;
        let o3 = (word >> 24) as usize;
        let base = c - y_off;
        y[base] += products[o0];
        y[base + 1] += products[o1];
        y[base + 2] += products[o2];
        y[base + 3] += products[o3];
        unique += tags.first_occurrence(FOLD[o0]) as u64;
        unique += tags.first_occurrence(FOLD[o1]) as u64;
        unique += tags.first_occurrence(FOLD[o2]) as u64;
        unique += tags.first_occurrence(FOLD[o3]) as u64;
        c += PACK_WIDTH;
    }
    while c < end {
        let off = ((words[c / PACK_WIDTH] >> (8 * (c % PACK_WIDTH))) & 0xFF) as usize;
        y[c - y_off] += products[off];
        unique += tags.first_occurrence(FOLD[off]) as u64;
        c += 1;
    }
    unique
}

/// Reusable scratch buffers for the packed hot path: one arena is
/// threaded through an executor's forward passes so the per-row and
/// per-chunk `Vec` allocations of the scalar reference kernels disappear
/// from prefill and decode.
///
/// Lifetime rules (see `rust/DESIGN.md` §"Packed functional hot path"):
/// an arena is owned by exactly one executor at a time, kernels leave
/// their result inside it (e.g. [`ExecArena::yq`]), and callers copy or
/// scale the result out before the next kernel call. Arenas never alias —
/// parallel workers each own their own arena (or build scratch locally),
/// which keeps parallel accounting trivially deterministic.
#[derive(Clone, Debug)]
pub struct ExecArena {
    /// Quantized input row (the input side of one matmul).
    pub(crate) xq: Vec<i8>,
    /// Integer matmul output row (read back via [`ExecArena::yq`]).
    pub(crate) yq: Vec<i32>,
    /// Signed product table — the RC value datapath.
    pub(crate) products: [i32; 256],
    /// First-occurrence tags — the RC accounting — for monolithic runs.
    pub(crate) tags: EpochTags,
    /// Per-shard first-occurrence tags for sharded runs (one independent
    /// Result Cache per shard).
    pub(crate) shard_tags: Vec<EpochTags>,
    /// Attention-score scratch (one causal row at a time).
    pub(crate) scores: Vec<f32>,
    /// LoRA side-pipe scratch: `x·A` in i64.
    pub(crate) xa: Vec<i64>,
    /// LoRA side-pipe output: `(x·A)·B` in i64 (read back via
    /// [`ExecArena::side`]).
    pub(crate) ys: Vec<i64>,
}

impl ExecArena {
    /// Fresh arena with empty buffers (they grow to steady-state sizes on
    /// first use and are reused afterwards).
    pub fn new() -> ExecArena {
        ExecArena {
            xq: Vec::new(),
            yq: Vec::new(),
            products: [0i32; 256],
            tags: EpochTags::new(),
            shard_tags: Vec::new(),
            scores: Vec::new(),
            xa: Vec::new(),
            ys: Vec::new(),
        }
    }

    /// The integer output of the last packed matmul kernel call.
    pub fn yq(&self) -> &[i32] {
        &self.yq
    }

    /// The i64 output of the last [`lora_side_matmul_arena`] call.
    pub fn side(&self) -> &[i64] {
        &self.ys
    }

    /// Quantize `row` onto its own fitted grid into the arena's input
    /// buffer (the row-wise activation-grid step of the hot path).
    pub fn quantize_into(&mut self, row: &[f32]) -> crate::quant::QuantParams {
        let params = crate::quant::QuantParams::fit(row, 8);
        self.quantize_with(row, params);
        params
    }

    /// Quantize `row` onto a caller-supplied grid into the arena's input
    /// buffer (the block-grid step of [`layer::qmatmul`]-style calls).
    pub(crate) fn quantize_with(&mut self, row: &[f32], params: crate::quant::QuantParams) {
        self.xq.clear();
        self.xq.extend(row.iter().map(|&v| params.quantize(v)));
    }
}

impl Default for ExecArena {
    fn default() -> Self {
        Self::new()
    }
}

/// Packed/tiled reuse-path execution of `y = x·W`: the blocked form of
/// [`reuse_matmul_chunked`] over a [`PackedQuantMatrix`], with the output
/// left in the arena ([`ExecArena::yq`]) and every scratch buffer drawn
/// from it — the kernel allocates nothing.
///
/// Per input element the signed product table is filled once; each RC
/// chunk is then walked as one [`packed_tile`] (byte head / word body /
/// byte tail, bounded by the chunk edges so padding bytes are never
/// visited), with value gather and epoch-tag accounting fused off the
/// same extracted offset byte. Bit-identical to [`reuse_matmul_chunked`]
/// in both values and counters — pinned by `tests/prop_packed.rs`.
pub fn reuse_matmul_packed(
    x: &[i8],
    w: &PackedQuantMatrix,
    chunk: usize,
    arena: &mut ExecArena,
) -> ExecStats {
    assert_eq!(x.len(), w.rows);
    assert!(chunk > 0);
    let ExecArena {
        yq, products, tags, ..
    } = arena;
    yq.clear();
    yq.resize(w.cols, 0);
    let mut stats = ExecStats::default();
    for (i, &xi) in x.iter().enumerate() {
        fill_products(xi as i32, products);
        let words = w.row_words(i);
        let mut col = 0usize;
        while col < w.cols {
            let end = (col + chunk).min(w.cols);
            tags.next_epoch();
            let unique = packed_tile(words, col, end, products, tags, yq, 0);
            stats.mults += unique;
            stats.reuses += (end - col) as u64 - unique;
            col = end;
        }
    }
    stats
}

/// Arena-backed adapter side pipeline: value-identical to
/// [`lora_side_matmul`], with the `x·A` scratch and the output drawn from
/// the arena (result read back via [`ExecArena::side`]; no allocation).
pub fn lora_side_matmul_arena(
    x: &[i8],
    adaptor: &LoraAdaptor,
    arena: &mut ExecArena,
) -> ExecStats {
    assert_eq!(x.len(), adaptor.a.rows);
    let r = adaptor.a.cols;
    let cols = adaptor.b.cols;
    arena.xa.clear();
    arena.xa.resize(r, 0);
    for (i, &xi) in x.iter().enumerate() {
        let xi = xi as i64;
        for (k, xak) in arena.xa.iter_mut().enumerate() {
            *xak += xi * adaptor.a.get(i, k) as i64;
        }
    }
    arena.ys.clear();
    arena.ys.resize(cols, 0);
    for (k, &xak) in arena.xa.iter().enumerate() {
        for (j, yj) in arena.ys.iter_mut().enumerate() {
            *yj += xak * adaptor.b.get(k, j) as i64;
        }
    }
    ExecStats {
        adapter_mults: adaptor.extra_macs(),
        ..ExecStats::default()
    }
}

/// Dense reference: `y[j] = Σ_i x[i]·W[i,j]` in i32.
pub fn dense_matmul(x: &[i8], w: &QuantMatrix) -> Vec<i32> {
    assert_eq!(x.len(), w.rows);
    let mut y = vec![0i32; w.cols];
    for (i, &xi) in x.iter().enumerate() {
        let xi = xi as i32;
        for (yj, &wij) in y.iter_mut().zip(w.row(i)) {
            *yj += xi * wij as i32;
        }
    }
    y
}

/// Reuse-path execution of `y = x·W` with a `chunk`-bounded Result Cache
/// (reuse cannot cross chunk boundaries — the W_buff size limit of §IV).
///
/// Returns the output and the multiply/reuse counts.
///
/// Hot-path layout (§Perf): the value datapath is branch-free — a signed
/// 255-entry product table indexed by `code + 127` (precisely the L1
/// Pallas kernel's formulation of the RC), with the RC hit/miss
/// *accounting* kept branch-free too via an epoch-tagged bitmap. This is
/// semantically identical to the tag-checked implementation (the product
/// of a hit equals the cached product bit-for-bit because int multiply is
/// deterministic) and ~3× faster; `sim::lane` retains the literal
/// fill/read RC structure.
pub fn reuse_matmul_chunked(x: &[i8], w: &QuantMatrix, chunk: usize) -> (Vec<i32>, ExecStats) {
    assert_eq!(x.len(), w.rows);
    assert!(chunk > 0);
    let mut y = vec![0i32; w.cols];
    let mut stats = ExecStats::default();
    // Folded-value first-occurrence tags (epoch-cleared, wrap-hardened).
    let mut tags = EpochTags::new();
    // Signed product table: products[q + 127] = x_i * q (256-wide, u8
    // indexed — entry 255 is code −128's slot, see [`fill_products`]).
    let mut products = [0i32; 256];
    for (i, &xi) in x.iter().enumerate() {
        fill_products(xi as i32, &mut products);
        let row = w.row(i);
        let mut col = 0;
        while col < w.cols {
            let end = (col + chunk).min(w.cols);
            tags.next_epoch();
            // Value datapath: pure gather+accumulate, no branches.
            for (&wij, yj) in row[col..end].iter().zip(&mut y[col..end]) {
                *yj += products[(wij as i32 + 127) as u8 as usize];
            }
            // RC accounting: first-occurrence count per chunk.
            let mut unique = 0u64;
            for &wij in &row[col..end] {
                unique += tags.first_occurrence(wij.unsigned_abs()) as u64;
            }
            stats.mults += unique;
            stats.reuses += (end - col) as u64 - unique;
            col = end;
        }
    }
    (y, stats)
}

/// Reuse-path execution with whole-row caching (unbounded buffer).
pub fn reuse_matmul(x: &[i8], w: &QuantMatrix) -> (Vec<i32>, ExecStats) {
    reuse_matmul_chunked(x, w, w.cols.max(1))
}

/// LoRA-adapted matmul via the combined `[W ∥ A]` matrix (paper Fig. 5):
/// `y = x·W + (x·A)·B`, with the x·W and x·A products sharing one RC pass.
///
/// Returns `(y_q, stats)` where `y_q[j] = Σ x·W + Σ (x·A)·B` is evaluated
/// in integer code space with B applied at i64 precision.
pub fn lora_matmul(
    x: &[i8],
    w: &QuantMatrix,
    adaptor: &LoraAdaptor,
    chunk: usize,
) -> (Vec<i64>, ExecStats) {
    let combined = adaptor.combined(w);
    let (yc, stats) = reuse_matmul_chunked(x, &combined, chunk);
    let (yw, xa) = yc.split_at(w.cols);
    // (x·A)·B in integer code space.
    let r = adaptor.b.rows;
    let mut y: Vec<i64> = yw.iter().map(|&v| v as i64).collect();
    for (k, &xak) in xa.iter().enumerate().take(r) {
        for (j, yj) in y.iter_mut().enumerate() {
            *yj += xak as i64 * adaptor.b.get(k, j) as i64;
        }
    }
    (y, stats)
}

/// The adapter **side pipeline** of per-request LoRA serving: the dense
/// rank-r computation `(x·A)·B` on its own, leaving the base `x·W` pass
/// (and its Result-Cache accounting) untouched.
///
/// This is how the serving path routes adapters — base pipe keeps its
/// reuse discount, the side pipe is dense — whereas [`lora_matmul`] is
/// the offline combined-`[W ∥ A]` kernel (paper Fig. 5). The two are
/// value-identical: for any input,
/// `reuse_matmul_chunked(x, w, c).0[j] + lora_side_matmul(x, a).0[j]
///  == lora_matmul(x, w, a, c).0[j]` exactly (`tests/prop_lora.rs`
/// proves this property; a fixed case is pinned below).
///
/// Returns `(y_side, stats)` where `y_side[j] = Σ_k (x·A)[k]·B[k,j]` in
/// integer code space (B applied at i64 precision) and `stats` counts
/// every side-pipe MAC in [`ExecStats::adapter_mults`].
pub fn lora_side_matmul(x: &[i8], adaptor: &LoraAdaptor) -> (Vec<i64>, ExecStats) {
    assert_eq!(x.len(), adaptor.a.rows);
    let r = adaptor.a.cols;
    let cols = adaptor.b.cols;
    // x·A in i64 (dense multiply path — no RC on the side pipe).
    let mut xa = vec![0i64; r];
    for (i, &xi) in x.iter().enumerate() {
        let xi = xi as i64;
        for (k, xak) in xa.iter_mut().enumerate() {
            *xak += xi * adaptor.a.get(i, k) as i64;
        }
    }
    // (x·A)·B in i64.
    let mut y = vec![0i64; cols];
    for (k, &xak) in xa.iter().enumerate() {
        for (j, yj) in y.iter_mut().enumerate() {
            *yj += xak * adaptor.b.get(k, j) as i64;
        }
    }
    let stats = ExecStats {
        adapter_mults: adaptor.extra_macs(),
        ..ExecStats::default()
    };
    (y, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LoraConfig;
    use crate::model::synth::{synthesize_matrix, WeightDistribution};
    use crate::util::rng::Rng;

    fn case(rows: usize, cols: usize, seed: u64) -> (Vec<i8>, QuantMatrix) {
        let mut rng = Rng::new(seed);
        let w = synthesize_matrix(rows, cols, WeightDistribution::default(), &mut rng);
        let x: Vec<i8> = (0..rows)
            .map(|_| rng.range_i64(-127, 127) as i8)
            .collect();
        (x, w)
    }

    #[test]
    fn reuse_equals_dense_exactly() {
        for seed in 0..5 {
            let (x, w) = case(64, 96, seed);
            let (y, stats) = reuse_matmul(&x, &w);
            assert_eq!(y, dense_matmul(&x, &w));
            assert!(stats.reuses > 0, "expected reuse on Gaussian weights");
        }
    }

    #[test]
    fn chunked_reuse_equals_dense_for_all_chunks() {
        let (x, w) = case(32, 200, 9);
        let dense = dense_matmul(&x, &w);
        for &chunk in &[1usize, 7, 64, 200, 500] {
            let (y, _) = reuse_matmul_chunked(&x, &w, chunk);
            assert_eq!(y, dense, "chunk={chunk}");
        }
    }

    #[test]
    fn epoch_tags_survive_u32_wraparound() {
        // Regression: the u32 epoch counter revisits old values after
        // 2^32 chunk clears; a stale tag must never alias a live epoch.
        let mut t = EpochTags::new();
        // A fresh tracker is immediately usable: zeroed tags never alias
        // the starting epoch.
        assert!(t.first_occurrence(3));
        assert!(!t.first_occurrence(3));
        t.force_epoch(u32::MAX - 1);
        t.next_epoch(); // → u32::MAX
        assert_eq!(t.epoch(), u32::MAX);
        assert!(t.first_occurrence(7));
        assert!(!t.first_occurrence(7), "second sighting must be a reuse");
        t.next_epoch(); // wraps → physical reset, epoch restarts at 1
        assert_eq!(t.epoch(), 1);
        for u in [0u8, 7, 127, 255] {
            assert!(
                t.first_occurrence(u),
                "value {u} aliased a stale tag across the epoch wrap"
            );
        }
        // And the fresh epoch still deduplicates correctly.
        assert!(!t.first_occurrence(127));
    }

    #[test]
    fn epoch_tags_counting_matches_matmul_accounting() {
        // The extracted tracker and the matmul's counters must agree:
        // drive one row through both and compare unique counts.
        let (x, w) = case(1, 300, 17);
        let chunk = 64;
        let (_, stats) = reuse_matmul_chunked(&x, &w, chunk);
        let mut t = EpochTags::new();
        let mut unique = 0u64;
        let row = w.row(0);
        for c in row.chunks(chunk) {
            t.next_epoch();
            for &wij in c {
                unique += t.first_occurrence(wij.unsigned_abs()) as u64;
            }
        }
        assert_eq!(stats.mults, unique);
        assert_eq!(stats.mults + stats.reuses, 300);
    }

    #[test]
    fn code_minus_128_contributes_its_true_product() {
        // Regression (−128 hazard): code −128's product-table offset
        // wraps to entry 255, which used to be left zero-filled — the
        // kernel silently added 0 instead of x_i·(−128). The symmetric
        // quantizer never emits −128 (and `from_q` rejects it), so build
        // the matrix via the struct literal to reach the hazard.
        let params = crate::quant::QuantParams { scale: 1.0, bits: 8 };
        let w = QuantMatrix {
            rows: 2,
            cols: 3,
            data: vec![-128, 5, -128, 7, -128, 0],
            params,
        };
        let x = vec![3i8, -2];
        let dense = dense_matmul(&x, &w);
        // y[0] = 3·(−128) + (−2)·7 = −398; y[1] = 3·5 + (−2)·(−128) = 271;
        // y[2] = 3·(−128) = −384.
        assert_eq!(dense, vec![-398, 271, -384]);
        for chunk in [1usize, 2, 3, 16] {
            let (y, stats) = reuse_matmul_chunked(&x, &w, chunk);
            assert_eq!(y, dense, "chunk={chunk}");
            assert_eq!(stats.mults + stats.reuses, 6);
        }
        let (y_sh, _) = sharded_reuse_matmul_chunked(&x, &w, 2, 2);
        assert_eq!(y_sh, dense);
        // The packed layout carries −128 as offset 255 and must agree.
        let mut arena = ExecArena::new();
        let stats = reuse_matmul_packed(&x, &w.packed(), 2, &mut arena);
        assert_eq!(arena.yq(), &dense[..]);
        assert_eq!(stats.mults + stats.reuses, 6);
    }

    #[test]
    fn fold_table_matches_quant_fold() {
        for q in -127i8..=127 {
            let off = (q as i16 + 127) as u8;
            assert_eq!(FOLD[off as usize], fold(q).0, "q={q}");
        }
        // Code −128 wraps to offset 255 and folds to 128 — the slot its
        // accounting (`unsigned_abs`) uses in the 256-wide tag array.
        assert_eq!(FOLD[255], 128);
    }

    #[test]
    fn packed_matches_scalar_reuse_exactly() {
        // Values AND counters, across chunk sizes including ones that are
        // not multiples of the pack width.
        let mut arena = ExecArena::new();
        for seed in 0..4 {
            let (x, w) = case(32, 130, seed);
            let packed = w.packed();
            for &chunk in &[1usize, 3, 4, 7, 64, 130, 500] {
                let (y, stats) = reuse_matmul_chunked(&x, &w, chunk);
                let sp = reuse_matmul_packed(&x, &packed, chunk, &mut arena);
                assert_eq!(arena.yq(), &y[..], "seed={seed} chunk={chunk}");
                assert_eq!(sp, stats, "seed={seed} chunk={chunk}");
            }
        }
    }

    #[test]
    fn packed_handles_degenerate_shapes() {
        let mut arena = ExecArena::new();
        // Empty matrix: no columns, no work.
        let (x, w) = case(8, 0, 1);
        let stats = reuse_matmul_packed(&x, &w.packed(), 16, &mut arena);
        assert!(arena.yq().is_empty());
        assert_eq!(stats, ExecStats::default());
        // Single column: one byte per row word.
        let (x, w) = case(8, 1, 2);
        let (y, st) = reuse_matmul_chunked(&x, &w, 16);
        let sp = reuse_matmul_packed(&x, &w.packed(), 16, &mut arena);
        assert_eq!(arena.yq(), &y[..]);
        assert_eq!(sp, st);
        // Empty input vector (0×N matrix).
        let (_, w) = case(0, 5, 3);
        let sp = reuse_matmul_packed(&[], &w.packed(), 4, &mut arena);
        assert_eq!(arena.yq(), &[0i32; 5][..]);
        assert_eq!(sp, ExecStats::default());
    }

    #[test]
    fn arena_reuse_across_calls_is_stateless() {
        // A dirty arena (stale yq/tags/products from a previous call)
        // must not leak into the next call's result.
        let mut arena = ExecArena::new();
        let (x1, w1) = case(24, 96, 31);
        let _ = reuse_matmul_packed(&x1, &w1.packed(), 17, &mut arena);
        let (x2, w2) = case(16, 200, 32);
        let (y, stats) = reuse_matmul_chunked(&x2, &w2, 64);
        let sp = reuse_matmul_packed(&x2, &w2.packed(), 64, &mut arena);
        assert_eq!(arena.yq(), &y[..]);
        assert_eq!(sp, stats);
    }

    #[test]
    fn lora_side_arena_matches_allocating_side_pipe() {
        let mut rng = Rng::new(41);
        let dist = WeightDistribution::default();
        let w = synthesize_matrix(48, 64, dist, &mut rng);
        let adaptor =
            LoraAdaptor::synthesize(&w, LoraConfig { rank: 4, alpha: 8.0 }, dist, &mut rng);
        let x: Vec<i8> = (0..48).map(|_| rng.range_i64(-100, 100) as i8).collect();
        let (side, side_stats) = lora_side_matmul(&x, &adaptor);
        let mut arena = ExecArena::new();
        let arena_stats = lora_side_matmul_arena(&x, &adaptor, &mut arena);
        assert_eq!(arena.side(), &side[..]);
        assert_eq!(arena_stats, side_stats);
        // And again on the dirty arena.
        let arena_stats2 = lora_side_matmul_arena(&x, &adaptor, &mut arena);
        assert_eq!(arena.side(), &side[..]);
        assert_eq!(arena_stats2, side_stats);
    }

    #[test]
    fn smaller_chunks_reuse_less() {
        let (x, w) = case(16, 512, 4);
        let (_, s64) = reuse_matmul_chunked(&x, &w, 64);
        let (_, s512) = reuse_matmul_chunked(&x, &w, 512);
        assert!(s512.reuse_rate() > s64.reuse_rate());
    }

    #[test]
    fn mults_bounded_by_unique_values_per_chunk() {
        let (x, w) = case(8, 512, 5);
        let (_, stats) = reuse_matmul(&x, &w);
        // ≤128 folded values per row → ≤128 mults per row.
        assert!(stats.mults <= 8 * 128);
        assert_eq!(stats.mults + stats.reuses, (8 * 512) as u64);
    }

    #[test]
    fn extreme_values_exact() {
        let params = crate::quant::QuantParams { scale: 1.0, bits: 8 };
        let w = QuantMatrix::from_q(2, 4, vec![127, -127, 0, 1, -1, 127, -127, 0], params);
        let x = vec![-127i8, 127];
        let (y, _) = reuse_matmul(&x, &w);
        assert_eq!(y, dense_matmul(&x, &w));
    }

    #[test]
    fn lora_matches_explicit_evaluation() {
        let mut rng = Rng::new(11);
        let dist = WeightDistribution::default();
        let w = synthesize_matrix(48, 48, dist, &mut rng);
        let adaptor =
            LoraAdaptor::synthesize(&w, LoraConfig { rank: 4, alpha: 8.0 }, dist, &mut rng);
        let x: Vec<i8> = (0..48).map(|_| rng.range_i64(-100, 100) as i8).collect();
        let (y, stats) = lora_matmul(&x, &w, &adaptor, 48 + 4);
        // Explicit: x·W + (x·A)·B.
        let yw = dense_matmul(&x, &w);
        let ya = dense_matmul(&x, &adaptor.a);
        let mut expect: Vec<i64> = yw.iter().map(|&v| v as i64).collect();
        for k in 0..4 {
            for j in 0..48 {
                expect[j] += ya[k] as i64 * adaptor.b.get(k, j) as i64;
            }
        }
        assert_eq!(y, expect);
        assert!(stats.reuse_rate() > 0.3);
    }

    #[test]
    fn side_pipe_plus_base_equals_offline_combined_kernel() {
        // The serving decomposition (base reuse pipe + dense rank-r side
        // pipe) must be value-identical to the offline combined [W ∥ A]
        // kernel — the generalized property lives in tests/prop_lora.rs.
        let mut rng = Rng::new(21);
        let dist = WeightDistribution::default();
        let w = synthesize_matrix(48, 64, dist, &mut rng);
        let adaptor =
            LoraAdaptor::synthesize(&w, LoraConfig { rank: 4, alpha: 8.0 }, dist, &mut rng);
        let x: Vec<i8> = (0..48).map(|_| rng.range_i64(-100, 100) as i8).collect();
        let (base, base_stats) = reuse_matmul_chunked(&x, &w, 64);
        let (side, side_stats) = lora_side_matmul(&x, &adaptor);
        let (combined, _) = lora_matmul(&x, &w, &adaptor, 64 + 4);
        for j in 0..w.cols {
            assert_eq!(base[j] as i64 + side[j], combined[j], "col {j}");
        }
        // Base-pipe accounting is untouched by the side pipe…
        assert_eq!(base_stats.adapter_mults, 0);
        let (_, base_alone) = reuse_matmul_chunked(&x, &w, 64);
        assert_eq!(base_stats, base_alone);
        // …and the side pipe is fully dense.
        assert_eq!(side_stats.mults, 0);
        assert_eq!(side_stats.reuses, 0);
        assert_eq!(side_stats.adapter_mults, adaptor.extra_macs());
        assert_eq!(side_stats.reuse_rate(), 0.0, "side MACs never count as reuse");
    }

    #[test]
    fn lora_combined_reuses_more_than_sum_of_parts() {
        let mut rng = Rng::new(13);
        let dist = WeightDistribution::default();
        let w = synthesize_matrix(64, 256, dist, &mut rng);
        let adaptor =
            LoraAdaptor::synthesize(&w, LoraConfig::default(), dist, &mut rng);
        let x: Vec<i8> = (0..64).map(|_| rng.range_i64(-100, 100) as i8).collect();
        let chunk = 256 + adaptor.a.cols;
        let (_, combined) = lora_matmul(&x, &w, &adaptor, chunk);
        let (_, sw) = reuse_matmul_chunked(&x, &w, 256);
        let (_, sa) = reuse_matmul_chunked(&x, &adaptor.a, adaptor.a.cols);
        // The A-columns piggyback on W's RC: fewer total multiplies than
        // processing W and A with separate caches.
        assert!(combined.mults <= sw.mults + sa.mults);
        assert!(combined.reuses >= sw.reuses + sa.reuses);
    }
}
