//! Group-aware reuse kernels (ROADMAP item 4): the Result-Cache datapath
//! with **per-group product tables keyed off the group's scale**.
//!
//! Group-wise quantization ([`GroupQuantMatrix`]) gives each contiguous
//! column group its own scale. A hardware Result Cache stores the
//! *scaled* product `x_i · q · s_g`, so a cached entry is invalid the
//! moment the column walk crosses into a group with a different scale —
//! the RC is conceptually one product table per group. These kernels
//! model that exactly: the epoch grid of the per-tensor kernels (W_buff
//! chunk boundaries) is refined by the **group boundary grid**, and a
//! fresh epoch opens at every segment
//! `[col, min(next chunk multiple, next group multiple, limit))`.
//!
//! Values are unchanged by the refinement — the integer accumulation
//! `y[j] = Σ_i x[i]·w[i,j]` is segment-order-free, and group scales
//! apply per output column *downstream* (dequantization), never inside
//! the integer datapath. Only the mult/reuse split moves. Consequences,
//! mirroring the sharding theorems of [`crate::exec::sharded`]:
//!
//! - `group ≥ cols` (one group) is **bit-identical** to the per-tensor
//!   kernels in outputs and counters, and
//! - shrinking the group width only refines epochs, so group-scoped
//!   mults are monotone non-decreasing (reuse only drops) — the
//!   "fragmented code distributions → lower RC hit rates" axis of the
//!   quant-sweep Pareto.
//!
//! Both are pinned by `tests/prop_quant_group.rs` across the scalar,
//! packed/tiled, and sharded kernel matrix.

use crate::exec::{fill_products, packed_tile, EpochTags, ExecArena, ExecStats};
use crate::exec::sharded::shard_ranges;
use crate::quant::{GroupQuantMatrix, PackedQuantMatrix, QuantMatrix, QuantParams};

/// Next epoch boundary at or after `col`: the tighter of the global
/// W_buff chunk grid and the group-scale grid, clamped to `limit`.
/// Saturating so the per-tensor sentinel (`group = usize::MAX`) and
/// other huge widths never overflow.
#[inline]
fn segment_end(col: usize, chunk: usize, group: usize, limit: usize) -> usize {
    let c = (col / chunk + 1).saturating_mul(chunk);
    let g = (col / group + 1).saturating_mul(group);
    c.min(g).min(limit)
}

/// Group-scoped form of [`crate::exec::reuse_matmul_chunked`]: `y = x·W`
/// through the RC with epochs on the intersection of the chunk grid and
/// the `group`-column scale grid. `group ≥ w.cols` degenerates
/// bit-exactly to the per-tensor kernel.
pub fn group_reuse_matmul_chunked(
    x: &[i8],
    w: &QuantMatrix,
    group: usize,
    chunk: usize,
) -> (Vec<i32>, ExecStats) {
    assert_eq!(x.len(), w.rows);
    assert!(chunk > 0);
    assert!(group > 0);
    let mut y = vec![0i32; w.cols];
    let mut stats = ExecStats::default();
    let mut tags = EpochTags::new();
    let mut products = [0i32; 256];
    for (i, &xi) in x.iter().enumerate() {
        fill_products(xi as i32, &mut products);
        let row = w.row(i);
        let mut col = 0;
        while col < w.cols {
            let end = segment_end(col, chunk, group, w.cols);
            // A fresh epoch per segment: crossing a group boundary
            // invalidates the (conceptually scale-keyed) product table.
            tags.next_epoch();
            for (&wij, yj) in row[col..end].iter().zip(&mut y[col..end]) {
                *yj += products[(wij as i32 + 127) as u8 as usize];
            }
            let mut unique = 0u64;
            for &wij in &row[col..end] {
                unique += tags.first_occurrence(wij.unsigned_abs()) as u64;
            }
            stats.mults += unique;
            stats.reuses += (end - col) as u64 - unique;
            col = end;
        }
    }
    (y, stats)
}

/// Group-scoped form of [`crate::exec::reuse_matmul_packed`]: the
/// packed/tiled hot path with the refined epoch grid. Each segment is
/// one [`packed_tile`] walk — tiles are bounded by segment edges, never
/// the 4-code word grid, so group boundaries straddling a pack word cost
/// only a byte-wise head/tail. Output left in [`ExecArena::yq`].
pub fn group_reuse_matmul_packed(
    x: &[i8],
    w: &PackedQuantMatrix,
    group: usize,
    chunk: usize,
    arena: &mut ExecArena,
) -> ExecStats {
    assert_eq!(x.len(), w.rows);
    assert!(chunk > 0);
    assert!(group > 0);
    let ExecArena {
        yq, products, tags, ..
    } = arena;
    yq.clear();
    yq.resize(w.cols, 0);
    let mut stats = ExecStats::default();
    for (i, &xi) in x.iter().enumerate() {
        fill_products(xi as i32, products);
        let words = w.row_words(i);
        let mut col = 0usize;
        while col < w.cols {
            let end = segment_end(col, chunk, group, w.cols);
            tags.next_epoch();
            let unique = packed_tile(words, col, end, products, tags, yq, 0);
            stats.mults += unique;
            stats.reuses += (end - col) as u64 - unique;
            col = end;
        }
    }
    stats
}

/// Group-scoped form of [`crate::exec::sharded_reuse_matmul_chunked`]:
/// each shard walks its column slice with its own [`EpochTags`] on the
/// **triple** intersection grid — global chunk multiples, group
/// multiples, and the shard edge. Shard segments therefore refine the
/// monolithic group segments exactly, keeping the sharding theorems
/// (ops column-additive, reuse only drops) intact under any regime.
pub fn sharded_group_reuse_matmul_chunked(
    x: &[i8],
    w: &QuantMatrix,
    group: usize,
    chunk: usize,
    shards: usize,
) -> (Vec<i32>, Vec<ExecStats>) {
    assert_eq!(x.len(), w.rows);
    assert!(chunk > 0);
    assert!(group > 0);
    let ranges = shard_ranges(w.cols, shards);
    let mut y = vec![0i32; w.cols];
    let mut per_shard = vec![ExecStats::default(); ranges.len()];
    let mut tags: Vec<EpochTags> = (0..ranges.len()).map(|_| EpochTags::new()).collect();
    let mut products = [0i32; 256];
    for (i, &xi) in x.iter().enumerate() {
        fill_products(xi as i32, &mut products);
        let row = w.row(i);
        for (s, range) in ranges.iter().enumerate() {
            let stats = &mut per_shard[s];
            let mut col = range.start;
            while col < range.end {
                let end = segment_end(col, chunk, group, range.end);
                tags[s].next_epoch();
                for (&wij, yj) in row[col..end].iter().zip(&mut y[col..end]) {
                    *yj += products[(wij as i32 + 127) as u8 as usize];
                }
                let mut unique = 0u64;
                for &wij in &row[col..end] {
                    unique += tags[s].first_occurrence(wij.unsigned_abs()) as u64;
                }
                stats.mults += unique;
                stats.reuses += (end - col) as u64 - unique;
                col = end;
            }
        }
    }
    (y, per_shard)
}

/// Group-scoped form of [`crate::exec::sharded_reuse_matmul_packed`]:
/// the packed/tiled sharded hot path on the triple grid, per-shard tags
/// persisted in the arena, counters **added** into `per_shard`, call
/// total returned, output in [`ExecArena::yq`].
pub fn sharded_group_reuse_matmul_packed(
    x: &[i8],
    w: &PackedQuantMatrix,
    group: usize,
    chunk: usize,
    shards: usize,
    per_shard: &mut [ExecStats],
    arena: &mut ExecArena,
) -> ExecStats {
    assert_eq!(x.len(), w.rows);
    assert!(chunk > 0);
    assert!(group > 0);
    let ranges = shard_ranges(w.cols, shards);
    assert_eq!(per_shard.len(), ranges.len());
    let ExecArena {
        yq,
        products,
        shard_tags,
        ..
    } = arena;
    yq.clear();
    yq.resize(w.cols, 0);
    if shard_tags.len() < ranges.len() {
        shard_tags.resize_with(ranges.len(), EpochTags::new);
    }
    let mut total = ExecStats::default();
    for (i, &xi) in x.iter().enumerate() {
        fill_products(xi as i32, products);
        let words = w.row_words(i);
        for (s, range) in ranges.iter().enumerate() {
            let mut col = range.start;
            while col < range.end {
                let end = segment_end(col, chunk, group, range.end);
                shard_tags[s].next_epoch();
                let unique = packed_tile(words, col, end, products, &mut shard_tags[s], yq, 0);
                per_shard[s].mults += unique;
                per_shard[s].reuses += (end - col) as u64 - unique;
                total.mults += unique;
                total.reuses += (end - col) as u64 - unique;
                col = end;
            }
        }
    }
    total
}

/// Group-scoped form of [`crate::exec::shard_accounting`]: the x-free
/// mult/reuse scan on the triple grid, scaled to `full_rows`. This is
/// what `SimBackend::with_quant_regime` measures — the RC split depends
/// only on codes and the epoch grid, never on the input vector.
pub fn group_accounting(
    w: &QuantMatrix,
    group: usize,
    chunk: usize,
    shards: usize,
    full_rows: u64,
) -> Vec<ExecStats> {
    assert!(chunk > 0);
    assert!(group > 0);
    let ranges = shard_ranges(w.cols, shards);
    let mut per_shard = vec![ExecStats::default(); ranges.len()];
    let mut tags: Vec<EpochTags> = (0..ranges.len()).map(|_| EpochTags::new()).collect();
    for i in 0..w.rows {
        let row = w.row(i);
        for (s, range) in ranges.iter().enumerate() {
            let stats = &mut per_shard[s];
            let mut col = range.start;
            while col < range.end {
                let end = segment_end(col, chunk, group, range.end);
                tags[s].next_epoch();
                let mut unique = 0u64;
                for &wij in &row[col..end] {
                    unique += tags[s].first_occurrence(wij.unsigned_abs()) as u64;
                }
                stats.mults += unique;
                stats.reuses += (end - col) as u64 - unique;
                col = end;
            }
        }
    }
    let sampled = w.rows.max(1) as u64;
    per_shard
        .into_iter()
        .map(|s| s.scaled(full_rows.max(sampled), sampled))
        .collect()
}

/// Float-in/float-out group-quantized matmul of one activation row:
/// fit a per-row activation grid, run the group-scoped RC kernel on the
/// code payload, and dequantize each output column with **its group's
/// scale** — the end-to-end fidelity path the round-trip property tests
/// bound per group.
pub fn group_matmul_f32(x: &[f32], w: &GroupQuantMatrix, chunk: usize) -> (Vec<f32>, ExecStats) {
    let params = QuantParams::fit(x, 8);
    let xq: Vec<i8> = x.iter().map(|&v| params.quantize(v)).collect();
    let (yq, stats) = group_reuse_matmul_chunked(&xq, &w.codes, w.group_size, chunk);
    let y = yq
        .iter()
        .enumerate()
        .map(|(j, &v)| v as f32 * params.scale * w.group_params[j / w.group_size].scale)
        .collect();
    (y, stats)
}

/// Group-regime route of `LayerExec`'s **scalar** matmul dispatch:
/// [`crate::exec::layer::qmatmul`]-family semantics (block-grid or
/// row-wise activation quantization, monolithic or sharded with
/// per-shard counters) with the group-scoped kernels underneath.
///
/// The weight codes stay on the model's per-tensor carrier grid
/// (`w.params`) — the functional regime re-scopes the Result Cache
/// without re-fitting, so logits are bit-identical to the per-tensor
/// run and only the mult/reuse split moves (pinned by
/// `tests/prop_quant_group.rs`).
#[allow(clippy::too_many_arguments)]
pub fn qmatmul_group(
    x: &[f32],
    seq: usize,
    w: &QuantMatrix,
    group: usize,
    chunk: usize,
    shards: usize,
    rowwise: bool,
    per_shard: &mut [ExecStats],
    stats: &mut ExecStats,
) -> Vec<f32> {
    let d = w.rows;
    assert_eq!(x.len(), seq * d);
    let block_params = if rowwise {
        None
    } else {
        Some(QuantParams::fit(x, 8))
    };
    let mut y = vec![0f32; seq * w.cols];
    for s in 0..seq {
        let row = &x[s * d..(s + 1) * d];
        let params = block_params.unwrap_or_else(|| QuantParams::fit(row, 8));
        let xq: Vec<i8> = row.iter().map(|&v| params.quantize(v)).collect();
        let scale = params.scale * w.params.scale;
        let yq = if shards <= 1 {
            let (yq, st) = group_reuse_matmul_chunked(&xq, w, group, chunk);
            stats.mults += st.mults;
            stats.reuses += st.reuses;
            yq
        } else {
            assert_eq!(per_shard.len(), shards);
            let (yq, per) = sharded_group_reuse_matmul_chunked(&xq, w, group, chunk, shards);
            for (acc, st) in per_shard.iter_mut().zip(&per) {
                acc.add(st);
                stats.add(st);
            }
            yq
        };
        for (yj, &v) in y[s * w.cols..(s + 1) * w.cols].iter_mut().zip(&yq) {
            *yj = v as f32 * scale;
        }
    }
    y
}

/// Group-regime route of `LayerExec`'s **packed** matmul dispatch: the
/// arena-backed hot path with group-scoped epochs, value-identical to
/// [`qmatmul_group`] in outputs and (per-shard) counters.
#[allow(clippy::too_many_arguments)]
pub fn qmatmul_group_packed(
    x: &[f32],
    seq: usize,
    w: &PackedQuantMatrix,
    group: usize,
    chunk: usize,
    shards: usize,
    rowwise: bool,
    per_shard: &mut [ExecStats],
    stats: &mut ExecStats,
    arena: &mut ExecArena,
) -> Vec<f32> {
    let d = w.rows;
    assert_eq!(x.len(), seq * d);
    let block_params = if rowwise {
        None
    } else {
        Some(QuantParams::fit(x, 8))
    };
    let mut y = vec![0f32; seq * w.cols];
    for s in 0..seq {
        let row = &x[s * d..(s + 1) * d];
        let params = match block_params {
            Some(p) => {
                arena.quantize_with(row, p);
                p
            }
            None => arena.quantize_into(row),
        };
        let scale = params.scale * w.params.scale;
        let xq = std::mem::take(&mut arena.xq);
        let st = if shards <= 1 {
            group_reuse_matmul_packed(&xq, w, group, chunk, arena)
        } else {
            assert_eq!(per_shard.len(), shards);
            sharded_group_reuse_matmul_packed(&xq, w, group, chunk, shards, per_shard, arena)
        };
        arena.xq = xq;
        stats.add(&st);
        for (yj, &v) in y[s * w.cols..(s + 1) * w.cols].iter_mut().zip(&arena.yq) {
            *yj = v as f32 * scale;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{
        dense_matmul, reuse_matmul_chunked, reuse_matmul_packed, sharded_reuse_matmul_chunked,
    };
    use crate::model::synth::{synthesize_floats, synthesize_matrix, WeightDistribution};
    use crate::util::rng::Rng;

    fn case(rows: usize, cols: usize, seed: u64) -> (Vec<i8>, QuantMatrix) {
        let mut rng = Rng::new(seed);
        let w = synthesize_matrix(rows, cols, WeightDistribution::default(), &mut rng);
        let x: Vec<i8> = (0..rows).map(|_| rng.range_i64(-127, 127) as i8).collect();
        (x, w)
    }

    #[test]
    fn whole_tensor_group_is_bit_identical_to_per_tensor() {
        let (x, w) = case(24, 200, 41);
        for chunk in [7usize, 64, 200] {
            let (y0, s0) = reuse_matmul_chunked(&x, &w, chunk);
            for group in [200usize, 201, 4096, usize::MAX] {
                let (y, s) = group_reuse_matmul_chunked(&x, &w, group, chunk);
                assert_eq!(y, y0, "chunk={chunk} group={group}");
                assert_eq!(s, s0, "chunk={chunk} group={group}");
            }
        }
    }

    #[test]
    fn group_segments_preserve_values_and_only_lose_reuse() {
        let (x, w) = case(16, 256, 42);
        let dense = dense_matmul(&x, &w);
        let chunk = 128;
        let (_, mono) = reuse_matmul_chunked(&x, &w, chunk);
        let mut prev_mults = mono.mults;
        for group in [128usize, 64, 16, 5, 1] {
            let (y, s) = group_reuse_matmul_chunked(&x, &w, group, chunk);
            assert_eq!(y, dense, "group={group}");
            assert_eq!(s.mults + s.reuses, mono.mults + mono.reuses, "group={group}");
            // Nested widths refine the epoch grid → mults monotone up.
            assert!(s.mults >= prev_mults, "group={group}: {} < {prev_mults}", s.mults);
            prev_mults = s.mults;
        }
        // group=1 → every element is a first occurrence.
        let (_, s1) = group_reuse_matmul_chunked(&x, &w, 1, chunk);
        assert_eq!(s1.mults, (w.rows * w.cols) as u64);
        assert_eq!(s1.reuses, 0);
    }

    #[test]
    fn packed_group_kernel_matches_scalar_group_kernel() {
        let (x, w) = case(20, 130, 43);
        let packed = w.packed();
        let mut arena = ExecArena::new();
        // Groups straddling the 4-code pack word and ragged tails.
        for group in [1usize, 2, 3, 5, 7, 13, 64, 130, usize::MAX] {
            for chunk in [3usize, 7, 64, 130] {
                let (y, s) = group_reuse_matmul_chunked(&x, &w, group, chunk);
                let sp = group_reuse_matmul_packed(&x, &packed, group, chunk, &mut arena);
                assert_eq!(arena.yq(), &y[..], "group={group} chunk={chunk}");
                assert_eq!(sp, s, "group={group} chunk={chunk}");
            }
        }
    }

    #[test]
    fn packed_group_degenerates_to_packed_per_tensor() {
        let (x, w) = case(12, 96, 44);
        let packed = w.packed();
        let mut a0 = ExecArena::new();
        let mut a1 = ExecArena::new();
        let s0 = reuse_matmul_packed(&x, &packed, 32, &mut a0);
        let s1 = group_reuse_matmul_packed(&x, &packed, 96, 32, &mut a1);
        assert_eq!(a1.yq(), a0.yq());
        assert_eq!(s1, s0);
    }

    #[test]
    fn sharded_group_kernels_agree_and_refine() {
        let (x, w) = case(16, 300, 45);
        let chunk = 128;
        for group in [300usize, 48, 10] {
            let (y_mono, mono) = group_reuse_matmul_chunked(&x, &w, group, chunk);
            for shards in [1usize, 2, 4] {
                let (y, per) = sharded_group_reuse_matmul_chunked(&x, &w, group, chunk, shards);
                assert_eq!(y, y_mono, "group={group} shards={shards}");
                let ops: u64 = per.iter().map(|s| s.mults + s.reuses).sum();
                assert_eq!(ops, mono.mults + mono.reuses);
                let mults: u64 = per.iter().map(|s| s.mults).sum();
                assert!(mults >= mono.mults, "sharding only loses reuse");
                // Packed sharded agrees in values and per-shard counters.
                let mut arena = ExecArena::new();
                let mut acc = vec![ExecStats::default(); shards];
                let total = sharded_group_reuse_matmul_packed(
                    &x, &w.packed(), group, chunk, shards, &mut acc, &mut arena,
                );
                assert_eq!(arena.yq(), &y[..]);
                assert_eq!(acc, per);
                assert_eq!(total.mults, mults);
            }
        }
        // Per-tensor-width group matches the seed sharded kernel exactly.
        let (y_seed, per_seed) = sharded_reuse_matmul_chunked(&x, &w, chunk, 4);
        let (y_g, per_g) = sharded_group_reuse_matmul_chunked(&x, &w, usize::MAX, chunk, 4);
        assert_eq!(y_g, y_seed);
        assert_eq!(per_g, per_seed);
    }

    #[test]
    fn accounting_matches_the_executing_kernel() {
        let (x, w) = case(20, 260, 46);
        for (group, shards) in [(260usize, 1usize), (32, 1), (32, 2), (9, 4)] {
            let (_, per_exec) = sharded_group_reuse_matmul_chunked(&x, &w, group, 64, shards);
            let per_scan = group_accounting(&w, group, 64, shards, w.rows as u64);
            assert_eq!(per_scan, per_exec, "group={group} shards={shards}");
        }
        // And scaling extrapolates ops linearly.
        let per = group_accounting(&w, 32, 64, 1, (w.rows * 3) as u64);
        let ops: u64 = per.iter().map(|s| s.mults + s.reuses).sum();
        assert_eq!(ops, (w.rows * w.cols * 3) as u64);
    }

    #[test]
    fn group_matmul_f32_tracks_the_float_product_per_group() {
        let mut rng = Rng::new(47);
        let (rows, cols) = (48, 96);
        let wf = synthesize_floats(rows, cols, WeightDistribution::default(), &mut rng);
        let gq = GroupQuantMatrix::fit(rows, cols, &wf, 8, 16);
        let x: Vec<f32> = (0..rows).map(|_| rng.normal() as f32 * 0.1).collect();
        let (y, stats) = group_matmul_f32(&x, &gq, 64);
        assert_eq!(stats.mults + stats.reuses, (rows * cols) as u64);
        // Float reference.
        let mut y_ref = vec![0f32; cols];
        for (i, &xi) in x.iter().enumerate() {
            for j in 0..cols {
                y_ref[j] += xi * wf[i * cols + j];
            }
        }
        // Two int8 grids: tolerance scales with the row norms.
        let tol = 0.05 * x.iter().map(|v| v.abs()).sum::<f32>().max(1.0);
        for (j, (&a, &b)) in y.iter().zip(&y_ref).enumerate() {
            assert!((a - b).abs() <= tol, "col {j}: {a} vs {b} (tol {tol})");
        }
    }

    #[test]
    fn qmatmul_group_wrappers_agree_scalar_vs_packed() {
        let mut rng = Rng::new(48);
        let (rows, cols, seq) = (64, 80, 3);
        let w = synthesize_matrix(rows, cols, WeightDistribution::default(), &mut rng);
        let packed = w.packed();
        let x: Vec<f32> = (0..seq * rows).map(|_| rng.normal() as f32 * 0.1).collect();
        for shards in [1usize, 2, 4] {
            for rowwise in [false, true] {
                for group in [80usize, 24, 7] {
                    let n = shards.max(1);
                    let mut st_s = ExecStats::default();
                    let mut per_s = vec![ExecStats::default(); n];
                    let y_s = qmatmul_group(
                        &x, seq, &w, group, 32, shards, rowwise, &mut per_s, &mut st_s,
                    );
                    let mut st_p = ExecStats::default();
                    let mut per_p = vec![ExecStats::default(); n];
                    let mut arena = ExecArena::new();
                    let y_p = qmatmul_group_packed(
                        &x, seq, &packed, group, 32, shards, rowwise, &mut per_p, &mut st_p,
                        &mut arena,
                    );
                    assert_eq!(y_s, y_p, "shards={shards} rowwise={rowwise} group={group}");
                    assert_eq!(st_s, st_p);
                    if shards > 1 {
                        assert_eq!(per_s, per_p);
                    }
                }
            }
        }
    }

    #[test]
    fn empty_and_single_column_shapes() {
        let (x, w) = case(6, 0, 49);
        let (y, s) = group_reuse_matmul_chunked(&x, &w, 4, 8);
        assert!(y.is_empty());
        assert_eq!(s, ExecStats::default());
        let (x1, w1) = case(6, 1, 50);
        let (y1, s1) = group_reuse_matmul_chunked(&x1, &w1, 1, 8);
        assert_eq!(y1, dense_matmul(&x1, &w1));
        assert_eq!(s1.mults + s1.reuses, 6);
    }
}
