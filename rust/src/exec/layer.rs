//! Full transformer-layer forward pass on the reuse datapath.
//!
//! Runs multi-head self-attention + feed-forward in f32 activations with
//! every weight matmul executed through [`reuse_matmul_chunked`] on the
//! quantized weights (int8 codes, per-tensor scales) — the computation the
//! accelerator performs, expressed functionally. Used by the Rust-side
//! end-to-end examples and cross-checked against the JAX/Pallas artifact
//! in the integration tests.

use crate::config::ModelConfig;
use crate::exec::group::{qmatmul_group, qmatmul_group_packed};
use crate::exec::{
    fill_products, packed_tile, reuse_matmul_chunked, reuse_matmul_packed, shard_ranges,
    sharded_reuse_matmul_chunked, sharded_reuse_matmul_packed, EpochTags, ExecArena, ExecStats,
};
use crate::model::LayerWeights;
use crate::model::MatKind;
use crate::quant::{PackedQuantMatrix, QuantMatrix, QuantParams};
use crate::util::pool::par_map;

/// Minimum `seq × cols` element count before a sharded matmul fans its
/// shards out across worker threads — below this (decode-sized calls) the
/// spawn/join overhead outweighs the work and the arena-backed sequential
/// kernel wins.
const PAR_MIN_ELEMS: usize = 32_768;

/// Row-wise softmax over a `rows×cols` matrix (in place).
pub fn softmax_rows(m: &mut [f32], rows: usize, cols: usize) {
    assert_eq!(m.len(), rows * cols);
    for r in 0..rows {
        let row = &mut m[r * cols..(r + 1) * cols];
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

fn layer_norm(m: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &mut m[r * cols..(r + 1) * cols];
        let mean = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for v in row.iter_mut() {
            *v = (*v - mean) * inv;
        }
    }
}

/// Quantized matmul of f32 activations against a quantized weight matrix
/// through the reuse path: `Y[s,:] = dequant(quant(X[s,:]) · W)`.
///
/// Activations are quantized per call on a shared symmetric grid (the
/// accelerator's int8 input datapath); `stats` accumulates reuse counters.
pub fn qmatmul(
    x: &[f32],
    seq: usize,
    w: &QuantMatrix,
    chunk: usize,
    stats: &mut ExecStats,
) -> Vec<f32> {
    let d = w.rows;
    assert_eq!(x.len(), seq * d);
    let xq_params = QuantParams::fit(x, 8);
    let mut y = vec![0f32; seq * w.cols];
    let scale = xq_params.scale * w.params.scale;
    for s in 0..seq {
        let row = &x[s * d..(s + 1) * d];
        let xq: Vec<i8> = row.iter().map(|&v| xq_params.quantize(v)).collect();
        let (yq, st) = reuse_matmul_chunked(&xq, w, chunk);
        stats.mults += st.mults;
        stats.reuses += st.reuses;
        for (j, &v) in yq.iter().enumerate() {
            y[s * w.cols + j] = v as f32 * scale;
        }
    }
    y
}

/// Fit a per-row int8 activation grid and quantize one row — the shared
/// input-side step of [`qmatmul_rowwise`] and the LoRA head path
/// (`FunctionalBackend::head_logits_for`). One implementation, so the
/// adapter side pipeline provably consumes the **same** quantized input
/// (and grid) as the base pipeline it rides next to.
pub fn quantize_row(row: &[f32]) -> (Vec<i8>, QuantParams) {
    let params = QuantParams::fit(row, 8);
    (row.iter().map(|&v| params.quantize(v)).collect(), params)
}

/// Row-wise-quantized matmul through the reuse path: like [`qmatmul`],
/// but the activation grid is fit per sequence position instead of per
/// block, so each output row depends only on its own input row.
///
/// This is the property KV-cached decode needs: a position's K/V (and
/// downstream logits) are bit-identical whether the position is processed
/// alone (one decode step) or as part of a longer block (prefill or full
/// recompute). Per-token dynamic activation grids are also the standard
/// practical choice for int8 serving datapaths.
pub fn qmatmul_rowwise(
    x: &[f32],
    seq: usize,
    w: &QuantMatrix,
    chunk: usize,
    stats: &mut ExecStats,
) -> Vec<f32> {
    let d = w.rows;
    assert_eq!(x.len(), seq * d);
    let mut y = vec![0f32; seq * w.cols];
    for s in 0..seq {
        let row = &x[s * d..(s + 1) * d];
        let (xq, xq_params) = quantize_row(row);
        let scale = xq_params.scale * w.params.scale;
        let (yq, st) = reuse_matmul_chunked(&xq, w, chunk);
        stats.mults += st.mults;
        stats.reuses += st.reuses;
        for (yj, &v) in y[s * w.cols..(s + 1) * w.cols].iter_mut().zip(&yq) {
            *yj = v as f32 * scale;
        }
    }
    y
}

/// Column-sharded [`qmatmul`]: identical block-grid quantization and
/// bit-identical output, with each shard's Result-Cache accounting kept
/// separately in `per_shard` (one entry per shard) and the total in
/// `stats` — the tensor-parallel serving path of the reuse datapath.
pub fn qmatmul_sharded(
    x: &[f32],
    seq: usize,
    w: &QuantMatrix,
    chunk: usize,
    shards: usize,
    per_shard: &mut [ExecStats],
    stats: &mut ExecStats,
) -> Vec<f32> {
    let d = w.rows;
    assert_eq!(x.len(), seq * d);
    assert_eq!(per_shard.len(), shards.max(1));
    let xq_params = QuantParams::fit(x, 8);
    let mut y = vec![0f32; seq * w.cols];
    let scale = xq_params.scale * w.params.scale;
    for s in 0..seq {
        let row = &x[s * d..(s + 1) * d];
        let xq: Vec<i8> = row.iter().map(|&v| xq_params.quantize(v)).collect();
        let (yq, per) = sharded_reuse_matmul_chunked(&xq, w, chunk, shards);
        for (acc, st) in per_shard.iter_mut().zip(&per) {
            acc.add(st);
            stats.add(st);
        }
        for (yj, &v) in y[s * w.cols..(s + 1) * w.cols].iter_mut().zip(&yq) {
            *yj = v as f32 * scale;
        }
    }
    y
}

/// Column-sharded [`qmatmul_rowwise`]: identical per-row quantization and
/// bit-identical output, with per-shard Result-Cache accounting (see
/// [`qmatmul_sharded`]). This is the kernel KV-cached decode shards with.
pub fn qmatmul_rowwise_sharded(
    x: &[f32],
    seq: usize,
    w: &QuantMatrix,
    chunk: usize,
    shards: usize,
    per_shard: &mut [ExecStats],
    stats: &mut ExecStats,
) -> Vec<f32> {
    let d = w.rows;
    assert_eq!(x.len(), seq * d);
    assert_eq!(per_shard.len(), shards.max(1));
    let mut y = vec![0f32; seq * w.cols];
    for s in 0..seq {
        let row = &x[s * d..(s + 1) * d];
        let (xq, xq_params) = quantize_row(row);
        let scale = xq_params.scale * w.params.scale;
        let (yq, per) = sharded_reuse_matmul_chunked(&xq, w, chunk, shards);
        for (acc, st) in per_shard.iter_mut().zip(&per) {
            acc.add(st);
            stats.add(st);
        }
        for (yj, &v) in y[s * w.cols..(s + 1) * w.cols].iter_mut().zip(&yq) {
            *yj = v as f32 * scale;
        }
    }
    y
}

/// Packed-kernel form of [`qmatmul`] (`rowwise = false`, block activation
/// grid) and [`qmatmul_rowwise`] (`rowwise = true`, per-row grids):
/// identical quantization grids, bit-identical output and counters, with
/// every piece of kernel scratch drawn from `arena` — the per-row `xq`
/// and `yq` `Vec` allocations of the scalar kernels disappear.
fn qmatmul_packed(
    x: &[f32],
    seq: usize,
    w: &PackedQuantMatrix,
    chunk: usize,
    rowwise: bool,
    stats: &mut ExecStats,
    arena: &mut ExecArena,
) -> Vec<f32> {
    let d = w.rows;
    assert_eq!(x.len(), seq * d);
    let block_params = if rowwise {
        None
    } else {
        Some(QuantParams::fit(x, 8))
    };
    let mut y = vec![0f32; seq * w.cols];
    for s in 0..seq {
        let row = &x[s * d..(s + 1) * d];
        let params = match block_params {
            Some(p) => {
                arena.quantize_with(row, p);
                p
            }
            None => arena.quantize_into(row),
        };
        let scale = params.scale * w.params.scale;
        // The quantized row moves out of the arena for the kernel call
        // (the kernel borrows the rest of the arena mutably) and back in
        // afterwards — a pointer swap, not a copy.
        let xq = std::mem::take(&mut arena.xq);
        let st = reuse_matmul_packed(&xq, w, chunk, arena);
        arena.xq = xq;
        stats.mults += st.mults;
        stats.reuses += st.reuses;
        for (yj, &v) in y[s * w.cols..(s + 1) * w.cols].iter_mut().zip(&arena.yq) {
            *yj = v as f32 * scale;
        }
    }
    y
}

/// Packed-kernel form of [`qmatmul_sharded`] / [`qmatmul_rowwise_sharded`]
/// with per-shard accounting. Prefill-scale calls (`seq × cols ≥`
/// [`PAR_MIN_ELEMS`]) fan the shards out across worker threads via
/// [`par_map`]; smaller calls run the arena-backed sequential kernel.
/// Both are bit-identical to the scalar sharded kernels in values and
/// per-shard counters.
#[allow(clippy::too_many_arguments)]
fn qmatmul_sharded_packed(
    x: &[f32],
    seq: usize,
    w: &PackedQuantMatrix,
    chunk: usize,
    shards: usize,
    rowwise: bool,
    per_shard: &mut [ExecStats],
    stats: &mut ExecStats,
    arena: &mut ExecArena,
) -> Vec<f32> {
    let d = w.rows;
    assert_eq!(x.len(), seq * d);
    assert_eq!(per_shard.len(), shards.max(1));
    if shards > 1 && seq * w.cols >= PAR_MIN_ELEMS {
        return qmatmul_sharded_packed_par(x, seq, w, chunk, shards, rowwise, per_shard, stats);
    }
    let block_params = if rowwise {
        None
    } else {
        Some(QuantParams::fit(x, 8))
    };
    let mut y = vec![0f32; seq * w.cols];
    for s in 0..seq {
        let row = &x[s * d..(s + 1) * d];
        let params = match block_params {
            Some(p) => {
                arena.quantize_with(row, p);
                p
            }
            None => arena.quantize_into(row),
        };
        let scale = params.scale * w.params.scale;
        let xq = std::mem::take(&mut arena.xq);
        let st = sharded_reuse_matmul_packed(&xq, w, chunk, shards, per_shard, arena);
        arena.xq = xq;
        stats.add(&st);
        for (yj, &v) in y[s * w.cols..(s + 1) * w.cols].iter_mut().zip(&arena.yq) {
            *yj = v as f32 * scale;
        }
    }
    y
}

/// Thread-parallel shard fan-out: every sequence row is quantized up
/// front (on exactly the grids the sequential path uses), then each shard
/// runs as one [`par_map`] task owning its own product table, epoch tags,
/// and output slab. The merge is deterministic — slabs and counters are
/// stitched in shard order, so values and per-shard accounting are
/// independent of worker scheduling (the deterministic-merge invariant of
/// `rust/DESIGN.md`).
#[allow(clippy::too_many_arguments)]
fn qmatmul_sharded_packed_par(
    x: &[f32],
    seq: usize,
    w: &PackedQuantMatrix,
    chunk: usize,
    shards: usize,
    rowwise: bool,
    per_shard: &mut [ExecStats],
    stats: &mut ExecStats,
) -> Vec<f32> {
    let d = w.rows;
    let block_params = if rowwise {
        None
    } else {
        Some(QuantParams::fit(x, 8))
    };
    let mut xq_all = vec![0i8; seq * d];
    let mut scales = vec![0f32; seq];
    for s in 0..seq {
        let row = &x[s * d..(s + 1) * d];
        let params = block_params.unwrap_or_else(|| QuantParams::fit(row, 8));
        for (q, &v) in xq_all[s * d..(s + 1) * d].iter_mut().zip(row) {
            *q = params.quantize(v);
        }
        scales[s] = params.scale * w.params.scale;
    }
    let ranges = shard_ranges(w.cols, shards);
    let xq_all = &xq_all;
    let slabs = par_map(ranges.clone(), |range| {
        let width = range.end - range.start;
        let mut slab = vec![0i32; seq * width];
        let mut tags = EpochTags::new();
        let mut products = [0i32; 256];
        let mut st = ExecStats::default();
        for s in 0..seq {
            let xq = &xq_all[s * d..(s + 1) * d];
            let yrow = &mut slab[s * width..(s + 1) * width];
            for (i, &xi) in xq.iter().enumerate() {
                fill_products(xi as i32, &mut products);
                let words = w.row_words(i);
                let mut col = range.start;
                while col < range.end {
                    // Global-grid chunking, as in the sequential kernels.
                    let end = ((col / chunk + 1) * chunk).min(range.end);
                    tags.next_epoch();
                    let unique =
                        packed_tile(words, col, end, &products, &mut tags, yrow, range.start);
                    st.mults += unique;
                    st.reuses += (end - col) as u64 - unique;
                    col = end;
                }
            }
        }
        (slab, st)
    });
    let mut y = vec![0f32; seq * w.cols];
    for ((range, (slab, st)), acc) in ranges.iter().zip(&slabs).zip(per_shard.iter_mut()) {
        acc.add(st);
        stats.add(st);
        let width = range.end - range.start;
        for s in 0..seq {
            let dst = &mut y[s * w.cols + range.start..s * w.cols + range.end];
            for (yj, &v) in dst.iter_mut().zip(&slab[s * width..(s + 1) * width]) {
                *yj = v as f32 * scales[s];
            }
        }
    }
    y
}

/// Route one layer matmul to the right kernel: scalar reference kernels
/// (the seed path — bench baseline and property-suite oracle) or the
/// packed/tiled arena path, monolithic or sharded, block-grid or row-wise
/// activation quantization. All routes are bit-identical in values and
/// counters.
///
/// A `group > 0` width routes through the group-scoped kernels of
/// [`crate::exec::group`]: the Result Cache re-opens at every
/// `group`-column scale boundary. Outputs stay bit-identical to the
/// per-tensor routes (the codes keep the model's carrier grid — see
/// [`qmatmul_group`]); only the mult/reuse split moves.
#[allow(clippy::too_many_arguments)]
fn matmul_dispatch(
    x: &[f32],
    seq: usize,
    weights: &LayerWeights,
    kind: MatKind,
    group: usize,
    chunk: usize,
    shards: usize,
    scalar: bool,
    rowwise: bool,
    stats: &mut ExecStats,
    shard_stats: &mut [ExecStats],
    arena: &mut ExecArena,
) -> Vec<f32> {
    if group > 0 {
        return if scalar {
            let w = weights.get(kind);
            qmatmul_group(x, seq, w, group, chunk, shards, rowwise, shard_stats, stats)
        } else {
            let w = weights.get_packed(kind);
            qmatmul_group_packed(
                x,
                seq,
                w,
                group,
                chunk,
                shards,
                rowwise,
                shard_stats,
                stats,
                arena,
            )
        };
    }
    if scalar {
        let w = weights.get(kind);
        match (shards <= 1, rowwise) {
            (true, false) => qmatmul(x, seq, w, chunk, stats),
            (true, true) => qmatmul_rowwise(x, seq, w, chunk, stats),
            (false, false) => qmatmul_sharded(x, seq, w, chunk, shards, shard_stats, stats),
            (false, true) => qmatmul_rowwise_sharded(x, seq, w, chunk, shards, shard_stats, stats),
        }
    } else {
        let w = weights.get_packed(kind);
        if shards <= 1 {
            qmatmul_packed(x, seq, w, chunk, rowwise, stats, arena)
        } else {
            qmatmul_sharded_packed(x, seq, w, chunk, shards, rowwise, shard_stats, stats, arena)
        }
    }
}

/// One layer's K/V cache for causal autoregressive decode: the keys and
/// values of every position processed so far, `len × d_model` row-major.
#[derive(Clone, Debug, Default)]
pub struct LayerKv {
    k: Vec<f32>,
    v: Vec<f32>,
    len: usize,
}

impl LayerKv {
    /// Fresh, empty cache.
    pub fn new() -> LayerKv {
        LayerKv::default()
    }

    /// Cached positions.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no positions are cached yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// A copy truncated to the first `n` cached positions — the prefix
    /// snapshot the cross-request KV cache stores at block boundaries.
    /// Causal attention makes this exact: position `t`'s K/V rows depend
    /// only on positions `≤ t`, so a truncated cache is bit-identical to
    /// one built by processing only those `n` positions.
    pub fn truncated(&self, n: usize) -> LayerKv {
        let n = n.min(self.len);
        let d = if self.len == 0 { 0 } else { self.k.len() / self.len };
        LayerKv {
            k: self.k[..n * d].to_vec(),
            v: self.v[..n * d].to_vec(),
            len: n,
        }
    }
}

/// One transformer layer bound to its quantized weights.
pub struct LayerExec<'a> {
    /// Model shape the layer belongs to.
    pub cfg: &'a ModelConfig,
    /// The layer's quantized weight matrices.
    pub weights: &'a LayerWeights,
    /// RC chunk bound (W_buff size).
    pub chunk: usize,
    /// Reuse counters accumulated across forward passes (total over all
    /// shards when sharded).
    pub stats: ExecStats,
    /// Tensor-parallel shards every weight matmul splits across (1 =
    /// monolithic execution).
    shards: usize,
    /// Per-shard reuse counters (empty when unsharded; one entry per
    /// shard otherwise — each shard owns an independent Result Cache).
    pub shard_stats: Vec<ExecStats>,
    /// Scratch arena the packed kernels draw from (recycled across
    /// forward passes and, via [`LayerExec::into_arena`], across layers).
    arena: ExecArena,
    /// Route matmuls through the seed scalar reference kernels instead of
    /// the packed/tiled arena path (bit-identical either way).
    scalar: bool,
    /// Column-group width of the active quantization regime (`0` =
    /// per-tensor, the default): when set, every weight matmul runs the
    /// group-scoped reuse kernels (RC re-opens at group boundaries).
    group: usize,
}

impl<'a> LayerExec<'a> {
    /// Bind a layer executor to a model shape and weight set.
    pub fn new(cfg: &'a ModelConfig, weights: &'a LayerWeights, chunk: usize) -> Self {
        LayerExec {
            cfg,
            weights,
            chunk,
            stats: ExecStats::default(),
            shards: 1,
            shard_stats: Vec::new(),
            arena: ExecArena::new(),
            scalar: false,
            group: 0,
        }
    }

    /// Adopt a caller-supplied scratch arena (one recycled across layers
    /// by a backend, its buffers already grown to steady-state sizes);
    /// pairs with [`LayerExec::into_arena`].
    pub fn with_arena(mut self, arena: ExecArena) -> Self {
        self.arena = arena;
        self
    }

    /// Surrender the scratch arena so the next layer can reuse it.
    pub fn into_arena(self) -> ExecArena {
        self.arena
    }

    /// Route every matmul through the seed scalar reference kernels
    /// (allocation-heavy, never thread-parallel) instead of the
    /// packed/tiled path. Outputs and counters are bit-identical either
    /// way — this exists as the honest baseline for
    /// `benches/functional_hot_loop.rs` and as the oracle for
    /// `tests/prop_packed.rs`.
    pub fn with_scalar(mut self, scalar: bool) -> Self {
        self.scalar = scalar;
        self
    }

    /// Split every weight matmul column-wise across `n` shards, each with
    /// its own Result Cache. Outputs stay bit-identical (column sharding
    /// is a scheduling transformation); [`LayerExec::shard_stats`] then
    /// carries one counter record per shard.
    pub fn with_shards(mut self, n: usize) -> Self {
        self.shards = n.max(1);
        self.shard_stats = if self.shards > 1 {
            vec![ExecStats::default(); self.shards]
        } else {
            Vec::new()
        };
        self
    }

    /// Scope the Result Cache to `group`-column scale groups (the
    /// group-wise quantization regime of [`crate::quant::QuantRegime`]):
    /// every weight matmul re-opens its cache at each group boundary, so
    /// reuse cannot cross a scale change. `0` restores the per-tensor
    /// default. Outputs stay bit-identical across settings — the regime
    /// re-scopes accounting, not values.
    pub fn with_quant_group(mut self, group: usize) -> Self {
        self.group = group;
        self
    }

    /// Forward one sequence (`seq × d_model`, row-major) through
    /// attention + FFN with residuals and layer norm (post-LN).
    pub fn forward(&mut self, x: &[f32], seq: usize) -> Vec<f32> {
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = self.cfg.d_head();
        assert_eq!(x.len(), seq * d);
        // Split borrows: the weight references must stay live across the
        // stat-accumulating matmul closure. The arena stays outside the
        // closure (passed per call) so the attention section can draw its
        // score scratch from it between matmuls.
        let (chunk, shards, scalar) = (self.chunk, self.shards, self.scalar);
        let group = self.group;
        let weights = self.weights;
        let stats = &mut self.stats;
        let shard_stats = &mut self.shard_stats;
        let arena = &mut self.arena;
        let mut qm = |x: &[f32], seq: usize, kind: MatKind, arena: &mut ExecArena| {
            matmul_dispatch(
                x,
                seq,
                weights,
                kind,
                group,
                chunk,
                shards,
                scalar,
                false,
                stats,
                shard_stats,
                arena,
            )
        };

        let q = qm(x, seq, MatKind::Wq, &mut *arena);
        let k = qm(x, seq, MatKind::Wk, &mut *arena);
        let v = qm(x, seq, MatKind::Wv, &mut *arena);

        // Per-head scaled dot-product attention.
        let mut ctx = vec![0f32; seq * d];
        let scale = 1.0 / (dh as f32).sqrt();
        for head in 0..h {
            let off = head * dh;
            arena.scores.clear();
            arena.scores.resize(seq * seq, 0.0);
            for i in 0..seq {
                for j in 0..seq {
                    let mut s = 0f32;
                    for t in 0..dh {
                        s += q[i * d + off + t] * k[j * d + off + t];
                    }
                    arena.scores[i * seq + j] = s * scale;
                }
            }
            softmax_rows(&mut arena.scores, seq, seq);
            for i in 0..seq {
                for j in 0..seq {
                    let a = arena.scores[i * seq + j];
                    for t in 0..dh {
                        ctx[i * d + off + t] += a * v[j * d + off + t];
                    }
                }
            }
        }

        let attn_out = qm(&ctx, seq, MatKind::Wo, &mut *arena);

        // Residual + LN.
        let mut h1: Vec<f32> = x.iter().zip(&attn_out).map(|(a, b)| a + b).collect();
        layer_norm(&mut h1, seq, d);

        // FFN: relu(h1·W1)·W2.
        let mut ff = qm(&h1, seq, MatKind::Ff1, &mut *arena);
        for v in ff.iter_mut() {
            *v = v.max(0.0);
        }
        let ff2 = qm(&ff, seq, MatKind::Ff2, &mut *arena);

        let mut out: Vec<f32> = h1.iter().zip(&ff2).map(|(a, b)| a + b).collect();
        layer_norm(&mut out, seq, d);
        out
    }

    /// Causal incremental forward: process `n_new` new positions given
    /// `kv` holding this layer's K/V for every earlier position, and
    /// append the new positions' K/V to the cache.
    ///
    /// Every matmul is row-wise-quantized ([`qmatmul_rowwise`]) and
    /// attention is causal (position p attends to 0..=p), so each output
    /// row depends only on its own position and the immutable cache
    /// prefix. Consequence, pinned by `rust/tests/prop_decode.rs`:
    /// prefill-then-N-decode-steps is **bit-identical** to one causal
    /// pass over the full extended sequence — the KV cache is a pure
    /// scheduling transformation, exactly like the Result Cache itself.
    pub fn forward_causal(&mut self, x_new: &[f32], n_new: usize, kv: &mut LayerKv) -> Vec<f32> {
        let d = self.cfg.d_model;
        let h = self.cfg.n_heads;
        let dh = self.cfg.d_head();
        assert_eq!(x_new.len(), n_new * d);
        let p0 = kv.len;
        // Split borrows, as in [`LayerExec::forward`]; the arena is
        // passed per call so the causal attention loop can draw its
        // score scratch from it between matmuls.
        let (chunk, shards, scalar) = (self.chunk, self.shards, self.scalar);
        let group = self.group;
        let weights = self.weights;
        let stats = &mut self.stats;
        let shard_stats = &mut self.shard_stats;
        let arena = &mut self.arena;
        let mut qm = |x: &[f32], seq: usize, kind: MatKind, arena: &mut ExecArena| {
            matmul_dispatch(
                x,
                seq,
                weights,
                kind,
                group,
                chunk,
                shards,
                scalar,
                true,
                stats,
                shard_stats,
                arena,
            )
        };

        let q = qm(x_new, n_new, MatKind::Wq, &mut *arena);
        let k_new = qm(x_new, n_new, MatKind::Wk, &mut *arena);
        let v_new = qm(x_new, n_new, MatKind::Wv, &mut *arena);
        kv.k.extend_from_slice(&k_new);
        kv.v.extend_from_slice(&v_new);
        kv.len += n_new;

        // Causal attention of each new position over the cache prefix
        // (which now includes the new positions themselves).
        let mut ctx = vec![0f32; n_new * d];
        let scale = 1.0 / (dh as f32).sqrt();
        for t in 0..n_new {
            let span = p0 + t + 1;
            for head in 0..h {
                let off = head * dh;
                arena.scores.clear();
                arena.scores.resize(span, 0.0);
                for (j, sc) in arena.scores.iter_mut().enumerate() {
                    let mut s = 0f32;
                    for u in 0..dh {
                        s += q[t * d + off + u] * kv.k[j * d + off + u];
                    }
                    *sc = s * scale;
                }
                softmax_rows(&mut arena.scores, 1, span);
                for (j, &a) in arena.scores.iter().enumerate() {
                    for u in 0..dh {
                        ctx[t * d + off + u] += a * kv.v[j * d + off + u];
                    }
                }
            }
        }

        let attn_out = qm(&ctx, n_new, MatKind::Wo, &mut *arena);

        // Residual + LN, then the FFN — all row-local.
        let mut h1: Vec<f32> = x_new.iter().zip(&attn_out).map(|(a, b)| a + b).collect();
        layer_norm(&mut h1, n_new, d);

        let mut ff = qm(&h1, n_new, MatKind::Ff1, &mut *arena);
        for v in ff.iter_mut() {
            *v = v.max(0.0);
        }
        let ff2 = qm(&ff, n_new, MatKind::Ff2, &mut *arena);

        let mut out: Vec<f32> = h1.iter().zip(&ff2).map(|(a, b)| a + b).collect();
        layer_norm(&mut out, n_new, d);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Model;
    use crate::workload::synth_embeddings;

    fn tiny() -> (ModelConfig, LayerWeights) {
        let cfg = ModelConfig::tiny();
        let model = Model::new(cfg.clone(), 3);
        let w = model.layer(0);
        (cfg, w)
    }

    #[test]
    fn softmax_rows_normalize() {
        let mut m = vec![1.0f32, 2.0, 3.0, -1.0, 0.0, 1.0];
        softmax_rows(&mut m, 2, 3);
        for r in 0..2 {
            let s: f32 = m[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
            assert!(m[r * 3..(r + 1) * 3].iter().all(|&v| v > 0.0));
        }
        // Monotone in the logits.
        assert!(m[2] > m[1] && m[1] > m[0]);
    }

    #[test]
    fn forward_shape_and_determinism() {
        let (cfg, w) = tiny();
        let seq = 6;
        let x = synth_embeddings(seq, cfg.d_model, 42);
        let mut l1 = LayerExec::new(&cfg, &w, 256);
        let mut l2 = LayerExec::new(&cfg, &w, 256);
        let y1 = l1.forward(&x, seq);
        let y2 = l2.forward(&x, seq);
        assert_eq!(y1.len(), seq * cfg.d_model);
        assert_eq!(y1, y2);
        assert!(y1.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_accumulates_reuse_stats() {
        let (cfg, w) = tiny();
        let seq = 4;
        let x = synth_embeddings(seq, cfg.d_model, 7);
        let mut l = LayerExec::new(&cfg, &w, 256);
        let _ = l.forward(&x, seq);
        // 6 matmuls × seq rows; reuse must be substantial on 128-wide rows.
        assert!(l.stats.mults > 0);
        assert!(l.stats.reuse_rate() > 0.2, "rate {}", l.stats.reuse_rate());
    }

    #[test]
    fn layernorm_output_standardized() {
        let (cfg, w) = tiny();
        let seq = 3;
        let x = synth_embeddings(seq, cfg.d_model, 9);
        let mut l = LayerExec::new(&cfg, &w, 128);
        let y = l.forward(&x, seq);
        for s in 0..seq {
            let row = &y[s * cfg.d_model..(s + 1) * cfg.d_model];
            let mean = row.iter().sum::<f32>() / cfg.d_model as f32;
            let var =
                row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / cfg.d_model as f32;
            assert!(mean.abs() < 1e-3, "mean {mean}");
            assert!((var - 1.0).abs() < 0.05, "var {var}");
        }
    }

    #[test]
    fn causal_incremental_matches_block_forward_bitexactly() {
        // One causal pass over 6 positions vs the same 6 positions fed
        // through the KV cache one at a time — outputs must be
        // bit-identical at every position.
        let (cfg, w) = tiny();
        let seq = 6;
        let d = cfg.d_model;
        let x = synth_embeddings(seq, d, 31);

        let mut block = LayerExec::new(&cfg, &w, 256);
        let mut kv_block = LayerKv::new();
        let y_block = block.forward_causal(&x, seq, &mut kv_block);

        let mut step = LayerExec::new(&cfg, &w, 256);
        let mut kv_step = LayerKv::new();
        let mut y_step = Vec::new();
        for s in 0..seq {
            let row = &x[s * d..(s + 1) * d];
            y_step.extend(step.forward_causal(row, 1, &mut kv_step));
        }

        assert_eq!(y_block, y_step);
        assert_eq!(kv_block.len(), seq);
        assert_eq!(kv_step.len(), seq);
        assert_eq!(block.stats, step.stats, "reuse counters must agree too");
    }

    #[test]
    fn causal_prefix_stable_under_extension() {
        // Appending new positions must not change earlier outputs: the
        // causal property the KV cache relies on.
        let (cfg, w) = tiny();
        let d = cfg.d_model;
        let x = synth_embeddings(5, d, 33);

        let mut short = LayerExec::new(&cfg, &w, 128);
        let y_short = short.forward_causal(&x[..3 * d], 3, &mut LayerKv::new());

        let mut long = LayerExec::new(&cfg, &w, 128);
        let y_long = long.forward_causal(&x, 5, &mut LayerKv::new());

        assert_eq!(y_short[..], y_long[..3 * d]);
    }

    #[test]
    fn truncated_kv_matches_short_run_bitexactly() {
        // The prefix-cache snapshot: truncating a 5-position cache to 3
        // yields exactly the cache a 3-position run would have built,
        // and resuming from it reproduces the long run's later outputs.
        let (cfg, w) = tiny();
        let d = cfg.d_model;
        let x = synth_embeddings(5, d, 33);

        let mut long = LayerExec::new(&cfg, &w, 128);
        let mut kv_long = LayerKv::new();
        let y_long = long.forward_causal(&x, 5, &mut kv_long);

        let mut short = LayerExec::new(&cfg, &w, 128);
        let mut kv_short = LayerKv::new();
        short.forward_causal(&x[..3 * d], 3, &mut kv_short);

        let cut = kv_long.truncated(3);
        assert_eq!(cut.len(), 3);
        assert_eq!(cut.k, kv_short.k);
        assert_eq!(cut.v, kv_short.v);

        // Warm resume over the suffix equals the cold long run.
        let mut resumed = cut;
        let mut warm = LayerExec::new(&cfg, &w, 128);
        let y_tail = warm.forward_causal(&x[3 * d..], 2, &mut resumed);
        assert_eq!(y_tail[..], y_long[3 * d..]);
        assert_eq!(resumed.k, kv_long.k);
        assert_eq!(resumed.v, kv_long.v);

        // Degenerate truncations are safe.
        assert_eq!(kv_long.truncated(0).len(), 0);
        assert_eq!(kv_long.truncated(99).len(), 5);
        assert_eq!(LayerKv::new().truncated(2).len(), 0);
    }

    #[test]
    fn rowwise_qmatmul_rows_are_independent() {
        let (cfg, w) = tiny();
        let wq = w.get(crate::model::MatKind::Wq);
        let d = cfg.d_model;
        let x = synth_embeddings(4, d, 35);
        let mut stats = ExecStats::default();
        let all = qmatmul_rowwise(&x, 4, wq, 256, &mut stats);
        for s in 0..4 {
            let mut st = ExecStats::default();
            let one = qmatmul_rowwise(&x[s * d..(s + 1) * d], 1, wq, 256, &mut st);
            assert_eq!(one[..], all[s * wq.cols..(s + 1) * wq.cols]);
        }
        assert!(stats.reuse_rate() > 0.2);
    }

    #[test]
    fn sharded_layer_is_bit_identical_with_partitioned_accounting() {
        // Column sharding is a scheduling transformation at the layer
        // level too: outputs bit-identical on the block and causal paths,
        // per-shard ops partitioning the monolithic element count.
        let (cfg, w) = tiny();
        let seq = 5;
        let x = synth_embeddings(seq, cfg.d_model, 51);
        for shards in [2usize, 4] {
            let mut mono = LayerExec::new(&cfg, &w, 256);
            let y_mono = mono.forward(&x, seq);
            let mut sh = LayerExec::new(&cfg, &w, 256).with_shards(shards);
            let y_sh = sh.forward(&x, seq);
            assert_eq!(y_mono, y_sh, "shards={shards}");
            assert_eq!(sh.shard_stats.len(), shards);
            let ops: u64 = sh.shard_stats.iter().map(|s| s.mults + s.reuses).sum();
            assert_eq!(ops, mono.stats.mults + mono.stats.reuses);
            assert_eq!(ops, sh.stats.mults + sh.stats.reuses);
            // Independent per-shard caches can only lose reuse.
            assert!(sh.stats.mults >= mono.stats.mults, "shards={shards}");

            let mut mono_c = LayerExec::new(&cfg, &w, 256);
            let yc_mono = mono_c.forward_causal(&x, seq, &mut LayerKv::new());
            let mut sh_c = LayerExec::new(&cfg, &w, 256).with_shards(shards);
            let yc_sh = sh_c.forward_causal(&x, seq, &mut LayerKv::new());
            assert_eq!(yc_mono, yc_sh, "causal shards={shards}");
            let ops_c: u64 = sh_c.shard_stats.iter().map(|s| s.mults + s.reuses).sum();
            assert_eq!(ops_c, mono_c.stats.mults + mono_c.stats.reuses);
        }
    }

    #[test]
    fn scalar_mode_is_bit_identical_including_stats() {
        // The packed/tiled arena path vs the seed scalar kernels: same
        // outputs, same total and per-shard counters, on both the block
        // and the causal path.
        let (cfg, w) = tiny();
        let seq = 5;
        let x = synth_embeddings(seq, cfg.d_model, 61);
        for shards in [1usize, 2, 4] {
            let mut fast = LayerExec::new(&cfg, &w, 256).with_shards(shards);
            let mut slow = LayerExec::new(&cfg, &w, 256)
                .with_shards(shards)
                .with_scalar(true);
            assert_eq!(fast.forward(&x, seq), slow.forward(&x, seq), "shards={shards}");
            assert_eq!(fast.stats, slow.stats, "shards={shards}");
            assert_eq!(fast.shard_stats, slow.shard_stats, "shards={shards}");

            let mut fast_c = LayerExec::new(&cfg, &w, 256).with_shards(shards);
            let mut slow_c = LayerExec::new(&cfg, &w, 256)
                .with_shards(shards)
                .with_scalar(true);
            let yf = fast_c.forward_causal(&x, seq, &mut LayerKv::new());
            let ys = slow_c.forward_causal(&x, seq, &mut LayerKv::new());
            assert_eq!(yf, ys, "causal shards={shards}");
            assert_eq!(fast_c.stats, slow_c.stats, "causal shards={shards}");
            assert_eq!(fast_c.shard_stats, slow_c.shard_stats, "causal shards={shards}");
        }
    }

    #[test]
    fn arena_recycling_across_layers_is_stateless() {
        // Handing a dirty arena from one executor to the next must not
        // change anything: same outputs and counters as a fresh arena.
        let (cfg, w) = tiny();
        let x = synth_embeddings(4, cfg.d_model, 63);
        let mut fresh = LayerExec::new(&cfg, &w, 256);
        let y_fresh = fresh.forward(&x, 4);

        let mut first = LayerExec::new(&cfg, &w, 256);
        let x2 = synth_embeddings(4, cfg.d_model, 64);
        let _ = first.forward(&x2, 4);
        let mut second = LayerExec::new(&cfg, &w, 256).with_arena(first.into_arena());
        assert_eq!(second.forward(&x, 4), y_fresh);
        assert_eq!(second.stats, fresh.stats);
    }

    #[test]
    fn parallel_sharded_matmul_matches_sequential() {
        // Drive the thread-parallel shard fan-out directly (the size gate
        // normally reserves it for prefill-scale calls) and pin it to the
        // scalar sharded kernels: same values, same per-shard counters,
        // on both activation-grid modes.
        let (cfg, w) = tiny();
        let wq = w.get(crate::model::MatKind::Wq);
        let packed = wq.packed();
        let d = cfg.d_model;
        let seq = 6;
        let x = synth_embeddings(seq, d, 71);
        for shards in [2usize, 3, 4] {
            for rowwise in [false, true] {
                let mut per_seq = vec![ExecStats::default(); shards];
                let mut st_seq = ExecStats::default();
                let y_seq = if rowwise {
                    qmatmul_rowwise_sharded(&x, seq, wq, 64, shards, &mut per_seq, &mut st_seq)
                } else {
                    qmatmul_sharded(&x, seq, wq, 64, shards, &mut per_seq, &mut st_seq)
                };
                let mut per_par = vec![ExecStats::default(); shards];
                let mut st_par = ExecStats::default();
                let y_par = qmatmul_sharded_packed_par(
                    &x,
                    seq,
                    &packed,
                    64,
                    shards,
                    rowwise,
                    &mut per_par,
                    &mut st_par,
                );
                assert_eq!(y_par, y_seq, "shards={shards} rowwise={rowwise}");
                assert_eq!(per_par, per_seq, "shards={shards} rowwise={rowwise}");
                assert_eq!(st_par, st_seq, "shards={shards} rowwise={rowwise}");
            }
        }
    }

    #[test]
    fn chunk_size_does_not_change_values() {
        // Reuse chunking is timing-only: functional output identical.
        let (cfg, w) = tiny();
        let seq = 3;
        let x = synth_embeddings(seq, cfg.d_model, 11);
        let y_small = LayerExec::new(&cfg, &w, 32).forward(&x, seq);
        let y_big = LayerExec::new(&cfg, &w, 512).forward(&x, seq);
        assert_eq!(y_small, y_big);
    }
}
