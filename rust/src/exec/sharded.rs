//! Tensor-parallel (column-sharded) execution of the reuse datapath.
//!
//! Production deployments shard each weight matrix **column-wise** across
//! `N` accelerator instances: shard `s` owns the contiguous column slice
//! [`shard_ranges`]`(cols, N)[s]`, computes the partial result `x·W[:, s]`
//! locally, and an all-gather stitches the slices back into the full
//! output row. Because every output column `y[j] = Σ_i x[i]·W[i,j]`
//! depends on no other column, column sharding is a pure scheduling
//! transformation — [`sharded_reuse_matmul_chunked`] is bit-identical to
//! the monolithic [`reuse_matmul_chunked`] for every shard count.
//!
//! What sharding *does* change is the reuse accounting: each shard owns
//! an **independent Result Cache** ([`EpochTags`] per shard), so a folded
//! weight value repeated across a shard boundary is a first occurrence on
//! both sides. Shard chunk boundaries follow the **global** W_buff round
//! grid (a shard streaming columns `[a, b)` takes its RC epochs at the
//! same column multiples of `chunk` the monolithic accelerator would),
//! so every shard chunk is the intersection of a monolithic chunk with
//! the shard's slice — a strict refinement of the monolithic chunk
//! partition. Two theorems follow, for every matrix shape:
//!
//! - `Σ_s (mults_s + reuses_s) == mults_mono + reuses_mono` (ops are
//!   column-additive), and
//! - `Σ_s mults_s ≥ mults_mono` (refining an RC chunk can only lose
//!   reuse — were shard chunks instead restarted at each slice start, a
//!   chunk straddling two monolithic chunks could *gain* reuse and the
//!   comparison to the paper's Fig. 8 rates would be apples-to-oranges).
//!
//! This is the measurable interaction between quantization-locality reuse
//! and tensor parallelism the shard-aware backends report per shard.

use crate::exec::{fill_products, packed_tile, EpochTags, ExecArena, ExecStats};
use crate::quant::{PackedQuantMatrix, QuantMatrix};
use std::ops::Range;

/// Exact column partition: shard `s` of `n` owns
/// `[s·cols/n, (s+1)·cols/n)`. Ranges are contiguous, disjoint, cover
/// `0..cols`, and differ in width by at most one column; shards beyond
/// the column count receive empty ranges.
pub fn shard_ranges(cols: usize, shards: usize) -> Vec<Range<usize>> {
    let n = shards.max(1);
    (0..n)
        .map(|s| (s * cols / n)..((s + 1) * cols / n))
        .collect()
}

/// Column-sharded reuse-path execution of `y = x·W`: shard `s` runs the
/// `chunk`-bounded Result-Cache datapath of
/// [`reuse_matmul_chunked`](crate::exec::reuse_matmul_chunked) over its
/// own column slice with its own [`EpochTags`] (an independent RC per
/// shard), and the output concatenates the slices (the all-gather).
///
/// Returns the full output row — bit-identical to the monolithic kernel
/// for any shard count, since output columns are independent and the
/// per-column accumulation order over `i` is unchanged — plus one
/// [`ExecStats`] per shard.
pub fn sharded_reuse_matmul_chunked(
    x: &[i8],
    w: &QuantMatrix,
    chunk: usize,
    shards: usize,
) -> (Vec<i32>, Vec<ExecStats>) {
    assert_eq!(x.len(), w.rows);
    assert!(chunk > 0);
    let ranges = shard_ranges(w.cols, shards);
    let mut y = vec![0i32; w.cols];
    let mut per_shard = vec![ExecStats::default(); ranges.len()];
    // One independent Result Cache (accounting tags) per shard.
    let mut tags: Vec<EpochTags> = (0..ranges.len()).map(|_| EpochTags::new()).collect();
    // Signed product table shared across shards: a value datapath detail
    // only — each shard's *accounting* is fully independent. Entry 255 is
    // code −128's slot (see [`fill_products`]).
    let mut products = [0i32; 256];
    for (i, &xi) in x.iter().enumerate() {
        fill_products(xi as i32, &mut products);
        let row = w.row(i);
        for (s, range) in ranges.iter().enumerate() {
            let stats = &mut per_shard[s];
            let mut col = range.start;
            while col < range.end {
                // Global-grid chunking: the next epoch boundary is the
                // next multiple of `chunk`, not `col + chunk`, so shard
                // chunks refine the monolithic chunk partition exactly
                // (see the module docs for why this matters).
                let end = ((col / chunk + 1) * chunk).min(range.end);
                tags[s].next_epoch();
                for (&wij, yj) in row[col..end].iter().zip(&mut y[col..end]) {
                    *yj += products[(wij as i32 + 127) as u8 as usize];
                }
                let mut unique = 0u64;
                for &wij in &row[col..end] {
                    unique += tags[s].first_occurrence(wij.unsigned_abs()) as u64;
                }
                stats.mults += unique;
                stats.reuses += (end - col) as u64 - unique;
                col = end;
            }
        }
    }
    (y, per_shard)
}

/// Packed/tiled form of [`sharded_reuse_matmul_chunked`]: shard `s` walks
/// its column slice of a [`PackedQuantMatrix`] on the same **global**
/// W_buff chunk grid, with per-shard [`EpochTags`] persisted in the arena
/// and the output left in [`ExecArena::yq`] — the kernel allocates
/// nothing. Per-call counters are **added** into `per_shard` (one entry
/// per shard) and the call's total is returned, so callers accumulating
/// across rows need no intermediate `Vec`.
///
/// Bit-identical to [`sharded_reuse_matmul_chunked`] in values and in
/// per-shard counters — pinned by `tests/prop_packed.rs`.
pub fn sharded_reuse_matmul_packed(
    x: &[i8],
    w: &PackedQuantMatrix,
    chunk: usize,
    shards: usize,
    per_shard: &mut [ExecStats],
    arena: &mut ExecArena,
) -> ExecStats {
    assert_eq!(x.len(), w.rows);
    assert!(chunk > 0);
    let ranges = shard_ranges(w.cols, shards);
    assert_eq!(per_shard.len(), ranges.len());
    let ExecArena {
        yq,
        products,
        shard_tags,
        ..
    } = arena;
    yq.clear();
    yq.resize(w.cols, 0);
    // One independent Result Cache (accounting tags) per shard; persisted
    // across calls — every chunk opens a fresh epoch, so stale tags can
    // never alias (the wrap reset in [`EpochTags::next_epoch`] covers the
    // 2^32 boundary).
    if shard_tags.len() < ranges.len() {
        shard_tags.resize_with(ranges.len(), EpochTags::new);
    }
    let mut total = ExecStats::default();
    for (i, &xi) in x.iter().enumerate() {
        fill_products(xi as i32, products);
        let words = w.row_words(i);
        for (s, range) in ranges.iter().enumerate() {
            let mut col = range.start;
            while col < range.end {
                // Global-grid chunking, as in the scalar sharded kernel.
                let end = ((col / chunk + 1) * chunk).min(range.end);
                shard_tags[s].next_epoch();
                let unique = packed_tile(words, col, end, products, &mut shard_tags[s], yq, 0);
                per_shard[s].mults += unique;
                per_shard[s].reuses += (end - col) as u64 - unique;
                total.mults += unique;
                total.reuses += (end - col) as u64 - unique;
                col = end;
            }
        }
    }
    total
}

/// Per-shard reuse accounting of one weight matrix, without executing any
/// products: the mult/reuse split of the RC depends only on the weight
/// codes, the chunk bound, and the shard boundaries — never on the input
/// vector — so shard-aware cost models can measure per-shard hit rates by
/// scanning a row sample.
///
/// Scans every row of `w` (callers pass a row-sampled prefix for
/// Llama-scale matrices) and scales the counters to `full_rows`, matching
/// the row-sampled extrapolation the cycle simulator uses.
pub fn shard_accounting(
    w: &QuantMatrix,
    chunk: usize,
    shards: usize,
    full_rows: u64,
) -> Vec<ExecStats> {
    assert!(chunk > 0);
    let ranges = shard_ranges(w.cols, shards);
    let mut per_shard = vec![ExecStats::default(); ranges.len()];
    let mut tags: Vec<EpochTags> = (0..ranges.len()).map(|_| EpochTags::new()).collect();
    for i in 0..w.rows {
        let row = w.row(i);
        for (s, range) in ranges.iter().enumerate() {
            let stats = &mut per_shard[s];
            let mut col = range.start;
            while col < range.end {
                // Same global-grid chunking as the executing kernel.
                let end = ((col / chunk + 1) * chunk).min(range.end);
                tags[s].next_epoch();
                let mut unique = 0u64;
                for &wij in &row[col..end] {
                    unique += tags[s].first_occurrence(wij.unsigned_abs()) as u64;
                }
                stats.mults += unique;
                stats.reuses += (end - col) as u64 - unique;
                col = end;
            }
        }
    }
    let sampled = w.rows.max(1) as u64;
    per_shard
        .into_iter()
        .map(|s| s.scaled(full_rows.max(sampled), sampled))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{dense_matmul, reuse_matmul_chunked};
    use crate::model::synth::{synthesize_matrix, WeightDistribution};
    use crate::util::rng::Rng;

    fn case(rows: usize, cols: usize, seed: u64) -> (Vec<i8>, QuantMatrix) {
        let mut rng = Rng::new(seed);
        let w = synthesize_matrix(rows, cols, WeightDistribution::default(), &mut rng);
        let x: Vec<i8> = (0..rows)
            .map(|_| rng.range_i64(-127, 127) as i8)
            .collect();
        (x, w)
    }

    #[test]
    fn shard_ranges_partition_exactly() {
        for (cols, n) in [(10, 3), (128, 4), (4, 8), (0, 2), (7, 1), (200, 7)] {
            let rs = shard_ranges(cols, n);
            assert_eq!(rs.len(), n.max(1));
            let mut next = 0;
            for r in &rs {
                assert_eq!(r.start, next, "cols={cols} n={n}");
                assert!(r.end >= r.start);
                next = r.end;
            }
            assert_eq!(next, cols);
            let widths: Vec<usize> = rs.iter().map(|r| r.end - r.start).collect();
            let min = widths.iter().min().unwrap();
            let max = widths.iter().max().unwrap();
            assert!(max - min <= 1, "balanced split: {widths:?}");
        }
    }

    #[test]
    fn sharded_values_bit_identical_for_all_shard_counts() {
        let (x, w) = case(32, 200, 3);
        let dense = dense_matmul(&x, &w);
        for shards in [1usize, 2, 3, 4, 8, 200, 500] {
            for chunk in [7usize, 64, 200] {
                let (y, per) = sharded_reuse_matmul_chunked(&x, &w, chunk, shards);
                assert_eq!(y, dense, "shards={shards} chunk={chunk}");
                assert_eq!(per.len(), shards);
            }
        }
    }

    #[test]
    fn one_shard_matches_monolithic_stats_exactly() {
        let (x, w) = case(16, 300, 9);
        for chunk in [5usize, 64, 300] {
            let (y_m, mono) = reuse_matmul_chunked(&x, &w, chunk);
            let (y_s, per) = sharded_reuse_matmul_chunked(&x, &w, chunk, 1);
            assert_eq!(y_s, y_m);
            assert_eq!(per.len(), 1);
            assert_eq!(per[0].mults, mono.mults, "chunk={chunk}");
            assert_eq!(per[0].reuses, mono.reuses, "chunk={chunk}");
        }
    }

    #[test]
    fn per_shard_ops_partition_and_reuse_only_drops() {
        let (x, w) = case(24, 512, 11);
        let chunk = 256;
        let (_, mono) = reuse_matmul_chunked(&x, &w, chunk);
        for shards in [2usize, 4, 8] {
            let (_, per) = sharded_reuse_matmul_chunked(&x, &w, chunk, shards);
            let ops: u64 = per.iter().map(|s| s.mults + s.reuses).sum();
            // Ops (elements) are column-additive: the shard split must
            // partition the monolithic element count exactly.
            assert_eq!(ops, mono.mults + mono.reuses, "shards={shards}");
            // Independent per-shard caches can only lose reuse.
            let mults: u64 = per.iter().map(|s| s.mults).sum();
            assert!(mults >= mono.mults, "shards={shards}");
            let reuses: u64 = per.iter().map(|s| s.reuses).sum();
            assert!(reuses <= mono.reuses, "shards={shards}");
            // Every non-empty shard did work.
            assert!(per.iter().all(|s| s.mults + s.reuses > 0));
        }
    }

    #[test]
    fn misaligned_shard_boundaries_still_refine_the_chunk_grid() {
        // Regression: with 600 columns, chunk 256, and 2 shards, shard 1
        // starts at column 300 — off the chunk grid. Slice-local
        // chunking would give it a [300, 556) chunk straddling the
        // monolithic [256, 512)/[512, 600) boundary and could GAIN
        // reuse; global-grid chunking must instead epoch at 512, keeping
        // shard chunks a strict refinement of the monolithic partition
        // so the "sharding only loses reuse" theorem holds on every
        // shape, not just chunk-aligned ones.
        let (x, w) = case(24, 600, 33);
        let chunk = 256;
        let (y_mono, mono) = reuse_matmul_chunked(&x, &w, chunk);
        for shards in [2usize, 3, 4, 5] {
            let (y, per) = sharded_reuse_matmul_chunked(&x, &w, chunk, shards);
            assert_eq!(y, y_mono, "shards={shards}");
            let ops: u64 = per.iter().map(|s| s.mults + s.reuses).sum();
            assert_eq!(ops, mono.mults + mono.reuses, "shards={shards}");
            let mults: u64 = per.iter().map(|s| s.mults).sum();
            assert!(
                mults >= mono.mults,
                "shards={shards}: refined chunks must never gain reuse \
                 ({mults} sharded mults < {} monolithic)",
                mono.mults
            );
            // And the x-free accounting agrees on the same grid.
            let scan = shard_accounting(&w, chunk, shards, w.rows as u64);
            for (a, b) in per.iter().zip(&scan) {
                assert_eq!(a.mults, b.mults, "shards={shards}");
                assert_eq!(a.reuses, b.reuses, "shards={shards}");
            }
        }
    }

    #[test]
    fn empty_shards_beyond_column_count_count_nothing() {
        let (x, w) = case(8, 3, 5);
        let (y, per) = sharded_reuse_matmul_chunked(&x, &w, 64, 8);
        assert_eq!(y, dense_matmul(&x, &w));
        assert_eq!(per.len(), 8);
        let ops: u64 = per.iter().map(|s| s.mults + s.reuses).sum();
        assert_eq!(ops, 8 * 3);
        assert!(per.iter().filter(|s| s.mults + s.reuses == 0).count() >= 5);
    }

    #[test]
    fn accounting_matches_the_executing_kernel() {
        // The x-free accounting scan must agree exactly with the
        // executing kernel's counters (same rows, no scaling).
        let (x, w) = case(20, 260, 17);
        for shards in [1usize, 2, 4] {
            let (_, per_exec) = sharded_reuse_matmul_chunked(&x, &w, 64, shards);
            let per_scan = shard_accounting(&w, 64, shards, w.rows as u64);
            for (a, b) in per_exec.iter().zip(&per_scan) {
                assert_eq!(a.mults, b.mults, "shards={shards}");
                assert_eq!(a.reuses, b.reuses, "shards={shards}");
            }
        }
    }

    #[test]
    fn packed_sharded_matches_scalar_sharded_exactly() {
        // Values AND per-shard counters, on misaligned shard boundaries
        // and chunk sizes that are not multiples of the pack width.
        let mut arena = ExecArena::new();
        let (x, w) = case(24, 130, 7);
        let packed = w.packed();
        for shards in [1usize, 2, 3, 4, 8] {
            for chunk in [3usize, 7, 64, 130] {
                let (y, per) = sharded_reuse_matmul_chunked(&x, &w, chunk, shards);
                let mut per_packed = vec![ExecStats::default(); shards];
                let total = sharded_reuse_matmul_packed(
                    &x,
                    &packed,
                    chunk,
                    shards,
                    &mut per_packed,
                    &mut arena,
                );
                assert_eq!(arena.yq(), &y[..], "shards={shards} chunk={chunk}");
                assert_eq!(per_packed, per, "shards={shards} chunk={chunk}");
                let sum = per.iter().fold(ExecStats::default(), |mut a, s| {
                    a.add(s);
                    a
                });
                assert_eq!(total, sum, "shards={shards} chunk={chunk}");
            }
        }
    }

    #[test]
    fn packed_sharded_accumulates_into_per_shard() {
        // The out-param contract: counters add across calls instead of
        // overwriting, so row-looping callers need no intermediate Vec.
        let mut arena = ExecArena::new();
        let (x, w) = case(8, 96, 13);
        let packed = w.packed();
        let mut acc = vec![ExecStats::default(); 2];
        let t1 = sharded_reuse_matmul_packed(&x, &packed, 32, 2, &mut acc, &mut arena);
        let t2 = sharded_reuse_matmul_packed(&x, &packed, 32, 2, &mut acc, &mut arena);
        assert_eq!(t1, t2, "same input, same counters");
        let (_, per) = sharded_reuse_matmul_chunked(&x, &w, 32, 2);
        for (a, p) in acc.iter().zip(&per) {
            assert_eq!(a.mults, 2 * p.mults);
            assert_eq!(a.reuses, 2 * p.reuses);
        }
    }

    #[test]
    fn accounting_scales_to_full_rows() {
        let (_, w) = case(16, 128, 21);
        let per = shard_accounting(&w, 64, 2, (w.rows * 4) as u64);
        let ops: u64 = per.iter().map(|s| s.mults + s.reuses).sum();
        assert_eq!(ops, (16 * 128 * 4) as u64, "scaled to 4× the sampled rows");
    }
}
