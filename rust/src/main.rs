//! AxLLM command-line interface.
//!
//! ```text
//! axllm reproduce <experiment> [--csv] [--seed N] [--sample-rows N]
//! axllm simulate --model <name> [--baseline|--sliced] [--lanes N]
//!                [--buffers N] [--slices P] [--seed N] [--sample-rows N]
//! axllm serve [--backend sim|functional|pjrt] [--model M] [--requests N]
//!             [--rate R] [--dataset D] [--batch B] [--artifacts DIR]
//!             [--adapters N] [--adapter-rank R]
//!             [--kv-blocks N] [--block-size B] [--prefix-groups K]
//!             [--profile FILE] [--save-profile FILE]
//! axllm map [--csv] [--json] [--seed N] [--sample-rows N] [--requests N]
//! axllm info [--artifacts DIR]
//! ```
//!
//! Argument parsing is hand-rolled (no clap offline); see `cli::Args`.

use axllm::backend::{ExecutionBackend, FunctionalBackend, PjrtBackend, SimBackend};
use axllm::config::{
    table1_benchmarks, AcceleratorConfig, BackendKind, Dataset, ExecProfile, ModelConfig,
};
use axllm::coordinator::{BatchPolicy, DecodeServeOpts, DisaggOpts, Engine, SloPolicy};
use axllm::model::Model;
use axllm::report::{self, RunCtx};
use axllm::sim::{Accelerator, LaneModel};
use axllm::util::table::count;
use axllm::workload::TraceGenerator;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

mod cli {
    /// Flags that never take a value. Without this list, `--csv fig1`
    /// would greedily swallow `fig1` as the flag's value and lose the
    /// positional experiment name.
    const BOOL_FLAGS: &[&str] = &[
        "csv", "baseline", "sliced", "live", "decode", "disagg", "slo", "scalar",
    ];

    /// Minimal flag parser: positionals plus `--key value` / `--flag`.
    pub struct Args {
        pub positional: Vec<String>,
        flags: std::collections::BTreeMap<String, String>,
    }

    impl Args {
        pub fn parse(argv: &[String]) -> Result<Args, String> {
            let mut positional = Vec::new();
            let mut flags = std::collections::BTreeMap::new();
            let mut it = argv.iter().peekable();
            while let Some(a) = it.next() {
                if let Some(name) = a.strip_prefix("--") {
                    if name.is_empty() {
                        return Err("stray `--`".into());
                    }
                    let value = if BOOL_FLAGS.contains(&name) {
                        // Boolean flags only consume an explicit boolean
                        // literal (`--csv false` still works); anything
                        // else stays a positional.
                        match it.peek() {
                            Some(v)
                                if matches!(
                                    v.as_str(),
                                    "true" | "false" | "1" | "0" | "yes" | "no"
                                ) =>
                            {
                                it.next().unwrap().clone()
                            }
                            _ => "true".to_string(),
                        }
                    } else {
                        match it.peek() {
                            Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                            _ => "true".to_string(),
                        }
                    };
                    flags.insert(name.to_string(), value);
                } else {
                    positional.push(a.clone());
                }
            }
            Ok(Args { positional, flags })
        }

        pub fn flag(&self, name: &str) -> Option<&str> {
            self.flags.get(name).map(|s| s.as_str())
        }

        pub fn get_bool(&self, name: &str) -> bool {
            matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
        }

        pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
            match self.flag(name) {
                None => Ok(default),
                Some(v) => v
                    .parse()
                    .map_err(|_| format!("invalid value for --{name}: {v}")),
            }
        }
    }
}

const USAGE: &str = "\
AxLLM — computation-reuse accelerator for quantized LLMs (paper reproduction)

USAGE:
  axllm reproduce <experiment> [--csv] [--seed N] [--sample-rows N]
      experiments: fig1 table1 fig8 fig9 lora shiftadd power area
                   ablation-buffer ablation-slices hazards ablation-dist
                   ablation-mapping ablation-bits all
  axllm simulate --model <distilbert|bert-base|bert-large|llama-7b|llama-13b|tiny>
                 [--baseline|--sliced] [--lanes N] [--buffers N] [--slices P]
                 [--seed N] [--sample-rows N]
  axllm serve [--backend <sim|functional|pjrt>] [--model M] [--requests N]
              [--rate R] [--dataset <agnews|yelp|squad|imdb>] [--batch B]
              [--max-wait-ms W] [--artifacts DIR] [--seed N]
              [--live] [--replicas N] [--decode] [--gen-tokens N]
              [--adapters N] [--adapter-rank R] [--shards N]
              [--kv-blocks N] [--block-size B] [--prefix-groups K]
              [--disagg] [--prefill-replicas P] [--decode-replicas D]
              [--chunk-tokens C] [--slo] [--scalar]
              [--profile FILE] [--save-profile FILE]
              [--diurnal AMP] [--flash-crowd MULT] [--heavy-tails SIGMA]
              [--abusive-tenants FRAC]
      backends:
        sim         cycle/energy attribution only — no logits, no artifacts
        functional  bit-exact in-process reuse-datapath execution, no artifacts
        pjrt        compiled HLO artifacts through the PJRT runtime (default)
      --live runs the threaded server (real clock, paced arrivals) instead
      of deterministic trace serving; --replicas N (default 1) spreads the
      live queue across N engine replicas with least-loaded dispatch.
      --decode serves autoregressive sessions (KV-cached prefill + decode)
      with token-level continuous batching, reporting TTFT/TPOT;
      --gen-tokens N fixes every request's generated-token budget
      (default: sampled per dataset).
      --adapters N serves N LoRA fine-tuned tenants off the one base
      model: each request routes through the base reuse pipeline plus
      its adapter's rank-R side pipeline (--adapter-rank R, default 16),
      mixed freely within one continuous batch. The summary then splits
      base-vs-adapter work per tenant. sim/functional backends serve
      adapters for real; pjrt serves base-only and reports the misses.
      --shards N executes every projection tensor-parallel across N
      shards, each with its own reuse cache: functional logits stay
      bit-identical, the sim cost model charges sliced compute plus the
      all-gather collective, and the summary reports each shard's reuse
      rate. A shard group is one logical replica (--replicas spreads
      whole groups). pjrt is shard-unaware and reports the misses.
      --kv-blocks N (decode only) adds a paged prefix KV cache of N
      fixed-size blocks (--block-size B positions each, default 16):
      multi-turn sessions sharing a prompt prefix resume the shared
      blocks instead of recomputing them — functional logits stay
      bit-identical warm or cold, the sim cost model bills cached tokens
      at block-copy rate plus eviction sweeps under memory pressure, and
      the summary reports the prefix hit rate. --prefix-groups K
      (default 4 when the cache is on) shapes the trace into K session
      groups with shared prefixes. pjrt has no KV surface and reports
      the misses.
      --disagg (decode only) serves on a disaggregated fleet: P dedicated
      prefill replicas (--prefill-replicas, default 1) run chunked
      prefill and hand each opened session's KV state across a metered
      tier link to D dedicated decode replicas (--decode-replicas,
      default 1). Trace mode runs the deterministic two-tier clock
      model; --live runs real prefill/decode worker threads with an
      in-process handoff channel. The summary adds the handoff bytes.
      --chunk-tokens C (decode only) slices every prompt into C-token
      prefill chunks interleaved with decode iterations, so no
      iteration stalls behind a whole long prompt (0 = monolithic;
      results are bit-identical either way).
      --slo (decode only) admits through the default SLO policy —
      interactive/standard/batch classes with aging boost, deadline
      shedding, and degraded budgets under overload — and shapes the
      trace into a mixed-class population; the summary reports
      attainment and the shed/degraded counts.
      --scalar (functional only) routes execution through the scalar
      reference kernels instead of the packed-code hot path; logits are
      bit-identical, only the kernel implementation changes.
      --profile FILE loads an ExecProfile TOML as the base execution
      configuration; explicit CLI flags override individual fields.
      --save-profile FILE writes the fully-resolved profile back out,
      so a flag combination can be replayed byte-for-byte later.
      hostile-traffic scenarios (composable trace shapers):
        --diurnal AMP        sinusoidal arrival rate, amplitude in [0,1]
        --flash-crowd MULT   a MULTx arrival burst over a quarter of the trace
        --heavy-tails SIGMA  lognormal prompt/decode lengths at sigma SIGMA
        --abusive-tenants F  fraction F of requests with 4x-inflated budgets
      examples:
        axllm serve --backend sim --requests 64 --model tiny
        axllm serve --backend functional --requests 16 --dataset squad
        axllm serve --backend pjrt --artifacts artifacts --batch 4
        axllm serve --live --replicas 4 --backend sim --requests 64
        axllm serve --decode --gen-tokens 16 --backend functional
        axllm serve --decode --live --backend sim --requests 64
        axllm serve --decode --adapters 4 --backend functional
        axllm serve --decode --adapters 8 --adapter-rank 8 --backend sim
        axllm serve --backend sim --shards 4 --requests 64
        axllm serve --backend functional --decode --shards 2
        axllm serve --decode --kv-blocks 64 --backend functional
        axllm serve --decode --kv-blocks 32 --block-size 8 --backend sim
        axllm serve --decode --disagg --prefill-replicas 2 --decode-replicas 2
        axllm serve --decode --disagg --chunk-tokens 32 --flash-crowd 8 --backend sim
        axllm serve --decode --slo --heavy-tails 1.5 --backend sim
        axllm serve --decode --disagg --live --backend functional
  axllm sweep-quant [--csv] [--json] [--seed N] [--sample-rows N]
      sweeps group-wise quantization regimes (per-tensor down to
      group-16 scales) over one seeded weight matrix and reports the
      reuse-rate / SNR / streamed-bytes Pareto; --json emits the
      deterministic document benches/quant_sweep.rs pins.
  axllm map [--csv] [--json] [--seed N] [--sample-rows N] [--requests N]
      enumerates a seeded grid of execution profiles (shards x quant
      regimes), evaluates each through the sim backend against one
      deterministic trace, and reports the tokens/s vs SNR vs
      streamed-bytes Pareto; --json emits the deterministic document
      benches/map_sweep.rs pins.
  axllm info [--artifacts DIR]
";

fn model_by_name(name: &str) -> Option<ModelConfig> {
    Some(match name {
        "distilbert" => ModelConfig::distilbert(),
        "bert-base" => ModelConfig::bert_base(),
        "bert-large" => ModelConfig::bert_large(),
        "llama-7b" => ModelConfig::llama_7b(),
        "llama-13b" => ModelConfig::llama_13b(),
        "tiny" => ModelConfig::tiny(),
        _ => return None,
    })
}

fn dataset_by_name(name: &str) -> Option<Dataset> {
    Some(match name {
        "agnews" => Dataset::AgNews,
        "yelp" => Dataset::YelpReviewFull,
        "squad" => Dataset::Squad,
        "imdb" => Dataset::Imdb,
        _ => return None,
    })
}

fn emit(t: &axllm::util::table::Table, csv: bool) {
    if csv {
        print!("{}", t.csv());
    } else {
        println!("{}", t.render());
    }
}

fn cmd_reproduce(args: &cli::Args) -> Result<(), String> {
    let exp = args
        .positional
        .get(1)
        .ok_or("reproduce: missing experiment name")?
        .as_str();
    let csv = args.get_bool("csv");
    let ctx = RunCtx {
        seed: args.get("seed", 42u64)?,
        sample_rows: args.get("sample-rows", 64usize)?,
    };
    let run = |name: &str| -> Result<(), String> {
        match name {
            "fig1" => emit(&report::fig1::generate(), csv),
            "table1" => emit(&report::fig8::table1(), csv),
            "fig8" => emit(&report::fig8::generate(ctx), csv),
            "fig9" => {
                emit(&report::fig9::generate(ctx), csv);
                let (ax, base) = report::fig9::distilbert_anchor(ctx);
                println!(
                    "DistilBERT absolute anchor @{} tokens: AxLLM {} vs baseline {} cycles (paper: 85.11M vs 159.34M)\n",
                    report::fig9::ANCHOR_TOKENS,
                    count(ax),
                    count(base)
                );
            }
            "lora" => emit(&report::lora::generate(ctx), csv),
            "shiftadd" => emit(&report::shiftadd::generate(ctx), csv),
            "power" => emit(&report::power::generate(ctx), csv),
            "area" => emit(&report::power::generate_area(), csv),
            "ablation-buffer" => emit(&report::ablation::buffer_sweep(ctx), csv),
            "ablation-slices" => emit(&report::ablation::slice_sweep_table(ctx), csv),
            "hazards" => emit(&report::ablation::hazard_rates(ctx), csv),
            "ablation-dist" => emit(&report::ablation::distribution_sensitivity(ctx), csv),
            "ablation-mapping" => emit(&report::ablation::rc_mapping_note(ctx), csv),
            "ablation-bits" => emit(&report::ablation::bitwidth_sweep(ctx), csv),
            other => return Err(format!("unknown experiment: {other}")),
        }
        Ok(())
    };
    if exp == "all" {
        for name in [
            "fig1",
            "table1",
            "fig8",
            "fig9",
            "lora",
            "shiftadd",
            "power",
            "area",
            "ablation-buffer",
            "ablation-slices",
            "hazards",
            "ablation-dist",
            "ablation-mapping",
            "ablation-bits",
        ] {
            run(name)?;
        }
        Ok(())
    } else {
        run(exp)
    }
}

fn cmd_simulate(args: &cli::Args) -> Result<(), String> {
    let name = args.flag("model").ok_or("simulate: --model is required")?;
    let model_cfg = model_by_name(name).ok_or_else(|| format!("unknown model: {name}"))?;
    let mut cfg = AcceleratorConfig::paper();
    cfg.lanes = args.get("lanes", cfg.lanes)?;
    cfg.buffer_entries = args.get("buffers", cfg.buffer_entries)?;
    cfg.slices = args.get("slices", cfg.slices)?;
    let seed = args.get("seed", 42u64)?;
    let sample_rows = args.get("sample-rows", 64usize)?;

    let model = Model::new(model_cfg.clone(), seed);
    let builder = Accelerator::builder().config(cfg);
    let acc = if args.get_bool("baseline") {
        builder.reuse(false).build()
    } else if args.get_bool("sliced") {
        builder.lane_model(LaneModel::Sliced).build()
    } else {
        builder.build()
    }
    .map_err(|e| e.to_string())?;
    let summary = acc.run_model(&model, sample_rows, seed);
    let s = &summary.total;
    println!("model: {} ({} layers)", model_cfg.name, model_cfg.n_layers);
    println!("lane model: {:?}", acc.lane_model);
    println!("cycles/token:        {}", count(s.cycles));
    println!("elements:            {}", count(s.elements));
    println!(
        "multiplications:     {} ({:.1}% reduction)",
        count(s.mults),
        s.mult_reduction() * 100.0
    );
    println!("reuse rate:          {:.1}%", s.reuse_rate() * 100.0);
    println!(
        "hazard stalls:       {} ({:.2}%)",
        count(s.hazard_stalls),
        s.hazard_rate() * 100.0
    );
    println!("collisions:          {}", count(s.collisions));
    let em = axllm::energy::EnergyModel::default();
    println!("energy/token:        {:.2} µJ", em.energy(s).total_pj / 1e6);
    Ok(())
}

fn print_cost(backend: &str, cost: &axllm::coordinator::CostModel) {
    println!(
        "backend: {} — cost model: {:.0} cycles/token AxLLM vs {:.0} baseline ({:.2}x), reuse {:.1}%",
        backend,
        cost.cycles_per_token_ax,
        cost.cycles_per_token_base,
        cost.speedup(),
        cost.reuse_rate * 100.0
    );
    if cost.shards > 1 {
        println!(
            "sharding: {} shards — modeled shard speedup {:.2}x on a 128-token pass",
            cost.shards,
            cost.shard_speedup(128)
        );
    }
}

fn print_summary(s: &axllm::coordinator::ServeSummary) {
    println!(
        "served {} requests in {} batches over {:.3}s",
        s.requests, s.batches, s.span_s
    );
    println!(
        "tokens: {}  throughput: {:.1} req/s, {:.0} tok/s",
        s.tokens, s.throughput_rps, s.throughput_tps
    );
    println!(
        "latency: mean {:.2}ms p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms max {:.2}ms",
        s.latency.mean_s * 1e3,
        s.latency.p50_s * 1e3,
        s.latency.p95_s * 1e3,
        s.latency.p99_s * 1e3,
        s.latency.max_s * 1e3
    );
    if s.gen_tokens > 0 {
        println!(
            "decode: {} generated tokens  TTFT p50 {:.2}ms p95 {:.2}ms  TPOT p50 {:.3}ms p95 {:.3}ms",
            s.gen_tokens,
            s.ttft.p50_s * 1e3,
            s.ttft.p95_s * 1e3,
            s.tpot.p50_s * 1e3,
            s.tpot.p95_s * 1e3
        );
    }
    if s.cached_tokens > 0 {
        println!(
            "prefix reuse: {} prompt tokens served from cache ({:.1}% hit rate)",
            s.cached_tokens,
            s.prefix_hit_rate * 100.0
        );
    }
    if s.shed + s.degraded > 0 || s.slo_attainment < 1.0 {
        println!(
            "slo: {:.1}% attainment, {} shed, {} degraded",
            s.slo_attainment * 100.0,
            s.shed,
            s.degraded
        );
    }
    if s.handoff_bytes > 0 {
        println!(
            "disagg handoff: {} KV bytes across the prefill→decode link",
            count(s.handoff_bytes)
        );
    }
    // Per-shard rollup — present only for tensor-parallel runs.
    if !s.per_shard.is_empty() {
        let total_ops: u64 = s
            .per_shard
            .iter()
            .map(|g| g.base_mults + g.base_reuses)
            .sum();
        println!(
            "sharding: {} shards, {} base ops across the group",
            s.per_shard.len(),
            count(total_ops)
        );
        for g in &s.per_shard {
            println!(
                "  shard {}: reuse {:.1}% ({} mults, {} reuses)",
                g.shard,
                g.reuse_rate * 100.0,
                count(g.base_mults),
                count(g.base_reuses)
            );
        }
    }
    // Per-adapter rollup — only worth printing when the run actually
    // mixed serving dimensions (any adapter group, or side-pipe work).
    if s.by_adapter.len() > 1 || s.adapter_ops > 0 {
        for g in &s.by_adapter {
            let name = match g.adapter {
                None => "base".to_string(),
                Some(id) => format!("adapter {id}"),
            };
            println!(
                "  {name:>10}: {} requests, {} tokens ({:.0} tok/s), base reuse {:.1}%, {} side-pipe MACs",
                g.requests,
                g.tokens,
                g.throughput_tps,
                g.base_reuse_rate * 100.0,
                g.adapter_ops
            );
        }
    }
    println!(
        "accelerator attribution: {} simulated cycles, reuse {:.1}%, {:.2} µJ, speedup vs baseline {:.2}x",
        count(s.sim_cycles),
        s.sim_reuse_rate * 100.0,
        s.sim_energy_j * 1e6,
        s.sim_speedup
    );
}

/// Shared `serve` options (trace generation + batching policy).
#[derive(Clone, Copy)]
struct ServeOpts {
    n: usize,
    rate: f64,
    dataset: Dataset,
    policy: BatchPolicy,
    seed: u64,
    replicas: usize,
    /// Serve autoregressive decode sessions (continuous batching).
    decode: bool,
    /// Fixed generated-token budget; 0 = sampled per dataset.
    gen_tokens: u32,
    /// LoRA tenants served off the base model; 0 = base-only.
    adapters: u32,
    /// Low-rank dimension of every served adapter.
    adapter_rank: usize,
    /// Tensor-parallel shards per replica (1 = monolithic).
    shards: usize,
    /// Paged prefix KV cache capacity in blocks; 0 = no cache.
    kv_blocks: usize,
    /// Token positions per KV block.
    block_size: usize,
    /// Shared-prefix session groups shaping the trace; 0 = untagged.
    prefix_groups: u32,
    /// Disaggregated prefill/decode serving (decode only).
    disagg: bool,
    /// Prefill-tier replicas when disaggregated.
    prefill_replicas: usize,
    /// Decode-tier replicas when disaggregated.
    decode_replicas: usize,
    /// Chunked-prefill token budget per iteration; 0 = monolithic.
    chunk_tokens: usize,
    /// Admit through the default SLO policy (shed/degrade/attainment).
    slo: bool,
    /// KV bytes per context token billed to disaggregated handoffs
    /// (0 = unmetered; set from the served model's K/V geometry).
    handoff_bpt: f64,
    /// Diurnal arrival-rate amplitude in [0, 1]; 0 = flat arrivals.
    diurnal: f64,
    /// Flash-crowd arrival-rate multiplier; 0 = no burst.
    flash_crowd: f64,
    /// Lognormal sigma for heavy-tailed lengths; 0 = dataset defaults.
    heavy_tails: f64,
    /// Fraction of requests from budget-inflating tenants; 0 = none.
    abusive: f64,
}

impl ServeOpts {
    /// The (prefill-only or decode) trace these options describe.
    fn trace(&self) -> Vec<axllm::workload::Request> {
        let mut gen =
            TraceGenerator::new(self.dataset, self.rate, self.seed).with_adapters(self.adapters);
        if self.prefix_groups > 0 {
            // Multi-turn sessions (4 turns each) sharing per-group
            // prompt prefixes — the traffic shape prefix caching pays
            // off on.
            gen = gen.with_shared_prefixes(self.prefix_groups, 4);
        }
        // Hostile-traffic shapers, scaled to the trace's nominal span so
        // the scenarios stay meaningful at any --requests/--rate combo.
        let span = self.n as f64 / self.rate.max(1.0);
        if self.diurnal > 0.0 {
            gen = gen.with_diurnal((span / 2.0).max(1e-3), self.diurnal);
        }
        if self.flash_crowd > 0.0 {
            gen = gen.with_flash_crowd(span * 0.25, (span * 0.25).max(1e-3), self.flash_crowd);
        }
        if self.heavy_tails > 0.0 {
            gen = gen.with_heavy_tails(self.heavy_tails, self.heavy_tails);
        }
        if self.abusive > 0.0 {
            gen = gen.with_abusive_tenants(self.abusive, 4.0);
        }
        if self.slo {
            gen = gen.with_slo_mix(0.25, 0.25);
        }
        if self.decode {
            gen.take_decode(self.n, (self.gen_tokens > 0).then_some(self.gen_tokens))
        } else {
            gen.take(self.n)
        }
    }
}

/// Serve a synthetic trace through any backend and print the summary.
/// `opts.seed` drives the trace generator (and, for the functional
/// backend, the synthesized weights too).
fn run_serve<B: ExecutionBackend>(engine: &Engine<B>, opts: &ServeOpts) -> Result<(), String> {
    print_cost(engine.backend.name(), engine.cost());
    let trace = opts.trace();
    let served = if opts.disagg {
        // Deterministic two-tier fleet on the virtual clock; take_decode
        // stamps every budget, so default_gen 1 is never consulted.
        let mut dopts = DisaggOpts::new(opts.prefill_replicas, opts.decode_replicas, 1)
            .with_chunking(opts.chunk_tokens)
            .with_handoff(opts.handoff_bpt);
        if opts.slo {
            dopts = dopts.with_slo(SloPolicy::default());
        }
        println!(
            "disagg: {} prefill + {} decode replicas, chunk {} tokens",
            opts.prefill_replicas, opts.decode_replicas, opts.chunk_tokens
        );
        engine.serve_trace_disagg(trace, opts.policy, dopts)
    } else if opts.decode {
        // take_decode stamps every request's budget, so the fallback
        // default is never consulted; 1 keeps it well-formed.
        let mut dopts = DecodeServeOpts::new(1).with_chunking(opts.chunk_tokens);
        if opts.slo {
            dopts = dopts.with_slo(SloPolicy::default());
        }
        engine.serve_trace_decode_opts(trace, opts.policy, dopts)
    } else {
        engine.serve_trace(trace, opts.policy)
    };
    let (_results, s) = served.map_err(|e| format!("{e:#}"))?;
    print_summary(&s);
    let misses = engine.backend.adapter_misses();
    if misses > 0 {
        println!("adapter misses (served base-only): {misses}");
    }
    let shard_misses = engine.backend.shard_misses();
    if shard_misses > 0 {
        println!("shard misses (served monolithically): {shard_misses}");
    }
    if let Some(ps) = engine.backend.prefix_stats() {
        println!(
            "prefix cache: {}/{} blocks in use ({} pinned), {} hits / {} lookups ({} tokens), {} evictions, {} preemptions",
            ps.blocks_in_use,
            ps.capacity_blocks,
            ps.pinned_blocks,
            ps.hits,
            ps.lookups,
            ps.hit_tokens,
            ps.evictions,
            ps.preemptions
        );
    }
    let kv_misses = engine.backend.kv_misses();
    if kv_misses > 0 {
        println!("kv misses (served without prefix reuse): {kv_misses}");
    }
    let quant_misses = engine.backend.quant_misses();
    if quant_misses > 0 {
        println!("quant misses (served per-tensor): {quant_misses}");
    }
    Ok(())
}

/// Live serving: start a replica pool, pace the trace's arrivals on the
/// wall clock, and aggregate the per-request results into the same
/// `ServeSummary` trace serving reports.
fn run_live<B, F>(backend: &str, make: F, opts: &ServeOpts) -> Result<(), String>
where
    B: ExecutionBackend + 'static,
    F: Fn(usize) -> axllm::Result<Engine<B>> + Send + Clone + 'static,
{
    use axllm::coordinator::{DecodeOpts, Server};

    let trace = opts.trace();
    let pool = if opts.decode {
        // Sim-backed live decode paces at the *iteration* level (the
        // decode weight pass is shared across the running batch), so the
        // sim backend itself must stay unpaced; host-executing backends
        // (functional/PJRT) take real time per step already.
        let dopts = DecodeOpts {
            default_gen: 1,
            pace: backend == "sim",
        };
        Server::start_decode_pool(opts.replicas, make, opts.policy, dopts)
    } else {
        Server::start_pool(opts.replicas, make, opts.policy)
    };
    // cost() is cached, so printing it first costs nothing; on failure
    // run() below surfaces the worker's real construction error.
    if let Some(cost) = pool.cost() {
        print_cost(backend, &cost);
        println!(
            "live{}: {} replica(s), arrivals paced at {:.0} req/s",
            if opts.decode { " decode" } else { "" },
            opts.replicas,
            opts.rate
        );
    }
    // Replay the trace's arrival offsets on the wall clock.
    let run = pool.run(trace, true).map_err(|e| format!("{e:#}"))?;
    print_summary(&run.summary);
    if run.adapter_misses > 0 {
        println!("adapter misses (served base-only): {}", run.adapter_misses);
    }
    if run.shard_misses > 0 {
        println!("shard misses (served monolithically): {}", run.shard_misses);
    }
    if run.kv_misses > 0 {
        println!("kv misses (served without prefix reuse): {}", run.kv_misses);
    }
    if run.quant_misses > 0 {
        println!("quant misses (served per-tensor): {}", run.quant_misses);
    }
    for (i, (b, r)) in run.replica_stats.iter().enumerate() {
        println!("replica {i}: {b} batches, {r} requests");
    }
    Ok(())
}

/// Live disaggregated serving: dedicated prefill and decode worker
/// threads joined by an in-process KV-handoff channel, fed the same
/// paced trace `run_live` uses.
fn run_live_disagg<B, F>(backend: &str, make: F, opts: &ServeOpts) -> Result<(), String>
where
    B: ExecutionBackend + 'static,
    F: Fn(usize) -> axllm::Result<Engine<B>> + Send + Clone + 'static,
{
    use axllm::coordinator::{DisaggPoolOpts, Server};

    let trace = opts.trace();
    let mut dopts = DisaggPoolOpts::new(1).with_handoff(opts.handoff_bpt);
    if opts.slo {
        dopts = dopts.with_slo(SloPolicy::default());
    }
    let pool = Server::start_disagg_pool(
        opts.prefill_replicas,
        opts.decode_replicas,
        make,
        opts.policy,
        dopts,
    );
    if let Some(cost) = pool.cost() {
        print_cost(backend, &cost);
        println!(
            "live disagg: {} prefill + {} decode replicas, arrivals paced at {:.0} req/s",
            opts.prefill_replicas, opts.decode_replicas, opts.rate
        );
    }
    let run = pool.run(trace, true).map_err(|e| format!("{e:#}"))?;
    print_summary(&run.summary);
    if run.adapter_misses > 0 {
        println!("adapter misses (served base-only): {}", run.adapter_misses);
    }
    if run.kv_misses > 0 {
        println!("kv misses (served without prefix reuse): {}", run.kv_misses);
    }
    if run.quant_misses > 0 {
        println!("quant misses (served per-tensor): {}", run.quant_misses);
    }
    Ok(())
}

/// Serve one resolved profile — trace or live, flat or disaggregated —
/// through whichever backend the profile names. Every backend arm in
/// `cmd_serve` collapses onto this single generic path: construction is
/// always `Engine::from_profile`, so the CLI can no longer drift from
/// the library's builder chains.
fn serve_profile<B: ExecutionBackend + 'static>(
    model_cfg: ModelConfig,
    profile: ExecProfile,
    opts: ServeOpts,
    live: bool,
) -> Result<(), String> {
    let name = profile.backend.name();
    if live {
        let make = move |_i: usize| Engine::<B>::from_profile(&model_cfg, &profile);
        if opts.disagg {
            run_live_disagg(name, make, &opts)
        } else {
            run_live(name, make, &opts)
        }
    } else {
        let engine =
            Engine::<B>::from_profile(&model_cfg, &profile).map_err(|e| format!("{e:#}"))?;
        run_serve(&engine, &opts)
    }
}

fn cmd_serve(args: &cli::Args) -> Result<(), String> {
    // Resolve the execution profile first: an optional --profile file is
    // the base, explicit CLI flags override individual fields, and
    // untouched fields keep the file's (or built-in) defaults.
    let mut profile = match args.flag("profile") {
        Some(path) => ExecProfile::load(Path::new(path)).map_err(|e| format!("{e:#}"))?,
        // The CLI's historical default backend is pjrt.
        None => ExecProfile::new(BackendKind::Pjrt),
    };
    if let Some(b) = args.flag("backend") {
        profile.backend = BackendKind::parse(b)
            .ok_or_else(|| format!("unknown backend: {b} (expected sim|functional|pjrt)"))?;
    }
    // Default seed 7 keeps the historical `axllm serve` trace (earlier
    // versions hardcoded trace seed 7), so recorded outputs stay
    // comparable.
    let kv_blocks = args.get("kv-blocks", profile.kv_blocks)?;
    let opts = ServeOpts {
        n: args.get("requests", 64usize)?,
        rate: args.get("rate", 200.0f64)?,
        dataset: dataset_by_name(args.flag("dataset").unwrap_or("imdb"))
            .ok_or("unknown dataset")?,
        policy: BatchPolicy {
            max_batch: args.get("batch", 4usize)?,
            max_wait_s: args.get("max-wait-ms", 10.0f64)? / 1e3,
        },
        seed: args.get("seed", profile.seed)?,
        replicas: args.get("replicas", 1usize)?,
        decode: args.get_bool("decode"),
        gen_tokens: args.get("gen-tokens", 0u32)?,
        adapters: args.get("adapters", profile.adapters as u32)?,
        adapter_rank: args.get("adapter-rank", profile.adapter_rank)?,
        shards: args.get("shards", profile.shards)?,
        kv_blocks,
        block_size: args.get("block-size", profile.block_size)?,
        // A prefix cache without shared-prefix traffic never hits:
        // tagging defaults on alongside the cache.
        prefix_groups: args.get("prefix-groups", if kv_blocks > 0 { 4u32 } else { 0u32 })?,
        disagg: args.get_bool("disagg"),
        prefill_replicas: args.get("prefill-replicas", 1usize)?,
        decode_replicas: args.get("decode-replicas", 1usize)?,
        chunk_tokens: args.get("chunk-tokens", profile.chunk_tokens)?,
        slo: args.get_bool("slo") || profile.slo,
        // Filled per-backend from the served model's K/V geometry.
        handoff_bpt: 0.0,
        diurnal: args.get("diurnal", 0.0f64)?,
        flash_crowd: args.get("flash-crowd", 0.0f64)?,
        heavy_tails: args.get("heavy-tails", 0.0f64)?,
        abusive: args.get("abusive-tenants", 0.0f64)?,
    };
    if args.get_bool("scalar") && profile.backend != BackendKind::Functional {
        return Err(
            "--scalar needs --backend functional (only the functional backend has a scalar \
             reference kernel path)"
                .into(),
        );
    }
    if args.flag("artifacts").is_some() && profile.backend != BackendKind::Pjrt {
        return Err(
            "--artifacts needs --backend pjrt (sim/functional synthesize weights in-process)"
                .into(),
        );
    }
    if args.flag("prefix-groups").is_some() && opts.kv_blocks == 0 {
        return Err(
            "--prefix-groups needs --kv-blocks (prefix-shaped traffic without a prefix cache \
             never reuses)"
                .into(),
        );
    }
    if opts.gen_tokens > 0 && !opts.decode {
        return Err("--gen-tokens needs --decode".into());
    }
    if opts.shards == 0 {
        return Err("--shards must be ≥ 1".into());
    }
    if opts.kv_blocks > 0 && !opts.decode {
        return Err("--kv-blocks needs --decode (prefix KV reuse is a decode-session feature)".into());
    }
    if args.flag("block-size").is_some() && opts.kv_blocks == 0 {
        return Err("--block-size needs --kv-blocks".into());
    }
    if opts.block_size == 0 {
        return Err("--block-size must be ≥ 1".into());
    }
    if args.flag("adapter-rank").is_some() && opts.adapters == 0 {
        return Err("--adapter-rank needs --adapters".into());
    }
    if opts.adapter_rank == 0 {
        return Err("--adapter-rank must be ≥ 1".into());
    }
    if opts.replicas == 0 {
        return Err("--replicas must be ≥ 1".into());
    }
    if opts.disagg && !opts.decode {
        return Err("--disagg needs --decode (prefill/decode tiers are a decode-session split)".into());
    }
    if !opts.disagg
        && (args.flag("prefill-replicas").is_some() || args.flag("decode-replicas").is_some())
    {
        return Err("--prefill-replicas/--decode-replicas need --disagg".into());
    }
    if opts.prefill_replicas == 0 || opts.decode_replicas == 0 {
        return Err("--prefill-replicas and --decode-replicas must be ≥ 1".into());
    }
    if opts.chunk_tokens > 0 && !opts.decode {
        return Err("--chunk-tokens needs --decode (chunked prefill feeds decode sessions)".into());
    }
    if opts.slo && !opts.decode {
        return Err("--slo needs --decode (targets are TTFT/TPOT deadlines)".into());
    }
    if !(0.0..=1.0).contains(&opts.diurnal) {
        return Err("--diurnal amplitude must be in [0, 1]".into());
    }
    if !(0.0..=1.0).contains(&opts.abusive) {
        return Err("--abusive-tenants fraction must be in [0, 1]".into());
    }
    let live = args.get_bool("live");
    if !live && opts.replicas > 1 {
        return Err("--replicas needs --live (trace serving is single-engine)".into());
    }
    if opts.disagg && opts.replicas > 1 {
        return Err("--replicas conflicts with --disagg (size the tiers instead)".into());
    }
    // Fold the resolved serving flags back into the profile so the one
    // value handed to `from_profile` (and `--save-profile`) is complete.
    let name = args.flag("model").unwrap_or("tiny");
    let model_cfg = model_by_name(name).ok_or_else(|| format!("unknown model: {name}"))?;
    profile.seed = opts.seed;
    profile.shards = opts.shards;
    profile.adapters = opts.adapters as usize;
    profile.adapter_rank = opts.adapter_rank;
    profile.kv_blocks = opts.kv_blocks;
    profile.block_size = opts.block_size;
    profile.chunk_tokens = opts.chunk_tokens;
    profile.slo = opts.slo;
    profile.scalar_kernels = args.get_bool("scalar") || profile.scalar_kernels;
    if let Some(dir) = args.flag("artifacts") {
        profile.artifacts = dir.to_string();
    }
    // Pacing is a CLI decision, not a file one: sim live serving paces
    // the worker for the simulated service time so queueing and replica
    // scaling behave like the modeled deployment — except decode mode,
    // which paces at the worker's iteration level instead (see
    // `run_live`), so its backend stays unpaced.
    profile.paced = profile.backend == BackendKind::Sim && live && !opts.decode;
    // Disaggregated handoffs ship 2·n_layers·d_model f32 K/V rows per
    // context token (the with_handoff_regime geometry); pjrt has no KV
    // surface to ship.
    let handoff_bpt = if profile.backend == BackendKind::Pjrt {
        0.0
    } else {
        (2 * model_cfg.n_layers * model_cfg.d_model * 4) as f64
    };
    let opts = ServeOpts { handoff_bpt, ..opts };
    profile.handoff_bytes_per_token = if opts.disagg { handoff_bpt } else { 0.0 };
    profile.validate().map_err(|e| format!("{e:#}"))?;

    if let Some(path) = args.flag("save-profile") {
        profile.save(Path::new(path)).map_err(|e| format!("{e:#}"))?;
        println!("profile saved to {path}");
    }

    match profile.backend {
        BackendKind::Sim => serve_profile::<SimBackend>(model_cfg, profile, opts, live),
        BackendKind::Functional => {
            serve_profile::<FunctionalBackend>(model_cfg, profile, opts, live)
        }
        BackendKind::Pjrt => {
            if opts.adapters > 0 {
                // The AOT artifacts bake the base weights into fixed-shape
                // HLO: adapter requests are served base-only and counted
                // as misses by the backend.
                println!(
                    "note: pjrt has no adapter surface — {} adapter(s) will serve base-only",
                    opts.adapters
                );
            }
            if opts.shards > 1 {
                // Fixed-shape artifacts cannot split their projections:
                // requests serve monolithically with recorded misses.
                println!(
                    "note: pjrt is shard-unaware — {} shards requested, serving monolithically",
                    opts.shards
                );
            }
            if opts.kv_blocks > 0 {
                // One fixed-shape HLO call per window: there is no
                // per-layer KV tensor to share, so prefix reuse cannot
                // be honored — requests recompute with recorded misses.
                println!(
                    "note: pjrt has no KV surface — {} blocks requested, serving without prefix reuse",
                    opts.kv_blocks
                );
            }
            if !profile.quant.is_per_tensor() || profile.quant.compressed {
                // Artifact weights were quantized per-tensor at compile
                // time; grouped scales cannot be honored after the fact.
                println!(
                    "note: pjrt artifacts are per-tensor — grouped quant requested, serving \
                     per-tensor with recorded misses"
                );
            }
            serve_profile::<PjrtBackend>(model_cfg, profile, opts, live)
        }
    }
}

fn cmd_info(args: &cli::Args) -> Result<(), String> {
    let dir = PathBuf::from(args.flag("artifacts").unwrap_or("artifacts"));
    println!(
        "axllm {} — AxLLM paper reproduction",
        env!("CARGO_PKG_VERSION")
    );
    println!("benchmarks (Table I):");
    for b in table1_benchmarks() {
        let (r, c) = b.weight_matrix();
        println!("  {:45} {}x{}", b.key(), r, c);
    }
    match axllm::runtime::Runtime::cpu() {
        Ok(rt) => {
            println!(
                "PJRT: platform={} devices={}",
                rt.platform(),
                rt.device_count()
            );
            match axllm::runtime::ArtifactSet::load(&rt, &dir) {
                Ok(a) => println!(
                    "artifacts: OK ({} kernels, tiny model B={} S={} D={})",
                    a.kernels.len(),
                    a.manifest.batch,
                    a.manifest.seq,
                    a.manifest.d_model
                ),
                Err(e) => println!("artifacts: NOT LOADED ({e:#}) — run `make artifacts`"),
            }
        }
        Err(e) => println!("PJRT: unavailable ({e:#})"),
    }
    Ok(())
}

fn cmd_sweep_quant(args: &cli::Args) -> Result<(), String> {
    let ctx = RunCtx {
        seed: args.get("seed", 42u64)?,
        sample_rows: args.get("sample-rows", 64usize)?,
    };
    if args.get_bool("json") {
        print!("{}", report::quant_sweep::json(ctx));
    } else {
        emit(&report::quant_sweep::generate(ctx), args.get_bool("csv"));
    }
    Ok(())
}

fn cmd_map(args: &cli::Args) -> Result<(), String> {
    let ctx = RunCtx {
        seed: args.get("seed", 42u64)?,
        sample_rows: args.get("sample-rows", 64usize)?,
    };
    let requests = args.get("requests", 48usize)?;
    if args.get_bool("json") {
        print!("{}", report::map::json(ctx, requests));
    } else {
        emit(&report::map::generate(ctx, requests), args.get_bool("csv"));
    }
    Ok(())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "reproduce" => cmd_reproduce(&args),
        "sweep-quant" => cmd_sweep_quant(&args),
        "map" => cmd_map(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(&args),
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command: {other}\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::cli::Args;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn bool_flags_do_not_swallow_positionals() {
        let a = Args::parse(&argv(&["reproduce", "--csv", "fig1"])).unwrap();
        assert_eq!(a.positional, vec!["reproduce", "fig1"]);
        assert!(a.get_bool("csv"));
        // Trailing bool flag still parses.
        let b = Args::parse(&argv(&["reproduce", "fig1", "--csv"])).unwrap();
        assert_eq!(b.positional, vec!["reproduce", "fig1"]);
        assert!(b.get_bool("csv"));
    }

    #[test]
    fn bool_flags_between_valued_flags() {
        let a = Args::parse(&argv(&[
            "simulate", "--baseline", "--model", "tiny", "--sliced", "--lanes", "8",
        ]))
        .unwrap();
        assert_eq!(a.positional, vec!["simulate"]);
        assert!(a.get_bool("baseline"));
        assert!(a.get_bool("sliced"));
        assert_eq!(a.flag("model"), Some("tiny"));
        assert_eq!(a.get("lanes", 0usize).unwrap(), 8);
    }

    #[test]
    fn bool_flags_still_accept_explicit_literals() {
        let a = Args::parse(&argv(&["reproduce", "--csv", "false", "fig1"])).unwrap();
        assert!(!a.get_bool("csv"));
        assert_eq!(a.positional, vec!["reproduce", "fig1"]);
        let b = Args::parse(&argv(&["reproduce", "--csv", "yes", "fig1"])).unwrap();
        assert!(b.get_bool("csv"));
        assert_eq!(b.positional, vec!["reproduce", "fig1"]);
    }

    #[test]
    fn valued_flags_still_consume_values() {
        let a = Args::parse(&argv(&["serve", "--backend", "sim", "--requests", "64"])).unwrap();
        assert_eq!(a.flag("backend"), Some("sim"));
        assert_eq!(a.get("requests", 0usize).unwrap(), 64);
        assert_eq!(a.positional, vec!["serve"]);
    }

    #[test]
    fn live_flag_composes_with_valued_flags() {
        let a = Args::parse(&argv(&[
            "serve",
            "--live",
            "--replicas",
            "4",
            "--backend",
            "sim",
        ]))
        .unwrap();
        assert!(a.get_bool("live"));
        assert_eq!(a.get("replicas", 1usize).unwrap(), 4);
        assert_eq!(a.flag("backend"), Some("sim"));
        assert_eq!(a.positional, vec!["serve"]);
    }

    #[test]
    fn decode_flag_composes_with_gen_tokens() {
        let a = Args::parse(&argv(&[
            "serve",
            "--decode",
            "--gen-tokens",
            "16",
            "--backend",
            "functional",
        ]))
        .unwrap();
        assert!(a.get_bool("decode"));
        assert_eq!(a.get("gen-tokens", 0u32).unwrap(), 16);
        assert_eq!(a.flag("backend"), Some("functional"));
        assert_eq!(a.positional, vec!["serve"]);
        // --decode directly before a valued flag must not swallow it.
        let b = Args::parse(&argv(&["serve", "--decode", "--requests", "8"])).unwrap();
        assert!(b.get_bool("decode"));
        assert_eq!(b.get("requests", 0usize).unwrap(), 8);
    }

    #[test]
    fn adapter_flags_parse_next_to_decode() {
        let a = Args::parse(&argv(&[
            "serve",
            "--decode",
            "--adapters",
            "4",
            "--adapter-rank",
            "8",
            "--backend",
            "sim",
        ]))
        .unwrap();
        assert!(a.get_bool("decode"));
        assert_eq!(a.get("adapters", 0u32).unwrap(), 4);
        assert_eq!(a.get("adapter-rank", 16usize).unwrap(), 8);
        assert_eq!(a.flag("backend"), Some("sim"));
        assert_eq!(a.positional, vec!["serve"]);
    }

    #[test]
    fn shards_flag_composes_with_backend_and_decode() {
        let a = Args::parse(&argv(&[
            "serve", "--decode", "--shards", "4", "--backend", "sim",
        ]))
        .unwrap();
        assert!(a.get_bool("decode"));
        assert_eq!(a.get("shards", 1usize).unwrap(), 4);
        assert_eq!(a.flag("backend"), Some("sim"));
        assert_eq!(a.positional, vec!["serve"]);
        // Default is monolithic.
        let b = Args::parse(&argv(&["serve", "--backend", "sim"])).unwrap();
        assert_eq!(b.get("shards", 1usize).unwrap(), 1);
    }

    #[test]
    fn kv_cache_flags_compose_with_decode() {
        let a = Args::parse(&argv(&[
            "serve",
            "--decode",
            "--kv-blocks",
            "64",
            "--block-size",
            "8",
            "--prefix-groups",
            "6",
            "--backend",
            "functional",
        ]))
        .unwrap();
        assert!(a.get_bool("decode"));
        assert_eq!(a.get("kv-blocks", 0usize).unwrap(), 64);
        assert_eq!(a.get("block-size", 16usize).unwrap(), 8);
        assert_eq!(a.get("prefix-groups", 0u32).unwrap(), 6);
        assert_eq!(a.flag("backend"), Some("functional"));
        assert_eq!(a.positional, vec!["serve"]);
        // Defaults: cache off, block size 16.
        let b = Args::parse(&argv(&["serve", "--decode", "--backend", "sim"])).unwrap();
        assert_eq!(b.get("kv-blocks", 0usize).unwrap(), 0);
        assert_eq!(b.get("block-size", 16usize).unwrap(), 16);
    }

    #[test]
    fn disagg_flags_compose_with_decode() {
        let a = Args::parse(&argv(&[
            "serve",
            "--decode",
            "--disagg",
            "--prefill-replicas",
            "2",
            "--decode-replicas",
            "3",
            "--chunk-tokens",
            "32",
            "--slo",
            "--flash-crowd",
            "8",
            "--backend",
            "sim",
        ]))
        .unwrap();
        assert!(a.get_bool("decode"));
        assert!(a.get_bool("disagg"));
        assert!(a.get_bool("slo"));
        assert_eq!(a.get("prefill-replicas", 1usize).unwrap(), 2);
        assert_eq!(a.get("decode-replicas", 1usize).unwrap(), 3);
        assert_eq!(a.get("chunk-tokens", 0usize).unwrap(), 32);
        assert_eq!(a.get("flash-crowd", 0.0f64).unwrap(), 8.0);
        assert_eq!(a.flag("backend"), Some("sim"));
        assert_eq!(a.positional, vec!["serve"]);
        // Defaults: unified pool, monolithic prefill, no SLO policy.
        let b = Args::parse(&argv(&["serve", "--decode", "--backend", "sim"])).unwrap();
        assert!(!b.get_bool("disagg"));
        assert!(!b.get_bool("slo"));
        assert_eq!(b.get("chunk-tokens", 0usize).unwrap(), 0);
    }

    #[test]
    fn stray_double_dash_rejected() {
        assert!(Args::parse(&argv(&["reproduce", "--"])).is_err());
    }

    #[test]
    fn scalar_is_a_bool_flag() {
        let a = Args::parse(&argv(&["serve", "--scalar", "--backend", "functional"])).unwrap();
        assert!(a.get_bool("scalar"));
        assert_eq!(a.flag("backend"), Some("functional"));
        // Directly before a valued flag it must not swallow the value.
        let b = Args::parse(&argv(&["serve", "--scalar", "--requests", "8"])).unwrap();
        assert!(b.get_bool("scalar"));
        assert_eq!(b.get("requests", 0usize).unwrap(), 8);
    }

    fn serve_err(flags: &[&str]) -> String {
        super::cmd_serve(&Args::parse(&argv(flags)).unwrap()).unwrap_err()
    }

    #[test]
    fn conflicting_serve_flags_are_rejected() {
        // Every silently-ignored combination must fail loudly instead.
        let e = serve_err(&["serve", "--scalar", "--backend", "sim"]);
        assert!(e.contains("--scalar"), "{e}");
        let e = serve_err(&["serve", "--artifacts", "artifacts", "--backend", "sim"]);
        assert!(e.contains("--artifacts"), "{e}");
        let e = serve_err(&["serve", "--decode", "--prefix-groups", "4", "--backend", "sim"]);
        assert!(e.contains("--prefix-groups"), "{e}");
        let e = serve_err(&["serve", "--block-size", "8", "--backend", "sim"]);
        assert!(e.contains("--block-size"), "{e}");
        let e = serve_err(&["serve", "--adapter-rank", "8", "--backend", "sim"]);
        assert!(e.contains("--adapter-rank"), "{e}");
        let e = serve_err(&["serve", "--decode", "--chunk-tokens", "8", "--backend", "tpu"]);
        assert!(e.contains("unknown backend"), "{e}");
    }

    #[test]
    fn save_profile_round_trips_through_serve() {
        use axllm::config::{BackendKind, ExecProfile};
        let dir = std::env::temp_dir().join("axllm_cli_profile_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cli_profile.toml");
        let path_s = path.to_str().unwrap();
        let a = Args::parse(&argv(&[
            "serve",
            "--backend",
            "sim",
            "--requests",
            "4",
            "--shards",
            "2",
            "--save-profile",
            path_s,
        ]))
        .unwrap();
        super::cmd_serve(&a).unwrap();
        let p = ExecProfile::load(&path).unwrap();
        assert_eq!(p.backend, BackendKind::Sim);
        assert_eq!(p.shards, 2);
        assert!(!p.paced, "trace serving must save an unpaced profile");
        // The saved file reproduces the run without any other flags.
        let b = Args::parse(&argv(&["serve", "--requests", "4", "--profile", path_s])).unwrap();
        super::cmd_serve(&b).unwrap();
    }
}
