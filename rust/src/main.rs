//! AxLLM command-line interface.
//!
//! ```text
//! axllm reproduce <experiment> [--csv] [--seed N] [--sample-rows N]
//! axllm simulate --model <name> [--baseline|--sliced] [--lanes N]
//!                [--buffers N] [--slices P] [--seed N] [--sample-rows N]
//! axllm serve [--requests N] [--rate R] [--dataset D] [--batch B]
//!             [--artifacts DIR]
//! axllm info [--artifacts DIR]
//! ```
//!
//! Argument parsing is hand-rolled (no clap offline); see `cli::Args`.

use axllm::config::{table1_benchmarks, AcceleratorConfig, Dataset, ModelConfig};
use axllm::coordinator::{BatchPolicy, Engine};
use axllm::model::Model;
use axllm::report::{self, RunCtx};
use axllm::sim::{Accelerator, LaneModel};
use axllm::util::table::count;
use axllm::workload::TraceGenerator;
use std::path::PathBuf;
use std::process::ExitCode;

mod cli {
    /// Minimal flag parser: positionals plus `--key value` / `--flag`.
    pub struct Args {
        pub positional: Vec<String>,
        flags: std::collections::BTreeMap<String, String>,
    }

    impl Args {
        pub fn parse(argv: &[String]) -> Result<Args, String> {
            let mut positional = Vec::new();
            let mut flags = std::collections::BTreeMap::new();
            let mut it = argv.iter().peekable();
            while let Some(a) = it.next() {
                if let Some(name) = a.strip_prefix("--") {
                    if name.is_empty() {
                        return Err("stray `--`".into());
                    }
                    let value = match it.peek() {
                        Some(v) if !v.starts_with("--") => it.next().unwrap().clone(),
                        _ => "true".to_string(),
                    };
                    flags.insert(name.to_string(), value);
                } else {
                    positional.push(a.clone());
                }
            }
            Ok(Args { positional, flags })
        }

        pub fn flag(&self, name: &str) -> Option<&str> {
            self.flags.get(name).map(|s| s.as_str())
        }

        pub fn get_bool(&self, name: &str) -> bool {
            matches!(self.flag(name), Some("true") | Some("1") | Some("yes"))
        }

        pub fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
            match self.flag(name) {
                None => Ok(default),
                Some(v) => v
                    .parse()
                    .map_err(|_| format!("invalid value for --{name}: {v}")),
            }
        }
    }
}

const USAGE: &str = "\
AxLLM — computation-reuse accelerator for quantized LLMs (paper reproduction)

USAGE:
  axllm reproduce <experiment> [--csv] [--seed N] [--sample-rows N]
      experiments: fig1 table1 fig8 fig9 lora shiftadd power area
                   ablation-buffer ablation-slices hazards ablation-dist
                   ablation-mapping ablation-bits all
  axllm simulate --model <distilbert|bert-base|bert-large|llama-7b|llama-13b|tiny>
                 [--baseline|--sliced] [--lanes N] [--buffers N] [--slices P]
                 [--seed N] [--sample-rows N]
  axllm serve [--requests N] [--rate R] [--dataset <agnews|yelp|squad|imdb>]
              [--batch B] [--max-wait-ms W] [--artifacts DIR]
  axllm info [--artifacts DIR]
";

fn model_by_name(name: &str) -> Option<ModelConfig> {
    Some(match name {
        "distilbert" => ModelConfig::distilbert(),
        "bert-base" => ModelConfig::bert_base(),
        "bert-large" => ModelConfig::bert_large(),
        "llama-7b" => ModelConfig::llama_7b(),
        "llama-13b" => ModelConfig::llama_13b(),
        "tiny" => ModelConfig::tiny(),
        _ => return None,
    })
}

fn dataset_by_name(name: &str) -> Option<Dataset> {
    Some(match name {
        "agnews" => Dataset::AgNews,
        "yelp" => Dataset::YelpReviewFull,
        "squad" => Dataset::Squad,
        "imdb" => Dataset::Imdb,
        _ => return None,
    })
}

fn emit(t: &axllm::util::table::Table, csv: bool) {
    if csv {
        print!("{}", t.csv());
    } else {
        println!("{}", t.render());
    }
}

fn cmd_reproduce(args: &cli::Args) -> Result<(), String> {
    let exp = args
        .positional
        .get(1)
        .ok_or("reproduce: missing experiment name")?
        .as_str();
    let csv = args.get_bool("csv");
    let ctx = RunCtx {
        seed: args.get("seed", 42u64)?,
        sample_rows: args.get("sample-rows", 64usize)?,
    };
    let run = |name: &str| -> Result<(), String> {
        match name {
            "fig1" => emit(&report::fig1::generate(), csv),
            "table1" => emit(&report::fig8::table1(), csv),
            "fig8" => emit(&report::fig8::generate(ctx), csv),
            "fig9" => {
                emit(&report::fig9::generate(ctx), csv);
                let (ax, base) = report::fig9::distilbert_anchor(ctx);
                println!(
                    "DistilBERT absolute anchor @{} tokens: AxLLM {} vs baseline {} cycles (paper: 85.11M vs 159.34M)\n",
                    report::fig9::ANCHOR_TOKENS,
                    count(ax),
                    count(base)
                );
            }
            "lora" => emit(&report::lora::generate(ctx), csv),
            "shiftadd" => emit(&report::shiftadd::generate(ctx), csv),
            "power" => emit(&report::power::generate(ctx), csv),
            "area" => emit(&report::power::generate_area(), csv),
            "ablation-buffer" => emit(&report::ablation::buffer_sweep(ctx), csv),
            "ablation-slices" => emit(&report::ablation::slice_sweep_table(ctx), csv),
            "hazards" => emit(&report::ablation::hazard_rates(ctx), csv),
            "ablation-dist" => emit(&report::ablation::distribution_sensitivity(ctx), csv),
            "ablation-mapping" => emit(&report::ablation::rc_mapping_note(ctx), csv),
            "ablation-bits" => emit(&report::ablation::bitwidth_sweep(ctx), csv),
            other => return Err(format!("unknown experiment: {other}")),
        }
        Ok(())
    };
    if exp == "all" {
        for name in [
            "fig1",
            "table1",
            "fig8",
            "fig9",
            "lora",
            "shiftadd",
            "power",
            "area",
            "ablation-buffer",
            "ablation-slices",
            "hazards",
            "ablation-dist",
            "ablation-mapping",
            "ablation-bits",
        ] {
            run(name)?;
        }
        Ok(())
    } else {
        run(exp)
    }
}

fn cmd_simulate(args: &cli::Args) -> Result<(), String> {
    let name = args.flag("model").ok_or("simulate: --model is required")?;
    let model_cfg = model_by_name(name).ok_or_else(|| format!("unknown model: {name}"))?;
    let mut cfg = AcceleratorConfig::paper();
    cfg.lanes = args.get("lanes", cfg.lanes)?;
    cfg.buffer_entries = args.get("buffers", cfg.buffer_entries)?;
    cfg.slices = args.get("slices", cfg.slices)?;
    cfg.validate().map_err(|e| e.to_string())?;
    let seed = args.get("seed", 42u64)?;
    let sample_rows = args.get("sample-rows", 64usize)?;

    let model = Model::new(model_cfg.clone(), seed);
    let acc = if args.get_bool("baseline") {
        Accelerator::baseline(cfg)
    } else if args.get_bool("sliced") {
        Accelerator::axllm(cfg).with_lane_model(LaneModel::Sliced)
    } else {
        Accelerator::axllm(cfg)
    };
    let summary = acc.run_model(&model, sample_rows, seed);
    let s = &summary.total;
    println!("model: {} ({} layers)", model_cfg.name, model_cfg.n_layers);
    println!("lane model: {:?}", acc.lane_model);
    println!("cycles/token:        {}", count(s.cycles));
    println!("elements:            {}", count(s.elements));
    println!(
        "multiplications:     {} ({:.1}% reduction)",
        count(s.mults),
        s.mult_reduction() * 100.0
    );
    println!("reuse rate:          {:.1}%", s.reuse_rate() * 100.0);
    println!(
        "hazard stalls:       {} ({:.2}%)",
        count(s.hazard_stalls),
        s.hazard_rate() * 100.0
    );
    println!("collisions:          {}", count(s.collisions));
    let em = axllm::energy::EnergyModel::default();
    println!("energy/token:        {:.2} µJ", em.energy(s).total_pj / 1e6);
    Ok(())
}

fn cmd_serve(args: &cli::Args) -> Result<(), String> {
    let n = args.get("requests", 64usize)?;
    let rate = args.get("rate", 200.0f64)?;
    let dataset =
        dataset_by_name(args.flag("dataset").unwrap_or("imdb")).ok_or("unknown dataset")?;
    let dir = PathBuf::from(args.flag("artifacts").unwrap_or("artifacts"));
    let policy = BatchPolicy {
        max_batch: args.get("batch", 4usize)?,
        max_wait_s: args.get("max-wait-ms", 10.0f64)? / 1e3,
    };
    let engine = Engine::load(&dir, AcceleratorConfig::paper()).map_err(|e| format!("{e:#}"))?;
    let trace = TraceGenerator::new(dataset, rate, 7).take(n);
    let (_results, s) = engine
        .serve_trace(trace, policy)
        .map_err(|e| format!("{e:#}"))?;
    println!(
        "served {} requests in {} batches over {:.3}s",
        s.requests, s.batches, s.span_s
    );
    println!(
        "tokens: {}  throughput: {:.1} req/s, {:.0} tok/s",
        s.tokens, s.throughput_rps, s.throughput_tps
    );
    println!(
        "latency: mean {:.2}ms p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms max {:.2}ms",
        s.latency.mean_s * 1e3,
        s.latency.p50_s * 1e3,
        s.latency.p95_s * 1e3,
        s.latency.p99_s * 1e3,
        s.latency.max_s * 1e3
    );
    println!(
        "accelerator attribution: {} simulated cycles, reuse {:.1}%, {:.2} µJ, speedup vs baseline {:.2}x",
        count(s.sim_cycles),
        s.sim_reuse_rate * 100.0,
        s.sim_energy_j * 1e6,
        s.sim_speedup
    );
    Ok(())
}

fn cmd_info(args: &cli::Args) -> Result<(), String> {
    let dir = PathBuf::from(args.flag("artifacts").unwrap_or("artifacts"));
    println!(
        "axllm {} — AxLLM paper reproduction",
        env!("CARGO_PKG_VERSION")
    );
    println!("benchmarks (Table I):");
    for b in table1_benchmarks() {
        let (r, c) = b.weight_matrix();
        println!("  {:45} {}x{}", b.key(), r, c);
    }
    match axllm::runtime::Runtime::cpu() {
        Ok(rt) => {
            println!(
                "PJRT: platform={} devices={}",
                rt.platform(),
                rt.device_count()
            );
            match axllm::runtime::ArtifactSet::load(&rt, &dir) {
                Ok(a) => println!(
                    "artifacts: OK ({} kernels, tiny model B={} S={} D={})",
                    a.kernels.len(),
                    a.manifest.batch,
                    a.manifest.seq,
                    a.manifest.d_model
                ),
                Err(e) => println!("artifacts: NOT LOADED ({e:#}) — run `make artifacts`"),
            }
        }
        Err(e) => println!("PJRT: unavailable ({e:#})"),
    }
    Ok(())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "reproduce" => cmd_reproduce(&args),
        "simulate" => cmd_simulate(&args),
        "serve" => cmd_serve(&args),
        "info" => cmd_info(&args),
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command: {other}\n{USAGE}")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}
