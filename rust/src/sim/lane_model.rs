//! Trait-based lane micro-architecture models.
//!
//! [`LaneSim`] is the open extension point for lane timing models: anything
//! that can turn one (stationary input element × weight chunk) pass into a
//! [`ChunkResult`] can drive the [`Accelerator`](crate::sim::Accelerator)
//! schedule. The three built-in implementations mirror the paper:
//!
//! - [`BaselineLane`] — multipliers only, no Result Cache (Fig. 9 baseline);
//! - [`SerialLane`] — the serial dual compute/reuse pipeline (paper-default);
//! - [`SlicedLane`] — P-way sliced buffers with collision queues (§IV).
//!
//! [`LaneModel`] remains the closed, `Copy` *identifier* of the built-in
//! models (it travels inside configs and CLI flags); [`LaneModel::sim`]
//! resolves it to the corresponding `&'static dyn LaneSim`, which is what
//! the accelerator actually dispatches through.

use crate::config::AcceleratorConfig;
use crate::sim::{baseline, lane, sliced, ChunkResult, LaneModel};

/// A lane timing model: simulates one input element streaming one weight
/// chunk, producing cycle/activity counters and the functional partial
/// sums. Implementations must be functionally exact — every built-in model
/// is property-tested bit-identical against dense multiplication.
pub trait LaneSim: Send + Sync {
    /// Which built-in [`LaneModel`] this implementation realizes.
    fn kind(&self) -> LaneModel;

    /// Short identifier for tables and CLI output.
    fn name(&self) -> &'static str {
        self.kind().name()
    }

    /// Simulate one (input element × weight chunk) pass through the lane.
    fn simulate_chunk(&self, x: i8, weights: &[i8], cfg: &AcceleratorConfig) -> ChunkResult;
}

/// Multiply-only lane: every element takes the compute path.
pub struct BaselineLane;

/// Serial dual-pipeline lane: compute path on first occurrence of a folded
/// value, 1-cycle reuse path on repeats (paper-calibrated default).
pub struct SerialLane;

/// P-way sliced lane: parallel buffer/RC slices with collision queues and
/// credit-based backpressure (§IV "Partitioning for Higher Throughput").
pub struct SlicedLane;

impl LaneSim for BaselineLane {
    fn kind(&self) -> LaneModel {
        LaneModel::Baseline
    }

    fn simulate_chunk(&self, x: i8, weights: &[i8], cfg: &AcceleratorConfig) -> ChunkResult {
        baseline::simulate_chunk(x, weights, cfg)
    }
}

impl LaneSim for SerialLane {
    fn kind(&self) -> LaneModel {
        LaneModel::Serial
    }

    fn simulate_chunk(&self, x: i8, weights: &[i8], cfg: &AcceleratorConfig) -> ChunkResult {
        lane::simulate_chunk(x, weights, cfg)
    }
}

impl LaneSim for SlicedLane {
    fn kind(&self) -> LaneModel {
        LaneModel::Sliced
    }

    fn simulate_chunk(&self, x: i8, weights: &[i8], cfg: &AcceleratorConfig) -> ChunkResult {
        sliced::simulate_chunk(x, weights, cfg)
    }
}

/// Every built-in lane model as a trait object, for sweeps and
/// equivalence tests.
pub static ALL_LANE_SIMS: [&dyn LaneSim; 3] = [&BaselineLane, &SerialLane, &SlicedLane];

impl LaneModel {
    /// All built-in lane models.
    pub const ALL: [LaneModel; 3] = [LaneModel::Baseline, LaneModel::Serial, LaneModel::Sliced];

    /// Short identifier for tables and CLI output.
    pub fn name(self) -> &'static str {
        match self {
            LaneModel::Baseline => "baseline",
            LaneModel::Serial => "serial",
            LaneModel::Sliced => "sliced",
        }
    }

    /// Resolve to the lane timing model the accelerator dispatches through.
    pub fn sim(self) -> &'static dyn LaneSim {
        match self {
            LaneModel::Baseline => &BaselineLane,
            LaneModel::Serial => &SerialLane,
            LaneModel::Sliced => &SlicedLane,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_round_trips_through_sim() {
        for lm in LaneModel::ALL {
            assert_eq!(lm.sim().kind(), lm);
            assert_eq!(lm.sim().name(), lm.name());
        }
    }

    #[test]
    fn trait_objects_match_free_functions() {
        let cfg = AcceleratorConfig::paper();
        let weights: Vec<i8> = (0..64).map(|i| ((i * 31) % 255 - 127) as i8).collect();
        let direct = lane::simulate_chunk(7, &weights, &cfg);
        let via_trait = LaneModel::Serial.sim().simulate_chunk(7, &weights, &cfg);
        assert_eq!(direct.partials, via_trait.partials);
        assert_eq!(direct.stats, via_trait.stats);
    }

    #[test]
    fn all_lane_sims_cover_all_models() {
        let kinds: Vec<LaneModel> = ALL_LANE_SIMS.iter().map(|s| s.kind()).collect();
        assert_eq!(kinds, LaneModel::ALL.to_vec());
    }
}
