//! ShiftAddLLM comparator (paper §V "Comparison with state-of-the-art";
//! DESIGN.md substitution S5).
//!
//! ShiftAddLLM [You et al., NeurIPS'24] reparameterizes a q-bit weight
//! matrix as q binary (±1) matrices with power-of-two scales:
//! `W ≈ Σᵢ αᵢ·bᵢ`, so `x·W ≈ Σᵢ αᵢ·(x·bᵢ)` — multiplications become
//! shifts and adds. The LUT optimization precomputes all 256 signed sums
//! of every 8-element activation subvector; each 8-element group of each
//! binary column then costs one lookup + one accumulate.
//!
//! This module provides both:
//! - a **functional** implementation (binary decomposition via greedy
//!   residual fitting, LUT-based evaluation) used to check the
//!   approximation semantics, and
//! - a **timing** model with `units` parallel shift-add units, matching
//!   the paper's 64-unit comparison setup: per vector×matrix, a setup
//!   phase fills the LUTs (one add per LUT entry), then each of the
//!   `C·q·(N/8)` group-steps costs one LUT read plus one accumulate
//!   (2 cycles on a unit — lookup then add, the structural difference
//!   the paper credits for AxLLM's 29% edge: AxLLM's reuse path is a
//!   single buffered access, and its result cache needs no setup phase).

use crate::quant::QuantMatrix;

/// Binary decomposition of one weight matrix: `q` ±1 matrices + scales.
#[derive(Clone, Debug)]
pub struct BinaryDecomposition {
    /// Row count of the decomposed matrix.
    pub rows: usize,
    /// Column count of the decomposed matrix.
    pub cols: usize,
    /// Base matrices, each rows×cols of ±1 stored as i8.
    pub bases: Vec<Vec<i8>>,
    /// Power-of-two scale per base (round(log2 α) exponent).
    pub scale_exp: Vec<i32>,
    /// Global dequantization scale (the quantized grid's scale).
    pub scale: f32,
}

/// Greedy residual binary decomposition of the quantized codes: at step i,
/// `bᵢ = sign(residual)`, `αᵢ = round_pow2(mean |residual|)`, residual −=
/// `αᵢ·bᵢ`. This is the standard BCQ-style construction ShiftAddLLM's
/// post-training reparameterization builds on.
pub fn decompose(w: &QuantMatrix, q: usize) -> BinaryDecomposition {
    let n = w.data.len();
    let mut residual: Vec<f64> = w.data.iter().map(|&v| v as f64).collect();
    let mut bases = Vec::with_capacity(q);
    let mut scale_exp = Vec::with_capacity(q);
    for _ in 0..q {
        let mean_abs = residual.iter().map(|r| r.abs()).sum::<f64>() / n as f64;
        // Round α to the nearest power of two (shift-friendly); floor at
        // 2^-8 to keep shifts bounded.
        let exp = if mean_abs > 0.0 {
            mean_abs.log2().round() as i32
        } else {
            -8
        }
        .max(-8);
        let alpha = 2f64.powi(exp);
        let mut b = Vec::with_capacity(n);
        for r in residual.iter_mut() {
            let s: i8 = if *r >= 0.0 { 1 } else { -1 };
            b.push(s);
            *r -= alpha * s as f64;
        }
        bases.push(b);
        scale_exp.push(exp);
    }
    BinaryDecomposition {
        rows: w.rows,
        cols: w.cols,
        bases,
        scale_exp,
        scale: w.params.scale,
    }
}

impl BinaryDecomposition {
    /// Reconstruct the approximated codes (float, pre-dequantization).
    pub fn reconstruct(&self) -> Vec<f64> {
        let n = self.rows * self.cols;
        let mut out = vec![0f64; n];
        for (b, &e) in self.bases.iter().zip(&self.scale_exp) {
            let alpha = 2f64.powi(e);
            for (o, &s) in out.iter_mut().zip(b.iter()) {
                *o += alpha * s as f64;
            }
        }
        out
    }

    /// Root-mean-square error of the approximation in code units.
    pub fn rms_error(&self, w: &QuantMatrix) -> f64 {
        let rec = self.reconstruct();
        let n = rec.len() as f64;
        (rec.iter()
            .zip(&w.data)
            .map(|(r, &v)| (r - v as f64) * (r - v as f64))
            .sum::<f64>()
            / n)
            .sqrt()
    }

    /// Functional LUT-based evaluation of `y ≈ x·W` (code units, f64).
    ///
    /// Builds the 256-entry LUT for every 8-element group of `x` (exactly
    /// the precomputation ShiftAddLLM performs), then evaluates every
    /// column of every base through group lookups.
    pub fn matmul_lut(&self, x: &[i8]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let groups = self.rows.div_ceil(8);
        // LUT[g][mask] = Σ_{k: bit k of mask set} x[8g+k] − Σ_{unset} x[8g+k]
        let mut lut = vec![[0i32; 256]; groups];
        for g in 0..groups {
            for mask in 0..256usize {
                let mut s = 0i32;
                for k in 0..8 {
                    let idx = 8 * g + k;
                    if idx < self.rows {
                        let sign = if mask >> k & 1 == 1 { 1 } else { -1 };
                        s += sign * x[idx] as i32;
                    }
                }
                lut[g][mask] = s;
            }
        }
        let mut y = vec![0f64; self.cols];
        for (b, &e) in self.bases.iter().zip(&self.scale_exp) {
            let alpha = 2f64.powi(e);
            for j in 0..self.cols {
                let mut s = 0i64;
                for g in 0..groups {
                    let mut mask = 0usize;
                    for k in 0..8 {
                        let idx = 8 * g + k;
                        if idx < self.rows && b[idx * self.cols + j] > 0 {
                            mask |= 1 << k;
                        }
                    }
                    s += lut[g][mask] as i64;
                }
                y[j] += alpha * s as f64;
            }
        }
        y
    }
}

/// Timing model of a ShiftAddLLM engine with `units` parallel shift-add
/// units (paper comparison: 64 units vs 64-lane AxLLM).
#[derive(Clone, Copy, Debug)]
pub struct ShiftAddSim {
    /// Parallel shift-add units.
    pub units: usize,
    /// Bases (= weight bit width).
    pub q: usize,
    /// Cycles per LUT entry fill during setup (gray-code: one add each).
    pub setup_cost: u32,
    /// Cycles per group-step in the main phase (LUT read + accumulate).
    pub step_cost: u32,
}

impl Default for ShiftAddSim {
    fn default() -> Self {
        ShiftAddSim {
            units: 64,
            q: 8,
            setup_cost: 1,
            step_cost: 2,
        }
    }
}

/// Cycle/operation counts of one ShiftAddLLM vector×matrix multiplication.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShiftAddStats {
    /// LUT-fill (setup-phase) cycles.
    pub setup_cycles: u64,
    /// Main-phase (read + accumulate) cycles.
    pub main_cycles: u64,
    /// LUT entries written during setup.
    pub lut_fills: u64,
    /// LUT reads during the main phase.
    pub lut_reads: u64,
    /// Additions performed.
    pub adds: u64,
}

impl ShiftAddStats {
    /// Total cycles (setup + main).
    pub fn cycles(&self) -> u64 {
        self.setup_cycles + self.main_cycles
    }
}

impl ShiftAddSim {
    /// Timing of `y ≈ x·W` for an `n×c` matrix.
    pub fn matmul_cycles(&self, n: usize, c: usize) -> ShiftAddStats {
        let groups = n.div_ceil(8) as u64;
        let lut_fills = groups * 256;
        let steps = c as u64 * self.q as u64 * groups;
        ShiftAddStats {
            setup_cycles: (lut_fills * self.setup_cost as u64).div_ceil(self.units as u64),
            main_cycles: (steps * self.step_cost as u64).div_ceil(self.units as u64),
            lut_fills,
            lut_reads: steps,
            adds: lut_fills + steps + c as u64 * self.q as u64,
        }
    }

    /// Timing of a whole model (sum over all weight matrices, one input
    /// vector each — same accounting as `Accelerator::run_model`).
    pub fn model_cycles(&self, cfg: &crate::config::ModelConfig) -> u64 {
        let mut total = 0u64;
        for kind in crate::model::MatKind::ALL {
            let (r, c) = kind.shape(cfg);
            total += self.matmul_cycles(r, c).cycles();
        }
        total * cfg.n_layers as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::{synthesize_matrix, WeightDistribution};
    use crate::util::rng::Rng;

    fn small_w(seed: u64) -> QuantMatrix {
        let mut rng = Rng::new(seed);
        synthesize_matrix(24, 16, WeightDistribution::default(), &mut rng)
    }

    #[test]
    fn decomposition_error_shrinks_with_bases() {
        let w = small_w(1);
        let e2 = decompose(&w, 2).rms_error(&w);
        let e4 = decompose(&w, 4).rms_error(&w);
        let e8 = decompose(&w, 8).rms_error(&w);
        assert!(e4 < e2, "{e4} !< {e2}");
        assert!(e8 <= e4, "{e8} !<= {e4}");
        // Power-of-two scale rounding floors the residual: rms ≈ 4 code
        // units (~3% of the ±127 range) is where the greedy pow2
        // decomposition converges.
        assert!(e8 < 6.0, "rms {e8}");
    }

    #[test]
    fn lut_matmul_matches_direct_base_evaluation() {
        let w = small_w(2);
        let d = decompose(&w, 4);
        let mut rng = Rng::new(3);
        let x: Vec<i8> = (0..w.rows)
            .map(|_| rng.range_i64(-50, 50) as i8)
            .collect();
        let via_lut = d.matmul_lut(&x);
        // Direct: y = Σ α_i (x · b_i)
        let mut direct = vec![0f64; w.cols];
        for (b, &e) in d.bases.iter().zip(&d.scale_exp) {
            let alpha = 2f64.powi(e);
            for j in 0..w.cols {
                let mut s = 0i64;
                for i in 0..w.rows {
                    s += x[i] as i64 * b[i * w.cols + j] as i64;
                }
                direct[j] += alpha * s as f64;
            }
        }
        for (a, b) in via_lut.iter().zip(&direct) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn approximation_tracks_exact_matmul() {
        let w = small_w(4);
        let d = decompose(&w, 8);
        let mut rng = Rng::new(5);
        let x: Vec<i8> = (0..w.rows)
            .map(|_| rng.range_i64(-50, 50) as i8)
            .collect();
        let approx = d.matmul_lut(&x);
        let mut exact = vec![0f64; w.cols];
        for i in 0..w.rows {
            for j in 0..w.cols {
                exact[j] += x[i] as f64 * w.get(i, j) as f64;
            }
        }
        // Relative error of the 8-base approximation on the output.
        let num: f64 = approx
            .iter()
            .zip(&exact)
            .map(|(a, e)| (a - e) * (a - e))
            .sum();
        let den: f64 = exact.iter().map(|e| e * e).sum::<f64>().max(1e-9);
        let rel = (num / den).sqrt();
        assert!(rel < 0.2, "relative output error {rel}");
    }

    #[test]
    fn timing_same_steps_as_axllm_but_costlier_per_step() {
        // Paper: "ShiftAddLLM and AxLLM ... require the same number of
        // steps": q·(N/8)·C group-steps = N·C elementary steps at q=8.
        let sim = ShiftAddSim::default();
        let st = sim.matmul_cycles(768, 768);
        assert_eq!(st.lut_reads, 768 / 8 * 8 * 768);
        assert!(st.setup_cycles > 0, "setup phase exists");
        // Main phase alone (2 cycles/step, 64 units): 768·768·2/64.
        assert_eq!(st.main_cycles, 768u64 * 768 * 2 / 64);
    }

    #[test]
    fn model_cycles_scale_with_layers() {
        let sim = ShiftAddSim::default();
        let d1 = crate::config::ModelConfig::distilbert();
        let mut d2 = d1.clone();
        d2.n_layers *= 2;
        assert_eq!(sim.model_cycles(&d2), 2 * sim.model_cycles(&d1));
    }
}
