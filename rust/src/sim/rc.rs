//! The Result Cache (RC) — the key enabler of computation reuse (paper
//! §III.b–c).
//!
//! One RC per lane, `2^(q-1)` entries after sign folding (128 at 8-bit).
//! Entry `u` caches the product `X · u` of the lane's stationary input
//! element with folded weight magnitude `u`. Each entry carries a state:
//!
//! - `Invalid` — value not yet seen for the current input element;
//! - `Pending` — first occurrence issued to the multiplier, result not yet
//!   written back (a repeat arriving now is the §IV read-after-compute
//!   hazard);
//! - `Valid(p)` — product available for 1-cycle reuse.
//!
//! Clearing between input elements resets all valid flags; we use an epoch
//! counter so the clear is O(1), matching the paper's "resetting the valid
//! flags" without a costly sweep in the simulator's hot loop.

/// Entry state as seen by the datapath.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RcState {
    /// Value not yet seen for the current input element.
    Invalid,
    /// First occurrence in flight; a repeat now is the RAW hazard.
    Pending,
    /// Cached product available for 1-cycle reuse.
    Valid(i32),
}

#[derive(Clone, Copy, Debug)]
struct Slot {
    epoch: u32,
    pending: bool,
    product: i32,
}

/// Epoch-cleared result cache.
#[derive(Clone, Debug)]
pub struct ResultCache {
    slots: Vec<Slot>,
    epoch: u32,
    /// Reads this epoch (activity factor).
    pub reads: u64,
    /// Writes this epoch (activity factor).
    pub writes: u64,
}

impl ResultCache {
    /// New cache with `entries` slots (≤ 256), all invalid.
    pub fn new(entries: usize) -> Self {
        assert!(entries > 0 && entries <= 256);
        ResultCache {
            slots: vec![
                Slot {
                    epoch: 0,
                    pending: false,
                    product: 0,
                };
                entries
            ],
            epoch: 1,
            reads: 0,
            writes: 0,
        }
    }

    /// Slot count of the cache.
    pub fn entries(&self) -> usize {
        self.slots.len()
    }

    /// State of entry `u` for the current input element. The valid-flag
    /// check itself is a flag-register read — not counted as a buffer
    /// access (paper §III.c "lightweight logic block").
    #[inline]
    pub fn state(&self, u: u8) -> RcState {
        let s = &self.slots[u as usize];
        if s.epoch != self.epoch {
            RcState::Invalid
        } else if s.pending {
            RcState::Pending
        } else {
            RcState::Valid(s.product)
        }
    }

    /// Mark `u` as issued to the multiplier.
    #[inline]
    pub fn mark_pending(&mut self, u: u8) {
        let e = self.epoch;
        let s = &mut self.slots[u as usize];
        debug_assert!(s.epoch != e, "mark_pending on live entry");
        s.epoch = e;
        s.pending = true;
    }

    /// Multiplier writeback: fill the entry and set the valid flag.
    #[inline]
    pub fn fill(&mut self, u: u8, product: i32) {
        let e = self.epoch;
        let s = &mut self.slots[u as usize];
        debug_assert!(
            s.epoch == e && s.pending,
            "fill must follow mark_pending in the same epoch"
        );
        s.pending = false;
        s.product = product;
        self.writes += 1;
    }

    /// Reuse read of a valid entry (1-cycle buffer access).
    #[inline]
    pub fn read(&mut self, u: u8) -> i32 {
        match self.state(u) {
            RcState::Valid(p) => {
                self.reads += 1;
                p
            }
            other => panic!("RC read of non-valid entry {u}: {other:?}"),
        }
    }

    /// O(1) clear for the next input element ("The RC is also cleared (by
    /// resetting the valid flags) and the algorithm continues with the
    /// next inputs", §III.c).
    #[inline]
    pub fn clear(&mut self) {
        self.epoch = self.epoch.wrapping_add(1);
        if self.epoch == 0 {
            // Epoch wrapped: physically reset so stale epochs cannot alias.
            for s in &mut self.slots {
                s.epoch = 0;
                s.pending = false;
            }
            self.epoch = 1;
        }
    }

    /// Count of currently-valid entries (diagnostics/tests).
    pub fn valid_count(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.epoch == self.epoch && !s.pending)
            .count()
    }
}

/// Map a folded value to its RC slice under range partitioning (paper §IV:
/// *"input slices 1 and 2 may fetch weights with identical or close values
/// at the same time, both requiring the partial result stored in RC slice
/// 2"* — close values share a slice ⇒ contiguous value ranges).
#[inline]
pub fn rc_slice_of(u: u8, entries: usize, slices: usize) -> usize {
    debug_assert!((u as usize) < entries);
    u as usize * slices / entries
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_invalid_pending_valid() {
        let mut rc = ResultCache::new(128);
        assert_eq!(rc.state(5), RcState::Invalid);
        rc.mark_pending(5);
        assert_eq!(rc.state(5), RcState::Pending);
        rc.fill(5, -350);
        assert_eq!(rc.state(5), RcState::Valid(-350));
        assert_eq!(rc.read(5), -350);
        assert_eq!(rc.reads, 1);
        assert_eq!(rc.writes, 1);
    }

    #[test]
    fn clear_invalidates_everything() {
        let mut rc = ResultCache::new(16);
        for u in 0..16u8 {
            rc.mark_pending(u);
            rc.fill(u, u as i32 * 10);
        }
        assert_eq!(rc.valid_count(), 16);
        rc.clear();
        assert_eq!(rc.valid_count(), 0);
        for u in 0..16u8 {
            assert_eq!(rc.state(u), RcState::Invalid);
        }
    }

    #[test]
    fn epoch_wrap_resets_cleanly() {
        let mut rc = ResultCache::new(4);
        rc.mark_pending(1);
        rc.fill(1, 42);
        // Force many clears past the wrap point.
        rc.epoch = u32::MAX - 1;
        rc.clear(); // → MAX
        rc.clear(); // wraps → physical reset, epoch = 1
        for u in 0..4u8 {
            assert_eq!(rc.state(u), RcState::Invalid);
        }
        rc.mark_pending(2);
        rc.fill(2, 7);
        assert_eq!(rc.state(2), RcState::Valid(7));
    }

    #[test]
    #[should_panic(expected = "RC read of non-valid entry")]
    fn read_invalid_panics() {
        let mut rc = ResultCache::new(8);
        rc.read(3);
    }

    #[test]
    fn range_partitioning_keeps_close_values_together() {
        // 128 entries, 4 slices → values 0..31 → slice 0, ..., 96..127 → 3.
        assert_eq!(rc_slice_of(0, 128, 4), 0);
        assert_eq!(rc_slice_of(31, 128, 4), 0);
        assert_eq!(rc_slice_of(32, 128, 4), 1);
        assert_eq!(rc_slice_of(95, 128, 4), 2);
        assert_eq!(rc_slice_of(127, 128, 4), 3);
        // Single slice: everything maps to 0.
        for u in 0..128u8 {
            assert_eq!(rc_slice_of(u, 128, 1), 0);
        }
    }
}
