//! The serial dual-pipeline lane (default timing model — see `sim` module
//! docs for why this reproduces the paper's published numbers).
//!
//! One lane holds the stationary input element `X = x[i]` and streams a
//! chunk of row `i` of W from its W_buff. Per weight element:
//!
//! - **compute path** (first occurrence of a folded value): the multiplier
//!   computes `X·u`, the result is written to `Out_buff` and cached in
//!   `RC[u]` with the valid flag set — `mult_latency` cycles on the
//!   in-order single write port;
//! - **reuse path** (repeat): `RC[u]` is read and written to `Out_buff`,
//!   bypassing the multiplier — `buf_latency` cycles.
//!
//! Sign folding: `u = |w|`; the reuse path negates the cached product when
//! the weight was negative (the 128-entry cache of §V).

use crate::config::AcceleratorConfig;
use crate::quant::fold;
use crate::sim::rc::{RcState, ResultCache};
use crate::sim::{ChunkResult, SimStats};

/// Simulate one (input element × weight chunk) pass through a serial
/// dual-pipeline lane.
pub fn simulate_chunk(x: i8, weights: &[i8], cfg: &AcceleratorConfig) -> ChunkResult {
    assert!(
        weights.len() <= cfg.buffer_entries,
        "chunk ({}) exceeds W_buff ({})",
        weights.len(),
        cfg.buffer_entries
    );
    let mut rc = ResultCache::new(cfg.rc_entries());
    let mut stats = SimStats {
        x_loads: 1,
        ..Default::default()
    };
    let mut partials = Vec::with_capacity(weights.len());

    // Pipeline fill: first W_buff read overlaps the X-register load; the
    // trailing writeback drains after the last element.
    let mut cycles: u64 = cfg.buf_latency as u64;

    for &w in weights {
        stats.w_reads += 1;
        stats.elements += 1;
        let (u, neg) = fold(w);
        match rc.state(u) {
            RcState::Valid(_) => {
                // Reuse path: RC read → Out_buff write.
                let p = rc.read(u);
                partials.push(if neg { -p } else { p });
                cycles += cfg.buf_latency as u64;
                stats.rc_hits += 1;
            }
            RcState::Invalid => {
                // Compute path: multiply → Out_buff write + RC fill.
                let p = (x as i32) * (u as i32);
                rc.mark_pending(u);
                rc.fill(u, p);
                partials.push(if neg { -p } else { p });
                cycles += cfg.mult_latency as u64;
                stats.mults += 1;
            }
            RcState::Pending => unreachable!("serial lane completes each miss before the next fetch"),
        }
        stats.out_writes += 1;
    }
    stats.rc_reads = rc.reads;
    stats.rc_writes = rc.writes;
    stats.cycles = cycles;
    ChunkResult { stats, partials }
}

/// Closed-form cycle count for a chunk with `unique` distinct folded
/// values (used by tests and by fast analytical sweeps):
/// `buf + unique·mult_latency + (n−unique)·buf_latency`.
pub fn serial_cycles(n: u64, unique: u64, cfg: &AcceleratorConfig) -> u64 {
    cfg.buf_latency as u64
        + unique * cfg.mult_latency as u64
        + (n - unique) * cfg.buf_latency as u64
}

/// The §IV "AxLLM pipeline" hazard model: fetch one weight per cycle; a
/// first occurrence enters the multiplier at t+1 and writes back at
/// t+mult_latency+1; a **repeat fetched before the writeback** is the
/// read-after-compute hazard and stalls the reuse path until the result
/// is available. Returns `(hazard_stall_cycles, total_cycles)` for one
/// chunk — the statistic behind the paper's "<2%" claim.
pub fn pipelined_hazard_scan(weights: &[i8], cfg: &AcceleratorConfig) -> (u64, u64) {
    let mut ready_at = [u64::MAX; 128]; // per folded value: writeback cycle
    let mut seen = [false; 128];
    let mut stalls = 0u64;
    let mut cycle = cfg.buf_latency as u64;
    for &w in weights {
        cycle += 1; // one fetch per cycle
        let (u, _) = fold(w);
        let ui = u as usize;
        if !seen[ui] {
            seen[ui] = true;
            ready_at[ui] = cycle + cfg.mult_latency as u64 + 1;
        } else if cycle < ready_at[ui] {
            let wait = ready_at[ui] - cycle;
            stalls += wait;
            cycle += wait;
        }
    }
    (stalls, cycle)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AcceleratorConfig {
        AcceleratorConfig::default()
    }

    #[test]
    fn partials_match_dense_products() {
        let weights: Vec<i8> = vec![3, -3, 5, 0, 5, -5, 127, -127, 0, 3];
        let x = -7i8;
        let r = simulate_chunk(x, &weights, &cfg());
        let expect: Vec<i32> = weights.iter().map(|&w| x as i32 * w as i32).collect();
        assert_eq!(r.partials, expect);
    }

    #[test]
    fn unique_values_multiplied_once() {
        let weights: Vec<i8> = vec![3, -3, 5, 0, 5, -5, 127, -127, 0, 3];
        let r = simulate_chunk(2, &weights, &cfg());
        // folded uniques: {3, 5, 0, 127} → 4 multiplies, 6 reuses.
        assert_eq!(r.stats.mults, 4);
        assert_eq!(r.stats.rc_hits, 6);
        assert_eq!(r.stats.elements, 10);
        assert_eq!(r.stats.rc_writes, 4);
        assert_eq!(r.stats.rc_reads, 6);
    }

    #[test]
    fn cycles_follow_hit1_miss3_model() {
        let weights: Vec<i8> = vec![3, -3, 5, 0, 5, -5, 127, -127, 0, 3];
        let c = cfg();
        let r = simulate_chunk(2, &weights, &c);
        assert_eq!(r.stats.cycles, serial_cycles(10, 4, &c));
        assert_eq!(r.stats.cycles, 1 + 4 * 3 + 6);
    }

    #[test]
    fn all_same_value_is_fastest() {
        let c = cfg();
        let same = simulate_chunk(9, &[7i8; 64], &c);
        let distinct: Vec<i8> = (0..64).map(|i| i as i8).collect();
        let worst = simulate_chunk(9, &distinct, &c);
        assert_eq!(same.stats.mults, 1);
        assert_eq!(same.stats.cycles, 1 + 3 + 63);
        assert_eq!(worst.stats.mults, 64);
        assert_eq!(worst.stats.cycles, 1 + 64 * 3);
        assert!(same.stats.cycles < worst.stats.cycles);
    }

    #[test]
    fn reuse_speedup_matches_paper_formula() {
        // r = 0.70 reuse → AxLLM/baseline cycle ratio ≈ (0.3·3 + 0.7)/3 =
        // 0.533, the paper's DistilBERT 85.11/159.34.
        let n = 1000u64;
        let unique = 300u64;
        let c = cfg();
        let ax = serial_cycles(n, unique, &c) as f64;
        let base = n as f64 * c.mult_latency as f64 + c.buf_latency as f64;
        let ratio = ax / base;
        assert!((ratio - 0.534).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn zero_weight_is_cached_like_any_value() {
        // AxLLM makes no zero-skipping assumption: 0 is a unique value,
        // multiplied once, reused after.
        let r = simulate_chunk(5, &[0i8, 0, 0, 0], &cfg());
        assert_eq!(r.stats.mults, 1);
        assert_eq!(r.stats.rc_hits, 3);
        assert_eq!(r.partials, vec![0, 0, 0, 0]);
    }

    #[test]
    fn negative_x_and_sign_folding_interact_correctly() {
        let r = simulate_chunk(-128i8 + 1, &[-127i8, 127], &cfg());
        assert_eq!(r.partials, vec![(-127i32) * (-127), (-127i32) * 127]);
        assert_eq!(r.stats.mults, 1, "127 and -127 share one RC slot");
    }

    #[test]
    #[should_panic(expected = "exceeds W_buff")]
    fn oversized_chunk_rejected() {
        let weights = vec![1i8; 257];
        simulate_chunk(1, &weights, &cfg());
    }

    #[test]
    fn empty_chunk_costs_only_fill() {
        let r = simulate_chunk(1, &[], &cfg());
        assert_eq!(r.stats.cycles, 1);
        assert_eq!(r.stats.elements, 0);
        assert!(r.partials.is_empty());
    }
}
