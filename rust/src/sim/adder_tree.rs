//! The adder tree accumulating per-lane partial sums into the global
//! output buffer (paper Fig. 3).
//!
//! Lane i produces `x[i]·W[i,j]` for every column j of the current round;
//! the tree sums across lanes element-wise. It is a pipelined binary tree
//! of depth ⌈log₂ L⌉ draining `slices` columns per cycle (one output per
//! Out_buff slice port).

use crate::sim::SimStats;

/// Accumulate `lane_partials` (one vector per active lane, equal lengths)
/// into `acc`, updating `stats` with the add count and drain cycles.
///
/// `overlap_drain`: with double-buffered output buffers the drain of round
/// k overlaps the compute of round k+1, so only the pipeline depth shows
/// up in the critical path; without it the full drain serializes.
pub fn accumulate(
    acc: &mut [i32],
    lane_partials: &[Vec<i32>],
    slices: usize,
    overlap_drain: bool,
    stats: &mut SimStats,
) {
    if lane_partials.is_empty() {
        return;
    }
    let width = lane_partials[0].len();
    assert!(
        lane_partials.iter().all(|p| p.len() == width),
        "ragged lane partials"
    );
    assert!(acc.len() >= width);

    let lanes = lane_partials.len();
    for j in 0..width {
        let mut s = 0i64;
        for p in lane_partials {
            s += p[j] as i64;
        }
        // Tree adds: lanes-1 per column, +1 accumulate into the global
        // output buffer (across lane groups).
        acc[j] = acc[j].wrapping_add(s as i32);
        stats.adds += lanes as u64; // (lanes-1) tree + 1 global accumulate
    }

    let depth = (lanes.max(2) as f64).log2().ceil() as u64;
    let drain = (width as u64).div_ceil(slices as u64);
    stats.cycles += if overlap_drain { depth } else { drain + depth };
}

/// Account the adder-tree cost of one lane group without materializing
/// per-lane partial vectors (the accelerator accumulates in place):
/// `lanes` adds per column (tree + global accumulate) plus the drain
/// cycles of [`accumulate`].
pub fn drain_cost(
    lanes: usize,
    width: usize,
    slices: usize,
    overlap_drain: bool,
    stats: &mut SimStats,
) {
    if lanes == 0 || width == 0 {
        return;
    }
    stats.adds += (lanes * width) as u64;
    let depth = (lanes.max(2) as f64).log2().ceil() as u64;
    let drain = (width as u64).div_ceil(slices as u64);
    stats.cycles += if overlap_drain { depth } else { drain + depth };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sums_across_lanes() {
        let mut acc = vec![0i32; 4];
        let parts = vec![vec![1, 2, 3, 4], vec![10, 20, 30, 40], vec![-1, -2, -3, -4]];
        let mut stats = SimStats::default();
        accumulate(&mut acc, &parts, 4, true, &mut stats);
        assert_eq!(acc, vec![10, 20, 30, 40]);
        assert_eq!(stats.adds, 12);
    }

    #[test]
    fn accumulates_into_existing_values() {
        let mut acc = vec![100i32, 200];
        let parts = vec![vec![1, 1]];
        let mut stats = SimStats::default();
        accumulate(&mut acc, &parts, 1, true, &mut stats);
        assert_eq!(acc, vec![101, 201]);
    }

    #[test]
    fn drain_cycles_depend_on_overlap() {
        let parts = vec![vec![0i32; 256]; 64];
        let mut acc = vec![0i32; 256];
        let mut s_overlap = SimStats::default();
        accumulate(&mut acc, &parts, 4, true, &mut s_overlap);
        let mut s_serial = SimStats::default();
        accumulate(&mut acc, &parts, 4, false, &mut s_serial);
        assert_eq!(s_overlap.cycles, 6); // log2(64)
        assert_eq!(s_serial.cycles, 64 + 6); // 256/4 + depth
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_inputs_rejected() {
        let mut acc = vec![0i32; 2];
        let parts = vec![vec![1, 2], vec![3]];
        accumulate(&mut acc, &parts, 1, true, &mut SimStats::default());
    }

    #[test]
    fn empty_lane_set_is_noop() {
        let mut acc = vec![5i32];
        let mut stats = SimStats::default();
        accumulate(&mut acc, &[], 4, true, &mut stats);
        assert_eq!(acc, vec![5]);
        assert_eq!(stats.cycles, 0);
    }
}
