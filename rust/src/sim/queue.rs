//! Fixed-capacity FIFO with credit-based backpressure (paper §IV:
//! *"A credit-based back-pressure flow control mechanism is used between
//! upstream and downstream buffers (e.g., between W_buff and the RC) to
//! prevent writes to full queues"*).
//!
//! The upstream holds one credit per free slot; `try_push` models a
//! credit-gated write (fails ⇒ the producer stalls this cycle).

/// Bounded FIFO. Capacity is fixed at construction (queue depth S).
#[derive(Clone, Debug)]
pub struct Queue<T> {
    items: std::collections::VecDeque<T>,
    cap: usize,
}

impl<T> Queue<T> {
    /// New empty queue with `cap` slots.
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "queue capacity must be ≥ 1");
        Queue {
            items: std::collections::VecDeque::with_capacity(cap),
            cap,
        }
    }

    /// Remaining credits (free slots).
    #[inline]
    pub fn credits(&self) -> usize {
        self.cap - self.items.len()
    }

    /// True when no credits remain.
    #[inline]
    pub fn is_full(&self) -> bool {
        self.items.len() == self.cap
    }

    /// True when nothing is queued.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Queued item count.
    #[inline]
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// Fixed capacity (queue depth S).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Credit-gated push: `false` means no credit — the producer must
    /// stall and retry next cycle.
    #[inline]
    pub fn try_push(&mut self, item: T) -> bool {
        if self.is_full() {
            false
        } else {
            self.items.push_back(item);
            true
        }
    }

    /// Pop the oldest item, if any.
    #[inline]
    pub fn pop(&mut self) -> Option<T> {
        self.items.pop_front()
    }

    /// Borrow the oldest item without popping.
    #[inline]
    pub fn peek(&self) -> Option<&T> {
        self.items.front()
    }

    /// Drop every queued item (epoch boundary).
    pub fn clear(&mut self) {
        self.items.clear();
    }
}

/// Round-robin arbiter over `n` requesters: remembers the last grant and
/// starts the next scan after it (paper §IV: *"inputs are read in a
/// round-robin fashion"*).
#[derive(Clone, Debug)]
pub struct RoundRobin {
    n: usize,
    next: usize,
}

impl RoundRobin {
    /// New arbiter over `n` requesters, starting at index 0.
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        RoundRobin { n, next: 0 }
    }

    /// Grant to the first index (in round-robin order) for which `ready`
    /// returns true; advances the pointer past the grant.
    pub fn grant<F: FnMut(usize) -> bool>(&mut self, mut ready: F) -> Option<usize> {
        for k in 0..self.n {
            let i = (self.next + k) % self.n;
            if ready(i) {
                self.next = (i + 1) % self.n;
                return Some(i);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut q = Queue::new(3);
        assert!(q.try_push(1));
        assert!(q.try_push(2));
        assert!(q.try_push(3));
        assert!(!q.try_push(4), "full queue must refuse");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert!(q.try_push(4));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn credits_track_occupancy() {
        let mut q = Queue::new(4);
        assert_eq!(q.credits(), 4);
        q.try_push(());
        q.try_push(());
        assert_eq!(q.credits(), 2);
        q.pop();
        assert_eq!(q.credits(), 3);
    }

    #[test]
    #[should_panic(expected = "capacity must be")]
    fn zero_capacity_rejected() {
        let _ = Queue::<u8>::new(0);
    }

    #[test]
    fn round_robin_is_fair() {
        let mut rr = RoundRobin::new(3);
        // All always ready → grants cycle 0,1,2,0,1,2.
        let grants: Vec<usize> = (0..6).map(|_| rr.grant(|_| true).unwrap()).collect();
        assert_eq!(grants, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_skips_not_ready() {
        let mut rr = RoundRobin::new(3);
        assert_eq!(rr.grant(|i| i == 2), Some(2));
        // pointer now past 2 → next scan starts at 0
        assert_eq!(rr.grant(|_| true), Some(0));
        assert_eq!(rr.grant(|_| false), None);
    }

    #[test]
    fn peek_does_not_consume() {
        let mut q = Queue::new(2);
        q.try_push(7);
        assert_eq!(q.peek(), Some(&7));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some(7));
    }
}
