//! The Fig. 9 normalization baseline: *"the AxLLM architecture with just
//! multipliers (and not the reuse buffer)"* — identical lane/buffer
//! organization, but every weight element takes the compute path.

use crate::config::AcceleratorConfig;
use crate::sim::{ChunkResult, SimStats};

/// Simulate one (input element × weight chunk) pass through a multiply-only
/// lane: every element occupies the multiplier for `mult_latency` cycles.
pub fn simulate_chunk(x: i8, weights: &[i8], cfg: &AcceleratorConfig) -> ChunkResult {
    assert!(
        weights.len() <= cfg.buffer_entries,
        "chunk ({}) exceeds W_buff ({})",
        weights.len(),
        cfg.buffer_entries
    );
    let mut stats = SimStats {
        x_loads: 1,
        ..Default::default()
    };
    let mut partials = Vec::with_capacity(weights.len());
    for &w in weights {
        stats.w_reads += 1;
        stats.elements += 1;
        stats.mults += 1;
        stats.out_writes += 1;
        partials.push(x as i32 * w as i32);
    }
    stats.cycles = cfg.buf_latency as u64 + weights.len() as u64 * cfg.mult_latency as u64;
    ChunkResult { stats, partials }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_element_multiplied() {
        let cfg = AcceleratorConfig::baseline();
        let weights: Vec<i8> = vec![5, 5, 5, -5, 0];
        let r = simulate_chunk(3, &weights, &cfg);
        assert_eq!(r.stats.mults, 5);
        assert_eq!(r.stats.rc_hits, 0);
        assert_eq!(r.stats.cycles, 1 + 5 * 3);
        assert_eq!(r.partials, vec![15, 15, 15, -15, 0]);
    }

    #[test]
    fn matches_reuse_lane_functionally() {
        let cfg = AcceleratorConfig::default();
        let weights: Vec<i8> = (0..100).map(|i| ((i * 37) % 255 - 127) as i8).collect();
        let b = simulate_chunk(-9, &weights, &cfg);
        let a = crate::sim::lane::simulate_chunk(-9, &weights, &cfg);
        assert_eq!(a.partials, b.partials);
        assert!(a.stats.cycles <= b.stats.cycles);
    }
}
