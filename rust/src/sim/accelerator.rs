//! The full AxLLM accelerator: L lanes + adder tree + global output
//! buffer, orchestrated over the input-stationary schedule with
//! bounded-column rounds (paper Fig. 3, §IV "Buffer size management").
//!
//! A vector×matrix multiplication `y = x·W` (x: R elements, W: R×C) runs
//! as:
//!
//! ```text
//! for round in column blocks of min(buffer_entries, round_cols):
//!   for group in row blocks of L lanes:
//!     lane j streams W[group·L + j, round] against x[group·L + j]
//!     adder tree accumulates the L partial-sum vectors into y[round]
//! ```
//!
//! Rounds bound the number of incomplete output cells to the block width
//! (§IV); lanes in a group run concurrently (cycles take the max), groups
//! and rounds serialize.

use crate::config::AcceleratorConfig;
use crate::model::{MatKind, Model};
use crate::quant::QuantMatrix;
use crate::sim::{adder_tree, LaneModel, SimStats};
use crate::util::pool::par_map;
use anyhow::anyhow;

/// Result of one simulated vector×matrix multiplication.
#[derive(Clone, Debug)]
pub struct MatmulResult {
    /// Cycle/activity counters of the simulated multiplication.
    pub stats: SimStats,
    /// `y = x·W` in i32 accumulator precision (empty for sampled runs).
    pub output: Vec<i32>,
}

/// The simulated accelerator instance.
#[derive(Clone, Copy, Debug)]
pub struct Accelerator {
    /// Micro-architecture sizing of this instance.
    pub cfg: AcceleratorConfig,
    /// Lane timing model chunks dispatch through.
    pub lane_model: LaneModel,
    /// Double-buffered Out_buffs: adder-tree drain overlaps the next
    /// round (design choice ablated in `report::ablation`).
    pub overlap_drain: bool,
}

/// Validating constructor for [`Accelerator`] instances.
///
/// `Accelerator::axllm` / `Accelerator::baseline` accept whatever sizing
/// they are given; the builder is the checked front door — it rejects
/// nonsense sizings (zero lanes, non-power-of-two slicing, slices wider
/// than the buffer, a reuse-pipeline lane model with the Result Cache
/// disabled) before a single cycle is simulated.
#[derive(Clone, Copy, Debug)]
pub struct AcceleratorBuilder {
    cfg: AcceleratorConfig,
    lane_model: Option<LaneModel>,
    overlap_drain: bool,
}

impl Default for AcceleratorBuilder {
    fn default() -> Self {
        Accelerator::builder()
    }
}

impl AcceleratorBuilder {
    /// Start from a whole config (field setters below still apply on top).
    pub fn config(mut self, cfg: AcceleratorConfig) -> Self {
        self.cfg = cfg;
        self
    }

    /// Number of parallel lanes (L).
    pub fn lanes(mut self, n: usize) -> Self {
        self.cfg.lanes = n;
        self
    }

    /// W_buff / Out_buff entries per lane.
    pub fn buffer_entries(mut self, n: usize) -> Self {
        self.cfg.buffer_entries = n;
        self
    }

    /// Buffer/RC slices per lane (P-way parallelism).
    pub fn slices(mut self, n: usize) -> Self {
        self.cfg.slices = n;
        self
    }

    /// Collision-queue depth in front of RC/Out_buff slices.
    pub fn queue_depth(mut self, n: usize) -> Self {
        self.cfg.queue_depth = n;
        self
    }

    /// Enable or disable the Result Cache (reuse path).
    pub fn reuse(mut self, enabled: bool) -> Self {
        self.cfg.reuse_enabled = enabled;
        self
    }

    /// Force a specific lane model (default: derived from `reuse`).
    pub fn lane_model(mut self, m: LaneModel) -> Self {
        self.lane_model = Some(m);
        self
    }

    /// Double-buffered Out_buffs (adder-tree drain overlaps next round).
    pub fn overlap_drain(mut self, v: bool) -> Self {
        self.overlap_drain = v;
        self
    }

    /// Validate the sizing and construct the accelerator.
    pub fn build(self) -> crate::Result<Accelerator> {
        // Builder-specific checks run first so their messages are the ones
        // users see (validate()'s divisibility rule also catches a slice
        // count above the buffer size, with a less direct message).
        if !self.cfg.slices.is_power_of_two() {
            return Err(anyhow!(
                "slices ({}) must be a power of two",
                self.cfg.slices
            ));
        }
        if self.cfg.slices > self.cfg.buffer_entries {
            return Err(anyhow!(
                "slices ({}) must not exceed buffer_entries ({})",
                self.cfg.slices,
                self.cfg.buffer_entries
            ));
        }
        self.cfg.validate()?;
        let lane_model = self.lane_model.unwrap_or(if self.cfg.reuse_enabled {
            LaneModel::Serial
        } else {
            LaneModel::Baseline
        });
        if !self.cfg.reuse_enabled && lane_model != LaneModel::Baseline {
            return Err(anyhow!(
                "lane model {lane_model:?} needs the reuse path; enable reuse or use LaneModel::Baseline"
            ));
        }
        Ok(Accelerator {
            cfg: self.cfg,
            lane_model,
            overlap_drain: self.overlap_drain,
        })
    }
}

impl Accelerator {
    /// Checked construction: start from the paper sizing, override fields,
    /// and validate with [`AcceleratorBuilder::build`].
    pub fn builder() -> AcceleratorBuilder {
        AcceleratorBuilder {
            cfg: AcceleratorConfig::paper(),
            lane_model: None,
            overlap_drain: true,
        }
    }

    /// AxLLM in its paper configuration.
    pub fn axllm(cfg: AcceleratorConfig) -> Self {
        let lane_model = if cfg.reuse_enabled {
            LaneModel::Serial
        } else {
            LaneModel::Baseline
        };
        Accelerator {
            cfg,
            lane_model,
            overlap_drain: true,
        }
    }

    /// The Fig. 9 multiply-only baseline at the same sizing.
    pub fn baseline(cfg: AcceleratorConfig) -> Self {
        Accelerator {
            cfg: AcceleratorConfig {
                reuse_enabled: false,
                ..cfg
            },
            lane_model: LaneModel::Baseline,
            overlap_drain: true,
        }
    }

    /// Switch to the P-way sliced lane model (§IV ablation).
    pub fn with_lane_model(mut self, m: LaneModel) -> Self {
        self.lane_model = m;
        self
    }

    /// W_buff-bounded column-chunk width: the number of weight elements a
    /// lane streams per round, and therefore the span one Result-Cache
    /// fill can be reused across. The functional backend uses the same
    /// bound so its reuse accounting matches the simulated datapath.
    pub fn chunk_cols(&self) -> usize {
        self.cfg.buffer_entries.min(self.cfg.round_cols)
    }

    /// The lane timing model this instance dispatches through.
    pub fn lane_sim(&self) -> &'static dyn crate::sim::LaneSim {
        self.lane_model.sim()
    }

    fn run_chunk(&self, x: i8, weights: &[i8]) -> crate::sim::ChunkResult {
        self.lane_sim().simulate_chunk(x, weights, &self.cfg)
    }

    /// Simulate `y = x·W` completely (cycles + functional output).
    pub fn matmul(&self, x: &[i8], w: &QuantMatrix) -> MatmulResult {
        assert_eq!(x.len(), w.rows, "x length must match W rows");
        let r = w.rows;
        let c = w.cols;
        let chunk = self.chunk_cols();
        let lanes = self.cfg.lanes;
        let mut output = vec![0i32; c];
        let mut stats = SimStats::default();

        let mut col = 0;
        while col < c {
            let width = chunk.min(c - col);
            let mut row = 0;
            while row < r {
                let group = lanes.min(r - row);
                // Lanes within a group run concurrently; simulate each,
                // merge with cycles = max, and accumulate its partial
                // sums straight into the output block (§Perf: avoids
                // holding `group` partial vectors and a second pass —
                // the adder-tree cost model is applied identically).
                let mut group_stats = SimStats::default();
                for j in 0..group {
                    let rr = row + j;
                    let res = self.run_chunk(x[rr], &w.row(rr)[col..col + width]);
                    group_stats.merge_parallel(&res.stats);
                    for (yj, p) in output[col..col + width].iter_mut().zip(&res.partials) {
                        *yj = yj.wrapping_add(*p);
                    }
                }
                adder_tree::drain_cost(
                    group,
                    width,
                    self.cfg.slices,
                    self.overlap_drain,
                    &mut group_stats,
                );
                stats.merge(&group_stats);
                row += group;
            }
            col += width;
        }
        MatmulResult { stats, output }
    }

    /// Simulate only the first `sample_rows` rows of W and scale counters
    /// to the full matrix — row-sampled measurement for Llama-scale
    /// matrices, where cycles and activity are row-homogeneous. No
    /// functional output.
    pub fn matmul_sampled(&self, x: &[i8], w: &QuantMatrix, sample_rows: usize) -> MatmulResult {
        let n = sample_rows.min(w.rows).max(1);
        // Round the sample to whole lane groups so group-max effects scale.
        let n = n.div_ceil(self.cfg.lanes.min(n)) * self.cfg.lanes.min(n);
        let n = n.min(w.rows);
        if n == w.rows {
            return self.matmul(x, w);
        }
        let sampled = QuantMatrix::from_q(
            n,
            w.cols,
            w.data[..n * w.cols].to_vec(),
            w.params,
        );
        let res = self.matmul(&x[..n], &sampled);
        MatmulResult {
            stats: res.stats.scaled(w.rows as u64, n as u64),
            output: Vec::new(),
        }
    }

    /// Simulate a whole model variant: every weight matrix of every layer
    /// of `model`, with one representative input vector per matrix, using
    /// row sampling above `sample_rows`. Layers run via the thread pool
    /// (simulation-host parallelism only — simulated cycles still
    /// serialize across matrices).
    pub fn run_model(&self, model: &Model, sample_rows: usize, seed: u64) -> ModelCycleSummary {
        let layers: Vec<usize> = (0..model.config.n_layers).collect();
        let per_layer: Vec<SimStats> = par_map(layers, |l| {
            let mut layer_stats = SimStats::default();
            for kind in MatKind::ALL {
                let (rows, _) = kind.shape(&model.config);
                // Sample whole lane groups: a partial group occupies the
                // same cycles as a full one, which would skew the
                // row-scaled extrapolation.
                let n = sample_rows.max(self.cfg.lanes).min(rows);
                let w = model.matrix_rows(l, kind, n);
                let x = synth_input(rows.min(n), seed ^ (l as u64) << 3 ^ kind as u64);
                let res = if n < rows {
                    // matmul over the sampled rows, scaled up.
                    let r = self.matmul(&x, &w);
                    MatmulResult {
                        stats: r.stats.scaled(rows as u64, n as u64),
                        output: Vec::new(),
                    }
                } else {
                    self.matmul(&x, &w)
                };
                layer_stats.merge(&res.stats);
            }
            layer_stats
        });
        let mut total = SimStats::default();
        for s in &per_layer {
            total.merge(s);
        }
        ModelCycleSummary {
            model: model.config.name.clone(),
            total,
            per_layer,
        }
    }
}

/// Deterministic synthetic int8 activation vector.
pub fn synth_input(n: usize, seed: u64) -> Vec<i8> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..n)
        .map(|_| (rng.normal() * 40.0).round().clamp(-127.0, 127.0) as i8)
        .collect()
}

/// Cycle/activity summary of one model run.
#[derive(Clone, Debug)]
pub struct ModelCycleSummary {
    /// Name of the simulated model.
    pub model: String,
    /// Counters summed over every layer.
    pub total: SimStats,
    /// Per-layer counters, in layer order.
    pub per_layer: Vec<SimStats>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::synth::{synthesize_matrix, WeightDistribution};
    use crate::util::rng::Rng;

    fn dense(x: &[i8], w: &QuantMatrix) -> Vec<i32> {
        let mut y = vec![0i32; w.cols];
        for (i, &xi) in x.iter().enumerate() {
            for (j, &wij) in w.row(i).iter().enumerate() {
                y[j] += xi as i32 * wij as i32;
            }
        }
        y
    }

    fn small_case(rows: usize, cols: usize, seed: u64) -> (Vec<i8>, QuantMatrix) {
        let mut rng = Rng::new(seed);
        let w = synthesize_matrix(rows, cols, WeightDistribution::default(), &mut rng);
        let x = synth_input(rows, seed ^ 1);
        (x, w)
    }

    #[test]
    fn matmul_matches_dense_all_lane_models() {
        let (x, w) = small_case(100, 70, 42);
        for lm in [LaneModel::Baseline, LaneModel::Serial, LaneModel::Sliced] {
            let acc = Accelerator::axllm(AcceleratorConfig {
                lanes: 16,
                ..AcceleratorConfig::default()
            })
            .with_lane_model(lm);
            let res = acc.matmul(&x, &w);
            assert_eq!(res.output, dense(&x, &w), "{lm:?}");
        }
    }

    #[test]
    fn reuse_beats_baseline_cycles() {
        let (x, w) = small_case(128, 512, 7);
        let cfg = AcceleratorConfig {
            lanes: 16,
            ..AcceleratorConfig::default()
        };
        let ax = Accelerator::axllm(cfg).matmul(&x, &w);
        let base = Accelerator::baseline(cfg).matmul(&x, &w);
        assert_eq!(ax.output, base.output);
        let speedup = base.stats.cycles as f64 / ax.stats.cycles as f64;
        assert!(speedup > 1.3, "speedup {speedup}");
        assert!(ax.stats.mults < base.stats.mults / 2);
    }

    #[test]
    fn rounds_bound_incomplete_outputs() {
        // Column blocks: a 16×600 matrix with chunk 256 → 3 rounds.
        let (x, w) = small_case(16, 600, 9);
        let acc = Accelerator::axllm(AcceleratorConfig {
            lanes: 16,
            ..AcceleratorConfig::default()
        });
        let res = acc.matmul(&x, &w);
        assert_eq!(res.output, dense(&x, &w));
        // Every element still processed exactly once.
        assert_eq!(res.stats.elements, 16 * 600);
    }

    #[test]
    fn groups_serialize_rows_beyond_lane_count() {
        let cfg = AcceleratorConfig {
            lanes: 8,
            ..AcceleratorConfig::default()
        };
        let (x, w) = small_case(32, 64, 3);
        let res = Accelerator::axllm(cfg).matmul(&x, &w);
        // 4 groups of 8 lanes; cycles must be ≥ 4 × min-group-cycles.
        assert!(res.stats.cycles >= 4 * 64);
        assert_eq!(res.output, dense(&x, &w));
    }

    #[test]
    fn sampled_run_scales_counters() {
        let (x, w) = small_case(128, 128, 11);
        let acc = Accelerator::axllm(AcceleratorConfig {
            lanes: 32,
            ..AcceleratorConfig::default()
        });
        let full = acc.matmul(&x, &w);
        let sampled = acc.matmul_sampled(&x, &w, 32);
        let ratio = sampled.stats.elements as f64 / full.stats.elements as f64;
        assert!((0.95..1.05).contains(&ratio), "elements ratio {ratio}");
        let cyc = sampled.stats.cycles as f64 / full.stats.cycles as f64;
        assert!((0.8..1.2).contains(&cyc), "cycle ratio {cyc}");
    }

    #[test]
    fn run_model_covers_all_matrices() {
        let model = Model::new(ModelConfig::tiny(), 5);
        let acc = Accelerator::axllm(AcceleratorConfig {
            lanes: 32,
            ..AcceleratorConfig::default()
        });
        let summary = acc.run_model(&model, 64, 1);
        assert_eq!(summary.per_layer.len(), 2);
        let cfg = ModelConfig::tiny();
        let expect_elems: u64 = (2 * (4 * cfg.d_model * cfg.d_model
            + 2 * cfg.d_model * cfg.d_ff)) as u64;
        // 64-row sampling on ≤256-row matrices: d_model=128 full, d_ff=256
        // sampled at 64 then scaled ×4 — totals must land on the exact
        // element count.
        assert_eq!(summary.total.elements, expect_elems);
        assert!(summary.total.reuse_rate() > 0.5);
    }

    #[test]
    fn builder_rejects_nonsense_sizings() {
        assert!(Accelerator::builder().lanes(0).build().is_err());
        assert!(Accelerator::builder().buffer_entries(0).build().is_err());
        // 3 divides 192, but slices must be a power of two.
        assert!(Accelerator::builder()
            .buffer_entries(192)
            .slices(3)
            .build()
            .is_err());
        // Slices wider than the buffer.
        assert!(Accelerator::builder()
            .buffer_entries(256)
            .slices(512)
            .build()
            .is_err());
        // Reuse-pipeline lane models need the Result Cache.
        assert!(Accelerator::builder()
            .reuse(false)
            .lane_model(LaneModel::Sliced)
            .build()
            .is_err());
    }

    #[test]
    fn builder_derives_lane_model_from_reuse() {
        let ax = Accelerator::builder().lanes(16).build().unwrap();
        assert_eq!(ax.lane_model, LaneModel::Serial);
        assert_eq!(ax.cfg.lanes, 16);
        assert!(ax.overlap_drain);
        let base = Accelerator::builder().reuse(false).build().unwrap();
        assert_eq!(base.lane_model, LaneModel::Baseline);
        let sliced = Accelerator::builder()
            .lane_model(LaneModel::Sliced)
            .overlap_drain(false)
            .build()
            .unwrap();
        assert_eq!(sliced.lane_model, LaneModel::Sliced);
        assert!(!sliced.overlap_drain);
    }

    #[test]
    fn builder_matmul_matches_legacy_constructors() {
        let (x, w) = small_case(64, 48, 21);
        let cfg = AcceleratorConfig {
            lanes: 16,
            ..AcceleratorConfig::default()
        };
        let built = Accelerator::builder().config(cfg).build().unwrap().matmul(&x, &w);
        let legacy = Accelerator::axllm(cfg).matmul(&x, &w);
        assert_eq!(built.output, legacy.output);
        assert_eq!(built.stats, legacy.stats);
    }

    #[test]
    fn x_shorter_than_rows_rejected() {
        let (x, w) = small_case(16, 16, 13);
        let acc = Accelerator::axllm(AcceleratorConfig::default());
        let r = std::panic::catch_unwind(|| acc.matmul(&x[..8], &w));
        assert!(r.is_err());
    }
}
