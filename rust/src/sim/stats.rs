//! Simulation counters: cycles, operation counts, stall taxonomy, and the
//! per-component activity factors the energy model consumes.

/// Counters accumulated by every lane/accelerator simulation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimStats {
    /// Simulated clock cycles.
    pub cycles: u64,
    /// Weight elements processed.
    pub elements: u64,
    /// Multiplications actually performed (compute-path traversals).
    pub mults: u64,
    /// Reuse-path traversals (RC hits).
    pub rc_hits: u64,
    /// RC fills (valid-flag sets; equals compute-path traversals that
    /// cached their result).
    pub rc_writes: u64,
    /// RC reads (hit lookups; the valid-flag check itself is free — a
    /// flag-register file, paper §III.c "lightweight logic block").
    pub rc_reads: u64,
    /// Cycles stalled on the read-after-compute hazard (repeat of a value
    /// whose multiply is still in flight, §IV).
    pub hazard_stalls: u64,
    /// Cycles a fetch stalled because a collision queue was full
    /// (credit-based backpressure, §IV).
    pub backpressure_stalls: u64,
    /// Requests that found their RC slice busy with another slice's
    /// request in the same cycle (collision serialization, §IV).
    pub collisions: u64,
    /// W_buff reads.
    pub w_reads: u64,
    /// Out_buff writes (partial-sum commits).
    pub out_writes: u64,
    /// Queue push+pop pairs through the collision/output queues.
    pub queue_ops: u64,
    /// Adder-tree additions (accumulation across lanes).
    pub adds: u64,
    /// Input-register loads (one per (input element, round)).
    pub x_loads: u64,
}

impl SimStats {
    /// Fraction of products served by the Result Cache.
    pub fn reuse_rate(&self) -> f64 {
        if self.elements == 0 {
            0.0
        } else {
            self.rc_hits as f64 / self.elements as f64
        }
    }

    /// Fraction of cycles lost to RAW hazards (the paper claims <2%).
    pub fn hazard_rate(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.hazard_stalls as f64 / self.cycles as f64
        }
    }

    /// Multiplication reduction vs. performing every product.
    pub fn mult_reduction(&self) -> f64 {
        if self.elements == 0 {
            0.0
        } else {
            1.0 - self.mults as f64 / self.elements as f64
        }
    }

    /// Merge counters (cycles add — use [`SimStats::merge_parallel`] for
    /// lanes that run concurrently).
    pub fn merge(&mut self, o: &SimStats) {
        self.cycles += o.cycles;
        self.merge_activity(o);
    }

    /// Merge counters from a concurrent unit: cycles take the max (lanes
    /// run in lock-step; the slowest one gates the group), activity adds.
    pub fn merge_parallel(&mut self, o: &SimStats) {
        self.cycles = self.cycles.max(o.cycles);
        self.merge_activity(o);
    }

    fn merge_activity(&mut self, o: &SimStats) {
        self.elements += o.elements;
        self.mults += o.mults;
        self.rc_hits += o.rc_hits;
        self.rc_writes += o.rc_writes;
        self.rc_reads += o.rc_reads;
        self.hazard_stalls += o.hazard_stalls;
        self.backpressure_stalls += o.backpressure_stalls;
        self.collisions += o.collisions;
        self.w_reads += o.w_reads;
        self.out_writes += o.out_writes;
        self.queue_ops += o.queue_ops;
        self.adds += o.adds;
        self.x_loads += o.x_loads;
    }

    /// Scale all counters by an integer factor (row-sampled measurements
    /// extrapolating to the full matrix).
    pub fn scaled(&self, num: u64, den: u64) -> SimStats {
        let s = |v: u64| (v as u128 * num as u128 / den as u128) as u64;
        SimStats {
            cycles: s(self.cycles),
            elements: s(self.elements),
            mults: s(self.mults),
            rc_hits: s(self.rc_hits),
            rc_writes: s(self.rc_writes),
            rc_reads: s(self.rc_reads),
            hazard_stalls: s(self.hazard_stalls),
            backpressure_stalls: s(self.backpressure_stalls),
            collisions: s(self.collisions),
            w_reads: s(self.w_reads),
            out_writes: s(self.out_writes),
            queue_ops: s(self.queue_ops),
            adds: s(self.adds),
            x_loads: s(self.x_loads),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SimStats {
        SimStats {
            cycles: 100,
            elements: 80,
            mults: 24,
            rc_hits: 56,
            rc_writes: 24,
            rc_reads: 56,
            hazard_stalls: 1,
            w_reads: 80,
            out_writes: 80,
            ..Default::default()
        }
    }

    #[test]
    fn rates() {
        let s = sample();
        assert!((s.reuse_rate() - 0.7).abs() < 1e-12);
        assert!((s.mult_reduction() - 0.7).abs() < 1e-12);
        assert!((s.hazard_rate() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn empty_rates_are_zero() {
        let s = SimStats::default();
        assert_eq!(s.reuse_rate(), 0.0);
        assert_eq!(s.hazard_rate(), 0.0);
        assert_eq!(s.mult_reduction(), 0.0);
    }

    #[test]
    fn merge_serial_adds_cycles() {
        let mut a = sample();
        a.merge(&sample());
        assert_eq!(a.cycles, 200);
        assert_eq!(a.elements, 160);
    }

    #[test]
    fn merge_parallel_maxes_cycles() {
        let mut a = sample();
        let mut b = sample();
        b.cycles = 250;
        a.merge_parallel(&b);
        assert_eq!(a.cycles, 250);
        assert_eq!(a.mults, 48);
    }

    #[test]
    fn scaled_is_proportional() {
        let s = sample().scaled(3, 1);
        assert_eq!(s.cycles, 300);
        assert_eq!(s.rc_hits, 168);
        let h = sample().scaled(1, 2);
        assert_eq!(h.elements, 40);
    }
}
