//! Cycle-level simulator of the AxLLM accelerator (paper §III.c–§IV).
//!
//! ## Architecture
//!
//! The simulator is layered around two abstractions:
//!
//! - [`LaneSim`] — the lane timing-model trait. A lane model turns one
//!   (stationary input element × weight chunk) pass into a [`ChunkResult`]
//!   (cycle/activity counters + functional partial sums). The built-in
//!   implementations are [`BaselineLane`], [`SerialLane`], and
//!   [`SlicedLane`]; new micro-architectures plug in by implementing the
//!   trait — the accelerator schedule never names a concrete model.
//! - [`Accelerator`] — the L-lane instance that orchestrates a lane model
//!   over the input-stationary schedule with bounded-column rounds and
//!   adder-tree accumulation. Construct it with [`Accelerator::builder`],
//!   which validates the sizing (lanes > 0, slices a power of two that
//!   divides the buffer entries, …) before any cycle is simulated.
//!
//! ## Timing model
//!
//! Latencies come from the paper's 15nm RTL synthesis (§IV): multiplier =
//! 3 cycles, buffer/RC access = 1 cycle. The three built-in lane models:
//!
//! - [`baseline`] / [`BaselineLane`] — multipliers only, no Result Cache:
//!   every weight element occupies the lane's multiplier for
//!   `mult_latency` cycles. This is the normalization baseline of Fig. 9
//!   (*"the AxLLM architecture with just multipliers (and not the reuse
//!   buffer)"*).
//! - [`lane`] / [`SerialLane`] — the **serial dual-pipeline** lane: the
//!   first occurrence of a folded value takes the compute path
//!   (`mult_latency` cycles on the single in-order write port), repeats
//!   take the reuse path (1-cycle RC read). This model reproduces the
//!   paper's published absolute numbers: DistilBERT baseline/AxLLM =
//!   159.34M/85.11M cycles ⇒ ratio 0.534 = ((1−r)·3 + r·1)/3 at r ≈ 0.70.
//! - [`sliced`] / [`SlicedLane`] — the §IV "Partitioning for Higher
//!   Throughput" micro-architecture: P-way sliced W/Out/RC buffers,
//!   per-slice collision queues with credit-based backpressure,
//!   round-robin arbitration, a single shared (pipelined) multiplier per
//!   lane, and RAW-hazard stalls.
//!
//! All lane models also compute the actual partial sums, which tests and
//! property tests cross-check against dense multiplication — the simulator
//! cannot drift from the functional semantics. See `rust/DESIGN.md` for
//! how the simulator slots under the serving stack
//! (`Engine → ExecutionBackend → Accelerator → LaneSim`).

pub mod accelerator;
pub mod adder_tree;
pub mod baseline;
pub mod lane;
pub mod lane_model;
pub mod queue;
pub mod rc;
pub mod shiftadd;
pub mod sliced;
pub mod stats;

pub use accelerator::{Accelerator, AcceleratorBuilder, MatmulResult, ModelCycleSummary};
pub use lane_model::{BaselineLane, LaneSim, SerialLane, SlicedLane, ALL_LANE_SIMS};
pub use stats::SimStats;

/// Identifier of a built-in lane micro-architecture model. Resolve to the
/// timing model itself with [`LaneModel::sim`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneModel {
    /// Multiply-only baseline (no RC).
    Baseline,
    /// Serial dual-pipeline (paper-calibrated; default).
    Serial,
    /// P-way sliced parallel lane with collision queues.
    Sliced,
}

/// Result of simulating one lane-chunk: cycle/activity counters plus the
/// functional partial sums the chunk produced.
#[derive(Clone, Debug)]
pub struct ChunkResult {
    /// Cycle/activity counters of the chunk pass.
    pub stats: SimStats,
    /// Partial sums `x * w[j]` for each chunk position j (i32 accumulator
    /// precision, as in the int8×int8→i32 datapath).
    pub partials: Vec<i32>,
}
