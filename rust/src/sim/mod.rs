//! Cycle-level simulator of the AxLLM accelerator (paper §III.c–§IV).
//!
//! ## Timing model
//!
//! Latencies come from the paper's 15nm RTL synthesis (§IV): multiplier =
//! 3 cycles, buffer/RC access = 1 cycle. Three lane models are provided:
//!
//! - [`baseline`] — multipliers only, no Result Cache: every weight element
//!   occupies the lane's multiplier for `mult_latency` cycles. This is the
//!   normalization baseline of Fig. 9 (*"the AxLLM architecture with just
//!   multipliers (and not the reuse buffer)"*).
//! - [`lane`] — the **serial dual-pipeline** lane: the first occurrence of
//!   a folded value takes the compute path (`mult_latency` cycles on the
//!   single in-order write port), repeats take the reuse path (1-cycle RC
//!   read). This model reproduces the paper's published absolute numbers:
//!   DistilBERT baseline/AxLLM = 159.34M/85.11M cycles ⇒ ratio 0.534 =
//!   ((1−r)·3 + r·1)/3 at r ≈ 0.70 — i.e. the Fig. 9 numbers follow
//!   hit-cost 1 / miss-cost `mult_latency` serialization. (The paper's §IV
//!   pipeline prose suggests more overlap than its own numbers exhibit; we
//!   document the discrepancy in EXPERIMENTS.md and expose the more
//!   aggressive model separately.)
//! - [`sliced`] — the §IV "Partitioning for Higher Throughput"
//!   micro-architecture: P-way sliced W/Out/RC buffers, per-slice
//!   collision queues with credit-based backpressure, round-robin
//!   arbitration, a single shared (pipelined) multiplier per lane, and
//!   RAW-hazard stalls. Used for the slicing ablation (E11) and the
//!   hazard-rate claim (E10).
//!
//! All lane models also compute the actual partial sums, which tests
//! cross-check against dense multiplication — the simulator cannot drift
//! from the functional semantics.

pub mod accelerator;
pub mod adder_tree;
pub mod baseline;
pub mod lane;
pub mod queue;
pub mod rc;
pub mod shiftadd;
pub mod sliced;
pub mod stats;

pub use accelerator::{Accelerator, MatmulResult, ModelCycleSummary};
pub use stats::SimStats;

/// Which lane micro-architecture model to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneModel {
    /// Multiply-only baseline (no RC).
    Baseline,
    /// Serial dual-pipeline (paper-calibrated; default).
    Serial,
    /// P-way sliced parallel lane with collision queues.
    Sliced,
}

/// Result of simulating one lane-chunk: cycle/activity counters plus the
/// functional partial sums the chunk produced.
#[derive(Clone, Debug)]
pub struct ChunkResult {
    pub stats: SimStats,
    /// Partial sums `x * w[j]` for each chunk position j (i32 accumulator
    /// precision, as in the int8×int8→i32 datapath).
    pub partials: Vec<i32>,
}
