//! The P-way sliced parallel lane (paper §IV: "Partitioning for Higher
//! Throughput", "Collision Handling and Flow Control", "Multiplier and
//! Data Path Organization").
//!
//! Micro-architecture simulated cycle by cycle:
//!
//! ```text
//!  W_buff slice 0 ─┐             ┌─ RC slice 0 ─┐            ┌─ Out slice 0
//!  W_buff slice 1 ─┤  P×P queues ├─ RC slice 1 ─┤ P+1 queues ├─ Out slice 1
//!      ...         │ (credit FC) │     ...      │ per slice  │    ...
//!  W_buff slice P-1┘             └─ RC slice P-1┘            └─ Out slice P-1
//!                                      │ P miss queues
//!                                      ▼
//!                                single multiplier (pipelined, II=1,
//!                                latency = mult_latency) → RC fill +
//!                                Out queue [P] of the element's slice
//! ```
//!
//! - Each W_buff slice fetches one weight per cycle and routes a request to
//!   `rc_queue[rc_slice(u)][from_slice]`; a full queue stalls the fetch
//!   (credit-based backpressure).
//! - Each RC slice services one request per cycle, scanning its P input
//!   queues round-robin: a `Valid` head is read and forwarded to
//!   `out_queue[from_slice][rc_slice]`; an `Invalid` head is marked
//!   `Pending` and moved to the slice's miss queue; a `Pending` head is the
//!   §IV read-after-compute hazard — it blocks its queue until the
//!   multiplier fills the entry (other queues may still be served; the
//!   cycle is counted as a hazard stall when only pending heads remain).
//! - Requests arriving when other queues at the same RC slice are busy are
//!   collision-serialized (counted).
//! - The single multiplier issues one miss per cycle (round-robin over the
//!   P miss queues) with a `mult_latency`-deep pipeline; writeback fills
//!   the RC entry (dual-port: the fill never conflicts with the read) and
//!   forwards the product to `out_queue[slice][P]`.
//! - Each Out_buff slice commits one result per cycle, round-robin over its
//!   P+1 input queues. W_buff slice i's results always land in Out slice i
//!   (paper: "no output conflicts occur").

use crate::config::AcceleratorConfig;
use crate::quant::fold;
use crate::sim::queue::{Queue, RoundRobin};
use crate::sim::rc::{rc_slice_of, RcState, ResultCache};
use crate::sim::{ChunkResult, SimStats};

#[derive(Clone, Copy, Debug)]
struct Request {
    /// Position within the chunk (→ Out_buff address).
    pos: u32,
    /// Folded value.
    u: u8,
    /// Negate cached product on reuse.
    neg: bool,
    /// Originating W_buff slice (→ Out_buff slice).
    from: u8,
}

#[derive(Clone, Copy, Debug)]
struct MultOp {
    done_at: u64,
    req: Request,
    product: i32,
}

/// Simulate one (input element × weight chunk) pass through a P-way sliced
/// lane.
pub fn simulate_chunk(x: i8, weights: &[i8], cfg: &AcceleratorConfig) -> ChunkResult {
    let n = weights.len();
    assert!(
        n <= cfg.buffer_entries,
        "chunk ({n}) exceeds W_buff ({})",
        cfg.buffer_entries
    );
    let p = cfg.slices;
    let depth = cfg.queue_depth;
    let rc_entries = cfg.rc_entries();

    // Contiguous W_buff slice ranges (last slice may be short).
    let slice_len = n.div_ceil(p).max(1);
    let mut cursors: Vec<usize> = (0..p).map(|s| (s * slice_len).min(n)).collect();
    let ends: Vec<usize> = (0..p).map(|s| ((s + 1) * slice_len).min(n)).collect();

    let mut rc = ResultCache::new(rc_entries);
    let mut rc_queues: Vec<Vec<Queue<Request>>> = (0..p)
        .map(|_| (0..p).map(|_| Queue::new(depth)).collect())
        .collect();
    let mut rc_arb: Vec<RoundRobin> = (0..p).map(|_| RoundRobin::new(p)).collect();
    let mut miss_queues: Vec<Queue<Request>> = (0..p).map(|_| Queue::new(depth)).collect();
    let mut miss_arb = RoundRobin::new(p);
    let mut out_queues: Vec<Vec<Queue<(u32, i32)>>> = (0..p)
        .map(|_| (0..p + 1).map(|_| Queue::new(depth)).collect())
        .collect();
    let mut out_arb: Vec<RoundRobin> = (0..p).map(|_| RoundRobin::new(p + 1)).collect();

    // Pipelined multiplier: at most one issue per cycle, `mult_latency`
    // cycles to writeback; a full out-queue holds the writeback (and, if
    // the pipe backs up, stalls issue).
    let mut mult_pipe: std::collections::VecDeque<MultOp> = std::collections::VecDeque::new();

    let mut stats = SimStats {
        x_loads: 1,
        ..Default::default()
    };
    let mut partials = vec![0i32; n];
    let mut committed = 0usize;
    let mut cycle: u64 = 0;
    let max_cycles = 64 * (n as u64 + 64) * cfg.mult_latency as u64;

    while committed < n {
        cycle += 1;
        assert!(
            cycle < max_cycles,
            "sliced lane deadlock: {committed}/{n} committed after {cycle} cycles"
        );

        // ── Stage 4: Out_buff commits (downstream first so an item cannot
        // traverse two stages in one cycle).
        for s in 0..p {
            let qs = &mut out_queues[s];
            if let Some(qi) = out_arb[s].grant(|i| !qs[i].is_empty()) {
                let (pos, v) = qs[qi].pop().unwrap();
                partials[pos as usize] = v;
                committed += 1;
                stats.out_writes += 1;
                stats.queue_ops += 1;
            }
        }

        // ── Stage 3: multiplier writeback then issue (II = 1).
        if let Some(op) = mult_pipe.front() {
            if op.done_at <= cycle {
                let dest = op.req.from as usize;
                let signed = if op.req.neg { -op.product } else { op.product };
                if out_queues[dest][p].try_push((op.req.pos, signed)) {
                    let op = mult_pipe.pop_front().unwrap();
                    rc.fill(op.req.u, op.product);
                    stats.queue_ops += 1;
                } else {
                    stats.backpressure_stalls += 1;
                }
            }
        }
        if mult_pipe.len() < cfg.mult_latency as usize {
            let mq = &mut miss_queues;
            if let Some(qi) = miss_arb.grant(|i| !mq[i].is_empty()) {
                let req = mq[qi].pop().unwrap();
                let product = x as i32 * req.u as i32;
                mult_pipe.push_back(MultOp {
                    done_at: cycle + cfg.mult_latency as u64,
                    req,
                    product,
                });
                stats.mults += 1;
                stats.queue_ops += 1;
            }
        }

        // ── Stage 2: RC slice service, one request per slice per cycle.
        let mut hazard_this_cycle = false;
        for s in 0..p {
            // Collision bookkeeping: >1 candidate queues with work at this
            // slice in the same cycle serialize through the arbiter.
            let ready = (0..p).filter(|&i| !rc_queues[s][i].is_empty()).count();
            if ready > 1 {
                stats.collisions += (ready - 1) as u64;
            }
            let mut hazard_blocked = false;
            let rcq = &mut rc_queues[s];
            let rc_ref = &rc;
            let miss_has_room = !miss_queues[s].is_full();
            let grant = rc_arb[s].grant(|i| match rcq[i].peek() {
                None => false,
                Some(req) => match rc_ref.state(req.u) {
                    RcState::Valid(_) => {
                        // Needs room in the destination out queue.
                        !out_queues[req.from as usize][s].is_full()
                    }
                    RcState::Invalid => miss_has_room,
                    RcState::Pending => {
                        hazard_blocked = true;
                        false
                    }
                },
            });
            match grant {
                Some(qi) => {
                    let req = *rcq[qi].peek().unwrap();
                    match rc.state(req.u) {
                        RcState::Valid(_) => {
                            let pfold = rc.read(req.u);
                            let v = if req.neg { -pfold } else { pfold };
                            let ok = out_queues[req.from as usize][s].try_push((req.pos, v));
                            debug_assert!(ok);
                            rcq[qi].pop();
                            stats.rc_hits += 1;
                            stats.queue_ops += 2;
                        }
                        RcState::Invalid => {
                            rc.mark_pending(req.u);
                            let ok = miss_queues[s].try_push(req);
                            debug_assert!(ok);
                            rcq[qi].pop();
                            stats.queue_ops += 2;
                        }
                        RcState::Pending => unreachable!(),
                    }
                }
                None => {
                    if hazard_blocked {
                        // §IV read-after-compute hazard: a repeat of a value
                        // whose multiply is in flight heads every servable
                        // queue of this slice.
                        hazard_this_cycle = true;
                    }
                }
            }
        }
        // Count lane-level hazard stall cycles (once per cycle, matching
        // the paper's "the system stalls only when ..." phrasing).
        if hazard_this_cycle {
            stats.hazard_stalls += 1;
        }

        // ── Stage 1: fetch, one weight per W_buff slice per cycle.
        for s in 0..p {
            if cursors[s] < ends[s] {
                let pos = cursors[s];
                let (u, neg) = fold(weights[pos]);
                let dest = rc_slice_of(u, rc_entries, p);
                let req = Request {
                    pos: pos as u32,
                    u,
                    neg,
                    from: s as u8,
                };
                if rc_queues[dest][s].try_push(req) {
                    cursors[s] += 1;
                    stats.w_reads += 1;
                    stats.elements += 1;
                    stats.queue_ops += 1;
                } else {
                    stats.backpressure_stalls += 1;
                }
            }
        }
    }

    stats.rc_reads = rc.reads;
    stats.rc_writes = rc.writes;
    stats.cycles = cycle + cfg.buf_latency as u64;
    ChunkResult { stats, partials }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg_p(slices: usize) -> AcceleratorConfig {
        AcceleratorConfig {
            slices,
            ..AcceleratorConfig::default()
        }
    }

    fn random_weights(n: usize, seed: u64) -> Vec<i8> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.range_i64(-127, 127) as i8).collect()
    }

    #[test]
    fn functional_equivalence_with_dense() {
        for &p in &[1usize, 2, 4, 8] {
            let weights = random_weights(256, 42);
            let r = simulate_chunk(-11, &weights, &cfg_p(p));
            let expect: Vec<i32> = weights.iter().map(|&w| -11i32 * w as i32).collect();
            assert_eq!(r.partials, expect, "P={p}");
            assert_eq!(r.stats.elements, 256);
            assert_eq!(r.stats.out_writes, 256);
        }
    }

    #[test]
    fn unique_values_multiplied_once_per_chunk() {
        let weights = random_weights(256, 7);
        let mut seen = [false; 128];
        let mut unique = 0u64;
        for &w in &weights {
            let (u, _) = fold(w);
            if !seen[u as usize] {
                seen[u as usize] = true;
                unique += 1;
            }
        }
        let r = simulate_chunk(5, &weights, &cfg_p(4));
        assert_eq!(r.stats.mults, unique);
        assert_eq!(r.stats.rc_hits, 256 - unique);
    }

    #[test]
    fn slicing_improves_throughput_on_spread_values() {
        // Values spread across all four RC slices → near-P-way speedup.
        let weights: Vec<i8> = (0..256).map(|i| (i % 127 + 1) as i8).collect();
        let c1 = simulate_chunk(3, &weights, &cfg_p(1)).stats.cycles;
        let c4 = simulate_chunk(3, &weights, &cfg_p(4)).stats.cycles;
        // Out_buff commit bandwidth (1/slice/cycle) floors P=4 at 64
        // cycles; occasional collisions keep it near 2× rather than the
        // ideal 4×.
        assert!(
            (c4 as f64) < 0.6 * c1 as f64,
            "P=4 ({c4}) should be well under P=1 ({c1})"
        );
        assert!(c4 >= 64, "cannot beat the commit-bandwidth floor: {c4}");
    }

    #[test]
    fn same_slice_values_degrade_toward_serial() {
        // All weights in one RC slice (values 1..=31 with 4 slices of 32):
        // paper §IV worst case — performance reverts toward the unsliced
        // lane.
        let mut rng = Rng::new(3);
        let weights: Vec<i8> = (0..256)
            .map(|_| (rng.range_i64(1, 31)) as i8)
            .collect();
        let c4_hot = simulate_chunk(3, &weights, &cfg_p(4)).stats.cycles;
        let spread: Vec<i8> = (0..256).map(|i| (i % 127 + 1) as i8).collect();
        let c4_spread = simulate_chunk(3, &spread, &cfg_p(4)).stats.cycles;
        let c1 = simulate_chunk(3, &weights, &cfg_p(1)).stats.cycles;
        // Hot-slice traffic serializes through one RC slice: markedly
        // slower than spread values and within ~10% of the unsliced lane
        // (the §IV worst case).
        assert!(
            c4_hot as f64 > 1.7 * c4_spread as f64,
            "hot {c4_hot} spread {c4_spread}"
        );
        assert!(
            c4_hot as f64 > 0.9 * c1 as f64,
            "worst case should revert toward P=1: hot {c4_hot} vs P=1 {c1}"
        );
    }

    #[test]
    fn hazards_detected_on_tight_repeats() {
        // Long run of one value: the first is a miss (3-cycle multiply);
        // immediate repeats must wait → hazard stalls > 0.
        let weights = vec![64i8; 32];
        let r = simulate_chunk(2, &weights, &cfg_p(4));
        assert!(r.stats.hazard_stalls > 0);
        assert_eq!(r.stats.mults, 1);
        assert_eq!(r.partials, vec![128; 32]);
    }

    #[test]
    fn hazard_rate_low_on_realistic_weights() {
        // Paper §IV: hazard likelihood below 2% on real benchmarks.
        let mut rng = Rng::new(12);
        let mut total_stall = 0u64;
        let mut total_cycles = 0u64;
        for _ in 0..16 {
            let weights: Vec<i8> = (0..256)
                .map(|_| {
                    let v = (rng.normal() * 30.0).round().clamp(-127.0, 127.0);
                    v as i8
                })
                .collect();
            let r = simulate_chunk(7, &weights, &cfg_p(4));
            total_stall += r.stats.hazard_stalls;
            total_cycles += r.stats.cycles;
        }
        let rate = total_stall as f64 / total_cycles as f64;
        assert!(rate < 0.05, "hazard rate {rate}");
    }

    #[test]
    fn collisions_counted_for_hot_slices() {
        let weights = vec![10i8; 64]; // all map to slice 0
        let r = simulate_chunk(1, &weights, &cfg_p(4));
        assert!(r.stats.collisions > 0);
    }

    #[test]
    fn backpressure_engages_with_shallow_queues() {
        let cfg = AcceleratorConfig {
            slices: 4,
            queue_depth: 1,
            ..AcceleratorConfig::default()
        };
        let mut rng = Rng::new(5);
        let weights: Vec<i8> = (0..256)
            .map(|_| (rng.normal() * 20.0).round().clamp(-127.0, 127.0) as i8)
            .collect();
        let r = simulate_chunk(3, &weights, &cfg);
        assert!(r.stats.backpressure_stalls > 0);
        // Functional output still exact under backpressure.
        let expect: Vec<i32> = weights.iter().map(|&w| 3 * w as i32).collect();
        assert_eq!(r.partials, expect);
    }

    #[test]
    fn p1_matches_functional_serial_lane() {
        let weights = random_weights(128, 9);
        let sliced = simulate_chunk(4, &weights, &cfg_p(1));
        let serial = crate::sim::lane::simulate_chunk(4, &weights, &AcceleratorConfig::default());
        assert_eq!(sliced.partials, serial.partials);
        assert_eq!(sliced.stats.mults, serial.stats.mults);
        assert_eq!(sliced.stats.rc_hits, serial.stats.rc_hits);
    }

    #[test]
    fn empty_chunk_terminates() {
        let r = simulate_chunk(1, &[], &cfg_p(4));
        assert_eq!(r.stats.elements, 0);
        assert!(r.partials.is_empty());
    }

    #[test]
    fn odd_sizes_and_slice_remainders() {
        for &n in &[1usize, 3, 63, 65, 255] {
            let weights = random_weights(n, n as u64);
            let r = simulate_chunk(-2, &weights, &cfg_p(4));
            let expect: Vec<i32> = weights.iter().map(|&w| -2 * w as i32).collect();
            assert_eq!(r.partials, expect, "n={n}");
        }
    }
}
