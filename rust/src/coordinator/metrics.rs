//! Serving metrics: latency percentiles, throughput, and accelerator
//! attribution (cycles, reuse, energy) aggregated over a run — trace-driven
//! or live ([`ServeSummary::from_results`] is the one aggregation both
//! paths share).

use crate::backend::CostModel;
use crate::coordinator::batcher::SloPolicy;
use crate::coordinator::engine::RequestResult;
use crate::model::AdapterId;

/// Latency distribution summary (seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyStats {
    /// Sample count.
    pub count: usize,
    /// Arithmetic mean, seconds.
    pub mean_s: f64,
    /// Median (nearest-rank), seconds.
    pub p50_s: f64,
    /// 95th percentile (nearest-rank), seconds.
    pub p95_s: f64,
    /// 99th percentile (nearest-rank), seconds.
    pub p99_s: f64,
    /// Largest sample, seconds.
    pub max_s: f64,
}

impl LatencyStats {
    /// Compute from raw samples (unordered). Non-finite samples (NaN —
    /// the signature of an upstream zero-span division or clock bug —
    /// or ±∞) are dropped before aggregation: they carry no latency
    /// information, and a single NaN must never panic the summary or
    /// poison every percentile. `count` reports the finite samples
    /// actually aggregated.
    pub fn from_samples(mut samples: Vec<f64>) -> LatencyStats {
        samples.retain(|v| v.is_finite());
        if samples.is_empty() {
            return LatencyStats::default();
        }
        // total_cmp, not partial_cmp().unwrap(): the comparison itself
        // must be total even if the finite filter above ever changes.
        samples.sort_by(f64::total_cmp);
        let n = samples.len();
        // Nearest-rank percentile: the smallest sample such that at least
        // p·n samples are ≤ it, i.e. 1-indexed rank ⌈n·p⌉. The previous
        // ⌊n·p⌋ 0-indexed form over-indexed by one rank (p50 of 1..=100
        // returned the 51st sample, 0.51).
        let pct = |p: f64| {
            let rank = ((n as f64) * p).ceil().max(1.0) as usize;
            samples[rank.min(n) - 1]
        };
        LatencyStats {
            count: n,
            mean_s: samples.iter().sum::<f64>() / n as f64,
            p50_s: pct(0.50),
            p95_s: pct(0.95),
            p99_s: pct(0.99),
            max_s: samples[n - 1],
        }
    }
}

/// One row of the per-adapter serving rollup: how requests served with a
/// given adapter (or base-only, `adapter: None`) fared over the run.
/// This is the measurement channel for the paper's "reuse survives LoRA"
/// claim: the base-pipeline reuse rate of every adapter group should sit
/// within noise of the base-only group's.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AdapterUsage {
    /// Adapter the group was served with (`None` = base-only, including
    /// adapter requests the backend missed).
    pub adapter: Option<AdapterId>,
    /// Requests in the group.
    pub requests: usize,
    /// Total tokens (prompt + generated) attributed to the group.
    pub tokens: u64,
    /// Generated tokens of the group (decode serving).
    pub gen_tokens: u64,
    /// Group tokens per second over the run's span.
    pub throughput_tps: f64,
    /// Dense side-pipeline MACs the group's adapters added.
    pub adapter_ops: u64,
    /// Measured base-pipeline reuse rate of the group (0 when the
    /// backend measured no base ops, e.g. PJRT).
    pub base_reuse_rate: f64,
}

/// One row of the per-shard serving rollup: how one tensor-parallel
/// shard's Result Cache fared over the run. Per-shard hit rates sit at
/// or near — never meaningfully above — the monolithic rate, because
/// each shard's independent cache sees only a column slice of every
/// weight matrix; the element counts still partition exactly
/// (`Σ_s (base_mults + base_reuses)` equals the run's total base ops).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ShardUsage {
    /// Shard index within the shard group.
    pub shard: usize,
    /// Base-pipeline multiplications this shard performed.
    pub base_mults: u64,
    /// Base-pipeline reuses this shard's Result Cache served.
    pub base_reuses: u64,
    /// This shard's measured reuse rate (0 when the shard did no work).
    pub reuse_rate: f64,
}

/// End-of-run summary for a served trace.
#[derive(Clone, Debug, Default)]
pub struct ServeSummary {
    /// Requests served.
    pub requests: usize,
    /// Batches (closed-batch serving) or iterations (decode serving).
    pub batches: usize,
    /// Total tokens (prompt + generated) attributed across all requests.
    pub tokens: u64,
    /// Generated tokens across all requests (decode serving; 0 for
    /// prefill-only runs).
    pub gen_tokens: u64,
    /// Prompt tokens served from the shared prefix KV cache across all
    /// requests (0 for untagged traces and cache-less backends).
    pub cached_tokens: u64,
    /// Fraction of prompt tokens served from the prefix cache:
    /// `cached_tokens / (tokens - gen_tokens)`. 0 for cache-less runs
    /// and for degenerate runs with no prompt tokens at all.
    pub prefix_hit_rate: f64,
    /// Wall-clock span of the trace (first arrival → last completion).
    pub span_s: f64,
    /// End-to-end latency distribution (arrival → completion).
    pub latency: LatencyStats,
    /// Time-to-first-token distribution (arrival → first generated
    /// token; equals `latency` for prefill-only serving).
    pub ttft: LatencyStats,
    /// Time-per-output-token distribution over decode sessions that
    /// generated ≥ 2 tokens (empty/zero otherwise).
    pub tpot: LatencyStats,
    /// Requests per second over the span.
    pub throughput_rps: f64,
    /// Tokens per second over the span.
    pub throughput_tps: f64,
    /// Simulated accelerator cycles attributed across all requests.
    pub sim_cycles: u64,
    /// Simulated reuse rate over all attributed work.
    pub sim_reuse_rate: f64,
    /// Simulated energy (J) on the accelerator.
    pub sim_energy_j: f64,
    /// Simulated speedup vs the multiply-only baseline for this workload.
    pub sim_speedup: f64,
    /// Dense adapter side-pipeline MACs across all requests (0 for
    /// base-model-only runs).
    pub adapter_ops: u64,
    /// Per-adapter rollup, base-only group (`adapter: None`) first, then
    /// ascending adapter id. Empty for an empty result set; a single
    /// `None` entry for an adapter-free run.
    pub by_adapter: Vec<AdapterUsage>,
    /// Per-shard rollup for tensor-parallel runs, ascending shard index.
    /// Empty when every request executed monolithically.
    pub per_shard: Vec<ShardUsage>,
    /// Fraction of served requests that met their SLO class targets
    /// (TTFT within `ttft_s`; TPOT within `tpot_s` when the session
    /// generated ≥ 2 tokens). `1.0` when no SLO policy governed the run
    /// — and for an empty result set (vacuously attained).
    pub slo_attainment: f64,
    /// Requests shed by SLO admission (never executed, not in the
    /// per-request results).
    pub shed: usize,
    /// Requests served with a degraded (clamped) decode budget.
    pub degraded: usize,
    /// KV-cache bytes transferred prefill → decode across disaggregated
    /// handoffs (0 for unified serving).
    pub handoff_bytes: u64,
}

impl ServeSummary {
    /// Aggregate per-request results into the end-of-run summary. Used by
    /// `Engine::serve_trace` and by live serving (`Server` / `ServerPool`
    /// drivers), so both report identical metrics for identical results.
    ///
    /// The span runs from the earliest arrival (`dispatch - queue_wait`)
    /// to the latest completion (`dispatch + exec`). Degenerate spans are
    /// well-defined: an empty result set, a run whose results all land
    /// in one instant (single fully-cached request), or non-finite
    /// stamps all report `span_s = 0` and **zero** throughputs — never
    /// NaN, never infinity, never a panic.
    pub fn from_results(
        results: &[RequestResult],
        batches: usize,
        cost: &CostModel,
    ) -> ServeSummary {
        ServeSummary::from_results_slo(results, batches, cost, None, 0, 0, 0)
    }

    /// [`ServeSummary::from_results`] plus the SLO/disaggregation
    /// dimensions: per-class attainment measured against `policy` (when
    /// one governed the run), and the shed/degraded/handoff counters the
    /// serving loop accumulated (shed requests have no result rows — the
    /// caller is the only witness, so it supplies the counts).
    pub fn from_results_slo(
        results: &[RequestResult],
        batches: usize,
        cost: &CostModel,
        policy: Option<&SloPolicy>,
        shed: usize,
        degraded: usize,
        handoff_bytes: u64,
    ) -> ServeSummary {
        let latency = LatencyStats::from_samples(results.iter().map(|r| r.latency_s).collect());
        let ttft = LatencyStats::from_samples(results.iter().map(|r| r.ttft_s).collect());
        let tpot = LatencyStats::from_samples(
            results
                .iter()
                .filter(|r| r.gen_tokens > 1)
                .map(|r| r.tpot_s)
                .collect(),
        );
        let tokens: u64 = results.iter().map(|r| r.tokens).sum();
        let gen_tokens: u64 = results.iter().map(|r| r.gen_tokens).sum();
        let cached_tokens: u64 = results.iter().map(|r| r.cached_tokens).sum();
        // Prompt tokens = attributed tokens minus generated ones; the
        // hit rate is cache coverage of the prompt side only.
        let prompt_tokens = tokens.saturating_sub(gen_tokens);
        let prefix_hit_rate = if prompt_tokens == 0 {
            0.0
        } else {
            cached_tokens as f64 / prompt_tokens as f64
        };
        let first_arrival = results
            .iter()
            .map(|r| r.dispatch_s - r.queue_wait_s)
            .fold(f64::INFINITY, f64::min);
        let last_completion = results
            .iter()
            .map(|r| r.dispatch_s + r.exec_s)
            .fold(f64::NEG_INFINITY, f64::max);
        // Zero/negative spans (all results in one instant) and non-finite
        // spans (empty runs, NaN stamps) cannot support a rate: report a
        // zero span and let `rate` pin every throughput to 0 instead of
        // letting a division manufacture inf/NaN.
        let raw_span = last_completion - first_arrival;
        let span_s = if raw_span.is_finite() && raw_span > 0.0 {
            raw_span
        } else {
            0.0
        };
        let rate = |x: f64| if span_s > 0.0 { x / span_s } else { 0.0 };
        // Per-adapter rollup: group results by the adapter they were
        // actually served with, base-only (`None`) first.
        let mut groups: Vec<Option<AdapterId>> = results.iter().map(|r| r.adapter).collect();
        groups.sort_unstable();
        groups.dedup();
        let by_adapter = groups
            .into_iter()
            .map(|adapter| {
                let rs: Vec<&RequestResult> =
                    results.iter().filter(|r| r.adapter == adapter).collect();
                let tokens: u64 = rs.iter().map(|r| r.tokens).sum();
                let base_mults: u64 = rs.iter().map(|r| r.base_mults).sum();
                let base_reuses: u64 = rs.iter().map(|r| r.base_reuses).sum();
                let base_ops = base_mults + base_reuses;
                AdapterUsage {
                    adapter,
                    requests: rs.len(),
                    tokens,
                    gen_tokens: rs.iter().map(|r| r.gen_tokens).sum(),
                    throughput_tps: rate(tokens as f64),
                    adapter_ops: rs.iter().map(|r| r.adapter_ops).sum(),
                    base_reuse_rate: if base_ops == 0 {
                        0.0
                    } else {
                        base_reuses as f64 / base_ops as f64
                    },
                }
            })
            .collect();
        // Per-shard rollup: sum each shard's counters across every
        // sharded result (monolithic results contribute nothing).
        let shard_n = results.iter().map(|r| r.per_shard.len()).max().unwrap_or(0);
        let per_shard = (0..shard_n)
            .map(|s| {
                let (base_mults, base_reuses) =
                    results.iter().fold((0u64, 0u64), |(m, ru), r| {
                        match r.per_shard.get(s) {
                            Some(a) => (m + a.base_mults, ru + a.base_reuses),
                            None => (m, ru),
                        }
                    });
                let ops = base_mults + base_reuses;
                ShardUsage {
                    shard: s,
                    base_mults,
                    base_reuses,
                    reuse_rate: if ops == 0 {
                        0.0
                    } else {
                        base_reuses as f64 / ops as f64
                    },
                }
            })
            .collect();
        // Attainment: a served request meets its SLO when its TTFT is
        // within the class target and — for sessions that actually
        // streamed (≥ 2 tokens) — its TPOT is too. Without a policy the
        // run vacuously attains.
        let slo_attainment = match policy {
            None => 1.0,
            Some(_) if results.is_empty() => 1.0,
            Some(p) => {
                let met = results
                    .iter()
                    .filter(|r| {
                        let t = p.target(r.slo);
                        r.ttft_s <= t.ttft_s && (r.gen_tokens < 2 || r.tpot_s <= t.tpot_s)
                    })
                    .count();
                met as f64 / results.len() as f64
            }
        };
        ServeSummary {
            requests: results.len(),
            batches,
            tokens,
            gen_tokens,
            cached_tokens,
            prefix_hit_rate,
            span_s,
            latency,
            ttft,
            tpot,
            throughput_rps: rate(results.len() as f64),
            throughput_tps: rate(tokens as f64),
            sim_cycles: results.iter().map(|r| r.sim_cycles).sum(),
            sim_reuse_rate: cost.reuse_rate,
            sim_energy_j: results.iter().map(|r| r.sim_energy_j).sum(),
            sim_speedup: cost.speedup(),
            adapter_ops: results.iter().map(|r| r.adapter_ops).sum(),
            by_adapter,
            per_shard,
            slo_attainment,
            shed,
            degraded,
            handoff_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cost() -> CostModel {
        CostModel {
            cycles_per_token_ax: 100.0,
            cycles_per_token_base: 300.0,
            energy_pj_per_token_ax: 1.0,
            energy_pj_per_token_base: 3.0,
            reuse_rate: 0.7,
            freq_ghz: 1.0,
            attn_cycles_per_ctx_token: 1.0,
            attn_energy_pj_per_ctx_token: 0.1,
            adapter_cycles_per_token: 10.0,
            adapter_energy_pj_per_token: 0.2,
            shards: 1,
            gather_bytes_per_token: 0.0,
            shard_collectives: 0.0,
            link_bytes_per_s: crate::backend::SHARD_LINK_BYTES_PER_S,
            link_latency_s: crate::backend::SHARD_LINK_LATENCY_S,
            kv_copy_cycles_per_token: 0.0,
            kv_copy_energy_pj_per_token: 0.0,
            kv_evict_cycles_per_block: 0.0,
            kv_evict_energy_pj_per_block: 0.0,
            handoff_bytes_per_token: 0.0,
            handoff_bytes_per_s: crate::backend::HANDOFF_LINK_BYTES_PER_S,
            handoff_latency_s: crate::backend::HANDOFF_LINK_LATENCY_S,
        }
    }

    /// A minimal served-request record for rollup tests.
    fn result(id: u64, adapter: Option<AdapterId>, tokens: u64) -> RequestResult {
        RequestResult {
            id,
            logits: Vec::new(),
            tokens,
            queue_wait_s: 0.0,
            exec_s: 0.001,
            latency_s: 0.001,
            dispatch_s: 0.0,
            batch_size: 1,
            sim_cycles: 100 * tokens,
            sim_energy_j: 1e-12,
            gen_tokens: 0,
            cached_tokens: 0,
            ttft_s: 0.001,
            tpot_s: 0.0,
            adapter,
            slo: crate::workload::SloClass::Standard,
            shed: false,
            base_mults: 30 * tokens,
            base_reuses: 70 * tokens,
            adapter_ops: if adapter.is_some() { 10 * tokens } else { 0 },
            per_shard: Vec::new(),
        }
    }

    #[test]
    fn by_adapter_rollup_none_only_run_pins_a_single_base_group() {
        // Mirror of the PR 3 empty-summary pin, one dimension up: an
        // adapter-free run must roll up to exactly one `None` group that
        // restates the run totals — no phantom adapter rows.
        let cost = test_cost();
        let rs = vec![result(0, None, 10), result(1, None, 20)];
        let s = ServeSummary::from_results(&rs, 1, &cost);
        assert_eq!(s.adapter_ops, 0);
        assert_eq!(s.by_adapter.len(), 1);
        let g = &s.by_adapter[0];
        assert_eq!(g.adapter, None);
        assert_eq!(g.requests, 2);
        assert_eq!(g.tokens, 30);
        assert_eq!(g.adapter_ops, 0);
        assert!((g.base_reuse_rate - 0.7).abs() < 1e-12);
        assert!((g.throughput_tps - s.throughput_tps).abs() < 1e-9);
    }

    #[test]
    fn by_adapter_rollup_groups_and_orders_mixed_runs() {
        let cost = test_cost();
        let rs = vec![
            result(0, Some(1), 10),
            result(1, None, 5),
            result(2, Some(0), 10),
            result(3, Some(1), 10),
        ];
        let s = ServeSummary::from_results(&rs, 1, &cost);
        // None first, then ascending adapter id.
        let order: Vec<Option<AdapterId>> =
            s.by_adapter.iter().map(|g| g.adapter).collect();
        assert_eq!(order, vec![None, Some(0), Some(1)]);
        assert_eq!(s.by_adapter[0].requests, 1);
        assert_eq!(s.by_adapter[1].requests, 1);
        assert_eq!(s.by_adapter[2].requests, 2);
        assert_eq!(s.by_adapter[2].tokens, 20);
        assert_eq!(s.by_adapter[2].adapter_ops, 200);
        assert_eq!(s.adapter_ops, 300);
        // The paper's claim, measurable: every group's base-pipe reuse
        // rate matches the base-only group's.
        for g in &s.by_adapter {
            assert!((g.base_reuse_rate - s.by_adapter[0].base_reuse_rate).abs() < 1e-12);
        }
        // Groups partition the run.
        let n: usize = s.by_adapter.iter().map(|g| g.requests).sum();
        assert_eq!(n, s.requests);
    }

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 / 100.0).collect();
        let l = LatencyStats::from_samples(samples);
        assert_eq!(l.count, 100);
        assert!(l.p50_s <= l.p95_s && l.p95_s <= l.p99_s && l.p99_s <= l.max_s);
        assert!((l.mean_s - 0.505).abs() < 1e-9);
        // Nearest-rank: p50 of 1..=100 is the 50th sample (0.50), not the
        // 51st — the off-by-one the ⌊n·p⌋ indexing used to produce.
        assert!((l.p50_s - 0.50).abs() < 1e-9);
        assert!((l.p95_s - 0.95).abs() < 1e-9);
        assert!((l.p99_s - 0.99).abs() < 1e-9);
        assert!((l.max_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nearest_rank_on_odd_counts() {
        // n=5, p50 → rank ⌈2.5⌉ = 3 → third-smallest.
        let l = LatencyStats::from_samples(vec![0.5, 0.1, 0.4, 0.2, 0.3]);
        assert!((l.p50_s - 0.3).abs() < 1e-12);
        // p99 → rank ⌈4.95⌉ = 5 → max.
        assert!((l.p99_s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_samples_are_zero() {
        let l = LatencyStats::from_samples(vec![]);
        assert_eq!(l, LatencyStats::default());
        assert_eq!(l.count, 0);
        assert_eq!(l.max_s, 0.0);
        // No NaN can leak out of an empty distribution.
        assert!(l.mean_s == 0.0 && l.p50_s == 0.0 && l.p99_s == 0.0);
    }

    #[test]
    fn empty_result_set_summarizes_without_panic_or_nan() {
        // Regression pin: zero served requests (an empty trace, or a
        // live run that was shut down before any completion) must
        // produce a well-formed summary — zero counts and throughputs,
        // never a NaN span or a divide-by-zero panic.
        let cost = test_cost();
        let s = ServeSummary::from_results(&[], 0, &cost);
        assert_eq!(s.requests, 0);
        assert_eq!(s.batches, 0);
        assert_eq!(s.tokens, 0);
        assert_eq!(s.gen_tokens, 0);
        assert_eq!(s.latency, LatencyStats::default());
        assert_eq!(s.ttft, LatencyStats::default());
        assert_eq!(s.tpot, LatencyStats::default());
        // A run with no completions has no span — and, crucially, no
        // fabricated throughputs.
        assert_eq!(s.span_s, 0.0);
        assert!(s.span_s.is_finite());
        assert_eq!(s.throughput_rps, 0.0);
        assert_eq!(s.throughput_tps, 0.0);
        assert!(s.throughput_rps.is_finite() && s.throughput_tps.is_finite());
        assert_eq!(s.sim_cycles, 0);
        assert_eq!(s.sim_energy_j, 0.0);
        // Cost-model-derived rates pass through unchanged.
        assert!((s.sim_speedup - 3.0).abs() < 1e-12);
        assert!((s.sim_reuse_rate - 0.7).abs() < 1e-12);
        // The adapter and shard rollups of an empty run are empty,
        // never a panic.
        assert_eq!(s.adapter_ops, 0);
        assert!(s.by_adapter.is_empty());
        assert!(s.per_shard.is_empty());
    }

    #[test]
    fn nan_latency_samples_never_panic_the_summary() {
        // Regression: the sort used partial_cmp().unwrap(), so one NaN
        // sample — e.g. a zero-span division feeding back through a
        // summary — panicked the whole serve report. Non-finite samples
        // are now dropped and the comparison is total.
        let l = LatencyStats::from_samples(vec![0.2, f64::NAN, 0.1, f64::INFINITY, 0.3]);
        assert_eq!(l.count, 3, "only the finite samples aggregate");
        assert!((l.mean_s - 0.2).abs() < 1e-12);
        assert!((l.p50_s - 0.2).abs() < 1e-12);
        assert!((l.max_s - 0.3).abs() < 1e-12);
        assert!(
            [l.mean_s, l.p50_s, l.p95_s, l.p99_s, l.max_s]
                .iter()
                .all(|v| v.is_finite()),
            "no NaN may survive into the stats"
        );
        // All-NaN degrades to the empty distribution, not a panic.
        assert_eq!(
            LatencyStats::from_samples(vec![f64::NAN, f64::NAN]),
            LatencyStats::default()
        );
    }

    #[test]
    fn single_instant_run_reports_zero_not_infinite_throughput() {
        // Regression: a trace whose results all land in one instant
        // (single fully-cached request: zero queue wait, zero exec) used
        // to divide by a zero-width span. The throughputs must come out
        // zero and finite — in the summary and in every rollup ratio.
        let cost = test_cost();
        let mut r = result(0, Some(1), 10);
        r.exec_s = 0.0;
        r.latency_s = 0.0;
        r.ttft_s = 0.0;
        let s = ServeSummary::from_results(&[r], 1, &cost);
        assert_eq!(s.span_s, 0.0);
        assert_eq!(s.throughput_rps, 0.0);
        assert_eq!(s.throughput_tps, 0.0);
        assert!(s.throughput_rps.is_finite() && s.throughput_tps.is_finite());
        assert_eq!(s.by_adapter.len(), 1);
        assert_eq!(s.by_adapter[0].throughput_tps, 0.0);
        assert!(s.by_adapter[0].base_reuse_rate.is_finite());
        // Counts and attribution still report: only the rates zero out.
        assert_eq!(s.requests, 1);
        assert_eq!(s.tokens, 10);
    }

    #[test]
    fn per_shard_rollup_sums_and_stays_sum_consistent() {
        use crate::backend::ShardActivity;
        let cost = test_cost();
        let mut a = result(0, None, 10);
        a.per_shard = vec![
            ShardActivity {
                base_mults: 200,
                base_reuses: 300,
            },
            ShardActivity {
                base_mults: 100,
                base_reuses: 400,
            },
        ];
        a.base_mults = 300;
        a.base_reuses = 700;
        let mut b = result(1, None, 10);
        b.per_shard = vec![
            ShardActivity {
                base_mults: 50,
                base_reuses: 150,
            },
            ShardActivity {
                base_mults: 60,
                base_reuses: 140,
            },
        ];
        b.base_mults = 110;
        b.base_reuses = 290;
        let s = ServeSummary::from_results(&[a, b], 1, &cost);
        assert_eq!(s.per_shard.len(), 2);
        assert_eq!(s.per_shard[0].shard, 0);
        assert_eq!(s.per_shard[1].shard, 1);
        assert_eq!(s.per_shard[0].base_mults, 250);
        assert_eq!(s.per_shard[0].base_reuses, 450);
        assert_eq!(s.per_shard[1].base_mults, 160);
        assert_eq!(s.per_shard[1].base_reuses, 540);
        // Sum-consistency: shard ops partition the run's base ops.
        let shard_ops: u64 = s
            .per_shard
            .iter()
            .map(|g| g.base_mults + g.base_reuses)
            .sum();
        assert_eq!(shard_ops, 300 + 700 + 110 + 290);
        assert!((s.per_shard[0].reuse_rate - 450.0 / 700.0).abs() < 1e-12);
        // Monolithic-only runs roll up no shard dimension.
        let mono = ServeSummary::from_results(&[result(2, None, 5)], 1, &cost);
        assert!(mono.per_shard.is_empty());
    }

    #[test]
    fn prefix_hit_rate_covers_the_prompt_side_only() {
        let cost = test_cost();
        // Two decode sessions: 16-token prompts + 4 generated each; one
        // resumed 8 prompt tokens from the prefix cache.
        let mut warm = result(0, None, 20);
        warm.gen_tokens = 4;
        warm.cached_tokens = 8;
        let mut cold = result(1, None, 20);
        cold.gen_tokens = 4;
        let s = ServeSummary::from_results(&[warm, cold], 1, &cost);
        assert_eq!(s.cached_tokens, 8);
        // 32 prompt tokens total (generated tokens excluded), 8 cached.
        assert!((s.prefix_hit_rate - 0.25).abs() < 1e-12);
        // Cache-less runs report a zero rate, never NaN.
        let off = ServeSummary::from_results(&[result(2, None, 10)], 1, &cost);
        assert_eq!(off.cached_tokens, 0);
        assert_eq!(off.prefix_hit_rate, 0.0);
        let empty = ServeSummary::from_results(&[], 0, &cost);
        assert_eq!(empty.prefix_hit_rate, 0.0);
        assert!(empty.prefix_hit_rate.is_finite());
    }

    #[test]
    fn slo_attainment_measures_per_class_targets() {
        use crate::workload::SloClass;
        let cost = test_cost();
        let mut policy = SloPolicy::default();
        policy.interactive.ttft_s = 0.1;
        policy.interactive.tpot_s = 0.01;
        policy.batch.ttft_s = 10.0;
        // Interactive request inside its targets; interactive request
        // that blew TTFT; batch request far over the interactive target
        // but inside its own.
        let mut ok = result(0, None, 10);
        ok.slo = SloClass::Interactive;
        ok.ttft_s = 0.05;
        ok.gen_tokens = 4;
        ok.tpot_s = 0.005;
        let mut late = result(1, None, 10);
        late.slo = SloClass::Interactive;
        late.ttft_s = 0.5;
        let mut batch = result(2, None, 10);
        batch.slo = SloClass::Batch;
        batch.ttft_s = 5.0;
        let rs = vec![ok, late, batch];
        let s = ServeSummary::from_results_slo(&rs, 1, &cost, Some(&policy), 2, 1, 4096);
        assert!((s.slo_attainment - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.shed, 2);
        assert_eq!(s.degraded, 1);
        assert_eq!(s.handoff_bytes, 4096);
        // Without a policy the run vacuously attains and carries no
        // overload counters.
        let plain = ServeSummary::from_results(&rs, 1, &cost);
        assert_eq!(plain.slo_attainment, 1.0);
        assert_eq!(plain.shed, 0);
        assert_eq!(plain.degraded, 0);
        assert_eq!(plain.handoff_bytes, 0);
        // Empty result set with a policy: vacuous attainment, not NaN.
        let empty = ServeSummary::from_results_slo(&[], 0, &cost, Some(&policy), 0, 0, 0);
        assert_eq!(empty.slo_attainment, 1.0);
        assert!(empty.slo_attainment.is_finite());
    }

    #[test]
    fn single_sample() {
        let l = LatencyStats::from_samples(vec![0.25]);
        assert_eq!(l.count, 1);
        assert_eq!(l.p50_s, 0.25);
        assert_eq!(l.p99_s, 0.25);
    }
}
