//! Serving metrics: latency percentiles, throughput, and accelerator
//! attribution (cycles, reuse, energy) aggregated over a run — trace-driven
//! or live ([`ServeSummary::from_results`] is the one aggregation both
//! paths share).

use crate::backend::CostModel;
use crate::coordinator::engine::RequestResult;
use crate::model::AdapterId;

/// Latency distribution summary (seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyStats {
    /// Sample count.
    pub count: usize,
    /// Arithmetic mean, seconds.
    pub mean_s: f64,
    /// Median (nearest-rank), seconds.
    pub p50_s: f64,
    /// 95th percentile (nearest-rank), seconds.
    pub p95_s: f64,
    /// 99th percentile (nearest-rank), seconds.
    pub p99_s: f64,
    /// Largest sample, seconds.
    pub max_s: f64,
}

impl LatencyStats {
    /// Compute from raw samples (unordered).
    pub fn from_samples(mut samples: Vec<f64>) -> LatencyStats {
        if samples.is_empty() {
            return LatencyStats::default();
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        // Nearest-rank percentile: the smallest sample such that at least
        // p·n samples are ≤ it, i.e. 1-indexed rank ⌈n·p⌉. The previous
        // ⌊n·p⌋ 0-indexed form over-indexed by one rank (p50 of 1..=100
        // returned the 51st sample, 0.51).
        let pct = |p: f64| {
            let rank = ((n as f64) * p).ceil().max(1.0) as usize;
            samples[rank.min(n) - 1]
        };
        LatencyStats {
            count: n,
            mean_s: samples.iter().sum::<f64>() / n as f64,
            p50_s: pct(0.50),
            p95_s: pct(0.95),
            p99_s: pct(0.99),
            max_s: samples[n - 1],
        }
    }
}

/// One row of the per-adapter serving rollup: how requests served with a
/// given adapter (or base-only, `adapter: None`) fared over the run.
/// This is the measurement channel for the paper's "reuse survives LoRA"
/// claim: the base-pipeline reuse rate of every adapter group should sit
/// within noise of the base-only group's.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AdapterUsage {
    /// Adapter the group was served with (`None` = base-only, including
    /// adapter requests the backend missed).
    pub adapter: Option<AdapterId>,
    /// Requests in the group.
    pub requests: usize,
    /// Total tokens (prompt + generated) attributed to the group.
    pub tokens: u64,
    /// Generated tokens of the group (decode serving).
    pub gen_tokens: u64,
    /// Group tokens per second over the run's span.
    pub throughput_tps: f64,
    /// Dense side-pipeline MACs the group's adapters added.
    pub adapter_ops: u64,
    /// Measured base-pipeline reuse rate of the group (0 when the
    /// backend measured no base ops, e.g. PJRT).
    pub base_reuse_rate: f64,
}

/// End-of-run summary for a served trace.
#[derive(Clone, Debug, Default)]
pub struct ServeSummary {
    /// Requests served.
    pub requests: usize,
    /// Batches (closed-batch serving) or iterations (decode serving).
    pub batches: usize,
    /// Total tokens (prompt + generated) attributed across all requests.
    pub tokens: u64,
    /// Generated tokens across all requests (decode serving; 0 for
    /// prefill-only runs).
    pub gen_tokens: u64,
    /// Wall-clock span of the trace (first arrival → last completion).
    pub span_s: f64,
    /// End-to-end latency distribution (arrival → completion).
    pub latency: LatencyStats,
    /// Time-to-first-token distribution (arrival → first generated
    /// token; equals `latency` for prefill-only serving).
    pub ttft: LatencyStats,
    /// Time-per-output-token distribution over decode sessions that
    /// generated ≥ 2 tokens (empty/zero otherwise).
    pub tpot: LatencyStats,
    /// Requests per second over the span.
    pub throughput_rps: f64,
    /// Tokens per second over the span.
    pub throughput_tps: f64,
    /// Simulated accelerator cycles attributed across all requests.
    pub sim_cycles: u64,
    /// Simulated reuse rate over all attributed work.
    pub sim_reuse_rate: f64,
    /// Simulated energy (J) on the accelerator.
    pub sim_energy_j: f64,
    /// Simulated speedup vs the multiply-only baseline for this workload.
    pub sim_speedup: f64,
    /// Dense adapter side-pipeline MACs across all requests (0 for
    /// base-model-only runs).
    pub adapter_ops: u64,
    /// Per-adapter rollup, base-only group (`adapter: None`) first, then
    /// ascending adapter id. Empty for an empty result set; a single
    /// `None` entry for an adapter-free run.
    pub by_adapter: Vec<AdapterUsage>,
}

impl ServeSummary {
    /// Aggregate per-request results into the end-of-run summary. Used by
    /// `Engine::serve_trace` and by live serving (`Server` / `ServerPool`
    /// drivers), so both report identical metrics for identical results.
    ///
    /// The span runs from the earliest arrival (`dispatch - queue_wait`)
    /// to the latest completion (`dispatch + exec`). An empty result set
    /// is well-defined: zero counts, default (all-zero) latency stats,
    /// and zero — never NaN or infinite — throughputs.
    pub fn from_results(
        results: &[RequestResult],
        batches: usize,
        cost: &CostModel,
    ) -> ServeSummary {
        let latency = LatencyStats::from_samples(results.iter().map(|r| r.latency_s).collect());
        let ttft = LatencyStats::from_samples(results.iter().map(|r| r.ttft_s).collect());
        let tpot = LatencyStats::from_samples(
            results
                .iter()
                .filter(|r| r.gen_tokens > 1)
                .map(|r| r.tpot_s)
                .collect(),
        );
        let tokens: u64 = results.iter().map(|r| r.tokens).sum();
        let gen_tokens: u64 = results.iter().map(|r| r.gen_tokens).sum();
        let first_arrival = results
            .iter()
            .map(|r| r.dispatch_s - r.queue_wait_s)
            .fold(f64::INFINITY, f64::min);
        let last_completion = results
            .iter()
            .map(|r| r.dispatch_s + r.exec_s)
            .fold(f64::NEG_INFINITY, f64::max);
        let span_s = if results.is_empty() {
            1e-9
        } else {
            (last_completion - first_arrival).max(1e-9)
        };
        // Per-adapter rollup: group results by the adapter they were
        // actually served with, base-only (`None`) first.
        let mut groups: Vec<Option<AdapterId>> = results.iter().map(|r| r.adapter).collect();
        groups.sort_unstable();
        groups.dedup();
        let by_adapter = groups
            .into_iter()
            .map(|adapter| {
                let rs: Vec<&RequestResult> =
                    results.iter().filter(|r| r.adapter == adapter).collect();
                let tokens: u64 = rs.iter().map(|r| r.tokens).sum();
                let base_mults: u64 = rs.iter().map(|r| r.base_mults).sum();
                let base_reuses: u64 = rs.iter().map(|r| r.base_reuses).sum();
                let base_ops = base_mults + base_reuses;
                AdapterUsage {
                    adapter,
                    requests: rs.len(),
                    tokens,
                    gen_tokens: rs.iter().map(|r| r.gen_tokens).sum(),
                    throughput_tps: tokens as f64 / span_s,
                    adapter_ops: rs.iter().map(|r| r.adapter_ops).sum(),
                    base_reuse_rate: if base_ops == 0 {
                        0.0
                    } else {
                        base_reuses as f64 / base_ops as f64
                    },
                }
            })
            .collect();
        ServeSummary {
            requests: results.len(),
            batches,
            tokens,
            gen_tokens,
            span_s,
            latency,
            ttft,
            tpot,
            throughput_rps: results.len() as f64 / span_s,
            throughput_tps: tokens as f64 / span_s,
            sim_cycles: results.iter().map(|r| r.sim_cycles).sum(),
            sim_reuse_rate: cost.reuse_rate,
            sim_energy_j: results.iter().map(|r| r.sim_energy_j).sum(),
            sim_speedup: cost.speedup(),
            adapter_ops: results.iter().map(|r| r.adapter_ops).sum(),
            by_adapter,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cost() -> CostModel {
        CostModel {
            cycles_per_token_ax: 100.0,
            cycles_per_token_base: 300.0,
            energy_pj_per_token_ax: 1.0,
            energy_pj_per_token_base: 3.0,
            reuse_rate: 0.7,
            freq_ghz: 1.0,
            attn_cycles_per_ctx_token: 1.0,
            attn_energy_pj_per_ctx_token: 0.1,
            adapter_cycles_per_token: 10.0,
            adapter_energy_pj_per_token: 0.2,
        }
    }

    /// A minimal served-request record for rollup tests.
    fn result(id: u64, adapter: Option<AdapterId>, tokens: u64) -> RequestResult {
        RequestResult {
            id,
            logits: Vec::new(),
            tokens,
            queue_wait_s: 0.0,
            exec_s: 0.001,
            latency_s: 0.001,
            dispatch_s: 0.0,
            batch_size: 1,
            sim_cycles: 100 * tokens,
            sim_energy_j: 1e-12,
            gen_tokens: 0,
            ttft_s: 0.001,
            tpot_s: 0.0,
            adapter,
            base_mults: 30 * tokens,
            base_reuses: 70 * tokens,
            adapter_ops: if adapter.is_some() { 10 * tokens } else { 0 },
        }
    }

    #[test]
    fn by_adapter_rollup_none_only_run_pins_a_single_base_group() {
        // Mirror of the PR 3 empty-summary pin, one dimension up: an
        // adapter-free run must roll up to exactly one `None` group that
        // restates the run totals — no phantom adapter rows.
        let cost = test_cost();
        let rs = vec![result(0, None, 10), result(1, None, 20)];
        let s = ServeSummary::from_results(&rs, 1, &cost);
        assert_eq!(s.adapter_ops, 0);
        assert_eq!(s.by_adapter.len(), 1);
        let g = &s.by_adapter[0];
        assert_eq!(g.adapter, None);
        assert_eq!(g.requests, 2);
        assert_eq!(g.tokens, 30);
        assert_eq!(g.adapter_ops, 0);
        assert!((g.base_reuse_rate - 0.7).abs() < 1e-12);
        assert!((g.throughput_tps - s.throughput_tps).abs() < 1e-9);
    }

    #[test]
    fn by_adapter_rollup_groups_and_orders_mixed_runs() {
        let cost = test_cost();
        let rs = vec![
            result(0, Some(1), 10),
            result(1, None, 5),
            result(2, Some(0), 10),
            result(3, Some(1), 10),
        ];
        let s = ServeSummary::from_results(&rs, 1, &cost);
        // None first, then ascending adapter id.
        let order: Vec<Option<AdapterId>> =
            s.by_adapter.iter().map(|g| g.adapter).collect();
        assert_eq!(order, vec![None, Some(0), Some(1)]);
        assert_eq!(s.by_adapter[0].requests, 1);
        assert_eq!(s.by_adapter[1].requests, 1);
        assert_eq!(s.by_adapter[2].requests, 2);
        assert_eq!(s.by_adapter[2].tokens, 20);
        assert_eq!(s.by_adapter[2].adapter_ops, 200);
        assert_eq!(s.adapter_ops, 300);
        // The paper's claim, measurable: every group's base-pipe reuse
        // rate matches the base-only group's.
        for g in &s.by_adapter {
            assert!((g.base_reuse_rate - s.by_adapter[0].base_reuse_rate).abs() < 1e-12);
        }
        // Groups partition the run.
        let n: usize = s.by_adapter.iter().map(|g| g.requests).sum();
        assert_eq!(n, s.requests);
    }

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 / 100.0).collect();
        let l = LatencyStats::from_samples(samples);
        assert_eq!(l.count, 100);
        assert!(l.p50_s <= l.p95_s && l.p95_s <= l.p99_s && l.p99_s <= l.max_s);
        assert!((l.mean_s - 0.505).abs() < 1e-9);
        // Nearest-rank: p50 of 1..=100 is the 50th sample (0.50), not the
        // 51st — the off-by-one the ⌊n·p⌋ indexing used to produce.
        assert!((l.p50_s - 0.50).abs() < 1e-9);
        assert!((l.p95_s - 0.95).abs() < 1e-9);
        assert!((l.p99_s - 0.99).abs() < 1e-9);
        assert!((l.max_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nearest_rank_on_odd_counts() {
        // n=5, p50 → rank ⌈2.5⌉ = 3 → third-smallest.
        let l = LatencyStats::from_samples(vec![0.5, 0.1, 0.4, 0.2, 0.3]);
        assert!((l.p50_s - 0.3).abs() < 1e-12);
        // p99 → rank ⌈4.95⌉ = 5 → max.
        assert!((l.p99_s - 0.5).abs() < 1e-12);
    }

    #[test]
    fn empty_samples_are_zero() {
        let l = LatencyStats::from_samples(vec![]);
        assert_eq!(l, LatencyStats::default());
        assert_eq!(l.count, 0);
        assert_eq!(l.max_s, 0.0);
        // No NaN can leak out of an empty distribution.
        assert!(l.mean_s == 0.0 && l.p50_s == 0.0 && l.p99_s == 0.0);
    }

    #[test]
    fn empty_result_set_summarizes_without_panic_or_nan() {
        // Regression pin: zero served requests (an empty trace, or a
        // live run that was shut down before any completion) must
        // produce a well-formed summary — zero counts and throughputs,
        // never a NaN span or a divide-by-zero panic.
        let cost = test_cost();
        let s = ServeSummary::from_results(&[], 0, &cost);
        assert_eq!(s.requests, 0);
        assert_eq!(s.batches, 0);
        assert_eq!(s.tokens, 0);
        assert_eq!(s.gen_tokens, 0);
        assert_eq!(s.latency, LatencyStats::default());
        assert_eq!(s.ttft, LatencyStats::default());
        assert_eq!(s.tpot, LatencyStats::default());
        assert!(s.span_s > 0.0 && s.span_s.is_finite(), "span {}", s.span_s);
        assert_eq!(s.throughput_rps, 0.0);
        assert_eq!(s.throughput_tps, 0.0);
        assert!(s.throughput_rps.is_finite() && s.throughput_tps.is_finite());
        assert_eq!(s.sim_cycles, 0);
        assert_eq!(s.sim_energy_j, 0.0);
        // Cost-model-derived rates pass through unchanged.
        assert!((s.sim_speedup - 3.0).abs() < 1e-12);
        assert!((s.sim_reuse_rate - 0.7).abs() < 1e-12);
        // The adapter rollup of an empty run is empty, never a panic.
        assert_eq!(s.adapter_ops, 0);
        assert!(s.by_adapter.is_empty());
    }

    #[test]
    fn single_sample() {
        let l = LatencyStats::from_samples(vec![0.25]);
        assert_eq!(l.count, 1);
        assert_eq!(l.p50_s, 0.25);
        assert_eq!(l.p99_s, 0.25);
    }
}
