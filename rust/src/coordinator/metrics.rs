//! Serving metrics: latency percentiles, throughput, and accelerator
//! attribution (cycles, reuse, energy) aggregated over a run.

/// Latency distribution summary (seconds).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl LatencyStats {
    /// Compute from raw samples (unordered).
    pub fn from_samples(mut samples: Vec<f64>) -> LatencyStats {
        if samples.is_empty() {
            return LatencyStats::default();
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let pct = |p: f64| samples[(((n as f64) * p) as usize).min(n - 1)];
        LatencyStats {
            count: n,
            mean_s: samples.iter().sum::<f64>() / n as f64,
            p50_s: pct(0.50),
            p95_s: pct(0.95),
            p99_s: pct(0.99),
            max_s: samples[n - 1],
        }
    }
}

/// End-of-run summary for a served trace.
#[derive(Clone, Debug, Default)]
pub struct ServeSummary {
    pub requests: usize,
    pub batches: usize,
    pub tokens: u64,
    /// Wall-clock span of the trace (first arrival → last completion).
    pub span_s: f64,
    pub latency: LatencyStats,
    /// Requests per second over the span.
    pub throughput_rps: f64,
    /// Tokens per second over the span.
    pub throughput_tps: f64,
    /// Simulated accelerator cycles attributed across all requests.
    pub sim_cycles: u64,
    /// Simulated reuse rate over all attributed work.
    pub sim_reuse_rate: f64,
    /// Simulated energy (J) on the accelerator.
    pub sim_energy_j: f64,
    /// Simulated speedup vs the multiply-only baseline for this workload.
    pub sim_speedup: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<f64> = (1..=100).map(|i| i as f64 / 100.0).collect();
        let l = LatencyStats::from_samples(samples);
        assert_eq!(l.count, 100);
        assert!(l.p50_s <= l.p95_s && l.p95_s <= l.p99_s && l.p99_s <= l.max_s);
        assert!((l.mean_s - 0.505).abs() < 1e-9);
        assert!((l.p50_s - 0.51).abs() < 1e-9);
        assert!((l.max_s - 1.0).abs() < 1e-12);
    }

    #[test]
    fn empty_samples_are_zero() {
        let l = LatencyStats::from_samples(vec![]);
        assert_eq!(l.count, 0);
        assert_eq!(l.max_s, 0.0);
    }

    #[test]
    fn single_sample() {
        let l = LatencyStats::from_samples(vec![0.25]);
        assert_eq!(l.count, 1);
        assert_eq!(l.p50_s, 0.25);
        assert_eq!(l.p99_s, 0.25);
    }
}
