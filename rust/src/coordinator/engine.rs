//! The inference engine: PJRT functional execution + simulated
//! accelerator attribution for every batch.

use crate::config::AcceleratorConfig;
use crate::coordinator::batcher::{Batch, BatchPolicy, DynamicBatcher};
use crate::coordinator::metrics::{LatencyStats, ServeSummary};
use crate::energy::EnergyModel;
use crate::model::Model;
use crate::runtime::{ArtifactSet, Runtime, TinyWeights};
use crate::sim::{Accelerator, SimStats};
use crate::workload::{synth_embeddings, Request};
use anyhow::Result;
use std::path::Path;

/// Precomputed per-token accelerator costs for the served model
/// (cycles/energy per token of matmul work, AxLLM vs baseline).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    pub cycles_per_token_ax: f64,
    pub cycles_per_token_base: f64,
    pub energy_pj_per_token_ax: f64,
    pub energy_pj_per_token_base: f64,
    pub reuse_rate: f64,
    pub freq_ghz: f64,
}

impl CostModel {
    /// Derive from one simulated token (one input vector through every
    /// weight matrix of the model).
    pub fn from_sim(model: &Model, acc_cfg: AcceleratorConfig) -> CostModel {
        let ax = Accelerator::axllm(acc_cfg).run_model(model, usize::MAX, 11);
        let base = Accelerator::baseline(acc_cfg).run_model(model, usize::MAX, 11);
        let em = EnergyModel::default();
        CostModel {
            cycles_per_token_ax: ax.total.cycles as f64,
            cycles_per_token_base: base.total.cycles as f64,
            energy_pj_per_token_ax: em.energy(&ax.total).total_pj,
            energy_pj_per_token_base: em.energy(&base.total).total_pj,
            reuse_rate: ax.total.reuse_rate(),
            freq_ghz: acc_cfg.freq_ghz,
        }
    }

    pub fn speedup(&self) -> f64 {
        self.cycles_per_token_base / self.cycles_per_token_ax
    }

    /// Simulated accelerator service time for `tokens` tokens, seconds.
    pub fn sim_time_s(&self, tokens: u64) -> f64 {
        self.cycles_per_token_ax * tokens as f64 / (self.freq_ghz * 1e9)
    }
}

/// Per-request outcome.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub logits: Vec<f32>,
    /// Time spent queued before the batch dispatched.
    pub queue_wait_s: f64,
    /// Host (PJRT) execution time of the batch this request rode in.
    pub exec_s: f64,
    /// queue_wait + exec.
    pub latency_s: f64,
    /// Simulated accelerator cycles attributed to this request.
    pub sim_cycles: u64,
    /// Simulated accelerator energy (J).
    pub sim_energy_j: f64,
}

/// The serving engine: compiled artifacts (incl. weights) + cost model.
pub struct Engine {
    _rt: Runtime,
    pub artifacts: ArtifactSet,
    pub cost: CostModel,
    /// Embedding seed base — request `id` deterministically derives its
    /// synthetic embedding stream.
    pub embed_seed: u64,
}

impl Engine {
    /// Load everything from an artifact directory (built by
    /// `make artifacts`).
    pub fn load(dir: &Path, acc_cfg: AcceleratorConfig) -> Result<Engine> {
        let rt = Runtime::cpu()?;
        let artifacts = ArtifactSet::load(&rt, dir)?;
        let model = Model::new(artifacts.manifest.model_config(), artifacts.manifest.seed);
        let cost = CostModel::from_sim(&model, acc_cfg);
        let embed_seed = artifacts.manifest.seed;
        Ok(Engine {
            _rt: rt,
            artifacts,
            cost,
            embed_seed,
        })
    }

    /// The quantized weights the artifact executes with.
    pub fn weights(&self) -> &TinyWeights {
        &self.artifacts.weights
    }

    /// Batch capacity of the compiled model artifact.
    pub fn max_batch(&self) -> usize {
        self.artifacts.manifest.batch
    }

    /// Synthesize the (padded/truncated) embedding block for one request.
    pub fn request_embeddings(&self, req: &Request) -> Vec<f32> {
        let m = &self.artifacts.manifest;
        let mut e = synth_embeddings(
            req.seq_len.min(m.seq),
            m.d_model,
            self.embed_seed ^ req.id.wrapping_mul(0x9E3779B97F4A7C15),
        );
        e.resize(m.seq * m.d_model, 0.0);
        e
    }

    /// Execute one batch through the PJRT model; returns per-request
    /// results (logits + attribution).
    pub fn run_batch(&self, batch: &Batch) -> Result<Vec<RequestResult>> {
        let m = &self.artifacts.manifest;
        assert!(
            batch.requests.len() <= m.batch,
            "batch {} exceeds artifact capacity {}",
            batch.requests.len(),
            m.batch
        );
        // Pad the batch to the compiled size with zero sequences.
        let mut data = vec![0f32; m.batch * m.seq * m.d_model];
        for (slot, req) in batch.requests.iter().enumerate() {
            let e = self.request_embeddings(req);
            data[slot * m.seq * m.d_model..(slot + 1) * m.seq * m.d_model]
                .copy_from_slice(&e);
        }
        let t0 = std::time::Instant::now();
        let logits = self.artifacts.run_tiny_model(&data)?;
        let exec_s = t0.elapsed().as_secs_f64();

        let mut out = Vec::with_capacity(batch.requests.len());
        for (slot, req) in batch.requests.iter().enumerate() {
            let tokens = req.seq_len.min(m.seq) as u64;
            let queue_wait_s = (batch.dispatch_s - req.arrival_s).max(0.0);
            out.push(RequestResult {
                id: req.id,
                logits: logits[slot * m.n_classes..(slot + 1) * m.n_classes].to_vec(),
                queue_wait_s,
                exec_s,
                latency_s: queue_wait_s + exec_s,
                sim_cycles: (self.cost.cycles_per_token_ax * tokens as f64) as u64,
                sim_energy_j: self.cost.energy_pj_per_token_ax * tokens as f64 * 1e-12,
            });
        }
        Ok(out)
    }

    /// Serve a whole arrival-ordered trace; returns per-request results
    /// and the aggregate summary.
    pub fn serve_trace(
        &self,
        trace: Vec<Request>,
        policy: BatchPolicy,
    ) -> Result<(Vec<RequestResult>, ServeSummary)> {
        let policy = BatchPolicy {
            max_batch: policy.max_batch.min(self.max_batch()),
            ..policy
        };
        let n_req = trace.len();
        let first_arrival = trace.first().map(|r| r.arrival_s).unwrap_or(0.0);
        let tokens: u64 = trace
            .iter()
            .map(|r| r.seq_len.min(self.artifacts.manifest.seq) as u64)
            .sum();
        let batches = DynamicBatcher::batch_trace(policy, trace);
        let mut results = Vec::with_capacity(n_req);
        for b in &batches {
            results.extend(self.run_batch(b)?);
        }
        let latency = LatencyStats::from_samples(results.iter().map(|r| r.latency_s).collect());
        let sim_cycles: u64 = results.iter().map(|r| r.sim_cycles).sum();
        let sim_energy_j: f64 = results.iter().map(|r| r.sim_energy_j).sum();
        let span_s = (batches.last().map(|b| b.dispatch_s).unwrap_or(0.0) - first_arrival
            + latency.max_s)
            .max(1e-9);
        let summary = ServeSummary {
            requests: n_req,
            batches: batches.len(),
            tokens,
            span_s,
            latency,
            throughput_rps: n_req as f64 / span_s,
            throughput_tps: tokens as f64 / span_s,
            sim_cycles,
            sim_reuse_rate: self.cost.reuse_rate,
            sim_energy_j,
            sim_speedup: self.cost.speedup(),
        };
        Ok((results, summary))
    }
}

/// Aggregate a set of simulated stats into a serving-attribution record
/// (used by reports and tests without a PJRT dependency).
pub fn attribute(stats: &SimStats, freq_ghz: f64) -> (f64, f64) {
    let em = EnergyModel::default();
    let t = stats.cycles as f64 / (freq_ghz * 1e9);
    (t, em.energy(stats).total_pj * 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn cost_model_reflects_reuse() {
        let model = Model::new(ModelConfig::tiny(), 3);
        let cm = CostModel::from_sim(&model, AcceleratorConfig::paper());
        assert!(cm.speedup() > 1.3, "speedup {}", cm.speedup());
        assert!(cm.reuse_rate > 0.5);
        assert!(cm.energy_pj_per_token_ax < cm.energy_pj_per_token_base);
        assert!(cm.sim_time_s(100) > 0.0);
    }

    #[test]
    fn attribute_converts_units() {
        let s = SimStats {
            cycles: 1_000_000_000,
            mults: 1000,
            ..Default::default()
        };
        let (t, e) = attribute(&s, 1.0);
        assert!((t - 1.0).abs() < 1e-9, "1e9 cycles @1GHz = 1s, got {t}");
        assert!(e > 0.0);
    }
}
