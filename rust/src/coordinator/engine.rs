//! The serving engine, generic over [`ExecutionBackend`]: batches flow
//! from the trace batcher into whichever backend the deployment selected
//! (pure-sim, functional, or PJRT), and every request gets simulated
//! accelerator cycles/energy attributed through the backend's cost model.

use crate::backend::{ExecutionBackend, PjrtBackend};
pub use crate::backend::CostModel;
use crate::config::AcceleratorConfig;
use crate::coordinator::batcher::{Batch, BatchPolicy, DynamicBatcher};
use crate::coordinator::metrics::ServeSummary;
use crate::energy::EnergyModel;
use crate::sim::SimStats;
use crate::workload::Request;
use anyhow::Result;
use std::path::Path;

/// Per-request outcome.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    /// Logits for this request (empty when the backend computes none,
    /// e.g. [`crate::backend::SimBackend`]).
    pub logits: Vec<f32>,
    /// Tokens attributed (sequence length truncated to the backend cap).
    pub tokens: u64,
    /// Time spent queued before the batch dispatched.
    pub queue_wait_s: f64,
    /// Execution time of the batch this request rode in (host wall-clock
    /// for functional/PJRT, simulated service time for the sim backend).
    pub exec_s: f64,
    /// queue_wait + exec.
    pub latency_s: f64,
    /// Dispatch time of the batch this request rode in (same clock as
    /// `Request::arrival_s`).
    pub dispatch_s: f64,
    /// Number of requests in that batch.
    pub batch_size: usize,
    /// Simulated accelerator cycles attributed to this request.
    pub sim_cycles: u64,
    /// Simulated accelerator energy (J).
    pub sim_energy_j: f64,
}

/// The serving engine: a batching/attribution shell around any
/// [`ExecutionBackend`]. Defaults to the PJRT artifact backend so
/// existing call sites (`Engine::load`) keep their meaning.
pub struct Engine<B: ExecutionBackend = PjrtBackend> {
    /// The execution backend every batch dispatches through.
    pub backend: B,
}

impl<B: ExecutionBackend> Engine<B> {
    /// Wrap a constructed backend.
    pub fn new(backend: B) -> Engine<B> {
        Engine { backend }
    }

    /// Per-token accelerator cost model used for attribution.
    pub fn cost(&self) -> &CostModel {
        self.backend.cost()
    }

    /// Batch capacity of the backend.
    pub fn max_batch(&self) -> usize {
        self.backend.max_batch()
    }

    /// Execute one batch through the backend; returns per-request
    /// results (logits + attribution).
    pub fn run_batch(&self, batch: &Batch) -> Result<Vec<RequestResult>> {
        assert!(
            batch.requests.len() <= self.backend.max_batch(),
            "batch {} exceeds backend capacity {}",
            batch.requests.len(),
            self.backend.max_batch()
        );
        let outcome = self.backend.run_batch(&batch.requests)?;
        anyhow::ensure!(
            outcome.logits.len() == batch.requests.len(),
            "backend {} returned {} logit rows for {} requests",
            self.backend.name(),
            outcome.logits.len(),
            batch.requests.len()
        );
        let cost = self.backend.cost();
        let seq_limit = self.backend.seq_limit();
        let exec_s = outcome.exec_s;
        let mut out = Vec::with_capacity(batch.requests.len());
        for (req, logits) in batch.requests.iter().zip(outcome.logits) {
            let tokens = req.seq_len.min(seq_limit) as u64;
            let wait_s = batch.dispatch_s - req.arrival_s;
            // The scheduler never dispatches a batch before one of its
            // requests arrived; a negative wait means the submit-side and
            // dispatch-side clocks use different epochs (the bug the shared
            // server epoch fixed) and must not be clamped away silently.
            debug_assert!(
                wait_s >= -1e-9,
                "negative queue wait {wait_s}s for request {} (dispatch {} < arrival {}): \
                 batching clock epochs are skewed",
                req.id,
                batch.dispatch_s,
                req.arrival_s
            );
            let queue_wait_s = wait_s.max(0.0);
            out.push(RequestResult {
                id: req.id,
                logits,
                tokens,
                queue_wait_s,
                exec_s,
                latency_s: queue_wait_s + exec_s,
                dispatch_s: batch.dispatch_s,
                batch_size: batch.requests.len(),
                sim_cycles: (cost.cycles_per_token_ax * tokens as f64) as u64,
                sim_energy_j: cost.energy_pj_per_token_ax * tokens as f64 * 1e-12,
            });
        }
        Ok(out)
    }

    /// Serve a whole arrival-ordered trace; returns per-request results
    /// and the aggregate summary.
    pub fn serve_trace(
        &self,
        trace: Vec<Request>,
        policy: BatchPolicy,
    ) -> Result<(Vec<RequestResult>, ServeSummary)> {
        let policy = BatchPolicy {
            max_batch: policy.max_batch.min(self.max_batch()),
            ..policy
        };
        let n_req = trace.len();
        let batches = DynamicBatcher::batch_trace(policy, trace);
        let mut results = Vec::with_capacity(n_req);
        for b in &batches {
            results.extend(self.run_batch(b)?);
        }
        let summary = ServeSummary::from_results(&results, batches.len(), self.backend.cost());
        Ok((results, summary))
    }
}

impl Engine {
    /// Load a PJRT-backed engine from an artifact directory (built by
    /// `make artifacts`).
    pub fn load(dir: &Path, acc_cfg: AcceleratorConfig) -> Result<Engine> {
        Ok(Engine::new(PjrtBackend::load(dir, acc_cfg)?))
    }
}

/// Aggregate a set of simulated stats into a serving-attribution record
/// (used by reports and tests without a PJRT dependency).
pub fn attribute(stats: &SimStats, freq_ghz: f64) -> (f64, f64) {
    let em = EnergyModel::default();
    let t = stats.cycles as f64 / (freq_ghz * 1e9);
    (t, em.energy(stats).total_pj * 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::Model;

    #[test]
    fn cost_model_reflects_reuse() {
        let model = Model::new(ModelConfig::tiny(), 3);
        let cm = CostModel::from_sim(&model, AcceleratorConfig::paper());
        assert!(cm.speedup() > 1.3, "speedup {}", cm.speedup());
        assert!(cm.reuse_rate > 0.5);
        assert!(cm.energy_pj_per_token_ax < cm.energy_pj_per_token_base);
        assert!(cm.sim_time_s(100) > 0.0);
    }

    #[test]
    fn attribute_converts_units() {
        let s = SimStats {
            cycles: 1_000_000_000,
            mults: 1000,
            ..Default::default()
        };
        let (t, e) = attribute(&s, 1.0);
        assert!((t - 1.0).abs() < 1e-9, "1e9 cycles @1GHz = 1s, got {t}");
        assert!(e > 0.0);
    }
}
