//! The serving engine, generic over [`ExecutionBackend`]: batches flow
//! from the trace batcher into whichever backend the deployment selected
//! (pure-sim, functional, or PJRT), and every request gets simulated
//! accelerator cycles/energy attributed through the backend's cost model.
//!
//! Two serving shapes share the engine:
//!
//! - **prefill-only** ([`Engine::serve_trace`]) — the original
//!   closed-batch path: one request = one forward pass;
//! - **decode** ([`Engine::serve_trace_decode`]) — phase-aware
//!   continuous batching: requests become autoregressive sessions
//!   (`prefill` → `decode_step`×budget) and the iteration loop admits
//!   new sessions / retires finished ones at every step boundary, on a
//!   deterministic virtual clock driven by
//!   [`CostModel::iteration_time_s`]. The closed-batch decode
//!   comparator ([`Engine::serve_trace_decode_closed`]) exists so
//!   `benches/decode_serve.rs` can measure what continuous batching
//!   buys.

use crate::backend::{
    ChunkedPrefill, ExecutionBackend, KvHandle, PjrtBackend, ReqActivity, ShardActivity,
};
pub use crate::backend::CostModel;
use crate::config::{AcceleratorConfig, ExecProfile, ModelConfig};
use crate::coordinator::batcher::{Batch, BatchPolicy, BatchScheduler, DynamicBatcher, SloPolicy};
use crate::coordinator::metrics::ServeSummary;
use crate::energy::EnergyModel;
use crate::model::AdapterId;
use crate::sim::SimStats;
use crate::workload::{Request, SloClass};
use anyhow::Result;
use std::path::Path;

/// Per-request outcome.
#[derive(Clone, Debug)]
pub struct RequestResult {
    /// Request id this result answers.
    pub id: u64,
    /// Logits for this request (empty when the backend computes none,
    /// e.g. [`crate::backend::SimBackend`]).
    pub logits: Vec<f32>,
    /// Tokens attributed (sequence length truncated to the backend cap).
    pub tokens: u64,
    /// Time spent queued before the batch dispatched.
    pub queue_wait_s: f64,
    /// Execution time of the batch this request rode in (host wall-clock
    /// for functional/PJRT, simulated service time for the sim backend).
    pub exec_s: f64,
    /// queue_wait + exec.
    pub latency_s: f64,
    /// Dispatch time of the batch this request rode in (same clock as
    /// `Request::arrival_s`).
    pub dispatch_s: f64,
    /// Number of requests in that batch.
    pub batch_size: usize,
    /// Simulated accelerator cycles attributed to this request.
    pub sim_cycles: u64,
    /// Simulated accelerator energy (J).
    pub sim_energy_j: f64,
    /// Generated tokens (decode sessions; 0 for prefill-only serving).
    pub gen_tokens: u64,
    /// Prompt tokens served from the shared prefix KV cache instead of
    /// being recomputed (0 for untagged requests and cache-less
    /// backends). Counted inside `tokens`; attribution bills them at
    /// block-copy rate rather than a full weight pass.
    pub cached_tokens: u64,
    /// Time to first token: arrival → first generated token (prefill
    /// completion). Equals `latency_s` for prefill-only serving, where
    /// the first "token" is the whole answer.
    pub ttft_s: f64,
    /// Time per output token after the first (0 when fewer than two
    /// tokens were generated).
    pub tpot_s: f64,
    /// LoRA adapter the request was actually served with (`None` when
    /// base-only — including adapter requests the backend missed).
    pub adapter: Option<AdapterId>,
    /// SLO class the request was served under (attainment accounting;
    /// [`SloClass::Standard`] when the trace carries no class mix).
    pub slo: SloClass,
    /// True when SLO admission shed this request before execution: the
    /// request was never served, only its identity/queue fields are
    /// meaningful, and aggregation
    /// ([`crate::coordinator::ServeSummary::from_results_slo`]) must
    /// exclude the row and count it as shed instead. Deterministic trace
    /// serving never emits shed rows (it reports counts only); the live
    /// disaggregated pool answers shed waiters with a marker row so
    /// their reply channels resolve.
    pub shed: bool,
    /// Measured base-pipeline multiplications (Result-Cache fills);
    /// 0 when the backend measures nothing itself.
    pub base_mults: u64,
    /// Measured base-pipeline reuses (Result-Cache hits).
    pub base_reuses: u64,
    /// Dense MACs on the adapter side pipeline (0 for base-only serving).
    pub adapter_ops: u64,
    /// Per-shard split of the base-pipeline counters for tensor-parallel
    /// serving (empty when the request executed monolithically; one
    /// entry per shard — summing to `base_mults`/`base_reuses` —
    /// otherwise).
    pub per_shard: Vec<ShardActivity>,
}

/// Options for continuous-batching decode serving
/// ([`Engine::serve_trace_decode_opts`]).
#[derive(Clone, Debug)]
pub struct DecodeServeOpts {
    /// Generated-token budget for requests whose `gen_tokens` is 0.
    pub default_gen: u32,
    /// Per-iteration chunked-prefill token budget: admitted prompts are
    /// sliced into chunks of at most this many tokens, interleaved with
    /// decode iterations. `0` disables chunking (monolithic prefill —
    /// the exact [`Engine::serve_trace_decode`] behavior).
    pub chunk_tokens: usize,
    /// SLO-aware admission policy. `None` keeps plain FIFO admission
    /// with no shedding or degradation.
    pub slo: Option<SloPolicy>,
}

impl DecodeServeOpts {
    /// Plain continuous batching: monolithic prefill, FIFO admission.
    pub fn new(default_gen: u32) -> DecodeServeOpts {
        DecodeServeOpts {
            default_gen,
            chunk_tokens: 0,
            slo: None,
        }
    }

    /// Enable chunked prefill with a per-iteration token budget.
    pub fn with_chunking(mut self, chunk_tokens: usize) -> DecodeServeOpts {
        self.chunk_tokens = chunk_tokens;
        self
    }

    /// Enable SLO-aware admission under `policy`.
    pub fn with_slo(mut self, policy: SloPolicy) -> DecodeServeOpts {
        self.slo = Some(policy);
        self
    }
}

/// The serving engine: a batching/attribution shell around any
/// [`ExecutionBackend`]. Defaults to the PJRT artifact backend so
/// existing call sites (`Engine::load`) keep their meaning.
pub struct Engine<B: ExecutionBackend = PjrtBackend> {
    /// The execution backend every batch dispatches through.
    pub backend: B,
}

impl<B: ExecutionBackend> Engine<B> {
    /// Wrap a constructed backend.
    pub fn new(backend: B) -> Engine<B> {
        Engine { backend }
    }

    /// Build an engine whose backend is constructed from one
    /// [`ExecProfile`] ([`ExecutionBackend::from_profile`]) — the
    /// uniform construction path the CLI and the profile sweeps use for
    /// every backend kind.
    pub fn from_profile(model_cfg: &ModelConfig, profile: &ExecProfile) -> Result<Engine<B>> {
        Ok(Engine::new(B::from_profile(model_cfg, profile)?))
    }

    /// Per-token accelerator cost model used for attribution.
    pub fn cost(&self) -> &CostModel {
        self.backend.cost()
    }

    /// Batch capacity of the backend.
    pub fn max_batch(&self) -> usize {
        self.backend.max_batch()
    }

    /// Execute one batch through the backend; returns per-request
    /// results (logits + attribution).
    pub fn run_batch(&self, batch: &Batch) -> Result<Vec<RequestResult>> {
        assert!(
            batch.requests.len() <= self.backend.max_batch(),
            "batch {} exceeds backend capacity {}",
            batch.requests.len(),
            self.backend.max_batch()
        );
        let outcome = self.backend.run_batch(&batch.requests)?;
        anyhow::ensure!(
            outcome.logits.len() == batch.requests.len(),
            "backend {} returned {} logit rows for {} requests",
            self.backend.name(),
            outcome.logits.len(),
            batch.requests.len()
        );
        anyhow::ensure!(
            outcome.activity.len() == batch.requests.len(),
            "backend {} returned {} activity records for {} requests",
            self.backend.name(),
            outcome.activity.len(),
            batch.requests.len()
        );
        let cost = self.backend.cost();
        let seq_limit = self.backend.seq_limit();
        let exec_s = outcome.exec_s;
        let mut out = Vec::with_capacity(batch.requests.len());
        for ((req, logits), activity) in batch
            .requests
            .iter()
            .zip(outcome.logits)
            .zip(outcome.activity)
        {
            let tokens = req.seq_len.min(seq_limit) as u64;
            // A request was served with its adapter iff the backend did
            // side-pipe work for it; missed adapters attribute base-only.
            let routed = activity.adapter_ops > 0;
            let adapter_cycles = if routed {
                cost.adapter_cycles_per_token * tokens as f64
            } else {
                0.0
            };
            let adapter_energy_pj = if routed {
                cost.adapter_energy_pj_per_token * tokens as f64
            } else {
                0.0
            };
            let wait_s = batch.dispatch_s - req.arrival_s;
            // The scheduler never dispatches a batch before one of its
            // requests arrived; a negative wait means the submit-side and
            // dispatch-side clocks use different epochs (the bug the shared
            // server epoch fixed) and must not be clamped away silently.
            debug_assert!(
                wait_s >= -1e-9,
                "negative queue wait {wait_s}s for request {} (dispatch {} < arrival {}): \
                 batching clock epochs are skewed",
                req.id,
                batch.dispatch_s,
                req.arrival_s
            );
            let queue_wait_s = wait_s.max(0.0);
            let ReqActivity {
                base_mults,
                base_reuses,
                adapter_ops,
                per_shard,
            } = activity;
            out.push(RequestResult {
                id: req.id,
                logits,
                tokens,
                queue_wait_s,
                exec_s,
                latency_s: queue_wait_s + exec_s,
                dispatch_s: batch.dispatch_s,
                batch_size: batch.requests.len(),
                sim_cycles: (cost.cycles_per_token_ax * tokens as f64 + adapter_cycles) as u64,
                sim_energy_j: (cost.energy_pj_per_token_ax * tokens as f64 + adapter_energy_pj)
                    * 1e-12,
                gen_tokens: 0,
                cached_tokens: 0,
                ttft_s: queue_wait_s + exec_s,
                tpot_s: 0.0,
                adapter: if routed { req.adapter } else { None },
                slo: req.slo,
                shed: false,
                base_mults,
                base_reuses,
                adapter_ops,
                per_shard,
            });
        }
        Ok(out)
    }

    /// Serve a whole arrival-ordered trace; returns per-request results
    /// and the aggregate summary.
    pub fn serve_trace(
        &self,
        trace: Vec<Request>,
        policy: BatchPolicy,
    ) -> Result<(Vec<RequestResult>, ServeSummary)> {
        let policy = BatchPolicy {
            max_batch: policy.max_batch.min(self.max_batch()),
            ..policy
        };
        let n_req = trace.len();
        let batches = DynamicBatcher::batch_trace(policy, trace);
        let mut results = Vec::with_capacity(n_req);
        for b in &batches {
            results.extend(self.run_batch(b)?);
        }
        let summary = ServeSummary::from_results(&results, batches.len(), self.backend.cost());
        Ok((results, summary))
    }

    /// Continuous-batching decode serving over an arrival-ordered trace,
    /// on a deterministic virtual clock.
    ///
    /// The loop is token-level: each iteration (a) admits pending
    /// arrivals into free session slots (FIFO through the shared
    /// [`BatchScheduler::take_ready`] rule), (b) takes one decode step
    /// for every running session and prefills the newly admitted ones —
    /// through the backend's wave APIs
    /// ([`ExecutionBackend::decode_steps`] /
    /// [`ExecutionBackend::prefill_batch`]), which thread-parallel
    /// backends overlap without changing any outcome — and (c) retires
    /// sessions that exhausted their generated-token budget. The clock
    /// advances by [`CostModel::iteration_time_s`]:
    /// prefill tokens pay per-token weight passes; all decode steps of an
    /// iteration share one weight pass (the weight-bound GEMV regime).
    /// Keeping the running batch full is therefore what buys throughput
    /// — exactly what closed batches can't do
    /// ([`Engine::serve_trace_decode_closed`]).
    ///
    /// `default_gen` is the generated-token budget for requests whose
    /// `gen_tokens` is 0. Backends execute for real (logits and tokens
    /// are theirs); the clock is always the modeled accelerator time, so
    /// results are deterministic and backend-comparable.
    pub fn serve_trace_decode(
        &self,
        trace: Vec<Request>,
        policy: BatchPolicy,
        default_gen: u32,
    ) -> Result<(Vec<RequestResult>, ServeSummary)> {
        self.serve_trace_decode_opts(trace, policy, DecodeServeOpts::new(default_gen))
    }

    /// [`Engine::serve_trace_decode`] with the full option set: chunked
    /// prefill and SLO-aware admission ([`DecodeServeOpts`]).
    ///
    /// **Chunked prefill** (`chunk_tokens > 0`): admitted prompts become
    /// [`ChunkedPrefill`] jobs instead of running a monolithic
    /// `prefill_batch`. Each iteration spends at most `chunk_tokens`
    /// prompt tokens across the in-flight jobs (FIFO), interleaved with
    /// the decode wave — so no decode iteration ever waits behind a full
    /// long prompt, at the price of later first tokens for the chunked
    /// prompts themselves. Chunk jobs occupy session slots while they
    /// prefill (they hold KV). The backend contract
    /// ([`ExecutionBackend::prefill_chunk`]) guarantees the completed
    /// session — logits, token, reuse counters — is bit-identical to the
    /// monolithic prefill; only the clock differs.
    ///
    /// **SLO admission** (`slo: Some(policy)`): free slots are filled
    /// through [`BatchScheduler::take_ready_slo`] — priority classes,
    /// aging boost, degradation, shedding — instead of plain FIFO. Shed
    /// requests never execute and are excluded from `results`; the
    /// summary carries their count (and the degraded count) alongside
    /// per-class SLO attainment.
    pub fn serve_trace_decode_opts(
        &self,
        trace: Vec<Request>,
        policy: BatchPolicy,
        opts: DecodeServeOpts,
    ) -> Result<(Vec<RequestResult>, ServeSummary)> {
        let cap = policy.max_batch.min(self.max_batch()).max(1);
        let cost = *self.cost();
        let mut sched = BatchScheduler::new(BatchPolicy {
            max_batch: cap,
            ..policy
        });
        let mut arrivals = trace.into_iter().peekable();
        let mut active: Vec<DecodeSession> = Vec::new();
        // In-flight chunked-prefill jobs (each owns a session slot) plus
        // the virtual-clock stamp at which the job was admitted.
        let mut chunk_jobs: Vec<(ChunkedPrefill, f64)> = Vec::new();
        let mut results: Vec<RequestResult> = Vec::new();
        let mut iterations = 0usize;
        let mut clock = 0.0f64;
        let mut shed = 0usize;
        let mut degraded = 0usize;

        loop {
            while arrivals.peek().map_or(false, |r| r.arrival_s <= clock) {
                sched.enqueue(arrivals.next().expect("peeked"));
            }
            let free = cap.saturating_sub(active.len() + chunk_jobs.len());
            let admitted = match &opts.slo {
                Some(policy) => {
                    let adm = sched.take_ready_slo(free, clock, policy);
                    shed += adm.shed.len();
                    degraded += adm.degraded;
                    adm.admitted
                }
                None => sched.take_ready(free),
            };
            if active.is_empty() && chunk_jobs.is_empty() && admitted.is_empty() {
                // Idle: jump to the next arrival, or finish.
                match arrivals.peek() {
                    Some(r) => {
                        clock = clock.max(r.arrival_s);
                        continue;
                    }
                    None => break,
                }
            }

            iterations += 1;
            let batch_now = active.len() + chunk_jobs.len() + admitted.len();
            let mut prefill_tokens = 0u64;
            // Prompt tokens resumed from the shared prefix cache this
            // iteration: billed at block-copy rate, not a weight pass.
            let mut copied_tokens = 0u64;
            // Adapter side-pipe tokens this iteration: per-session dense
            // work, never amortized by the shared decode weight pass.
            let mut adapter_tokens = 0u64;
            let mut decode_ctxs: Vec<u64> = Vec::with_capacity(active.len());
            for s in active.iter() {
                let ctx = s.kv.context_len() as u64;
                decode_ctxs.push(ctx);
                adapter_tokens += s.kv.adapter.is_some() as u64;
            }
            // One decode wave through the backend's batch API (session
            // order is preserved, so attribution below is unchanged).
            let kv_refs: Vec<&mut KvHandle> = active.iter_mut().map(|s| &mut s.kv).collect();
            let outs = self.backend.decode_steps(kv_refs)?;
            for ((s, ctx), out) in active.iter_mut().zip(&decode_ctxs).zip(outs) {
                s.record_step(*ctx, out, &cost);
                s.peak_batch = s.peak_batch.max(batch_now);
            }
            if opts.chunk_tokens == 0 {
                // Monolithic prefill: the whole admitted prompt set runs
                // this iteration (the original serve_trace_decode path).
                let jobs: Vec<(Request, u32)> = admitted
                    .into_iter()
                    .map(|req| {
                        let budget = decode_budget(&req, opts.default_gen);
                        (req, budget)
                    })
                    .collect();
                let prefilled = self.backend.prefill_batch(&jobs)?;
                for ((req, _), (kv, out)) in jobs.iter().zip(prefilled) {
                    let computed = (kv.prompt_len - kv.cached_tokens) as u64;
                    prefill_tokens += computed;
                    copied_tokens += kv.cached_tokens as u64;
                    if kv.adapter.is_some() {
                        adapter_tokens += computed;
                    }
                    active.push(DecodeSession::admit(
                        kv,
                        out,
                        req.arrival_s,
                        clock,
                        &cost,
                        batch_now,
                    ));
                }
            } else {
                for req in admitted {
                    let budget = decode_budget(&req, opts.default_gen);
                    chunk_jobs.push((ChunkedPrefill::new(req, budget), clock));
                }
                // Spend the per-iteration chunk budget FIFO across the
                // in-flight jobs; completed jobs join the decode batch.
                let mut budget_left = opts.chunk_tokens;
                let mut i = 0;
                while i < chunk_jobs.len() && budget_left > 0 {
                    let (job, admit_s) = &mut chunk_jobs[i];
                    let outcome = self.backend.prefill_chunk(job, budget_left)?;
                    prefill_tokens += outcome.computed_tokens;
                    copied_tokens += outcome.copied_tokens;
                    adapter_tokens += outcome.adapter_tokens;
                    budget_left -= (outcome.computed_tokens as usize).min(budget_left);
                    if let Some((kv, out)) = outcome.done {
                        let arrival_s = job.req.arrival_s;
                        let admit_s = *admit_s;
                        chunk_jobs.remove(i);
                        active.push(DecodeSession::admit(
                            kv, out, arrival_s, admit_s, &cost, batch_now,
                        ));
                    } else {
                        i += 1;
                    }
                }
            }
            clock += cost.iteration_time_s(prefill_tokens, &decode_ctxs)
                + cost.kv_copy_time_s(copied_tokens)
                + cost.adapter_time_s(adapter_tokens);
            let mut i = 0;
            while i < active.len() {
                let s = &mut active[i];
                if s.ttft_abs.is_none() {
                    // The session's first token (from prefill) completed
                    // within this iteration.
                    s.ttft_abs = Some(clock);
                }
                if s.kv.done() {
                    let mut done = active.swap_remove(i);
                    done.finish_abs = Some(clock);
                    results.push(done.into_result());
                } else {
                    i += 1;
                }
            }
        }
        let summary = ServeSummary::from_results_slo(
            &results,
            iterations,
            self.backend.cost(),
            opts.slo.as_ref(),
            shed,
            degraded,
            0,
        );
        Ok((results, summary))
    }

    /// Closed-batch decode comparator: batches form through the
    /// closed-batch `batch_trace` rules and then **run to completion** —
    /// no admissions at step boundaries, so slots retired by short
    /// sessions idle until the whole batch drains. This is the baseline
    /// `benches/decode_serve.rs` measures continuous batching against;
    /// attribution and per-step execution are identical to
    /// [`Engine::serve_trace_decode`].
    pub fn serve_trace_decode_closed(
        &self,
        trace: Vec<Request>,
        policy: BatchPolicy,
        default_gen: u32,
    ) -> Result<(Vec<RequestResult>, ServeSummary)> {
        let policy = BatchPolicy {
            max_batch: policy.max_batch.min(self.max_batch()).max(1),
            ..policy
        };
        let cost = *self.cost();
        let batches = DynamicBatcher::batch_trace(policy, trace);
        let mut results: Vec<RequestResult> = Vec::new();
        let mut iterations = 0usize;
        let mut clock = 0.0f64;
        for b in batches {
            clock = clock.max(b.dispatch_s);
            let batch_size = b.requests.len();
            // Iteration 1: prefill the whole batch.
            iterations += 1;
            let mut sessions: Vec<DecodeSession> = Vec::with_capacity(batch_size);
            let mut prefill_tokens = 0u64;
            let mut copied_tokens = 0u64;
            let mut adapter_tokens = 0u64;
            let jobs: Vec<(Request, u32)> = b
                .requests
                .into_iter()
                .map(|req| {
                    let budget = decode_budget(&req, default_gen);
                    (req, budget)
                })
                .collect();
            let prefilled = self.backend.prefill_batch(&jobs)?;
            for ((req, _), (kv, out)) in jobs.iter().zip(prefilled) {
                let computed = (kv.prompt_len - kv.cached_tokens) as u64;
                prefill_tokens += computed;
                copied_tokens += kv.cached_tokens as u64;
                if kv.adapter.is_some() {
                    adapter_tokens += computed;
                }
                sessions.push(DecodeSession::admit(
                    kv,
                    out,
                    req.arrival_s,
                    clock,
                    &cost,
                    batch_size,
                ));
            }
            clock += cost.iteration_time_s(prefill_tokens, &[])
                + cost.kv_copy_time_s(copied_tokens)
                + cost.adapter_time_s(adapter_tokens);
            for s in sessions.iter_mut() {
                s.ttft_abs = Some(clock);
                if s.kv.done() {
                    s.finish_abs = Some(clock);
                }
            }
            // Lockstep decode until the whole batch drains; finished
            // sessions idle their slot (the closed-batch cost).
            while sessions.iter().any(|s| s.finish_abs.is_none()) {
                iterations += 1;
                let mut decode_ctxs = Vec::new();
                let mut adapter_steps = 0u64;
                let mut stepping: Vec<&mut DecodeSession> = sessions
                    .iter_mut()
                    .filter(|s| s.finish_abs.is_none())
                    .collect();
                for s in stepping.iter() {
                    let ctx = s.kv.context_len() as u64;
                    decode_ctxs.push(ctx);
                    adapter_steps += s.kv.adapter.is_some() as u64;
                }
                let kv_refs: Vec<&mut KvHandle> =
                    stepping.iter_mut().map(|s| &mut s.kv).collect();
                let outs = self.backend.decode_steps(kv_refs)?;
                for ((s, ctx), out) in stepping.iter_mut().zip(&decode_ctxs).zip(outs) {
                    s.record_step(*ctx, out, &cost);
                }
                clock += cost.iteration_time_s(0, &decode_ctxs)
                    + cost.adapter_time_s(adapter_steps);
                for s in sessions.iter_mut() {
                    if s.kv.done() && s.finish_abs.is_none() {
                        s.finish_abs = Some(clock);
                    }
                }
            }
            results.extend(sessions.into_iter().map(DecodeSession::into_result));
        }
        let summary = ServeSummary::from_results(&results, iterations, self.backend.cost());
        Ok((results, summary))
    }
}

/// Budget resolution shared by every decode path: the request's own
/// `gen_tokens` wins; 0 falls back to the caller's default; the result is
/// always ≥ 1 (a session produces at least its prefill token).
pub(crate) fn decode_budget(req: &Request, default_gen: u32) -> u32 {
    let g = if req.gen_tokens > 0 {
        req.gen_tokens
    } else {
        default_gen
    };
    g.max(1)
}

/// Bookkeeping for one in-flight decode session. ONE implementation for
/// both decode serving paths — the engine's virtual-clock loops and the
/// live `Server` decode worker — so cost accumulation and the TTFT/TPOT
/// result math cannot drift between trace and live reporting (the same
/// reason `ServeSummary::from_results` is shared).
pub(crate) struct DecodeSession {
    pub(crate) kv: KvHandle,
    pub(crate) arrival_s: f64,
    pub(crate) admit_s: f64,
    /// Completion stamp of the first generated token (prefill); `None`
    /// until the caller's clock observes it.
    pub(crate) ttft_abs: Option<f64>,
    /// Completion stamp of the last generated token.
    pub(crate) finish_abs: Option<f64>,
    pub(crate) prompt_tokens: u64,
    pub(crate) last_logits: Vec<f32>,
    pub(crate) cycles: f64,
    pub(crate) energy_pj: f64,
    pub(crate) peak_batch: usize,
    /// Accumulated base-vs-adapter activity across prefill + steps.
    pub(crate) activity: ReqActivity,
}

impl DecodeSession {
    /// Open a session from a completed prefill, attributing the prompt's
    /// weight passes (plus the adapter side pipe for adapter sessions).
    /// Prompt tokens resumed from the prefix cache bill at block-copy
    /// rate instead of a weight pass. TTFT/finish stamps are left for
    /// the caller's clock.
    pub(crate) fn admit(
        kv: KvHandle,
        first: crate::backend::StepOutcome,
        arrival_s: f64,
        admit_s: f64,
        cost: &CostModel,
        batch_now: usize,
    ) -> DecodeSession {
        let prompt_tokens = kv.prompt_len as u64;
        let copied_tokens = kv.cached_tokens as u64;
        let computed_tokens = prompt_tokens - copied_tokens;
        let adapter_tokens = if kv.adapter.is_some() {
            computed_tokens
        } else {
            0
        };
        DecodeSession {
            kv,
            arrival_s,
            admit_s,
            ttft_abs: None,
            finish_abs: None,
            prompt_tokens,
            last_logits: first.logits,
            cycles: cost.cycles_per_token_ax * computed_tokens as f64
                + cost.kv_copy_cycles_per_token * copied_tokens as f64
                + cost.adapter_cycles_per_token * adapter_tokens as f64,
            energy_pj: cost.energy_pj_per_token_ax * computed_tokens as f64
                + cost.kv_copy_energy_pj_per_token * copied_tokens as f64
                + cost.adapter_energy_pj_per_token * adapter_tokens as f64,
            peak_batch: batch_now,
            activity: first.activity,
        }
    }

    /// Record one completed decode step taken at context length `ctx`
    /// (standalone attribution — batch-independent by construction:
    /// base step cost from the session's own context, adapter side-pipe
    /// cost from the session's own adapter).
    pub(crate) fn record_step(
        &mut self,
        ctx: u64,
        out: crate::backend::StepOutcome,
        cost: &CostModel,
    ) {
        if !out.logits.is_empty() {
            self.last_logits = out.logits;
        }
        self.activity.add(&out.activity);
        self.cycles += cost.decode_step_cycles(ctx);
        self.energy_pj += cost.decode_step_energy_pj(ctx);
        if self.kv.adapter.is_some() {
            self.cycles += cost.adapter_cycles_per_token;
            self.energy_pj += cost.adapter_energy_pj_per_token;
        }
    }

    pub(crate) fn into_result(self) -> RequestResult {
        let gen = self.kv.generated.len() as u64;
        let finish = self.finish_abs.unwrap_or(self.admit_s);
        let ttft_abs = self.ttft_abs.unwrap_or(finish);
        let tpot_s = if gen > 1 {
            ((finish - ttft_abs) / (gen - 1) as f64).max(0.0)
        } else {
            0.0
        };
        let ReqActivity {
            base_mults,
            base_reuses,
            adapter_ops,
            per_shard,
        } = self.activity;
        RequestResult {
            id: self.kv.id,
            adapter: self.kv.adapter,
            slo: self.kv.slo,
            shed: false,
            logits: self.last_logits,
            tokens: self.prompt_tokens + gen,
            queue_wait_s: (self.admit_s - self.arrival_s).max(0.0),
            exec_s: (finish - self.admit_s).max(0.0),
            latency_s: (finish - self.arrival_s).max(0.0),
            dispatch_s: self.admit_s,
            batch_size: self.peak_batch.max(1),
            sim_cycles: self.cycles as u64,
            sim_energy_j: self.energy_pj * 1e-12,
            gen_tokens: gen,
            cached_tokens: self.kv.cached_tokens as u64,
            ttft_s: (ttft_abs - self.arrival_s).max(0.0),
            tpot_s,
            base_mults,
            base_reuses,
            adapter_ops,
            per_shard,
        }
    }
}

impl Engine {
    /// Load a PJRT-backed engine from an artifact directory (built by
    /// `make artifacts`).
    pub fn load(dir: &Path, acc_cfg: AcceleratorConfig) -> Result<Engine> {
        Ok(Engine::new(PjrtBackend::load(dir, acc_cfg)?))
    }
}

/// Aggregate a set of simulated stats into a serving-attribution record
/// (used by reports and tests without a PJRT dependency).
pub fn attribute(stats: &SimStats, freq_ghz: f64) -> (f64, f64) {
    let em = EnergyModel::default();
    let t = stats.cycles as f64 / (freq_ghz * 1e9);
    (t, em.energy(stats).total_pj * 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::Model;

    #[test]
    fn cost_model_reflects_reuse() {
        let model = Model::new(ModelConfig::tiny(), 3);
        let cm = CostModel::from_sim(&model, AcceleratorConfig::paper());
        assert!(cm.speedup() > 1.3, "speedup {}", cm.speedup());
        assert!(cm.reuse_rate > 0.5);
        assert!(cm.energy_pj_per_token_ax < cm.energy_pj_per_token_base);
        assert!(cm.sim_time_s(100) > 0.0);
    }

    #[test]
    fn decode_budget_resolution() {
        use crate::config::Dataset;
        let mk = |gen_tokens: u32| crate::workload::Request {
            id: 0,
            dataset: Dataset::Imdb,
            seq_len: 8,
            arrival_s: 0.0,
            gen_tokens,
            adapter: None,
            prefix: None,
            slo: SloClass::Standard,
        };
        assert_eq!(decode_budget(&mk(5), 2), 5, "request budget wins");
        assert_eq!(decode_budget(&mk(0), 2), 2, "0 falls back to default");
        assert_eq!(decode_budget(&mk(0), 0), 1, "budget is always ≥ 1");
    }

    #[test]
    fn cost_model_carries_the_decode_regime() {
        let model = Model::new(ModelConfig::tiny(), 3);
        let cm = CostModel::from_sim(&model, AcceleratorConfig::paper());
        assert!(cm.attn_cycles_per_ctx_token > 0.0);
        assert!(cm.attn_energy_pj_per_ctx_token > 0.0);
        // Step cost grows linearly with context.
        let d0 = cm.decode_step_cycles(0);
        let d8 = cm.decode_step_cycles(8);
        let d16 = cm.decode_step_cycles(16);
        assert!(((d16 - d8) - (d8 - d0)).abs() < 1e-9);
        assert!(d16 > d8 && d8 > d0);
        assert!((d0 - cm.cycles_per_token_ax).abs() < 1e-9);
    }

    #[test]
    fn adapter_regime_is_purely_additive() {
        let model = Model::new(ModelConfig::tiny(), 3);
        let cm = CostModel::from_sim(&model, AcceleratorConfig::paper());
        assert_eq!(cm.adapter_cycles_per_token, 0.0);
        assert_eq!(cm.adapter_time_s(10), 0.0);
        let with = cm.with_adapter_regime(&ModelConfig::tiny(), AcceleratorConfig::paper(), 16);
        assert!(with.adapter_cycles_per_token > 0.0);
        assert!(with.adapter_energy_pj_per_token > 0.0);
        assert!(with.adapter_time_s(10) > 0.0);
        // The base pipe — and its reuse discount — is untouched.
        assert_eq!(with.cycles_per_token_ax, cm.cycles_per_token_ax);
        assert_eq!(with.energy_pj_per_token_ax, cm.energy_pj_per_token_ax);
        assert_eq!(with.reuse_rate, cm.reuse_rate);
        // Rank scales the dense side pipe linearly.
        let wide = cm.with_adapter_regime(&ModelConfig::tiny(), AcceleratorConfig::paper(), 32);
        assert!(wide.adapter_cycles_per_token > with.adapter_cycles_per_token);
    }

    #[test]
    fn shard_regime_divides_compute_and_charges_the_collective() {
        let model = Model::new(ModelConfig::tiny(), 3);
        let cm = CostModel::from_sim(&model, AcceleratorConfig::paper());
        // Monolithic: no collective, speedup exactly 1.
        assert_eq!(cm.shards, 1);
        assert_eq!(cm.allreduce_time_s(1e6, 1), 0.0);
        assert_eq!(cm.shard_speedup(100), 1.0);
        let sh = cm.with_shard_regime(&ModelConfig::tiny(), 4);
        assert_eq!(sh.shards, 4);
        assert!(sh.gather_bytes_per_token > 0.0);
        // Compute divides by N; the collective term keeps the total above
        // compute/N but (for a real token batch) below the monolithic
        // time → sub-linear speedup in (1, N).
        let tokens = 128;
        let mono = cm.sim_time_s(tokens);
        let sharded = sh.sim_time_s(tokens);
        assert!(sharded > mono / 4.0, "{sharded} vs mono/4 {}", mono / 4.0);
        assert!(sharded < mono, "{sharded} vs mono {mono}");
        let s = sh.shard_speedup(tokens);
        assert!(s > 1.0 && s < 4.0, "speedup {s}");
        // Zero-token passes pay nothing, sharded or not.
        assert_eq!(sh.sim_time_s(0), 0.0);
        assert_eq!(sh.iteration_time_s(0, &[]), 0.0);
        // Iteration and step times stay shard-consistent: a sharded
        // iteration with a meaningful token batch is cheaper than the
        // monolithic one at equal work (tiny single-token iterations can
        // legitimately lose to the collective latency — decode is
        // latency-bound under tensor parallelism).
        let ctxs = [16u64; 8];
        assert!(sh.iteration_time_s(16, &ctxs) < cm.iteration_time_s(16, &ctxs));
        // Single-token decode steps are collective-latency-bound: still
        // charged honestly (compute/N + one token's gather).
        let step_mono = cm.decode_step_time_s(16);
        let step_sh = sh.decode_step_time_s(16);
        assert!(step_sh > step_mono / 4.0);
        // The base and adapter regimes are untouched by sharding.
        assert_eq!(sh.cycles_per_token_ax, cm.cycles_per_token_ax);
        assert_eq!(sh.reuse_rate, cm.reuse_rate);
        // More shards gather over more hops: collective cost grows.
        let sh8 = cm.with_shard_regime(&ModelConfig::tiny(), 8);
        assert!(
            sh8.allreduce_time_s(1024.0, 8) > sh.allreduce_time_s(1024.0, 4),
            "latency term must grow with the ring"
        );
    }

    #[test]
    fn attribute_converts_units() {
        let s = SimStats {
            cycles: 1_000_000_000,
            mults: 1000,
            ..Default::default()
        };
        let (t, e) = attribute(&s, 1.0);
        assert!((t - 1.0).abs() < 1e-9, "1e9 cycles @1GHz = 1s, got {t}");
        assert!(e > 0.0);
    }
}
