//! Disaggregated prefill/decode fleet serving on the deterministic
//! virtual clock.
//!
//! [`Engine::serve_trace_disagg`] models the two-tier topology the live
//! [`crate::coordinator::Server::start_disagg_pool`] runs on wall
//! clocks: `P` dedicated **prefill replicas** advance chunked-prefill
//! jobs ([`ExecutionBackend::prefill_chunk`]) and hand each opened
//! session across a metered KV link ([`CostModel::handoff_time_s`]) to
//! `D` dedicated **decode replicas** that drive continuous-batching
//! decode waves ([`ExecutionBackend::decode_steps`]). The fleet runs in
//! lockstep ticks: every replica that has work executes once per tick
//! and the clock advances by the *slowest* replica's tick time — a
//! conservative synchronous model that still exposes the structural
//! win, because chunking bounds every prefill tick by `chunk_tokens`
//! weight passes where a unified replica's iteration can stall behind a
//! whole long prompt.
//!
//! Why TTFT improves under bursts: in the unified loop
//! ([`Engine::serve_trace_decode`]) a prompt must win a *session slot*
//! that decode sessions hold for their whole generated-token budget, so
//! flash-crowd prompts queue behind decode retirements. Here the
//! prefill tier has its own slots — first tokens are gated only by
//! prefill capacity (plus the handoff link), never by decode occupancy.
//! The price is decode-tier transfer bytes and a split hardware budget,
//! which is why [`Engine::serve_trace_unified`] exists: the same trace
//! on `P + D` *unified* replicas, the equal-hardware baseline every
//! disaggregation claim must beat (`benches/disagg_serve.rs` asserts
//! the p99-TTFT win).
//!
//! One physical backend serves every virtual replica, so logits, tokens
//! and reuse counters are bit-identical to single-engine serving (the
//! chunked-prefill contract guarantees chunking changes only the
//! clock); replicas are cost-model constructs, exactly like the shard
//! model. The prefix cache, when enabled, is therefore shared
//! fleet-wide on both sides of the comparison.

use crate::backend::{ChunkedPrefill, CostModel, ExecutionBackend, KvHandle, StepOutcome};
use crate::coordinator::batcher::{BatchPolicy, BatchScheduler, SloPolicy};
use crate::coordinator::engine::{decode_budget, DecodeSession, Engine, RequestResult};
use crate::coordinator::metrics::ServeSummary;
use crate::workload::Request;
use anyhow::Result;
use std::collections::VecDeque;

/// Options for [`Engine::serve_trace_disagg`].
#[derive(Clone, Copy, Debug)]
pub struct DisaggOpts {
    /// Dedicated prefill replicas (≥ 1). Each holds up to the policy's
    /// `max_batch` chunk jobs and spends `chunk_tokens` prompt tokens
    /// per tick across them, FIFO.
    pub prefill_replicas: usize,
    /// Dedicated decode replicas (≥ 1), each capped at the policy's
    /// `max_batch` running sessions.
    pub decode_replicas: usize,
    /// Prompt tokens each prefill replica computes per tick; 0 runs
    /// whole prompts monolithically (one job finishes per call).
    pub chunk_tokens: usize,
    /// Generated-token budget for requests whose `gen_tokens` is 0.
    pub default_gen: u32,
    /// SLO-aware admission into the prefill tier
    /// ([`BatchScheduler::take_ready_slo`]); `None` admits FIFO.
    pub slo: Option<SloPolicy>,
    /// Bytes of K/V state per context token crossing the prefill→decode
    /// link (the [`CostModel::with_handoff_regime`] convention is
    /// `2·n_layers·d_model·4`). 0 makes handoffs free and unmetered —
    /// set it to make the tier link a real cost.
    pub handoff_bytes_per_token: f64,
}

impl DisaggOpts {
    /// `p` prefill / `d` decode replicas, monolithic prefill, FIFO
    /// admission, free handoffs.
    pub fn new(p: usize, d: usize, default_gen: u32) -> DisaggOpts {
        DisaggOpts {
            prefill_replicas: p,
            decode_replicas: d,
            chunk_tokens: 0,
            default_gen,
            slo: None,
            handoff_bytes_per_token: 0.0,
        }
    }

    /// Chunk prefill at `tokens` prompt tokens per replica per tick.
    pub fn with_chunking(mut self, tokens: usize) -> DisaggOpts {
        self.chunk_tokens = tokens;
        self
    }

    /// Enable SLO-aware admission.
    pub fn with_slo(mut self, policy: SloPolicy) -> DisaggOpts {
        self.slo = Some(policy);
        self
    }

    /// Meter the tier link at `bytes` per context token.
    pub fn with_handoff(mut self, bytes: f64) -> DisaggOpts {
        self.handoff_bytes_per_token = bytes;
        self
    }
}

/// Generated tokens a decode replica still owes its sessions — the
/// load measure handoff placement balances (same token-weighted idea as
/// the live pool's backlog counter).
fn remaining_tokens(sessions: &[DecodeSession]) -> usize {
    sessions
        .iter()
        .map(|s| (s.kv.budget as usize).saturating_sub(s.kv.generated.len()))
        .sum()
}

impl<B: ExecutionBackend> Engine<B> {
    /// Serve a trace on a disaggregated `P`-prefill / `D`-decode fleet
    /// (see the module docs for the tick model). Results carry the same
    /// per-request fields as every other serving path; the summary adds
    /// handoff bytes, shed/degraded counts, and SLO attainment when a
    /// policy is set.
    pub fn serve_trace_disagg(
        &self,
        trace: Vec<Request>,
        policy: BatchPolicy,
        opts: DisaggOpts,
    ) -> Result<(Vec<RequestResult>, ServeSummary)> {
        let p = opts.prefill_replicas.max(1);
        let d = opts.decode_replicas.max(1);
        let cap = policy.max_batch.min(self.max_batch()).max(1);
        let mut cost: CostModel = *self.cost();
        if opts.handoff_bytes_per_token > 0.0 {
            cost.handoff_bytes_per_token = opts.handoff_bytes_per_token;
        }
        let chunk = if opts.chunk_tokens == 0 {
            usize::MAX
        } else {
            opts.chunk_tokens
        };
        let mut sched = BatchScheduler::new(BatchPolicy {
            max_batch: cap,
            ..policy
        });
        let mut arrivals = trace.into_iter().peekable();
        // Prefill tier: per-replica FIFO of in-flight chunk jobs, each
        // with its admission stamp.
        let mut prefill: Vec<Vec<(ChunkedPrefill, f64)>> = (0..p).map(|_| Vec::new()).collect();
        // Sessions that finished prefill but have not found a decode
        // slot yet (first token already produced — waiting here costs
        // inter-token latency, never TTFT).
        let mut handoffs: VecDeque<DecodeSession> = VecDeque::new();
        // Decode tier: per-replica running sessions.
        let mut decode: Vec<Vec<DecodeSession>> = (0..d).map(|_| Vec::new()).collect();
        let mut results: Vec<RequestResult> = Vec::new();
        let mut iterations = 0usize;
        let mut clock = 0.0f64;
        let mut shed = 0usize;
        let mut degraded = 0usize;
        let mut handoff_bytes = 0u64;

        loop {
            while arrivals.peek().map_or(false, |r| r.arrival_s <= clock) {
                sched.enqueue(arrivals.next().expect("peeked"));
            }
            let free: usize = prefill.iter().map(|q| cap.saturating_sub(q.len())).sum();
            let admitted = match &opts.slo {
                Some(policy) => {
                    let adm = sched.take_ready_slo(free, clock, policy);
                    shed += adm.shed.len();
                    degraded += adm.degraded;
                    adm.admitted
                }
                None => sched.take_ready(free),
            };
            let tier_idle = prefill.iter().all(|q| q.is_empty())
                && decode.iter().all(|q| q.is_empty())
                && handoffs.is_empty();
            if tier_idle && admitted.is_empty() {
                match arrivals.peek() {
                    Some(r) => {
                        clock = clock.max(r.arrival_s);
                        continue;
                    }
                    None => break,
                }
            }
            iterations += 1;
            // Place admitted prompts on the prefill replica with the
            // fewest jobs (lowest index on ties — deterministic).
            for req in admitted {
                let budget = decode_budget(&req, opts.default_gen);
                let i = (0..p)
                    .min_by_key(|&i| (prefill[i].len(), i))
                    .expect("p >= 1");
                prefill[i].push((ChunkedPrefill::new(req, budget), clock));
            }

            // ---- one lockstep tick: every busy replica executes once;
            // the clock advances by the slowest replica's time.
            let mut tick_s = 0.0f64;

            // Decode waves, one per replica holding sessions.
            for q in decode.iter_mut() {
                if q.is_empty() {
                    continue;
                }
                let batch_now = q.len();
                let mut ctxs: Vec<u64> = Vec::with_capacity(q.len());
                let mut adapter_steps = 0u64;
                for s in q.iter() {
                    ctxs.push(s.kv.context_len() as u64);
                    adapter_steps += s.kv.adapter.is_some() as u64;
                }
                let kv_refs: Vec<&mut KvHandle> = q.iter_mut().map(|s| &mut s.kv).collect();
                let outs = self.backend.decode_steps(kv_refs)?;
                for ((s, ctx), out) in q.iter_mut().zip(&ctxs).zip(outs) {
                    s.record_step(*ctx, out, &cost);
                    s.peak_batch = s.peak_batch.max(batch_now);
                }
                let t = cost.iteration_time_s(0, &ctxs) + cost.adapter_time_s(adapter_steps);
                tick_s = tick_s.max(t);
            }

            // Prefill replicas: spend this tick's chunk budget FIFO over
            // the replica's jobs; completed jobs pay the handoff link.
            let mut completed: Vec<(KvHandle, StepOutcome, f64, f64)> = Vec::new();
            for q in prefill.iter_mut() {
                if q.is_empty() {
                    continue;
                }
                let mut budget_left = chunk;
                let mut prefill_tokens = 0u64;
                let mut copied_tokens = 0u64;
                let mut adapter_tokens = 0u64;
                let mut handoff_s = 0.0f64;
                let mut i = 0;
                while i < q.len() && budget_left > 0 {
                    let (job, admit_s) = &mut q[i];
                    let outcome = self.backend.prefill_chunk(job, budget_left)?;
                    prefill_tokens += outcome.computed_tokens;
                    copied_tokens += outcome.copied_tokens;
                    adapter_tokens += outcome.adapter_tokens;
                    budget_left -= (outcome.computed_tokens as usize).min(budget_left);
                    if let Some((kv, out)) = outcome.done {
                        let arrival_s = job.req.arrival_s;
                        let admit_s = *admit_s;
                        q.remove(i);
                        let ctx = kv.context_len() as u64;
                        handoff_bytes += cost.handoff_bytes(ctx);
                        handoff_s += cost.handoff_time_s(ctx);
                        completed.push((kv, out, arrival_s, admit_s));
                    } else {
                        i += 1;
                    }
                }
                let t = cost.iteration_time_s(prefill_tokens, &[])
                    + cost.kv_copy_time_s(copied_tokens)
                    + cost.adapter_time_s(adapter_tokens)
                    + handoff_s;
                tick_s = tick_s.max(t);
            }
            clock += tick_s;

            // First tokens completed within this tick; budget-1 sessions
            // finish without ever reaching the decode tier.
            for (kv, out, arrival_s, admit_s) in completed {
                let mut s = DecodeSession::admit(kv, out, arrival_s, admit_s, &cost, 0);
                s.ttft_abs = Some(clock);
                if s.kv.done() {
                    s.finish_abs = Some(clock);
                    results.push(s.into_result());
                } else {
                    handoffs.push_back(s);
                }
            }
            // Retire decode sessions whose budgets exhausted this tick.
            for q in decode.iter_mut() {
                let mut i = 0;
                while i < q.len() {
                    if q[i].kv.done() {
                        let mut s = q.swap_remove(i);
                        s.finish_abs = Some(clock);
                        results.push(s.into_result());
                    } else {
                        i += 1;
                    }
                }
            }
            // Fill freed decode slots from the handoff queue, FIFO, each
            // onto the replica owing the fewest remaining tokens.
            while let Some(s) = handoffs.pop_front() {
                let slot = (0..d)
                    .filter(|&i| decode[i].len() < cap)
                    .min_by_key(|&i| (remaining_tokens(&decode[i]), i));
                match slot {
                    Some(i) => decode[i].push(s),
                    None => {
                        handoffs.push_front(s);
                        break;
                    }
                }
            }
        }
        let summary = ServeSummary::from_results_slo(
            &results,
            iterations,
            &cost,
            opts.slo.as_ref(),
            shed,
            degraded,
            handoff_bytes,
        );
        Ok((results, summary))
    }

    /// Equal-hardware unified baseline for [`Engine::serve_trace_disagg`]:
    /// the same trace split across `replicas` independent unified
    /// continuous-batching loops ([`Engine::serve_trace_decode`]), each
    /// on its own virtual clock from the shared epoch. Requests are
    /// assigned in arrival order to the replica with the least
    /// token-weighted work — the same rule live pool dispatch uses — so
    /// the baseline is not handicapped by naive round-robin.
    pub fn serve_trace_unified(
        &self,
        trace: Vec<Request>,
        policy: BatchPolicy,
        replicas: usize,
        default_gen: u32,
    ) -> Result<(Vec<RequestResult>, ServeSummary)> {
        let n = replicas.max(1);
        let mut parts: Vec<Vec<Request>> = (0..n).map(|_| Vec::new()).collect();
        let mut load = vec![0usize; n];
        for req in trace {
            let i = (0..n).min_by_key(|&i| (load[i], i)).expect("n >= 1");
            load[i] += req.seq_len + req.gen_tokens.max(1) as usize;
            parts[i].push(req);
        }
        let mut results: Vec<RequestResult> = Vec::new();
        let mut iterations = 0usize;
        for part in parts {
            let (rs, summary) = self.serve_trace_decode(part, policy, default_gen)?;
            iterations += summary.batches;
            results.extend(rs);
        }
        let summary = ServeSummary::from_results(&results, iterations, self.backend.cost());
        Ok((results, summary))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::{FunctionalBackend, SimBackend};
    use crate::config::{AcceleratorConfig, Dataset, ModelConfig};
    use crate::coordinator::batcher::SloTarget;
    use crate::workload::SloClass;

    fn sim() -> Engine<SimBackend> {
        let be = SimBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper())
            .expect("sim backend must construct");
        Engine::new(be)
    }

    fn functional() -> Engine<FunctionalBackend> {
        let be = FunctionalBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper(), 7)
            .expect("functional backend must construct");
        Engine::new(be)
    }

    fn req(id: u64, arrival_s: f64, seq_len: usize, gen: u32) -> Request {
        Request {
            id,
            dataset: Dataset::Imdb,
            arrival_s,
            seq_len,
            gen_tokens: gen,
            adapter: None,
            prefix: None,
            slo: SloClass::Standard,
        }
    }

    fn policy() -> BatchPolicy {
        BatchPolicy {
            max_batch: 4,
            max_wait_s: 0.0,
        }
    }

    /// Disaggregation changes the clock, never the computation: per-id
    /// logits, tokens, and reuse counters are bit-identical to the
    /// single-replica unified path on the functional backend — chunked
    /// prefill included.
    #[test]
    fn disagg_serving_is_bit_identical_to_unified() {
        let trace: Vec<Request> = (0..10)
            .map(|i| req(i, 0.02 * i as f64, 5 + (i as usize % 7), 3 + (i % 4) as u32))
            .collect();
        let (mut uni, _) = functional().serve_trace_decode(trace.clone(), policy(), 4).unwrap();
        let opts = DisaggOpts::new(2, 2, 4).with_chunking(3);
        let (mut dis, summary) = functional().serve_trace_disagg(trace, policy(), opts).unwrap();
        assert_eq!(uni.len(), dis.len());
        uni.sort_by_key(|r| r.id);
        dis.sort_by_key(|r| r.id);
        for (u, v) in uni.iter().zip(dis.iter()) {
            assert_eq!(u.id, v.id);
            assert_eq!(u.logits, v.logits, "request {} diverged", u.id);
            assert_eq!(u.tokens, v.tokens);
            assert_eq!(u.gen_tokens, v.gen_tokens);
            assert_eq!(u.base_mults, v.base_mults);
            assert_eq!(u.base_reuses, v.base_reuses);
        }
        assert!(summary.slo_attainment == 1.0 && summary.shed == 0);
    }

    /// The tier link is metered exactly: one handoff per served request,
    /// each billed at bytes-per-token × context length (prompt + first
    /// token), and TTFT absorbs the link time.
    #[test]
    fn handoff_bytes_are_metered_per_context_token() {
        let bpt = 64.0;
        let trace = vec![req(0, 0.0, 8, 4), req(1, 0.0, 5, 4)];
        let eng = sim();
        let opts = DisaggOpts::new(1, 1, 4).with_handoff(bpt);
        let (results, summary) = eng.serve_trace_disagg(trace, policy(), opts).unwrap();
        assert_eq!(results.len(), 2);
        // context at handoff = prompt_len + the prefill token.
        let expected = (bpt as u64) * ((8 + 1) + (5 + 1));
        assert_eq!(summary.handoff_bytes, expected);

        let (_, free) = eng
            .serve_trace_disagg(vec![req(0, 0.0, 8, 4)], policy(), DisaggOpts::new(1, 1, 4))
            .unwrap();
        assert_eq!(free.handoff_bytes, 0);
    }

    /// The structural TTFT claim on a flash crowd: with decode budgets
    /// holding unified session slots hostage, a burst's first tokens
    /// queue behind retirements in the unified pool but only behind
    /// prefill capacity in the disaggregated one — at equal replica
    /// count (4 unified vs 2+2 disaggregated).
    #[test]
    fn flash_crowd_p99_ttft_favors_disaggregation() {
        let trace: Vec<Request> = (0..64).map(|i| req(i, 0.0, 16, 256)).collect();
        let eng = sim();
        let (_, uni) = eng.serve_trace_unified(trace.clone(), policy(), 4, 16).unwrap();
        let opts = DisaggOpts::new(2, 2, 16).with_chunking(32);
        let (results, dis) = eng.serve_trace_disagg(trace, policy(), opts).unwrap();
        assert_eq!(results.len(), 64, "conservation: every request answered");
        assert!(
            dis.ttft.p99_s < uni.ttft.p99_s,
            "disagg p99 TTFT {} must beat unified {}",
            dis.ttft.p99_s,
            uni.ttft.p99_s
        );
    }

    /// SLO admission composes with the tiered fleet: a zero-tolerance
    /// deadline sheds the overflow a saturated prefill tier cannot seat,
    /// and the summary accounts every request exactly once.
    #[test]
    fn saturated_prefill_tier_sheds_zero_deadline_overflow() {
        let base = SloPolicy::default();
        let slo = SloPolicy {
            standard: SloTarget {
                max_wait_s: 0.0,
                ttft_s: f64::INFINITY, // isolate shedding from degradation
                ..base.standard
            },
            ..base
        };
        let trace: Vec<Request> = (0..12).map(|i| req(i, 0.0, 40, 4)).collect();
        let eng = sim();
        let opts = DisaggOpts {
            prefill_replicas: 1,
            decode_replicas: 1,
            chunk_tokens: 8,
            default_gen: 4,
            slo: Some(slo),
            handoff_bytes_per_token: 0.0,
        };
        let pol = BatchPolicy {
            max_batch: 2,
            max_wait_s: 0.0,
        };
        let (results, summary) = eng.serve_trace_disagg(trace, pol, opts).unwrap();
        assert!(summary.shed > 0, "overflow past the deadline must shed");
        assert_eq!(results.len() + summary.shed, 12);
        assert!(results.iter().all(|r| !r.shed));
    }
}
