//! Threaded serving front-end: a live request queue in front of a
//! PJRT-backed engine.
//!
//! The engine (and the PJRT client inside its
//! [`crate::backend::PjrtBackend`]) is constructed inside the worker
//! thread — PJRT handles are not `Send`, so the worker owns the whole
//! execution stack and the outside world talks to it through channels.
//! Batching uses wall-clock `recv_timeout`, mirroring the deterministic
//! trace batcher's policy.

use crate::config::AcceleratorConfig;
use crate::coordinator::batcher::{Batch, BatchPolicy};
use crate::coordinator::engine::{Engine, RequestResult};
use crate::workload::Request;
use anyhow::Result;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

enum Msg {
    Submit(Request, mpsc::Sender<RequestResult>),
    Shutdown,
}

/// A running server instance.
pub struct Server {
    tx: mpsc::Sender<Msg>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
    started: Instant,
}

impl Server {
    /// Start the worker. Fails later (on first submit) if the artifacts
    /// are missing; startup errors surface through `shutdown()`.
    pub fn start(artifact_dir: PathBuf, acc_cfg: AcceleratorConfig, policy: BatchPolicy) -> Server {
        let (tx, rx) = mpsc::channel::<Msg>();
        let handle = std::thread::spawn(move || worker(artifact_dir, acc_cfg, policy, rx));
        Server {
            tx,
            handle: Some(handle),
            started: Instant::now(),
        }
    }

    /// Submit a request; the result arrives on the returned channel.
    pub fn submit(&self, mut req: Request) -> mpsc::Receiver<RequestResult> {
        // Stamp arrival with server-relative wall time so queue-wait
        // accounting matches the live batcher.
        req.arrival_s = self.started.elapsed().as_secs_f64();
        let (rtx, rrx) = mpsc::channel();
        let _ = self.tx.send(Msg::Submit(req, rtx));
        rrx
    }

    /// Stop the worker and propagate any error it hit.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        match self.handle.take() {
            Some(h) => h.join().unwrap_or_else(|_| anyhow::bail!("worker panicked")),
            None => Ok(()),
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker(
    dir: PathBuf,
    acc_cfg: AcceleratorConfig,
    policy: BatchPolicy,
    rx: mpsc::Receiver<Msg>,
) -> Result<()> {
    let engine = Engine::load(&dir, acc_cfg)?;
    let max_batch = policy.max_batch.min(engine.max_batch());
    let started = Instant::now();
    let mut pending: Vec<(Request, mpsc::Sender<RequestResult>)> = Vec::new();

    let dispatch = |pending: &mut Vec<(Request, mpsc::Sender<RequestResult>)>| -> Result<()> {
        if pending.is_empty() {
            return Ok(());
        }
        let now = started.elapsed().as_secs_f64();
        let taken: Vec<_> = pending.drain(..).collect();
        let batch = Batch {
            requests: taken.iter().map(|(r, _)| r.clone()).collect(),
            dispatch_s: now,
        };
        let results = engine.run_batch(&batch)?;
        for (res, (_, tx)) in results.into_iter().zip(taken) {
            let _ = tx.send(res);
        }
        Ok(())
    };

    loop {
        let timeout = Duration::from_secs_f64(policy.max_wait_s.max(1e-4));
        match rx.recv_timeout(timeout) {
            Ok(Msg::Submit(req, tx)) => {
                pending.push((req, tx));
                if pending.len() >= max_batch {
                    dispatch(&mut pending)?;
                }
            }
            Ok(Msg::Shutdown) => {
                dispatch(&mut pending)?;
                return Ok(());
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                dispatch(&mut pending)?;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                dispatch(&mut pending)?;
                return Ok(());
            }
        }
    }
}

// Integration coverage lives in rust/tests/integration_coordinator.rs
// (requires built artifacts).
