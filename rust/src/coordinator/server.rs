//! Threaded serving front-end: a live request queue in front of a
//! backend-generic engine, plus a multi-replica worker pool.
//!
//! [`Server<B>`] is generic over [`ExecutionBackend`], like
//! [`Engine<B>`]: live serving works artifact-free with
//! [`crate::backend::SimBackend`] / [`crate::backend::FunctionalBackend`]
//! and production-shaped with [`PjrtBackend`]. The engine is constructed
//! *inside* the worker thread through a caller-supplied factory — PJRT
//! handles are not `Send`, so the worker owns the whole execution stack
//! and the outside world talks to it through channels.
//!
//! Two invariants shared with the trace path:
//!
//! - **One closure implementation.** The worker drives the same
//!   [`BatchScheduler`] that `batch_trace` uses; its `recv_timeout` is the
//!   time until the *oldest pending request's* deadline
//!   (`oldest.arrival_s + max_wait_s − now`), never a fresh `max_wait_s`
//!   window per message. A steady trickle of arrivals therefore cannot
//!   starve the head of the queue: whenever the engine keeps up, queue
//!   wait is bounded by `max_wait_s` (plus wake-up slop) by construction.
//!   Under backlog the worker drains the queue before consulting the
//!   clock (so batches still fill to `max_batch`) and stamps dispatches
//!   at actual wall time, so overload shows up honestly in `queue_wait_s`
//!   instead of being clipped to the policy bound.
//! - **One clock.** The epoch `Instant` is created before the worker
//!   spawns and moved into it, so submit-side arrival stamps and
//!   worker-side dispatch stamps share an epoch and `queue_wait_s` cannot
//!   absorb engine-construction time (or go negative and get silently
//!   clamped).
//!
//! [`ServerPool`] ([`Server::start_pool`]) scales the same front-end
//! across N replica workers — each with its own engine — using
//! least-loaded dispatch with a round-robin tie-break. "Load" is the
//! token-weighted work backlog ([`ServerStats::backlog`]): prompt plus
//! generated-token budget of every unanswered request, so a replica
//! holding a few deep decode sessions no longer beats one holding many
//! trivial requests just because it has fewer of them.
//!
//! [`Server::start_disagg_pool`] builds the **disaggregated** topology
//! instead: dedicated prefill workers pull from one shared request
//! queue, run the prompt phase, and hand the opened session (its
//! [`KvHandle`] plus first-token outcome) over a handoff channel to
//! dedicated decode workers that drive the continuous-batching wave
//! loop. TTFT is stamped on the prefill tier; handoff traffic is
//! metered in [`ServerStats::handoff_bytes`]; SLO admission (shed /
//! degrade) runs at the prefill boundary, where queue wait is known.
//!
//! **A shard group is one logical replica.** Tensor-parallel sharding
//! lives *inside* the backend (`with_shards(n)` splits every projection
//! across n per-shard Result Caches and charges the collective regime),
//! so the pool keeps dispatching whole requests to replicas — never to
//! raw shards: one replica = one shard group that answers the request
//! end to end. Shard capability misses (a shard-unaware backend serving
//! monolithically) are published per worker in
//! [`ServerStats::shard_misses`] and aggregated into
//! [`LiveRun::shard_misses`], mirroring the adapter-miss channel.

use crate::backend::{CostModel, ExecutionBackend, KvHandle, PjrtBackend, StepOutcome};
use crate::config::AcceleratorConfig;
use crate::coordinator::batcher::{Batch, BatchPolicy, BatchScheduler, SloPolicy};
use crate::coordinator::engine::{decode_budget, DecodeSession, Engine, RequestResult};
use crate::coordinator::metrics::ServeSummary;
use crate::workload::Request;
use anyhow::Result;
use std::collections::VecDeque;
use std::marker::PhantomData;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

enum Msg {
    Submit(Request, mpsc::Sender<RequestResult>),
    Shutdown,
}

/// Token-weighted work estimate of one request: prompt tokens plus its
/// generated-token ask (at least 1 — every session produces its prefill
/// token). This is what [`ServerStats::backlog`] counts and what pool
/// dispatch ranks replicas by; it intentionally uses the request's *own*
/// `gen_tokens` (not the worker's resolved default) so submit-side adds
/// and worker-side removes agree without knowing worker options.
fn work_estimate(req: &Request) -> usize {
    req.seq_len + req.gen_tokens.max(1) as usize
}

/// Least-loaded index over `loads`, scanning from `start` (the
/// round-robin cursor) so exact ties rotate instead of pinning to
/// replica 0. Strict `<` keeps the earliest-scanned minimum.
fn pick_min_load(loads: &[usize], start: usize) -> usize {
    let n = loads.len();
    let mut best = start % n;
    let mut best_load = loads[best];
    for k in 1..n {
        let i = (start + k) % n;
        if loads[i] < best_load {
            best = i;
            best_load = loads[i];
        }
    }
    best
}

/// Options for continuous-batching decode serving
/// ([`Server::start_decode_with`] / [`Server::start_decode_pool`]).
#[derive(Clone, Copy, Debug)]
pub struct DecodeOpts {
    /// Generated-token budget for requests whose `gen_tokens` is 0.
    pub default_gen: u32,
    /// Sleep each iteration for the modeled accelerator time
    /// ([`CostModel::iteration_time_s`]) so a sim-backed worker is
    /// occupied like the modeled hardware. Pacing lives at the
    /// *iteration* level because that is where the decode weight pass is
    /// shared across the running batch — per-step backend pacing
    /// ([`crate::backend::SimBackend::with_paced`]) would charge one
    /// full weight pass per session per step and break the
    /// continuous-batching cost model, so keep the backend itself
    /// unpaced when setting this. Leave false for host-executing
    /// backends (functional/PJRT), whose steps take real time already.
    pub pace: bool,
}

impl DecodeOpts {
    /// Unpaced decode serving with the given default budget.
    pub fn new(default_gen: u32) -> DecodeOpts {
        DecodeOpts {
            default_gen,
            pace: false,
        }
    }
}

/// How a worker serves its queue: closed batches (the original
/// prefill-only path) or token-level continuous batching over
/// autoregressive decode sessions.
#[derive(Clone, Copy, Debug)]
enum WorkerMode {
    Batch,
    Decode(DecodeOpts),
}

/// Live counters shared between a server front-end and its worker.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Requests accepted by `submit`.
    pub submitted: AtomicUsize,
    /// Requests answered by the worker.
    pub completed: AtomicUsize,
    /// Batches the worker has dispatched.
    pub batches: AtomicUsize,
    /// Adapter requests the worker's backend served base-only (mirrors
    /// [`crate::backend::ExecutionBackend::adapter_misses`], published
    /// after every dispatch/iteration so the front-end can report silent
    /// fallbacks without reaching into the worker-owned engine).
    pub adapter_misses: AtomicUsize,
    /// Requests the worker's backend served monolithically despite a
    /// sharded deployment ask (mirrors
    /// [`crate::backend::ExecutionBackend::shard_misses`]; published on
    /// the same schedule as `adapter_misses`).
    pub shard_misses: AtomicUsize,
    /// Requests the worker's backend served without prefix reuse despite
    /// a KV-cache deployment ask (mirrors
    /// [`crate::backend::ExecutionBackend::kv_misses`]; published on the
    /// same schedule as `adapter_misses`).
    pub kv_misses: AtomicUsize,
    /// Requests the worker's backend served per-tensor despite a
    /// non-default quantization-regime ask (mirrors
    /// [`crate::backend::ExecutionBackend::quant_misses`]; published on
    /// the same schedule as `adapter_misses`).
    pub quant_misses: AtomicUsize,
    /// Token-weighted outstanding work: Σ `work_estimate` (prompt tokens
    /// + generated-token ask) over submitted-but-unanswered requests.
    /// This — not the request *count* — is what least-loaded dispatch
    /// ranks replicas by: a replica holding one 512-token decode session
    /// is busier than one holding three 8-token requests.
    pub backlog: AtomicUsize,
    /// Requests shed by SLO admission (answered with a marker result,
    /// never executed). Only the disaggregated prefill tier sheds.
    pub shed: AtomicUsize,
    /// Requests whose generated-token budget was clamped to their SLO
    /// class's degraded ask because they missed their TTFT target while
    /// queued.
    pub degraded: AtomicUsize,
    /// KV bytes shipped prefill→decode across the tier link (zero unless
    /// the pool runs disaggregated with a handoff regime).
    pub handoff_bytes: AtomicUsize,
}

impl ServerStats {
    /// Requests submitted but not yet answered.
    pub fn in_flight(&self) -> usize {
        let done = self.completed.load(Ordering::Relaxed);
        self.submitted.load(Ordering::Relaxed).saturating_sub(done)
    }
}

/// A running server instance over execution backend `B`.
pub struct Server<B: ExecutionBackend = PjrtBackend> {
    tx: mpsc::Sender<Msg>,
    handle: Option<std::thread::JoinHandle<Result<()>>>,
    /// Shared epoch for submit-side arrival stamps and worker-side
    /// dispatch stamps.
    epoch: Instant,
    stats: Arc<ServerStats>,
    /// Guarded so `Server` stays `Sync` (shared-reference submitters).
    cost_rx: Mutex<mpsc::Receiver<CostModel>>,
    cost_cache: OnceLock<CostModel>,
    _backend: PhantomData<fn() -> B>,
}

impl<B: ExecutionBackend + 'static> Server<B> {
    /// Start a worker whose engine is built by `make` inside the worker
    /// thread. Construction failures surface through `shutdown()` (and
    /// through `cost()` returning `None`).
    pub fn start_with<F>(make: F, policy: BatchPolicy) -> Server<B>
    where
        F: FnOnce() -> Result<Engine<B>> + Send + 'static,
    {
        Self::start_with_epoch(make, policy, WorkerMode::Batch, Instant::now())
    }

    /// Start a **continuous-batching decode** worker: every request
    /// becomes an autoregressive session (budget = its `gen_tokens`, or
    /// `opts.default_gen` when 0); the worker's iteration loop admits
    /// waiting requests into free session slots at step boundaries and
    /// answers each request when its budget is exhausted, with TTFT/TPOT
    /// stamps in the result.
    pub fn start_decode_with<F>(make: F, policy: BatchPolicy, opts: DecodeOpts) -> Server<B>
    where
        F: FnOnce() -> Result<Engine<B>> + Send + 'static,
    {
        Self::start_with_epoch(make, policy, WorkerMode::Decode(opts), Instant::now())
    }

    /// `start_with` against a caller-supplied epoch — every replica of a
    /// pool shares one epoch so cross-replica timestamps are comparable.
    fn start_with_epoch<F>(
        make: F,
        policy: BatchPolicy,
        mode: WorkerMode,
        epoch: Instant,
    ) -> Server<B>
    where
        F: FnOnce() -> Result<Engine<B>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (cost_tx, cost_rx) = mpsc::channel::<CostModel>();
        let stats = Arc::new(ServerStats::default());
        let wstats = Arc::clone(&stats);
        let handle = std::thread::spawn(move || match mode {
            WorkerMode::Batch => worker(make, policy, epoch, wstats, cost_tx, rx),
            WorkerMode::Decode(opts) => {
                decode_worker(make, policy, opts, epoch, wstats, cost_tx, rx)
            }
        });
        Server {
            tx,
            handle: Some(handle),
            epoch,
            stats,
            cost_rx: Mutex::new(cost_rx),
            cost_cache: OnceLock::new(),
            _backend: PhantomData,
        }
    }

    /// Start `n` identical replicas; `make(i)` builds replica `i`'s engine
    /// inside that replica's worker thread.
    pub fn start_pool<F>(n: usize, make: F, policy: BatchPolicy) -> ServerPool<B>
    where
        F: Fn(usize) -> Result<Engine<B>> + Send + Clone + 'static,
    {
        Self::pool_with_mode(n, make, policy, WorkerMode::Batch)
    }

    /// [`Server::start_pool`] with continuous-batching decode replicas
    /// ([`Server::start_decode_with`] semantics per worker).
    pub fn start_decode_pool<F>(
        n: usize,
        make: F,
        policy: BatchPolicy,
        opts: DecodeOpts,
    ) -> ServerPool<B>
    where
        F: Fn(usize) -> Result<Engine<B>> + Send + Clone + 'static,
    {
        Self::pool_with_mode(n, make, policy, WorkerMode::Decode(opts))
    }

    fn pool_with_mode<F>(n: usize, make: F, policy: BatchPolicy, mode: WorkerMode) -> ServerPool<B>
    where
        F: Fn(usize) -> Result<Engine<B>> + Send + Clone + 'static,
    {
        assert!(n > 0, "pool needs at least one replica");
        // One epoch for the whole pool: arrival/dispatch stamps from
        // different replicas land on the same clock, so aggregated
        // summaries (span, first arrival, last completion) are coherent.
        let epoch = Instant::now();
        let replicas = (0..n)
            .map(|i| {
                let make = make.clone();
                Server::start_with_epoch(move || make(i), policy, mode, epoch)
            })
            .collect();
        ServerPool {
            replicas,
            rr: AtomicUsize::new(0),
        }
    }

    /// Submit a request; the result arrives on the returned channel.
    pub fn submit(&self, mut req: Request) -> mpsc::Receiver<RequestResult> {
        // Stamp arrival on the epoch the worker's dispatch clock uses.
        req.arrival_s = self.epoch.elapsed().as_secs_f64();
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        self.stats
            .backlog
            .fetch_add(work_estimate(&req), Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        let _ = self.tx.send(Msg::Submit(req, rtx));
        rrx
    }

    /// Live counters (submitted / completed / batches).
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Requests submitted but not yet answered.
    pub fn in_flight(&self) -> usize {
        self.stats.in_flight()
    }

    /// Token-weighted outstanding work ([`ServerStats::backlog`]) — the
    /// quantity pool dispatch balances.
    pub fn load(&self) -> usize {
        self.stats.backlog.load(Ordering::Relaxed)
    }

    /// The worker engine's cost model. Blocks until the engine finishes
    /// constructing; `None` if the worker failed before reporting one.
    pub fn cost(&self) -> Option<CostModel> {
        if let Some(c) = self.cost_cache.get() {
            return Some(*c);
        }
        let rx = self.cost_rx.lock().ok()?;
        // Another caller may have filled the cache while we waited.
        if let Some(c) = self.cost_cache.get() {
            return Some(*c);
        }
        match rx.recv() {
            Ok(c) => {
                let _ = self.cost_cache.set(c);
                Some(c)
            }
            Err(_) => None,
        }
    }

    /// Stop the worker and propagate any error it hit.
    pub fn shutdown(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Shutdown);
        match self.handle.take() {
            Some(h) => h.join().unwrap_or_else(|_| anyhow::bail!("worker panicked")),
            None => Ok(()),
        }
    }
}

impl Server<PjrtBackend> {
    /// Start a PJRT-backed worker. Fails later (on first submit) if the
    /// artifacts are missing; startup errors surface through `shutdown()`.
    pub fn start(artifact_dir: PathBuf, acc_cfg: AcceleratorConfig, policy: BatchPolicy) -> Server {
        Server::start_with(move || Engine::load(&artifact_dir, acc_cfg), policy)
    }
}

impl<B: ExecutionBackend> Drop for Server<B> {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// A pool of N identical server replicas behind least-loaded dispatch.
pub struct ServerPool<B: ExecutionBackend = PjrtBackend> {
    replicas: Vec<Server<B>>,
    /// Round-robin cursor used as the tie-break starting point.
    rr: AtomicUsize,
}

/// Outcome of a one-shot live run ([`ServerPool::run`]).
pub struct LiveRun {
    /// Aggregate over all replicas — the same `ServeSummary` the
    /// trace-driven path reports.
    pub summary: ServeSummary,
    /// Per-request results in submit order.
    pub results: Vec<RequestResult>,
    /// Per-replica `(batches, completed)` counters at the end of the run.
    pub replica_stats: Vec<(usize, usize)>,
    /// Adapter requests served base-only across all replicas (a non-zero
    /// value means some tenants were silently downgraded — report it).
    pub adapter_misses: u64,
    /// Requests served monolithically despite a sharded deployment ask,
    /// across all replicas (non-zero means the backend cannot shard —
    /// report the downgrade).
    pub shard_misses: u64,
    /// Requests served without prefix reuse despite a KV-cache
    /// deployment ask, across all replicas (non-zero means the backend
    /// cannot share KV state — report the downgrade).
    pub kv_misses: u64,
    /// Requests served per-tensor despite a non-default
    /// quantization-regime ask, across all replicas (non-zero means the
    /// backend cannot switch its weight storage — report the downgrade).
    pub quant_misses: u64,
}

impl<B: ExecutionBackend + 'static> ServerPool<B> {
    /// Number of replica workers.
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// One-shot live run: wait for every replica engine, drive the whole
    /// trace ([`ServerPool::serve`]), shut the pool down, and aggregate.
    /// On any failure the *worker's* error (engine construction, failed
    /// batch) is preferred over generic channel failures, so the root
    /// cause is never lost in a dropped reply channel.
    pub fn run(self, trace: Vec<Request>, pace: bool) -> Result<LiveRun> {
        let cost = self.cost();
        let served = match cost {
            Some(_) => self.serve(trace, pace),
            None => Err(anyhow::anyhow!(
                "live worker exited before reporting its cost model"
            )),
        };
        let batches = self.batches();
        let replica_stats = self.replica_stats();
        let adapter_misses = self.adapter_misses();
        let shard_misses = self.shard_misses();
        let kv_misses = self.kv_misses();
        let quant_misses = self.quant_misses();
        let stopped = self.shutdown();
        if let Err(worker_err) = stopped {
            return Err(worker_err);
        }
        let results = served?;
        let cost = cost.expect("serve() succeeded, so every replica reported its cost");
        Ok(LiveRun {
            summary: ServeSummary::from_results(&results, batches, &cost),
            results,
            replica_stats,
            adapter_misses,
            shard_misses,
            kv_misses,
            quant_misses,
        })
    }

    /// Drive a whole trace through the pool: submit every request —
    /// sleeping until each request's `arrival_s` offset when `pace` is
    /// true, burst-submitting otherwise — then block for all results, in
    /// submit order. Fails if any worker dies before answering.
    pub fn serve(&self, trace: Vec<Request>, pace: bool) -> Result<Vec<RequestResult>> {
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(trace.len());
        for req in trace {
            if pace {
                let target = Duration::from_secs_f64(req.arrival_s.max(0.0));
                if let Some(sleep) = target.checked_sub(t0.elapsed()) {
                    std::thread::sleep(sleep);
                }
            }
            rxs.push(self.submit(req));
        }
        let mut results = Vec::with_capacity(rxs.len());
        for rx in rxs {
            results.push(
                rx.recv()
                    .map_err(|_| anyhow::anyhow!("live worker dropped a request"))?,
            );
        }
        Ok(results)
    }

    /// Submit to the least-loaded replica, breaking ties round-robin so
    /// idle pools still rotate. Load is the token-weighted backlog
    /// ([`Server::load`]), not the in-flight request count: counting
    /// requests made a replica draining a few deep decode sessions look
    /// idle next to one answering many short prompts, so decode-heavy
    /// replicas kept winning ties and piling up wall-clock latency.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<RequestResult> {
        let n = self.replicas.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        let loads: Vec<usize> = self.replicas.iter().map(|s| s.load()).collect();
        self.replicas[pick_min_load(&loads, start)].submit(req)
    }

    /// Total batches dispatched across all replicas.
    pub fn batches(&self) -> usize {
        self.replicas
            .iter()
            .map(|s| s.stats().batches.load(Ordering::Relaxed))
            .sum()
    }

    /// Adapter requests served base-only across all replicas (as last
    /// published by each worker).
    pub fn adapter_misses(&self) -> u64 {
        self.replicas
            .iter()
            .map(|s| s.stats().adapter_misses.load(Ordering::Relaxed) as u64)
            .sum()
    }

    /// Requests served monolithically despite a sharded deployment ask,
    /// across all replicas (as last published by each worker).
    pub fn shard_misses(&self) -> u64 {
        self.replicas
            .iter()
            .map(|s| s.stats().shard_misses.load(Ordering::Relaxed) as u64)
            .sum()
    }

    /// Requests served without prefix reuse despite a KV-cache
    /// deployment ask, across all replicas (as last published by each
    /// worker).
    pub fn kv_misses(&self) -> u64 {
        self.replicas
            .iter()
            .map(|s| s.stats().kv_misses.load(Ordering::Relaxed) as u64)
            .sum()
    }

    /// Requests served per-tensor despite a non-default
    /// quantization-regime ask, across all replicas (as last published
    /// by each worker).
    pub fn quant_misses(&self) -> u64 {
        self.replicas
            .iter()
            .map(|s| s.stats().quant_misses.load(Ordering::Relaxed) as u64)
            .sum()
    }

    /// Per-replica `(batches, completed)` counters.
    pub fn replica_stats(&self) -> Vec<(usize, usize)> {
        self.replicas
            .iter()
            .map(|s| {
                (
                    s.stats().batches.load(Ordering::Relaxed),
                    s.stats().completed.load(Ordering::Relaxed),
                )
            })
            .collect()
    }

    /// Cost model of the replica engines (identical by construction).
    /// Blocks until EVERY replica finishes constructing, so a `Some`
    /// means the whole pool is ready to serve; `None` means at least one
    /// worker failed before reporting (its error surfaces through
    /// `shutdown()`).
    pub fn cost(&self) -> Option<CostModel> {
        let mut first = None;
        for s in &self.replicas {
            let c = s.cost()?;
            first.get_or_insert(c);
        }
        first
    }

    /// Stop every replica; the first worker error wins.
    pub fn shutdown(self) -> Result<()> {
        let mut first_err = None;
        for s in self.replicas {
            if let Err(e) = s.shutdown() {
                first_err.get_or_insert(e);
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Reply channels for queued requests, FIFO, each with the request's
/// `work_estimate` so the backlog counter can be released exactly as
/// added. The scheduler drains its entire pending set (in arrival order)
/// on every closure, so batch results always map onto the front of this
/// queue.
type Waiters = VecDeque<(u64, usize, mpsc::Sender<RequestResult>)>;

fn dispatch<B: ExecutionBackend>(
    engine: &Engine<B>,
    mut batch: Batch,
    epoch: Instant,
    waiters: &mut Waiters,
    stats: &ServerStats,
) -> Result<()> {
    debug_assert!(
        !batch.requests.is_empty(),
        "scheduler closures never emit empty batches"
    );
    // The scheduler stamps deadline-closed batches at their *deadline*
    // (trace-replay semantics). Live attribution must report the time the
    // batch actually left the queue, or an overloaded worker would
    // under-report queue waits by however far it has fallen behind.
    batch.dispatch_s = batch.dispatch_s.max(epoch.elapsed().as_secs_f64());
    stats.batches.fetch_add(1, Ordering::Relaxed);
    let results = engine.run_batch(&batch)?;
    stats
        .adapter_misses
        .store(engine.backend.adapter_misses() as usize, Ordering::Relaxed);
    stats
        .shard_misses
        .store(engine.backend.shard_misses() as usize, Ordering::Relaxed);
    stats
        .kv_misses
        .store(engine.backend.kv_misses() as usize, Ordering::Relaxed);
    stats
        .quant_misses
        .store(engine.backend.quant_misses() as usize, Ordering::Relaxed);
    for res in results {
        let (queued_id, est, tx) = waiters
            .pop_front()
            .expect("every batched request has a queued waiter");
        debug_assert_eq!(queued_id, res.id, "batch order diverged from FIFO");
        // Count BEFORE sending: the channel's send→recv edge then makes
        // the counter visible to anyone who has received this result, so
        // post-serve snapshots (ServerPool::run) can never under-count.
        stats.completed.fetch_add(1, Ordering::Relaxed);
        stats.backlog.fetch_sub(est, Ordering::Relaxed);
        let _ = tx.send(res);
    }
    Ok(())
}

struct WorkerState<B: ExecutionBackend> {
    engine: Engine<B>,
    sched: BatchScheduler,
    waiters: Waiters,
    epoch: Instant,
    stats: Arc<ServerStats>,
}

impl<B: ExecutionBackend> WorkerState<B> {
    /// Queue one request, applying only the `max_batch` closure. Deadline
    /// closures happen in the worker loop's single wall-clock `poll`, so
    /// a drained backlog batches together instead of replaying its stale
    /// inter-arrival gaps as singleton deadline batches.
    fn admit(&mut self, req: Request, tx: mpsc::Sender<RequestResult>) -> Result<()> {
        self.waiters.push_back((req.id, work_estimate(&req), tx));
        if let Some(b) = self.sched.admit(req) {
            dispatch(&self.engine, b, self.epoch, &mut self.waiters, &self.stats)?;
        }
        Ok(())
    }

    /// Flush whatever is pending and end the worker (shutdown or all
    /// senders gone).
    fn finish(&mut self) -> Result<()> {
        let now = self.epoch.elapsed().as_secs_f64();
        if let Some(b) = self.sched.flush(now) {
            dispatch(&self.engine, b, self.epoch, &mut self.waiters, &self.stats)?;
        }
        Ok(())
    }
}

fn worker<B: ExecutionBackend, F>(
    make: F,
    policy: BatchPolicy,
    epoch: Instant,
    stats: Arc<ServerStats>,
    cost_tx: mpsc::Sender<CostModel>,
    rx: mpsc::Receiver<Msg>,
) -> Result<()>
where
    F: FnOnce() -> Result<Engine<B>>,
{
    let engine = make()?;
    let _ = cost_tx.send(*engine.cost());
    let policy = BatchPolicy {
        max_batch: policy.max_batch.min(engine.max_batch()),
        ..policy
    };
    let mut st = WorkerState {
        engine,
        sched: BatchScheduler::new(policy),
        waiters: VecDeque::new(),
        epoch,
        stats,
    };

    loop {
        // 1. Drain every message already queued BEFORE consulting the
        //    clock: when the worker falls behind (engine slower than the
        //    arrival rate), the backlog must still batch up to max_batch
        //    instead of degenerating into deadline-expired singletons.
        loop {
            match rx.try_recv() {
                Ok(Msg::Submit(req, tx)) => st.admit(req, tx)?,
                Ok(Msg::Shutdown) | Err(mpsc::TryRecvError::Disconnected) => {
                    return st.finish();
                }
                Err(mpsc::TryRecvError::Empty) => break,
            }
        }
        // 2. Close an overdue batch, then re-drain — messages may have
        //    arrived while the engine ran.
        let now = st.epoch.elapsed().as_secs_f64();
        if let Some(b) = st.sched.poll(now) {
            dispatch(&st.engine, b, st.epoch, &mut st.waiters, &st.stats)?;
            continue;
        }
        // 3. Nothing due: sleep until the oldest pending request's
        //    absolute deadline (`oldest.arrival_s + max_wait_s − now`),
        //    or indefinitely when idle — NEVER a fresh max_wait_s window
        //    per message (that reset is the trickle-starvation bug).
        let msg = match st.sched.deadline_s() {
            Some(deadline) => {
                rx.recv_timeout(Duration::from_secs_f64((deadline - now).max(1e-6)))
            }
            None => rx.recv().map_err(|_| mpsc::RecvTimeoutError::Disconnected),
        };
        match msg {
            // Deadline evaluation happens at the loop top on the next
            // pass (drain, then one wall-clock poll).
            Ok(Msg::Submit(req, tx)) => st.admit(req, tx)?,
            Ok(Msg::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                return st.finish();
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
        }
    }
}

/// The continuous-batching decode worker loop.
///
/// Iteration shape (mirrors `Engine::serve_trace_decode`, but on the
/// wall clock): drain the channel, admit waiting requests FIFO into free
/// session slots (prefill runs at admission — TTFT is its completion
/// stamp), take one decode step for every running session, retire and
/// answer finished ones. Session bookkeeping and the TTFT/TPOT result
/// math are the engine's [`DecodeSession`] — one implementation for the
/// trace and live paths. Starvation-freedom is structural: admission is
/// FIFO and every iteration retires-or-advances every running session,
/// so a waiting request is delayed by at most the remaining budgets of
/// the `max_batch` sessions ahead of it — there is no deadline to reset,
/// which is why the closed-batch trickle bug cannot recur here.
///
/// Admission applies the engine's oldest-first `take_ready` rule, but on
/// a local `(Request, reply)` queue rather than the `BatchScheduler`
/// itself: a request must stay coupled to its reply channel, and the
/// single-channel worker receives submissions already in arrival order,
/// so FIFO here *is* oldest-first without risking a result being paired
/// with another request's waiter.
///
/// When `opts.pace` is set, the worker sleeps each iteration for the
/// modeled [`CostModel::iteration_time_s`] — prefill weight passes plus
/// ONE shared decode weight pass — so sim-backed live decode exhibits
/// the same amortization economics as the deterministic path instead of
/// charging a full weight pass per session per step.
fn decode_worker<B: ExecutionBackend, F>(
    make: F,
    policy: BatchPolicy,
    opts: DecodeOpts,
    epoch: Instant,
    stats: Arc<ServerStats>,
    cost_tx: mpsc::Sender<CostModel>,
    rx: mpsc::Receiver<Msg>,
) -> Result<()>
where
    F: FnOnce() -> Result<Engine<B>>,
{
    let engine = make()?;
    let cost = *engine.cost();
    let _ = cost_tx.send(cost);
    let cap = policy.max_batch.min(engine.max_batch()).max(1);
    let mut pending: VecDeque<(Request, mpsc::Sender<RequestResult>)> = VecDeque::new();
    let mut active: Vec<(DecodeSession, usize, mpsc::Sender<RequestResult>)> = Vec::new();
    let mut stopping = false;

    loop {
        // 1. Drain every queued message without blocking.
        loop {
            match rx.try_recv() {
                Ok(Msg::Submit(req, tx)) => pending.push_back((req, tx)),
                Ok(Msg::Shutdown) | Err(mpsc::TryRecvError::Disconnected) => {
                    stopping = true;
                    break;
                }
                Err(mpsc::TryRecvError::Empty) => break,
            }
        }
        // 2. Fully idle: block for work (or finish on shutdown — running
        //    sessions always drain to completion first).
        if active.is_empty() && pending.is_empty() {
            if stopping {
                return Ok(());
            }
            match rx.recv() {
                Ok(Msg::Submit(req, tx)) => {
                    pending.push_back((req, tx));
                    continue;
                }
                Ok(Msg::Shutdown) | Err(_) => return Ok(()),
            }
        }
        // 3. Admit FIFO into free slots at this step boundary; prefill at
        //    admission (the session's first token).
        let mut prefill_tokens = 0u64;
        // Prompt tokens resumed from the shared prefix cache this
        // iteration (billed at block-copy rate when pacing).
        let mut copied_tokens = 0u64;
        // Adapter side-pipe tokens of this iteration (per-session dense
        // work — never amortized by the shared decode weight pass).
        let mut adapter_tokens = 0u64;
        while active.len() < cap {
            let (req, tx) = match pending.pop_front() {
                Some(p) => p,
                None => break,
            };
            let admit_s = epoch.elapsed().as_secs_f64();
            let est = work_estimate(&req);
            let budget = decode_budget(&req, opts.default_gen);
            let (kv, out) = engine.backend.prefill(&req, budget)?;
            let computed = (kv.prompt_len - kv.cached_tokens) as u64;
            prefill_tokens += computed;
            copied_tokens += kv.cached_tokens as u64;
            if kv.adapter.is_some() {
                adapter_tokens += computed;
            }
            let mut s = DecodeSession::admit(kv, out, req.arrival_s, admit_s, &cost, 0);
            // First token completed at prefill return (wall clock).
            s.ttft_abs = Some(epoch.elapsed().as_secs_f64());
            active.push((s, est, tx));
        }
        let batch_now = active.len();
        // 4. One decode step per running session (one "iteration batch").
        stats.batches.fetch_add(1, Ordering::Relaxed);
        let mut decode_ctxs: Vec<u64> = Vec::with_capacity(active.len());
        for (s, _, _) in active.iter_mut() {
            s.peak_batch = s.peak_batch.max(batch_now);
            if s.kv.done() {
                // Budget-1 session: finished at prefill, retires below.
                continue;
            }
            let ctx = s.kv.context_len() as u64;
            decode_ctxs.push(ctx);
            adapter_tokens += s.kv.adapter.is_some() as u64;
            let out = engine.backend.decode_step(&mut s.kv)?;
            s.record_step(ctx, out, &cost);
        }
        if opts.pace {
            let iter_s = cost.iteration_time_s(prefill_tokens, &decode_ctxs)
                + cost.kv_copy_time_s(copied_tokens)
                + cost.adapter_time_s(adapter_tokens);
            if iter_s > 0.0 {
                std::thread::sleep(Duration::from_secs_f64(iter_s));
            }
        }
        // 5. Publish the backend's miss counters and retire finished
        //    sessions, answering their waiters.
        stats
            .adapter_misses
            .store(engine.backend.adapter_misses() as usize, Ordering::Relaxed);
        stats
            .shard_misses
            .store(engine.backend.shard_misses() as usize, Ordering::Relaxed);
        stats
            .kv_misses
            .store(engine.backend.kv_misses() as usize, Ordering::Relaxed);
        stats
            .quant_misses
            .store(engine.backend.quant_misses() as usize, Ordering::Relaxed);
        let now = epoch.elapsed().as_secs_f64();
        let mut i = 0;
        while i < active.len() {
            if active[i].0.kv.done() {
                let (mut s, est, tx) = active.swap_remove(i);
                s.finish_abs = Some(now);
                // Count BEFORE sending (same visibility argument as the
                // closed-batch dispatch path).
                stats.completed.fetch_add(1, Ordering::Relaxed);
                stats.backlog.fetch_sub(est, Ordering::Relaxed);
                let _ = tx.send(s.into_result());
            } else {
                i += 1;
            }
        }
    }
}

/// Options for a live disaggregated pool
/// ([`Server::start_disagg_pool`]).
#[derive(Clone, Copy, Debug)]
pub struct DisaggPoolOpts {
    /// Generated-token budget for requests whose `gen_tokens` is 0.
    pub default_gen: u32,
    /// SLO admission at the prefill boundary: a popped request that
    /// already overshot its class's `max_wait_s` is shed (answered with
    /// a marker result, [`RequestResult::shed`]); one that overshot its
    /// TTFT target has its generated-token ask clamped to the class's
    /// degraded budget. `None` serves strictly FIFO.
    pub slo: Option<SloPolicy>,
    /// Bytes of K/V state per context token shipped prefill→decode
    /// (the [`CostModel::with_handoff_regime`] convention:
    /// `2·n_layers·d_model·4`). Only meters [`ServerStats::handoff_bytes`]
    /// — the live tiers move a [`KvHandle`] through a channel, so no
    /// wall-clock transfer is simulated. 0 disables metering.
    pub handoff_bytes_per_token: f64,
}

impl DisaggPoolOpts {
    /// FIFO disaggregated serving with the given default budget and no
    /// handoff metering.
    pub fn new(default_gen: u32) -> DisaggPoolOpts {
        DisaggPoolOpts {
            default_gen,
            slo: None,
            handoff_bytes_per_token: 0.0,
        }
    }

    /// Enable SLO admission at the prefill boundary.
    pub fn with_slo(mut self, policy: SloPolicy) -> DisaggPoolOpts {
        self.slo = Some(policy);
        self
    }

    /// Meter handoff traffic at `bytes` per context token.
    pub fn with_handoff(mut self, bytes: f64) -> DisaggPoolOpts {
        self.handoff_bytes_per_token = bytes;
        self
    }
}

/// One opened session crossing the prefill→decode tier boundary.
struct Handoff {
    kv: KvHandle,
    first: StepOutcome,
    arrival_s: f64,
    admit_s: f64,
    /// Wall-clock stamp of first-token completion — TTFT belongs to the
    /// prefill tier, not to whenever a decode worker picks the session
    /// up.
    ttft_abs: f64,
    /// Submit-side `work_estimate`, released from the backlog counter
    /// when the decode tier answers.
    est: usize,
    tx: mpsc::Sender<RequestResult>,
}

type PrefillJob = (Request, mpsc::Sender<RequestResult>);

/// Marker result for a request shed by SLO admission before execution:
/// identity and queue-wait fields are real, everything served-related is
/// zero, and [`RequestResult::shed`] is set so aggregation excludes the
/// row.
fn shed_result(req: &Request, now: f64) -> RequestResult {
    let wait = (now - req.arrival_s).max(0.0);
    RequestResult {
        id: req.id,
        adapter: None,
        slo: req.slo,
        shed: true,
        logits: Vec::new(),
        tokens: 0,
        queue_wait_s: wait,
        exec_s: 0.0,
        latency_s: wait,
        dispatch_s: now,
        batch_size: 0,
        sim_cycles: 0,
        sim_energy_j: 0.0,
        gen_tokens: 0,
        cached_tokens: 0,
        ttft_s: 0.0,
        tpot_s: 0.0,
        base_mults: 0,
        base_reuses: 0,
        adapter_ops: 0,
        per_shard: Vec::new(),
    }
}

/// A live disaggregated prefill/decode pool ([`Server::start_disagg_pool`]).
///
/// Topology: `submit` pushes onto one shared request queue; `p` prefill
/// workers (each owning its own engine) pop jobs, apply SLO admission,
/// run the prompt phase, and send the opened session over the handoff
/// channel; `d` decode workers (own engines too) pull handoffs into free
/// session slots and drive the continuous-batching wave loop
/// ([`ExecutionBackend::decode_steps`]) until each budget is exhausted.
/// The shared queues make dispatch self-balancing — an idle worker pulls
/// the next job — so there is no per-replica routing decision to get
/// wrong. Shutdown cascades: closing the request queue ends the prefill
/// workers, dropping the last handoff sender ends the decode workers
/// once their sessions drain.
pub struct DisaggPool<B: ExecutionBackend = PjrtBackend> {
    job_tx: Option<mpsc::Sender<PrefillJob>>,
    prefill_handles: Vec<std::thread::JoinHandle<Result<()>>>,
    decode_handles: Vec<std::thread::JoinHandle<Result<()>>>,
    epoch: Instant,
    /// Pool-wide counters (one instance — the shared queues leave
    /// nothing per-replica to attribute).
    stats: Arc<ServerStats>,
    /// SLO policy the pool was started with (for summary attainment).
    slo: Option<SloPolicy>,
    n_workers: usize,
    cost_rx: Mutex<mpsc::Receiver<CostModel>>,
    cost_cache: OnceLock<CostModel>,
    _backend: PhantomData<fn() -> B>,
}

impl<B: ExecutionBackend + 'static> Server<B> {
    /// Start a disaggregated pool: `p` prefill workers and `d` decode
    /// workers, each with its own engine built by `make(i)` inside the
    /// worker thread (prefill workers get `0..p`, decode workers
    /// `p..p+d`). `policy.max_batch` caps each decode worker's running
    /// batch.
    pub fn start_disagg_pool<F>(
        p: usize,
        d: usize,
        make: F,
        policy: BatchPolicy,
        opts: DisaggPoolOpts,
    ) -> DisaggPool<B>
    where
        F: Fn(usize) -> Result<Engine<B>> + Send + Clone + 'static,
    {
        assert!(p > 0 && d > 0, "disaggregated pool needs both tiers");
        let epoch = Instant::now();
        let stats = Arc::new(ServerStats::default());
        let (job_tx, job_rx) = mpsc::channel::<PrefillJob>();
        let jobs = Arc::new(Mutex::new(job_rx));
        let (handoff_tx, handoff_rx) = mpsc::channel::<Handoff>();
        let handoffs = Arc::new(Mutex::new(handoff_rx));
        let (cost_tx, cost_rx) = mpsc::channel::<CostModel>();
        let prefill_handles = (0..p)
            .map(|i| {
                let make = make.clone();
                let jobs = Arc::clone(&jobs);
                let htx = handoff_tx.clone();
                let st = Arc::clone(&stats);
                let ctx = cost_tx.clone();
                std::thread::spawn(move || {
                    disagg_prefill_worker(move || make(i), opts, epoch, st, ctx, jobs, htx)
                })
            })
            .collect();
        // The clones above are the only live handoff senders once this
        // original drops, so decode workers observe disconnect exactly
        // when the prefill tier has fully exited.
        drop(handoff_tx);
        let decode_handles = (0..d)
            .map(|i| {
                let make = make.clone();
                let hrx = Arc::clone(&handoffs);
                let st = Arc::clone(&stats);
                let ctx = cost_tx.clone();
                std::thread::spawn(move || {
                    disagg_decode_worker(move || make(p + i), policy, epoch, st, ctx, hrx)
                })
            })
            .collect();
        DisaggPool {
            job_tx: Some(job_tx),
            prefill_handles,
            decode_handles,
            epoch,
            stats,
            slo: opts.slo,
            n_workers: p + d,
            cost_rx: Mutex::new(cost_rx),
            cost_cache: OnceLock::new(),
            _backend: PhantomData,
        }
    }
}

impl<B: ExecutionBackend + 'static> DisaggPool<B> {
    /// Submit a request; the result arrives on the returned channel
    /// (a shed marker if SLO admission drops it).
    pub fn submit(&self, mut req: Request) -> mpsc::Receiver<RequestResult> {
        req.arrival_s = self.epoch.elapsed().as_secs_f64();
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        self.stats
            .backlog
            .fetch_add(work_estimate(&req), Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        if let Some(tx) = &self.job_tx {
            let _ = tx.send((req, rtx));
        }
        rrx
    }

    /// Pool-wide live counters.
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Cost model of the worker engines (identical by construction).
    /// Blocks until EVERY worker — both tiers — reports; `None` if any
    /// worker failed before reporting (its error surfaces through
    /// `shutdown()`).
    pub fn cost(&self) -> Option<CostModel> {
        if let Some(c) = self.cost_cache.get() {
            return Some(*c);
        }
        let rx = self.cost_rx.lock().ok()?;
        if let Some(c) = self.cost_cache.get() {
            return Some(*c);
        }
        let mut first = None;
        for _ in 0..self.n_workers {
            match rx.recv() {
                Ok(c) => {
                    first.get_or_insert(c);
                }
                Err(_) => return None,
            }
        }
        let c = first?;
        let _ = self.cost_cache.set(c);
        Some(c)
    }

    /// Drive a whole trace through the pool (same contract as
    /// [`ServerPool::serve`]): burst-submit or arrival-paced, then block
    /// for every result in submit order.
    pub fn serve(&self, trace: Vec<Request>, pace: bool) -> Result<Vec<RequestResult>> {
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(trace.len());
        for req in trace {
            if pace {
                let target = Duration::from_secs_f64(req.arrival_s.max(0.0));
                if let Some(sleep) = target.checked_sub(t0.elapsed()) {
                    std::thread::sleep(sleep);
                }
            }
            rxs.push(self.submit(req));
        }
        let mut results = Vec::with_capacity(rxs.len());
        for rx in rxs {
            results.push(
                rx.recv()
                    .map_err(|_| anyhow::anyhow!("live worker dropped a request"))?,
            );
        }
        Ok(results)
    }

    /// One-shot live run: wait for every worker engine, drive the trace,
    /// shut the pool down, and aggregate — shed markers are excluded
    /// from the summary (counted as shed) but kept in `results` so
    /// callers see every answer.
    pub fn run(self, trace: Vec<Request>, pace: bool) -> Result<LiveRun> {
        let opts_slo = self.slo;
        let cost = self.cost();
        let served = match cost {
            Some(_) => self.serve(trace, pace),
            None => Err(anyhow::anyhow!(
                "live worker exited before reporting its cost model"
            )),
        };
        let stats = Arc::clone(&self.stats);
        let stopped = self.shutdown();
        if let Err(worker_err) = stopped {
            return Err(worker_err);
        }
        let results = served?;
        let cost = cost.expect("serve() succeeded, so every worker reported its cost");
        let load = |c: &AtomicUsize| c.load(Ordering::Relaxed);
        let served_rows: Vec<RequestResult> =
            results.iter().filter(|r| !r.shed).cloned().collect();
        Ok(LiveRun {
            summary: ServeSummary::from_results_slo(
                &served_rows,
                load(&stats.batches),
                &cost,
                opts_slo.as_ref(),
                load(&stats.shed),
                load(&stats.degraded),
                load(&stats.handoff_bytes) as u64,
            ),
            results,
            replica_stats: vec![(load(&stats.batches), load(&stats.completed))],
            adapter_misses: load(&stats.adapter_misses) as u64,
            shard_misses: load(&stats.shard_misses) as u64,
            kv_misses: load(&stats.kv_misses) as u64,
            quant_misses: load(&stats.quant_misses) as u64,
        })
    }

    /// Stop both tiers and propagate the first worker error: close the
    /// request queue (prefill workers drain and exit, dropping their
    /// handoff senders), then join the decode workers (they drain
    /// remaining sessions and exit on disconnect).
    pub fn shutdown(mut self) -> Result<()> {
        self.job_tx.take();
        let mut first_err = None;
        for h in self
            .prefill_handles
            .drain(..)
            .chain(self.decode_handles.drain(..))
        {
            match h.join() {
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert(anyhow::anyhow!("worker panicked"));
                }
                Ok(Ok(())) => {}
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl<B: ExecutionBackend> Drop for DisaggPool<B> {
    fn drop(&mut self) {
        self.job_tx.take();
        for h in self
            .prefill_handles
            .drain(..)
            .chain(self.decode_handles.drain(..))
        {
            let _ = h.join();
        }
    }
}

/// Prefill-tier worker: pop jobs from the shared queue, apply SLO
/// admission (queue wait is fully known here — this is the only point
/// where shed/degrade decisions can be made honestly on the live path),
/// run the prompt phase, and hand the opened session to the decode tier.
fn disagg_prefill_worker<B: ExecutionBackend, F>(
    make: F,
    opts: DisaggPoolOpts,
    epoch: Instant,
    stats: Arc<ServerStats>,
    cost_tx: mpsc::Sender<CostModel>,
    jobs: Arc<Mutex<mpsc::Receiver<PrefillJob>>>,
    handoff_tx: mpsc::Sender<Handoff>,
) -> Result<()>
where
    F: FnOnce() -> Result<Engine<B>>,
{
    let engine = make()?;
    let cost = *engine.cost();
    let _ = cost_tx.send(cost);
    loop {
        // Holding the lock across the blocking recv is the shared-queue
        // idiom: exactly one idle worker waits at a time; the others
        // queue on the mutex and take the next job.
        let job = {
            let rx = jobs.lock().expect("job queue lock poisoned");
            rx.recv()
        };
        let (mut req, tx) = match job {
            Ok(j) => j,
            Err(_) => return Ok(()), // queue closed: tier drains out
        };
        let est = work_estimate(&req);
        let now = epoch.elapsed().as_secs_f64();
        if let Some(policy) = &opts.slo {
            let target = policy.target(req.slo);
            let wait = now - req.arrival_s;
            if wait > target.max_wait_s {
                stats.shed.fetch_add(1, Ordering::Relaxed);
                stats.completed.fetch_add(1, Ordering::Relaxed);
                stats.backlog.fetch_sub(est, Ordering::Relaxed);
                let _ = tx.send(shed_result(&req, now));
                continue;
            }
            if wait > target.ttft_s
                && target.degrade_gen > 0
                && decode_budget(&req, opts.default_gen) > target.degrade_gen
            {
                req.gen_tokens = target.degrade_gen;
                stats.degraded.fetch_add(1, Ordering::Relaxed);
            }
        }
        let budget = decode_budget(&req, opts.default_gen);
        let (kv, first) = engine.backend.prefill(&req, budget)?;
        stats.batches.fetch_add(1, Ordering::Relaxed);
        if opts.handoff_bytes_per_token > 0.0 {
            let bytes = (opts.handoff_bytes_per_token * kv.context_len() as f64) as usize;
            stats.handoff_bytes.fetch_add(bytes, Ordering::Relaxed);
        }
        stats
            .adapter_misses
            .store(engine.backend.adapter_misses() as usize, Ordering::Relaxed);
        stats
            .shard_misses
            .store(engine.backend.shard_misses() as usize, Ordering::Relaxed);
        stats
            .kv_misses
            .store(engine.backend.kv_misses() as usize, Ordering::Relaxed);
        stats
            .quant_misses
            .store(engine.backend.quant_misses() as usize, Ordering::Relaxed);
        let handoff = Handoff {
            kv,
            first,
            arrival_s: req.arrival_s,
            admit_s: now,
            ttft_abs: epoch.elapsed().as_secs_f64(),
            est,
            tx,
        };
        if handoff_tx.send(handoff).is_err() {
            // Decode tier gone (pool torn down mid-request).
            return Ok(());
        }
    }
}

/// Decode-tier worker: pull handed-off sessions from the shared channel
/// into free slots, then drive the continuous-batching wave loop
/// ([`ExecutionBackend::decode_steps`]) — the same session bookkeeping
/// as every other decode path ([`DecodeSession`]).
fn disagg_decode_worker<B: ExecutionBackend, F>(
    make: F,
    policy: BatchPolicy,
    epoch: Instant,
    stats: Arc<ServerStats>,
    cost_tx: mpsc::Sender<CostModel>,
    handoffs: Arc<Mutex<mpsc::Receiver<Handoff>>>,
) -> Result<()>
where
    F: FnOnce() -> Result<Engine<B>>,
{
    let engine = make()?;
    let cost = *engine.cost();
    let _ = cost_tx.send(cost);
    let cap = policy.max_batch.min(engine.max_batch()).max(1);
    let mut active: Vec<(DecodeSession, usize, mpsc::Sender<RequestResult>)> = Vec::new();
    loop {
        // 1. Fill free slots from the shared handoff channel. Block (in
        //    short slices, so the mutex stays fair across decode
        //    workers) only when fully idle.
        let mut disconnected = false;
        while active.len() < cap {
            let got = {
                let rx = handoffs.lock().expect("handoff channel lock poisoned");
                if active.is_empty() {
                    rx.recv_timeout(Duration::from_millis(1))
                } else {
                    match rx.try_recv() {
                        Ok(h) => Ok(h),
                        Err(mpsc::TryRecvError::Empty) => Err(mpsc::RecvTimeoutError::Timeout),
                        Err(mpsc::TryRecvError::Disconnected) => {
                            Err(mpsc::RecvTimeoutError::Disconnected)
                        }
                    }
                }
            };
            match got {
                Ok(h) => {
                    let mut s =
                        DecodeSession::admit(h.kv, h.first, h.arrival_s, h.admit_s, &cost, 0);
                    s.ttft_abs = Some(h.ttft_abs);
                    active.push((s, h.est, h.tx));
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        if active.is_empty() {
            if disconnected {
                return Ok(()); // prefill tier gone and nothing left to serve
            }
            continue;
        }
        // 2. One wave over every unfinished session, through the batch
        //    decode API.
        let batch_now = active.len();
        stats.batches.fetch_add(1, Ordering::Relaxed);
        let mut decode_ctxs: Vec<u64> = Vec::new();
        {
            let mut stepping: Vec<&mut DecodeSession> = active
                .iter_mut()
                .map(|(s, _, _)| s)
                .filter(|s| !s.kv.done())
                .collect();
            for s in stepping.iter_mut() {
                s.peak_batch = s.peak_batch.max(batch_now);
                decode_ctxs.push(s.kv.context_len() as u64);
            }
            let kv_refs: Vec<&mut KvHandle> = stepping.iter_mut().map(|s| &mut s.kv).collect();
            let outs = engine.backend.decode_steps(kv_refs)?;
            for ((s, ctx), out) in stepping.iter_mut().zip(&decode_ctxs).zip(outs) {
                s.record_step(*ctx, out, &cost);
            }
        }
        // 3. Retire finished sessions and answer their waiters.
        let now = epoch.elapsed().as_secs_f64();
        let mut i = 0;
        while i < active.len() {
            if active[i].0.kv.done() {
                let (mut s, est, tx) = active.swap_remove(i);
                s.finish_abs = Some(now);
                stats.completed.fetch_add(1, Ordering::Relaxed);
                stats.backlog.fetch_sub(est, Ordering::Relaxed);
                let _ = tx.send(s.into_result());
            } else {
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataset;
    use crate::workload::SloClass;

    fn req(id: u64, seq_len: usize, gen: u32) -> Request {
        Request {
            id,
            dataset: Dataset::Imdb,
            arrival_s: 0.0,
            seq_len,
            gen_tokens: gen,
            adapter: None,
            prefix: None,
            slo: SloClass::Standard,
        }
    }

    #[test]
    fn work_estimate_weighs_prompt_and_decode_budget() {
        assert_eq!(work_estimate(&req(0, 8, 64)), 72);
        // gen_tokens == 0 still counts the guaranteed prefill token.
        assert_eq!(work_estimate(&req(1, 8, 0)), 9);
    }

    /// Regression for least-loaded dispatch: ranking replicas by
    /// in-flight request COUNT let a replica draining one deep decode
    /// session (huge remaining work) win ties against a replica holding
    /// several trivial requests. Token-weighted backlog inverts that
    /// choice.
    #[test]
    fn dispatch_ranks_by_token_backlog_not_request_count() {
        // Replica 0: one request, but a 4+512-token decode session.
        // Replica 1: three requests of 8+1 tokens each.
        let in_flight = [1usize, 3];
        let backlog = [work_estimate(&req(0, 4, 512)), 3 * work_estimate(&req(1, 8, 1))];
        // The old rule (request count) picks the decode-heavy replica…
        assert_eq!(pick_min_load(&in_flight, 0), 0);
        // …the work-aware rule routes away from it.
        assert_eq!(pick_min_load(&backlog, 0), 1);
    }

    #[test]
    fn pick_min_load_rotates_ties_from_round_robin_cursor() {
        let loads = [5usize, 5, 5];
        assert_eq!(pick_min_load(&loads, 0), 0);
        assert_eq!(pick_min_load(&loads, 1), 1);
        assert_eq!(pick_min_load(&loads, 2), 2);
        assert_eq!(pick_min_load(&loads, 4), 1); // cursor wraps
        // Strict minimum always wins regardless of cursor.
        assert_eq!(pick_min_load(&[7, 2, 7], 2), 1);
    }

    #[test]
    fn backlog_counter_tracks_submit_and_completion() {
        let stats = ServerStats::default();
        stats.backlog.fetch_add(40, Ordering::Relaxed);
        stats.backlog.fetch_add(9, Ordering::Relaxed);
        stats.backlog.fetch_sub(40, Ordering::Relaxed);
        assert_eq!(stats.backlog.load(Ordering::Relaxed), 9);
    }
}

// Artifact-free coverage lives in rust/tests/live_server.rs (sim and
// functional backends: closed-batch regressions plus the decode
// continuous-batching sessions, and the disaggregated pool); PJRT
// coverage in rust/tests/integration_coordinator.rs (requires built
// artifacts).
