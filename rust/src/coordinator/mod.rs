//! The serving coordinator: request queue → dynamic batcher → engine that
//! dispatches every batch through a pluggable execution backend while
//! attributing simulated accelerator cycles/energy to each request.
//!
//! The paper's contribution lives at the micro-architecture level, so the
//! coordinator is the thin-but-real serving harness a deployment of AxLLM
//! would sit behind: admission, batching, padding, execution, per-request
//! metrics, and throughput/latency reporting. [`Engine`] is generic over
//! [`crate::backend::ExecutionBackend`], so the same batching and
//! attribution code serves traffic three ways:
//!
//! - `Engine::new(SimBackend::…)` — cycle-attribution-only serving, no
//!   artifacts or PJRT (CI, capacity studies);
//! - `Engine::new(FunctionalBackend::…)` — bit-exact in-process execution
//!   with real logits (correctness soaks);
//! - `Engine::load(dir, …)` — the compiled PJRT artifact runtime
//!   (production-shaped path; requires `make artifacts`).
//!
//! Rust owns the event loop; Python never runs on this path. See
//! `rust/DESIGN.md` for the `Engine → ExecutionBackend → Accelerator`
//! layering diagram.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use batcher::{Batch, BatchPolicy, DynamicBatcher};
pub use engine::{CostModel, Engine, RequestResult};
pub use metrics::{LatencyStats, ServeSummary};
pub use server::Server;
