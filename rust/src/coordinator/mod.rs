//! The serving coordinator: request queue → dynamic batcher → router that
//! dispatches every batch to the PJRT functional model while attributing
//! simulated accelerator cycles/energy to each request.
//!
//! The paper's contribution lives at the micro-architecture level, so L3
//! here is the thin-but-real serving harness a deployment of AxLLM would
//! sit behind (DESIGN.md §2): admission, batching, padding, execution,
//! per-request metrics, and throughput/latency reporting. Rust owns the
//! event loop; Python never runs on this path.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use batcher::{Batch, BatchPolicy, DynamicBatcher};
pub use engine::{CostModel, Engine, RequestResult};
pub use metrics::{LatencyStats, ServeSummary};
pub use server::Server;
