//! The serving coordinator: request queue → batch scheduler → engine that
//! dispatches every batch through a pluggable execution backend while
//! attributing simulated accelerator cycles/energy to each request.
//!
//! The paper's contribution lives at the micro-architecture level, so the
//! coordinator is the thin-but-real serving harness a deployment of AxLLM
//! would sit behind: admission, batching, padding, execution, per-request
//! metrics, and throughput/latency reporting. [`Engine`] is generic over
//! [`crate::backend::ExecutionBackend`], so the same batching and
//! attribution code serves traffic three ways:
//!
//! - `Engine::new(SimBackend::…)` — cycle-attribution-only serving, no
//!   artifacts or PJRT (CI, capacity studies);
//! - `Engine::new(FunctionalBackend::…)` — bit-exact in-process execution
//!   with real logits (correctness soaks);
//! - `Engine::load(dir, …)` — the compiled PJRT artifact runtime
//!   (production-shaped path; requires `make artifacts`).
//!
//! Trace-driven and live serving share one batch-closure implementation:
//! [`BatchScheduler`] owns the deadline tracking and closure rules, the
//! trace path drives it with arrival stamps
//! ([`BatchScheduler::batch_trace`]), and the threaded [`Server`] worker
//! drives it with wall time against a single shared epoch. [`ServerPool`]
//! ([`Server::start_pool`]) scales live serving across N replica engines
//! with least-loaded dispatch, and [`ServeSummary::from_results`] is the
//! one aggregation both paths report through.
//!
//! Serving is **phase-aware**: besides the closed-batch prefill path,
//! both the engine ([`Engine::serve_trace_decode`]) and the server
//! ([`Server::start_decode_with`] / [`Server::start_decode_pool`]) run
//! autoregressive decode with **token-level continuous batching** — an
//! iteration loop that admits waiting requests into free session slots
//! at step boundaries ([`BatchScheduler::take_ready`]) and retires
//! sessions as their generated-token budgets exhaust, reporting
//! TTFT/TPOT alongside the end-to-end latency percentiles.
//!
//! Serving is also **tier-aware**: [`Engine::serve_trace_disagg`]
//! models a disaggregated fleet — dedicated prefill replicas running
//! chunked prefill ([`crate::backend::ExecutionBackend::prefill_chunk`])
//! hand opened sessions across a metered KV link to dedicated decode
//! replicas — on the same deterministic virtual clock, with
//! [`Engine::serve_trace_unified`] as the equal-hardware baseline; the
//! live counterpart is [`Server::start_disagg_pool`]. Admission on both
//! paths can be SLO-aware ([`BatchScheduler::take_ready_slo`]): priority
//! classes with aging boost, deadline shedding, and degraded budgets
//! under overload.
//!
//! Rust owns the event loop; Python never runs on this path. See
//! `rust/DESIGN.md` for the `Server<B> → BatchScheduler → Engine<B>`
//! layering diagram and the live-vs-trace invariants.

pub mod batcher;
pub mod disagg;
pub mod engine;
pub mod metrics;
pub mod server;

pub use batcher::{
    Batch, BatchPolicy, BatchScheduler, DynamicBatcher, SloAdmission, SloPolicy, SloTarget,
};
pub use disagg::DisaggOpts;
pub use engine::{CostModel, DecodeServeOpts, Engine, RequestResult};
pub use metrics::{AdapterUsage, LatencyStats, ServeSummary, ShardUsage};
pub use server::{
    DecodeOpts, DisaggPool, DisaggPoolOpts, LiveRun, Server, ServerPool, ServerStats,
};
