//! Dynamic batching: one scheduler core shared by trace-driven and live
//! serving.
//!
//! Requests arrive with timestamps (from [`crate::workload::TraceGenerator`]
//! or a live queue); a batch forms when either `max_batch` requests are
//! waiting or the oldest request has waited `max_wait_s`. This is the
//! standard serving trade-off: larger batches amortize executable dispatch,
//! longer waits hurt tail latency.
//!
//! [`BatchScheduler`] owns the closure rules against an *externally
//! supplied* clock, so the same logic drives both callers:
//!
//! - trace serving ([`BatchScheduler::batch_trace`]) advances the clock to
//!   each request's arrival stamp — fully deterministic, no wall clock;
//! - the live [`crate::coordinator::Server`] worker advances the clock with
//!   wall time and uses [`BatchScheduler::deadline_s`] to sleep *exactly
//!   until the oldest pending request's deadline* — never a fresh
//!   `max_wait_s` window per message, which is what used to let a steady
//!   trickle of arrivals starve the oldest request indefinitely.

use crate::workload::{Request, SloClass};

/// Per-class service-level targets: the latency the class is promised and
/// the overload escape hatches (admission deadline, degraded budget) the
/// scheduler may use to keep the promise for everyone else.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloTarget {
    /// Time-to-first-token target (seconds from arrival to the prefill
    /// token). Attainment is measured against this; admitted requests
    /// that have already waited past it are *degraded* (see
    /// [`SloTarget::degrade_gen`]) rather than served at full budget.
    pub ttft_s: f64,
    /// Time-per-output-token target (seconds per decode token after the
    /// first). Attainment accounting only — the scheduler never slows a
    /// running session, it just reports the violation.
    pub tpot_s: f64,
    /// Admission deadline: a pending request that has waited longer than
    /// this and *still* cannot be admitted is shed (returned to the
    /// caller, never served). `f64::INFINITY` disables shedding for the
    /// class; `0.0` sheds on the first admission pass that cannot seat
    /// the request.
    pub max_wait_s: f64,
    /// Degraded decode budget: an admitted request whose wait has already
    /// blown [`SloTarget::ttft_s`] gets `gen_tokens` clamped to this
    /// (when non-zero and smaller than the request's own budget), trading
    /// output length for queue drain under overload. `0` disables
    /// degradation for the class.
    pub degrade_gen: u32,
}

/// SLO-aware admission policy: one [`SloTarget`] per [`SloClass`] plus the
/// anti-starvation boost. Class rank orders admission (interactive first);
/// the boost promotes any request that has waited `boost_after_s` to the
/// front rank, so sustained high-priority load can delay — but never
/// permanently starve — batch traffic.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloPolicy {
    /// Targets for [`SloClass::Interactive`].
    pub interactive: SloTarget,
    /// Targets for [`SloClass::Standard`].
    pub standard: SloTarget,
    /// Targets for [`SloClass::Batch`].
    pub batch: SloTarget,
    /// Any pending request that has waited at least this long is ranked
    /// with the interactive class regardless of its own class (ties break
    /// oldest-first, so a boosted batch request beats a fresher
    /// interactive one). This is the starvation-freedom guarantee.
    pub boost_after_s: f64,
}

impl Default for SloPolicy {
    /// Interactive chats demand sub-second first tokens and shed fast;
    /// standard requests tolerate seconds; batch jobs are never shed
    /// (infinite admission deadline) and never degraded — they simply
    /// wait, bounded by the boost.
    fn default() -> Self {
        SloPolicy {
            interactive: SloTarget {
                ttft_s: 0.25,
                tpot_s: 0.05,
                max_wait_s: 1.0,
                degrade_gen: 8,
            },
            standard: SloTarget {
                ttft_s: 1.0,
                tpot_s: 0.2,
                max_wait_s: 5.0,
                degrade_gen: 16,
            },
            batch: SloTarget {
                ttft_s: 30.0,
                tpot_s: 1.0,
                max_wait_s: f64::INFINITY,
                degrade_gen: 0,
            },
            boost_after_s: 10.0,
        }
    }
}

impl SloPolicy {
    /// The target set for `class`.
    pub fn target(&self, class: SloClass) -> &SloTarget {
        match class {
            SloClass::Interactive => &self.interactive,
            SloClass::Standard => &self.standard,
            SloClass::Batch => &self.batch,
        }
    }
}

/// Outcome of one SLO-aware admission pass
/// ([`BatchScheduler::take_ready_slo`]).
#[derive(Clone, Debug, Default)]
pub struct SloAdmission {
    /// Requests admitted this pass, priority-then-arrival ordered, with
    /// any degradation already applied to `gen_tokens`.
    pub admitted: Vec<Request>,
    /// Requests shed this pass: past their class admission deadline and
    /// still not seatable. Removed from the pending set; the caller
    /// accounts them (and may re-enqueue a retry with a fresh arrival
    /// stamp — the scheduler holds no memory of shed ids).
    pub shed: Vec<Request>,
    /// How many admitted requests had `gen_tokens` clamped to their
    /// class's degraded budget.
    pub degraded: usize,
}

/// Batching policy knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchPolicy {
    /// Maximum requests per batch (the artifact's compiled batch size).
    pub max_batch: usize,
    /// Maximum time the oldest request may wait before the batch closes.
    pub max_wait_s: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 4,
            max_wait_s: 0.010,
        }
    }
}

/// A closed batch: the requests plus the time at which it was dispatched.
/// Scheduler closures (`offer`/`admit`/`poll`/`flush`) never emit an
/// empty batch.
#[derive(Clone, Debug)]
pub struct Batch {
    /// The batched requests, in admission order.
    pub requests: Vec<Request>,
    /// Dispatch time on the caller's clock.
    pub dispatch_s: f64,
}

/// Deadline-tracking batch scheduler. Holds the pending request set and
/// applies the closure rules; time is supplied by the caller (arrival
/// stamps for traces, a shared epoch clock for live serving), making the
/// policy logic identical — and identically testable — on both paths.
#[derive(Clone, Debug)]
pub struct BatchScheduler {
    policy: BatchPolicy,
    pending: Vec<Request>,
}

/// Trace-driving name for the scheduler (the original API). Both names
/// refer to the *same* closure implementation — there is deliberately no
/// second copy of the batching rules anywhere in the crate.
pub type DynamicBatcher = BatchScheduler;

impl BatchScheduler {
    /// New scheduler with an empty pending set.
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch > 0);
        assert!(policy.max_wait_s >= 0.0);
        BatchScheduler {
            policy,
            pending: Vec::new(),
        }
    }

    /// Number of requests waiting for a closure rule to fire.
    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Absolute deadline (seconds on the caller's clock) by which the
    /// pending set must dispatch: the oldest arrival plus `max_wait_s`.
    /// `None` when nothing is pending — there is nothing to wait for.
    /// Scans all pending arrivals (bounded by `max_batch`) rather than
    /// trusting insertion order, for the same reason the `max_batch`
    /// closure folds over arrivals: concurrent submitters can deliver
    /// slightly out-of-order stamps, and the wait bound must track the
    /// true oldest request.
    pub fn deadline_s(&self) -> Option<f64> {
        if self.pending.is_empty() {
            return None;
        }
        let oldest = self
            .pending
            .iter()
            .map(|r| r.arrival_s)
            .fold(f64::INFINITY, f64::min);
        Some(oldest + self.policy.max_wait_s)
    }

    /// Close the pending batch if its deadline has passed at `now`.
    /// The batch dispatches *at the deadline*, not at `now`: queue-wait
    /// attribution is bounded by the policy even when the caller observes
    /// the deadline late.
    pub fn poll(&mut self, now: f64) -> Option<Batch> {
        let deadline = self.deadline_s()?;
        if now >= deadline {
            Some(Batch {
                requests: std::mem::take(&mut self.pending),
                dispatch_s: deadline,
            })
        } else {
            None
        }
    }

    /// Offer one request at its arrival time; returns any batches this
    /// arrival closed.
    ///
    /// Closure rules, evaluated at the new request's arrival time:
    /// 1. if the oldest pending request's deadline has passed, the pending
    ///    set (without the new arrival) dispatches first, at its deadline;
    /// 2. if pending then reaches `max_batch`, it dispatches immediately.
    pub fn offer(&mut self, req: Request) -> Vec<Batch> {
        let now = req.arrival_s;
        let mut out = Vec::new();
        if let Some(due) = self.poll(now) {
            out.push(due);
        }
        if let Some(full) = self.admit(req) {
            out.push(full);
        }
        out
    }

    /// Admit one request applying only the `max_batch` closure — the
    /// deadline rule is NOT evaluated. The live worker uses this while
    /// draining a backlog, deferring deadline closures to one [`poll`] at
    /// the current wall time once the queue is empty: requests that are
    /// all already late then batch together (up to `max_batch`) instead
    /// of replaying their stale inter-arrival gaps as singleton batches.
    /// Deterministic trace replay must use [`offer`] instead.
    ///
    /// [`poll`]: BatchScheduler::poll
    /// [`offer`]: BatchScheduler::offer
    pub fn admit(&mut self, req: Request) -> Option<Batch> {
        self.pending.push(req);
        if self.pending.len() >= self.policy.max_batch {
            // Dispatch at the latest member arrival (robust to slightly
            // out-of-order stamps from concurrent submitters, so queue
            // waits can never go negative).
            let dispatch_s = self
                .pending
                .iter()
                .map(|r| r.arrival_s)
                .fold(f64::NEG_INFINITY, f64::max);
            Some(Batch {
                requests: std::mem::take(&mut self.pending),
                dispatch_s,
            })
        } else {
            None
        }
    }

    /// Queue one request for **continuous batching** without evaluating
    /// any closure rule. Continuous batching has no closed batches:
    /// admission happens at step boundaries through
    /// [`BatchScheduler::take_ready`], so the deadline/`max_batch` rules
    /// never fire. Closed-batch callers must keep using
    /// [`BatchScheduler::offer`] / [`BatchScheduler::admit`].
    ///
    /// [`offer`]: BatchScheduler::offer
    /// [`admit`]: BatchScheduler::admit
    pub fn enqueue(&mut self, req: Request) {
        self.pending.push(req);
    }

    /// Continuous-batching admission: remove and return up to `n` pending
    /// requests, oldest arrival first. Free session slots are refilled
    /// FIFO at every iteration boundary, so a long-running session can
    /// delay — but never permanently starve — a waiting request; the
    /// arrival sort keeps the rule honest under slightly out-of-order
    /// stamps from concurrent submitters (same reasoning as
    /// [`BatchScheduler::deadline_s`]).
    pub fn take_ready(&mut self, n: usize) -> Vec<Request> {
        if n == 0 || self.pending.is_empty() {
            return Vec::new();
        }
        // total_cmp, not partial_cmp().unwrap(): one NaN arrival stamp
        // (an upstream clock bug) must never panic the admission path —
        // NaN sorts last, so well-stamped requests keep strict FIFO.
        self.pending
            .sort_by(|a, b| f64::total_cmp(&a.arrival_s, &b.arrival_s));
        let k = n.min(self.pending.len());
        self.pending.drain(..k).collect()
    }

    /// SLO-aware continuous-batching admission: remove and return up to
    /// `n` pending requests ranked by (class priority, arrival), then shed
    /// every still-pending request past its class admission deadline.
    ///
    /// Rules, evaluated at `now` on the caller's clock:
    /// 1. **Rank**: interactive < standard < batch, except that any
    ///    request that has waited `policy.boost_after_s` is promoted to
    ///    the front rank (anti-starvation aging). Ties break oldest
    ///    arrival first, NaN stamps last (same `total_cmp` reasoning as
    ///    [`BatchScheduler::take_ready`]).
    /// 2. **Degrade**: an admitted request whose wait already exceeds its
    ///    class [`SloTarget::ttft_s`] gets `gen_tokens` clamped to
    ///    [`SloTarget::degrade_gen`] (when non-zero and smaller).
    /// 3. **Shed**: an un-admitted request whose wait exceeds its class
    ///    [`SloTarget::max_wait_s`] is removed and returned in
    ///    [`SloAdmission::shed`] — a request is only ever shed when an
    ///    admission pass could not seat it, never while it is running.
    ///
    /// With no policy pressure (all deadlines infinite, one class) this
    /// degenerates to exactly [`BatchScheduler::take_ready`].
    pub fn take_ready_slo(&mut self, n: usize, now: f64, policy: &SloPolicy) -> SloAdmission {
        if self.pending.is_empty() {
            return SloAdmission::default();
        }
        let rank = |r: &Request| -> u8 {
            if now - r.arrival_s >= policy.boost_after_s {
                0
            } else {
                r.slo as u8
            }
        };
        self.pending.sort_by(|a, b| {
            rank(a)
                .cmp(&rank(b))
                .then(f64::total_cmp(&a.arrival_s, &b.arrival_s))
        });
        let k = n.min(self.pending.len());
        let mut admitted: Vec<Request> = self.pending.drain(..k).collect();
        let mut degraded = 0usize;
        for r in &mut admitted {
            let t = policy.target(r.slo);
            if now - r.arrival_s > t.ttft_s && t.degrade_gen > 0 && r.gen_tokens > t.degrade_gen {
                r.gen_tokens = t.degrade_gen;
                degraded += 1;
            }
        }
        let (shed, keep): (Vec<Request>, Vec<Request>) = std::mem::take(&mut self.pending)
            .into_iter()
            .partition(|r| now - r.arrival_s > policy.target(r.slo).max_wait_s);
        self.pending = keep;
        SloAdmission {
            admitted,
            shed,
            degraded,
        }
    }

    /// Flush the remaining requests (end of trace / server shutdown).
    /// Dispatches at the pending deadline or `now`, whichever is earlier.
    pub fn flush(&mut self, now: f64) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            let dispatch_s = self.deadline_s().map(|d| d.min(now)).unwrap_or(now);
            Some(Batch {
                requests: std::mem::take(&mut self.pending),
                dispatch_s,
            })
        }
    }

    /// Batch an entire trace (requests must be arrival-ordered).
    pub fn batch_trace(policy: BatchPolicy, trace: Vec<Request>) -> Vec<Batch> {
        let mut b = BatchScheduler::new(policy);
        let mut out = Vec::new();
        let end = trace.last().map(|r| r.arrival_s).unwrap_or(0.0);
        for r in trace {
            out.extend(b.offer(r));
        }
        if let Some(last) = b.flush(end + policy.max_wait_s) {
            out.push(last);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataset;

    fn req(id: u64, t: f64) -> Request {
        Request {
            id,
            dataset: Dataset::Imdb,
            seq_len: 32,
            arrival_s: t,
            gen_tokens: 0,
            adapter: None,
            prefix: None,
            slo: SloClass::Standard,
        }
    }

    fn sreq(id: u64, t: f64, slo: SloClass, gen: u32) -> Request {
        Request {
            gen_tokens: gen,
            slo,
            ..req(id, t)
        }
    }

    #[test]
    fn fills_to_max_batch() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 3,
            max_wait_s: 10.0,
        });
        assert!(b.offer(req(0, 0.001)).is_empty());
        assert!(b.offer(req(1, 0.002)).is_empty());
        let batches = b.offer(req(2, 0.003));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn timeout_closes_partial_batch() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 8,
            max_wait_s: 0.01,
        });
        assert!(b.offer(req(0, 0.0)).is_empty());
        let batches = b.offer(req(1, 0.10));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 1);
        assert_eq!(batches[0].requests[0].id, 0);
        assert!((batches[0].dispatch_s - 0.01).abs() < 1e-9);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn flush_drains_pending() {
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        b.offer(req(0, 0.0));
        b.offer(req(1, 0.001));
        let batch = b.flush(1.0).unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert!(b.flush(2.0).is_none());
    }

    #[test]
    fn batch_trace_covers_every_request_once() {
        let trace: Vec<Request> = (0..23).map(|i| req(i, i as f64 * 0.004)).collect();
        let batches = DynamicBatcher::batch_trace(
            BatchPolicy {
                max_batch: 4,
                max_wait_s: 0.01,
            },
            trace,
        );
        let mut ids: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..23).collect::<Vec<_>>());
        assert!(batches.iter().all(|b| b.requests.len() <= 4));
    }

    #[test]
    fn dispatch_times_monotone() {
        let trace: Vec<Request> = (0..50).map(|i| req(i, i as f64 * 0.003)).collect();
        let batches = DynamicBatcher::batch_trace(BatchPolicy::default(), trace);
        for w in batches.windows(2) {
            assert!(w[1].dispatch_s >= w[0].dispatch_s);
        }
    }

    #[test]
    fn deadline_tracks_oldest_pending() {
        let mut b = BatchScheduler::new(BatchPolicy {
            max_batch: 8,
            max_wait_s: 0.05,
        });
        assert_eq!(b.deadline_s(), None);
        b.offer(req(0, 1.0));
        assert!((b.deadline_s().unwrap() - 1.05).abs() < 1e-12);
        // Later arrivals do NOT push the deadline out — this is the
        // starvation bug the live server used to have.
        b.offer(req(1, 1.02));
        b.offer(req(2, 1.04));
        assert!((b.deadline_s().unwrap() - 1.05).abs() < 1e-12);
    }

    #[test]
    fn poll_dispatches_at_deadline_not_at_now() {
        let mut b = BatchScheduler::new(BatchPolicy {
            max_batch: 8,
            max_wait_s: 0.05,
        });
        b.offer(req(0, 0.0));
        // Not due yet.
        assert!(b.poll(0.049).is_none());
        assert_eq!(b.pending(), 1);
        // Observed late: still attributed to the deadline.
        let batch = b.poll(0.30).unwrap();
        assert!((batch.dispatch_s - 0.05).abs() < 1e-12);
        assert_eq!(b.pending(), 0);
        assert!(b.poll(1.0).is_none());
    }

    #[test]
    fn poll_closes_exactly_at_the_deadline_boundary() {
        // Edge pin for the sharded live path: the closure rule is
        // `now >= deadline`, so a poll landing EXACTLY on the deadline
        // instant must close the batch (and stamp it at the deadline) —
        // an exclusive comparison would leave the batch pending until
        // the next wake-up, adding a full scheduling quantum of latency.
        let mut b = BatchScheduler::new(BatchPolicy {
            max_batch: 8,
            max_wait_s: 0.05,
        });
        b.offer(req(0, 1.0));
        let deadline = b.deadline_s().unwrap();
        assert!((deadline - 1.05).abs() < 1e-12);
        // One tick before the boundary: still pending.
        assert!(b.poll(deadline - 1e-12).is_none());
        assert_eq!(b.pending(), 1);
        // Exactly at the boundary: closes, stamped at the deadline.
        let batch = b.poll(deadline).expect("now == deadline must close");
        assert!((batch.dispatch_s - deadline).abs() < 1e-12);
        assert_eq!(batch.requests.len(), 1);
        assert_eq!(b.pending(), 0);
        // max_wait_s = 0: the deadline IS the arrival; an immediate poll
        // at the arrival instant closes the singleton.
        let mut zero = BatchScheduler::new(BatchPolicy {
            max_batch: 8,
            max_wait_s: 0.0,
        });
        zero.offer(req(1, 2.0));
        let batch = zero.poll(2.0).expect("zero-wait deadline closes at arrival");
        assert!((batch.dispatch_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn take_ready_is_fifo_by_arrival_and_bounded() {
        let mut b = BatchScheduler::new(BatchPolicy {
            max_batch: 64,
            max_wait_s: 10.0,
        });
        assert!(b.take_ready(4).is_empty());
        // Out-of-order enqueues (concurrent submitters): admission must
        // still be oldest-first.
        b.enqueue(req(2, 0.03));
        b.enqueue(req(0, 0.01));
        b.enqueue(req(1, 0.02));
        assert_eq!(b.pending(), 3);
        assert!(b.take_ready(0).is_empty());
        let first: Vec<u64> = b.take_ready(2).iter().map(|r| r.id).collect();
        assert_eq!(first, vec![0, 1]);
        assert_eq!(b.pending(), 1);
        let rest: Vec<u64> = b.take_ready(8).iter().map(|r| r.id).collect();
        assert_eq!(rest, vec![2]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn take_ready_survives_nan_arrival_stamps() {
        // Regression: the arrival sort used partial_cmp().unwrap(), so a
        // single NaN arrival stamp panicked the continuous-batching
        // admission path. total_cmp orders NaN after every real stamp:
        // admission must not panic, well-stamped requests must keep
        // strict arrival order, and the NaN request must still be
        // admitted (last), never silently dropped.
        let mut b = BatchScheduler::new(BatchPolicy {
            max_batch: 64,
            max_wait_s: 10.0,
        });
        b.enqueue(req(0, 0.02));
        b.enqueue(req(1, f64::NAN));
        b.enqueue(req(2, 0.01));
        let first: Vec<u64> = b.take_ready(2).iter().map(|r| r.id).collect();
        assert_eq!(first, vec![2, 0], "finite stamps stay oldest-first");
        let rest: Vec<u64> = b.take_ready(8).iter().map(|r| r.id).collect();
        assert_eq!(rest, vec![1], "the NaN-stamped request sorts last");
        assert_eq!(b.pending(), 0, "no request may be dropped");
    }

    #[test]
    fn enqueue_never_closes_a_batch() {
        let mut b = BatchScheduler::new(BatchPolicy {
            max_batch: 2,
            max_wait_s: 0.0,
        });
        // Past-deadline, over-capacity enqueues: no closure fires.
        for i in 0..5 {
            b.enqueue(req(i, i as f64));
        }
        assert_eq!(b.pending(), 5);
        // The deadline is still visible for idle-sleep computation.
        assert!((b.deadline_s().unwrap() - 0.0).abs() < 1e-12);
    }

    /// A permissive policy for tests: no shedding, no degradation, no
    /// boost interference unless a test opts in.
    fn lax_policy() -> SloPolicy {
        let lax = SloTarget {
            ttft_s: f64::INFINITY,
            tpot_s: f64::INFINITY,
            max_wait_s: f64::INFINITY,
            degrade_gen: 0,
        };
        SloPolicy {
            interactive: lax,
            standard: lax,
            batch: lax,
            boost_after_s: f64::INFINITY,
        }
    }

    #[test]
    fn slo_admission_ranks_by_class_then_arrival() {
        let mut b = BatchScheduler::new(BatchPolicy {
            max_batch: 64,
            max_wait_s: 10.0,
        });
        b.enqueue(sreq(0, 0.01, SloClass::Batch, 4));
        b.enqueue(sreq(1, 0.02, SloClass::Interactive, 4));
        b.enqueue(sreq(2, 0.03, SloClass::Standard, 4));
        b.enqueue(sreq(3, 0.04, SloClass::Interactive, 4));
        let out = b.take_ready_slo(3, 0.05, &lax_policy());
        let ids: Vec<u64> = out.admitted.iter().map(|r| r.id).collect();
        // Interactive first (oldest-first within the class), then
        // standard; the batch request waits but is NOT shed (infinite
        // deadline) and surfaces on the next pass.
        assert_eq!(ids, vec![1, 3, 2]);
        assert!(out.shed.is_empty());
        assert_eq!(out.degraded, 0);
        assert_eq!(b.pending(), 1);
        let rest = b.take_ready_slo(4, 0.06, &lax_policy());
        assert_eq!(rest.admitted[0].id, 0);
    }

    #[test]
    fn aging_boost_prevents_low_priority_starvation() {
        // Sustained interactive load: every pass refills with fresh
        // interactive requests, and capacity admits exactly that many.
        // Without aging the batch request would lose every tie forever;
        // the boost must get it through once it has waited boost_after_s.
        let mut policy = lax_policy();
        policy.boost_after_s = 1.0;
        let mut b = BatchScheduler::new(BatchPolicy {
            max_batch: 64,
            max_wait_s: 10.0,
        });
        b.enqueue(sreq(0, 0.0, SloClass::Batch, 4));
        let mut served_batch_at = None;
        for pass in 0..20 {
            let now = 0.1 + pass as f64 * 0.1;
            b.enqueue(sreq(100 + pass as u64, now, SloClass::Interactive, 4));
            let out = b.take_ready_slo(1, now, &policy);
            assert_eq!(out.admitted.len(), 1);
            if out.admitted[0].id == 0 {
                served_batch_at = Some(now);
                break;
            }
        }
        let t = served_batch_at.expect("batch request must not starve");
        // It got through at the first pass where its wait crossed the
        // boost (arrival 0.0, boost 1.0 → the pass at now = 1.0), beating
        // that pass's fresh interactive arrival on the older stamp.
        assert!((t - 1.0).abs() < 1e-9, "served at {t}");
    }

    #[test]
    fn overload_degrades_admitted_and_sheds_unseated_requests() {
        let mut policy = lax_policy();
        policy.interactive.ttft_s = 0.05;
        policy.interactive.degrade_gen = 2;
        policy.interactive.max_wait_s = 0.5;
        policy.standard.max_wait_s = 0.2;
        let mut b = BatchScheduler::new(BatchPolicy {
            max_batch: 64,
            max_wait_s: 10.0,
        });
        b.enqueue(sreq(0, 0.0, SloClass::Interactive, 32));
        b.enqueue(sreq(1, 0.0, SloClass::Standard, 32));
        b.enqueue(sreq(2, 0.0, SloClass::Standard, 32));
        // One slot at t = 0.3: the interactive request is admitted but
        // its TTFT target (0.05) is already blown → degraded to 2 tokens.
        // The standard requests cannot be seated and are past their 0.2 s
        // admission deadline → both shed.
        let out = b.take_ready_slo(1, 0.3, &policy);
        assert_eq!(out.admitted.len(), 1);
        assert_eq!(out.admitted[0].id, 0);
        assert_eq!(out.admitted[0].gen_tokens, 2);
        assert_eq!(out.degraded, 1);
        let mut shed_ids: Vec<u64> = out.shed.iter().map(|r| r.id).collect();
        shed_ids.sort_unstable();
        assert_eq!(shed_ids, vec![1, 2]);
        assert_eq!(b.pending(), 0);
        // Shed-then-retry: re-enqueue one shed request with a fresh
        // arrival stamp; it admits cleanly (the scheduler holds no shed
        // memory) and un-degraded (wait restarts at the retry stamp).
        let mut retry = out.shed[0].clone();
        retry.arrival_s = 0.4;
        let retry_gen = retry.gen_tokens;
        b.enqueue(retry);
        let again = b.take_ready_slo(1, 0.45, &policy);
        assert_eq!(again.admitted.len(), 1);
        assert_eq!(again.admitted[0].gen_tokens, retry_gen);
        assert!(again.shed.is_empty());
        assert_eq!(again.degraded, 0);
    }

    #[test]
    fn zero_admission_deadline_sheds_whatever_a_pass_cannot_seat() {
        // max_wait_s = 0: the admission deadline IS the arrival instant,
        // so any pass at now > arrival seats up to `n` and sheds the
        // rest — the backpressure mode the chunked-prefill engine uses
        // when prefill slots are saturated. Capacity-first: a request is
        // only ever shed by a pass that could not seat it.
        let mut policy = lax_policy();
        policy.standard.max_wait_s = 0.0;
        let mut b = BatchScheduler::new(BatchPolicy {
            max_batch: 64,
            max_wait_s: 10.0,
        });
        for i in 0..5 {
            b.enqueue(sreq(i, 0.0, SloClass::Standard, 4));
        }
        let out = b.take_ready_slo(2, 0.001, &policy);
        assert_eq!(out.admitted.len(), 2);
        assert_eq!(out.shed.len(), 3);
        assert_eq!(b.pending(), 0);
        // At exactly now == arrival the deadline has not yet passed
        // (strict comparison): nothing is shed, the remainder stays
        // pending for the next pass.
        let mut b2 = BatchScheduler::new(BatchPolicy {
            max_batch: 64,
            max_wait_s: 10.0,
        });
        for i in 0..3 {
            b2.enqueue(sreq(i, 0.5, SloClass::Standard, 4));
        }
        let out2 = b2.take_ready_slo(1, 0.5, &policy);
        assert_eq!(out2.admitted.len(), 1);
        assert!(out2.shed.is_empty());
        assert_eq!(b2.pending(), 2);
    }

    #[test]
    fn poll_driven_schedule_matches_batch_trace() {
        // Drive the scheduler the way the live worker does — poll at each
        // deadline that elapses between arrivals, then offer — and check
        // the result is identical to the one-shot trace batching. Mixed
        // inter-arrival gaps exercise both closure rules.
        let policy = BatchPolicy {
            max_batch: 3,
            max_wait_s: 0.01,
        };
        let gaps = [
            0.0, 0.002, 0.02, 0.001, 0.001, 0.03, 0.004, 0.004, 0.004, 0.004, 0.05, 0.001,
        ];
        let mut t = 0.0;
        let mut trace = Vec::new();
        for (i, g) in gaps.iter().enumerate() {
            t += g;
            trace.push(req(i as u64, t));
        }

        let expected = BatchScheduler::batch_trace(policy, trace.clone());

        let mut live = BatchScheduler::new(policy);
        let mut got = Vec::new();
        for r in trace {
            let arrival = r.arrival_s;
            // The worker wakes at every deadline before the next message.
            while let Some(d) = live.deadline_s() {
                if d > arrival {
                    break;
                }
                got.extend(live.poll(d));
            }
            got.extend(live.offer(r));
        }
        if let Some(last) = live.flush(t + policy.max_wait_s) {
            got.push(last);
        }

        assert_eq!(expected.len(), got.len());
        for (e, g) in expected.iter().zip(&got) {
            assert!((e.dispatch_s - g.dispatch_s).abs() < 1e-12);
            let eid: Vec<u64> = e.requests.iter().map(|r| r.id).collect();
            let gid: Vec<u64> = g.requests.iter().map(|r| r.id).collect();
            assert_eq!(eid, gid);
        }
    }
}
