//! Dynamic batching over a request trace.
//!
//! Requests arrive with timestamps (from [`crate::workload::TraceGenerator`]
//! or a live queue); the batcher forms a batch when either `max_batch`
//! requests are waiting or the oldest request has waited `max_wait_s`.
//! This is the standard serving trade-off: larger batches amortize
//! executable dispatch, longer waits hurt tail latency.

use crate::workload::Request;

/// Batching policy knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchPolicy {
    /// Maximum requests per batch (the artifact's compiled batch size).
    pub max_batch: usize,
    /// Maximum time the oldest request may wait before the batch closes.
    pub max_wait_s: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 4,
            max_wait_s: 0.010,
        }
    }
}

/// A closed batch: the requests plus the time at which it was dispatched.
#[derive(Clone, Debug)]
pub struct Batch {
    pub requests: Vec<Request>,
    pub dispatch_s: f64,
}

/// Deterministic trace-driven batcher (no wall clock — simulation time
/// comes from request arrival stamps, making tests and experiments
/// reproducible).
#[derive(Clone, Debug)]
pub struct DynamicBatcher {
    policy: BatchPolicy,
    pending: Vec<Request>,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch > 0);
        assert!(policy.max_wait_s >= 0.0);
        DynamicBatcher {
            policy,
            pending: Vec::new(),
        }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    /// Offer one request; returns a batch if this arrival closed one.
    ///
    /// Closure rules, evaluated at the new request's arrival time `now`:
    /// 1. if the oldest pending request has waited ≥ `max_wait_s`, the
    ///    pending set (without the new arrival) dispatches first;
    /// 2. if pending reaches `max_batch`, it dispatches immediately.
    pub fn offer(&mut self, req: Request) -> Vec<Batch> {
        let now = req.arrival_s;
        let mut out = Vec::new();
        if let Some(oldest) = self.pending.first() {
            if now - oldest.arrival_s >= self.policy.max_wait_s && !self.pending.is_empty() {
                let dispatch_s = oldest.arrival_s + self.policy.max_wait_s;
                out.push(Batch {
                    requests: std::mem::take(&mut self.pending),
                    dispatch_s,
                });
            }
        }
        self.pending.push(req);
        if self.pending.len() >= self.policy.max_batch {
            out.push(Batch {
                requests: std::mem::take(&mut self.pending),
                dispatch_s: now,
            });
        }
        out
    }

    /// Flush the remaining requests at end of trace.
    pub fn flush(&mut self, now: f64) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            let dispatch_s = self
                .pending
                .first()
                .map(|r| (r.arrival_s + self.policy.max_wait_s).min(now))
                .unwrap_or(now);
            Some(Batch {
                requests: std::mem::take(&mut self.pending),
                dispatch_s,
            })
        }
    }

    /// Batch an entire trace (requests must be arrival-ordered).
    pub fn batch_trace(policy: BatchPolicy, trace: Vec<Request>) -> Vec<Batch> {
        let mut b = DynamicBatcher::new(policy);
        let mut out = Vec::new();
        let end = trace.last().map(|r| r.arrival_s).unwrap_or(0.0);
        for r in trace {
            out.extend(b.offer(r));
        }
        if let Some(last) = b.flush(end + policy.max_wait_s) {
            out.push(last);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataset;

    fn req(id: u64, t: f64) -> Request {
        Request {
            id,
            dataset: Dataset::Imdb,
            seq_len: 32,
            arrival_s: t,
        }
    }

    #[test]
    fn fills_to_max_batch() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 3,
            max_wait_s: 10.0,
        });
        assert!(b.offer(req(0, 0.001)).is_empty());
        assert!(b.offer(req(1, 0.002)).is_empty());
        let batches = b.offer(req(2, 0.003));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn timeout_closes_partial_batch() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 8,
            max_wait_s: 0.01,
        });
        assert!(b.offer(req(0, 0.0)).is_empty());
        let batches = b.offer(req(1, 0.10));
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 1);
        assert_eq!(batches[0].requests[0].id, 0);
        assert!((batches[0].dispatch_s - 0.01).abs() < 1e-9);
        assert_eq!(b.pending(), 1);
    }

    #[test]
    fn flush_drains_pending() {
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        b.offer(req(0, 0.0));
        b.offer(req(1, 0.001));
        let batch = b.flush(1.0).unwrap();
        assert_eq!(batch.requests.len(), 2);
        assert!(b.flush(2.0).is_none());
    }

    #[test]
    fn batch_trace_covers_every_request_once() {
        let trace: Vec<Request> = (0..23).map(|i| req(i, i as f64 * 0.004)).collect();
        let batches = DynamicBatcher::batch_trace(
            BatchPolicy {
                max_batch: 4,
                max_wait_s: 0.01,
            },
            trace,
        );
        let mut ids: Vec<u64> = batches
            .iter()
            .flat_map(|b| b.requests.iter().map(|r| r.id))
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..23).collect::<Vec<_>>());
        assert!(batches.iter().all(|b| b.requests.len() <= 4));
    }

    #[test]
    fn dispatch_times_monotone() {
        let trace: Vec<Request> = (0..50).map(|i| req(i, i as f64 * 0.003)).collect();
        let batches = DynamicBatcher::batch_trace(BatchPolicy::default(), trace);
        for w in batches.windows(2) {
            assert!(w[1].dispatch_s >= w[0].dispatch_s);
        }
    }
}
