//! Paged KV-cache subsystem with cross-request prefix sharing.
//!
//! AxLLM's reuse story so far lives *within* a forward pass (the Result
//! Cache over repeated weight codes). This module adds the serving-side
//! complement: **cross-request** reuse of the KV prefix shared by
//! requests that open with the same system prompt or multi-turn history
//! (the vLLM-style paged prefix cache identified in PAPERS.md as the key
//! serving-side memory optimization).
//!
//! Three pieces, layered:
//!
//! - [`BlockPool`] — a ref-counted pool of fixed-size KV blocks with
//!   capacity accounting and copy-on-extend semantics. Blocks are pure
//!   capacity tokens here: the *contents* of a cached block live in the
//!   trie node's payload (e.g. a per-layer KV snapshot on the functional
//!   backend, `()` on the analytic sim backend).
//! - [`PrefixCache`] — a prefix trie keyed on block-granular token-prefix
//!   keys ([`block_keys`]). Each trie node owns exactly one pool block;
//!   a root-to-node path is a block chain for one shared prefix. Lookups
//!   pin the matched path ([`PrefixLease`]) so eviction cannot reclaim
//!   blocks under an active session.
//! - **Eviction & preemption** — when the pool is exhausted, the LRU
//!   *unpinned* leaf is evicted (its payload recomputable from scratch).
//!   If every leaf is pinned, the LRU *pinned* leaf is **preempted**:
//!   force-evicted with its pins cleared, its holders' leases degrading
//!   to safe no-ops. Correctness is unaffected either way — sessions own
//!   clones of the cached payload and a victim prefix is simply
//!   recomputed (and recharged at full prefill rate) on its next miss.
//!
//! Invariants (checked by [`PrefixCache::validate`], property-tested in
//! `tests/prop_kvcache.rs`):
//!
//! - a live node's block refcount is exactly `1 + pins` (one liveness
//!   ref plus one per outstanding lease);
//! - refcounts never go negative; a dead node holds no pins;
//! - blocks-in-use equals the live node count — no block leaks across
//!   eviction or preemption;
//! - a zero-capacity pool is safe: lookups miss, inserts no-op, leases
//!   release cleanly.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Sizing for a [`PrefixCache`]: a fixed number of fixed-size blocks
/// (HBM capacity expressed in KV blocks, vLLM-style).
#[derive(Clone, Copy, Debug)]
pub struct KvCacheConfig {
    /// Pool capacity in blocks. Zero disables caching (all lookups
    /// miss, all inserts no-op) without disturbing callers.
    pub blocks: usize,
    /// Tokens per block. Prefixes are cached at block granularity: a
    /// prefix of `n` tokens occupies `n / block_size` full blocks and
    /// the remainder is recomputed.
    pub block_size: usize,
}

impl KvCacheConfig {
    /// A config with `blocks` blocks of `block_size` tokens each.
    pub fn new(blocks: usize, block_size: usize) -> KvCacheConfig {
        assert!(block_size > 0, "block_size must be at least 1 token");
        KvCacheConfig { blocks, block_size }
    }
}

/// Handle to one fixed-size block in a [`BlockPool`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockId(usize);

impl BlockId {
    /// Slot index inside the pool (stable for the block's lifetime).
    pub fn index(self) -> usize {
        self.0
    }
}

/// Ref-counted pool of fixed-size KV blocks with capacity accounting.
///
/// The pool tracks *capacity*, not contents: a block is a claim on
/// `block_size` tokens worth of KV memory. Refcounts support prefix
/// sharing (many sessions pin one block) and [`copy_on_extend`]
/// (diverging a shared block before writing).
///
/// [`copy_on_extend`]: BlockPool::copy_on_extend
#[derive(Debug)]
pub struct BlockPool {
    /// Refcount per slot; 0 means the slot is free.
    refs: Vec<u32>,
    /// Free-list of slot indices.
    free: Vec<usize>,
    /// Slots currently allocated (refcount > 0).
    in_use: usize,
    /// Tokens per block.
    block_size: usize,
}

impl BlockPool {
    /// A pool of `capacity` blocks of `block_size` tokens each.
    pub fn new(capacity: usize, block_size: usize) -> BlockPool {
        assert!(block_size > 0, "block_size must be at least 1 token");
        BlockPool {
            refs: vec![0; capacity],
            free: (0..capacity).rev().collect(),
            in_use: 0,
            block_size,
        }
    }

    /// Allocate a free block with refcount 1, or `None` when the pool
    /// is exhausted (callers evict/preempt and retry, or degrade).
    pub fn try_alloc(&mut self) -> Option<BlockId> {
        let slot = self.free.pop()?;
        debug_assert_eq!(self.refs[slot], 0, "free-list slot had live refs");
        self.refs[slot] = 1;
        self.in_use += 1;
        Some(BlockId(slot))
    }

    /// Add a reference to an allocated block (prefix sharing / pinning).
    pub fn retain(&mut self, b: BlockId) {
        assert!(self.refs[b.0] > 0, "retain on a free block");
        self.refs[b.0] += 1;
    }

    /// Drop one reference; returns `true` when this was the last ref
    /// and the block went back on the free list.
    pub fn release(&mut self, b: BlockId) -> bool {
        assert!(self.refs[b.0] > 0, "release on a free block (refcount underflow)");
        self.refs[b.0] -= 1;
        if self.refs[b.0] == 0 {
            self.free.push(b.0);
            self.in_use -= 1;
            true
        } else {
            false
        }
    }

    /// Copy-on-extend: make `b` safe to append to. A uniquely owned
    /// block (refcount 1) is returned as-is; a shared block loses one
    /// ref and a fresh private block is allocated for the writer
    /// (`None` if the pool is full — the caller must evict first).
    pub fn copy_on_extend(&mut self, b: BlockId) -> Option<BlockId> {
        assert!(self.refs[b.0] > 0, "copy_on_extend on a free block");
        if self.refs[b.0] == 1 {
            return Some(b);
        }
        let fresh = self.try_alloc()?;
        self.release(b);
        Some(fresh)
    }

    /// Current refcount of a slot (0 for free slots). For invariant
    /// checks and tests.
    pub fn refs(&self, b: BlockId) -> u32 {
        self.refs[b.0]
    }

    /// Blocks currently allocated.
    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// Blocks currently free.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Total pool capacity in blocks.
    pub fn capacity(&self) -> usize {
        self.refs.len()
    }

    /// Tokens per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }
}

/// Counters and gauges snapshot of a [`PrefixCache`]
/// ([`PrefixCache::stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefixStats {
    /// Prefix lookups attempted.
    pub lookups: u64,
    /// Lookups that matched at least one cached block.
    pub hits: u64,
    /// Total tokens served from cache across all hits.
    pub hit_tokens: u64,
    /// Blocks inserted (trie nodes created) over the cache lifetime.
    pub inserted_blocks: u64,
    /// LRU evictions of unpinned prefix blocks.
    pub evictions: u64,
    /// Preemptions: pinned prefix blocks force-evicted under memory
    /// pressure (their holders' leases degrade to no-ops).
    pub preemptions: u64,
    /// Blocks currently allocated in the pool (gauge).
    pub blocks_in_use: u64,
    /// Blocks currently pinned by outstanding leases (gauge; a live
    /// serving path should drain this to zero between requests).
    pub pinned_blocks: u64,
    /// Pool capacity in blocks (gauge).
    pub capacity_blocks: u64,
}

/// A pin on a root-to-node trie path, returned by
/// [`PrefixCache::lookup_pin`]. While held, eviction cannot reclaim the
/// pinned blocks (preemption still can — release then no-ops). Release
/// exactly once per lease via [`PrefixCache::release`].
#[derive(Clone, Debug)]
pub struct PrefixLease {
    /// Node indices of the pinned path, root-side first.
    path: Vec<usize>,
}

impl PrefixLease {
    /// Number of pinned blocks on this lease's path.
    pub fn blocks(&self) -> usize {
        self.path.len()
    }
}

/// A successful prefix lookup: the pinned path, the number of prefix
/// tokens served from cache, and a clone of the deepest node's payload.
#[derive(Clone, Debug)]
pub struct PrefixHit<T> {
    /// Pin on the matched block chain — release when the session ends.
    pub lease: PrefixLease,
    /// Prefix tokens covered by the matched chain
    /// (`matched blocks × block_size`).
    pub tokens: usize,
    /// Payload snapshot of the deepest matched node (e.g. per-layer KV
    /// state truncated at `tokens`).
    pub payload: T,
}

/// One trie node: one block of one shared prefix chain.
#[derive(Debug)]
struct Node<T> {
    /// Block key at this depth (see [`block_keys`]).
    key: u64,
    /// Parent node index; `None` for children of the trie root.
    parent: Option<usize>,
    /// Live children only (dead nodes are unlinked immediately).
    children: BTreeMap<u64, usize>,
    /// The pool block this node owns (1 liveness ref + 1 per pin).
    block: BlockId,
    /// Outstanding lease pins through this node.
    pins: u32,
    /// Logical LRU clock stamp of the last touch.
    last_use: u64,
    /// Cached payload snapshot at this node's block boundary.
    payload: T,
    /// Dead nodes stay in the arena (slots are never reused) but hold
    /// no block and no pins.
    live: bool,
}

/// Mutex-guarded trie + pool state of a [`PrefixCache`].
#[derive(Debug)]
struct Inner<T> {
    /// Node arena; grow-only, dead nodes flagged rather than reused so
    /// lease paths can never dangle onto a different prefix.
    nodes: Vec<Node<T>>,
    /// Children of the (implicit) root, by block key.
    root_children: BTreeMap<u64, usize>,
    /// Capacity accounting for all cached blocks.
    pool: BlockPool,
    /// Logical LRU clock; bumped once per cache operation.
    tick: u64,
    /// Running counters (gauges come from the pool at snapshot time).
    stats: PrefixStats,
}

impl<T> Inner<T> {
    /// Get a block, evicting the LRU unpinned leaf — or, when every
    /// leaf is pinned, preempting the LRU pinned leaf — as needed.
    /// Nodes in `protect` (the in-flight insertion path) are exempt.
    /// `None` only when the trie has no evictable node left (e.g. a
    /// zero-capacity pool).
    fn ensure_block(&mut self, protect: &[usize]) -> Option<BlockId> {
        loop {
            if let Some(b) = self.pool.try_alloc() {
                return Some(b);
            }
            let leaf = |n: &Node<T>| n.live && n.children.is_empty();
            let victim = self
                .nodes
                .iter()
                .enumerate()
                .filter(|(i, n)| leaf(n) && n.pins == 0 && !protect.contains(i))
                .min_by_key(|(_, n)| n.last_use)
                .map(|(i, _)| i);
            if let Some(i) = victim {
                self.evict(i);
                self.stats.evictions += 1;
                continue;
            }
            // Memory pressure with every leaf pinned: preempt the LRU
            // pinned leaf. Its holders keep their cloned payloads; the
            // prefix is recomputed on its next miss.
            let victim = self
                .nodes
                .iter()
                .enumerate()
                .filter(|(i, n)| leaf(n) && !protect.contains(i))
                .min_by_key(|(_, n)| n.last_use)
                .map(|(i, _)| i);
            match victim {
                Some(i) => {
                    self.preempt(i);
                    self.stats.preemptions += 1;
                }
                None => return None,
            }
        }
    }

    /// Unlink `i` from its parent's child map (it must be live).
    fn unlink(&mut self, i: usize) {
        let (key, parent) = (self.nodes[i].key, self.nodes[i].parent);
        match parent {
            None => self.root_children.remove(&key),
            Some(p) => self.nodes[p].children.remove(&key),
        };
    }

    /// Evict an unpinned leaf: unlink, release its liveness ref (which
    /// frees the block), mark dead.
    fn evict(&mut self, i: usize) {
        debug_assert_eq!(self.nodes[i].pins, 0, "evict picked a pinned node");
        self.unlink(i);
        let b = self.nodes[i].block;
        self.pool.release(b);
        self.nodes[i].live = false;
    }

    /// Preempt a pinned leaf: unlink, drop the liveness ref AND every
    /// pin ref so the block frees immediately, mark dead. Outstanding
    /// leases observe `live == false` and release as a no-op.
    fn preempt(&mut self, i: usize) {
        self.unlink(i);
        let (b, pins) = (self.nodes[i].block, self.nodes[i].pins);
        for _ in 0..=pins {
            self.pool.release(b);
        }
        self.nodes[i].pins = 0;
        self.nodes[i].live = false;
    }

    /// Child of `parent` (or of the root) with block key `key`.
    fn child(&self, parent: Option<usize>, key: u64) -> Option<usize> {
        match parent {
            None => self.root_children.get(&key).copied(),
            Some(p) => self.nodes[p].children.get(&key).copied(),
        }
    }
}

/// A prefix trie over ref-counted KV blocks, shared across requests.
///
/// `T` is the per-block payload snapshot: `Vec<LayerKv>` (truncated at
/// the block boundary) on the functional backend, `()` on the analytic
/// sim backend. All methods take `&self` — the cache lives inside
/// backends whose trait surface is `&self` — with a mutex inside.
pub struct PrefixCache<T: Clone> {
    inner: Mutex<Inner<T>>,
    block_size: usize,
}

impl<T: Clone> PrefixCache<T> {
    /// An empty cache over a fresh [`BlockPool`] sized by `cfg`.
    pub fn new(cfg: KvCacheConfig) -> PrefixCache<T> {
        PrefixCache {
            inner: Mutex::new(Inner {
                nodes: Vec::new(),
                root_children: BTreeMap::new(),
                pool: BlockPool::new(cfg.blocks, cfg.block_size),
                tick: 0,
                stats: PrefixStats::default(),
            }),
            block_size: cfg.block_size,
        }
    }

    /// Tokens per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner<T>> {
        self.inner.lock().expect("kv cache mutex poisoned")
    }

    /// Match `keys` against the trie and pin the deepest cached chain.
    /// `None` on a complete miss; on a hit the lease pins every matched
    /// block against eviction until [`release`](PrefixCache::release).
    pub fn lookup_pin(&self, keys: &[u64]) -> Option<PrefixHit<T>> {
        let mut g = self.lock();
        g.tick += 1;
        let tick = g.tick;
        g.stats.lookups += 1;
        let mut path: Vec<usize> = Vec::new();
        let mut parent: Option<usize> = None;
        for &key in keys {
            match g.child(parent, key) {
                Some(i) => {
                    path.push(i);
                    parent = Some(i);
                }
                None => break,
            }
        }
        let &deepest = path.last()?;
        for &i in &path {
            g.nodes[i].pins += 1;
            g.nodes[i].last_use = tick;
            let b = g.nodes[i].block;
            g.pool.retain(b);
        }
        let tokens = path.len() * self.block_size;
        g.stats.hits += 1;
        g.stats.hit_tokens += tokens as u64;
        Some(PrefixHit {
            payload: g.nodes[deepest].payload.clone(),
            lease: PrefixLease { path },
            tokens,
        })
    }

    /// Insert the block chain for `keys`, calling
    /// `payload_at(cumulative_tokens)` for each *new* block boundary
    /// (existing nodes are freshened, not overwritten — chains are
    /// content-deterministic per key). Stops early, keeping a valid
    /// shorter chain, if the pool cannot yield another block.
    pub fn insert_with<F>(&self, keys: &[u64], mut payload_at: F)
    where
        F: FnMut(usize) -> T,
    {
        let mut g = self.lock();
        g.tick += 1;
        let tick = g.tick;
        let mut parent: Option<usize> = None;
        let mut path: Vec<usize> = Vec::new();
        for (depth, &key) in keys.iter().enumerate() {
            let idx = match g.child(parent, key) {
                Some(i) => {
                    g.nodes[i].last_use = tick;
                    i
                }
                None => {
                    let block = match g.ensure_block(&path) {
                        Some(b) => b,
                        None => return,
                    };
                    let payload = payload_at((depth + 1) * self.block_size);
                    let idx = g.nodes.len();
                    g.nodes.push(Node {
                        key,
                        parent,
                        children: BTreeMap::new(),
                        block,
                        pins: 0,
                        last_use: tick,
                        payload,
                        live: true,
                    });
                    match parent {
                        None => g.root_children.insert(key, idx),
                        Some(p) => g.nodes[p].children.insert(key, idx),
                    };
                    g.stats.inserted_blocks += 1;
                    idx
                }
            };
            path.push(idx);
            parent = Some(idx);
        }
    }

    /// Release a lease: unpin every still-live node on its path (nodes
    /// preempted while the lease was out are skipped — their refs were
    /// already force-dropped). Call exactly once per lease.
    pub fn release(&self, lease: PrefixLease) {
        let mut g = self.lock();
        for i in lease.path {
            if !g.nodes[i].live {
                continue;
            }
            debug_assert!(g.nodes[i].pins > 0, "release without a matching pin");
            g.nodes[i].pins -= 1;
            let b = g.nodes[i].block;
            g.pool.release(b);
        }
    }

    /// Snapshot the counters plus the pool's live gauges.
    pub fn stats(&self) -> PrefixStats {
        let g = self.lock();
        PrefixStats {
            blocks_in_use: g.pool.in_use() as u64,
            pinned_blocks: g
                .nodes
                .iter()
                .filter(|n| n.live && n.pins > 0)
                .count() as u64,
            capacity_blocks: g.pool.capacity() as u64,
            ..g.stats
        }
    }

    /// Check every structural invariant (see the module docs); `Err`
    /// describes the first violation. Test/debug surface — the serving
    /// path never calls this.
    pub fn validate(&self) -> std::result::Result<(), String> {
        let g = self.lock();
        let mut live = 0usize;
        for (i, n) in g.nodes.iter().enumerate() {
            if !n.live {
                if n.pins != 0 {
                    return Err(format!("dead node {i} retains {} pins", n.pins));
                }
                continue;
            }
            live += 1;
            let refs = g.pool.refs(n.block);
            if refs != 1 + n.pins {
                return Err(format!(
                    "node {i}: block refcount {refs} != 1 + {} pins",
                    n.pins
                ));
            }
            if g.child(n.parent, n.key) != Some(i) {
                return Err(format!("node {i} not linked from its parent"));
            }
            if let Some(p) = n.parent {
                if !g.nodes[p].live {
                    return Err(format!("live node {i} hangs off dead parent {p}"));
                }
            }
        }
        if g.pool.in_use() != live {
            return Err(format!(
                "blocks in use {} != live nodes {live} (leak or double-free)",
                g.pool.in_use()
            ));
        }
        if g.pool.in_use() + g.pool.free_blocks() != g.pool.capacity() {
            return Err("pool capacity accounting diverged".to_string());
        }
        Ok(())
    }
}

/// Block-granular trie keys for a shared-prefix group: key `i` hashes
/// the whole prefix up to block `i` (chained), so two groups collide on
/// a chain only by hash accident and a shorter chain's keys are always
/// a prefix of a longer chain's.
pub fn block_keys(group: u64, blocks: usize) -> Vec<u64> {
    let mut h = group ^ 0xA55E_55ED_5EED_0001;
    (0..blocks)
        .map(|i| {
            h = h
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .rotate_left(23)
                ^ (i as u64 + 1);
            h
        })
        .collect()
}

/// Cacheable prefix length for a request: its shared-prefix tag length,
/// capped at `seq_len - 1` (prefill must compute at least the final row
/// to produce last-position logits), rounded down to a whole number of
/// blocks.
pub fn aligned_prefix(tag_len: usize, seq_len: usize, block_size: usize) -> usize {
    if block_size == 0 {
        return 0;
    }
    let usable = tag_len.min(seq_len.saturating_sub(1));
    (usable / block_size) * block_size
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_alloc_retain_release_roundtrip() {
        let mut p = BlockPool::new(2, 16);
        assert_eq!((p.capacity(), p.in_use(), p.free_blocks()), (2, 0, 2));
        let a = p.try_alloc().unwrap();
        let b = p.try_alloc().unwrap();
        assert!(p.try_alloc().is_none(), "pool must report exhaustion");
        p.retain(a);
        assert_eq!(p.refs(a), 2);
        assert!(!p.release(a), "shared block must survive one release");
        assert!(p.release(a), "last release frees");
        assert!(p.release(b));
        assert_eq!((p.in_use(), p.free_blocks()), (0, 2));
        // Freed slots recycle.
        assert!(p.try_alloc().is_some());
    }

    #[test]
    fn pool_copy_on_extend_diverges_only_shared_blocks() {
        let mut p = BlockPool::new(2, 16);
        let a = p.try_alloc().unwrap();
        // Unique owner: extend in place.
        assert_eq!(p.copy_on_extend(a), Some(a));
        // Shared: writer gets a fresh block, reader keeps the original.
        p.retain(a);
        let w = p.copy_on_extend(a).unwrap();
        assert_ne!(w, a);
        assert_eq!(p.refs(a), 1);
        assert_eq!(p.refs(w), 1);
        // Shared and pool full: divergence is refused, refs unchanged.
        p.retain(a);
        assert_eq!(p.copy_on_extend(a), None);
        assert_eq!(p.refs(a), 2);
    }

    #[test]
    fn lookup_hits_deepest_inserted_chain_and_pins_it() {
        let cache: PrefixCache<usize> = PrefixCache::new(KvCacheConfig::new(8, 4));
        let keys = block_keys(7, 3);
        cache.insert_with(&keys, |tokens| tokens);
        // Full-chain hit returns the deepest payload and token count.
        let hit = cache.lookup_pin(&keys).expect("inserted chain must hit");
        assert_eq!(hit.tokens, 12);
        assert_eq!(hit.payload, 12);
        assert_eq!(hit.lease.blocks(), 3);
        // A longer probe of the same group still matches the cached 3.
        let longer = cache.lookup_pin(&block_keys(7, 5)).unwrap();
        assert_eq!(longer.tokens, 12);
        // A different group misses entirely.
        assert!(cache.lookup_pin(&block_keys(8, 3)).is_none());
        let s = cache.stats();
        assert_eq!((s.lookups, s.hits, s.hit_tokens), (3, 2, 24));
        assert_eq!(s.blocks_in_use, 3);
        cache.release(hit.lease);
        cache.release(longer.lease);
        cache.validate().unwrap();
    }

    #[test]
    fn eviction_reclaims_lru_unpinned_leaf_without_leaking() {
        // Capacity 2: inserting a third group's block must evict the
        // least-recently-used unpinned chain.
        let cache: PrefixCache<()> = PrefixCache::new(KvCacheConfig::new(2, 4));
        cache.insert_with(&block_keys(1, 1), |_| ());
        cache.insert_with(&block_keys(2, 1), |_| ());
        // Touch group 1 so group 2 becomes the LRU victim.
        cache.lookup_pin(&block_keys(1, 1)).map(|h| cache.release(h.lease));
        cache.insert_with(&block_keys(3, 1), |_| ());
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.blocks_in_use, 2);
        assert!(cache.lookup_pin(&block_keys(2, 1)).is_none(), "LRU evicted");
        assert!(cache.lookup_pin(&block_keys(1, 1)).is_some(), "MRU survives");
        cache.validate().unwrap();
    }

    #[test]
    fn pinned_chains_survive_eviction_until_preemption() {
        let cache: PrefixCache<()> = PrefixCache::new(KvCacheConfig::new(1, 4));
        cache.insert_with(&block_keys(1, 1), |_| ());
        let hit = cache.lookup_pin(&block_keys(1, 1)).unwrap();
        // The only block is pinned: the next insert must preempt it.
        cache.insert_with(&block_keys(2, 1), |_| ());
        let s = cache.stats();
        assert_eq!((s.evictions, s.preemptions), (0, 1));
        assert_eq!(s.blocks_in_use, 1);
        assert!(cache.lookup_pin(&block_keys(2, 1)).is_some());
        // Releasing the preempted lease is a safe no-op.
        cache.release(hit.lease);
        cache.validate().unwrap();
    }

    #[test]
    fn zero_capacity_pool_is_inert_but_safe() {
        let cache: PrefixCache<()> = PrefixCache::new(KvCacheConfig::new(0, 16));
        cache.insert_with(&block_keys(1, 4), |_| ());
        assert!(cache.lookup_pin(&block_keys(1, 4)).is_none());
        let s = cache.stats();
        assert_eq!((s.inserted_blocks, s.blocks_in_use, s.capacity_blocks), (0, 0, 0));
        cache.validate().unwrap();
    }

    #[test]
    fn insert_protects_its_own_path_from_eviction() {
        // Capacity 2, inserting a 3-block chain: the chain's own first
        // blocks must never be chosen as eviction victims mid-insert —
        // the insert just stops when capacity runs out.
        let cache: PrefixCache<usize> = PrefixCache::new(KvCacheConfig::new(2, 4));
        cache.insert_with(&block_keys(1, 3), |t| t);
        let s = cache.stats();
        assert_eq!(s.inserted_blocks, 2);
        assert_eq!((s.evictions, s.preemptions), (0, 0));
        let hit = cache.lookup_pin(&block_keys(1, 3)).unwrap();
        assert_eq!(hit.tokens, 8, "truncated chain still serves its blocks");
        cache.release(hit.lease);
        cache.validate().unwrap();
    }

    #[test]
    fn block_keys_are_chained_and_prefix_consistent() {
        let short = block_keys(42, 2);
        let long = block_keys(42, 5);
        assert_eq!(&long[..2], &short[..], "shorter chain is a strict prefix");
        assert_ne!(block_keys(41, 2), short, "groups get distinct chains");
        assert_ne!(long[3], long[4], "keys vary along the chain");
    }

    #[test]
    fn aligned_prefix_caps_at_seq_minus_one_and_block_aligns() {
        // 20-token tag, 32-token request, 8-token blocks: 16 cacheable.
        assert_eq!(aligned_prefix(20, 32, 8), 16);
        // Tag covering the whole request leaves the last row computed.
        assert_eq!(aligned_prefix(32, 32, 8), 24);
        assert_eq!(aligned_prefix(8, 8, 8), 0);
        // Short tags round down to zero blocks.
        assert_eq!(aligned_prefix(7, 32, 8), 0);
        assert_eq!(aligned_prefix(0, 32, 8), 0);
        assert_eq!(aligned_prefix(16, 1, 8), 0);
    }
}
