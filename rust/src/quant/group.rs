//! Group-wise quantization regimes (ROADMAP item 4, FineQuant-style).
//!
//! Per-tensor symmetric quantization fits **one** scale to a whole weight
//! matrix. Group-wise quantization instead fits one [`QuantParams`] per
//! contiguous **column group** of `group_size` output columns, trading
//! fidelity (each group's grid hugs its own amplitude) against Result-
//! Cache locality: codes from different groups live on different grids,
//! so a product cached for one group is invalid in the next — the RC's
//! product table is conceptually *per group* ("keyed off the group's
//! scale"), and reuse cannot cross a group boundary. `group_size = cols`
//! (one group) degenerates bit-exactly to the per-tensor path — codes,
//! outputs, and reuse counters — pinned by `tests/prop_quant_group.rs`.
//!
//! The module also provides the compressed weight-code streaming model:
//! a measured run-length / entropy-proxy packing of the code stream
//! ([`compress_codes`]) whose byte counts feed
//! `CostModel::with_quant_regime` as reduced weight-streaming bandwidth.

use crate::quant::{QuantMatrix, QuantParams};

/// A quantization regime: the group width scales are fitted over, plus
/// whether weight codes stream compressed. Threaded through
/// `LayerExec`/`FunctionalBackend` (group-scoped reuse kernels) and
/// `SimBackend`/`CostModel` (streaming-bandwidth accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantRegime {
    /// Column-group width one fitted scale covers. `0` means per-tensor
    /// (one group spanning all columns — today's default path).
    pub group_size: usize,
    /// Stream weight codes through the run-length/entropy-proxy
    /// compressed representation instead of raw one-byte codes.
    pub compressed: bool,
}

impl QuantRegime {
    /// The default per-tensor regime: one scale per matrix, raw codes.
    pub fn per_tensor() -> QuantRegime {
        QuantRegime {
            group_size: 0,
            compressed: false,
        }
    }

    /// Group-wise regime with one fitted scale per `group_size` columns.
    pub fn grouped(group_size: usize) -> QuantRegime {
        assert!(
            group_size > 0,
            "group_size must be positive (0 is the per-tensor sentinel)"
        );
        QuantRegime {
            group_size,
            compressed: false,
        }
    }

    /// Toggle the compressed weight-code streaming path.
    pub fn with_compressed(mut self, compressed: bool) -> QuantRegime {
        self.compressed = compressed;
        self
    }

    /// True when the regime is the per-tensor degenerate (one group).
    pub fn is_per_tensor(&self) -> bool {
        self.group_size == 0
    }

    /// The concrete group width for a matrix of `cols` columns: the
    /// per-tensor sentinel (and any width ≥ `cols`) resolves to one
    /// group spanning every column.
    pub fn effective_group(&self, cols: usize) -> usize {
        if self.group_size == 0 {
            cols.max(1)
        } else {
            self.group_size.min(cols.max(1))
        }
    }
}

impl Default for QuantRegime {
    fn default() -> Self {
        QuantRegime::per_tensor()
    }
}

/// A weight matrix quantized group-wise: the code payload plus one
/// fitted [`QuantParams`] per contiguous column group.
///
/// The codes live in an ordinary [`QuantMatrix`] carrier so the existing
/// kernels (which operate purely in integer code space — scales apply
/// downstream) run unchanged; `codes.params` holds group 0's scale so a
/// single-group matrix **is** the per-tensor matrix. Dequantization of a
/// multi-group matrix must go through [`GroupQuantMatrix::dequantize`]
/// (per-group scales), never `codes.dequantize()`.
#[derive(Clone, Debug)]
pub struct GroupQuantMatrix {
    /// Code payload (`rows × cols` row-major). `codes.params` is the
    /// group-0 scale (the whole-tensor fit when there is one group).
    pub codes: QuantMatrix,
    /// Column-group width the scales were fitted over (≥ 1; clamped to
    /// the column count).
    pub group_size: usize,
    /// One fitted [`QuantParams`] per column group
    /// (`ceil(cols / group_size)` entries; empty for empty matrices).
    pub group_params: Vec<QuantParams>,
}

impl GroupQuantMatrix {
    /// Fit a group-wise quantization of `data` (`rows × cols` row-major
    /// floats): each contiguous `group_size`-column group gets its own
    /// symmetric [`QuantParams::fit`] over the group's values across
    /// **all** rows, and its columns are quantized on that grid.
    ///
    /// `group_size ≥ cols` (or `0`, the per-tensor sentinel) yields one
    /// group whose fit — and therefore every code — is bit-identical to
    /// [`QuantMatrix::from_f32`].
    pub fn fit(
        rows: usize,
        cols: usize,
        data: &[f32],
        bits: u8,
        group_size: usize,
    ) -> GroupQuantMatrix {
        assert_eq!(data.len(), rows * cols);
        let group = if group_size == 0 {
            cols.max(1)
        } else {
            group_size.min(cols.max(1))
        };
        let n_groups = cols.div_ceil(group);
        let mut group_params = Vec::with_capacity(n_groups);
        let mut q = vec![0i8; rows * cols];
        let mut scratch: Vec<f32> = Vec::new();
        for g in 0..n_groups {
            let (c0, c1) = (g * group, ((g + 1) * group).min(cols));
            scratch.clear();
            for r in 0..rows {
                scratch.extend_from_slice(&data[r * cols + c0..r * cols + c1]);
            }
            let params = QuantParams::fit(&scratch, bits);
            for r in 0..rows {
                for c in c0..c1 {
                    q[r * cols + c] = params.quantize(data[r * cols + c]);
                }
            }
            group_params.push(params);
        }
        let carrier = group_params
            .first()
            .copied()
            .unwrap_or(QuantParams { scale: 1.0, bits });
        GroupQuantMatrix {
            codes: QuantMatrix {
                rows,
                cols,
                data: q,
                params: carrier,
            },
            group_size: group,
            group_params,
        }
    }

    /// Re-scope an existing per-tensor matrix into column groups
    /// **without refitting**: codes are unchanged (every group keeps the
    /// source scale), so only the Result-Cache scoping — and the
    /// per-group scale-streaming overhead — differ. This is the form the
    /// sim backend measures (the model's analytic grid stays
    /// row-sampling-stable) and the bit-identity oracle the property
    /// suite pins the group kernels against.
    pub fn from_quant(m: &QuantMatrix, group_size: usize) -> GroupQuantMatrix {
        let group = if group_size == 0 {
            m.cols.max(1)
        } else {
            group_size.min(m.cols.max(1))
        };
        let n_groups = m.cols.div_ceil(group);
        GroupQuantMatrix {
            codes: m.clone(),
            group_size: group,
            group_params: vec![m.params; n_groups],
        }
    }

    /// Number of column groups (`0` only for empty matrices).
    pub fn n_groups(&self) -> usize {
        self.group_params.len()
    }

    /// The group owning column `c`.
    pub fn group_of(&self, c: usize) -> usize {
        c / self.group_size
    }

    /// Dequantize the whole matrix with each column's own group scale.
    pub fn dequantize(&self) -> Vec<f32> {
        let (rows, cols) = (self.codes.rows, self.codes.cols);
        let mut out = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for (c, &q) in self.codes.row(r).iter().enumerate() {
                out.push(self.group_params[c / self.group_size].dequantize(q));
            }
        }
        out
    }

    /// Collapse to a plain per-tensor [`QuantMatrix`]. Only meaningful
    /// in the degenerate single-group case (asserted), where the result
    /// is bit-identical to the per-tensor fit.
    pub fn to_quant(&self) -> QuantMatrix {
        assert!(
            self.n_groups() <= 1,
            "to_quant: {} groups cannot collapse to one per-tensor scale",
            self.n_groups()
        );
        self.codes.clone()
    }

    /// SNR proxy of this quantization against the original floats
    /// (`rows × cols` row-major), in dB, with the same finite-value
    /// semantics as [`crate::quant::quant_snr_db`]: `0.0` for empty or
    /// all-zero input, capped at [`crate::quant::SNR_CAP_DB`].
    pub fn snr_db(&self, original: &[f32]) -> f64 {
        assert_eq!(original.len(), self.codes.rows * self.codes.cols);
        let deq = self.dequantize();
        let mut sig = 0.0f64;
        let mut noise = 0.0f64;
        for (&x, &y) in original.iter().zip(&deq) {
            sig += (x as f64) * (x as f64);
            let e = (x - y) as f64;
            noise += e * e;
        }
        crate::quant::snr_db_from_power(sig, noise)
    }
}

/// Measured byte accounting of one weight matrix's code stream under the
/// compressed storage path: the cheaper of a run-length packing and an
/// entropy-proxy packing, with a stored-raw escape so the payload can
/// never exceed the raw stream. Produced by [`compress_codes`]; consumed
/// by `CostModel::with_quant_regime` as the weight-streaming byte tariff.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CompressedCodes {
    /// Raw stream: one byte per weight code.
    pub raw_bytes: u64,
    /// Chosen payload: `min(run-length, entropy-proxy, raw)` bytes.
    pub payload_bytes: u64,
    /// Run-length candidate: 2 bytes (code, count ≤ 255) per run.
    pub rle_bytes: u64,
    /// Entropy-proxy candidate: `⌈n·H/8⌉` stream bytes plus a 2-byte
    /// table entry per distinct code (H = Shannon entropy of the code
    /// histogram, bits/code).
    pub entropy_bytes: u64,
    /// Scale sidecar: 4 bytes (one `f32`) per column group. Streams with
    /// the payload either way, but grows as groups shrink — the memory
    /// axis of the group-size Pareto.
    pub scale_bytes: u64,
}

impl CompressedCodes {
    /// Total streamed bytes on the compressed path: payload + scales.
    pub fn total_bytes(&self) -> u64 {
        self.payload_bytes + self.scale_bytes
    }

    /// Compression ratio `payload / raw` (1.0 for an empty stream).
    pub fn ratio(&self) -> f64 {
        if self.raw_bytes == 0 {
            1.0
        } else {
            self.payload_bytes as f64 / self.raw_bytes as f64
        }
    }
}

/// Measure the compressed size of a weight-code stream (run-length and
/// entropy-proxy candidates, stored-raw escape) carrying `n_groups`
/// group scales. Pure accounting — nothing is materialized; the sim cost
/// model only needs the byte counts.
///
/// Invariant (pinned by tests): `payload_bytes ≤ raw_bytes` for every
/// input, because the raw stream is always a candidate.
pub fn compress_codes(data: &[i8], n_groups: usize) -> CompressedCodes {
    let raw_bytes = data.len() as u64;
    // Run-length candidate: (code, count) pairs, runs capped at 255.
    let mut rle_bytes = 0u64;
    let mut i = 0usize;
    while i < data.len() {
        let mut j = i + 1;
        while j < data.len() && data[j] == data[i] && j - i < 255 {
            j += 1;
        }
        rle_bytes += 2;
        i = j;
    }
    // Entropy-proxy candidate: Shannon entropy of the code histogram.
    let mut hist = [0u64; 256];
    for &q in data {
        hist[(q as u8) as usize] += 1;
    }
    let n = data.len() as f64;
    let mut h_bits = 0.0f64;
    let mut distinct = 0u64;
    for &c in &hist {
        if c > 0 {
            distinct += 1;
            let p = c as f64 / n;
            h_bits -= p * p.log2();
        }
    }
    let entropy_bytes = if data.is_empty() {
        0
    } else {
        (n * h_bits / 8.0).ceil() as u64 + 2 * distinct
    };
    CompressedCodes {
        raw_bytes,
        payload_bytes: raw_bytes.min(rle_bytes).min(entropy_bytes),
        rle_bytes,
        entropy_bytes,
        scale_bytes: 4 * n_groups as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::{synthesize_floats, WeightDistribution};
    use crate::util::rng::Rng;

    #[test]
    fn single_group_fit_is_bit_identical_to_per_tensor() {
        let mut rng = Rng::new(71);
        let (rows, cols) = (12, 96);
        let data = synthesize_floats(rows, cols, WeightDistribution::default(), &mut rng);
        let per_tensor = QuantMatrix::from_f32(rows, cols, &data, 8);
        for group in [0usize, cols, cols + 1, 10 * cols] {
            let g = GroupQuantMatrix::fit(rows, cols, &data, 8, group);
            assert_eq!(g.n_groups(), 1);
            assert_eq!(g.codes.data, per_tensor.data, "group={group}");
            assert_eq!(g.codes.params, per_tensor.params, "group={group}");
            assert_eq!(g.to_quant().data, per_tensor.data);
        }
    }

    #[test]
    fn group_fit_bounds_per_group_roundtrip_error() {
        let mut rng = Rng::new(72);
        let (rows, cols) = (8, 64);
        let data = synthesize_floats(rows, cols, WeightDistribution::default(), &mut rng);
        for group in [8usize, 16, 32, 64] {
            let g = GroupQuantMatrix::fit(rows, cols, &data, 8, group);
            let deq = g.dequantize();
            for (c, (&x, &y)) in data.iter().zip(&deq).enumerate() {
                let params = g.group_params[(c % cols) / g.group_size];
                // Round-to-nearest on an un-clipped symmetric grid:
                // error ≤ half a step of the *group's* scale.
                assert!(
                    (x - y).abs() <= 0.5 * params.scale + f32::EPSILON,
                    "group={group} idx={c}: |{x} - {y}| > scale/2 = {}",
                    0.5 * params.scale
                );
            }
        }
    }

    #[test]
    fn smaller_groups_never_hurt_snr_on_gaussian_weights() {
        let mut rng = Rng::new(73);
        let (rows, cols) = (32, 256);
        let data = synthesize_floats(rows, cols, WeightDistribution::default(), &mut rng);
        let snr_pt = GroupQuantMatrix::fit(rows, cols, &data, 8, 0).snr_db(&data);
        let snr_g = GroupQuantMatrix::fit(rows, cols, &data, 8, 16).snr_db(&data);
        // Each group's amax ≤ the global amax, so group grids are finer.
        assert!(snr_g > snr_pt, "group 16 {snr_g} dB vs per-tensor {snr_pt} dB");
    }

    #[test]
    fn from_quant_keeps_codes_and_counts_groups() {
        let mut rng = Rng::new(74);
        let data = synthesize_floats(4, 100, WeightDistribution::default(), &mut rng);
        let m = QuantMatrix::from_f32(4, 100, &data, 8);
        let g = GroupQuantMatrix::from_quant(&m, 30);
        assert_eq!(g.codes.data, m.data);
        assert_eq!(g.n_groups(), 4, "100 cols / width 30 → 4 ragged groups");
        assert!(g.group_params.iter().all(|p| *p == m.params));
        assert_eq!(g.group_of(29), 0);
        assert_eq!(g.group_of(30), 1);
        assert_eq!(g.group_of(99), 3);
    }

    #[test]
    fn empty_and_degenerate_shapes_are_finite() {
        let g = GroupQuantMatrix::fit(0, 0, &[], 8, 0);
        assert_eq!(g.n_groups(), 0);
        assert_eq!(g.snr_db(&[]), 0.0, "empty matrix SNR must be finite");
        assert_eq!(g.to_quant().data.len(), 0);
        let z = GroupQuantMatrix::fit(2, 3, &[0.0; 6], 8, 2);
        assert_eq!(z.snr_db(&[0.0; 6]), 0.0, "all-zero input SNR must be finite");
        assert!(z.dequantize().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn compressed_payload_never_exceeds_raw() {
        let mut rng = Rng::new(75);
        // Gaussian codes, constant runs, uniform codes, empty.
        let gauss: Vec<i8> = {
            let f = synthesize_floats(16, 256, WeightDistribution::default(), &mut rng);
            QuantMatrix::from_f32(16, 256, &f, 8).data
        };
        let runs = vec![3i8; 4096];
        let uni: Vec<i8> = (0..4096).map(|_| rng.range_i64(-127, 127) as i8).collect();
        for (name, data) in [
            ("gaussian", gauss),
            ("runs", runs),
            ("uniform", uni),
            ("empty", Vec::new()),
        ] {
            let c = compress_codes(&data, 4);
            assert!(
                c.payload_bytes <= c.raw_bytes,
                "{name}: payload {} > raw {}",
                c.payload_bytes,
                c.raw_bytes
            );
            assert_eq!(c.scale_bytes, 16);
            assert!(c.ratio().is_finite());
        }
    }

    #[test]
    fn gaussian_codes_entropy_compress_strictly() {
        let mut rng = Rng::new(76);
        let f = synthesize_floats(64, 512, WeightDistribution::default(), &mut rng);
        let m = QuantMatrix::from_f32(64, 512, &f, 8);
        let c = compress_codes(&m.data, 1);
        // Clipped-Gaussian 8-bit codes carry well under 8 bits/code of
        // entropy — the compressed streaming claim of ROADMAP item 4.
        assert!(
            c.total_bytes() < c.raw_bytes,
            "total {} must beat raw {}",
            c.total_bytes(),
            c.raw_bytes
        );
        assert!(c.entropy_bytes <= c.rle_bytes, "entropy path should win on Gaussian codes");
    }

    #[test]
    fn constant_stream_prefers_run_length() {
        let c = compress_codes(&vec![-5i8; 10_000], 1);
        assert_eq!(c.rle_bytes, 2 * (10_000u64).div_ceil(255));
        assert!(c.payload_bytes == c.rle_bytes && c.rle_bytes < c.entropy_bytes.max(1));
    }

    #[test]
    fn regime_effective_group_resolves_sentinels() {
        assert!(QuantRegime::per_tensor().is_per_tensor());
        assert_eq!(QuantRegime::per_tensor().effective_group(512), 512);
        assert_eq!(QuantRegime::grouped(64).effective_group(512), 64);
        assert_eq!(QuantRegime::grouped(1024).effective_group(512), 512);
        assert_eq!(QuantRegime::per_tensor().effective_group(0), 1);
        assert!(QuantRegime::grouped(8).with_compressed(true).compressed);
    }
}
