//! Symmetric fixed-point quantization and the value-locality statistics
//! that computation reuse exploits (paper §III.a–b).
//!
//! All AxLLM experiments quantize weights to **signed 8-bit fixed point**
//! (`i8` in `[-127, 127]`; −128 is excluded so that a value and its
//! negation always fold onto the same Result-Cache slot — paper §V
//! "Simulation setup": *"we maintain a 128-element reuse cache (instead of
//! 256) and map each value and its negative to the same cell"*).

pub mod group;
pub mod stats;

pub use group::{compress_codes, CompressedCodes, GroupQuantMatrix, QuantRegime};
pub use stats::{chunk_unique_counts, LocalityStats};

/// Number of distinct folded values with sign-folding 8-bit quantization.
pub const RC_ENTRIES_8BIT: usize = 128;

/// Quantization parameters for one tensor (symmetric: zero-point = 0).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    /// Dequantized value = scale * q.
    pub scale: f32,
    /// Bit width (≤ 8; experiments use 8).
    pub bits: u8,
}

impl QuantParams {
    /// Largest representable magnitude at this bit width (symmetric range
    /// `[-qmax, qmax]`, excluding the asymmetric minimum).
    pub fn qmax(&self) -> i32 {
        (1i32 << (self.bits - 1)) - 1
    }

    /// Derive parameters from data: scale chosen so max |x| maps to qmax.
    pub fn fit(data: &[f32], bits: u8) -> QuantParams {
        assert!((2..=8).contains(&bits), "bits must be in 2..=8");
        let amax = data.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let scale = if amax > 0.0 { amax / qmax } else { 1.0 };
        QuantParams { scale, bits }
    }

    /// Quantize one value (round-to-nearest, clamp to symmetric range).
    #[inline]
    pub fn quantize(&self, x: f32) -> i8 {
        let q = (x / self.scale).round();
        let qmax = self.qmax() as f32;
        q.clamp(-qmax, qmax) as i8
    }

    /// Dequantize one value.
    #[inline]
    pub fn dequantize(&self, q: i8) -> f32 {
        self.scale * q as f32
    }

    /// Quantize a slice.
    pub fn quantize_all(&self, xs: &[f32]) -> Vec<i8> {
        xs.iter().map(|&x| self.quantize(x)).collect()
    }

    /// Dequantize a slice.
    pub fn dequantize_all(&self, qs: &[i8]) -> Vec<f32> {
        qs.iter().map(|&q| self.dequantize(q)).collect()
    }
}

/// Fold a signed quantized value onto its Result-Cache index: `v` and `-v`
/// share a slot (paper §V), so the RC needs `2^(q-1)` entries.
///
/// Returns `(index, negated)`: `negated` tells the datapath to negate the
/// cached product on reuse.
#[inline]
pub fn fold(q: i8) -> (u8, bool) {
    debug_assert!(q != i8::MIN, "quantizer must exclude -128");
    if q < 0 {
        ((-q) as u8, true)
    } else {
        (q as u8, false)
    }
}

/// Inverse of [`fold`].
#[inline]
pub fn unfold(index: u8, negated: bool) -> i8 {
    if negated {
        -(index as i8)
    } else {
        index as i8
    }
}

/// Number of RC entries needed at a bit width with sign folding.
pub fn rc_entries(bits: u8) -> usize {
    1usize << (bits - 1)
}

/// A quantized matrix in row-major order, carrying its parameters.
///
/// This is the weight representation everything downstream consumes: the
/// cycle simulator streams its rows, the functional executor multiplies it,
/// and the AOT path exports it as uint8 RC indices.
#[derive(Clone, Debug)]
pub struct QuantMatrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Quantized codes, row-major.
    pub data: Vec<i8>,
    /// The grid the codes live on.
    pub params: QuantParams,
}

impl QuantMatrix {
    /// Quantize float data onto a grid fit to its own max magnitude.
    pub fn from_f32(rows: usize, cols: usize, data: &[f32], bits: u8) -> QuantMatrix {
        assert_eq!(data.len(), rows * cols);
        let params = QuantParams::fit(data, bits);
        QuantMatrix {
            rows,
            cols,
            data: params.quantize_all(data),
            params,
        }
    }

    /// Build directly from quantized values (tests, synthetic models).
    pub fn from_q(rows: usize, cols: usize, data: Vec<i8>, params: QuantParams) -> QuantMatrix {
        assert_eq!(data.len(), rows * cols);
        assert!(
            data.iter().all(|&q| q != i8::MIN),
            "-128 excluded by the symmetric quantizer"
        );
        QuantMatrix {
            rows,
            cols,
            data,
            params,
        }
    }

    /// Borrow row `r` of the quantized codes.
    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// One quantized code at (row, col).
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i8 {
        self.data[r * self.cols + c]
    }

    /// Dequantize the whole matrix (row-major f32).
    pub fn dequantize(&self) -> Vec<f32> {
        self.params.dequantize_all(&self.data)
    }

    /// Export as folded RC indices + sign bits (the "weights as pointers
    /// into the RC" representation of paper §III.b).
    pub fn to_rc_indices(&self) -> (Vec<u8>, Vec<bool>) {
        let mut idx = Vec::with_capacity(self.data.len());
        let mut neg = Vec::with_capacity(self.data.len());
        for &q in &self.data {
            let (i, n) = fold(q);
            idx.push(i);
            neg.push(n);
        }
        (idx, neg)
    }

    /// Export as unsigned byte offsets `q + 127` in `[0, 254]` — the
    /// representation the Pallas kernel's 255-entry product table uses.
    pub fn to_u8_offset(&self) -> Vec<u8> {
        self.data.iter().map(|&q| (q as i16 + 127) as u8).collect()
    }

    /// Pack into the 4-codes-per-word layout the tiled kernels consume.
    pub fn packed(&self) -> PackedQuantMatrix {
        PackedQuantMatrix::from_quant(self)
    }

    /// Concatenate another matrix on the column axis (same row count).
    /// This is the paper's Fig. 5 W∥A trick for LoRA reuse sharing.
    pub fn concat_cols(&self, other: &QuantMatrix) -> QuantMatrix {
        assert_eq!(self.rows, other.rows, "W and A must share row count");
        let cols = self.cols + other.cols;
        let mut data = Vec::with_capacity(self.rows * cols);
        for r in 0..self.rows {
            data.extend_from_slice(self.row(r));
            data.extend_from_slice(other.row(r));
        }
        QuantMatrix {
            rows: self.rows,
            cols,
            data,
            // Reuse requires only equal *quantized codes*; the combined
            // matrix keeps W's params (A is re-coded onto W's grid by the
            // model builder before concatenation).
            params: self.params,
        }
    }
}

/// Number of weight codes packed into one `u32` word of a
/// [`PackedQuantMatrix`].
pub const PACK_WIDTH: usize = 4;

/// A [`QuantMatrix`] re-laid-out as packed unsigned byte offsets: four
/// codes per `u32` word, little-end first (code `c` of a word sits at bit
/// `8 * c`). Each byte holds `q + 127` in `[0, 255]` — exactly the index
/// the 256-entry product table of
/// [`reuse_matmul_packed`](crate::exec::reuse_matmul_packed) consumes, so
/// the hot loop extracts codes with shifts and masks instead of a signed
/// add per element.
///
/// Rows are padded to a whole number of words; padding bytes carry offset
/// 127 (code 0) and are never visited by the kernels (tile loops are
/// bounded by [`PackedQuantMatrix::cols`], not by the word grid).
#[derive(Clone, Debug)]
pub struct PackedQuantMatrix {
    /// Row count (same as the source matrix).
    pub rows: usize,
    /// Logical column count (same as the source matrix; excludes padding).
    pub cols: usize,
    /// Words per row: `ceil(cols / PACK_WIDTH)`.
    pub words_per_row: usize,
    /// Packed offset codes, row-major over the word grid.
    pub words: Vec<u32>,
    /// The quantization grid the codes live on.
    pub params: QuantParams,
}

impl PackedQuantMatrix {
    /// Pack a quantized matrix (4 offset codes per `u32` word).
    pub fn from_quant(m: &QuantMatrix) -> PackedQuantMatrix {
        let words_per_row = m.cols.div_ceil(PACK_WIDTH);
        let mut words = Vec::with_capacity(m.rows * words_per_row);
        for r in 0..m.rows {
            let row = m.row(r);
            for chunk in row.chunks(PACK_WIDTH) {
                let mut word = 0u32;
                for (c, &q) in chunk.iter().enumerate() {
                    word |= ((q as i16 + 127) as u8 as u32) << (8 * c);
                }
                // Pad the tail with offset 127 (code 0).
                for c in chunk.len()..PACK_WIDTH {
                    word |= 127u32 << (8 * c);
                }
                words.push(word);
            }
        }
        PackedQuantMatrix {
            rows: m.rows,
            cols: m.cols,
            words_per_row,
            words,
            params: m.params,
        }
    }

    /// Borrow the packed words of row `r`.
    #[inline]
    pub fn row_words(&self, r: usize) -> &[u32] {
        &self.words[r * self.words_per_row..(r + 1) * self.words_per_row]
    }

    /// Unpack one offset code `q + 127` at (row, col). Test/diagnostic
    /// accessor — the kernels read whole words.
    #[inline]
    pub fn offset_at(&self, r: usize, c: usize) -> u8 {
        debug_assert!(c < self.cols);
        let word = self.words[r * self.words_per_row + c / PACK_WIDTH];
        (word >> (8 * (c % PACK_WIDTH))) as u8
    }

    /// Unpack the whole matrix back to signed codes (row-major, padding
    /// dropped). Test/diagnostic helper.
    pub fn unpack(&self) -> Vec<i8> {
        let mut out = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.push((self.offset_at(r, c) as i16 - 127) as i8);
            }
        }
        out
    }
}

/// Finite cap on reported SNR, dB. A lossless round trip has zero noise
/// and a true SNR of +∞, but the report/bench emitters require every
/// metric to stay finite (the PR 5 NaN/inf hygiene sweep), so exact
/// reconstructions report this ceiling instead — far above any value an
/// 8-bit quantizer can reach on real data (~50 dB).
pub const SNR_CAP_DB: f64 = 300.0;

/// Finite SNR in dB from accumulated signal/noise power: `0.0` when the
/// signal is empty or all-zero, [`SNR_CAP_DB`] when the noise is exactly
/// zero, the capped ratio otherwise. Shared by [`quant_snr_db`] and
/// [`GroupQuantMatrix::snr_db`] so both report the same edge-case
/// semantics (regression-tested below).
pub fn snr_db_from_power(sig: f64, noise: f64) -> f64 {
    if sig == 0.0 {
        0.0
    } else if noise == 0.0 {
        SNR_CAP_DB
    } else {
        (10.0 * (sig / noise).log10()).min(SNR_CAP_DB)
    }
}

/// Quantization error metrics (used to check the "<1% accuracy impact"
/// premise on synthetic activations). Always finite: empty and all-zero
/// inputs report 0 dB, lossless round trips report [`SNR_CAP_DB`].
pub fn quant_snr_db(original: &[f32], params: &QuantParams) -> f64 {
    let mut sig = 0.0f64;
    let mut noise = 0.0f64;
    for &x in original {
        let q = params.dequantize(params.quantize(x));
        sig += (x as f64) * (x as f64);
        let e = (x - q) as f64;
        noise += e * e;
    }
    snr_db_from_power(sig, noise)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fit_covers_range_symmetric() {
        let data = [-2.0f32, -1.0, 0.0, 1.0, 2.0];
        let p = QuantParams::fit(&data, 8);
        assert_eq!(p.quantize(2.0), 127);
        assert_eq!(p.quantize(-2.0), -127);
        assert_eq!(p.quantize(0.0), 0);
    }

    #[test]
    fn never_produces_i8_min() {
        let mut rng = Rng::new(1);
        let data: Vec<f32> = (0..10_000).map(|_| rng.normal() as f32 * 3.0).collect();
        let p = QuantParams::fit(&data, 8);
        for &x in &data {
            assert_ne!(p.quantize(x * 2.0), i8::MIN); // even out-of-range clamps to -127
        }
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let mut rng = Rng::new(2);
        let data: Vec<f32> = (0..1000).map(|_| rng.normal() as f32).collect();
        let p = QuantParams::fit(&data, 8);
        for &x in &data {
            let err = (x - p.dequantize(p.quantize(x))).abs();
            assert!(err <= p.scale / 2.0 + 1e-6, "err {err} scale {}", p.scale);
        }
    }

    #[test]
    fn fold_unfold_involutive() {
        for q in -127i8..=127 {
            let (i, n) = fold(q);
            assert!(i <= 127);
            assert_eq!(unfold(i, n), q);
        }
    }

    #[test]
    fn fold_maps_negatives_to_same_slot() {
        for q in 1i8..=127 {
            assert_eq!(fold(q).0, fold(-q).0);
            assert!(fold(-q).1);
            assert!(!fold(q).1);
        }
    }

    #[test]
    fn rc_entries_by_bits() {
        assert_eq!(rc_entries(8), 128);
        assert_eq!(rc_entries(4), 8);
        assert_eq!(RC_ENTRIES_8BIT, 128);
    }

    #[test]
    fn matrix_row_access_and_indices() {
        let params = QuantParams { scale: 0.5, bits: 8 };
        let m = QuantMatrix::from_q(2, 3, vec![1, -1, 2, 3, -3, 0], params);
        assert_eq!(m.row(0), &[1, -1, 2]);
        assert_eq!(m.get(1, 1), -3);
        let (idx, neg) = m.to_rc_indices();
        assert_eq!(idx, vec![1, 1, 2, 3, 3, 0]);
        assert_eq!(neg, vec![false, true, false, false, true, false]);
    }

    #[test]
    fn u8_offset_range() {
        let params = QuantParams { scale: 1.0, bits: 8 };
        let m = QuantMatrix::from_q(1, 3, vec![-127, 0, 127], params);
        assert_eq!(m.to_u8_offset(), vec![0, 127, 254]);
    }

    #[test]
    fn concat_cols_layout() {
        let params = QuantParams { scale: 1.0, bits: 8 };
        let w = QuantMatrix::from_q(2, 2, vec![1, 2, 3, 4], params);
        let a = QuantMatrix::from_q(2, 1, vec![9, 8], params);
        let c = w.concat_cols(&a);
        assert_eq!(c.cols, 3);
        assert_eq!(c.row(0), &[1, 2, 9]);
        assert_eq!(c.row(1), &[3, 4, 8]);
    }

    #[test]
    fn packed_roundtrips_codes_and_pads_tail() {
        let params = QuantParams { scale: 0.5, bits: 8 };
        // cols = 5 is ragged: one full word plus a 1-byte tail per row.
        let m = QuantMatrix::from_q(2, 5, vec![1, -1, 127, -127, 0, 3, -3, 0, 9, -9], params);
        let p = m.packed();
        assert_eq!(p.words_per_row, 2);
        assert_eq!(p.words.len(), 4);
        assert_eq!(p.unpack(), m.data);
        for r in 0..2 {
            for c in 0..5 {
                assert_eq!(p.offset_at(r, c), (m.get(r, c) as i16 + 127) as u8);
            }
        }
        // Padding bytes carry offset 127 (code 0).
        for r in 0..2 {
            let tail = p.row_words(r)[1];
            assert_eq!((tail >> 16) as u8, 127);
            assert_eq!((tail >> 24) as u8, 127);
        }
    }

    #[test]
    fn packed_handles_empty_and_aligned_shapes() {
        let params = QuantParams { scale: 1.0, bits: 8 };
        let empty = QuantMatrix::from_q(3, 0, vec![], params);
        let p = empty.packed();
        assert_eq!(p.words_per_row, 0);
        assert!(p.words.is_empty());
        assert!(p.unpack().is_empty());

        let aligned = QuantMatrix::from_q(1, 8, vec![1, 2, 3, 4, -1, -2, -3, -4], params);
        let pa = aligned.packed();
        assert_eq!(pa.words_per_row, 2);
        assert_eq!(pa.unpack(), aligned.data);
    }

    #[test]
    fn snr_reasonable_for_8bit_gaussian() {
        let mut rng = Rng::new(3);
        let data: Vec<f32> = (0..20_000).map(|_| rng.normal() as f32).collect();
        let p = QuantParams::fit(&data, 8);
        let snr = quant_snr_db(&data, &p);
        // 8-bit on ±4σ-ish data: comfortably above 30 dB.
        assert!(snr > 30.0, "snr {snr}");
    }

    #[test]
    fn lower_bits_lower_snr() {
        let mut rng = Rng::new(4);
        let data: Vec<f32> = (0..20_000).map(|_| rng.normal() as f32).collect();
        let p8 = QuantParams::fit(&data, 8);
        let p4 = QuantParams::fit(&data, 4);
        assert!(quant_snr_db(&data, &p8) > quant_snr_db(&data, &p4) + 10.0);
    }

    #[test]
    fn snr_edge_cases_stay_finite() {
        // Regression (ROADMAP item 4 / PR 5 hygiene): quant_snr_db used
        // to return +∞ for zero-noise inputs, which poisons every JSON
        // emitter downstream. Empty input → 0 dB; all-zero input (zero
        // signal AND zero noise) → 0 dB; exactly representable input
        // (zero noise, nonzero signal) → the finite cap.
        let p = QuantParams { scale: 1.0, bits: 8 };
        assert_eq!(quant_snr_db(&[], &p), 0.0);
        assert_eq!(quant_snr_db(&[0.0; 64], &p), 0.0);
        let exact = quant_snr_db(&[1.0, -3.0, 64.0], &p);
        assert_eq!(exact, SNR_CAP_DB);
        let noisy = quant_snr_db(&[0.5, 1.25, -0.3], &p);
        assert!(noisy.is_finite() && noisy < SNR_CAP_DB);
    }
}
