//! Value-locality statistics over quantized weight matrices.
//!
//! The computation-reuse opportunity (paper §III.a) is a pure function of
//! how many *distinct folded values* appear per row chunk: within a chunk
//! of `C` weights holding `U` unique folded values, `C − U` multiplications
//! are reusable, so the structural reuse rate is `1 − U/C`. These helpers
//! measure exactly that, independent of any timing model, and feed Fig. 8.

use super::{fold, QuantMatrix};

/// Locality statistics for one matrix at a given chunk (buffer) size.
#[derive(Clone, Debug, Default)]
pub struct LocalityStats {
    /// Total weight elements scanned.
    pub elements: u64,
    /// Total unique folded values across all (row, chunk) pairs — i.e. the
    /// number of multiplications an ideal reuse datapath must perform.
    pub unique: u64,
    /// Histogram of unique-count per chunk (index = unique count).
    pub unique_hist: Vec<u64>,
    /// Chunk size used.
    pub chunk: usize,
}

impl LocalityStats {
    /// Structural reuse rate: fraction of multiplications served by reuse.
    pub fn reuse_rate(&self) -> f64 {
        if self.elements == 0 {
            0.0
        } else {
            1.0 - self.unique as f64 / self.elements as f64
        }
    }

    /// Mean unique folded values per chunk.
    pub fn mean_unique(&self) -> f64 {
        let chunks: u64 = self.unique_hist.iter().sum();
        if chunks == 0 {
            0.0
        } else {
            self.unique as f64 / chunks as f64
        }
    }
}

/// Count unique folded values per `chunk`-sized piece of each row.
///
/// `chunk` mirrors the W_buff size limit (§IV "Buffer size management"):
/// the RC persists only while one input element's row chunk streams through
/// a lane, so reuse cannot cross chunk boundaries.
pub fn measure_locality(m: &QuantMatrix, chunk: usize) -> LocalityStats {
    assert!(chunk > 0);
    let mut stats = LocalityStats {
        elements: 0,
        unique: 0,
        unique_hist: vec![0; chunk.min(129) + 1],
        chunk,
    };
    // 128 possible folded values → fixed-size seen-marker with epoch trick
    // (no clearing between chunks).
    let mut seen = [0u32; 128];
    let mut epoch = 0u32;
    for r in 0..m.rows {
        let row = m.row(r);
        for piece in row.chunks(chunk) {
            epoch += 1;
            let mut unique = 0u64;
            for &q in piece {
                let (idx, _) = fold(q);
                if seen[idx as usize] != epoch {
                    seen[idx as usize] = epoch;
                    unique += 1;
                }
            }
            stats.elements += piece.len() as u64;
            stats.unique += unique;
            let h = (unique as usize).min(stats.unique_hist.len() - 1);
            stats.unique_hist[h] += 1;
        }
    }
    stats
}

/// Unique counts per chunk for a single row (used by the LoRA A∩W study
/// and by tests).
pub fn chunk_unique_counts(row: &[i8], chunk: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut seen = [false; 128];
    for piece in row.chunks(chunk) {
        seen.fill(false);
        let mut u = 0;
        for &q in piece {
            let (idx, _) = fold(q);
            if !seen[idx as usize] {
                seen[idx as usize] = true;
                u += 1;
            }
        }
        out.push(u);
    }
    out
}

/// Fraction of elements of `a_row` whose folded value also appears in the
/// matching `w_row` (paper §V: "an average of 90% of the elements of each
/// row of the adaptor matrix A repeats in the corresponding row in W").
pub fn overlap_fraction(w_row: &[i8], a_row: &[i8]) -> f64 {
    if a_row.is_empty() {
        return 0.0;
    }
    let mut in_w = [false; 128];
    for &q in w_row {
        in_w[fold(q).0 as usize] = true;
    }
    let hits = a_row.iter().filter(|&&q| in_w[fold(q).0 as usize]).count();
    hits as f64 / a_row.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantParams;
    use crate::util::rng::Rng;

    fn q(rows: usize, cols: usize, data: Vec<i8>) -> QuantMatrix {
        QuantMatrix::from_q(rows, cols, data, QuantParams { scale: 1.0, bits: 8 })
    }

    #[test]
    fn all_same_value_maximal_reuse() {
        let m = q(1, 100, vec![5; 100]);
        let s = measure_locality(&m, 100);
        assert_eq!(s.unique, 1);
        assert!((s.reuse_rate() - 0.99).abs() < 1e-9);
    }

    #[test]
    fn all_distinct_no_reuse() {
        let data: Vec<i8> = (0..100).map(|i| i as i8).collect();
        let m = q(1, 100, data);
        let s = measure_locality(&m, 100);
        assert_eq!(s.unique, 100);
        assert_eq!(s.reuse_rate(), 0.0);
    }

    #[test]
    fn sign_folding_counts_negatives_as_reuse() {
        let m = q(1, 4, vec![7, -7, 7, -7]);
        let s = measure_locality(&m, 4);
        assert_eq!(s.unique, 1);
    }

    #[test]
    fn chunking_resets_reuse() {
        // Same 4 values in each chunk of 4 → unique=4 per chunk.
        let m = q(1, 8, vec![1, 2, 3, 4, 1, 2, 3, 4]);
        let full = measure_locality(&m, 8);
        let halves = measure_locality(&m, 4);
        assert_eq!(full.unique, 4);
        assert_eq!(halves.unique, 8);
        assert!(full.reuse_rate() > halves.reuse_rate());
    }

    #[test]
    fn unique_cannot_exceed_128_or_chunk() {
        let mut rng = Rng::new(9);
        let data: Vec<i8> = (0..4096)
            .map(|_| rng.range_i64(-127, 127) as i8)
            .collect();
        let m = q(4, 1024, data);
        for &chunk in &[64usize, 512, 1024] {
            let s = measure_locality(&m, chunk);
            assert!(s.mean_unique() <= 128.0_f64.min(chunk as f64));
        }
    }

    #[test]
    fn reuse_grows_with_chunk_size_uniform_values() {
        let mut rng = Rng::new(10);
        let data: Vec<i8> = (0..8192)
            .map(|_| rng.range_i64(-127, 127) as i8)
            .collect();
        let m = q(2, 4096, data);
        let r64 = measure_locality(&m, 64).reuse_rate();
        let r512 = measure_locality(&m, 512).reuse_rate();
        let r4096 = measure_locality(&m, 4096).reuse_rate();
        assert!(r64 < r512 && r512 < r4096, "{r64} {r512} {r4096}");
        // Llama-style full row over 128 folded values: ≥ 1 - 128/4096.
        assert!(r4096 >= 1.0 - 128.0 / 4096.0 - 1e-9);
    }

    #[test]
    fn chunk_unique_counts_per_piece() {
        let row = [1i8, 1, 2, 2, 3, 3, 4, 4];
        assert_eq!(chunk_unique_counts(&row, 4), vec![2, 2]);
        assert_eq!(chunk_unique_counts(&row, 8), vec![4]);
    }

    #[test]
    fn overlap_fraction_bounds_and_folding() {
        let w = [1i8, 2, 3];
        assert_eq!(overlap_fraction(&w, &[-1, -2, -3]), 1.0);
        assert_eq!(overlap_fraction(&w, &[4, 5, 6]), 0.0);
        assert!((overlap_fraction(&w, &[1, 9]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn locality_edge_cases_stay_finite() {
        // Regression (ROADMAP item 4 / PR 5 hygiene): the division
        // guards in reuse_rate/mean_unique must hold on empty matrices,
        // all-zero codes, and single-chunk rows — the three shapes the
        // quant-sweep emitters can feed them.
        let empty = measure_locality(&q(0, 0, vec![]), 64);
        assert_eq!(empty.reuse_rate(), 0.0);
        assert_eq!(empty.mean_unique(), 0.0);
        assert!(empty.reuse_rate().is_finite() && empty.mean_unique().is_finite());

        let zeros = measure_locality(&q(2, 32, vec![0; 64]), 64);
        assert!((zeros.reuse_rate() - (1.0 - 2.0 / 64.0)).abs() < 1e-12);
        assert_eq!(zeros.mean_unique(), 1.0);

        let single = measure_locality(&q(1, 5, vec![1, 2, 3, 2, 1]), 64);
        assert!(single.reuse_rate().is_finite());
        assert_eq!(single.mean_unique(), 3.0);
    }

    #[test]
    fn hist_sums_to_chunk_count() {
        let mut rng = Rng::new(11);
        let data: Vec<i8> = (0..2048)
            .map(|_| rng.range_i64(-50, 50) as i8)
            .collect();
        let m = q(4, 512, data);
        let s = measure_locality(&m, 128);
        let chunks: u64 = s.unique_hist.iter().sum();
        assert_eq!(chunks, (4 * 512 / 128) as u64);
    }
}
