//! Minimal TOML-subset parser for configuration files (the `toml` crate is
//! not available offline).
//!
//! Supported grammar — deliberately the subset our configs use:
//!
//! ```toml
//! # comment
//! [section]
//! int_key = 64
//! float_key = 1.5
//! bool_key = true
//! string_key = "hello"
//! array_key = [1, 2, 3]
//! ```
//!
//! Keys before any `[section]` land in the `""` (root) section. Duplicate
//! keys overwrite (last wins), matching typical layered-config usage.

use std::collections::BTreeMap;

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// Boolean literal.
    Bool(bool),
    /// Quoted string.
    Str(String),
    /// Bracketed array of values.
    Array(Vec<Value>),
}

impl Value {
    /// The integer value, if this is an `Int`.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// The integer value as a usize, if non-negative.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_int().and_then(|i| usize::try_from(i).ok())
    }
    /// The numeric value as f64 (`Float` or widened `Int`).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// The boolean value, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The string value, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The array elements, if this is an `Array`.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: section name → key → value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Doc {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    /// Look up `key` in `section` (use `""` for the root section).
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|m| m.get(key))
    }

    /// Iterate over (section name, key→value map) pairs.
    pub fn sections(&self) -> impl Iterator<Item = (&str, &BTreeMap<String, Value>)> {
        self.sections.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Insert or overwrite one key in a section.
    pub fn set(&mut self, section: &str, key: &str, value: Value) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value);
    }

    /// Serialize back to the supported TOML subset.
    pub fn to_string(&self) -> String {
        fn render_value(v: &Value) -> String {
            match v {
                Value::Int(i) => i.to_string(),
                Value::Float(f) => {
                    if f.fract() == 0.0 && f.abs() < 1e15 {
                        format!("{f:.1}")
                    } else {
                        format!("{f}")
                    }
                }
                Value::Bool(b) => b.to_string(),
                Value::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
                Value::Array(xs) => format!(
                    "[{}]",
                    xs.iter().map(render_value).collect::<Vec<_>>().join(", ")
                ),
            }
        }
        let mut out = String::new();
        for (section, kv) in &self.sections {
            if !section.is_empty() {
                out.push_str(&format!("[{section}]\n"));
            }
            for (k, v) in kv {
                out.push_str(&format!("{k} = {}\n", render_value(v)));
            }
            out.push('\n');
        }
        out
    }
}

/// Parse error with line number.
#[derive(Debug)]
pub struct ParseError {
    /// 1-based line the error was detected on.
    pub line: usize,
    /// Human-readable description.
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "tomlite parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn parse_scalar(s: &str, line: usize) -> Result<Value, ParseError> {
    let s = s.trim();
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            return Err(ParseError {
                line,
                msg: format!("unterminated string: {s}"),
            });
        };
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    other => {
                        return Err(ParseError {
                            line,
                            msg: format!("bad escape: \\{other:?}"),
                        })
                    }
                }
            } else {
                out.push(c);
            }
        }
        return Ok(Value::Str(out));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ParseError {
        line,
        msg: format!("unrecognized value: {s}"),
    })
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            return Err(ParseError {
                line,
                msg: "unterminated array".into(),
            });
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        // No nested arrays / no strings-with-commas in our subset.
        let items = inner
            .split(',')
            .map(|item| parse_scalar(item, line))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    parse_scalar(s, line)
}

/// Parse a document.
pub fn parse(text: &str) -> Result<Doc, ParseError> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        // Strip comments (naive: '#' outside strings; our configs do not
        // embed '#' in strings).
        let line = match raw.find('#') {
            Some(p) if !raw[..p].contains('"') => &raw[..p],
            _ => raw,
        };
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let Some(name) = rest.strip_suffix(']') else {
                return Err(ParseError {
                    line: line_no,
                    msg: format!("bad section header: {line}"),
                });
            };
            section = name.trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(ParseError {
                line: line_no,
                msg: format!("expected key = value: {line}"),
            });
        };
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(ParseError {
                line: line_no,
                msg: "empty key".into(),
            });
        }
        let value = parse_value(&line[eq + 1..], line_no)?;
        doc.set(&section, key, value);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
# accelerator config
top = "root"
[accelerator]
lanes = 64
freq_ghz = 1.0
reuse = true
slices = [1, 2, 4, 8]
name = "axllm-64"
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_str(), Some("root"));
        assert_eq!(doc.get("accelerator", "lanes").unwrap().as_int(), Some(64));
        assert_eq!(
            doc.get("accelerator", "freq_ghz").unwrap().as_float(),
            Some(1.0)
        );
        assert_eq!(doc.get("accelerator", "reuse").unwrap().as_bool(), Some(true));
        assert_eq!(
            doc.get("accelerator", "slices").unwrap().as_array().unwrap(),
            &[Value::Int(1), Value::Int(2), Value::Int(4), Value::Int(8)]
        );
        assert_eq!(
            doc.get("accelerator", "name").unwrap().as_str(),
            Some("axllm-64")
        );
    }

    #[test]
    fn roundtrip() {
        let mut doc = Doc::default();
        doc.set("a", "x", Value::Int(3));
        doc.set("a", "y", Value::Str("hi \"there\"".into()));
        doc.set("", "z", Value::Float(2.5));
        let text = doc.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back, doc);
    }

    #[test]
    fn error_carries_line() {
        let err = parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn string_escapes() {
        let doc = parse(r#"s = "a\"b\\c\nd""#).unwrap();
        assert_eq!(doc.get("", "s").unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn int_vs_float() {
        let doc = parse("i = 5\nf = 5.0").unwrap();
        assert_eq!(doc.get("", "i").unwrap(), &Value::Int(5));
        assert_eq!(doc.get("", "f").unwrap(), &Value::Float(5.0));
        // ints coerce to float on demand
        assert_eq!(doc.get("", "i").unwrap().as_float(), Some(5.0));
    }

    #[test]
    fn empty_array() {
        let doc = parse("a = []").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_array().unwrap().len(), 0);
    }
}
