//! Micro-benchmark harness (criterion is not available offline).
//!
//! `harness = false` bench targets call [`Bench::new`] and register
//! closures; each is warmed up, then timed over enough iterations to pass a
//! minimum measurement window, and median/mean/σ are reported in a
//! criterion-like format. Results can also be dumped as CSV or as the
//! machine-readable JSON perf log (see `rust/DESIGN.md` §Perf).

use std::time::{Duration, Instant};

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Benchmark name.
    pub name: String,
    /// Total timed iterations.
    pub iters: u64,
    /// Median per-iteration time.
    pub median: Duration,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Standard deviation of the per-sample times.
    pub stddev: Duration,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl Measurement {
    /// Elements per second, if a throughput denominator was set. `None`
    /// also when the median rounded to zero — a 0 ns measurement has no
    /// finite rate, and emitting ∞ would poison the JSON/CSV logs.
    pub fn throughput(&self) -> Option<f64> {
        let s = self.median.as_secs_f64();
        if s <= 0.0 {
            return None;
        }
        self.elements.map(|e| e as f64 / s)
    }
}

/// Benchmark runner configuration.
pub struct Bench {
    warmup: Duration,
    window: Duration,
    min_iters: u64,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

fn human(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

impl Bench {
    /// New runner (honors `AXLLM_BENCH_FAST=1` for short CI windows).
    pub fn new() -> Self {
        // AXLLM_BENCH_FAST=1 shrinks the window so `cargo bench` in CI
        // finishes quickly; default window targets stable medians.
        let fast = std::env::var("AXLLM_BENCH_FAST").is_ok();
        Bench {
            warmup: if fast {
                Duration::from_millis(50)
            } else {
                Duration::from_millis(300)
            },
            window: if fast {
                Duration::from_millis(200)
            } else {
                Duration::from_secs(1)
            },
            min_iters: 5,
            results: Vec::new(),
        }
    }

    /// Time `f`, reporting elements/sec using `elements` per iteration.
    pub fn run_throughput<F: FnMut()>(&mut self, name: &str, elements: u64, f: F) {
        self.run_inner(name, Some(elements), f);
    }

    /// Time `f`.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) {
        self.run_inner(name, None, f);
    }

    fn run_inner<F: FnMut()>(&mut self, name: &str, elements: Option<u64>, mut f: F) {
        // Warmup and single-iteration estimate.
        let wstart = Instant::now();
        let mut witers = 0u64;
        while wstart.elapsed() < self.warmup || witers == 0 {
            f();
            witers += 1;
        }
        let est = wstart.elapsed() / witers.max(1) as u32;

        // Choose a per-sample iteration count so each sample is ≥ ~1ms.
        let per_sample = if est.as_nanos() == 0 {
            1000
        } else {
            (1_000_000 / est.as_nanos().max(1)).max(1) as u64
        };
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        let mut total_iters = 0u64;
        while (start.elapsed() < self.window || samples.len() < self.min_iters as usize)
            && samples.len() < 5000
        {
            let s = Instant::now();
            for _ in 0..per_sample {
                f();
            }
            samples.push(s.elapsed() / per_sample as u32);
            total_iters += per_sample;
        }
        samples.sort_unstable();
        let median = samples[samples.len() / 2];
        let mean_ns =
            samples.iter().map(|d| d.as_nanos() as f64).sum::<f64>() / samples.len() as f64;
        let var = samples
            .iter()
            .map(|d| {
                let x = d.as_nanos() as f64 - mean_ns;
                x * x
            })
            .sum::<f64>()
            / samples.len() as f64;
        let m = Measurement {
            name: name.to_string(),
            iters: total_iters,
            median,
            mean: Duration::from_nanos(mean_ns as u64),
            stddev: Duration::from_nanos(var.sqrt() as u64),
            elements,
        };
        let mut line = format!(
            "{:<44} time: [{} ± {}]  ({} iters)",
            m.name,
            human(m.median),
            human(m.stddev),
            m.iters
        );
        if let Some(t) = m.throughput() {
            line.push_str(&format!("  thrpt: {:.2} Melem/s", t / 1e6));
        }
        println!("{line}");
        self.results.push(m);
    }

    /// All measurements so far.
    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Machine-readable JSON dump:
    /// `{"meta": {"threads": N}, "results": [{"name": …, …}]}` where each
    /// result's `ns_per_op` is the median. `meta.threads` records this
    /// machine's `available_parallelism` so perf trajectories across
    /// machines are interpretable (thread-parallel benches scale with
    /// it). Measurements registered through [`Bench::run_throughput`]
    /// also carry `throughput_eps` (elements/second — requests/second
    /// when the element is a request). Non-finite floats are emitted as
    /// JSON `null`: `inf`/`NaN` are not valid JSON tokens and one
    /// degenerate measurement must never make the whole perf log
    /// unparseable. Bench targets write this next to their stdout report
    /// (e.g. `BENCH_sim_hot_loop.json`, `BENCH_live_serve.json`) so
    /// successive PRs have a perf trajectory to compare against.
    pub fn json(&self) -> String {
        let esc = |s: &str| s.replace('\\', "\\\\").replace('"', "\\\"");
        let threads = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1);
        let mut out = format!("{{\n\"meta\": {{\"threads\": {threads}}},\n\"results\": [\n");
        for (i, m) in self.results.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "  {{\"name\": \"{}\", \"iterations\": {}, \"ns_per_op\": {}, \"mean_ns\": {}, \"stddev_ns\": {}",
                esc(&m.name),
                m.iters,
                m.median.as_nanos(),
                m.mean.as_nanos(),
                m.stddev.as_nanos()
            ));
            if let Some(t) = m.throughput() {
                out.push_str(&format!(", \"throughput_eps\": {}", json_f64(t)));
            }
            out.push('}');
        }
        out.push_str("\n]\n}\n");
        out
    }

    /// CSV dump (name,median_ns,mean_ns,stddev_ns,throughput_eps).
    /// Non-finite rates emit an empty cell, matching the JSON guard.
    pub fn csv(&self) -> String {
        let mut out = String::from("name,median_ns,mean_ns,stddev_ns,throughput_eps\n");
        for m in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                m.name,
                m.median.as_nanos(),
                m.mean.as_nanos(),
                m.stddev.as_nanos(),
                m.throughput()
                    .filter(|t| t.is_finite())
                    .map(|t| format!("{t:.1}"))
                    .unwrap_or_default()
            ));
        }
        out
    }
}

/// Render a float for the JSON log: fixed-point when finite, `null`
/// otherwise (bare `inf`/`NaN` would make the file invalid JSON).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("AXLLM_BENCH_FAST", "1");
        let mut b = Bench::new();
        let mut acc = 0u64;
        b.run("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(b.results().len(), 1);
        assert!(b.results()[0].iters > 0);
    }

    #[test]
    fn throughput_reported() {
        std::env::set_var("AXLLM_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.run_throughput("sum1k", 1000, || {
            let s: u64 = black_box((0..1000u64).sum());
            black_box(s);
        });
        assert!(b.results()[0].throughput().unwrap() > 0.0);
        assert!(b.csv().lines().count() == 2);
    }

    #[test]
    fn json_lists_every_measurement() {
        std::env::set_var("AXLLM_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.run("alpha \"quoted\"", || {
            black_box(1u64 + 1);
        });
        b.run("beta", || {
            black_box(2u64 + 2);
        });
        let j = b.json();
        assert!(j.starts_with('{') && j.trim_end().ends_with('}'));
        // The meta header records the machine's thread count so perf
        // trajectories across machines are interpretable.
        assert!(j.contains("\"meta\""));
        assert!(j.contains("\"threads\": "));
        assert!(j.contains("\"results\": ["));
        assert_eq!(j.matches("\"name\"").count(), 2);
        assert!(j.contains("\\\"quoted\\\""));
        assert!(j.contains("\"ns_per_op\""));
        assert!(j.contains("\"iterations\""));
        // Plain `run` measurements carry no throughput field…
        assert!(!j.contains("throughput_eps"));
    }

    #[test]
    fn json_carries_throughput_for_throughput_runs() {
        std::env::set_var("AXLLM_BENCH_FAST", "1");
        let mut b = Bench::new();
        b.run_throughput("tp", 64, || {
            black_box(1u64 + 1);
        });
        assert!(b.json().contains("\"throughput_eps\""));
    }

    #[test]
    fn json_never_emits_non_finite_floats() {
        // Regression: a 0 ns median (degenerate measurement) used to
        // serialize `"throughput_eps": inf` — invalid JSON that made the
        // whole BENCH_*.json unparseable. The rate is withheld for
        // zero-time medians, and any non-finite float that does reach
        // the emitter renders as JSON null.
        let mut b = Bench::new();
        b.results.push(Measurement {
            name: "degenerate".into(),
            iters: 1,
            median: Duration::ZERO,
            mean: Duration::ZERO,
            stddev: Duration::ZERO,
            elements: Some(1_000),
        });
        assert_eq!(b.results[0].throughput(), None, "0 ns has no finite rate");
        let j = b.json();
        assert!(!j.contains("inf") && !j.contains("NaN"), "{j}");
        let c = b.csv();
        assert!(!c.contains("inf") && !c.contains("NaN"), "{c}");
        // And the null path itself is well-formed JSON.
        assert_eq!(json_f64(f64::INFINITY), "null");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(2.5), "2.500");
    }

    #[test]
    fn human_format_units() {
        assert!(human(Duration::from_nanos(500)).ends_with("ns"));
        assert!(human(Duration::from_micros(50)).ends_with("µs"));
        assert!(human(Duration::from_millis(50)).ends_with("ms"));
        assert!(human(Duration::from_secs(2)).ends_with(" s"));
    }
}
