//! Deterministic pseudo-random number generation.
//!
//! `Rng` is xoshiro256** seeded via SplitMix64 — fast, high-quality, and
//! fully reproducible from a `u64` seed, which every simulator run, test,
//! and benchmark in this crate threads through explicitly.

/// xoshiro256** PRNG with distribution helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform i64 in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as i64
    }

    /// Standard normal sample (Box–Muller; one value per call, no caching
    /// so the stream stays simple to reason about).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Laplace(0, b) sample — heavier tails than normal; used for the
    /// weight-distribution sensitivity study (DESIGN.md §8 S1).
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.f64() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Student-t sample with `nu` degrees of freedom (ratio-of-normals via
    /// chi-square from summed squared normals; exact for integer nu).
    pub fn student_t(&mut self, nu: u32) -> f64 {
        debug_assert!(nu >= 1);
        let z = self.normal();
        let mut chi2 = 0.0;
        for _ in 0..nu {
            let n = self.normal();
            chi2 += n * n;
        }
        z / (chi2 / nu as f64).sqrt()
    }

    /// Exponential(rate) sample — inter-arrival times for request traces.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a random element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Fork an independent generator (for parallel workers): hashes the
    /// current state with the stream id so forks do not overlap.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sum2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sum2 += x * x;
        }
        let mean = sum / n as f64;
        let var = sum2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn laplace_variance_is_2b2() {
        let mut r = Rng::new(13);
        let b = 0.7;
        let n = 200_000;
        let mut sum2 = 0.0;
        for _ in 0..n {
            let x = r.laplace(b);
            sum2 += x * x;
        }
        let var = sum2 / n as f64;
        assert!((var - 2.0 * b * b).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(17);
        let rate = 4.0;
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.exponential(rate);
        }
        let mean = sum / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(29);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn forks_are_independent() {
        let mut base = Rng::new(5);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert!(same < 2);
    }
}
