//! Scoped parallel-map over OS threads (rayon is not available offline).
//!
//! The simulator sweeps are embarrassingly parallel across matrices/layers;
//! [`par_map`] splits the items over `min(n_items, available_parallelism)`
//! scoped threads and preserves input order in the output.

/// Parallel map preserving order. Falls back to sequential for tiny inputs.
pub fn par_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n = items.len();
    if n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(n);
    if threads <= 1 {
        return items.into_iter().map(f).collect();
    }

    // Pre-size the output with None slots, hand each thread a strided set
    // of indices so long items spread across workers.
    let mut slots: Vec<Option<U>> = (0..n).map(|_| None).collect();
    let items: Vec<Option<T>> = items.into_iter().map(Some).collect();
    let items = std::sync::Mutex::new(items);
    let next = std::sync::atomic::AtomicUsize::new(0);

    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..threads {
            let items = &items;
            let next = &next;
            let f = &f;
            handles.push(scope.spawn(move || {
                let mut out: Vec<(usize, U)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let item = items.lock().unwrap()[i].take().unwrap();
                    out.push((i, f(item)));
                }
                out
            }));
        }
        for h in handles {
            for (i, u) in h.join().unwrap() {
                slots[i] = Some(u);
            }
        }
    });
    slots.into_iter().map(|s| s.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<u64> = (0..100).collect();
        let ys = par_map(xs, |x| x * 2);
        assert_eq!(ys, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn handles_empty_and_single() {
        assert_eq!(par_map(Vec::<u32>::new(), |x| x), Vec::<u32>::new());
        assert_eq!(par_map(vec![7], |x| x + 1), vec![8]);
    }

    #[test]
    fn actually_runs_work() {
        // Heavier payloads so multiple threads engage; result must match
        // the sequential reference exactly.
        let xs: Vec<u64> = (0..32).collect();
        let ys = par_map(xs.clone(), |x| (0..10_000).fold(x, |a, b| a.wrapping_add(b)));
        let expect: Vec<u64> = xs
            .into_iter()
            .map(|x| (0..10_000).fold(x, |a, b| a.wrapping_add(b)))
            .collect();
        assert_eq!(ys, expect);
    }
}
