//! Scoped parallel-map over OS threads (rayon is not available offline).
//!
//! The simulator sweeps are embarrassingly parallel across matrices/layers;
//! [`par_map`] splits the items over `min(n_items, available_parallelism)`
//! scoped threads and preserves input order in the output.

/// Parallel map preserving order. Falls back to sequential for tiny inputs.
///
/// Each worker receives an **owned strided bucket** of items up front
/// (item `i` goes to worker `i % threads`, so long items spread across
/// workers) — no shared queue, no locks, zero contention on the hot path.
/// Workers return `(index, result)` pairs and the join scatters them back
/// into input order, so the output is deterministic regardless of worker
/// scheduling.
pub fn par_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n = items.len();
    if n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(n);
    if threads <= 1 {
        return items.into_iter().map(f).collect();
    }

    // Deal the items into owned per-worker buckets, round-robin.
    let mut buckets: Vec<Vec<(usize, T)>> = (0..threads).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        buckets[i % threads].push((i, item));
    }

    let mut slots: Vec<Option<U>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for bucket in buckets {
            let f = &f;
            handles.push(scope.spawn(move || {
                bucket
                    .into_iter()
                    .map(|(i, item)| (i, f(item)))
                    .collect::<Vec<(usize, U)>>()
            }));
        }
        for h in handles {
            for (i, u) in h.join().unwrap() {
                slots[i] = Some(u);
            }
        }
    });
    slots.into_iter().map(|s| s.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let xs: Vec<u64> = (0..100).collect();
        let ys = par_map(xs, |x| x * 2);
        assert_eq!(ys, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn handles_empty_and_single() {
        assert_eq!(par_map(Vec::<u32>::new(), |x| x), Vec::<u32>::new());
        assert_eq!(par_map(vec![7], |x| x + 1), vec![8]);
    }

    #[test]
    fn actually_runs_work() {
        // Heavier payloads so multiple threads engage; result must match
        // the sequential reference exactly.
        let xs: Vec<u64> = (0..32).collect();
        let ys = par_map(xs.clone(), |x| (0..10_000).fold(x, |a, b| a.wrapping_add(b)));
        let expect: Vec<u64> = xs
            .into_iter()
            .map(|x| (0..10_000).fold(x, |a, b| a.wrapping_add(b)))
            .collect();
        assert_eq!(ys, expect);
    }
}
