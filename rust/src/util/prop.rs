//! Minimal property-based testing runner (proptest is not available
//! offline).
//!
//! A property is a closure from a seeded [`Rng`](super::rng::Rng) to
//! `Result<(), String>`. The runner executes `cases` random cases; on
//! failure it retries the failing seed with progressively "smaller"
//! generation budgets if the property opts into sizing, and always reports
//! the failing seed so the case can be replayed deterministically:
//!
//! ```text
//! property failed (seed=0xDEADBEEF case=17): <message>
//! ```

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: u32,
    /// Base seed the per-case seeds derive from.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        // Honor AXLLM_PROP_CASES for heavier local runs.
        let cases = std::env::var("AXLLM_PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Config {
            cases,
            seed: 0xAD5EED,
        }
    }
}

/// Run a property over `cfg.cases` seeded cases. Panics (test-failure) on
/// the first violated case, printing the replay seed.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed (seed={case_seed:#x} case={case}): {msg}");
        }
    }
}

/// Convenience: run with default config.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check(name, Config::default(), prop);
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

/// Equality assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default("add-commutes", |rng| {
            let a = rng.range_i64(-1000, 1000);
            let b = rng.range_i64(-1000, 1000);
            prop_assert_eq!(a + b, b + a);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check(
            "always-fails",
            Config { cases: 3, seed: 1 },
            |_rng| Err("boom".to_string()),
        );
    }

    #[test]
    fn cases_are_deterministic() {
        let mut first: Vec<i64> = Vec::new();
        check(
            "record",
            Config { cases: 5, seed: 99 },
            |rng| {
                first.push(rng.range_i64(0, 1_000_000));
                Ok(())
            },
        );
        let mut second: Vec<i64> = Vec::new();
        check(
            "record",
            Config { cases: 5, seed: 99 },
            |rng| {
                second.push(rng.range_i64(0, 1_000_000));
                Ok(())
            },
        );
        assert_eq!(first, second);
    }
}
