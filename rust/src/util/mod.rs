//! In-crate substrates: deterministic RNG, micro-benchmark harness,
//! property-test runner, TOML-subset parser, ASCII/CSV table printer,
//! and a small scoped thread pool.
//!
//! These exist because the build environment is fully offline: only the
//! `xla` crate closure is vendored, so `rand`, `criterion`, `proptest`,
//! `serde`/`toml` and `rayon` are reimplemented here at the scale this
//! project needs.

pub mod bench;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod table;
pub mod tomlite;
