//! ASCII table rendering and CSV emission for the report generators.
//!
//! Every figure/table reproduction prints through [`Table`] so output is
//! uniform across the CLI, benches, and examples, and every report can be
//! exported as CSV for external plotting.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    /// Left-align the column.
    Left,
    /// Right-align the column (default for numeric columns).
    Right,
}

/// A simple text table with a title, headers, and rows.
#[derive(Clone, Debug)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New empty table with a title and column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            rows: Vec::new(),
        }
    }

    /// Override column alignments (defaults: first left, rest right).
    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Cell accessor (row, col) — used by tests to assert on report values.
    pub fn cell(&self, r: usize, c: usize) -> &str {
        &self.rows[r][c]
    }

    /// Column headers.
    pub fn headers(&self) -> &[String] {
        &self.headers
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for ((c, w), a) in cells.iter().zip(&widths).zip(&self.aligns) {
                match a {
                    Align::Left => line.push_str(&format!("| {c:<w$} ")),
                    Align::Right => line.push_str(&format!("| {c:>w$} ")),
                }
            }
            line + "|"
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as CSV (headers + rows; minimal quoting).
    pub fn csv(&self) -> String {
        fn esc(s: &str) -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        }
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a f64 with fixed decimals, trimming to a compact form.
pub fn fnum(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Format a ratio as a percentage string.
pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Format a large count with thousands separators.
pub fn count(x: u64) -> String {
    let s = x.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["model", "rate"]);
        t.row(vec!["distilbert".into(), "87.0%".into()]);
        t.row(vec!["bert".into(), "90.1%".into()]);
        let r = t.render();
        assert!(r.contains("| model      |"));
        assert!(r.contains("| 87.0% |"));
        assert_eq!(t.n_rows(), 2);
        assert_eq!(t.cell(1, 1), "90.1%");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_quotes_commas() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["x,y".into(), "2".into()]);
        assert_eq!(t.csv(), "a,b\n\"x,y\",2\n");
    }

    #[test]
    fn count_separators() {
        assert_eq!(count(159_340_000), "159,340,000");
        assert_eq!(count(5), "5");
        assert_eq!(count(1234), "1,234");
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.8712), "87.1%");
    }
}
