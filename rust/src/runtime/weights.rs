//! Parser for `artifacts/tiny_weights.bin` — the quantized weights the AOT
//! model baked in, exported so the Rust functional path can run the same
//! model and cross-check the PJRT executable.
//!
//! Layout (little endian), written by `python/compile/model.py
//! export_weights_bin`:
//!
//! ```text
//! u32 magic "AXLM", u32 version, u32 n_layers, u32 d_model, u32 n_heads,
//! u32 d_ff, u32 n_classes
//! repeated matrix records (per layer: wq wk wv wo ff1 ff2; then head):
//!   u32 rows, u32 cols, f32 scale, rows*cols i8 codes
//! ```

use crate::model::{LayerWeights, MatKind};
use crate::quant::{QuantMatrix, QuantParams};
use anyhow::{anyhow, Context, Result};
use std::path::Path;

const MAGIC: u32 = 0x41584C4D;

/// The tiny model's weights, layer by layer, plus the classifier head.
#[derive(Clone, Debug)]
pub struct TinyWeights {
    /// Layer count.
    pub n_layers: usize,
    /// Hidden size.
    pub d_model: usize,
    /// Attention head count.
    pub n_heads: usize,
    /// Feed-forward inner dimension.
    pub d_ff: usize,
    /// Classifier classes of the logit head.
    pub n_classes: usize,
    /// Per-layer quantized matrices.
    pub layers: Vec<LayerWeights>,
    /// The classifier/logit head matrix.
    pub head: QuantMatrix,
}

struct Reader<'a> {
    data: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn u32(&mut self) -> Result<u32> {
        let b = self
            .data
            .get(self.pos..self.pos + 4)
            .ok_or_else(|| anyhow!("truncated weights file at {}", self.pos))?;
        self.pos += 4;
        Ok(u32::from_le_bytes(b.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn codes(&mut self, n: usize) -> Result<&'a [u8]> {
        let b = self
            .data
            .get(self.pos..self.pos + n)
            .ok_or_else(|| anyhow!("truncated codes at {}", self.pos))?;
        self.pos += n;
        Ok(b)
    }

    fn matrix(&mut self) -> Result<QuantMatrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let scale = self.f32()?;
        let raw = self.codes(rows * cols)?;
        let data: Vec<i8> = raw.iter().map(|&b| b as i8).collect();
        Ok(QuantMatrix::from_q(
            rows,
            cols,
            data,
            QuantParams { scale, bits: 8 },
        ))
    }
}

/// Parse the weight binary.
pub fn load_weights_bin(path: &Path) -> Result<TinyWeights> {
    let data =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let mut r = Reader {
        data: &data,
        pos: 0,
    };
    let magic = r.u32()?;
    if magic != MAGIC {
        return Err(anyhow!("bad magic {magic:#x} (expected AXLM)"));
    }
    let version = r.u32()?;
    if version != 1 {
        return Err(anyhow!("unsupported weights version {version}"));
    }
    let n_layers = r.u32()? as usize;
    let d_model = r.u32()? as usize;
    let n_heads = r.u32()? as usize;
    let d_ff = r.u32()? as usize;
    let n_classes = r.u32()? as usize;

    let mut layers = Vec::with_capacity(n_layers);
    for layer_idx in 0..n_layers {
        let mut mats = Vec::with_capacity(6);
        for kind in MatKind::ALL {
            let m = r.matrix()?;
            mats.push((kind, m));
        }
        layers.push(LayerWeights::new(layer_idx, mats, None, None));
    }
    let head = r.matrix()?;
    if r.pos != data.len() {
        return Err(anyhow!(
            "trailing bytes in weights file: {} of {}",
            data.len() - r.pos,
            data.len()
        ));
    }
    Ok(TinyWeights {
        n_layers,
        d_model,
        n_heads,
        d_ff,
        n_classes,
        layers,
        head,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_sample(path: &Path) {
        // 1 layer of 2×2 matrices (shapes unrealistic but format-valid)
        // + 2×1 head.
        let mut bytes = Vec::new();
        for v in [MAGIC, 1u32, 1, 2, 1, 2, 1] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for _ in 0..6 {
            bytes.extend_from_slice(&2u32.to_le_bytes());
            bytes.extend_from_slice(&2u32.to_le_bytes());
            bytes.extend_from_slice(&0.5f32.to_le_bytes());
            bytes.extend_from_slice(&[1i8 as u8, (-2i8) as u8, 3, 0]);
        }
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        bytes.extend_from_slice(&[(-1i8) as u8, 5]);
        std::fs::write(path, bytes).unwrap();
    }

    #[test]
    fn parses_valid_file() {
        let dir = std::env::temp_dir().join("axllm_weights_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        write_sample(&path);
        let w = load_weights_bin(&path).unwrap();
        assert_eq!(w.n_layers, 1);
        assert_eq!(w.layers[0].mats.len(), 6);
        let wq = w.layers[0].get(MatKind::Wq);
        assert_eq!(wq.data, vec![1, -2, 3, 0]);
        assert_eq!(wq.params.scale, 0.5);
        assert_eq!(w.head.data, vec![-1, 5]);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("axllm_weights_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        std::fs::write(&path, [0u8; 64]).unwrap();
        let err = load_weights_bin(&path).unwrap_err();
        assert!(err.to_string().contains("bad magic"));
    }

    #[test]
    fn rejects_truncation() {
        let dir = std::env::temp_dir().join("axllm_weights_trunc");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("w.bin");
        write_sample(&path);
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..data.len() - 1]).unwrap();
        assert!(load_weights_bin(&path).is_err());
    }
}
