//! Artifact registry: the manifest written by `python/compile/aot.py` and
//! the set of compiled executables the coordinator serves from.

use crate::model::MatKind;
use crate::runtime::weights::TinyWeights;
use crate::runtime::{Executable, Runtime};
use crate::util::tomlite;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.toml`.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Compiled batch dimension of the tiny model.
    pub batch: usize,
    /// Compiled sequence length.
    pub seq: usize,
    /// Hidden size.
    pub d_model: usize,
    /// Layer count.
    pub n_layers: usize,
    /// Attention head count.
    pub n_heads: usize,
    /// Feed-forward inner dimension.
    pub d_ff: usize,
    /// Classifier classes of the logit head.
    pub n_classes: usize,
    /// Weight-synthesis seed the artifacts were exported with.
    pub seed: u64,
    /// Row counts of the standalone reuse-kernel artifacts.
    pub kernel_shapes: Vec<usize>,
}

impl Manifest {
    /// Parse `manifest.toml` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.toml");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = tomlite::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let geti = |key: &str| -> Result<usize> {
            doc.get("tiny", key)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| anyhow!("manifest missing [tiny].{key}"))
        };
        let kernel_shapes = doc
            .get("kernels", "shapes")
            .and_then(|v| v.as_array())
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default();
        Ok(Manifest {
            batch: geti("batch")?,
            seq: geti("seq")?,
            d_model: geti("d_model")?,
            n_layers: geti("n_layers")?,
            n_heads: geti("n_heads")?,
            d_ff: geti("d_ff")?,
            n_classes: geti("n_classes")?,
            seed: geti("seed")? as u64,
            kernel_shapes,
        })
    }

    /// The rust-side model configuration matching the artifact.
    pub fn model_config(&self) -> crate::config::ModelConfig {
        crate::config::ModelConfig {
            name: "Tiny (artifact)".into(),
            d_model: self.d_model,
            n_layers: self.n_layers,
            n_heads: self.n_heads,
            d_ff: self.d_ff,
            lora: None,
        }
    }
}

/// All compiled artifacts the serving path uses, plus the persistent
/// weight-parameter literals.
///
/// Weight codes travel as **runtime parameters** (not baked constants —
/// xla_extension 0.5.1 mis-constant-folds the gather over baked weight
/// tensors); the canonical order is per layer `wq wk wv wo ff1 ff2`, then
/// the classifier head.
pub struct ArtifactSet {
    /// Directory the set was loaded from.
    pub dir: PathBuf,
    /// Parsed manifest.
    pub manifest: Manifest,
    /// The compiled end-to-end tiny model.
    pub tiny_model: Executable,
    /// The compiled single-layer executable.
    pub tiny_layer: Executable,
    /// Standalone reuse kernels, keyed by row count.
    pub kernels: Vec<(usize, Executable)>,
    /// The exported quantized weights the artifacts execute with.
    pub weights: TinyWeights,
    /// Weight-offset literals for `tiny_model`, canonical order.
    model_weight_lits: Vec<xla::Literal>,
    /// Layer-0 weight-offset literals for `tiny_layer`.
    layer_weight_lits: Vec<xla::Literal>,
}

fn offset_literal(m: &crate::quant::QuantMatrix) -> Result<xla::Literal> {
    let off: Vec<i32> = m.data.iter().map(|&q| q as i32 + 127).collect();
    Ok(xla::Literal::vec1(&off).reshape(&[m.rows as i64, m.cols as i64])?)
}

impl ArtifactSet {
    /// Load + compile everything under `dir` (built by `make artifacts`).
    pub fn load(rt: &Runtime, dir: &Path) -> Result<ArtifactSet> {
        let manifest = Manifest::load(dir)?;
        let tiny_model = rt.load_hlo_text(&dir.join("tiny_model.hlo.txt"))?;
        let tiny_layer = rt.load_hlo_text(&dir.join("tiny_layer.hlo.txt"))?;
        let mut kernels = Vec::new();
        for &r in &manifest.kernel_shapes {
            let exe = rt.load_hlo_text(&dir.join(format!("reuse_matmul_{r}.hlo.txt")))?;
            kernels.push((r, exe));
        }
        let weights = crate::runtime::weights::load_weights_bin(&dir.join("tiny_weights.bin"))?;
        let mut model_weight_lits = Vec::new();
        for layer in &weights.layers {
            for kind in MatKind::ALL {
                model_weight_lits.push(offset_literal(layer.get(kind))?);
            }
        }
        model_weight_lits.push(offset_literal(&weights.head)?);
        let mut layer_weight_lits = Vec::new();
        for kind in MatKind::ALL {
            layer_weight_lits.push(offset_literal(weights.layers[0].get(kind))?);
        }
        Ok(ArtifactSet {
            dir: dir.to_path_buf(),
            manifest,
            tiny_model,
            tiny_layer,
            kernels,
            weights,
            model_weight_lits,
            layer_weight_lits,
        })
    }

    /// Run the end-to-end tiny classifier: `x` is `[batch, seq, d_model]`
    /// row-major f32; returns `[batch, n_classes]` logits.
    pub fn run_tiny_model(&self, x: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        anyhow::ensure!(x.len() == m.batch * m.seq * m.d_model, "bad input size");
        let x_lit = xla::Literal::vec1(x).reshape(&[
            m.batch as i64,
            m.seq as i64,
            m.d_model as i64,
        ])?;
        let mut args: Vec<&xla::Literal> = vec![&x_lit];
        args.extend(self.model_weight_lits.iter());
        let out = self.tiny_model.run_refs(&args)?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Run one transformer layer (layer 0): `x` is `[seq, d_model]` f32.
    pub fn run_tiny_layer(&self, x: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        anyhow::ensure!(x.len() == m.seq * m.d_model, "bad input size");
        let x_lit = xla::Literal::vec1(x).reshape(&[m.seq as i64, m.d_model as i64])?;
        let mut args: Vec<&xla::Literal> = vec![&x_lit];
        args.extend(self.layer_weight_lits.iter());
        let out = self.tiny_layer.run_refs(&args)?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Default artifact directory: `$AXLLM_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("AXLLM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_generated_format() {
        let dir = std::env::temp_dir().join("axllm_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.toml"),
            "[tiny]\nbatch = 4\nseq = 32\nd_model = 128\nn_layers = 2\nn_heads = 4\nd_ff = 256\nn_classes = 4\nseed = 20250710\n\n[kernels]\nshapes = [128, 768]\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.batch, 4);
        assert_eq!(m.d_model, 128);
        assert_eq!(m.kernel_shapes, vec![128, 768]);
        let cfg = m.model_config();
        assert_eq!(cfg.n_layers, 2);
        assert_eq!(cfg.d_head(), 32);
    }

    #[test]
    fn manifest_missing_key_errors() {
        let dir = std::env::temp_dir().join("axllm_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.toml"), "[tiny]\nbatch = 4\n").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
