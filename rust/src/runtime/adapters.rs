//! Serving-side adapter provisioning: turn a deployment's `--adapters N
//! --adapter-rank R` request into a concrete [`AdapterRegistry`], and
//! count the requests a backend had to serve base-only.
//!
//! Real deployments would load trained A/B pairs from an adapter store
//! next to the compiled artifacts; offline, this module synthesizes them
//! deterministically against the served base matrix — on the base
//! matrix's quantization grid, exactly as a deployment would re-code
//! adaptors when preparing them for this accelerator
//! (see [`crate::model::lora`] for the grid-sharing argument).

use crate::config::LoraConfig;
use crate::model::{AdapterRegistry, WeightDistribution};
use crate::quant::QuantMatrix;
use std::sync::atomic::{AtomicU64, Ordering};

/// Provision a registry of `count` rank-`rank` adaptors for the given
/// base matrix. Deterministic in `seed`, so every replica of a serving
/// pool (and every backend sharing the seed) holds byte-identical
/// tenants. Rank is clamped to ≥ 1 by
/// [`AdapterRegistry::synthesize`] itself.
pub fn provision(
    base: &QuantMatrix,
    count: usize,
    rank: usize,
    seed: u64,
) -> AdapterRegistry {
    AdapterRegistry::synthesize(
        base,
        count,
        LoraConfig {
            rank,
            ..LoraConfig::default()
        },
        WeightDistribution::default(),
        seed ^ 0xADA9_7E55,
    )
}

/// Thread-safe count of requests a backend served without a capability
/// the deployment asked for: an adapter it could not honor (unknown
/// adapter id, or a runtime with no adapter support at all, like the
/// fixed-shape PJRT artifacts), or — the same honest-fallback pattern,
/// counted by a second instance — tensor-parallel sharding a
/// shard-unaware runtime served monolithically
/// ([`crate::backend::ExecutionBackend::shard_misses`]).
#[derive(Debug, Default)]
pub struct AdapterMisses(AtomicU64);

impl AdapterMisses {
    /// Fresh counter at zero.
    pub fn new() -> AdapterMisses {
        AdapterMisses::default()
    }

    /// Record one base-only fallback.
    pub fn record(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Total base-only fallbacks recorded so far.
    pub fn count(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synthesize_matrix;
    use crate::util::rng::Rng;

    #[test]
    fn provision_is_deterministic_and_grid_shared() {
        let mut rng = Rng::new(3);
        let base = synthesize_matrix(32, 8, WeightDistribution::default(), &mut rng);
        let a = provision(&base, 2, 4, 42);
        let b = provision(&base, 2, 4, 42);
        assert_eq!(a.len(), 2);
        assert_eq!(a.rank(), 4);
        assert_eq!(a.get(1).unwrap().a.data, b.get(1).unwrap().a.data);
        assert_eq!(a.get(0).unwrap().a.params, base.params);
        // Rank 0 is clamped to a well-formed rank-1 pair.
        assert_eq!(provision(&base, 1, 0, 1).rank(), 1);
    }

    #[test]
    fn misses_accumulate() {
        let m = AdapterMisses::new();
        assert_eq!(m.count(), 0);
        m.record();
        m.record();
        assert_eq!(m.count(), 2);
    }
}
