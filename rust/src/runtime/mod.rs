//! PJRT runtime: load the AOT-compiled HLO-text artifacts and execute them
//! from Rust — the request path never touches Python.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All artifacts were lowered with
//! `return_tuple=True`, so results unwrap with `to_tuple1`.

pub mod adapters;
pub mod artifacts;
pub mod weights;

pub use adapters::AdapterMisses;
pub use artifacts::{ArtifactSet, Manifest};
pub use weights::{load_weights_bin, TinyWeights};

use anyhow::{Context, Result};
use std::path::Path;

/// A compiled executable bound to the shared PJRT CPU client.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

/// The PJRT runtime: one CPU client, many compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of PJRT devices the client sees.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let path_str = path
            .to_str()
            .context("artifact path is not valid UTF-8")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }
}

impl Executable {
    /// File-stem name of the compiled artifact.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with literal inputs; returns the unwrapped 1-tuple result.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<xla::Literal> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Execute with borrowed literal inputs (avoids cloning persistent
    /// weight literals on the hot path).
    pub fn run_refs(&self, inputs: &[&xla::Literal]) -> Result<xla::Literal> {
        let result = self
            .exe
            .execute::<&xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // Artifacts are lowered with return_tuple=True.
        Ok(lit.to_tuple1()?)
    }

    /// Convenience: f32 tensors in (row-major data + dims) → f32 vec out.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<f32>> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| Ok(xla::Literal::vec1(data).reshape(dims)?))
            .collect::<Result<Vec<_>>>()?;
        let out = self.run(&lits)?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Convenience: i32 tensors in → i32 vec out.
    pub fn run_i32(&self, inputs: &[(&[i32], &[i64])]) -> Result<Vec<i32>> {
        let lits = inputs
            .iter()
            .map(|(data, dims)| Ok(xla::Literal::vec1(data).reshape(dims)?))
            .collect::<Result<Vec<_>>>()?;
        let out = self.run(&lits)?;
        Ok(out.to_vec::<i32>()?)
    }
}

// PJRT-dependent tests live in rust/tests/integration_runtime.rs so
// `cargo test --lib` stays independent of the artifacts directory.
