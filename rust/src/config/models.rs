//! The model zoo and benchmark suite of the paper's Table I.

/// LoRA adaptor hyper-parameters (paper §III.c "AxLLM support of LoRA").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LoraConfig {
    /// Low-rank dimension r of A (d×r) and B (r×d).
    pub rank: usize,
    /// Scaling α (kept for completeness; cycle counts are α-independent).
    pub alpha: f32,
}

impl Default for LoraConfig {
    fn default() -> Self {
        LoraConfig {
            rank: 16,
            alpha: 32.0,
        }
    }
}

/// Architectural description of one transformer model.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Human-readable model name (Table I row label).
    pub name: String,
    /// Hidden size (== rows/cols of the attention projection matrices, the
    /// "Weight Matrix Size" column of Table I).
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Attention head count.
    pub n_heads: usize,
    /// Feed-forward inner dimension.
    pub d_ff: usize,
    /// LoRA adaptor attached to Q/V projections, when fine-tuned.
    pub lora: Option<LoraConfig>,
}

impl ModelConfig {
    /// DistilBERT (Table I rows 1–2).
    pub fn distilbert() -> Self {
        ModelConfig {
            name: "DistilBERT".into(),
            d_model: 768,
            n_layers: 6,
            n_heads: 12,
            d_ff: 3072,
            lora: None,
        }
    }

    /// BERT Base Uncased (Table I rows 3–4).
    pub fn bert_base() -> Self {
        ModelConfig {
            name: "BERT Base Uncased".into(),
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            d_ff: 3072,
            lora: None,
        }
    }

    /// Large BERT (Table I row 5).
    pub fn bert_large() -> Self {
        ModelConfig {
            name: "Large BERT".into(),
            d_model: 1024,
            n_layers: 24,
            n_heads: 16,
            d_ff: 4096,
            lora: None,
        }
    }

    /// Llama 7B (Table I row 6).
    pub fn llama_7b() -> Self {
        ModelConfig {
            name: "Llama 7B".into(),
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            d_ff: 11008,
            lora: None,
        }
    }

    /// Llama 13B (Table I row 7).
    pub fn llama_13b() -> Self {
        ModelConfig {
            name: "Llama 13B".into(),
            d_model: 5120,
            n_layers: 40,
            n_heads: 40,
            d_ff: 13824,
            lora: None,
        }
    }

    /// A tiny configuration for the end-to-end PJRT driver and tests:
    /// small enough to AOT-compile and run on CPU in seconds.
    pub fn tiny() -> Self {
        ModelConfig {
            name: "Tiny".into(),
            d_model: 128,
            n_layers: 2,
            n_heads: 4,
            d_ff: 256,
            lora: None,
        }
    }

    /// Attach a LoRA adaptor (fine-tuned variant).
    pub fn with_lora(mut self, lora: LoraConfig) -> Self {
        self.name = format!("{} (fine-tuned)", self.name);
        self.lora = Some(lora);
        self
    }

    /// Per-head dimension.
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Approximate parameter count (embeddings excluded — the accelerator
    /// only runs matmuls).
    pub fn param_count(&self) -> u64 {
        let d = self.d_model as u64;
        let ff = self.d_ff as u64;
        // Q,K,V,O projections + 2 FFN matrices per layer.
        self.n_layers as u64 * (4 * d * d + 2 * d * ff)
    }

    /// MAC count of the matmuls for one token at a given context length
    /// (see `model::flops` for the full per-component breakdown).
    pub fn macs_per_token(&self, context: usize) -> u64 {
        let d = self.d_model as u64;
        let ff = self.d_ff as u64;
        let ctx = context as u64;
        self.n_layers as u64 * (4 * d * d + 2 * d * ff + 2 * ctx * d)
    }
}

/// Datasets of Table I, modeled as sequence-length profiles (substitution
/// S2 in DESIGN.md: reuse is weight-side; datasets set sequence lengths and
/// request mixes only).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dataset {
    /// AG News (news-topic classification, short texts).
    AgNews,
    /// Yelp Review Full (review classification, medium texts).
    YelpReviewFull,
    /// SQuAD (question answering, long contexts).
    Squad,
    /// IMDb (sentiment classification, long reviews).
    Imdb,
}

impl Dataset {
    /// Human-readable dataset name (Table I column).
    pub fn name(&self) -> &'static str {
        match self {
            Dataset::AgNews => "AG News",
            Dataset::YelpReviewFull => "Yelp Review Full",
            Dataset::Squad => "SQuAD",
            Dataset::Imdb => "IMDb",
        }
    }

    /// Mean token length of the dataset's examples (published corpus
    /// statistics, rounded).
    pub fn mean_len(&self) -> usize {
        match self {
            Dataset::AgNews => 48,
            Dataset::YelpReviewFull => 179,
            Dataset::Squad => 384,
            Dataset::Imdb => 256,
        }
    }

    /// Maximum sequence length used when tokenizing (BERT-style cap).
    pub fn max_len(&self) -> usize {
        match self {
            Dataset::AgNews => 128,
            Dataset::YelpReviewFull => 512,
            Dataset::Squad => 384,
            Dataset::Imdb => 512,
        }
    }

    /// Mean generated-output length for autoregressive decode workloads:
    /// label-like outputs for the classification corpora, longer spans
    /// for QA. (Synthetic calibration — the corpora publish no generation
    /// statistics; what matters downstream is the per-dataset *mix* of
    /// output lengths, which drives continuous-batching raggedness.)
    pub fn mean_gen_len(&self) -> usize {
        match self {
            Dataset::AgNews => 8,
            Dataset::YelpReviewFull => 24,
            Dataset::Squad => 48,
            Dataset::Imdb => 16,
        }
    }
}

/// One Table-I row: a model/dataset pair.
#[derive(Clone, Debug)]
pub struct Benchmark {
    /// Model variant of the benchmark row.
    pub model: ModelConfig,
    /// Dataset profile of the benchmark row.
    pub dataset: Dataset,
}

impl Benchmark {
    /// Short key for tables and CSVs.
    pub fn key(&self) -> String {
        format!("{} / {}", self.model.name, self.dataset.name())
    }

    /// The "Weight Matrix Size" column of Table I.
    pub fn weight_matrix(&self) -> (usize, usize) {
        (self.model.d_model, self.model.d_model)
    }
}

/// All seven Table-I benchmarks, in paper order.
pub fn table1_benchmarks() -> Vec<Benchmark> {
    vec![
        Benchmark {
            model: ModelConfig::distilbert(),
            dataset: Dataset::AgNews,
        },
        Benchmark {
            model: ModelConfig::distilbert().with_lora(LoraConfig::default()),
            dataset: Dataset::YelpReviewFull,
        },
        Benchmark {
            model: ModelConfig::bert_base(),
            dataset: Dataset::Squad,
        },
        Benchmark {
            model: ModelConfig::bert_base().with_lora(LoraConfig::default()),
            dataset: Dataset::Imdb,
        },
        Benchmark {
            model: ModelConfig::bert_large(),
            dataset: Dataset::Imdb,
        },
        Benchmark {
            model: ModelConfig::llama_7b(),
            dataset: Dataset::Imdb,
        },
        Benchmark {
            model: ModelConfig::llama_13b(),
            dataset: Dataset::Imdb,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let b = table1_benchmarks();
        assert_eq!(b.len(), 7);
        assert_eq!(b[0].weight_matrix(), (768, 768));
        assert_eq!(b[4].weight_matrix(), (1024, 1024));
        assert_eq!(b[5].weight_matrix(), (4096, 4096));
        assert_eq!(b[6].weight_matrix(), (5120, 5120));
        assert!(b[1].model.lora.is_some());
        assert!(b[3].model.lora.is_some());
        assert!(b[0].model.lora.is_none());
        assert_eq!(b[1].dataset, Dataset::YelpReviewFull);
        assert_eq!(b[3].dataset, Dataset::Imdb);
    }

    #[test]
    fn head_dims_divide() {
        for b in table1_benchmarks() {
            assert_eq!(b.model.d_model % b.model.n_heads, 0, "{}", b.model.name);
        }
    }

    #[test]
    fn llama7b_param_count_in_range() {
        // Matmul-only params of Llama-7B ≈ 6.5e9 (embeddings excluded).
        let p = ModelConfig::llama_7b().param_count() as f64;
        assert!((5.0e9..8.0e9).contains(&p), "{p}");
    }

    #[test]
    fn fine_tuned_naming() {
        let m = ModelConfig::distilbert().with_lora(LoraConfig::default());
        assert!(m.name.contains("fine-tuned"));
        assert_eq!(m.lora.unwrap().rank, 16);
    }

    #[test]
    fn macs_scale_with_context() {
        let m = ModelConfig::tiny();
        assert!(m.macs_per_token(256) > m.macs_per_token(16));
    }

    #[test]
    fn dataset_profiles() {
        assert!(Dataset::AgNews.mean_len() < Dataset::Imdb.mean_len());
        for d in [
            Dataset::AgNews,
            Dataset::YelpReviewFull,
            Dataset::Squad,
            Dataset::Imdb,
        ] {
            assert!(d.mean_len() <= d.max_len());
            assert!(d.mean_gen_len() >= 1);
            assert!(d.mean_gen_len() < d.max_len());
        }
        assert!(Dataset::Squad.mean_gen_len() > Dataset::AgNews.mean_gen_len());
    }
}
