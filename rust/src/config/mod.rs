//! Configuration: accelerator micro-architecture parameters and the model
//! zoo matching the paper's Table I, with TOML-subset load/save.

pub mod models;
pub mod profile;

pub use models::{table1_benchmarks, Benchmark, Dataset, LoraConfig, ModelConfig};
pub use profile::{BackendKind, ExecProfile};

use crate::util::tomlite::{self, Doc, Value};
use anyhow::{anyhow, Context};

/// Micro-architecture parameters of one AxLLM instance (paper §III.c–§IV).
///
/// Defaults reproduce the paper's evaluated configuration: *"AxLLM is
/// organized as a 64-lane architecture, each with 256-entry weight/output
/// buffers. In each lane, the buffers are arranged as four 64-entry slices
/// that are processed in parallel"* (§V), with 3-cycle multipliers and
/// 1-cycle buffer accesses from the 15nm RTL synthesis (§IV).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AcceleratorConfig {
    /// Parallel lanes (L). Lane i processes input element x[i].
    pub lanes: usize,
    /// W_buff / Out_buff entries per lane (whole-lane, pre-slicing).
    pub buffer_entries: usize,
    /// Number of buffer/RC slices per lane (P-way parallelism, §IV).
    pub slices: usize,
    /// Depth of each collision queue in front of RC/Out_buff slices.
    pub queue_depth: usize,
    /// Multiplier latency in cycles (RTL synthesis: 3).
    pub mult_latency: u32,
    /// Buffer / RC access latency in cycles (RTL synthesis: 1).
    pub buf_latency: u32,
    /// Column-round width bounding incomplete output cells (§IV: 512).
    pub round_cols: usize,
    /// Weight bit width (8 everywhere in the paper).
    pub weight_bits: u8,
    /// Clock frequency in GHz (for power = energy / time).
    pub freq_ghz: f64,
    /// When false, the reuse path is disabled → the Fig. 9 baseline
    /// ("the AxLLM architecture with just multipliers").
    pub reuse_enabled: bool,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        AcceleratorConfig {
            lanes: 64,
            buffer_entries: 256,
            slices: 4,
            queue_depth: 4,
            mult_latency: 3,
            buf_latency: 1,
            round_cols: 512,
            weight_bits: 8,
            freq_ghz: 1.0,
            reuse_enabled: true,
        }
    }
}

impl AcceleratorConfig {
    /// The paper's evaluated configuration (see type-level docs).
    pub fn paper() -> Self {
        Self::default()
    }

    /// The Fig. 9 normalization baseline: identical sizing, multipliers
    /// only (no Result Cache).
    pub fn baseline() -> Self {
        AcceleratorConfig {
            reuse_enabled: false,
            ..Self::default()
        }
    }

    /// Result-Cache entries implied by the bit width (sign-folded).
    pub fn rc_entries(&self) -> usize {
        crate::quant::rc_entries(self.weight_bits)
    }

    /// Entries per buffer slice.
    pub fn slice_entries(&self) -> usize {
        self.buffer_entries / self.slices
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> crate::Result<()> {
        if self.lanes == 0 {
            return Err(anyhow!("lanes must be > 0"));
        }
        if self.buffer_entries == 0 {
            return Err(anyhow!("buffer_entries must be > 0"));
        }
        if self.slices == 0 || self.buffer_entries % self.slices != 0 {
            return Err(anyhow!(
                "slices ({}) must divide buffer_entries ({})",
                self.slices,
                self.buffer_entries
            ));
        }
        if !(2..=8).contains(&self.weight_bits) {
            return Err(anyhow!("weight_bits must be in 2..=8"));
        }
        if self.mult_latency == 0 || self.buf_latency == 0 {
            return Err(anyhow!("latencies must be ≥ 1 cycle"));
        }
        if self.queue_depth == 0 {
            return Err(anyhow!("queue_depth must be ≥ 1"));
        }
        if self.round_cols == 0 {
            return Err(anyhow!("round_cols must be > 0"));
        }
        if self.freq_ghz <= 0.0 {
            return Err(anyhow!("freq_ghz must be > 0"));
        }
        Ok(())
    }

    /// Serialize into a `[accelerator]` TOML section.
    pub fn to_doc(&self, doc: &mut Doc) {
        let s = "accelerator";
        doc.set(s, "lanes", Value::Int(self.lanes as i64));
        doc.set(s, "buffer_entries", Value::Int(self.buffer_entries as i64));
        doc.set(s, "slices", Value::Int(self.slices as i64));
        doc.set(s, "queue_depth", Value::Int(self.queue_depth as i64));
        doc.set(s, "mult_latency", Value::Int(self.mult_latency as i64));
        doc.set(s, "buf_latency", Value::Int(self.buf_latency as i64));
        doc.set(s, "round_cols", Value::Int(self.round_cols as i64));
        doc.set(s, "weight_bits", Value::Int(self.weight_bits as i64));
        doc.set(s, "freq_ghz", Value::Float(self.freq_ghz));
        doc.set(s, "reuse_enabled", Value::Bool(self.reuse_enabled));
    }

    /// Read from a `[accelerator]` TOML section; missing keys keep their
    /// defaults so config files can be sparse overrides.
    pub fn from_doc(doc: &Doc) -> crate::Result<Self> {
        let mut c = Self::default();
        let s = "accelerator";
        let geti = |key: &str, default: usize| -> crate::Result<usize> {
            match doc.get(s, key) {
                None => Ok(default),
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| anyhow!("[accelerator].{key} must be a non-negative int")),
            }
        };
        c.lanes = geti("lanes", c.lanes)?;
        c.buffer_entries = geti("buffer_entries", c.buffer_entries)?;
        c.slices = geti("slices", c.slices)?;
        c.queue_depth = geti("queue_depth", c.queue_depth)?;
        c.mult_latency = geti("mult_latency", c.mult_latency as usize)? as u32;
        c.buf_latency = geti("buf_latency", c.buf_latency as usize)? as u32;
        c.round_cols = geti("round_cols", c.round_cols)?;
        c.weight_bits = geti("weight_bits", c.weight_bits as usize)? as u8;
        if let Some(v) = doc.get(s, "freq_ghz") {
            c.freq_ghz = v
                .as_float()
                .ok_or_else(|| anyhow!("[accelerator].freq_ghz must be a number"))?;
        }
        if let Some(v) = doc.get(s, "reuse_enabled") {
            c.reuse_enabled = v
                .as_bool()
                .ok_or_else(|| anyhow!("[accelerator].reuse_enabled must be a bool"))?;
        }
        c.validate()?;
        Ok(c)
    }

    /// Load from a TOML file.
    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let doc = tomlite::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_doc(&doc)
    }

    /// Save to a TOML file.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut doc = Doc::default();
        self.to_doc(&mut doc);
        std::fs::write(path, doc.to_string())
            .with_context(|| format!("writing config {}", path.display()))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_paper_config() {
        let c = AcceleratorConfig::paper();
        assert_eq!(c.lanes, 64);
        assert_eq!(c.buffer_entries, 256);
        assert_eq!(c.slices, 4);
        assert_eq!(c.slice_entries(), 64);
        assert_eq!(c.mult_latency, 3);
        assert_eq!(c.buf_latency, 1);
        assert_eq!(c.rc_entries(), 128);
        assert!(c.reuse_enabled);
        c.validate().unwrap();
    }

    #[test]
    fn baseline_disables_reuse_only() {
        let b = AcceleratorConfig::baseline();
        let p = AcceleratorConfig::paper();
        assert!(!b.reuse_enabled);
        assert_eq!(
            AcceleratorConfig {
                reuse_enabled: true,
                ..b
            },
            p
        );
    }

    #[test]
    fn validate_rejects_bad_slicing() {
        let c = AcceleratorConfig {
            slices: 3,
            buffer_entries: 256,
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_zero_fields() {
        for f in 0..5 {
            let mut c = AcceleratorConfig::default();
            match f {
                0 => c.lanes = 0,
                1 => c.queue_depth = 0,
                2 => c.mult_latency = 0,
                3 => c.round_cols = 0,
                _ => c.freq_ghz = 0.0,
            }
            assert!(c.validate().is_err(), "field {f} should fail");
        }
    }

    #[test]
    fn toml_roundtrip() {
        let c = AcceleratorConfig {
            lanes: 32,
            buffer_entries: 512,
            slices: 8,
            freq_ghz: 1.5,
            reuse_enabled: false,
            ..Default::default()
        };
        let mut doc = Doc::default();
        c.to_doc(&mut doc);
        let back = AcceleratorConfig::from_doc(&tomlite::parse(&doc.to_string()).unwrap()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn sparse_doc_keeps_defaults() {
        let doc = tomlite::parse("[accelerator]\nlanes = 16\n").unwrap();
        let c = AcceleratorConfig::from_doc(&doc).unwrap();
        assert_eq!(c.lanes, 16);
        assert_eq!(c.buffer_entries, 256);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("axllm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("acc.toml");
        let c = AcceleratorConfig::paper();
        c.save(&path).unwrap();
        assert_eq!(AcceleratorConfig::load(&path).unwrap(), c);
    }
}
