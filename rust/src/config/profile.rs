//! The unified execution profile: one plain-data value describing a full
//! execution configuration across every layer of the stack.
//!
//! Nine features in, each capability (shards, adapters, KV cache, quant
//! regime, packed kernels, chunking, SLO admission, handoff metering) was
//! configured through per-backend `with_*` builder chains plus a matching
//! `CostModel::with_*_regime` call, duplicated across `SimBackend`,
//! `FunctionalBackend`, `PjrtBackend` and ~8 construction match arms in
//! `main.rs`. [`ExecProfile`] collapses all of that into a single
//! enumerable, serializable struct: backends construct uniformly via
//! `ExecutionBackend::from_profile`, the cost plane composes via
//! `CostModel::from_profile` in one canonical order, and the CLI parses
//! flags (or a `--profile file.toml`) into one profile value. The payoff
//! is `report::map` / `axllm map`: because a configuration is now data, a
//! seeded grid of profiles can be swept mechanically (ROADMAP item 5).
//!
//! Invariant (pinned by `tests/prop_profile.rs`): a profile-built backend
//! is **bit-identical** — logits, `ExecStats`, and cost attribution — to
//! the equivalent legacy builder chain.

use crate::config::AcceleratorConfig;
use crate::quant::QuantRegime;
use crate::util::tomlite::{self, Doc, Value};
use anyhow::{anyhow, Context};

/// Which `ExecutionBackend` implementation a profile targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Analytic cost-model backend (`SimBackend`).
    Sim,
    /// Bit-exact quantized reference (`FunctionalBackend`).
    Functional,
    /// AOT artifact executor (`PjrtBackend`).
    Pjrt,
}

impl BackendKind {
    /// Stable lowercase name, matching the CLI `--backend` values and
    /// each backend's `ExecutionBackend::name()`.
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Sim => "sim",
            BackendKind::Functional => "functional",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// Parse a CLI / TOML backend name.
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "sim" => Some(BackendKind::Sim),
            "functional" => Some(BackendKind::Functional),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }
}

/// One complete execution configuration, as plain data.
///
/// Every field has a neutral default so profiles can be sparse
/// overrides; `0` is the "off / backend default" sentinel for the
/// optional capacities (`kv_blocks`, `seq_limit`, `chunk_tokens`) and
/// `0.0` for `handoff_bytes_per_token`.
///
/// The serving-tier fields (`chunk_tokens`, `slo`, `handoff_bytes_per_token`,
/// `paced`) are carried here so a profile fully describes a run, but are
/// consumed by the coordinator (`DecodeServeOpts` / `DisaggOpts`), not by
/// backend construction.
#[derive(Clone, Debug, PartialEq)]
pub struct ExecProfile {
    /// Which backend to construct.
    pub backend: BackendKind,
    /// Accelerator micro-architecture (serialized as `[accelerator]`).
    pub acc: AcceleratorConfig,
    /// Weight-synthesis / trace seed (functional backend weights).
    pub seed: u64,
    /// Artifact directory for the pjrt backend.
    pub artifacts: String,
    /// Tensor-parallel shard count (1 = unsharded).
    pub shards: usize,
    /// Provisioned LoRA adapter slots (0 = adapters off).
    pub adapters: usize,
    /// LoRA rank for provisioned adapters.
    pub adapter_rank: usize,
    /// Paged-KV block pool size (0 = KV cache off).
    pub kv_blocks: usize,
    /// Tokens per KV block.
    pub block_size: usize,
    /// Quantization regime (group size + compressed code streaming).
    pub quant: QuantRegime,
    /// Use scalar reference kernels instead of the packed hot path
    /// (functional backend only).
    pub scalar_kernels: bool,
    /// Per-request sequence limit override (0 = backend default).
    pub seq_limit: usize,
    /// Chunked-prefill budget in tokens (0 = unchunked).
    pub chunk_tokens: usize,
    /// Prefill→decode handoff metering in bytes/token (0 = unmetered).
    pub handoff_bytes_per_token: f64,
    /// SLO-aware admission (interactive/batch classes) in the serving tier.
    pub slo: bool,
    /// Pace simulated execution to wall-clock (sim backend live runs).
    pub paced: bool,
}

impl Default for ExecProfile {
    fn default() -> Self {
        ExecProfile {
            backend: BackendKind::Sim,
            acc: AcceleratorConfig::paper(),
            seed: 7,
            artifacts: "artifacts".to_string(),
            shards: 1,
            adapters: 0,
            adapter_rank: 16,
            kv_blocks: 0,
            block_size: 16,
            quant: QuantRegime::default(),
            scalar_kernels: false,
            seq_limit: 0,
            chunk_tokens: 0,
            handoff_bytes_per_token: 0.0,
            slo: false,
            paced: false,
        }
    }
}

impl ExecProfile {
    /// A default profile targeting `backend`.
    pub fn new(backend: BackendKind) -> ExecProfile {
        ExecProfile {
            backend,
            ..Default::default()
        }
    }

    /// Set the tensor-parallel shard count.
    pub fn with_shards(mut self, shards: usize) -> ExecProfile {
        self.shards = shards;
        self
    }

    /// Provision `count` adapter slots of rank `rank` (0 = off).
    pub fn with_adapters(mut self, count: usize, rank: usize) -> ExecProfile {
        self.adapters = count;
        self.adapter_rank = rank;
        self
    }

    /// Enable the paged KV cache with `blocks` blocks of `block_size`.
    pub fn with_kv_cache(mut self, blocks: usize, block_size: usize) -> ExecProfile {
        self.kv_blocks = blocks;
        self.block_size = block_size;
        self
    }

    /// Set the quantization regime.
    pub fn with_quant(mut self, quant: QuantRegime) -> ExecProfile {
        self.quant = quant;
        self
    }

    /// Validate internal consistency (including the nested accelerator).
    pub fn validate(&self) -> crate::Result<()> {
        if self.shards == 0 {
            return Err(anyhow!("shards must be ≥ 1"));
        }
        if self.adapter_rank == 0 {
            return Err(anyhow!("adapter_rank must be ≥ 1"));
        }
        if self.block_size == 0 {
            return Err(anyhow!("block_size must be ≥ 1"));
        }
        if self.handoff_bytes_per_token < 0.0 || !self.handoff_bytes_per_token.is_finite() {
            return Err(anyhow!("handoff_bytes_per_token must be finite and ≥ 0"));
        }
        if self.scalar_kernels && self.backend != BackendKind::Functional {
            return Err(anyhow!(
                "scalar_kernels only applies to the functional backend"
            ));
        }
        self.acc.validate()
    }

    /// Serialize into `[profile]` + `[accelerator]` TOML sections.
    pub fn to_doc(&self, doc: &mut Doc) {
        let s = "profile";
        doc.set(s, "backend", Value::Str(self.backend.name().to_string()));
        doc.set(s, "seed", Value::Int(self.seed as i64));
        doc.set(s, "artifacts", Value::Str(self.artifacts.clone()));
        doc.set(s, "shards", Value::Int(self.shards as i64));
        doc.set(s, "adapters", Value::Int(self.adapters as i64));
        doc.set(s, "adapter_rank", Value::Int(self.adapter_rank as i64));
        doc.set(s, "kv_blocks", Value::Int(self.kv_blocks as i64));
        doc.set(s, "block_size", Value::Int(self.block_size as i64));
        doc.set(s, "quant_group_size", Value::Int(self.quant.group_size as i64));
        doc.set(s, "quant_compressed", Value::Bool(self.quant.compressed));
        doc.set(s, "scalar_kernels", Value::Bool(self.scalar_kernels));
        doc.set(s, "seq_limit", Value::Int(self.seq_limit as i64));
        doc.set(s, "chunk_tokens", Value::Int(self.chunk_tokens as i64));
        doc.set(
            s,
            "handoff_bytes_per_token",
            Value::Float(self.handoff_bytes_per_token),
        );
        doc.set(s, "slo", Value::Bool(self.slo));
        doc.set(s, "paced", Value::Bool(self.paced));
        self.acc.to_doc(doc);
    }

    /// Read from `[profile]` + `[accelerator]` sections; missing keys keep
    /// their defaults so profile files can be sparse overrides.
    pub fn from_doc(doc: &Doc) -> crate::Result<Self> {
        let mut p = Self::default();
        let s = "profile";
        let geti = |key: &str, default: usize| -> crate::Result<usize> {
            match doc.get(s, key) {
                None => Ok(default),
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| anyhow!("[profile].{key} must be a non-negative int")),
            }
        };
        let getb = |key: &str, default: bool| -> crate::Result<bool> {
            match doc.get(s, key) {
                None => Ok(default),
                Some(v) => v
                    .as_bool()
                    .ok_or_else(|| anyhow!("[profile].{key} must be a bool")),
            }
        };
        if let Some(v) = doc.get(s, "backend") {
            let name = v
                .as_str()
                .ok_or_else(|| anyhow!("[profile].backend must be a string"))?;
            p.backend = BackendKind::parse(name)
                .ok_or_else(|| anyhow!("unknown backend {name:?} (sim|functional|pjrt)"))?;
        }
        p.seed = geti("seed", p.seed as usize)? as u64;
        if let Some(v) = doc.get(s, "artifacts") {
            p.artifacts = v
                .as_str()
                .ok_or_else(|| anyhow!("[profile].artifacts must be a string"))?
                .to_string();
        }
        p.shards = geti("shards", p.shards)?;
        p.adapters = geti("adapters", p.adapters)?;
        p.adapter_rank = geti("adapter_rank", p.adapter_rank)?;
        p.kv_blocks = geti("kv_blocks", p.kv_blocks)?;
        p.block_size = geti("block_size", p.block_size)?;
        p.quant.group_size = geti("quant_group_size", p.quant.group_size)?;
        p.quant.compressed = getb("quant_compressed", p.quant.compressed)?;
        p.scalar_kernels = getb("scalar_kernels", p.scalar_kernels)?;
        p.seq_limit = geti("seq_limit", p.seq_limit)?;
        p.chunk_tokens = geti("chunk_tokens", p.chunk_tokens)?;
        if let Some(v) = doc.get(s, "handoff_bytes_per_token") {
            p.handoff_bytes_per_token = v
                .as_float()
                .ok_or_else(|| anyhow!("[profile].handoff_bytes_per_token must be a number"))?;
        }
        p.slo = getb("slo", p.slo)?;
        p.paced = getb("paced", p.paced)?;
        p.acc = AcceleratorConfig::from_doc(doc)?;
        p.validate()?;
        Ok(p)
    }

    /// Load a profile from a TOML file.
    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading profile {}", path.display()))?;
        let doc = tomlite::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_doc(&doc)
    }

    /// Save a profile to a TOML file.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut doc = Doc::default();
        self.to_doc(&mut doc);
        std::fs::write(path, doc.to_string())
            .with_context(|| format!("writing profile {}", path.display()))?;
        Ok(())
    }

    /// Compact human-readable label for sweep rows and logs, e.g.
    /// `sim×2 g64c kv256`.
    pub fn label(&self) -> String {
        let mut l = format!("{}×{}", self.backend.name(), self.shards);
        if self.quant.group_size > 0 || self.quant.compressed {
            let g = if self.quant.group_size == 0 {
                "pt".to_string()
            } else {
                format!("{}", self.quant.group_size)
            };
            l.push_str(&format!(" g{}{}", g, if self.quant.compressed { "c" } else { "" }));
        }
        if self.adapters > 0 {
            l.push_str(&format!(" a{}r{}", self.adapters, self.adapter_rank));
        }
        if self.kv_blocks > 0 {
            l.push_str(&format!(" kv{}", self.kv_blocks));
        }
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_profile_validates() {
        let p = ExecProfile::default();
        assert_eq!(p.backend, BackendKind::Sim);
        assert_eq!(p.shards, 1);
        assert_eq!(p.quant, QuantRegime::per_tensor());
        p.validate().unwrap();
    }

    #[test]
    fn backend_kind_parse_roundtrip() {
        for k in [BackendKind::Sim, BackendKind::Functional, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(k.name()), Some(k));
        }
        assert_eq!(BackendKind::parse("tpu"), None);
    }

    #[test]
    fn toml_roundtrip_is_exact() {
        let p = ExecProfile::new(BackendKind::Functional)
            .with_shards(4)
            .with_adapters(2, 8)
            .with_kv_cache(64, 8)
            .with_quant(QuantRegime::grouped(64).with_compressed(true));
        let mut doc = Doc::default();
        p.to_doc(&mut doc);
        let back = ExecProfile::from_doc(&tomlite::parse(&doc.to_string()).unwrap()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn sparse_doc_keeps_defaults() {
        let doc = tomlite::parse("[profile]\nshards = 2\n").unwrap();
        let p = ExecProfile::from_doc(&doc).unwrap();
        assert_eq!(p.shards, 2);
        assert_eq!(p.backend, BackendKind::Sim);
        assert_eq!(p.kv_blocks, 0);
    }

    #[test]
    fn rejects_unknown_backend_and_bad_fields() {
        let doc = tomlite::parse("[profile]\nbackend = \"tpu\"\n").unwrap();
        assert!(ExecProfile::from_doc(&doc).is_err());
        let doc = tomlite::parse("[profile]\nshards = 0\n").unwrap();
        assert!(ExecProfile::from_doc(&doc).is_err());
        let doc = tomlite::parse("[profile]\nscalar_kernels = true\n").unwrap();
        assert!(
            ExecProfile::from_doc(&doc).is_err(),
            "scalar kernels require the functional backend"
        );
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("axllm_profile_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profile.toml");
        let p = ExecProfile::new(BackendKind::Sim).with_shards(2);
        p.save(&path).unwrap();
        assert_eq!(ExecProfile::load(&path).unwrap(), p);
    }

    #[test]
    fn label_is_compact() {
        let p = ExecProfile::new(BackendKind::Sim)
            .with_shards(2)
            .with_quant(QuantRegime::grouped(64).with_compressed(true));
        assert_eq!(p.label(), "sim×2 g64c");
        assert_eq!(ExecProfile::default().label(), "sim×1");
    }
}
