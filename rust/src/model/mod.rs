//! Synthetic quantized transformer models mirroring the paper's Table I
//! benchmarks.
//!
//! Real pre-trained checkpoints are unavailable offline (substitution S1 in
//! DESIGN.md): weights are synthesized from near-Gaussian distributions —
//! the empirically documented shape of trained transformer weights — then
//! pushed through the *real* quantizer from [`crate::quant`], so every
//! locality statistic downstream is **measured**, never assumed.

pub mod flops;
pub mod lora;
pub mod synth;

pub use flops::{layer_breakdown, ComponentFlops};
pub use lora::{AdapterId, AdapterRegistry, LoraAdaptor};
pub use synth::{synthesize_matrix, WeightDistribution};

use crate::config::ModelConfig;
use crate::quant::{PackedQuantMatrix, QuantMatrix};
use crate::util::rng::Rng;

/// Which weight matrix of a layer (the matmuls AxLLM accelerates).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MatKind {
    /// Query projection W_q (d×d).
    Wq,
    /// Key projection W_k (d×d).
    Wk,
    /// Value projection W_v (d×d).
    Wv,
    /// Attention output projection W_o (d×d).
    Wo,
    /// First feed-forward matrix (d×d_ff).
    Ff1,
    /// Second feed-forward matrix (d_ff×d).
    Ff2,
}

impl MatKind {
    /// Every weight matrix of one layer, in streaming order.
    pub const ALL: [MatKind; 6] = [
        MatKind::Wq,
        MatKind::Wk,
        MatKind::Wv,
        MatKind::Wo,
        MatKind::Ff1,
        MatKind::Ff2,
    ];

    /// Short display name of the matrix kind.
    pub fn name(&self) -> &'static str {
        match self {
            MatKind::Wq => "Wq",
            MatKind::Wk => "Wk",
            MatKind::Wv => "Wv",
            MatKind::Wo => "Wo",
            MatKind::Ff1 => "FF1",
            MatKind::Ff2 => "FF2",
        }
    }

    /// (rows, cols) of this matrix in the given model.
    pub fn shape(&self, cfg: &ModelConfig) -> (usize, usize) {
        let d = cfg.d_model;
        let ff = cfg.d_ff;
        match self {
            MatKind::Wq | MatKind::Wk | MatKind::Wv | MatKind::Wo => (d, d),
            MatKind::Ff1 => (d, ff),
            MatKind::Ff2 => (ff, d),
        }
    }
}

/// One transformer layer's quantized weights (+ optional LoRA on Q and V,
/// the standard attachment points).
///
/// Built through [`LayerWeights::new`], which also derives the packed
/// 4-codes-per-word layout ([`PackedQuantMatrix`]) of every matrix once,
/// at load time — the functional hot path consumes the packed view, the
/// scalar reference kernels and the cycle simulator keep consuming the
/// byte codes.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    /// Layer index within the model.
    pub layer_idx: usize,
    /// The layer's quantized matrices, one per [`MatKind`].
    pub mats: Vec<(MatKind, QuantMatrix)>,
    /// LoRA adaptor on the Q projection (fine-tuned models).
    pub lora_q: Option<LoraAdaptor>,
    /// LoRA adaptor on the V projection (fine-tuned models).
    pub lora_v: Option<LoraAdaptor>,
    /// Packed views of `mats`, same order (derived at construction).
    packed: Vec<(MatKind, PackedQuantMatrix)>,
}

impl LayerWeights {
    /// Assemble a layer from its quantized matrices, deriving the packed
    /// view of each one up front.
    pub fn new(
        layer_idx: usize,
        mats: Vec<(MatKind, QuantMatrix)>,
        lora_q: Option<LoraAdaptor>,
        lora_v: Option<LoraAdaptor>,
    ) -> LayerWeights {
        let packed = mats.iter().map(|(k, m)| (*k, m.packed())).collect();
        LayerWeights {
            layer_idx,
            mats,
            lora_q,
            lora_v,
            packed,
        }
    }

    /// The layer's matrix of the given kind (panics if absent).
    pub fn get(&self, kind: MatKind) -> &QuantMatrix {
        &self
            .mats
            .iter()
            .find(|(k, _)| *k == kind)
            .unwrap_or_else(|| panic!("missing matrix {kind:?}"))
            .1
    }

    /// The packed view of the given kind (panics if absent).
    pub fn get_packed(&self, kind: MatKind) -> &PackedQuantMatrix {
        &self
            .packed
            .iter()
            .find(|(k, _)| *k == kind)
            .unwrap_or_else(|| panic!("missing matrix {kind:?}"))
            .1
    }
}

/// A synthesized model: configuration plus a per-layer weight generator.
///
/// Layers are materialized **on demand** ([`Model::layer`]) so that
/// Llama-13B-scale experiments never hold the full parameter set (≈10 GB)
/// in memory; determinism comes from hashing (seed, layer, matrix kind)
/// into the per-matrix RNG stream.
#[derive(Clone, Debug)]
pub struct Model {
    /// Architectural shape (Table I row).
    pub config: ModelConfig,
    /// Seed all weight streams derive from.
    pub seed: u64,
    /// Synthesis distribution of the weights.
    pub dist: WeightDistribution,
}

impl Model {
    /// New model with the default (Gaussian) weight distribution.
    pub fn new(config: ModelConfig, seed: u64) -> Model {
        Model {
            config,
            seed,
            dist: WeightDistribution::default(),
        }
    }

    /// Override the weight-synthesis distribution.
    pub fn with_distribution(mut self, dist: WeightDistribution) -> Model {
        self.dist = dist;
        self
    }

    fn mat_seed(&self, layer: usize, kind: MatKind) -> u64 {
        // Mix seed, layer, and matrix kind into one stream id.
        let k = kind as u64 + 1;
        self.seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add((layer as u64) << 8)
            .wrapping_add(k)
    }

    fn layer_sigma(&self, layer: usize) -> f64 {
        // Per-layer σ drift mimics the depth-dependent scale variation of
        // trained transformers (later layers slightly wider).
        self.dist.sigma * (1.0 + 0.05 * layer as f64 / self.config.n_layers.max(1) as f64)
    }

    /// Sigma multiple at which the quantization grid clips: the
    /// percentile-calibrated clipping used by practical post-training
    /// quantizers (AWQ-style), which trades ~0.006% clipped outliers for
    /// finer resolution of the bulk. Besides being standard practice, this
    /// is the calibration that reproduces the paper's measured locality:
    /// DistilBERT full-row reuse ≈ 87–91%, 256-entry-buffer reuse ≈ 70%.
    pub const CLIP_SIGMAS: f64 = 4.0;

    /// The quantization grid of one matrix, derived **analytically** from
    /// the synthesis distribution rather than fit to the sampled data
    /// (`amax = CLIP_SIGMAS·σ`). This keeps row-sampled prefixes
    /// code-identical to the full matrix — per-tensor max-fit would couple
    /// every code to every sample.
    pub fn grid(&self, layer: usize, _kind: MatKind) -> crate::quant::QuantParams {
        let sigma = self.layer_sigma(layer);
        let amax = sigma * Self::CLIP_SIGMAS;
        let qmax = ((1i32 << (self.dist.bits - 1)) - 1) as f32;
        crate::quant::QuantParams {
            scale: (amax as f32 / qmax).max(f32::MIN_POSITIVE),
            bits: self.dist.bits,
        }
    }

    /// Materialize one full weight matrix.
    pub fn matrix(&self, layer: usize, kind: MatKind) -> QuantMatrix {
        let (rows, cols) = kind.shape(&self.config);
        self.matrix_rows_inner(layer, kind, rows, cols)
    }

    /// Materialize only the first `n_rows` of a matrix — enough for
    /// row-sampled locality/cycle measurements on Llama-scale models.
    /// Rows are generated by the same stream and quantization grid as
    /// [`Model::matrix`], so a prefix here equals a prefix of the full
    /// matrix.
    pub fn matrix_rows(&self, layer: usize, kind: MatKind, n_rows: usize) -> QuantMatrix {
        let (rows, cols) = kind.shape(&self.config);
        self.matrix_rows_inner(layer, kind, n_rows.min(rows), cols)
    }

    fn matrix_rows_inner(
        &self,
        layer: usize,
        kind: MatKind,
        n_rows: usize,
        cols: usize,
    ) -> QuantMatrix {
        let mut rng = Rng::new(self.mat_seed(layer, kind));
        let dist = self.dist.with_sigma(self.layer_sigma(layer));
        synth::synthesize_on_grid(n_rows, cols, dist, self.grid(layer, kind), &mut rng)
    }

    /// Materialize one full layer (with LoRA adaptors when configured).
    pub fn layer(&self, layer: usize) -> LayerWeights {
        let mats = MatKind::ALL
            .iter()
            .map(|&k| (k, self.matrix(layer, k)))
            .collect::<Vec<_>>();
        let (lora_q, lora_v) = match self.config.lora {
            None => (None, None),
            Some(lc) => {
                let wq = &mats[0].1;
                let wv = &mats[2].1;
                let mk = |w: &QuantMatrix, tag: u64| {
                    let mut rng = Rng::new(self.mat_seed(layer, MatKind::Wq) ^ (0xA0A0 + tag));
                    LoraAdaptor::synthesize(w, lc, self.dist, &mut rng)
                };
                (Some(mk(wq, 1)), Some(mk(wv, 2)))
            }
        };
        LayerWeights::new(layer, mats, lora_q, lora_v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{LoraConfig, ModelConfig};

    #[test]
    fn shapes_match_config() {
        let cfg = ModelConfig::tiny();
        let m = Model::new(cfg.clone(), 7);
        let l = m.layer(0);
        assert_eq!(l.get(MatKind::Wq).rows, cfg.d_model);
        assert_eq!(l.get(MatKind::Ff1).cols, cfg.d_ff);
        assert_eq!(l.get(MatKind::Ff2).rows, cfg.d_ff);
        assert!(l.lora_q.is_none());
    }

    #[test]
    fn deterministic_by_seed() {
        let m1 = Model::new(ModelConfig::tiny(), 42);
        let m2 = Model::new(ModelConfig::tiny(), 42);
        assert_eq!(
            m1.matrix(1, MatKind::Wk).data,
            m2.matrix(1, MatKind::Wk).data
        );
        let m3 = Model::new(ModelConfig::tiny(), 43);
        assert_ne!(
            m1.matrix(1, MatKind::Wk).data,
            m3.matrix(1, MatKind::Wk).data
        );
    }

    #[test]
    fn distinct_streams_per_layer_and_kind() {
        let m = Model::new(ModelConfig::tiny(), 1);
        assert_ne!(m.matrix(0, MatKind::Wq).data, m.matrix(1, MatKind::Wq).data);
        assert_ne!(m.matrix(0, MatKind::Wq).data, m.matrix(0, MatKind::Wk).data);
    }

    #[test]
    fn row_prefix_matches_full_matrix() {
        let m = Model::new(ModelConfig::tiny(), 5);
        let full = m.matrix(0, MatKind::Wo);
        let part = m.matrix_rows(0, MatKind::Wo, 3);
        assert_eq!(part.rows, 3);
        assert_eq!(part.data[..], full.data[..3 * full.cols]);
    }

    #[test]
    fn packed_views_match_byte_codes() {
        let m = Model::new(ModelConfig::tiny(), 11);
        let l = m.layer(0);
        for &kind in &MatKind::ALL {
            let q = l.get(kind);
            let p = l.get_packed(kind);
            assert_eq!(p.rows, q.rows);
            assert_eq!(p.cols, q.cols);
            assert_eq!(p.unpack(), q.data, "{kind:?}");
        }
    }

    #[test]
    fn lora_layers_materialize_adaptors() {
        let cfg = ModelConfig::tiny().with_lora(LoraConfig { rank: 4, alpha: 8.0 });
        let m = Model::new(cfg, 9);
        let l = m.layer(0);
        let a = l.lora_q.as_ref().unwrap();
        assert_eq!(a.a.rows, 128);
        assert_eq!(a.a.cols, 4);
        assert_eq!(a.b.rows, 4);
        assert_eq!(a.b.cols, 128);
        assert!(l.lora_v.is_some());
    }
}
