//! Synthetic weight generation (DESIGN.md §8 substitution S1).
//!
//! Trained transformer weight matrices are near-Gaussian per tensor, with
//! occasional heavier-tailed layers; the reuse statistics AxLLM exploits
//! depend only on this value-locality profile after quantization. The
//! default generator is Gaussian; Laplace and Student-t generators support
//! the distribution-sensitivity study (`report::ablation`), demonstrating
//! that the paper's reuse-rate conclusion is not an artifact of the
//! Gaussian choice.

use crate::quant::{QuantMatrix, QuantParams};
use crate::util::rng::Rng;

/// Family of the synthetic weight distribution.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DistKind {
    /// Standard normal (the empirical shape of trained weights; default).
    Gaussian,
    /// Laplace — heavier tails than normal.
    Laplace,
    /// Student-t with the given degrees of freedom (heavier tails).
    StudentT(u32),
    /// Uniform over [-a, a] — worst case for locality (flat histogram).
    Uniform,
}

/// Distribution + scale for weight synthesis.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WeightDistribution {
    /// Distribution family to draw from.
    pub kind: DistKind,
    /// Standard-deviation-like scale parameter.
    pub sigma: f64,
    /// Quantization bit width applied after synthesis.
    pub bits: u8,
}

impl Default for WeightDistribution {
    fn default() -> Self {
        WeightDistribution {
            kind: DistKind::Gaussian,
            // ~N(0, 0.02): typical magnitude for trained transformer
            // weights (initialization-scale, preserved by training).
            sigma: 0.02,
            bits: 8,
        }
    }
}

impl WeightDistribution {
    /// Override the scale parameter.
    pub fn with_sigma(mut self, sigma: f64) -> Self {
        self.sigma = sigma;
        self
    }

    /// Override the distribution family.
    pub fn with_kind(mut self, kind: DistKind) -> Self {
        self.kind = kind;
        self
    }

    /// Override the post-synthesis quantization bit width.
    pub fn with_bits(mut self, bits: u8) -> Self {
        self.bits = bits;
        self
    }

    /// Draw one float sample.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> f32 {
        let x = match self.kind {
            DistKind::Gaussian => rng.normal(),
            DistKind::Laplace => rng.laplace(1.0 / std::f64::consts::SQRT_2), // unit variance
            DistKind::StudentT(nu) => {
                let x = rng.student_t(nu);
                // Normalize to unit variance when it exists (nu > 2).
                if nu > 2 {
                    x / (nu as f64 / (nu as f64 - 2.0)).sqrt()
                } else {
                    x
                }
            }
            DistKind::Uniform => (rng.f64() * 2.0 - 1.0) * 3.0f64.sqrt(), // unit variance
        };
        (x * self.sigma) as f32
    }
}

/// Synthesize the raw float samples of a `rows×cols` matrix, row-major.
///
/// The un-quantized form feeds the group-wise quantization study
/// ([`crate::quant::GroupQuantMatrix::fit`] needs the floats to fit one
/// grid per column group) and any fidelity measurement that compares a
/// quantizer's output against the original values.
pub fn synthesize_floats(
    rows: usize,
    cols: usize,
    dist: WeightDistribution,
    rng: &mut Rng,
) -> Vec<f32> {
    let n = rows * cols;
    let mut data = Vec::with_capacity(n);
    for _ in 0..n {
        data.push(dist.sample(rng));
    }
    data
}

/// Synthesize a quantized `rows×cols` matrix.
///
/// The float samples go through [`QuantParams::fit`] — the same symmetric
/// quantizer a real checkpoint would — so clipping and rounding behaviour
/// (and therefore the folded-value histogram) match the real pipeline.
pub fn synthesize_matrix(
    rows: usize,
    cols: usize,
    dist: WeightDistribution,
    rng: &mut Rng,
) -> QuantMatrix {
    let data = synthesize_floats(rows, cols, dist, rng);
    QuantMatrix::from_f32(rows, cols, &data, dist.bits)
}

/// Synthesize a quantized matrix whose codes live on a **given** grid
/// (scale), clamping instead of refitting. Used to re-code LoRA A onto W's
/// grid so equal dequantized values produce equal codes (Fig. 5 sharing).
pub fn synthesize_on_grid(
    rows: usize,
    cols: usize,
    dist: WeightDistribution,
    params: QuantParams,
    rng: &mut Rng,
) -> QuantMatrix {
    let n = rows * cols;
    let mut data = Vec::with_capacity(n);
    for _ in 0..n {
        data.push(params.quantize(dist.sample(rng)));
    }
    QuantMatrix::from_q(rows, cols, data, params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::stats::measure_locality;

    #[test]
    fn gaussian_matrix_has_gaussian_histogram() {
        let mut rng = Rng::new(1);
        let m = synthesize_matrix(16, 512, WeightDistribution::default(), &mut rng);
        // Center-heavy: |q| <= 42 (±1σ after fit maps σ→~max/3... loosely)
        // must hold far more mass than the tails.
        let center = m.data.iter().filter(|&&q| q.unsigned_abs() <= 42).count();
        let tails = m.data.len() - center;
        assert!(center > tails * 2, "center {center} tails {tails}");
    }

    #[test]
    fn uniform_has_flat_histogram() {
        let mut rng = Rng::new(2);
        let dist = WeightDistribution::default().with_kind(DistKind::Uniform);
        let m = synthesize_matrix(16, 512, dist, &mut rng);
        let center = m.data.iter().filter(|&&q| q.unsigned_abs() <= 42).count() as f64;
        let frac = center / m.data.len() as f64;
        // Uniform ±max → |q|≤42 covers about a third of the mass.
        assert!((0.25..0.45).contains(&frac), "{frac}");
    }

    #[test]
    fn gaussian_localizes_better_than_uniform() {
        let mut rng = Rng::new(3);
        let g = synthesize_matrix(8, 512, WeightDistribution::default(), &mut rng);
        let u = synthesize_matrix(
            8,
            512,
            WeightDistribution::default().with_kind(DistKind::Uniform),
            &mut rng,
        );
        let rg = measure_locality(&g, 512).reuse_rate();
        let ru = measure_locality(&u, 512).reuse_rate();
        assert!(rg > ru, "gaussian {rg} uniform {ru}");
        // Even uniform over 128 folded values reuses heavily at chunk 512.
        assert!(ru > 0.7, "{ru}");
    }

    #[test]
    fn student_t_heavier_tails_than_gaussian() {
        let mut rng = Rng::new(4);
        let g = synthesize_matrix(8, 1024, WeightDistribution::default(), &mut rng);
        let t = synthesize_matrix(
            8,
            1024,
            WeightDistribution::default().with_kind(DistKind::StudentT(3)),
            &mut rng,
        );
        // After fit, heavy tails compress the center → more codes near 0.
        let gz = g.data.iter().filter(|&&q| q == 0).count();
        let tz = t.data.iter().filter(|&&q| q == 0).count();
        assert!(tz > gz, "t zeros {tz} gaussian zeros {gz}");
    }

    #[test]
    fn on_grid_synthesis_respects_params() {
        let mut rng = Rng::new(5);
        let params = QuantParams { scale: 0.0001, bits: 8 };
        let m = synthesize_on_grid(4, 64, WeightDistribution::default(), params, &mut rng);
        assert_eq!(m.params, params);
        // σ=0.02 on scale 0.0001 → lots of clamping to ±127.
        assert!(m.data.iter().any(|&q| q == 127 || q == -127));
        assert!(m.data.iter().all(|&q| q != i8::MIN));
    }

    #[test]
    fn unit_variance_normalizations() {
        let mut rng = Rng::new(6);
        for kind in [
            DistKind::Gaussian,
            DistKind::Laplace,
            DistKind::StudentT(5),
            DistKind::Uniform,
        ] {
            let dist = WeightDistribution {
                kind,
                sigma: 1.0,
                bits: 8,
            };
            let n = 100_000;
            let mut sum2 = 0.0;
            for _ in 0..n {
                let x = dist.sample(&mut rng) as f64;
                sum2 += x * x;
            }
            let var = sum2 / n as f64;
            assert!((0.85..1.25).contains(&var), "{kind:?} var {var}");
        }
    }
}
