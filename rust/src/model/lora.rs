//! LoRA adaptors and the W∥A combined-matrix reuse trick (paper §III.c,
//! Fig. 5).
//!
//! LoRA replaces `xW` with `xW + xAB`. Since both `W` (d×d) and `A` (d×r)
//! are multiplied by the same input vector `x`, AxLLM concatenates `A`
//! beside `W` column-wise: the lane that streams row i of W simply keeps
//! streaming row i of A, and every A element whose folded value already
//! appeared in the W row reuses the cached product for free.
//!
//! For code-level sharing the A matrix must live on the **same quantization
//! grid** as W (equal dequantized values ⇒ equal codes); the synthesizer
//! re-codes A onto W's scale, matching what a deployment would do when
//! preparing adaptors for this accelerator.

use crate::config::LoraConfig;
use crate::model::synth::{synthesize_on_grid, WeightDistribution};
use crate::quant::{stats::overlap_fraction, QuantMatrix};
use crate::util::rng::Rng;

/// Identifier of one LoRA adapter within an [`AdapterRegistry`] — the
/// per-request serving dimension carried by
/// [`crate::workload::Request::adapter`].
pub type AdapterId = u32;

/// A quantized LoRA adaptor pair (A: d×r, B: r×d) attached to a base W.
#[derive(Clone, Debug)]
pub struct LoraAdaptor {
    /// Down-projection A (d×r), re-coded onto the base matrix's grid.
    pub a: QuantMatrix,
    /// Up-projection B (r×d), on its own fitted grid.
    pub b: QuantMatrix,
    /// Rank/α hyper-parameters the pair was synthesized with.
    pub config: LoraConfig,
}

impl LoraAdaptor {
    /// Synthesize an adaptor for base matrix `w`. A is re-coded onto W's
    /// quantization grid (see module docs); B gets its own fitted grid (it
    /// multiplies the r-dimensional intermediate, not x, so it does not
    /// participate in W-sharing).
    pub fn synthesize(
        w: &QuantMatrix,
        config: LoraConfig,
        dist: WeightDistribution,
        rng: &mut Rng,
    ) -> LoraAdaptor {
        // LoRA init: A ~ N(0, σ_A). Trained adaptors keep magnitudes close
        // to the base-weight scale; we use the same σ as the base weights
        // so re-coding onto W's grid is representative.
        let a = synthesize_on_grid(w.rows, config.rank, dist, w.params, rng);
        let bdist = dist;
        let bdata: Vec<f32> = (0..config.rank * w.cols)
            .map(|_| bdist.sample(rng))
            .collect();
        let b = QuantMatrix::from_f32(config.rank, w.cols, &bdata, dist.bits);
        LoraAdaptor { a, b, config }
    }

    /// The paper's Fig. 5 combined matrix: `[W ∥ A]`, streamed as one
    /// wider matrix so RC contents carry over from W columns into A
    /// columns within each row.
    pub fn combined(&self, w: &QuantMatrix) -> QuantMatrix {
        w.concat_cols(&self.a)
    }

    /// Mean fraction of A-row elements whose folded value also occurs in
    /// the matching W row (paper §V reports ≈90% on its benchmarks).
    pub fn overlap_with(&self, w: &QuantMatrix) -> f64 {
        assert_eq!(w.rows, self.a.rows);
        let mut acc = 0.0;
        for r in 0..w.rows {
            acc += overlap_fraction(w.row(r), self.a.row(r));
        }
        acc / w.rows as f64
    }

    /// Extra MACs per input vector introduced by this adaptor (xA then
    /// (xA)B), before any reuse.
    pub fn extra_macs(&self) -> u64 {
        (self.a.rows * self.a.cols + self.b.rows * self.b.cols) as u64
    }
}

/// The set of LoRA adaptors a serving deployment holds for one base
/// model — the multi-tenant registry behind per-request adapter routing.
///
/// Every adaptor is an independent rank-r A/B pair against the same base
/// matrix; each A is re-coded onto the base matrix's quantization grid
/// (see module docs), so any tenant's side pipeline can share the base
/// pipeline's Result Cache without touching the base weights — the
/// paper's "no parameter change, no retraining, no offline
/// preprocessing" claim applied per request instead of per model.
/// Adapter ids are dense indices `0..len`.
#[derive(Clone, Debug)]
pub struct AdapterRegistry {
    adaptors: Vec<LoraAdaptor>,
    rank: usize,
}

impl AdapterRegistry {
    /// Synthesize `n` independent adaptors of the given rank against one
    /// base matrix. Deterministic in `seed`; adapter `i` draws from its
    /// own forked stream, so registries are stable under re-ordering of
    /// lookups and identical across backends with the same seed.
    ///
    /// The rank is clamped to ≥ 1 here — the single enforcement point —
    /// so no caller can produce degenerate d×0 adaptors whose zero
    /// side-pipe work would be indistinguishable from base-only serving.
    pub fn synthesize(
        base: &QuantMatrix,
        n: usize,
        config: LoraConfig,
        dist: WeightDistribution,
        seed: u64,
    ) -> AdapterRegistry {
        let config = LoraConfig {
            rank: config.rank.max(1),
            ..config
        };
        let adaptors = (0..n)
            .map(|i| {
                let mut rng =
                    Rng::new(seed ^ (i as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15));
                LoraAdaptor::synthesize(base, config, dist, &mut rng)
            })
            .collect();
        AdapterRegistry {
            adaptors,
            rank: config.rank,
        }
    }

    /// Look up one adaptor; `None` for ids outside the registry (the
    /// caller decides whether that is a hard error or a recorded miss).
    pub fn get(&self, id: AdapterId) -> Option<&LoraAdaptor> {
        self.adaptors.get(id as usize)
    }

    /// Number of registered adaptors.
    pub fn len(&self) -> usize {
        self.adaptors.len()
    }

    /// True when no adaptors are registered.
    pub fn is_empty(&self) -> bool {
        self.adaptors.is_empty()
    }

    /// The (uniform) low-rank dimension of every registered adaptor.
    pub fn rank(&self) -> usize {
        self.rank
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::synthesize_matrix;
    use crate::quant::fold;

    fn setup(rank: usize) -> (QuantMatrix, LoraAdaptor) {
        let mut rng = Rng::new(77);
        let dist = WeightDistribution::default();
        let w = synthesize_matrix(96, 96, dist, &mut rng);
        let lora = LoraAdaptor::synthesize(
            &w,
            LoraConfig {
                rank,
                alpha: 16.0,
            },
            dist,
            &mut rng,
        );
        (w, lora)
    }

    #[test]
    fn shapes() {
        let (w, l) = setup(8);
        assert_eq!(l.a.rows, w.rows);
        assert_eq!(l.a.cols, 8);
        assert_eq!(l.b.rows, 8);
        assert_eq!(l.b.cols, w.cols);
        assert_eq!(l.extra_macs(), (96 * 8 + 8 * 96) as u64);
    }

    #[test]
    fn a_lives_on_w_grid() {
        let (w, l) = setup(8);
        assert_eq!(l.a.params, w.params);
    }

    #[test]
    fn combined_matrix_streams_w_then_a() {
        let (w, l) = setup(4);
        let c = l.combined(&w);
        assert_eq!(c.cols, w.cols + 4);
        assert_eq!(&c.row(5)[..w.cols], w.row(5));
        assert_eq!(&c.row(5)[w.cols..], l.a.row(5));
    }

    #[test]
    fn overlap_is_high_for_matched_distributions() {
        // The paper reports ≈90% A∩W overlap; with matched σ and a 96-col
        // W row the overlap is high but not total. Sanity band:
        let (w, l) = setup(8);
        let f = l.overlap_with(&w);
        assert!(f > 0.5, "overlap {f}");
    }

    #[test]
    fn overlap_approaches_paper_value_at_realistic_width() {
        // DistilBERT-sized: W row = 768 cols → nearly every A value folded
        // appears in the W row.
        let mut rng = Rng::new(3);
        let dist = WeightDistribution::default();
        let w = synthesize_matrix(32, 768, dist, &mut rng);
        let l = LoraAdaptor::synthesize(&w, LoraConfig::default(), dist, &mut rng);
        let f = l.overlap_with(&w);
        assert!(f > 0.85, "overlap {f}");
    }

    #[test]
    fn registry_holds_independent_adaptors_on_the_base_grid() {
        let mut rng = Rng::new(5);
        let dist = WeightDistribution::default();
        let w = synthesize_matrix(64, 64, dist, &mut rng);
        let reg = AdapterRegistry::synthesize(
            &w,
            3,
            LoraConfig { rank: 4, alpha: 8.0 },
            dist,
            77,
        );
        assert_eq!(reg.len(), 3);
        assert!(!reg.is_empty());
        assert_eq!(reg.rank(), 4);
        assert!(reg.get(3).is_none(), "ids are dense 0..len");
        for id in 0..3 {
            let a = reg.get(id).expect("registered adaptor");
            assert_eq!(a.a.params, w.params, "A lives on the base grid");
            assert_eq!(a.a.cols, 4);
            assert_eq!(a.b.rows, 4);
        }
        // Tenants are distinct…
        assert_ne!(reg.get(0).unwrap().a.data, reg.get(1).unwrap().a.data);
        // …and the registry is deterministic in its seed.
        let again = AdapterRegistry::synthesize(
            &w,
            3,
            LoraConfig { rank: 4, alpha: 8.0 },
            dist,
            77,
        );
        assert_eq!(reg.get(2).unwrap().a.data, again.get(2).unwrap().a.data);
        assert_eq!(reg.get(2).unwrap().b.data, again.get(2).unwrap().b.data);
        // Rank 0 clamps to a well-formed rank-1 pair at the single
        // enforcement point — no degenerate d×0 adaptors.
        let clamped =
            AdapterRegistry::synthesize(&w, 1, LoraConfig { rank: 0, alpha: 1.0 }, dist, 1);
        assert_eq!(clamped.rank(), 1);
        assert_eq!(clamped.get(0).unwrap().a.cols, 1);
        assert!(clamped.get(0).unwrap().extra_macs() > 0);
    }

    #[test]
    fn combined_reuse_exceeds_separate() {
        // Streaming A after W (combined) must yield at least as many RC
        // hits for A elements as streaming A alone.
        let (w, l) = setup(8);
        let mut hits_combined = 0usize;
        let mut hits_alone = 0usize;
        for r in 0..w.rows {
            let mut seen = [false; 128];
            for &q in w.row(r) {
                seen[fold(q).0 as usize] = true;
            }
            for &q in l.a.row(r) {
                if seen[fold(q).0 as usize] {
                    hits_combined += 1;
                }
            }
            let mut seen_a = [false; 128];
            for &q in l.a.row(r) {
                let i = fold(q).0 as usize;
                if seen_a[i] {
                    hits_alone += 1;
                }
                seen_a[i] = true;
            }
        }
        assert!(hits_combined >= hits_alone);
    }
}
