//! LoRA adaptors and the W∥A combined-matrix reuse trick (paper §III.c,
//! Fig. 5).
//!
//! LoRA replaces `xW` with `xW + xAB`. Since both `W` (d×d) and `A` (d×r)
//! are multiplied by the same input vector `x`, AxLLM concatenates `A`
//! beside `W` column-wise: the lane that streams row i of W simply keeps
//! streaming row i of A, and every A element whose folded value already
//! appeared in the W row reuses the cached product for free.
//!
//! For code-level sharing the A matrix must live on the **same quantization
//! grid** as W (equal dequantized values ⇒ equal codes); the synthesizer
//! re-codes A onto W's scale, matching what a deployment would do when
//! preparing adaptors for this accelerator.

use crate::config::LoraConfig;
use crate::model::synth::{synthesize_on_grid, WeightDistribution};
use crate::quant::{stats::overlap_fraction, QuantMatrix};
use crate::util::rng::Rng;

/// A quantized LoRA adaptor pair (A: d×r, B: r×d) attached to a base W.
#[derive(Clone, Debug)]
pub struct LoraAdaptor {
    pub a: QuantMatrix,
    pub b: QuantMatrix,
    pub config: LoraConfig,
}

impl LoraAdaptor {
    /// Synthesize an adaptor for base matrix `w`. A is re-coded onto W's
    /// quantization grid (see module docs); B gets its own fitted grid (it
    /// multiplies the r-dimensional intermediate, not x, so it does not
    /// participate in W-sharing).
    pub fn synthesize(
        w: &QuantMatrix,
        config: LoraConfig,
        dist: WeightDistribution,
        rng: &mut Rng,
    ) -> LoraAdaptor {
        // LoRA init: A ~ N(0, σ_A). Trained adaptors keep magnitudes close
        // to the base-weight scale; we use the same σ as the base weights
        // so re-coding onto W's grid is representative.
        let a = synthesize_on_grid(w.rows, config.rank, dist, w.params, rng);
        let bdist = dist;
        let bdata: Vec<f32> = (0..config.rank * w.cols)
            .map(|_| bdist.sample(rng))
            .collect();
        let b = QuantMatrix::from_f32(config.rank, w.cols, &bdata, dist.bits);
        LoraAdaptor { a, b, config }
    }

    /// The paper's Fig. 5 combined matrix: `[W ∥ A]`, streamed as one
    /// wider matrix so RC contents carry over from W columns into A
    /// columns within each row.
    pub fn combined(&self, w: &QuantMatrix) -> QuantMatrix {
        w.concat_cols(&self.a)
    }

    /// Mean fraction of A-row elements whose folded value also occurs in
    /// the matching W row (paper §V reports ≈90% on its benchmarks).
    pub fn overlap_with(&self, w: &QuantMatrix) -> f64 {
        assert_eq!(w.rows, self.a.rows);
        let mut acc = 0.0;
        for r in 0..w.rows {
            acc += overlap_fraction(w.row(r), self.a.row(r));
        }
        acc / w.rows as f64
    }

    /// Extra MACs per input vector introduced by this adaptor (xA then
    /// (xA)B), before any reuse.
    pub fn extra_macs(&self) -> u64 {
        (self.a.rows * self.a.cols + self.b.rows * self.b.cols) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::synth::synthesize_matrix;
    use crate::quant::fold;

    fn setup(rank: usize) -> (QuantMatrix, LoraAdaptor) {
        let mut rng = Rng::new(77);
        let dist = WeightDistribution::default();
        let w = synthesize_matrix(96, 96, dist, &mut rng);
        let lora = LoraAdaptor::synthesize(
            &w,
            LoraConfig {
                rank,
                alpha: 16.0,
            },
            dist,
            &mut rng,
        );
        (w, lora)
    }

    #[test]
    fn shapes() {
        let (w, l) = setup(8);
        assert_eq!(l.a.rows, w.rows);
        assert_eq!(l.a.cols, 8);
        assert_eq!(l.b.rows, 8);
        assert_eq!(l.b.cols, w.cols);
        assert_eq!(l.extra_macs(), (96 * 8 + 8 * 96) as u64);
    }

    #[test]
    fn a_lives_on_w_grid() {
        let (w, l) = setup(8);
        assert_eq!(l.a.params, w.params);
    }

    #[test]
    fn combined_matrix_streams_w_then_a() {
        let (w, l) = setup(4);
        let c = l.combined(&w);
        assert_eq!(c.cols, w.cols + 4);
        assert_eq!(&c.row(5)[..w.cols], w.row(5));
        assert_eq!(&c.row(5)[w.cols..], l.a.row(5));
    }

    #[test]
    fn overlap_is_high_for_matched_distributions() {
        // The paper reports ≈90% A∩W overlap; with matched σ and a 96-col
        // W row the overlap is high but not total. Sanity band:
        let (w, l) = setup(8);
        let f = l.overlap_with(&w);
        assert!(f > 0.5, "overlap {f}");
    }

    #[test]
    fn overlap_approaches_paper_value_at_realistic_width() {
        // DistilBERT-sized: W row = 768 cols → nearly every A value folded
        // appears in the W row.
        let mut rng = Rng::new(3);
        let dist = WeightDistribution::default();
        let w = synthesize_matrix(32, 768, dist, &mut rng);
        let l = LoraAdaptor::synthesize(&w, LoraConfig::default(), dist, &mut rng);
        let f = l.overlap_with(&w);
        assert!(f > 0.85, "overlap {f}");
    }

    #[test]
    fn combined_reuse_exceeds_separate() {
        // Streaming A after W (combined) must yield at least as many RC
        // hits for A elements as streaming A alone.
        let (w, l) = setup(8);
        let mut hits_combined = 0usize;
        let mut hits_alone = 0usize;
        for r in 0..w.rows {
            let mut seen = [false; 128];
            for &q in w.row(r) {
                seen[fold(q).0 as usize] = true;
            }
            for &q in l.a.row(r) {
                if seen[fold(q).0 as usize] {
                    hits_combined += 1;
                }
            }
            let mut seen_a = [false; 128];
            for &q in l.a.row(r) {
                let i = fold(q).0 as usize;
                if seen_a[i] {
                    hits_alone += 1;
                }
                seen_a[i] = true;
            }
        }
        assert!(hits_combined >= hits_alone);
    }
}
