//! Analytic per-component computation breakdown of one transformer layer
//! (reproduces Fig. 1 of the paper).
//!
//! Counts multiply-accumulate operations (MACs) for the matmul components
//! and elementwise op counts for softmax/activation/layernorm, for a given
//! sequence length. The two targets of AxLLM — linear projections and the
//! feed-forward network — dominate, which is the paper's motivation for
//! focusing reuse there.

use crate::config::ModelConfig;

/// One component of a transformer layer's compute.
#[derive(Clone, Debug, PartialEq)]
pub struct ComponentFlops {
    /// Human-readable component name (Fig. 1 legend entry).
    pub name: &'static str,
    /// Operation count (MACs for matmuls, elementwise ops otherwise).
    pub ops: u64,
    /// Whether AxLLM's reuse datapath accelerates this component (it
    /// targets weight-matrix multiplications: value locality requires the
    /// *static* quantized weight operand; dynamic QK^T / attn×V products
    /// have two activation operands).
    pub reuse_target: bool,
}

/// Per-component op counts for one layer at sequence length `seq`.
pub fn layer_breakdown(cfg: &ModelConfig, seq: usize) -> Vec<ComponentFlops> {
    let s = seq as u64;
    let d = cfg.d_model as u64;
    let ff = cfg.d_ff as u64;
    vec![
        ComponentFlops {
            name: "QKV projections",
            ops: 3 * s * d * d,
            reuse_target: true,
        },
        ComponentFlops {
            name: "Attention scores (QK^T)",
            ops: s * s * d,
            reuse_target: false,
        },
        ComponentFlops {
            name: "Softmax",
            ops: 5 * s * s * cfg.n_heads as u64,
            reuse_target: false,
        },
        ComponentFlops {
            name: "Attention x V",
            ops: s * s * d,
            reuse_target: false,
        },
        ComponentFlops {
            name: "Output projection",
            ops: s * d * d,
            reuse_target: true,
        },
        ComponentFlops {
            name: "Feed-forward FF1",
            ops: s * d * ff,
            reuse_target: true,
        },
        ComponentFlops {
            name: "Activation",
            ops: s * ff,
            reuse_target: false,
        },
        ComponentFlops {
            name: "Feed-forward FF2",
            ops: s * ff * d,
            reuse_target: true,
        },
        ComponentFlops {
            name: "LayerNorm (x2)",
            ops: 2 * 5 * s * d,
            reuse_target: false,
        },
    ]
}

/// Total ops of a breakdown.
pub fn total_ops(parts: &[ComponentFlops]) -> u64 {
    parts.iter().map(|p| p.ops).sum()
}

/// Fraction of a layer's ops covered by AxLLM's reuse targets.
pub fn reuse_target_fraction(parts: &[ComponentFlops]) -> f64 {
    let covered: u64 = parts.iter().filter(|p| p.reuse_target).map(|p| p.ops).sum();
    covered as f64 / total_ops(parts) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distilbert_targets_dominate() {
        // Paper Fig. 1: linear projections + feed-forward dominate one
        // DistilBERT layer's compute.
        let parts = layer_breakdown(&ModelConfig::distilbert(), 128);
        let frac = reuse_target_fraction(&parts);
        assert!(frac > 0.9, "reuse-target fraction {frac}");
    }

    #[test]
    fn ffn_is_majority_component() {
        // "The feedforward layer ... accounts for the majority of
        // computations in transformers (see Fig. 1)".
        let parts = layer_breakdown(&ModelConfig::distilbert(), 128);
        let total = total_ops(&parts) as f64;
        let ffn: u64 = parts
            .iter()
            .filter(|p| p.name.starts_with("Feed-forward"))
            .map(|p| p.ops)
            .sum();
        assert!(ffn as f64 / total > 0.5, "ffn share {}", ffn as f64 / total);
    }

    #[test]
    fn attention_grows_with_sequence_length() {
        let cfg = ModelConfig::distilbert();
        let short = layer_breakdown(&cfg, 32);
        let long = layer_breakdown(&cfg, 512);
        let share = |parts: &[ComponentFlops]| {
            let attn: u64 = parts
                .iter()
                .filter(|p| p.name.contains("Attention"))
                .map(|p| p.ops)
                .sum();
            attn as f64 / total_ops(parts) as f64
        };
        assert!(share(&long) > share(&short));
    }

    #[test]
    fn component_count_and_names_stable() {
        let parts = layer_breakdown(&ModelConfig::tiny(), 16);
        assert_eq!(parts.len(), 9);
        assert_eq!(parts[0].name, "QKV projections");
        assert!(parts.iter().all(|p| p.ops > 0));
    }
}
