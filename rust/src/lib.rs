//! # AxLLM — computation-reuse accelerator for quantized LLMs
//!
//! Full-system reproduction of *AxLLM: accelerator architecture for large
//! language models with computation reuse capability* (Ahadi, Modarressi,
//! Daneshtalab — CS.AR 2025).
//!
//! The paper's insight: with q-bit quantization a weight-matrix row of
//! thousands of elements draws from at most `2^q` distinct values, so in an
//! input-stationary dataflow each product `x[i] * u` needs computing once
//! per unique value `u` and can be **reused** for every repeat via a small
//! Result Cache (RC). This crate contains:
//!
//! - [`sim`] — a cycle-level simulator of the AxLLM micro-architecture
//!   (lanes, dual compute/reuse pipelines, P-way sliced buffers with
//!   collision queues and credit-based flow control) plus the multiply-only
//!   baseline and a ShiftAddLLM comparator.
//! - [`quant`] — symmetric int8 quantization and the value-locality
//!   statistics the reuse mechanism exploits.
//! - [`model`] — a synthetic quantized transformer model zoo mirroring the
//!   paper's Table I benchmarks, with LoRA adaptors and the multi-tenant
//!   [`model::AdapterRegistry`].
//! - [`workload`] — dataset-calibrated synthetic workload and request-trace
//!   generation.
//! - [`exec`] — a functional (bit-exact) implementation of the reuse
//!   datapath, used to prove exact arithmetic semantics.
//! - [`kvcache`] — the cross-request prefix KV reuse subsystem: a
//!   ref-counted paged block pool plus a prefix trie mapping shared
//!   request prefixes (system prompts, multi-turn history) to pinned
//!   block chains, with LRU eviction and preemption-with-recompute
//!   under memory pressure. Backends consult it at prefill to skip
//!   already-computed prefix tokens.
//! - [`energy`] — activity-factor energy/power and gate-count area models
//!   calibrated to the paper's 15nm synthesis anchors.
//! - [`runtime`] — PJRT CPU runtime that loads the AOT-compiled JAX/Pallas
//!   artifacts (HLO text) and executes them from Rust.
//! - [`backend`] — the unified, phase-aware `ExecutionBackend` API:
//!   pure-sim, functional (bit-exact), and PJRT execution behind one
//!   trait — batch prefill plus a session/step decode surface
//!   (`prefill`/`decode_step` over KV-cached sessions) — so the serving
//!   stack is generic over how a batch or a token actually runs.
//!   Shard-aware: `with_shards(n)` splits every projection
//!   tensor-parallel across `n` per-shard reuse caches (bit-identical
//!   logits, measured per-shard reuse rates, all-gather collective in
//!   the cost model).
//! - [`coordinator`] — a serving layer (request queue, dynamic batcher,
//!   backend-generic engine, token-level continuous batching for decode
//!   with TTFT/TPOT metrics and a per-adapter rollup) that drives batched
//!   inference through any execution backend while attributing
//!   cycles/energy through the simulator.
//!
//! Serving is **multi-tenant**: every request may name a LoRA adapter
//! ([`workload::Request::adapter`]); backends route it through the base
//! reuse pipeline plus the adapter's dense rank-r side pipeline without
//! touching the base weights — the paper's "serves fine-tuned models
//! with no parameter change" claim, measurable end-to-end through
//! [`coordinator::ServeSummary::by_adapter`].
//! - [`report`] — generators for every figure and table in the paper's
//!   evaluation (Fig. 1, Fig. 8, Fig. 9, LoRA, ShiftAddLLM, power, area,
//!   plus ablations).
//! - [`util`] — in-crate substrates (deterministic RNG, bench harness,
//!   property-test runner, TOML-subset config parser, table printer) so the
//!   crate builds fully offline.
//!
//! See `rust/DESIGN.md` for the architecture, the module map, and the
//! `Engine → ExecutionBackend → Accelerator` layering diagram; the
//! top-level `README.md` has the quickstart and the bench-reproduction
//! table.

#![warn(missing_docs)]

pub mod backend;
pub mod config;
pub mod coordinator;
pub mod energy;
pub mod exec;
pub mod kvcache;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
