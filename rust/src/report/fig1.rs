//! E1 / Fig. 1 — contribution of each part to the total computation of one
//! DistilBERT layer, and the fraction AxLLM's reuse targets cover.

use crate::config::{Dataset, ModelConfig};
use crate::model::flops::{layer_breakdown, reuse_target_fraction, total_ops};
use crate::util::table::{pct, Table};

/// Generate the Fig. 1 breakdown for `model` at `seq` tokens.
pub fn generate_for(model: &ModelConfig, seq: usize) -> Table {
    let parts = layer_breakdown(model, seq);
    let total = total_ops(&parts) as f64;
    let mut t = Table::new(
        &format!(
            "Fig. 1 — computation breakdown, one {} layer (seq={seq})",
            model.name
        ),
        &["component", "ops (M)", "share", "reuse target"],
    );
    for p in &parts {
        t.row(vec![
            p.name.to_string(),
            format!("{:.1}", p.ops as f64 / 1e6),
            pct(p.ops as f64 / total),
            if p.reuse_target { "yes" } else { "-" }.to_string(),
        ]);
    }
    t.row(vec![
        "TOTAL".into(),
        format!("{:.1}", total / 1e6),
        pct(1.0),
        pct(reuse_target_fraction(&parts)),
    ]);
    t
}

/// The paper's Fig. 1 setting: DistilBERT at its AG News mean length.
pub fn generate() -> Table {
    generate_for(&ModelConfig::distilbert(), Dataset::AgNews.mean_len())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reuse_targets_dominate_distilbert() {
        let t = generate();
        // Last row, last column: covered fraction ≥ 90% (the paper's
        // motivation for targeting projections + FFN).
        let covered: f64 = t
            .cell(t.n_rows() - 1, 3)
            .trim_end_matches('%')
            .parse()
            .unwrap();
        assert!(covered > 90.0, "covered {covered}%");
    }

    #[test]
    fn nine_components_plus_total() {
        let t = generate();
        assert_eq!(t.n_rows(), 10);
    }

    #[test]
    fn ffn_rows_largest() {
        let t = generate();
        let share = |r: usize| -> f64 {
            t.cell(r, 2).trim_end_matches('%').parse().unwrap()
        };
        // FF1 (row 5) and FF2 (row 7) each larger than attention scores
        // (row 1).
        assert!(share(5) > share(1));
        assert!(share(7) > share(1));
    }
}
