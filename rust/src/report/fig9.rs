//! E4 / Fig. 9 — AxLLM speedup over the multiply-only baseline, per
//! benchmark, in the paper's 64-lane / 256-entry / 4×64-slice
//! configuration, plus the paper's absolute-cycles anchor:
//! DistilBERT AxLLM 85.11M vs baseline 159.34M cycles.
//!
//! The paper's absolute numbers correspond to an ~80-token DistilBERT
//! workload (≈ two mean-length AG News sequences of full-model inference)
//! on this configuration — see EXPERIMENTS.md E4 for the derivation.

use crate::config::{table1_benchmarks, AcceleratorConfig};
use crate::model::Model;
use crate::report::RunCtx;
use crate::sim::{Accelerator, SimStats};
use crate::util::table::{count, Table};

/// The token count at which the paper's DistilBERT absolute cycle counts
/// are reproduced (≈ two AG News sequences through all 6 layers).
pub const ANCHOR_TOKENS: u64 = 80;

/// Simulated AxLLM-vs-baseline cycle counts for one benchmark.
pub struct Fig9Row {
    /// Benchmark key (model / dataset).
    pub model: String,
    /// AxLLM simulated counters.
    pub ax: SimStats,
    /// Multiply-only baseline counters.
    pub base: SimStats,
}

impl Fig9Row {
    /// Baseline/AxLLM cycle ratio.
    pub fn speedup(&self) -> f64 {
        self.base.cycles as f64 / self.ax.cycles as f64
    }
}

/// Simulate every benchmark (one token of matmul work per matrix,
/// row-sampled, scaled — cycle ratios are token-count invariant).
pub fn measure(ctx: RunCtx) -> Vec<Fig9Row> {
    let cfg = AcceleratorConfig::paper();
    table1_benchmarks()
        .into_iter()
        .map(|b| {
            let model = Model::new(b.model.clone(), ctx.seed);
            let ax = Accelerator::axllm(cfg)
                .run_model(&model, ctx.sample_rows, ctx.seed)
                .total;
            let base = Accelerator::baseline(cfg)
                .run_model(&model, ctx.sample_rows, ctx.seed)
                .total;
            Fig9Row {
                model: b.key(),
                ax,
                base,
            }
        })
        .collect()
}

/// Fig. 9 as a table (normalized execution time + the DistilBERT
/// absolute-cycle anchor at [`ANCHOR_TOKENS`]).
pub fn generate(ctx: RunCtx) -> Table {
    let rows = measure(ctx);
    let mut t = Table::new(
        "Fig. 9 — AxLLM speedup (64 lanes, 256-entry buffers, 4x64 slices)",
        &[
            "benchmark",
            "normalized time",
            "speedup",
            "reuse",
            "cycles/token AxLLM",
            "cycles/token base",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.model.clone(),
            format!("{:.3}", 1.0 / r.speedup()),
            format!("{:.2}x", r.speedup()),
            format!("{:.1}%", r.ax.reuse_rate() * 100.0),
            count(r.ax.cycles),
            count(r.base.cycles),
        ]);
    }
    let gmean = rows
        .iter()
        .map(|r| r.speedup().ln())
        .sum::<f64>()
        / rows.len() as f64;
    t.row(vec![
        "GEOMEAN".into(),
        format!("{:.3}", (-gmean).exp()),
        format!("{:.2}x", gmean.exp()),
        "-".into(),
        "-".into(),
        "-".into(),
    ]);
    t
}

/// The paper's absolute anchor: DistilBERT cycles at 480 tokens.
pub fn distilbert_anchor(ctx: RunCtx) -> (u64, u64) {
    let rows = measure(ctx);
    let d = &rows[0];
    (
        d.ax.cycles * ANCHOR_TOKENS,
        d.base.cycles * ANCHOR_TOKENS,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn speedups_in_paper_band() {
        // Paper: average 1.7×, DistilBERT 1.87×; all models converge.
        for r in measure(RunCtx::default()) {
            let s = r.speedup();
            assert!((1.5..2.3).contains(&s), "{}: speedup {s}", r.model);
        }
    }

    #[test]
    fn distilbert_absolute_anchor_close_to_paper() {
        // Paper: 85.11M (AxLLM) vs 159.34M (baseline) cycles.
        let (ax, base) = distilbert_anchor(RunCtx::default());
        let ax_m = ax as f64 / 1e6;
        let base_m = base as f64 / 1e6;
        assert!((75.0..95.0).contains(&ax_m), "AxLLM {ax_m}M cycles");
        assert!((145.0..175.0).contains(&base_m), "baseline {base_m}M cycles");
    }

    #[test]
    fn speedups_converge_across_models() {
        // Paper: "the reuse rate, and hence the speedup, converge to
        // similar values" (same buffer size everywhere).
        let rows = measure(RunCtx::default());
        let speedups: Vec<f64> = rows.iter().map(|r| r.speedup()).collect();
        let min = speedups.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = speedups.iter().cloned().fold(0.0, f64::max);
        assert!(max / min < 1.15, "spread too wide: {min}..{max}");
    }

    #[test]
    fn table_has_geomean_row() {
        let t = generate(RunCtx::default());
        assert_eq!(t.n_rows(), 8);
        assert_eq!(t.cell(7, 0), "GEOMEAN");
    }
}
