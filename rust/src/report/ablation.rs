//! E9–E11 ablations and claim checks:
//!
//! - **buffer sweep** (§IV "Buffer size management"): reuse rate and area
//!   vs W_buff/Out_buff size 64→4096 — the trade-off behind the paper's
//!   choice of 256–512.
//! - **slice sweep** (§IV "Partitioning for Higher Throughput"): sliced-
//!   lane throughput, collisions, and backpressure vs P ∈ {1, 2, 4, 8}.
//! - **hazard rate** (§IV pipeline): the <2% read-after-compute stall
//!   claim, measured on the sliced micro-architecture.
//! - **distribution sensitivity** (DESIGN.md §8 S1): reuse rate under
//!   Gaussian / Laplace / Student-t / uniform weight synthesis — the
//!   reuse conclusion must not be an artifact of the Gaussian choice.

use crate::config::{AcceleratorConfig, ModelConfig};
use crate::energy::AreaModel;
use crate::model::synth::{DistKind, WeightDistribution};
use crate::model::{MatKind, Model};
use crate::quant::stats::measure_locality;
use crate::report::RunCtx;
use crate::sim::{Accelerator, LaneModel};
use crate::util::rng::Rng;
use crate::util::table::{fnum, pct, Table};

/// E9: buffer-size sweep on DistilBERT weights.
pub fn buffer_sweep(ctx: RunCtx) -> Table {
    let model = Model::new(ModelConfig::distilbert(), ctx.seed);
    let w = model.matrix_rows(0, MatKind::Ff1, ctx.sample_rows);
    let area = AreaModel::default();
    let mut t = Table::new(
        "Ablation — buffer size vs reuse rate and area (DistilBERT FF1)",
        &["buffer entries", "reuse rate", "speedup (serial lane)", "area (k gates)"],
    );
    for &buf in &[64usize, 128, 256, 512, 1024, 2048, 4096] {
        let r = measure_locality(&w, buf).reuse_rate();
        let speedup = 3.0 / (3.0 * (1.0 - r) + r);
        let cfg = AcceleratorConfig {
            buffer_entries: buf,
            slices: if buf >= 4 { 4 } else { 1 },
            ..AcceleratorConfig::paper()
        };
        t.row(vec![
            buf.to_string(),
            pct(r),
            format!("{speedup:.2}x"),
            fnum(area.area(&cfg).total / 1e3, 1),
        ]);
    }
    t
}

/// E11: slice-count sweep on the cycle-accurate sliced lane.
pub struct SliceRow {
    /// Slice count (P).
    pub slices: usize,
    /// Simulated cycles of the swept matmul.
    pub cycles: u64,
    /// Elements processed per cycle.
    pub throughput_elems_per_cycle: f64,
    /// Same-cycle RC-slice collisions.
    pub collisions: u64,
    /// Cycles stalled on full collision queues.
    pub backpressure: u64,
    /// RAW-hazard stalls per lane-cycle.
    pub hazard_rate: f64,
}

/// Run the P ∈ {1, 2, 4, 8} slice sweep.
pub fn slice_sweep(ctx: RunCtx) -> Vec<SliceRow> {
    let model = Model::new(ModelConfig::distilbert(), ctx.seed);
    let w = model.matrix_rows(0, MatKind::Wq, ctx.sample_rows);
    let x = crate::sim::accelerator::synth_input(w.rows, ctx.seed);
    [1usize, 2, 4, 8]
        .iter()
        .map(|&p| {
            let cfg = AcceleratorConfig {
                slices: p,
                buffer_entries: 256,
                ..AcceleratorConfig::paper()
            };
            let acc = Accelerator::axllm(cfg).with_lane_model(LaneModel::Sliced);
            let s = acc.matmul(&x, &w).stats;
            // Counters are summed over all concurrent lanes while cycles
            // are group-maxed — normalize the stall rate per lane-cycle.
            let lanes = cfg.lanes.min(w.rows) as u64;
            SliceRow {
                slices: p,
                cycles: s.cycles,
                throughput_elems_per_cycle: s.elements as f64 / s.cycles as f64,
                collisions: s.collisions,
                backpressure: s.backpressure_stalls,
                hazard_rate: s.hazard_stalls as f64 / (s.cycles * lanes) as f64,
            }
        })
        .collect()
}

/// The slice sweep as a table.
pub fn slice_sweep_table(ctx: RunCtx) -> Table {
    let mut t = Table::new(
        "Ablation — P-way slicing (sliced lane model, DistilBERT Wq)",
        &["slices", "cycles", "elems/cycle", "collisions", "backpressure", "hazard rate"],
    );
    for r in slice_sweep(ctx) {
        t.row(vec![
            r.slices.to_string(),
            r.cycles.to_string(),
            fnum(r.throughput_elems_per_cycle, 3),
            r.collisions.to_string(),
            r.backpressure.to_string(),
            pct(r.hazard_rate),
        ]);
    }
    t
}

/// E10: the paper's <2% hazard-stall claim, measured on the §IV pipeline
/// model it is stated for (single lane, 1 fetch/cycle, repeat-in-flight
/// stalls; see [`crate::sim::lane::pipelined_hazard_scan`]). The sliced
/// micro-architecture's hazard behaviour is reported separately in the
/// slice-sweep table.
pub fn hazard_rates(ctx: RunCtx) -> Table {
    let mut t = Table::new(
        "Read-after-compute hazard stalls, §IV pipeline (paper claim: <2% of cycles)",
        &["benchmark", "hazard stall cycles", "pipeline cycles", "rate"],
    );
    let cfg = AcceleratorConfig::paper();
    for b in crate::config::table1_benchmarks() {
        let model = Model::new(b.model.clone(), ctx.seed);
        let w = model.matrix_rows(0, MatKind::Wq, ctx.sample_rows.min(16));
        let mut stalls = 0u64;
        let mut cycles = 0u64;
        for row in 0..w.rows {
            for chunk in w.row(row).chunks(cfg.buffer_entries) {
                let (s, c) = crate::sim::lane::pipelined_hazard_scan(chunk, &cfg);
                stalls += s;
                cycles += c;
            }
        }
        t.row(vec![
            b.key(),
            stalls.to_string(),
            cycles.to_string(),
            pct(stalls as f64 / cycles.max(1) as f64),
        ]);
    }
    t
}

/// Distribution-sensitivity study: reuse at 256/512/full-row chunk for
/// four synthesis families.
pub fn distribution_sensitivity(ctx: RunCtx) -> Table {
    let mut t = Table::new(
        "Sensitivity — weight distribution family vs reuse rate (768-wide rows)",
        &["distribution", "reuse @256", "reuse @512", "reuse @full row"],
    );
    for (name, kind) in [
        ("Gaussian", DistKind::Gaussian),
        ("Laplace", DistKind::Laplace),
        ("Student-t (nu=4)", DistKind::StudentT(4)),
        ("Uniform (worst case)", DistKind::Uniform),
    ] {
        let dist = WeightDistribution::default().with_kind(kind);
        let mut rng = Rng::new(ctx.seed);
        let w = crate::model::synth::synthesize_matrix(ctx.sample_rows, 768, dist, &mut rng);
        t.row(vec![
            name.to_string(),
            pct(measure_locality(&w, 256).reuse_rate()),
            pct(measure_locality(&w, 512).reuse_rate()),
            pct(measure_locality(&w, 768).reuse_rate()),
        ]);
    }
    t
}

/// Bit-width ablation: the RC holds `2^(q-1)` sign-folded entries, so the
/// quantization width q sets both the reuse opportunity and the reuse
/// cache's area. The paper fixes q=8 ("an effective tradeoff"); this
/// sweep shows why: below 8 bits reuse saturates near 100% but model
/// accuracy (SNR) collapses, above costs area.
pub fn bitwidth_sweep(ctx: RunCtx) -> Table {
    use crate::quant::quant_snr_db;
    let area = AreaModel::default();
    let mut t = Table::new(
        "Ablation — quantization bit width vs reuse, RC area, and weight SNR",
        &["bits", "RC entries", "reuse @256", "reuse @512", "RC area (k gates)", "SNR (dB)"],
    );
    for bits in [2u8, 3, 4, 5, 6, 7, 8] {
        let dist = WeightDistribution::default().with_bits(bits);
        let mut rng = Rng::new(ctx.seed);
        // Float samples + fitted grid at this width (SNR needs the floats).
        let n = ctx.sample_rows * 768;
        let samples: Vec<f32> = (0..n).map(|_| dist.sample(&mut rng)).collect();
        let params = crate::quant::QuantParams::fit(&samples, bits);
        let snr = quant_snr_db(&samples, &params);
        let data: Vec<i8> = samples.iter().map(|&v| params.quantize(v)).collect();
        let w = crate::quant::QuantMatrix::from_q(ctx.sample_rows, 768, data, params);
        let cfg = AcceleratorConfig {
            weight_bits: bits,
            ..AcceleratorConfig::paper()
        };
        t.row(vec![
            bits.to_string(),
            cfg.rc_entries().to_string(),
            pct(measure_locality(&w, 256).reuse_rate()),
            pct(measure_locality(&w, 512).reuse_rate()),
            fnum(area.area(&cfg).rc / 1e3, 1),
            fnum(snr, 1),
        ]);
    }
    t
}

/// Design-choice ablation: range vs interleaved RC-slice mapping. The
/// paper's prose implies range partitioning ("identical or close values
/// ... the same RC slice"); this quantifies what that costs vs an
/// interleaved (value mod P) mapping under value-concentrated weights.
pub fn rc_mapping_note(ctx: RunCtx) -> Table {
    // The sliced model uses range mapping (rc_slice_of); emulate
    // interleaved mapping by permuting folded values so that range
    // mapping of the permuted values equals interleaved mapping of the
    // originals: perm(u) = (u % P) * (128/P) + u / P.
    let model = Model::new(ModelConfig::distilbert(), ctx.seed);
    let w = model.matrix_rows(0, MatKind::Wq, ctx.sample_rows.min(16));
    let x = crate::sim::accelerator::synth_input(w.rows, ctx.seed);
    let cfg = AcceleratorConfig::paper();
    let p = cfg.slices as i16;
    let stride = 128i16 / p;
    let permuted_data: Vec<i8> = w
        .data
        .iter()
        .map(|&q| {
            let (u, neg) = crate::quant::fold(q);
            let u = u as i16;
            let pu = ((u % p) * stride + u / p) as u8;
            crate::quant::unfold(pu, neg)
        })
        .collect();
    let wp = crate::quant::QuantMatrix::from_q(w.rows, w.cols, permuted_data, w.params);
    let acc = Accelerator::axllm(cfg).with_lane_model(LaneModel::Sliced);
    let range = acc.matmul(&x, &w).stats;
    let inter = acc.matmul(&x, &wp).stats;
    let mut t = Table::new(
        "Design ablation — RC slice mapping under Gaussian-concentrated values",
        &["mapping", "cycles", "collisions", "elems/cycle"],
    );
    for (name, s) in [("range (paper)", range), ("interleaved (mod P)", inter)] {
        t.row(vec![
            name.to_string(),
            s.cycles.to_string(),
            s.collisions.to_string(),
            fnum(s.elements as f64 / s.cycles as f64, 3),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pct_of(t: &Table, r: usize, c: usize) -> f64 {
        t.cell(r, c).trim_end_matches('%').parse().unwrap()
    }

    #[test]
    fn buffer_sweep_monotone_reuse() {
        let t = buffer_sweep(RunCtx::default());
        let mut prev = 0.0;
        for r in 0..t.n_rows() {
            let v = pct_of(&t, r, 1);
            assert!(v >= prev, "reuse must grow with buffer size");
            prev = v;
        }
        // 256-entry row is the knee the paper picks: ≥65%.
        assert!(pct_of(&t, 2, 1) > 65.0);
    }

    #[test]
    fn slice_sweep_throughput_improves_then_saturates() {
        let rows = slice_sweep(RunCtx::default());
        assert!(rows[1].throughput_elems_per_cycle > rows[0].throughput_elems_per_cycle);
        assert!(rows[2].throughput_elems_per_cycle > rows[1].throughput_elems_per_cycle * 0.9);
    }

    #[test]
    fn hazard_rates_below_5pct() {
        // Paper claims <2%; allow margin for synthetic weights.
        let t = hazard_rates(RunCtx::default());
        for r in 0..t.n_rows() {
            assert!(pct_of(&t, r, 3) < 5.0, "row {r}: {}", t.cell(r, 3));
        }
    }

    #[test]
    fn bitwidth_sweep_tradeoff_shape() {
        let t = bitwidth_sweep(RunCtx::default());
        assert_eq!(t.n_rows(), 7);
        // Reuse @256 falls as bits grow (more distinct codes)...
        let first = pct_of(&t, 0, 2);
        let last = pct_of(&t, 6, 2);
        assert!(first > last, "reuse must fall with bit width: {first} vs {last}");
        // ...while SNR rises monotonically (the accuracy side of the
        // paper's "8-bit is an effective tradeoff").
        let mut prev = f64::NEG_INFINITY;
        for r in 0..t.n_rows() {
            let snr: f64 = t.cell(r, 5).parse().unwrap();
            assert!(snr > prev, "SNR must grow with bits");
            prev = snr;
        }
        // 8-bit row: reuse still ≥65% at 256 buffers and SNR > 30 dB.
        assert!(pct_of(&t, 6, 2) > 65.0);
        assert!(t.cell(6, 5).parse::<f64>().unwrap() > 30.0);
    }

    #[test]
    fn gaussian_beats_uniform_everywhere() {
        let t = distribution_sensitivity(RunCtx::default());
        for c in 1..=3 {
            assert!(pct_of(&t, 0, c) > pct_of(&t, 3, c));
        }
        // Even the uniform worst case reuses heavily at full-row width:
        // the pigeonhole core of the paper holds for any distribution.
        assert!(pct_of(&t, 3, 3) > 60.0);
    }

    #[test]
    fn interleaved_mapping_outperforms_range_under_concentration() {
        let t = rc_mapping_note(RunCtx::default());
        let range_cyc: f64 = t.cell(0, 1).parse().unwrap();
        let inter_cyc: f64 = t.cell(1, 1).parse().unwrap();
        assert!(
            inter_cyc <= range_cyc * 1.02,
            "interleaved {inter_cyc} should not lose to range {range_cyc}"
        );
    }
}
