//! Quantization-regime design-space sweep (ROADMAP item 4): the
//! reuse-rate / SNR-proxy / memory Pareto over group sizes.
//!
//! AxLLM's core claim is that quantization creates parameter locality a
//! reuse cache can exploit. This sweep probes the claim across the
//! quantization design space: per-group scales (FineQuant-style,
//! [`crate::quant::GroupQuantMatrix`]) improve fidelity — each group's
//! grid hugs its own amplitude — but fragment the code distribution the
//! Result Cache feeds on, because reuse cannot cross a scale boundary.
//! Compressed code streaming ([`crate::quant::compress_codes`]) moves the
//! third axis: weight-streaming bytes. One table row per swept group
//! width; surfaced as `axllm sweep-quant` and pinned by
//! `benches/quant_sweep.rs` → `BENCH_quant_sweep.json`.

use crate::config::AcceleratorConfig;
use crate::exec::{group_accounting, ExecStats};
use crate::model::synth::{synthesize_floats, WeightDistribution};
use crate::quant::{compress_codes, GroupQuantMatrix};
use crate::report::RunCtx;
use crate::sim::Accelerator;
use crate::util::rng::Rng;
use crate::util::table::{count, fnum, pct, Table};

/// Group widths the sweep visits, coarse to fine (`0` = per-tensor).
pub const GROUP_SIZES: [usize; 4] = [0, 256, 64, 16];

/// Columns of the swept weight matrix (a Llama-block-sized row slice).
pub const SWEEP_COLS: usize = 512;

/// One point of the group-size Pareto.
#[derive(Clone, Debug)]
pub struct QuantSweepRow {
    /// Swept group width (`0` = per-tensor).
    pub group_size: usize,
    /// Fitted scale groups at this width.
    pub n_groups: usize,
    /// SNR proxy of the refit quantization against the float weights, dB.
    pub snr_db: f64,
    /// Group-scoped Result-Cache reuse rate of the refit codes at the
    /// paper chunk bound.
    pub reuse_rate: f64,
    /// Raw streaming bytes: one byte per code plus the scale sidecar.
    pub raw_bytes: u64,
    /// Compressed streaming bytes ([`compress_codes`] payload + sidecar).
    pub streamed_bytes: u64,
}

impl QuantSweepRow {
    /// Human label of the group width.
    pub fn label(&self) -> String {
        if self.group_size == 0 {
            "per-tensor".to_string()
        } else {
            self.group_size.to_string()
        }
    }

    /// Streamed-over-raw byte ratio.
    pub fn ratio(&self) -> f64 {
        if self.raw_bytes == 0 {
            1.0
        } else {
            self.streamed_bytes as f64 / self.raw_bytes as f64
        }
    }
}

/// Measure the Pareto: synthesize one Gaussian weight matrix
/// (`ctx.sample_rows × SWEEP_COLS`, seeded by `ctx.seed`), refit it at
/// every swept group width, and record fidelity (SNR), group-scoped
/// reuse (RC epochs on the chunk × group grid), and streaming bytes.
pub fn measure(ctx: RunCtx) -> Vec<QuantSweepRow> {
    let rows_n = ctx.sample_rows.max(16);
    let mut rng = Rng::new(ctx.seed ^ 0x9EAD);
    let data = synthesize_floats(rows_n, SWEEP_COLS, WeightDistribution::default(), &mut rng);
    let chunk = Accelerator::axllm(AcceleratorConfig::paper()).chunk_cols();
    GROUP_SIZES
        .iter()
        .map(|&g| {
            let gq = GroupQuantMatrix::fit(rows_n, SWEEP_COLS, &data, 8, g);
            let mut st = ExecStats::default();
            for s in group_accounting(&gq.codes, gq.group_size, chunk, 1, rows_n as u64) {
                st.add(&s);
            }
            let c = compress_codes(&gq.codes.data, gq.n_groups());
            QuantSweepRow {
                group_size: g,
                n_groups: gq.n_groups(),
                snr_db: gq.snr_db(&data),
                reuse_rate: st.reuse_rate(),
                raw_bytes: c.raw_bytes + c.scale_bytes,
                streamed_bytes: c.total_bytes(),
            }
        })
        .collect()
}

/// The sweep as a table (`axllm sweep-quant`).
pub fn generate(ctx: RunCtx) -> Table {
    let rows = measure(ctx);
    let mut t = Table::new(
        "Quantization-regime sweep — reuse rate vs SNR vs streamed bytes per group size",
        &["group size", "groups", "SNR (dB)", "reuse rate", "raw B", "streamed B", "ratio"],
    );
    for r in &rows {
        t.row(vec![
            r.label(),
            r.n_groups.to_string(),
            fnum(r.snr_db, 2),
            pct(r.reuse_rate),
            count(r.raw_bytes),
            count(r.streamed_bytes),
            fnum(r.ratio(), 3),
        ]);
    }
    t
}

/// The sweep as a deterministic JSON document: fixed field order, fixed
/// decimal widths, no floating environment dependence — seeded weights
/// must produce a **byte-stable** emission (golden-pinned below and by
/// `benches/quant_sweep.rs`).
pub fn json(ctx: RunCtx) -> String {
    let rows = measure(ctx);
    let mut s = String::from("{\n  \"quant_sweep\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 == rows.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"group_size\": {}, \"n_groups\": {}, \"snr_db\": {:.3}, \
             \"reuse_rate\": {:.6}, \"raw_bytes\": {}, \"streamed_bytes\": {}, \
             \"ratio\": {:.6}}}{sep}\n",
            r.group_size,
            r.n_groups,
            r.snr_db,
            r.reuse_rate,
            r.raw_bytes,
            r.streamed_bytes,
            r.ratio(),
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_spans_the_locality_fidelity_tradeoff() {
        let rows = measure(RunCtx::default());
        assert_eq!(rows.len(), GROUP_SIZES.len());
        let pt = &rows[0];
        let finest = rows.last().unwrap();
        assert_eq!(pt.label(), "per-tensor");
        assert_eq!(pt.n_groups, 1);
        assert_eq!(finest.n_groups, SWEEP_COLS / 16);
        // The acceptance tradeoff: the finest groups trade reuse for SNR.
        assert!(
            finest.reuse_rate < pt.reuse_rate,
            "group-16 reuse {} not below per-tensor {}",
            finest.reuse_rate,
            pt.reuse_rate
        );
        assert!(
            finest.snr_db > pt.snr_db,
            "group-16 SNR {} not above per-tensor {}",
            finest.snr_db,
            pt.snr_db
        );
        for r in &rows {
            assert!(
                r.streamed_bytes < r.raw_bytes,
                "{}: streamed {} not below raw {}",
                r.label(),
                r.streamed_bytes,
                r.raw_bytes
            );
            assert!(r.ratio() > 0.0 && r.ratio() < 1.0);
            assert!(r.snr_db.is_finite() && r.reuse_rate.is_finite());
            assert!(r.reuse_rate > 0.0 && r.reuse_rate < 1.0);
        }
    }

    #[test]
    fn table_has_one_row_per_group_size() {
        let t = generate(RunCtx::default());
        assert_eq!(t.n_rows(), GROUP_SIZES.len());
        assert_eq!(t.cell(0, 0), "per-tensor");
        assert_eq!(t.cell(3, 0), "16");
    }

    #[test]
    fn golden_json_is_byte_stable_and_clean() {
        // Seeded weights must emit byte-identical JSON on every run —
        // the golden pin guarding the Pareto emitter against silent
        // drift — with no non-finite artifacts.
        let a = json(RunCtx::default());
        let b = json(RunCtx::default());
        assert_eq!(a, b, "quant_sweep JSON must be deterministic");
        assert!(a.starts_with("{\n  \"quant_sweep\": [\n"));
        assert!(a.trim_end().ends_with("]\n}"));
        assert_eq!(a.matches("\"group_size\"").count(), GROUP_SIZES.len());
        assert!(!a.contains("inf") && !a.contains("NaN") && !a.contains("nan"));
        // A different seed moves the measured cells.
        let other = json(RunCtx { seed: 43, ..RunCtx::default() });
        assert_ne!(a, other);
    }
}
