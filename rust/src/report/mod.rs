//! Report generators: one per figure/table of the paper's evaluation
//! (DESIGN.md §5 experiment index), shared by the CLI (`axllm reproduce`),
//! the benches, and the integration tests.
//!
//! Each generator returns [`Table`]s whose cells tests assert on, so the
//! reproduction claims in EXPERIMENTS.md are themselves regression-tested.

pub mod ablation;
pub mod fig1;
pub mod fig8;
pub mod fig9;
pub mod lora;
pub mod map;
pub mod power;
pub mod quant_sweep;
pub mod shiftadd;

pub use crate::util::table::Table;

/// Shared run parameters for the report generators.
#[derive(Clone, Copy, Debug)]
pub struct RunCtx {
    /// Weight-synthesis seed.
    pub seed: u64,
    /// Row-sampling bound for Llama-scale matrices (whole lane groups).
    pub sample_rows: usize,
}

impl Default for RunCtx {
    fn default() -> Self {
        RunCtx {
            seed: 42,
            sample_rows: 64,
        }
    }
}
