//! E6 — comparison with ShiftAddLLM (paper §V): at matched 64-unit /
//! 64-lane configurations on 8-bit DistilBERT, AxLLM is ≈29% faster,
//! credited to (1) parallel reuse operations and (2) no LUT setup phase.

use crate::config::{AcceleratorConfig, ModelConfig};
use crate::model::Model;
use crate::report::RunCtx;
use crate::sim::shiftadd::ShiftAddSim;
use crate::sim::Accelerator;
use crate::util::table::{count, Table};

/// AxLLM vs ShiftAddLLM cycle comparison for one model.
pub struct ShiftAddRow {
    /// Model name.
    pub model: String,
    /// AxLLM cycles for one token of matmul work.
    pub ax_cycles: u64,
    /// ShiftAddLLM cycles for the same work.
    pub sa_cycles: u64,
    /// LUT-setup share of the ShiftAddLLM cycles.
    pub sa_setup_cycles: u64,
}

impl ShiftAddRow {
    /// AxLLM speedup over ShiftAddLLM.
    pub fn axllm_speedup(&self) -> f64 {
        self.sa_cycles as f64 / self.ax_cycles as f64
    }
}

/// Measure one model (the paper uses DistilBERT as the representative).
pub fn measure_model(cfg: &ModelConfig, ctx: RunCtx) -> ShiftAddRow {
    let model = Model::new(cfg.clone(), ctx.seed);
    let ax = Accelerator::axllm(AcceleratorConfig::paper())
        .run_model(&model, ctx.sample_rows, ctx.seed)
        .total;
    let sa = ShiftAddSim::default();
    let mut sa_cycles = 0u64;
    let mut sa_setup = 0u64;
    for kind in crate::model::MatKind::ALL {
        let (r, c) = kind.shape(cfg);
        let st = sa.matmul_cycles(r, c);
        sa_cycles += st.cycles();
        sa_setup += st.setup_cycles;
    }
    sa_cycles *= cfg.n_layers as u64;
    sa_setup *= cfg.n_layers as u64;
    ShiftAddRow {
        model: cfg.name.clone(),
        ax_cycles: ax.cycles,
        sa_cycles,
        sa_setup_cycles: sa_setup,
    }
}

/// The ShiftAddLLM comparison as a table.
pub fn generate(ctx: RunCtx) -> Table {
    let r = measure_model(&ModelConfig::distilbert(), ctx);
    let mut t = Table::new(
        "AxLLM vs ShiftAddLLM (64 shift-add units vs 64 lanes, 8-bit DistilBERT, per token)",
        &["engine", "cycles/token", "setup cycles", "AxLLM speedup"],
    );
    t.row(vec![
        "AxLLM".into(),
        count(r.ax_cycles),
        "0 (no setup phase)".into(),
        "1.00x".into(),
    ]);
    t.row(vec![
        "ShiftAddLLM".into(),
        count(r.sa_cycles),
        count(r.sa_setup_cycles),
        format!("{:.2}x", r.axllm_speedup()),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axllm_about_29pct_faster_on_distilbert() {
        let r = measure_model(&ModelConfig::distilbert(), RunCtx::default());
        let s = r.axllm_speedup();
        assert!((1.15..1.45).contains(&s), "speedup {s} (paper: 1.29)");
    }

    #[test]
    fn shiftadd_setup_is_real_but_minor() {
        let r = measure_model(&ModelConfig::distilbert(), RunCtx::default());
        assert!(r.sa_setup_cycles > 0);
        assert!(r.sa_setup_cycles < r.sa_cycles / 5);
    }
}
