//! Execution-profile map sweep (ROADMAP item 5): the first mechanical
//! design-space walk the unified [`ExecProfile`] plane unlocks.
//!
//! Because a full execution configuration is now plain data, a seeded
//! grid of profiles — tensor-parallel shard counts crossed with
//! quantization regimes — can be enumerated, constructed uniformly via
//! `ExecutionBackend::from_profile`, and evaluated against one
//! deterministic trace. Each grid point reports the three axes the
//! accelerator trades between: serving throughput (tokens/s on the sim
//! backend's virtual clock), quantization fidelity (SNR of the refit
//! codes, the same proxy [`crate::report::quant_sweep`] uses), and
//! weight-streaming traffic (cost-model bytes/token). Rows on the
//! Pareto front are flagged; surfaced as `axllm map` and pinned by
//! `benches/map_sweep.rs` → `BENCH_map_sweep.json`.

use crate::backend::SimBackend;
use crate::config::{BackendKind, Dataset, ExecProfile, ModelConfig};
use crate::coordinator::{BatchPolicy, Engine};
use crate::model::synth::{synthesize_floats, WeightDistribution};
use crate::quant::{GroupQuantMatrix, QuantRegime};
use crate::report::RunCtx;
use crate::util::rng::Rng;
use crate::util::table::{count, fnum, Table};
use crate::workload::TraceGenerator;

/// Shard counts the grid visits.
pub const SHARD_GRID: [usize; 3] = [1, 2, 4];

/// Quantization regimes the grid visits: the compressed-streaming
/// column of the quant sweep plus two raw-streaming points, so the
/// bytes axis spans both storage paths.
pub fn quant_grid() -> Vec<QuantRegime> {
    vec![
        QuantRegime::per_tensor().with_compressed(true),
        QuantRegime::grouped(256).with_compressed(true),
        QuantRegime::grouped(64).with_compressed(true),
        QuantRegime::grouped(16).with_compressed(true),
        QuantRegime::grouped(64),
        QuantRegime::grouped(16),
    ]
}

/// Columns of the SNR probe matrix (matches the quant sweep).
pub const SNR_COLS: usize = 512;

/// The enumerated profile grid: shards × quant regimes on the sim
/// backend (the only backend with an analytic cost surface to sweep).
pub fn grid(seed: u64) -> Vec<ExecProfile> {
    let mut g = Vec::new();
    for &shards in &SHARD_GRID {
        for q in quant_grid() {
            let mut p = ExecProfile::new(BackendKind::Sim)
                .with_shards(shards)
                .with_quant(q);
            p.seed = seed;
            g.push(p);
        }
    }
    g
}

/// One evaluated grid point.
#[derive(Clone, Debug)]
pub struct MapRow {
    /// Compact profile label (`ExecProfile::label`), e.g. `sim×2 g64c`.
    pub label: String,
    /// Tensor-parallel shard count.
    pub shards: usize,
    /// Quant group width (`0` = per-tensor).
    pub group_size: usize,
    /// Compressed weight-code streaming on?
    pub compressed: bool,
    /// Serving throughput on the deterministic trace, tokens/s.
    pub tokens_per_s: f64,
    /// SNR of the refit quantization at this group width, dB.
    pub snr_db: f64,
    /// Cost-model weight-code streaming, bytes/token.
    pub streamed_bytes_per_token: f64,
    /// On the max-tps / max-SNR / min-bytes Pareto front?
    pub pareto: bool,
}

/// Throughput of one profile against the shared deterministic trace:
/// construct through the uniform `from_profile` path, serve the seeded
/// prefill trace on the virtual clock, report tokens/s.
pub fn evaluate(profile: &ExecProfile, requests: usize) -> f64 {
    let model_cfg = ModelConfig::tiny();
    let engine = Engine::<SimBackend>::from_profile(&model_cfg, profile)
        .expect("map grid profiles must construct");
    let trace = TraceGenerator::new(Dataset::Imdb, 200.0, profile.seed).take(requests.max(1));
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait_s: 0.010,
    };
    let (_results, summary) = engine
        .serve_trace(trace, policy)
        .expect("sim trace serving is infallible on a valid profile");
    summary.throughput_tps
}

/// `true` at every index on the Pareto front of
/// (max `tokens_per_s`, max `snr_db`, min `streamed_bytes_per_token`).
fn pareto_front(rows: &[MapRow]) -> Vec<bool> {
    rows.iter()
        .map(|r| {
            !rows.iter().any(|o| {
                let ge = o.tokens_per_s >= r.tokens_per_s
                    && o.snr_db >= r.snr_db
                    && o.streamed_bytes_per_token <= r.streamed_bytes_per_token;
                let strict = o.tokens_per_s > r.tokens_per_s
                    || o.snr_db > r.snr_db
                    || o.streamed_bytes_per_token < r.streamed_bytes_per_token;
                ge && strict
            })
        })
        .collect()
}

/// Evaluate the whole grid: one row per profile, Pareto flags filled.
///
/// SNR is probed once per group width on a seeded
/// `ctx.sample_rows × SNR_COLS` Gaussian matrix (codes are independent
/// of the shard count and of the storage path, so the probe is shared
/// across rows of equal width).
pub fn measure(ctx: RunCtx, requests: usize) -> Vec<MapRow> {
    let rows_n = ctx.sample_rows.max(16);
    let mut rng = Rng::new(ctx.seed ^ 0x9EAD);
    let data = synthesize_floats(rows_n, SNR_COLS, WeightDistribution::default(), &mut rng);
    let snr_of = |group_size: usize| -> f64 {
        GroupQuantMatrix::fit(rows_n, SNR_COLS, &data, 8, group_size).snr_db(&data)
    };
    let mut rows: Vec<MapRow> = grid(ctx.seed)
        .iter()
        .map(|p| MapRow {
            label: p.label(),
            shards: p.shards,
            group_size: p.quant.group_size,
            compressed: p.quant.compressed,
            tokens_per_s: evaluate(p, requests),
            snr_db: snr_of(p.quant.group_size),
            streamed_bytes_per_token: {
                let model_cfg = ModelConfig::tiny();
                let engine = Engine::<SimBackend>::from_profile(&model_cfg, p)
                    .expect("map grid profiles must construct");
                engine.cost().weight_bytes_streamed_per_token
            },
            pareto: false,
        })
        .collect();
    let front = pareto_front(&rows);
    for (r, on) in rows.iter_mut().zip(front) {
        r.pareto = on;
    }
    rows
}

/// Index of the highest-throughput row (first wins ties, so the choice
/// is deterministic).
pub fn best(rows: &[MapRow]) -> usize {
    let mut bi = 0;
    for (i, r) in rows.iter().enumerate() {
        if r.tokens_per_s > rows[bi].tokens_per_s {
            bi = i;
        }
    }
    bi
}

/// The map as a table (`axllm map`).
pub fn generate(ctx: RunCtx, requests: usize) -> Table {
    let rows = measure(ctx, requests);
    let bi = best(&rows);
    let mut t = Table::new(
        "Execution-profile map — tokens/s vs SNR vs streamed bytes over the profile grid",
        &["profile", "shards", "group", "tok/s", "SNR (dB)", "stream B/tok", "pareto"],
    );
    for (i, r) in rows.iter().enumerate() {
        t.row(vec![
            r.label.clone(),
            r.shards.to_string(),
            if r.group_size == 0 {
                "per-tensor".to_string()
            } else {
                r.group_size.to_string()
            },
            fnum(r.tokens_per_s, 1),
            fnum(r.snr_db, 2),
            count(r.streamed_bytes_per_token.round() as u64),
            match (r.pareto, i == bi) {
                (true, true) => "* best".to_string(),
                (true, false) => "*".to_string(),
                _ => String::new(),
            },
        ]);
    }
    t
}

/// The map as a deterministic JSON document: fixed field order, fixed
/// decimal widths, byte-stable for a given seed (golden-pinned below
/// and by `benches/map_sweep.rs`).
pub fn json(ctx: RunCtx, requests: usize) -> String {
    let rows = measure(ctx, requests);
    let bi = best(&rows);
    let mut s = format!(
        "{{\n  \"seed\": {}, \"requests\": {}, \"best\": {},\n  \"map\": [\n",
        ctx.seed, requests, bi
    );
    for (i, r) in rows.iter().enumerate() {
        let sep = if i + 1 == rows.len() { "" } else { "," };
        s.push_str(&format!(
            "    {{\"label\": \"{}\", \"shards\": {}, \"group_size\": {}, \
             \"compressed\": {}, \"tokens_per_s\": {:.3}, \"snr_db\": {:.3}, \
             \"streamed_bytes_per_token\": {:.3}, \"pareto\": {}}}{sep}\n",
            r.label,
            r.shards,
            r.group_size,
            r.compressed,
            r.tokens_per_s,
            r.snr_db,
            r.streamed_bytes_per_token,
            r.pareto,
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    const REQS: usize = 16;

    #[test]
    fn grid_meets_the_sweep_floor() {
        let g = grid(42);
        assert!(g.len() >= 16, "grid has only {} profiles", g.len());
        assert_eq!(g.len(), SHARD_GRID.len() * quant_grid().len());
        for p in &g {
            p.validate().unwrap();
            assert_eq!(p.backend, BackendKind::Sim);
        }
    }

    #[test]
    fn map_spans_the_three_axes_and_flags_a_front() {
        let rows = measure(RunCtx::default(), REQS);
        assert_eq!(rows.len(), grid(42).len());
        for r in &rows {
            assert!(r.tokens_per_s.is_finite() && r.tokens_per_s > 0.0, "{}", r.label);
            assert!(r.snr_db.is_finite(), "{}", r.label);
            assert!(
                r.streamed_bytes_per_token.is_finite() && r.streamed_bytes_per_token > 0.0,
                "{}",
                r.label
            );
        }
        let n_front = rows.iter().filter(|r| r.pareto).count();
        assert!(n_front >= 2, "degenerate Pareto front ({n_front} rows)");
        assert!(n_front < rows.len(), "everything on the front — axes collapsed");
        // The best-throughput row can never be dominated.
        assert!(rows[best(&rows)].pareto, "best row off its own front");
        // Compression moves only the bytes axis: at equal shards/width,
        // the compressed row streams strictly fewer bytes.
        let find = |g: usize, c: bool| {
            rows.iter()
                .find(|r| r.shards == 1 && r.group_size == g && r.compressed == c)
                .unwrap()
        };
        assert!(
            find(64, true).streamed_bytes_per_token < find(64, false).streamed_bytes_per_token
        );
        assert_eq!(find(64, true).snr_db, find(64, false).snr_db);
    }

    #[test]
    fn golden_json_is_byte_stable_and_clean() {
        let a = json(RunCtx::default(), REQS);
        let b = json(RunCtx::default(), REQS);
        assert_eq!(a, b, "map JSON must be deterministic");
        assert!(a.starts_with("{\n  \"seed\": 42"));
        assert!(a.trim_end().ends_with("]\n}"));
        assert_eq!(a.matches("\"label\"").count(), grid(42).len());
        assert!(!a.contains("inf") && !a.contains("NaN") && !a.contains("nan"));
        // A different trace seed moves the throughput cells.
        let other = json(RunCtx { seed: 43, ..RunCtx::default() }, REQS);
        assert_ne!(a, other);
    }
}
