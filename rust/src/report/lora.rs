//! E5 — LoRA adaptor reuse (paper §III.c Fig. 5 + §V):
//! ≈90% of each A-row's values repeat in the matching W row, and the
//! adaptor-matrix execution speeds up ≈1.8× through W∥A sharing.

use crate::config::{AcceleratorConfig, LoraConfig, ModelConfig};
use crate::model::{LoraAdaptor, MatKind, Model};
use crate::report::RunCtx;
use crate::util::table::{pct, Table};

/// LoRA reuse measurements for one fine-tuned benchmark.
pub struct LoraRow {
    /// Model name.
    pub model: String,
    /// Mean fraction of A-row values present in the matching W row.
    pub overlap: f64,
    /// Speedup of the adaptor-matrix (A) execution via the combined W∥A
    /// stream vs the multiply-only baseline on A alone.
    pub adaptor_speedup: f64,
    /// Reuse rate observed on the A columns of the combined stream.
    pub a_reuse: f64,
}

fn measure_one(cfg: &ModelConfig, ctx: RunCtx) -> LoraRow {
    let lora_cfg = cfg.lora.unwrap_or_default();
    let model = Model::new(
        ModelConfig {
            lora: None,
            ..cfg.clone()
        },
        ctx.seed,
    );
    let acc_cfg = AcceleratorConfig::paper();
    let rows = ctx.sample_rows;
    let mut overlap = 0.0;
    let mut a_cycles_combined = 0u64;
    let mut a_cycles_base = 0u64;
    let mut a_hits = 0u64;
    let mut a_elems = 0u64;
    // Q and V attachments of layer 0 (the standard LoRA points).
    for kind in [MatKind::Wq, MatKind::Wv] {
        let w = model.matrix_rows(0, kind, rows);
        let mut rng = crate::util::rng::Rng::new(ctx.seed ^ 0xA0A0 ^ kind as u64);
        let adaptor = LoraAdaptor::synthesize(&w, lora_cfg, model.dist, &mut rng);
        overlap += adaptor.overlap_with(&w);

        // Cycle accounting for the A columns (paper Fig. 5): the lane's
        // final W_buff chunk of each row holds the last
        // (buffer − rank) W columns followed by the row's rank A
        // columns, so A streams against an RC warmed by W. The marginal
        // cycles of the A columns = chunk(W-tail ∥ A) − chunk(W-tail);
        // the comparison baseline is multiply-only on A alone.
        let r = lora_cfg.rank;
        let tail = acc_cfg.buffer_entries - r;
        let x = crate::sim::accelerator::synth_input(rows, ctx.seed ^ 7);
        for row in 0..w.rows {
            let wrow = w.row(row);
            let wtail = &wrow[wrow.len() - tail..];
            let mut chunk: Vec<i8> = wtail.to_vec();
            chunk.extend_from_slice(adaptor.a.row(row));
            let with_a = crate::sim::lane::simulate_chunk(x[row], &chunk, &acc_cfg).stats;
            let w_only = crate::sim::lane::simulate_chunk(x[row], wtail, &acc_cfg).stats;
            let base_a =
                crate::sim::baseline::simulate_chunk(x[row], adaptor.a.row(row), &acc_cfg).stats;
            a_cycles_combined += with_a.cycles - w_only.cycles;
            a_cycles_base += base_a.cycles - acc_cfg.buf_latency as u64; // marginal, no refill
            a_hits += with_a.rc_hits - w_only.rc_hits;
            a_elems += r as u64;
        }
    }
    LoraRow {
        model: cfg.name.clone(),
        overlap: overlap / 2.0,
        adaptor_speedup: a_cycles_base as f64 / a_cycles_combined.max(1) as f64,
        a_reuse: a_hits as f64 / a_elems.max(1) as f64,
    }
}

/// Measure the two fine-tuned benchmarks of Table I.
pub fn measure(ctx: RunCtx) -> Vec<LoraRow> {
    vec![
        measure_one(
            &ModelConfig::bert_base().with_lora(LoraConfig::default()),
            ctx,
        ),
        measure_one(
            &ModelConfig::distilbert().with_lora(LoraConfig::default()),
            ctx,
        ),
    ]
}

/// The Fig. 5 LoRA-reuse measurements as a table.
pub fn generate(ctx: RunCtx) -> Table {
    let mut t = Table::new(
        "LoRA adaptor reuse via the combined W||A stream (Fig. 5)",
        &["model", "A-in-W overlap", "A reuse rate", "adaptor speedup"],
    );
    for r in measure(ctx) {
        t.row(vec![
            r.model,
            pct(r.overlap),
            pct(r.a_reuse),
            format!("{:.2}x", r.adaptor_speedup),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_near_90pct() {
        // Paper: "an average of 90% of the elements of each row of the
        // adaptor matrix A repeats in the corresponding row in W".
        for r in measure(RunCtx::default()) {
            assert!((0.80..1.0).contains(&r.overlap), "{}: {}", r.model, r.overlap);
        }
    }

    #[test]
    fn adaptor_speedup_at_least_paper_value() {
        // Paper: 1.82× (BERT/IMDb) and 1.81× (DistilBERT/Yelp). Our
        // Fig. 5 implementation lands ≈2.5× because ≥90% A-in-W overlap
        // makes the marginal A-element cost ≈1.2 cycles vs 3; the paper's
        // lower figure suggests their accounting also charges cold chunks
        // or the (x·A)·B stage (see EXPERIMENTS.md E5).
        for r in measure(RunCtx::default()) {
            assert!(
                (1.5..2.9).contains(&r.adaptor_speedup),
                "{}: {}",
                r.model,
                r.adaptor_speedup
            );
        }
    }

    #[test]
    fn a_reuse_exceeds_standalone() {
        // Sharing W's RC must make A's reuse at least as high as the
        // overlap statistic implies.
        for r in measure(RunCtx::default()) {
            assert!(r.a_reuse > 0.6, "{}: a_reuse {}", r.model, r.a_reuse);
        }
    }
}
