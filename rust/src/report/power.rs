//! E7+E8 — power/energy (§V "Power consumption") and area (§V "Area").
//!
//! Power: the paper reports "average power ... reduced from 0.94 W to
//! 0.67 W" (−28%) alongside a 1.87× speedup. We anchor the baseline at
//! 0.94 W via calibration (substitution S3) and report AxLLM's **energy
//! normalized to the baseline runtime** — the quantity for which the
//! "0.94 → 0.67, −28%" statement is self-consistent (see
//! `energy::power` module docs and EXPERIMENTS.md).

use crate::config::{AcceleratorConfig, ModelConfig};
use crate::energy::{AreaModel, EnergyModel};
use crate::model::Model;
use crate::report::RunCtx;
use crate::sim::{Accelerator, SimStats};
use crate::util::table::{fnum, pct, Table};

/// Calibrated power/energy comparison of one DistilBERT layer (paper §V).
pub struct PowerResult {
    /// Simulated activity of the multiply-only baseline.
    pub base_stats: SimStats,
    /// Simulated activity of AxLLM on the same layer.
    pub ax_stats: SimStats,
    /// Baseline average power (calibrated to the paper's 0.94 W).
    pub base_power_w: f64,
    /// AxLLM energy normalized to the baseline's runtime (the figure the
    /// paper's "0.67 W" corresponds to — see module docs).
    pub ax_iso_time_power_w: f64,
    /// AxLLM average power over its own (shorter) runtime.
    pub ax_true_power_w: f64,
    /// AxLLM / baseline total-energy ratio.
    pub energy_ratio: f64,
    /// Multiplier share of the baseline's energy (the paper's motivation
    /// for attacking multiplications first).
    pub mult_energy_share_base: f64,
}

/// Simulate one DistilBERT layer on both datapaths and calibrate the
/// energy model so the baseline dissipates the paper's 0.94 W.
pub fn measure(ctx: RunCtx) -> PowerResult {
    let cfg = AcceleratorConfig::paper();
    let mut model_cfg = ModelConfig::distilbert();
    model_cfg.n_layers = 1; // one layer, as in the paper's power experiment
    let model = Model::new(model_cfg, ctx.seed);
    let ax_stats = Accelerator::axllm(cfg)
        .run_model(&model, ctx.sample_rows, ctx.seed)
        .total;
    let base_stats = Accelerator::baseline(cfg)
        .run_model(&model, ctx.sample_rows, ctx.seed)
        .total;
    let em = EnergyModel::default().calibrate(&base_stats, 0.94, cfg.freq_ghz);
    let base_e = em.energy(&base_stats);
    let ax_e = em.energy(&ax_stats);
    PowerResult {
        base_stats,
        ax_stats,
        base_power_w: em.avg_power_w(&base_stats, cfg.freq_ghz),
        ax_iso_time_power_w: em.iso_time_power_w(&ax_stats, base_stats.cycles, cfg.freq_ghz),
        ax_true_power_w: em.avg_power_w(&ax_stats, cfg.freq_ghz),
        energy_ratio: ax_e.total_pj / base_e.total_pj,
        mult_energy_share_base: base_e.mult_pj / base_e.total_pj,
    }
}

/// The power/energy comparison as a table.
pub fn generate(ctx: RunCtx) -> Table {
    let r = measure(ctx);
    let mut t = Table::new(
        "Power & energy — one DistilBERT layer (baseline anchored at the paper's 0.94 W)",
        &["metric", "baseline", "AxLLM", "reduction"],
    );
    t.row(vec![
        "energy-derived power @ baseline runtime (W)".into(),
        fnum(r.base_power_w, 2),
        fnum(r.ax_iso_time_power_w, 2),
        pct(1.0 - r.energy_ratio),
    ]);
    t.row(vec![
        "true average power over own runtime (W)".into(),
        fnum(r.base_power_w, 2),
        fnum(r.ax_true_power_w, 2),
        pct(1.0 - r.ax_true_power_w / r.base_power_w),
    ]);
    t.row(vec![
        "multiplications (M)".into(),
        fnum(r.base_stats.mults as f64 / 1e6, 2),
        fnum(r.ax_stats.mults as f64 / 1e6, 2),
        pct(1.0 - r.ax_stats.mults as f64 / r.base_stats.mults as f64),
    ]);
    t.row(vec![
        "cycles (M)".into(),
        fnum(r.base_stats.cycles as f64 / 1e6, 2),
        fnum(r.ax_stats.cycles as f64 / 1e6, 2),
        pct(1.0 - r.ax_stats.cycles as f64 / r.base_stats.cycles as f64),
    ]);
    t
}

/// E8 — the area table.
pub fn generate_area() -> Table {
    let m = AreaModel::default();
    let ax = m.area(&AcceleratorConfig::paper());
    let base = m.area(&AcceleratorConfig::baseline());
    let mut t = Table::new(
        "Area — 64-lane AxLLM, 15nm-class gate equivalents (paper: 132k gates, 28/44/19/9%)",
        &["component", "gates (k)", "share"],
    );
    for (name, gates) in [
        ("input/output buffers", ax.buffers),
        ("multipliers + accumulators", ax.mult_acc),
        ("reuse cache", ax.rc),
        ("controller (incl. queues)", ax.controller),
    ] {
        t.row(vec![
            name.to_string(),
            fnum(gates / 1e3, 1),
            pct(gates / ax.total),
        ]);
    }
    t.row(vec![
        "TOTAL".into(),
        fnum(ax.total / 1e3, 1),
        pct(1.0),
    ]);
    t.row(vec![
        "baseline (no reuse)".into(),
        fnum(base.total / 1e3, 1),
        "-".into(),
    ]);
    t.row(vec![
        "reuse overhead".into(),
        fnum(ax.reuse_overhead / 1e3, 1),
        pct(ax.overhead_fraction()),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_anchored_at_paper_power() {
        let r = measure(RunCtx::default());
        assert!((r.base_power_w - 0.94).abs() < 1e-6);
    }

    #[test]
    fn iso_time_power_near_067() {
        // Paper: 0.94 W → 0.67 W.
        let r = measure(RunCtx::default());
        assert!(
            (0.60..0.75).contains(&r.ax_iso_time_power_w),
            "AxLLM iso-time power {}",
            r.ax_iso_time_power_w
        );
    }

    #[test]
    fn energy_reduction_near_28pct() {
        let r = measure(RunCtx::default());
        let red = 1.0 - r.energy_ratio;
        assert!((0.22..0.36).contains(&red), "energy reduction {red}");
    }

    #[test]
    fn mult_energy_dominates_baseline() {
        // "replacing power-hungry multipliers with more power-efficient
        // buffer reuse" requires multipliers to dominate baseline energy.
        let r = measure(RunCtx::default());
        assert!(
            r.mult_energy_share_base > 0.5,
            "mult share {}",
            r.mult_energy_share_base
        );
    }

    #[test]
    fn area_table_matches_paper_shape() {
        let t = generate_area();
        assert_eq!(t.n_rows(), 7);
        let total: f64 = t.cell(4, 1).parse().unwrap();
        assert!((125.0..139.0).contains(&total), "total {total}k");
        let overhead: f64 = t.cell(6, 2).trim_end_matches('%').parse().unwrap();
        assert!((19.0..27.0).contains(&overhead), "overhead {overhead}%");
    }
}
