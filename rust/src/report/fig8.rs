//! E2+E3 / Table I + Fig. 8 — the benchmark suite and the computation
//! reuse rate of every model, with full-row buffers vs 256-entry buffers.
//!
//! Paper claims: ≥87% minimum reuse (full-row series), ≈70% average with
//! 256-entry buffers, and reuse growing with matrix size.

use crate::config::table1_benchmarks;
use crate::model::{MatKind, Model};
use crate::quant::stats::measure_locality;
use crate::report::RunCtx;
use crate::util::table::{pct, Table};

/// Table I: the benchmark suite.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I — datasets, tasks, and pre-trained models",
        &["model", "dataset", "weight matrix"],
    );
    for b in table1_benchmarks() {
        let (r, c) = b.weight_matrix();
        t.row(vec![
            b.model.name.clone(),
            b.dataset.name().to_string(),
            format!("{r}x{c}"),
        ]);
    }
    t
}

/// Measured reuse rates per benchmark. Rates average over all six weight
/// matrices of the first and middle layer (row-sampled on Llama-scale
/// models), mirroring the paper's "across different layers and across the
/// vectors in each layer".
pub struct Fig8Row {
    /// Benchmark key (model / dataset).
    pub model: String,
    /// Reuse rate with whole-row caching (unbounded buffer).
    pub reuse_full_row: f64,
    /// Reuse rate at a 512-entry buffer chunk.
    pub reuse_512: f64,
    /// Reuse rate at the paper's 256-entry buffer chunk.
    pub reuse_256: f64,
}

/// Measure every benchmark's reuse-rate profile.
pub fn measure(ctx: RunCtx) -> Vec<Fig8Row> {
    table1_benchmarks()
        .into_iter()
        .map(|b| {
            let model = Model::new(b.model.clone(), ctx.seed);
            let layers = [0, b.model.n_layers / 2];
            let mut acc = [0.0f64; 3];
            let mut n = 0usize;
            for &l in &layers {
                for kind in MatKind::ALL {
                    let w = model.matrix_rows(l, kind, ctx.sample_rows);
                    acc[0] += measure_locality(&w, w.cols).reuse_rate();
                    acc[1] += measure_locality(&w, 512).reuse_rate();
                    acc[2] += measure_locality(&w, 256).reuse_rate();
                    n += 1;
                }
            }
            Fig8Row {
                model: b.key(),
                reuse_full_row: acc[0] / n as f64,
                reuse_512: acc[1] / n as f64,
                reuse_256: acc[2] / n as f64,
            }
        })
        .collect()
}

/// Fig. 8 as a table.
pub fn generate(ctx: RunCtx) -> Table {
    let rows = measure(ctx);
    let mut t = Table::new(
        "Fig. 8 — computation reuse rate (weights 8-bit, sign-folded 128-entry RC)",
        &["benchmark", "full-row buffers", "512-entry buffers", "256-entry buffers"],
    );
    for r in &rows {
        t.row(vec![
            r.model.clone(),
            pct(r.reuse_full_row),
            pct(r.reuse_512),
            pct(r.reuse_256),
        ]);
    }
    let mean = |f: fn(&Fig8Row) -> f64| rows.iter().map(f).sum::<f64>() / rows.len() as f64;
    t.row(vec![
        "MEAN".into(),
        pct(mean(|r| r.reuse_full_row)),
        pct(mean(|r| r.reuse_512)),
        pct(mean(|r| r.reuse_256)),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_seven_benchmarks() {
        let t = table1();
        assert_eq!(t.n_rows(), 7);
        assert_eq!(t.cell(5, 2), "4096x4096");
    }

    #[test]
    fn full_row_reuse_at_least_87pct_band() {
        // Paper: "this rate is 87% at minimum" (full-row series).
        let rows = measure(RunCtx::default());
        for r in &rows {
            assert!(
                r.reuse_full_row > 0.85,
                "{}: full-row reuse {}",
                r.model,
                r.reuse_full_row
            );
        }
    }

    #[test]
    fn reuse_256_averages_near_70pct() {
        // Paper: "all models achieve a similar reuse rate, averaging
        // about 70%" with 256-entry buffers.
        let rows = measure(RunCtx::default());
        let mean: f64 =
            rows.iter().map(|r| r.reuse_256).sum::<f64>() / rows.len() as f64;
        assert!((0.62..0.80).contains(&mean), "mean 256-buffer reuse {mean}");
    }

    #[test]
    fn reuse_grows_with_matrix_size() {
        // Paper: "The reuse rate grows with matrix size".
        let rows = measure(RunCtx::default());
        let distil = rows[0].reuse_full_row;
        let llama13 = rows[6].reuse_full_row;
        assert!(llama13 > distil, "llama {llama13} !> distilbert {distil}");
    }

    #[test]
    fn chunked_rates_ordered() {
        for r in measure(RunCtx::default()) {
            assert!(r.reuse_full_row >= r.reuse_512);
            assert!(r.reuse_512 >= r.reuse_256);
        }
    }
}
