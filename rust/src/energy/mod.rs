//! Energy/power and area models (DESIGN.md §7; substitution S3).
//!
//! The paper feeds simulator activity factors into a VHDL model synthesized
//! with the NanGate 15nm open cell library; no synthesis toolchain exists
//! in this environment, so we use an analytic activity-factor model with
//! per-operation energies in the published range for 15nm-class logic,
//! **calibrated to the paper's absolute anchors**: 0.94 W baseline power on
//! one DistilBERT layer, 132k-gate AxLLM area with a 28/44/19/9% component
//! split and 23% reuse overhead. Relative savings — the quantities the
//! paper's claims are about — depend on activity *ratios* measured by the
//! simulator, not on the absolute pJ constants.

pub mod area;
pub mod power;

pub use area::{AreaModel, AreaReport};
pub use power::{EnergyModel, EnergyReport};
