//! Gate-count area model, calibrated to the paper's synthesis anchors
//! (§V "Area"): 132k gates total for the 64-lane configuration, split
//! 28% buffers / 44% multipliers+accumulators / 19% reuse cache /
//! 9% controller, with a 23% reuse overhead (the RC plus 4 points of
//! controller area).

use crate::config::AcceleratorConfig;

/// Per-structure area constants in NAND2-equivalent gates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AreaModel {
    /// SRAM-style buffer bit (W_buff, Out_buff).
    pub gates_per_sram_bit: f64,
    /// Flop-array bit (result cache — flops for single-cycle access).
    pub gates_per_ff_bit: f64,
    /// One 8×8 multiplier + 24-bit accumulator + pipeline registers.
    pub gates_per_mult_acc: f64,
    /// One 32-bit adder-tree node.
    pub gates_per_tree_add: f64,
    /// Base controller per lane.
    pub gates_ctrl_per_lane: f64,
    /// Extra controller per slice (arbiters, credit counters).
    pub gates_ctrl_per_slice: f64,
    /// One queue slot (request-width flops + control).
    pub gates_per_queue_slot: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        // Calibrated so that `AcceleratorConfig::paper()` reproduces the
        // paper's 132k gates and 28/44/19/9 split (tests assert this).
        AreaModel {
            gates_per_sram_bit: 0.094,
            gates_per_ff_bit: 0.172,
            gates_per_mult_acc: 760.0,
            gates_per_tree_add: 150.0,
            gates_ctrl_per_lane: 90.0,
            gates_ctrl_per_slice: 3.0,
            gates_per_queue_slot: 2.0,
        }
    }
}

/// Area breakdown in gate equivalents.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AreaReport {
    /// W_buff + Out_buff gates.
    pub buffers: f64,
    /// Multiplier/accumulator + adder-tree gates.
    pub mult_acc: f64,
    /// Result-Cache gates.
    pub rc: f64,
    /// Controller gates (arbiters, credits, sequencing).
    pub controller: f64,
    /// Total gate count.
    pub total: f64,
    /// Gates attributable to reuse support (RC + reuse share of the
    /// controller) — the paper's "23% overhead".
    pub reuse_overhead: f64,
}

impl AreaReport {
    /// Reuse-support gates as a fraction of the total.
    pub fn overhead_fraction(&self) -> f64 {
        self.reuse_overhead / self.total
    }
}

impl AreaModel {
    /// Area of one accelerator configuration.
    pub fn area(&self, cfg: &AcceleratorConfig) -> AreaReport {
        let lanes = cfg.lanes as f64;
        let w_bits = lanes * cfg.buffer_entries as f64 * cfg.weight_bits as f64;
        // Out_buff holds 16-bit partial sums.
        let out_bits = lanes * cfg.buffer_entries as f64 * 16.0;
        let buffers = (w_bits + out_bits) * self.gates_per_sram_bit;

        let tree_adders = (cfg.lanes.saturating_sub(1)) as f64;
        let mult_acc = lanes * self.gates_per_mult_acc + tree_adders * self.gates_per_tree_add;

        // RC: product (16b) + valid/pending flags per entry.
        let rc = if cfg.reuse_enabled {
            lanes * cfg.rc_entries() as f64 * 18.0 * self.gates_per_ff_bit
        } else {
            0.0
        };

        // Queues exist per slice (collision + output queues) — reuse
        // machinery; the remaining controller is common.
        let common_ctrl = lanes
            * (self.gates_ctrl_per_lane + cfg.slices as f64 * self.gates_ctrl_per_slice);
        let reuse_ctrl = if cfg.reuse_enabled {
            // Per-slice skid-buffer queues: 2P+1 queue structures per lane
            // (P collision queues, P miss queues, 1 multiplier output
            // queue — the RTL shares the per-producer fan-in within each),
            // `queue_depth` slots each.
            let slots = (2 * cfg.slices + 1) * cfg.queue_depth;
            lanes * slots as f64 * self.gates_per_queue_slot
        } else {
            0.0
        };
        let controller = common_ctrl + reuse_ctrl;
        let total = buffers + mult_acc + rc + controller;
        AreaReport {
            buffers,
            mult_acc,
            rc,
            controller,
            total,
            reuse_overhead: rc + reuse_ctrl,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_matches_synthesis_anchors() {
        let a = AreaModel::default().area(&AcceleratorConfig::paper());
        // 132k gates ±5%.
        assert!(
            (125_000.0..139_000.0).contains(&a.total),
            "total {} gates",
            a.total
        );
        // Component split ±4 points of 28/44/19/9.
        let pct = |x: f64| x / a.total * 100.0;
        assert!((pct(a.buffers) - 28.0).abs() < 4.0, "buffers {}%", pct(a.buffers));
        assert!((pct(a.mult_acc) - 44.0).abs() < 4.0, "mult {}%", pct(a.mult_acc));
        assert!((pct(a.rc) - 19.0).abs() < 4.0, "rc {}%", pct(a.rc));
        assert!(
            (pct(a.controller) - 9.0).abs() < 4.0,
            "ctrl {}%",
            pct(a.controller)
        );
        // 23% reuse overhead ±4 points.
        assert!(
            (a.overhead_fraction() * 100.0 - 23.0).abs() < 4.0,
            "overhead {}%",
            a.overhead_fraction() * 100.0
        );
    }

    #[test]
    fn baseline_has_no_reuse_area() {
        let m = AreaModel::default();
        let base = m.area(&AcceleratorConfig::baseline());
        assert_eq!(base.rc, 0.0);
        assert_eq!(base.reuse_overhead, 0.0);
        let ax = m.area(&AcceleratorConfig::paper());
        assert!(ax.total > base.total);
        // AxLLM − baseline = exactly the reuse overhead.
        assert!((ax.total - base.total - ax.reuse_overhead).abs() < 1e-6);
    }

    #[test]
    fn area_scales_with_lanes_and_buffers() {
        let m = AreaModel::default();
        let small = m.area(&AcceleratorConfig {
            lanes: 16,
            ..AcceleratorConfig::paper()
        });
        let big = m.area(&AcceleratorConfig {
            buffer_entries: 512,
            ..AcceleratorConfig::paper()
        });
        let paper = m.area(&AcceleratorConfig::paper());
        assert!(small.total < paper.total);
        assert!(big.buffers > paper.buffers * 1.8);
    }

    #[test]
    fn lower_bitwidth_shrinks_rc() {
        let m = AreaModel::default();
        let mut cfg = AcceleratorConfig::paper();
        cfg.weight_bits = 4;
        let a4 = m.area(&cfg);
        let a8 = m.area(&AcceleratorConfig::paper());
        assert!(a4.rc < a8.rc / 10.0, "4-bit RC should be 16× smaller");
    }
}
