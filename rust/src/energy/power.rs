//! Activity-factor energy model.
//!
//! `EnergyModel` turns the simulator's [`SimStats`] counters into an energy
//! breakdown using per-operation energies for 15nm-class logic. Absolute
//! anchoring to the paper's "0.94 W baseline on one DistilBERT layer"
//! happens via a single calibration factor (see [`EnergyModel::calibrate`]);
//! every *relative* claim (−28% energy, multiplier-energy dominance) is
//! driven purely by measured activity ratios.
//!
//! ### Power vs. energy in the paper
//!
//! The paper reports "average power ... reduced from 0.94 W to 0.67 W" and
//! "28% lower energy". Those are mutually consistent only at equal runtime,
//! while AxLLM also runs 1.87× faster — running faster at lower total
//! energy *raises* instantaneous power. We therefore reproduce the figure
//! the claims support: **energy consumption normalized to the baseline's
//! runtime** (`iso_time_power`), which makes "0.94 W → 0.67 W" and "−28%
//! energy" the same statement. `EXPERIMENTS.md` discusses this.

use crate::sim::SimStats;

/// Per-operation dynamic energies in pJ (15nm-class, pre-calibration).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnergyModel {
    /// 8×8-bit multiply + accumulator update.
    pub mult_pj: f64,
    /// W_buff read per element (8-bit SRAM access slice).
    pub w_read_pj: f64,
    /// Out_buff write per partial sum (16-bit).
    pub out_write_pj: f64,
    /// Result-cache access (16-bit flop-array read or write).
    pub rc_access_pj: f64,
    /// 32-bit adder-tree addition.
    pub add_pj: f64,
    /// Collision/output queue push+pop pair.
    pub queue_pj: f64,
    /// Controller + clock per lane-cycle.
    pub ctrl_cycle_pj: f64,
    /// Input-register load.
    pub x_load_pj: f64,
    /// Global calibration multiplier (see [`EnergyModel::calibrate`]).
    pub calibration: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            mult_pj: 2.1,
            w_read_pj: 0.30,
            out_write_pj: 0.45,
            rc_access_pj: 0.70,
            add_pj: 0.15,
            queue_pj: 0.05,
            ctrl_cycle_pj: 0.08,
            x_load_pj: 0.10,
            calibration: 1.0,
        }
    }
}

/// Energy breakdown in pJ.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyReport {
    /// Multiplier + accumulator energy.
    pub mult_pj: f64,
    /// W_buff/Out_buff/input-register energy.
    pub buffer_pj: f64,
    /// Result-Cache access energy.
    pub rc_pj: f64,
    /// Adder-tree energy.
    pub adder_pj: f64,
    /// Collision/output queue energy.
    pub queue_pj: f64,
    /// Controller + clock energy.
    pub ctrl_pj: f64,
    /// Sum of all components.
    pub total_pj: f64,
}

impl EnergyModel {
    /// Energy of a simulated run.
    pub fn energy(&self, s: &SimStats) -> EnergyReport {
        let c = self.calibration;
        let mult_pj = s.mults as f64 * self.mult_pj * c;
        let buffer_pj = (s.w_reads as f64 * self.w_read_pj
            + s.out_writes as f64 * self.out_write_pj
            + s.x_loads as f64 * self.x_load_pj)
            * c;
        let rc_pj = (s.rc_reads + s.rc_writes) as f64 * self.rc_access_pj * c;
        let adder_pj = s.adds as f64 * self.add_pj * c;
        let queue_pj = s.queue_ops as f64 * self.queue_pj * c;
        let ctrl_pj = s.cycles as f64 * self.ctrl_cycle_pj * c;
        EnergyReport {
            mult_pj,
            buffer_pj,
            rc_pj,
            adder_pj,
            queue_pj,
            ctrl_pj,
            total_pj: mult_pj + buffer_pj + rc_pj + adder_pj + queue_pj + ctrl_pj,
        }
    }

    /// True average power in W over the run's own duration.
    pub fn avg_power_w(&self, s: &SimStats, freq_ghz: f64) -> f64 {
        let t_ns = s.cycles as f64 / freq_ghz;
        self.energy(s).total_pj / t_ns * 1e-3
    }

    /// Energy normalized to a *reference* runtime (the paper's power
    /// figure; see module docs): `E / t_ref`.
    pub fn iso_time_power_w(&self, s: &SimStats, ref_cycles: u64, freq_ghz: f64) -> f64 {
        let t_ns = ref_cycles as f64 / freq_ghz;
        self.energy(s).total_pj / t_ns * 1e-3
    }

    /// Return a copy whose calibration makes `reference` dissipate
    /// `target_w` average power at `freq_ghz` — used to anchor the
    /// DistilBERT baseline layer at the paper's 0.94 W.
    pub fn calibrate(&self, reference: &SimStats, target_w: f64, freq_ghz: f64) -> EnergyModel {
        let current = self.avg_power_w(reference, freq_ghz);
        assert!(current > 0.0, "reference run has no activity");
        EnergyModel {
            calibration: self.calibration * target_w / current,
            ..*self
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(mults: u64, hits: u64) -> SimStats {
        let n = mults + hits;
        // With reuse: every miss fills the RC; without (hits == 0, the
        // multiply-only baseline) the RC does not exist.
        let reuse = hits > 0;
        SimStats {
            cycles: mults * 3 + hits,
            elements: n,
            mults,
            rc_hits: hits,
            rc_reads: hits,
            rc_writes: if reuse { mults } else { 0 },
            w_reads: n,
            out_writes: n,
            adds: n,
            x_loads: 1,
            ..Default::default()
        }
    }

    #[test]
    fn baseline_energy_dominated_by_multipliers() {
        let m = EnergyModel::default();
        let e = m.energy(&stats(1000, 0));
        assert!(e.mult_pj / e.total_pj > 0.5, "mult share too small");
        assert_eq!(e.rc_pj, 0.0);
    }

    #[test]
    fn reuse_cuts_energy_about_28_percent_at_70_reuse() {
        // The headline claim: at ~70% reuse the energy drops ≈28%.
        let m = EnergyModel::default();
        let base = m.energy(&stats(1000, 0));
        let ax = m.energy(&stats(300, 700));
        let ratio = ax.total_pj / base.total_pj;
        assert!(
            (0.65..0.80).contains(&ratio),
            "energy ratio {ratio} not near 0.72"
        );
    }

    #[test]
    fn calibration_hits_target() {
        let m = EnergyModel::default();
        let s = stats(500, 500);
        let cal = m.calibrate(&s, 0.94, 1.0);
        let p = cal.avg_power_w(&s, 1.0);
        assert!((p - 0.94).abs() < 1e-9, "calibrated power {p}");
    }

    #[test]
    fn iso_time_power_tracks_energy_ratio() {
        let m = EnergyModel::default();
        let base = stats(1000, 0);
        let ax = stats(300, 700);
        let p_base = m.iso_time_power_w(&base, base.cycles, 1.0);
        let p_ax = m.iso_time_power_w(&ax, base.cycles, 1.0);
        let e_ratio = m.energy(&ax).total_pj / m.energy(&base).total_pj;
        assert!((p_ax / p_base - e_ratio).abs() < 1e-12);
    }

    #[test]
    fn energy_components_sum_to_total() {
        let m = EnergyModel::default();
        let e = m.energy(&stats(123, 456));
        let sum = e.mult_pj + e.buffer_pj + e.rc_pj + e.adder_pj + e.queue_pj + e.ctrl_pj;
        assert!((sum - e.total_pj).abs() < 1e-9);
    }

    #[test]
    fn power_scales_with_frequency() {
        let m = EnergyModel::default();
        let s = stats(100, 100);
        assert!(m.avg_power_w(&s, 2.0) > m.avg_power_w(&s, 1.0) * 1.9);
    }
}
