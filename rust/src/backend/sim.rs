//! Pure-simulation backend: serve traffic with cycle/energy attribution
//! and no functional execution at all.
//!
//! One token is simulated through every weight matrix of the model at
//! construction time (row-sampled for Llama-scale matrices); serving then
//! scales those per-token counters by each batch's token count. `exec_s`
//! is the **simulated accelerator service time** — the latency the batch
//! would take on the modeled hardware — so queueing metrics stay
//! meaningful without any host execution. Logits are empty: this backend
//! exists for CI serving paths, capacity studies, and batcher tests where
//! no artifact directory (and no PJRT runtime) is available.

use crate::backend::{BatchOutcome, CostModel, ExecutionBackend, COST_SAMPLE_ROWS, DEFAULT_SEQ_LIMIT};
use crate::config::{AcceleratorConfig, ModelConfig};
use crate::model::Model;
use crate::sim::SimStats;
use crate::workload::Request;
use anyhow::Result;

/// Cycle-attribution-only execution backend.
pub struct SimBackend {
    model_name: String,
    cost: CostModel,
    per_token: SimStats,
    seq_limit: usize,
    paced: bool,
}

impl SimBackend {
    /// Simulate one token of `model_cfg` on builder-validated
    /// accelerators (AxLLM and multiply-only baseline) and cache the
    /// per-token costs.
    pub fn new(model_cfg: ModelConfig, acc_cfg: AcceleratorConfig) -> Result<SimBackend> {
        let model = Model::new(model_cfg, 11);
        let (cost, ax_run) = CostModel::from_sampled(&model, acc_cfg, COST_SAMPLE_ROWS)?;
        Ok(SimBackend {
            model_name: ax_run.model,
            cost,
            per_token: ax_run.total,
            seq_limit: DEFAULT_SEQ_LIMIT,
            paced: false,
        })
    }

    /// Override the per-request sequence cap (default
    /// [`DEFAULT_SEQ_LIMIT`]).
    pub fn with_seq_limit(mut self, seq: usize) -> SimBackend {
        self.seq_limit = seq.max(1);
        self
    }

    /// When paced, `run_batch` *sleeps* for the simulated accelerator
    /// service time instead of returning instantly. Live serving uses
    /// this so a sim-backed worker is occupied for as long as the modeled
    /// hardware would be — queueing dynamics and replica scaling then
    /// behave like the modeled deployment instead of degenerating to
    /// zero-cost execution. Trace-driven serving should stay unpaced.
    pub fn with_paced(mut self, paced: bool) -> SimBackend {
        self.paced = paced;
        self
    }

    /// Name of the simulated model.
    pub fn model_name(&self) -> &str {
        &self.model_name
    }
}

impl ExecutionBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn max_batch(&self) -> usize {
        // No compiled shape to respect — the batching policy is the only
        // batch-size bound.
        usize::MAX
    }

    fn seq_limit(&self) -> usize {
        self.seq_limit
    }

    fn n_classes(&self) -> usize {
        0
    }

    fn cost(&self) -> &CostModel {
        &self.cost
    }

    fn run_batch(&self, requests: &[Request]) -> crate::Result<BatchOutcome> {
        let tokens: u64 = requests
            .iter()
            .map(|r| r.seq_len.min(self.seq_limit) as u64)
            .sum();
        let exec_s = self.cost.sim_time_s(tokens);
        if self.paced {
            std::thread::sleep(std::time::Duration::from_secs_f64(exec_s));
        }
        Ok(BatchOutcome {
            logits: vec![Vec::new(); requests.len()],
            exec_s,
            stats: self.per_token.scaled(tokens, 1),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Dataset;

    fn req(id: u64, seq_len: usize) -> Request {
        Request {
            id,
            dataset: Dataset::Imdb,
            seq_len,
            arrival_s: id as f64 * 0.001,
        }
    }

    #[test]
    fn sim_backend_attributes_per_token() {
        let b = SimBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper()).unwrap();
        assert_eq!(b.name(), "sim");
        assert!(b.cost().speedup() > 1.3);
        let one = b.run_batch(&[req(0, 16)]).unwrap();
        let two = b.run_batch(&[req(0, 16), req(1, 16)]).unwrap();
        assert_eq!(one.logits, vec![Vec::<f32>::new()]);
        assert!(two.exec_s > one.exec_s);
        assert_eq!(two.stats.elements, 2 * one.stats.elements);
        assert!(one.stats.cycles > 0);
    }

    #[test]
    fn sim_backend_truncates_to_seq_limit() {
        let b = SimBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper()).unwrap();
        let capped = b.run_batch(&[req(0, 10_000)]).unwrap();
        let exact = b.run_batch(&[req(0, DEFAULT_SEQ_LIMIT)]).unwrap();
        assert_eq!(capped.stats, exact.stats);
    }

    #[test]
    fn paced_run_batch_occupies_the_worker() {
        let b = SimBackend::new(ModelConfig::tiny(), AcceleratorConfig::paper())
            .unwrap()
            .with_paced(true);
        let reqs: Vec<Request> = (0..8).map(|i| req(i, 32)).collect();
        let t0 = std::time::Instant::now();
        let out = b.run_batch(&reqs).unwrap();
        // sleep() guarantees at-least semantics, so wall time bounds the
        // simulated service time from above.
        assert!(t0.elapsed().as_secs_f64() >= out.exec_s);
        assert!(out.exec_s > 0.0);
    }

    #[test]
    fn sim_backend_rejects_invalid_sizing() {
        let bad = AcceleratorConfig {
            lanes: 0,
            ..AcceleratorConfig::paper()
        };
        assert!(SimBackend::new(ModelConfig::tiny(), bad).is_err());
    }
}
